"""End-to-end serving driver: batched ANN requests against a *mutable*
DET-LSH index — the paper's deployment scenario (rapid index build,
immediate serving) extended with live traffic, now through the
epoch-pinned ``ServingRuntime`` (docs/DESIGN.md §9): points arrive and
disappear while queries run, sealing delta segments and triggering
compaction; hopeless deadlines are shed with an explicit ``Rejected``;
injected engine and compaction faults recover with bit-identical answers.
The finale snapshots the live index and restarts the service from the
snapshot — no rebuild; a durability phase serves a WAL-backed
``DurableIndex``, kills it with an un-checkpointed tail, and recovers it
bit-identically (docs/DESIGN.md §13); and a last phase serves the
*sharded* PDET index on a forced 4-device host mesh, bit-identical to
its single-device twin (docs/DESIGN.md §7).

  PYTHONPATH=src python examples/vector_search_service.py
"""

import os

# The PDET phase wants a multi-device mesh; on a CPU host we force four
# host-platform devices (must happen before jax initializes).
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import dataclasses
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

import repro
from repro.api import IndexSpec, PlacementSpec, SearchRequest
from repro.serving import (Answer, COMPACTION_SWAP, ENGINE_CALL, FaultPlan,
                           InjectedFault, Rejected, ServingRuntime)


def main():
    rng = np.random.default_rng(1)
    n, d, n_requests = 20000, 48, 96

    centers = rng.standard_normal((32, d)).astype(np.float32)

    def draw(m):
        return (centers[rng.integers(0, 32, m)]
                + 0.25 * rng.standard_normal((m, d)).astype(np.float32))

    data = draw(n)

    t0 = time.perf_counter()
    spec = IndexSpec(kind="streaming", K=4, L=8, c=1.5, beta_override=0.05,
                     delta_capacity=1024, max_segments=3)
    index = repro.api.build(jnp.asarray(data), jax.random.key(0), spec)
    jax.block_until_ready(index.manifest.segments[0].forest.point_ids)
    print(f"index built in {time.perf_counter() - t0:.2f}s "
          f"({index.index_size_bytes() / 1e6:.1f} MB, "
          f"{index.n_live} live points)")

    # Explicit r_min pins the search radius, so every equality below —
    # retry vs baseline, restart vs live — compares like with like.
    base_req = SearchRequest(k=10, r_min=float(index.r_min_for(10)))
    plan = FaultPlan()
    # max_wait 50ms: closed-loop submits are µs apart, so bursts coalesce
    # into full buckets (one compiled batch shape) instead of fragmenting.
    rt = ServingRuntime(index, k=10, max_batch=32, pad_to=32,
                        max_wait_ms=50.0, fault_plan=plan, request=base_req)
    rt.warmup(d)

    def queries(m):
        return [data[rng.integers(0, n)]
                + 0.05 * rng.standard_normal(d).astype(np.float32)
                for _ in range(m)]

    def stream(vecs, deadline=None):
        # serve() iterates lazily, so arrivals are stamped at submit time —
        # pre-stamping a whole burst makes every request look old after the
        # first batch's service time and fragments the batching.
        return ((time.perf_counter(), v, deadline) for v in vecs)

    # Phase 1: read-only traffic against the base build.
    results = rt.serve(stream(queries(n_requests)))
    assert all(isinstance(o, Answer) for o in results)
    print(f"phase 1 (static): served {len(results)}: {rt.stats.summary()}")

    # Phase 2: live traffic — interleave upserts/deletes with query bursts.
    # Mutations are barriers (queued queries answer first); seals happen at
    # delta capacity and compaction fires via the runtime trigger.
    t0 = time.perf_counter()
    for round_ in range(4):
        fresh = draw(800)
        gids = rt.upsert(fresh)
        rt.delete(gids[::7])                       # churn: drop every 7th
        rt.delete(rng.integers(0, n, 100))         # and some base points
        burst = rt.serve(stream(queries(32)))
        assert len(burst) == 32
    rt.delete(np.arange(10**8, 10**8 + 5))         # counted no-op deletes
    print(f"phase 2 (live churn, {time.perf_counter() - t0:.2f}s): "
          f"{rt.stats.summary()}")
    print(f"index now: {index.stats()}")

    # A just-upserted point must be findable right away.
    probe = draw(1)[0]
    [gid] = rt.upsert(probe)
    ans, = rt.serve([(time.perf_counter(), probe)])
    assert int(ans.ids[0]) == int(gid) and ans.dists[0] < 1e-3
    print(f"fresh upsert gid={int(gid)} served with dist={ans.dists[0]:.2g}")

    rt.delete([gid])
    ans, = rt.serve([(time.perf_counter(), probe)])
    assert int(ans.ids[0]) != int(gid)
    print(f"...and invisible immediately after delete "
          f"(top hit now gid={int(ans.ids[0])})")

    # Load shedding is explicit: a request whose deadline already passed is
    # rejected with a reason, never silently dropped or silently late.
    past = time.perf_counter() - 1.0
    shed = rt.serve(stream(queries(8), deadline=past))
    assert all(isinstance(o, Rejected) and o.reason == "deadline"
               for o in shed)
    print(f"hopeless deadlines shed explicitly: {rt.stats.summary()['shed']}")

    fault_recovery_phase(rt, index, plan, queries, stream, base_req)

    # Snapshot the live index (segments + tombstones + un-sealed delta
    # rows) and restart the service from disk — the rebuild the paper's
    # rapid-indexing pitch exists to avoid now happens zero times.
    with tempfile.TemporaryDirectory() as tmp:
        t0 = time.perf_counter()
        index.save(tmp)
        restored = repro.api.load(tmp)
        print(f"snapshot save+load in {time.perf_counter() - t0:.2f}s "
              f"({restored.n_live} live points restored)")
        rt2 = ServingRuntime(restored, k=10, max_batch=32, pad_to=32,
                             request=base_req)
        probe2 = draw(1)[0]
        before, = rt.serve([(time.perf_counter(), probe2)])
        after, = rt2.serve([(time.perf_counter(), probe2)])
        assert np.array_equal(before.ids, after.ids)
        assert np.array_equal(before.dists, after.dists)
        print("restarted service answers bit-identically from the snapshot")

    # Phase 3: durability — serve a WAL-backed index, kill it mid-flight,
    # recover the root, and keep serving with bit-identical answers.
    kill_and_recover_phase(draw, base_req)

    # Phase 4: the sharded PDET index, served through the same runtime.
    serve_pdet(data, draw)


def kill_and_recover_phase(draw, base_req):
    """DurableIndex lifecycle (docs/DESIGN.md §13): WAL-logged mutations,
    a kill with an un-checkpointed tail, and bit-identical recovery."""
    from repro.core import derive_params
    from repro.durability import DurableIndex, recover
    from repro.streaming import StreamingDETLSH

    rng = np.random.default_rng(13)
    base = draw(4000)
    p = derive_params(K=4, c=1.5, L=8, beta_override=0.05)
    idx = StreamingDETLSH.build(jnp.asarray(base), jax.random.key(5), p,
                                delta_capacity=1024, max_segments=3)

    with tempfile.TemporaryDirectory() as tmp:
        root = os.path.join(tmp, "durable")
        durable = DurableIndex.create(idx, root, checkpoint_bytes=1 << 22)
        rt = ServingRuntime(durable, k=10, max_batch=32, pad_to=32,
                            request=base_req)
        t0 = time.perf_counter()
        for _ in range(3):
            gids = rt.upsert(draw(600))
            rt.delete(gids[::9])
        s = rt.stats.summary()
        print(f"\ndurability phase: {time.perf_counter() - t0:.2f}s of "
              f"WAL-logged churn (wal_bytes={s['wal_bytes']}, "
              f"fsyncs={s['fsyncs']}, checkpoints={s['checkpoints']})")

        probes = np.stack([draw(1)[0] for _ in range(16)])
        before = durable.search(jnp.asarray(probes), base_req)
        digest = durable.state_digest()
        durable.wal._f.close()       # the kill: no flush, no final snapshot

        t0 = time.perf_counter()
        recovered = recover(root)
        report = recovered.last_recovery
        print(f"recovered in {time.perf_counter() - t0:.2f}s from "
              f"{report.checkpoint}, replayed {report.n_replayed} WAL "
              f"records (torn_bytes={report.torn_bytes})")
        assert recovered.state_digest() == digest
        after = recovered.search(jnp.asarray(probes), base_req)
        assert np.array_equal(np.asarray(before.ids), np.asarray(after.ids))
        assert np.array_equal(np.asarray(before.dists),
                              np.asarray(after.dists))

        # ...and the recovered index serves + mutates like nothing happened
        rt2 = ServingRuntime(recovered, k=10, max_batch=32, pad_to=32,
                             request=base_req)
        assert rt2.stats.summary()["recovery_replayed"] == report.n_replayed
        rt2.upsert(draw(64))
        out = rt2.serve((time.perf_counter(), q) for q in probes)
        assert all(isinstance(o, Answer) for o in out)
        recovered.close()
        print("recovered index answers bit-identically and keeps serving")


def fault_recovery_phase(rt, index, plan, queries, stream, base_req):
    """Inject the §9 faults live and prove recovery is bit-identical."""
    probes = queries(32)

    # Engine-call failure: one retry on the vmap semantics-of-record
    # engine.  32 probes = exactly one batch, so the whole serve runs on
    # the retry path — and its answers must be bit-identical to a
    # fault-free serialized run on that same engine.
    retries0 = rt.stats.retries
    plan.arm(ENGINE_CALL, times=1)
    recovered = rt.serve(stream(probes))
    assert rt.stats.retries == retries0 + 1
    assert all(isinstance(o, Answer) for o in recovered)
    oracle = index.search(
        jnp.asarray(np.stack(probes)),
        dataclasses.replace(base_req, engine="vmap", n_active=len(probes)))
    oids, odists = np.asarray(oracle.ids), np.asarray(oracle.dists)
    for i, a in enumerate(recovered):
        assert np.array_equal(a.ids, oids[i])
        assert np.array_equal(a.dists, odists[i])
    print(f"engine fault: retried on vmap, {len(recovered)} answers "
          f"bit-identical to a fault-free run on the retry engine")

    # Compaction crash mid-swap: the manifest stays on the pre-swap epoch,
    # a pinned reader keeps answering identically through the crash AND
    # through the successful retry (RCU), and live traffic still matches.
    qs = jnp.asarray(np.stack(probes[:8]))
    req = dataclasses.replace(base_req, n_active=8)
    epoch = rt.pin()
    before = epoch.search(qs, req)
    v0 = index.manifest.version
    plan.arm(COMPACTION_SWAP, times=1)
    assert rt.compact(force=True) is False
    assert isinstance(rt.last_compaction_error, InjectedFault)
    assert index.manifest.version == v0          # pre-swap epoch intact
    assert rt.compact(force=True) is True        # retried swap completes
    after = epoch.search(qs, req)
    assert np.array_equal(np.asarray(before.ids), np.asarray(after.ids))
    assert np.array_equal(np.asarray(before.dists),
                          np.asarray(after.dists))
    rt.release(epoch)
    live = rt.serve(stream(probes))
    assert all(isinstance(o, Answer) for o in live)
    print(f"compaction crash: recovered to pre-swap epoch, pinned reader "
          f"bit-identical across the retried swap "
          f"(crashes={rt.stats.compaction_crashes}, "
          f"compactions={rt.stats.compactions})")


def serve_pdet(data, draw):
    n_dev = len(jax.devices())
    shards = max(s for s in (4, 2, 1) if n_dev >= s)
    base = IndexSpec(kind="static", K=4, L=8, c=1.5, beta_override=0.05,
                     leaf_size=64)
    spec = dataclasses.replace(
        base, placement=PlacementSpec(mesh_shape=(shards,),
                                      mesh_axes=("data",)))
    t0 = time.perf_counter()
    pdet = repro.api.build(jnp.asarray(data), jax.random.key(7), spec)
    det = repro.api.build(jnp.asarray(data), jax.random.key(7), base)
    print(f"\nPDET phase: {shards}-shard mesh "
          f"({time.perf_counter() - t0:.2f}s for both builds)")

    # Immutable indexes get trivial epochs — the same runtime serves them.
    rt = ServingRuntime(pdet, k=10, max_batch=32, pad_to=32)
    rt.warmup(data.shape[1])
    probes = [draw(1)[0] for _ in range(48)]
    results = rt.serve((time.perf_counter(), p) for p in probes)
    assert all(isinstance(o, Answer) for o in results)
    print(f"served {len(results)} via PDET: {rt.stats.summary()}")

    req = SearchRequest(k=10, r_min=0.5)
    a = pdet.search(jnp.asarray(np.stack(probes[:16])), req)
    b = det.search(jnp.asarray(np.stack(probes[:16])),
                   SearchRequest(k=10, r_min=0.5, engine="fused"))
    assert np.array_equal(np.asarray(a.ids), np.asarray(b.ids))
    assert np.array_equal(np.asarray(a.dists), np.asarray(b.dists))
    print(f"PDET == DET bit-identical over {shards} shards "
          f"(engine={a.stats.engine}, per-shard candidates="
          f"{np.asarray(a.stats.shard_candidates).tolist()}, "
          f"psum_rounds={int(a.stats.psum_rounds)})")

    # Sharded snapshot: per-shard files, reshard-on-load.
    with tempfile.TemporaryDirectory() as tmp:
        pdet.save(tmp)
        halved = repro.api.load(
            tmp, placement=PlacementSpec(mesh_shape=(max(shards // 2, 1),),
                                         mesh_axes=("data",)))
        c = halved.search(jnp.asarray(np.stack(probes[:16])), req)
        assert np.array_equal(np.asarray(c.ids), np.asarray(a.ids))
        print(f"snapshot resharded {shards} -> {halved.n_shards} shards; "
              f"answers unchanged")


if __name__ == "__main__":
    main()
