"""End-to-end serving driver: batched ANN requests against a DET-LSH index
(the paper's deployment scenario — rapid index build, immediate serving).

  PYTHONPATH=src python examples/vector_search_service.py
"""

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core import DETLSH, derive_params
from repro.serving.lsh_service import LSHService


def main():
    rng = np.random.default_rng(1)
    n, d, n_requests = 20000, 48, 96

    centers = rng.standard_normal((32, d)).astype(np.float32)
    data = centers[rng.integers(0, 32, n)] \
        + 0.25 * rng.standard_normal((n, d)).astype(np.float32)

    t0 = time.perf_counter()
    params = derive_params(K=4, c=1.5, L=8, beta_override=0.05)
    index = DETLSH.build(jnp.asarray(data), jax.random.key(0), params)
    jax.block_until_ready(index.forest.point_ids)
    print(f"index built in {time.perf_counter() - t0:.2f}s "
          f"({index.index_size_bytes() / 1e6:.1f} MB)")

    svc = LSHService(index, k=10, max_batch=32, pad_to=32)
    svc.warmup(d)

    now = time.perf_counter()
    stream = [(now, data[rng.integers(0, n)]
               + 0.05 * rng.standard_normal(d).astype(np.float32))
              for _ in range(n_requests)]
    results = svc.serve(stream)
    print(f"served {len(results)} requests: {svc.stats.summary()}")
    ids0, d0 = results[0]
    print(f"first result ids={ids0[:5]} dists={np.round(d0[:5], 3)}")


if __name__ == "__main__":
    main()
