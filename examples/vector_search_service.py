"""End-to-end serving driver: batched ANN requests against a *mutable*
DET-LSH index — the paper's deployment scenario (rapid index build,
immediate serving) extended with live traffic: points arrive and disappear
while queries run, sealing delta segments and triggering compaction.
Everything goes through the unified ``repro.api`` surface, the finale
snapshots the live index and restarts the service from the snapshot — no
rebuild — and a last phase serves the *sharded* PDET index on a forced
4-device host mesh, bit-identical to its single-device twin
(docs/DESIGN.md §7).

  PYTHONPATH=src python examples/vector_search_service.py
"""

import os

# The PDET phase wants a multi-device mesh; on a CPU host we force four
# host-platform devices (must happen before jax initializes).
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

import repro
from repro.api import IndexSpec, PlacementSpec, SearchRequest
from repro.serving.lsh_service import LSHService


def main():
    rng = np.random.default_rng(1)
    n, d, n_requests = 20000, 48, 96

    centers = rng.standard_normal((32, d)).astype(np.float32)

    def draw(m):
        return (centers[rng.integers(0, 32, m)]
                + 0.25 * rng.standard_normal((m, d)).astype(np.float32))

    data = draw(n)

    t0 = time.perf_counter()
    spec = IndexSpec(kind="streaming", K=4, L=8, c=1.5, beta_override=0.05,
                     delta_capacity=1024, max_segments=3)
    index = repro.api.build(jnp.asarray(data), jax.random.key(0), spec)
    jax.block_until_ready(index.manifest.segments[0].forest.point_ids)
    print(f"index built in {time.perf_counter() - t0:.2f}s "
          f"({index.index_size_bytes() / 1e6:.1f} MB, "
          f"{index.n_live} live points)")

    svc = LSHService(index, k=10, max_batch=32, pad_to=32)
    svc.warmup(d)

    def queries(m):
        now = time.perf_counter()
        return [(now, data[rng.integers(0, n)]
                 + 0.05 * rng.standard_normal(d).astype(np.float32))
                for _ in range(m)]

    # Phase 1: read-only traffic against the base build.
    results = svc.serve(queries(n_requests))
    print(f"phase 1 (static): served {len(results)}: {svc.stats.summary()}")

    # Phase 2: live traffic — interleave upserts/deletes with query bursts.
    # Upserts land in the delta (served exactly, immediately); seals happen
    # at delta capacity and compaction fires via the service trigger.
    t0 = time.perf_counter()
    for round_ in range(4):
        fresh = draw(800)
        gids = svc.upsert(fresh)
        svc.delete(gids[::7])                      # churn: drop every 7th
        svc.delete(rng.integers(0, n, 100))        # and some base points
        burst = svc.serve(queries(32))
        assert len(burst) == 32
    print(f"phase 2 (live churn, {time.perf_counter() - t0:.2f}s): "
          f"{svc.stats.summary()}")
    print(f"index now: {index.stats()}")

    # A just-upserted point must be findable right away.
    probe = draw(1)[0]
    [gid] = svc.upsert(probe)
    (ids, dists), = svc.serve([(time.perf_counter(), probe)])
    assert int(ids[0]) == int(gid) and dists[0] < 1e-3, (ids[0], gid)
    print(f"fresh upsert gid={int(gid)} served with dist={dists[0]:.2g}")

    svc.delete([gid])
    (ids, _), = svc.serve([(time.perf_counter(), probe)])
    assert int(ids[0]) != int(gid)
    print(f"...and invisible immediately after delete "
          f"(top hit now gid={int(ids[0])})")

    # Snapshot the live index (segments + tombstones + un-sealed delta
    # rows) and restart the service from disk — the rebuild the paper's
    # rapid-indexing pitch exists to avoid now happens zero times.
    with tempfile.TemporaryDirectory() as tmp:
        t0 = time.perf_counter()
        index.save(tmp)
        restored = repro.api.load(tmp)
        print(f"snapshot save+load in {time.perf_counter() - t0:.2f}s "
              f"({restored.n_live} live points restored)")
        svc2 = LSHService(restored, k=10, max_batch=32, pad_to=32)
        probe2 = draw(1)[0]
        before, = svc.serve([(time.perf_counter(), probe2)])
        after, = svc2.serve([(time.perf_counter(), probe2)])
        assert np.array_equal(before[0], after[0])
        assert np.array_equal(before[1], after[1])
        print("restarted service answers bit-identically from the snapshot")

    # Phase 3: the sharded PDET index, served through the same protocols.
    # The placement is part of the IndexSpec; 'auto' routes to the 'pdet'
    # engine because the index carries an active mesh, and the answers are
    # bit-identical to the single-device DETLSH on the same spec minus
    # placement (DESIGN.md §7) — asserted live below.
    serve_pdet(data, draw)


def serve_pdet(data, draw):
    n_dev = len(jax.devices())
    shards = max(s for s in (4, 2, 1) if n_dev >= s)
    import dataclasses
    base = IndexSpec(kind="static", K=4, L=8, c=1.5, beta_override=0.05,
                     leaf_size=64)
    spec = dataclasses.replace(
        base, placement=PlacementSpec(mesh_shape=(shards,),
                                      mesh_axes=("data",)))
    t0 = time.perf_counter()
    pdet = repro.api.build(jnp.asarray(data), jax.random.key(7), spec)
    det = repro.api.build(jnp.asarray(data), jax.random.key(7), base)
    print(f"\nPDET phase: {shards}-shard mesh "
          f"({time.perf_counter() - t0:.2f}s for both builds)")

    svc = LSHService(pdet, k=10, max_batch=32, pad_to=32)
    svc.warmup(data.shape[1])
    probes = [draw(1)[0] for _ in range(48)]
    results = svc.serve([(time.perf_counter(), p) for p in probes])
    print(f"served {len(results)} via PDET: {svc.stats.summary()}")

    req = SearchRequest(k=10, r_min=0.5)
    a = pdet.search(jnp.asarray(np.stack(probes[:16])), req)
    b = det.search(jnp.asarray(np.stack(probes[:16])),
                   SearchRequest(k=10, r_min=0.5, engine="fused"))
    assert np.array_equal(np.asarray(a.ids), np.asarray(b.ids))
    assert np.array_equal(np.asarray(a.dists), np.asarray(b.dists))
    print(f"PDET == DET bit-identical over {shards} shards "
          f"(engine={a.stats.engine}, per-shard candidates="
          f"{np.asarray(a.stats.shard_candidates).tolist()}, "
          f"psum_rounds={int(a.stats.psum_rounds)})")

    # Sharded snapshot: per-shard files, reshard-on-load.
    with tempfile.TemporaryDirectory() as tmp:
        pdet.save(tmp)
        halved = repro.api.load(
            tmp, placement=PlacementSpec(mesh_shape=(max(shards // 2, 1),),
                                         mesh_axes=("data",)))
        c = halved.search(jnp.asarray(np.stack(probes[:16])), req)
        assert np.array_equal(np.asarray(c.ids), np.asarray(a.ids))
        print(f"snapshot resharded {shards} -> {halved.n_shards} shards; "
              f"answers unchanged")


if __name__ == "__main__":
    main()
