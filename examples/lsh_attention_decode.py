"""LSH-accelerated decode attention demo (paper §I: LSH for LLM inference).

The KV cache is an index (``repro.decode.KVCacheIndex``): prefill builds
per-(batch, kv-head) DE-Forests over the MIPS-augmented keys through the
fused build pipeline, then a multi-step decode loop runs — every step
upserts its new key into the streaming delta (live KV growth), retrieval
is a batched fused ``range_rerank`` query, and exact attention runs over
the retrieved ∪ window ∪ sink survivor set.

  PYTHONPATH=src python examples/lsh_attention_decode.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.decode import KVCacheIndex, KVSpec, LSHDecoder
from repro.models import layers as L


def main():
    rng = np.random.default_rng(0)
    b, S, hk, g, dh = 1, 4096, 4, 4, 64
    h = hk * g
    steps, prefill_len = 24, S - 32
    print(f"cache: {S} slots x {hk} kv heads x {dh} dims; "
          f"prefill {prefill_len}, decode {steps} steps")

    k_cache = jnp.asarray(rng.standard_normal((b, S, hk, dh)).astype(
        np.float32) * 0.3)
    v_cache = jnp.asarray(rng.standard_normal((b, S, hk, dh)).astype(
        np.float32))

    t0 = time.perf_counter()
    index = KVCacheIndex.prefill(k_cache[:, :prefill_len], jax.random.key(0),
                                 KVSpec(delta_capacity=64, m_top=64,
                                        max_rounds=6))
    jax.block_until_ready(index.forest.points_sorted)
    print(f"KV index prefilled in {time.perf_counter() - t0:.2f}s "
          f"({index.n_points} positions, "
          f"{index.index_size_bytes() / 2 ** 20:.1f} MiB)")

    decoder = LSHDecoder(index, window=64, sinks=4, refresh_every=4)
    cos_all = []
    planted = 0
    for t in range(steps):
        length = prefill_len + t + 1
        # query attends strongly to a planted earlier position; the target
        # moves at refresh boundaries (between refreshes the cached
        # candidate table serves the drifting-slowly query regime)
        if t % decoder.refresh_every == 0:
            planted = int(rng.integers(0, prefill_len))
        q = np.repeat(np.asarray(k_cache[:, planted])[:, :, None, :], g, 2)
        q = jnp.asarray((q * 16).reshape(b, 1, h, dh))
        k_new = k_cache[:, length - 1]                     # (b, hk, dh)

        out_lsh = decoder.step(q, k_cache, v_cache, k_new, length)
        out_full = L.decode_gqa_attention(q, k_cache, v_cache, length)
        a = np.asarray(out_lsh).reshape(-1)
        f = np.asarray(out_full).reshape(-1)
        cos_all.append(float(a @ f / (np.linalg.norm(a)
                                      * np.linalg.norm(f) + 1e-9)))

    m = index.spec.m_top + index.spec.delta_capacity + 64 + 4
    print(f"decoded {steps} steps with {decoder.n_refreshes} retrievals "
          f"(refresh_every={decoder.refresh_every}), "
          f"{index.delta.count} keys in the delta")
    print(f"positions attended per head <= {m}/{prefill_len + steps} "
          f"({100 * m / (prefill_len + steps):.1f}%)")
    print(f"cosine(lsh_decode, exact): mean={np.mean(cos_all):.4f} "
          f"min={np.min(cos_all):.4f}")


if __name__ == "__main__":
    main()
