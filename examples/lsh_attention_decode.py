"""DET-LSH-accelerated decode attention demo (paper Sec. I: LSH for LLM
inference acceleration): index a long KV cache's keys with DE-Forests,
retrieve top positions per decode step, compare against exact attention.

  PYTHONPATH=src python examples/lsh_attention_decode.py
"""

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core import det_attention as DA
from repro.models import layers as L


def main():
    rng = np.random.default_rng(0)
    b, S, hk, g, dh = 1, 4096, 4, 4, 64
    h = hk * g
    print(f"cache: {S} positions x {hk} kv heads x {dh} dims")

    k_cache = jnp.asarray(rng.standard_normal((b, S, hk, dh)).astype(
        np.float32) * 0.3)
    v_cache = jnp.asarray(rng.standard_normal((b, S, hk, dh)).astype(
        np.float32))
    # a query attending strongly to a planted position
    q = np.repeat(np.asarray(k_cache[:, 777])[:, :, None, :], g, 2) * 16
    q = jnp.asarray(q.reshape(b, 1, h, dh))

    t0 = time.perf_counter()
    index = DA.build_kv_index(k_cache, jax.random.key(0))
    jax.block_until_ready(index.point_ids)
    print(f"KV index built in {time.perf_counter() - t0:.2f}s")

    out_full = L.decode_gqa_attention(q, k_cache, v_cache, S)
    out_det = DA.det_decode_attention(q, k_cache, v_cache, index, S,
                                      m_leaves=16, window=64, sinks=4)
    a = np.asarray(out_det).reshape(-1)
    f = np.asarray(out_full).reshape(-1)
    cos = float(a @ f / (np.linalg.norm(a) * np.linalg.norm(f) + 1e-9))
    scanned = 16 * index.leaf_size + 64 + 4
    print(f"positions scanned per head: {scanned}/{S} "
          f"({100 * scanned / S:.1f}%)")
    print(f"cosine(det_attention, exact) = {cos:.4f}")


if __name__ == "__main__":
    main()
