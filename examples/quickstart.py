"""Quickstart against the unified ``repro.api`` surface: declare an
IndexSpec, build, answer typed c^2-k-ANN searches, check the theoretical
guarantee, then snapshot and reload the index without a rebuild.

  PYTHONPATH=src python examples/quickstart.py
"""

import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

import repro
from repro.api import IndexSpec, SearchRequest


def main():
    rng = np.random.default_rng(0)
    n, d, nq, k = 30000, 64, 32, 10

    # clustered synthetic vectors (image-descriptor-like)
    centers = rng.standard_normal((64, d)).astype(np.float32)
    data = centers[rng.integers(0, 64, n)] \
        + 0.2 * rng.standard_normal((n, d)).astype(np.float32)
    queries = data[rng.choice(n, nq, replace=False)] \
        + 0.05 * rng.standard_normal((nq, d)).astype(np.float32)

    # one declarative build config — paper parameters: K=4, L=16
    # (PDET recommendation, Sec. VI-C3), c=1.5
    spec = IndexSpec(kind="static", K=4, L=16, c=1.5, beta_override=0.1)
    params = spec.derive_params()
    print(f"spec: {spec.kind} K={spec.K} L={spec.L} c={spec.c} -> "
          f"eps={params.epsilon:.3f} beta={params.beta:.3f} "
          f"success_prob>={params.success_probability:.3f}")

    index = repro.api.build(jnp.asarray(data), jax.random.key(0), spec)
    print(f"index: {index.index_size_bytes() / 1e6:.1f} MB, "
          f"L={params.L} trees, {index.forest.n_leaves} leaves each, "
          f"n_points={index.n_points}")

    # typed per-request overrides; r_min=None uses the per-(index, k) cache
    res = index.search(jnp.asarray(queries), SearchRequest(k=k, M=12))
    print(f"search: engine={res.stats.engine} "
          f"r_min={res.stats.r_min:.3f} (cached={res.stats.r_min_cached})")

    # ground truth + quality
    d2 = ((queries[:, None, :] - data[None, :, :]) ** 2).sum(-1)
    gt = np.argsort(d2, 1)[:, :k]
    gt_d = np.sqrt(np.sort(d2, 1)[:, :k])
    ids = np.asarray(res.ids)
    recall = np.mean([len(set(ids[i]) & set(gt[i])) / k for i in range(nq)])
    ratio = float(np.mean(np.asarray(res.dists) / np.maximum(gt_d, 1e-9)))
    ok = np.all(np.asarray(res.dists) <= params.c ** 2 * gt_d + 1e-4, axis=1)
    print(f"recall@{k}: {recall:.3f}   overall ratio: {ratio:.4f}")
    print(f"c^2 guarantee held on {ok.mean() * 100:.1f}% of queries "
          f"(bound: >={params.success_probability * 100:.1f}%)")
    assert ok.mean() >= params.success_probability

    # snapshot persistence: a service restart skips the rebuild entirely
    with tempfile.TemporaryDirectory() as tmp:
        index.save(tmp)
        reloaded = repro.api.load(tmp)
        res2 = reloaded.search(jnp.asarray(queries), SearchRequest(k=k, M=12))
        assert np.array_equal(np.asarray(res.ids), np.asarray(res2.ids))
        assert np.array_equal(np.asarray(res.dists), np.asarray(res2.dists))
        print("snapshot: save -> load -> search is bit-identical "
              "(no rebuild)")


if __name__ == "__main__":
    main()
