"""Quickstart: build a DET-LSH index, answer c^2-k-ANN queries, check the
theoretical guarantee.

  PYTHONPATH=src python examples/quickstart.py
"""

import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core import DETLSH, derive_params


def main():
    rng = np.random.default_rng(0)
    n, d, nq, k = 30000, 64, 32, 10

    # clustered synthetic vectors (image-descriptor-like)
    centers = rng.standard_normal((64, d)).astype(np.float32)
    data = centers[rng.integers(0, 64, n)] \
        + 0.2 * rng.standard_normal((n, d)).astype(np.float32)
    queries = data[rng.choice(n, nq, replace=False)] \
        + 0.05 * rng.standard_normal((nq, d)).astype(np.float32)

    # paper parameters: K=4, L=16 (PDET recommendation, Sec. VI-C3), c=1.5
    params = derive_params(K=4, c=1.5, L=16, beta_override=0.1)
    print(f"params: eps={params.epsilon:.3f} beta={params.beta:.3f} "
          f"success_prob>={params.success_probability:.3f}")

    index = DETLSH.build(jnp.asarray(data), jax.random.key(0), params)
    print(f"index: {index.index_size_bytes() / 1e6:.1f} MB, "
          f"L={params.L} trees, {index.forest.n_leaves} leaves each")

    res = index.query(jnp.asarray(queries), k=k, M=12)

    # ground truth + quality
    d2 = ((queries[:, None, :] - data[None, :, :]) ** 2).sum(-1)
    gt = np.argsort(d2, 1)[:, :k]
    gt_d = np.sqrt(np.sort(d2, 1)[:, :k])
    ids = np.asarray(res.ids)
    recall = np.mean([len(set(ids[i]) & set(gt[i])) / k for i in range(nq)])
    ratio = float(np.mean(np.asarray(res.dists) / np.maximum(gt_d, 1e-9)))
    ok = np.all(np.asarray(res.dists) <= params.c ** 2 * gt_d + 1e-4, axis=1)
    print(f"recall@{k}: {recall:.3f}   overall ratio: {ratio:.4f}")
    print(f"c^2 guarantee held on {ok.mean() * 100:.1f}% of queries "
          f"(bound: >={params.success_probability * 100:.1f}%)")
    assert ok.mean() >= params.success_probability


if __name__ == "__main__":
    main()
