"""End-to-end training driver: train a reduced assigned-architecture LM on
the deterministic synthetic pipeline with checkpoint/resume.

  PYTHONPATH=src python examples/train_lm.py --arch qwen3-1.7b --steps 100
  (add --no-reduced on a real pod to train the full config)
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--no-reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    argv = ["--arch", args.arch, "--steps", str(args.steps),
            "--batch", "8", "--seq", "64", "--ckpt-dir", args.ckpt_dir,
            "--ckpt-every", "25"]
    if not args.no_reduced:
        argv.append("--reduced")
    return train_main(argv)


if __name__ == "__main__":
    sys.exit(main())
