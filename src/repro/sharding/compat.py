"""jax cross-version compatibility for shard_map.

The codebase targets the current jax API (top-level ``jax.shard_map`` with
``check_vma=``); older jaxlibs ship it as ``jax.experimental.shard_map`` with
the kwarg spelled ``check_rep=``.  Import ``shard_map`` from here so every
call site stays on the new spelling.
"""

from __future__ import annotations

import functools
import inspect

try:  # jax >= 0.5 re-exports shard_map at top level
    from jax import shard_map as _shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map

if "check_vma" in inspect.signature(_shard_map).parameters:
    shard_map = _shard_map
else:
    @functools.wraps(_shard_map)
    def shard_map(*args, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map(*args, **kwargs)

__all__ = ["shard_map"]
