"""Logical-axis sharding rules -> NamedSharding, with divisibility fallback.

The model code annotates activations with *logical* axis names
(``constrain(x, ('batch', 'seq', 'd_model'))``) and the launcher activates a
rule set mapping logical names to mesh axes.  Dims whose size is not
divisible by the mapped mesh-axis product silently fall back to replication
(JAX rejects uneven shardings on jit boundaries).

Default production rules (mesh = (pod,) data, model):

  batch    -> ('pod', 'data')     data parallel
  d_ff / heads / experts / vocab -> 'model'   tensor / expert parallel
  kv_seq   -> 'model'             decode context parallelism (flash-decode)
  fsdp     -> 'data'              weight second-dim sharding (ZeRO-3)
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    # Megatron-style sequence parallelism: the residual stream (and thus the
    # per-layer remat-saved activations) shards its seq dim over 'model';
    # GSPMD inserts the all-gather before attention/FFN and the
    # reduce-scatter after (Perf iteration 7).
    "residual_seq": "model",
    "kv_seq": "model",          # decode-time KV cache context parallelism
    "d_model": None,
    "heads": "model",
    "kv_heads": None,           # GQA kv <= 16 everywhere: replicate
    "d_head": None,
    "d_ff": "model",
    "experts": "model",
    "vocab": "model",
    "fsdp": "data",             # weights' non-TP dim
    "ssm_inner": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "enc_seq": None,
    "vis_seq": None,
}


class ShardingRules:
    def __init__(self, mesh: Mesh, rules: dict | None = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)
        # drop mesh axes that don't exist (e.g. 'pod' on the single-pod mesh)
        for k, v in list(self.rules.items()):
            self.rules[k] = self._filter_axes(v)

    def _filter_axes(self, v):
        if v is None:
            return None
        axes = (v,) if isinstance(v, str) else tuple(v)
        axes = tuple(a for a in axes if a in self.mesh.shape)
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]

    def axis_size(self, v) -> int:
        if v is None:
            return 1
        axes = (v,) if isinstance(v, str) else v
        size = 1
        for a in axes:
            size *= self.mesh.shape[a]
        return size

    def spec(self, names: Sequence[Optional[str]],
             shape: Sequence[int] | None = None) -> P:
        """Resolve logical names to a PartitionSpec, dropping non-divisible
        mappings, and never assigning one mesh axis to two dims."""
        used: set = set()
        parts = []
        for i, nm in enumerate(names):
            v = self.rules.get(nm) if nm else None
            if v is not None:
                axes = (v,) if isinstance(v, str) else tuple(v)
                if any(a in used for a in axes):
                    v = None
                elif shape is not None and shape[i] % self.axis_size(v) != 0:
                    v = None
                else:
                    used.update(axes)
            parts.append(v)
        return P(*parts)

    def sharding(self, names, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(names, shape))


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def active_rules() -> Optional[ShardingRules]:
    return getattr(_STATE, "rules", None)


def constrain(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    """Annotate an activation with logical axes (no-op without active rules)."""
    r = active_rules()
    if r is None:
        return x
    return jax.lax.with_sharding_constraint(x, r.sharding(names, x.shape))


# ---------------------------------------------------------------------------
# Parameter shardings: resolved from pytree path name patterns
# ---------------------------------------------------------------------------

# pattern (matched against the last two path segments) -> logical axes
_PARAM_TABLE = [
    ("embed",        ("vocab", "fsdp")),
    ("pos_embed",    (None, None)),
    ("unembed",      ("fsdp", "vocab")),
    ("wq",           ("fsdp", "heads", None)),
    ("wk",           ("fsdp", "kv_heads", None)),
    ("wv",           ("fsdp", "kv_heads", None)),
    ("wo",           ("heads", None, "fsdp")),
    ("bq",           ("heads", None)),
    ("bk",           ("kv_heads", None)),
    ("bv",           ("kv_heads", None)),
    ("w_gate",       ("fsdp", "d_ff")),
    ("w_up",         ("fsdp", "d_ff")),
    ("w_down",       ("d_ff", "fsdp")),
    ("router",       ("fsdp", "experts")),
    ("we_gate",      ("experts", "fsdp", "d_ff")),
    ("we_up",        ("experts", "fsdp", "d_ff")),
    ("we_down",      ("experts", "d_ff", "fsdp")),
    ("in_proj",      ("fsdp", "ssm_inner")),
    ("out_proj",     ("ssm_inner", "fsdp")),
    ("conv_w",       (None, "ssm_inner")),
    ("dt_bias",      (None,)),
    ("a_log",        (None,)),
    ("ssm_d",        (None,)),
    ("ssm_norm",     (None,)),
    ("scale",        (None,)),      # norms
    ("bias",         (None,)),
]


def param_logical_axes(path: tuple, leaf) -> tuple:
    """Logical axes for a param (or optimizer-state) leaf, by path pattern.

    Optimizer states nest the param path under m/v and may end in 'q'/'scale'
    (int8 codes keep the param's shape; scales shrink the last dim) — we
    match the *deepest* path segment that names a known parameter.
    """
    names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    table = dict(_PARAM_TABLE)
    for nm in reversed(names):
        if nm in table:
            axes = table[nm]
            if len(axes) == leaf.ndim:
                return axes
            # stacked-by-layer params carry leading n_layers/(blocks, n_self)
            if len(axes) == leaf.ndim - 1:
                return (None,) + axes
            if len(axes) == leaf.ndim - 2:
                return (None, None) + axes
            break
    return (None,) * leaf.ndim


def param_specs(rules: ShardingRules, params) -> object:
    """PartitionSpec pytree for a param pytree (by path-name patterns)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: rules.spec(param_logical_axes(p, x), x.shape), params)


def param_shardings(rules: ShardingRules, params) -> object:
    return jax.tree_util.tree_map_with_path(
        lambda p, x: NamedSharding(
            rules.mesh, rules.spec(param_logical_axes(p, x), x.shape)),
        params)
