"""The jit-able train / prefill / decode steps per architecture.

``make_step(cfg, kind)`` returns (step_fn, abstract input specs builder).
Training supports gradient accumulation (scan over microbatches — also the
compute/comm overlap vehicle: each microbatch's reduce-scatter overlaps the
next microbatch's compute under XLA latency hiding).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.train.optimizer import AdamWConfig, adamw_update


def loss_and_grads(cfg: ModelConfig, params, batch):
    def lf(p):
        loss, metrics = T.loss_fn(cfg, p, batch)
        return loss, metrics
    (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
    return loss, metrics, grads


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig | None = None,
                    grad_shardings=None):
    """``grad_shardings``: optional NamedSharding pytree (same structure as
    params) pinned onto per-microbatch grads and the f32 accumulator —
    without it, grads flowing out of shard_map'd layers (MoE) lose their
    FSDP dim and the accumulator replicates (§Perf iteration 6)."""
    opt_cfg = opt_cfg or AdamWConfig(
        state_dtype=cfg.parallel.opt_state_dtype)
    accum = max(cfg.parallel.accum_steps, 1)

    def pin(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(jax.lax.with_sharding_constraint, grads,
                            grad_shardings)

    def train_step(params, opt_state, batch):
        if accum == 1:
            loss, metrics, grads = loss_and_grads(cfg, params, batch)
            grads = pin(grads)
        else:
            def micro(batch_i):
                return jax.tree.map(
                    lambda x: x.reshape(accum, x.shape[0] // accum,
                                        *x.shape[1:])[batch_i], batch)

            def body(carry, i):
                gsum, lsum = carry
                loss_i, _, g_i = loss_and_grads(cfg, params, micro(i))
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, pin(g_i))
                return (pin(gsum), lsum + loss_i), None

            g0 = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params))
            (gsum, lsum), _ = jax.lax.scan(body, (g0, jnp.zeros(())),
                                           jnp.arange(accum))
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
            metrics = {"loss": loss}
        new_params, new_opt, opt_metrics = adamw_update(params, grads,
                                                        opt_state, opt_cfg)
        metrics = dict(metrics, **opt_metrics)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return T.prefill(cfg, params, batch)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, token, cache, length):
        logits, new_cache = T.decode_step(cfg, params, token, cache, length)
        return logits, new_cache
    return decode_step


# ---------------------------------------------------------------------------
# Abstract input specs (ShapeDtypeStruct) per (arch, shape) — dry-run inputs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape, *, kind: str | None = None):
    """ShapeDtypeStruct stand-ins for the data batch of a shape config."""
    kind = kind or shape.kind
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    cdt = dict(float32=jnp.float32, bfloat16=jnp.bfloat16)[cfg.compute_dtype]
    if kind == "train":
        batch = {"tokens": sds((B, S), jnp.int32),
                 "labels": sds((B, S), jnp.int32)}
    elif kind == "prefill":
        batch = {"tokens": sds((B, S), jnp.int32)}
    elif kind == "decode":
        return {
            "token": sds((B, 1), jnp.int32),
            "cache": T.cache_spec(cfg, B, S),
            "length": sds((), jnp.int32),
        }
    else:
        raise ValueError(kind)
    if cfg.family == "encdec":
        batch["frames"] = sds((B, cfg.enc_len, cfg.d_model), cdt)
    if cfg.family == "vlm":
        batch["patches"] = sds((B, cfg.vision_len, cfg.d_model), cdt)
    return batch


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(functools.partial(T.init_params, cfg),
                          jax.random.key(0))


def abstract_opt_state(cfg: ModelConfig, opt_cfg: AdamWConfig | None = None):
    from repro.train.optimizer import adamw_init
    opt_cfg = opt_cfg or AdamWConfig(state_dtype=cfg.parallel.opt_state_dtype)
    params = abstract_params(cfg)
    return jax.eval_shape(functools.partial(adamw_init, cfg=opt_cfg), params)
