"""int8 gradient all-reduce with error feedback (distributed-optimization
trick for bandwidth-bound data parallelism).

Used by the explicit-DP (shard_map) training path: each worker quantizes its
local gradient to int8 (blockwise scales), all-reduces the int8 codes (sum
of dequantized blocks ≈ psum of f32 within quantization error), and adds the
quantization residual back into the next step's gradient (error feedback),
which restores convergence to the uncompressed trajectory asymptotically.

Wire format per tensor: int32 accumulation of int8 codes + f32 scale psum —
4x less traffic than f32 all-reduce when links are the bottleneck (the
collective term of the roofline), at ~0.4% gradient RMS error per step
(tested).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.train import quant


def compress_psum(grad: jax.Array, residual: jax.Array, axis_names):
    """Quantize (grad + residual), psum, return (global_grad, new_residual).

    Inside shard_map.  The int8 codes are summed in int32 (exact); the
    per-block scales are all-gathered implicitly by summing scale-weighted
    dequantized blocks — i.e. we psum (code * scale) per worker, which is
    what arrives on the wire as int8 + one f32 per 128 elements.
    """
    g = grad.astype(jnp.float32) + residual
    q, scale = quant.quantize(g)
    deq = quant.dequantize(q, scale)
    new_residual = g - deq                       # error feedback
    summed = jax.lax.psum(deq, axis_names)
    return summed, new_residual


def init_residuals(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_tree_psum(grads, residuals, axis_names):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [compress_psum(g, r, axis_names) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
