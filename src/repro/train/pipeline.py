"""GPipe-style pipeline parallelism over a mesh axis (feature demo).

Layers are split into S stages sharded over a mesh axis (e.g. the `pod`
axis); microbatches stream through with the classic (M + S - 1)-tick
schedule; activations hop stages via ``ppermute`` (autodiff
transposes the permute, so ``jax.grad`` through the pipelined forward gives
1F1B-equivalent gradients without extra machinery).

This is deliberately compact: the production configs default to
FSDP+TP+EP+SP (see DESIGN.md §5) and pipelining is exercised by
``tests/test_pipeline.py`` at a 4-stage mesh as the PP capability proof.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding.compat import shard_map


def pipeline_apply(stage_params, x_micro, stage_fn, mesh: Mesh,
                   axis: str = "stage"):
    """Run microbatches through S pipeline stages sharded over ``axis``.

    stage_params: pytree with leading dim S (sharded over ``axis``).
    x_micro: (M, micro_batch, ...) microbatched inputs (replicated).
    stage_fn(params_slice, x) -> y, applied by each stage.
    Returns (M, micro_batch, ...) outputs of the final stage.
    """
    S = mesh.shape[axis]
    M = x_micro.shape[0]
    ticks = M + S - 1

    def inner(params_local, xs):
        # params_local: (1, ...) this stage's slice; xs: (M, mb, ...) full
        pslice = jax.tree.map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any remain)
            feed = jnp.where(t < M, t, M - 1)
            x_in = jnp.where((sid == 0) & (t < M), xs[feed], buf)
            active = (t >= sid) & (t - sid < M)
            y = stage_fn(pslice, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # pass activations downstream (stage i -> i+1)
            perm = [(i, (i + 1) % S) for i in range(S)]
            nxt = jax.lax.ppermute(y, axis, perm)
            # last stage records its finished microbatch
            done_idx = t - (S - 1)
            outs = jax.lax.cond(
                (sid == S - 1) & (done_idx >= 0),
                lambda o: o.at[jnp.maximum(done_idx, 0)].set(y),
                lambda o: o, outs)
            return (nxt, outs), None

        buf0 = jnp.zeros(mb_shape, xs.dtype)
        outs0 = jnp.zeros((M,) + mb_shape, xs.dtype)
        (buf, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                      jnp.arange(ticks))
        # broadcast final outputs from the last stage to all ranks
        outs = jax.lax.psum(
            jnp.where(sid == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    return shard_map(
        inner, mesh=mesh,
        in_specs=(P(axis), P()), out_specs=P(), check_vma=False,
    )(stage_params, x_micro)


def sequential_reference(stage_params, x_micro, stage_fn):
    """Same computation without pipelining (oracle for tests)."""
    S = jax.tree.leaves(stage_params)[0].shape[0]

    def one_micro(x):
        for s in range(S):
            pslice = jax.tree.map(lambda a: a[s], stage_params)
            x = stage_fn(pslice, x)
        return x

    return jax.vmap(one_micro)(x_micro)
