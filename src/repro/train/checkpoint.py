"""Fault-tolerant sharded checkpointing with elastic resharding.

Layout (one directory per step):

  <dir>/step_000120/
      manifest.json     — pytree structure, shapes, dtypes, shard map, status
      arr_<idx>.npy     — one file per leaf (host-gathered)
  <dir>/LATEST          — name of the newest *committed* checkpoint

Properties:
  * atomic commit: data is written into a tmp dir, fsynced, then renamed;
    LATEST is updated last — a crash mid-write never corrupts the newest
    valid checkpoint (restore scans back to the last committed one).
  * mesh-independence (elastic): leaves are stored as full (global) arrays;
    ``restore`` re-shards onto whatever mesh/sharding the caller provides,
    so a job can resume on a different number of pods.
  * self-validating: manifest carries per-leaf shape/dtype (+ a sampled
    checksum) — mismatches are detected at restore.

On a real multi-host deployment the host-gather becomes
``multihost_utils.process_allgather`` + per-host shard files; the manifest
format is unchanged.  This container is single-process, so gathering is a
``jax.device_get``.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import time
from typing import Any, Optional

import jax
import numpy as np


def _leaf_checksum(a: np.ndarray) -> str:
    # sampled checksum (full hash of >GB arrays is too slow on restore path)
    flat = a.reshape(-1).view(np.uint8)
    step = max(1, flat.size // 65536)
    return hashlib.sha1(flat[::step].tobytes()).hexdigest()[:16]


def save(ckpt_dir: str, step: int, tree: Any, *, extra: dict | None = None):
    """Write a committed checkpoint for ``tree`` at ``step``."""
    leaves, treedef = jax.tree.flatten(tree)
    name = f"step_{step:08d}"
    final = os.path.join(ckpt_dir, name)
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = tempfile.mkdtemp(prefix=f".{name}.", dir=ckpt_dir)
    manifest = {"step": step, "treedef": jax.tree_util.tree_structure(
        tree).serialize_using_proto().hex(),
        "extra": extra or {}, "leaves": [], "time": time.time()}
    try:
        for i, leaf in enumerate(leaves):
            a = np.asarray(jax.device_get(leaf))
            np.save(os.path.join(tmp, f"arr_{i}.npy"), a)
            manifest["leaves"].append({
                "shape": list(a.shape), "dtype": str(a.dtype),
                "checksum": _leaf_checksum(a)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic commit
        _write_latest(ckpt_dir, name)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def _write_latest(ckpt_dir: str, name: str):
    tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(tmp, "w") as f:
        f.write(name)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, os.path.join(ckpt_dir, "LATEST"))


def _is_committed(path: str) -> bool:
    return os.path.exists(os.path.join(path, "manifest.json"))


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest committed step (scans back past partial/corrupt dirs)."""
    if not os.path.isdir(ckpt_dir):
        return None
    cands = sorted((d for d in os.listdir(ckpt_dir)
                    if d.startswith("step_")), reverse=True)
    for d in cands:
        if _is_committed(os.path.join(ckpt_dir, d)):
            return int(d.split("_")[1])
    return None


def restore(ckpt_dir: str, step: Optional[int], like: Any, *,
            shardings: Any = None) -> tuple[Any, dict]:
    """Restore a checkpoint into the structure of ``like``.

    ``shardings``: optional pytree of NamedShardings — the *elastic* path:
    stored global arrays are device_put onto the new mesh regardless of the
    mesh they were saved from.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = jax.tree.flatten(like)
    metas = manifest["leaves"]
    if len(metas) != len(leaves_like):
        raise ValueError(f"checkpoint has {len(metas)} leaves, expected "
                         f"{len(leaves_like)}")
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(metas))
    out = []
    for i, (meta, proto, sh) in enumerate(zip(metas, leaves_like,
                                              shard_leaves)):
        a = np.load(os.path.join(path, f"arr_{i}.npy"))
        if list(a.shape) != list(proto.shape) or str(a.dtype) != str(
                np.dtype(proto.dtype)):
            raise ValueError(
                f"leaf {i}: stored {a.shape}/{a.dtype} != expected "
                f"{proto.shape}/{np.dtype(proto.dtype)}")
        if meta.get("checksum") and _leaf_checksum(a) != meta["checksum"]:
            raise ValueError(f"leaf {i}: checksum mismatch (corrupt file)")
        out.append(jax.device_put(a, sh) if sh is not None
                   else jax.device_put(a))
    return treedef.unflatten(out), manifest.get("extra", {})


def garbage_collect(ckpt_dir: str, keep: int = 3):
    """Delete all but the newest ``keep`` committed checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    cands = sorted((d for d in os.listdir(ckpt_dir)
                    if d.startswith("step_") and
                    _is_committed(os.path.join(ckpt_dir, d))), reverse=True)
    for d in cands[keep:]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
