"""Blockwise int8 quantization — shared by the compressed AdamW states and
the int8 gradient all-reduce (error-feedback compression).

Per-block (last-dim blocks of 128) absmax scaling, bitsandbytes-style.
Codes keep the tensor's shape (so sharding rules apply unchanged); scales
have shape ``x.shape[:-1] + (ceil(last/128),)`` and shard on the leading
dims.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 128


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """-> (int8 codes, f32 blockwise scales).  Shape-preserving."""
    if x.ndim == 0:
        x = x[None]
        q, s = quantize(x)
        return q[0], s
    xf = x.astype(jnp.float32)
    last = x.shape[-1]
    nb = -(-last // BLOCK)
    pad = nb * BLOCK - last
    xp = jnp.pad(xf, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    blocks = xp.reshape(*x.shape[:-1], nb, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0       # (..., nb)
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[..., None]), -127, 127)
    q = q.reshape(*x.shape[:-1], nb * BLOCK)[..., :last]
    return q.astype(jnp.int8), scale


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    if q.ndim == 0:
        return (q.astype(jnp.float32) * scale[0]).astype(dtype)
    last = q.shape[-1]
    nb = scale.shape[-1]
    pad = nb * BLOCK - last
    qp = jnp.pad(q.astype(jnp.float32),
                 [(0, 0)] * (q.ndim - 1) + [(0, pad)])
    blocks = qp.reshape(*q.shape[:-1], nb, BLOCK)
    x = blocks * scale[..., None]
    return x.reshape(*q.shape[:-1], nb * BLOCK)[..., :last].astype(dtype)
