"""AdamW with optional int8-quantized moments + LR schedules + clipping.

The int8 state path (blockwise absmax, ``repro.train.quant``) is what lets
arctic-480b / llama-3.2-vision-90b fit the 16 GB/chip v5e budget:
bf16 params + bf16 grads + int8 (m, v) = 6 bytes/param instead of 16.
Updates are always computed in f32 and cast back.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.train import quant


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    state_dtype: str = "float32"    # 'float32' | 'int8'
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def _zeros_like_state(p, state_dtype):
    if state_dtype == "int8":
        q, s = quant.quantize(jnp.zeros(p.shape, jnp.float32))
        return {"q": q, "scale": s}
    return jnp.zeros(p.shape, jnp.float32)


def adamw_init(params, cfg: AdamWConfig):
    mk = lambda p: _zeros_like_state(p, cfg.state_dtype)
    return {
        "m": jax.tree.map(mk, params),
        "v": jax.tree.map(mk, params),
        "count": jnp.zeros((), jnp.int32),
    }


def _load(state_leaf, shape):
    if isinstance(state_leaf, dict):
        return quant.dequantize(state_leaf["q"], state_leaf["scale"])
    return state_leaf


def _store(x, state_dtype):
    if state_dtype == "int8":
        q, s = quant.quantize(x)
        return {"q": q, "scale": s}
    return x.astype(jnp.float32)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(grads, max_norm):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gnorm


# Leaves above this element count run their update as a lax.scan over the
# leading (layer-stack) dim: the f32 m/v/step temporaries of a monolithic
# update on a 100+ GB stacked expert tensor would otherwise dominate device
# memory (EXPERIMENTS.md §Perf iteration 3 — arctic-480b train).
CHUNKED_UPDATE_MIN_ELEMS = 1 << 28


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.max_grad_norm)
    count = state["count"] + 1
    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m_leaf, v_leaf, wd):
        gf = g.astype(jnp.float32)
        m = cfg.b1 * _load(m_leaf, p.shape) + (1 - cfg.b1) * gf
        v = cfg.b2 * _load(v_leaf, p.shape) + (1 - cfg.b2) * gf * gf
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        new_p = p.astype(jnp.float32) * (1 - lr * wd) - lr * step
        return (new_p.astype(p.dtype), _store(m, cfg.state_dtype),
                _store(v, cfg.state_dtype))

    def upd_leaf(p, g, m_leaf, v_leaf):
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        if p.size >= CHUNKED_UPDATE_MIN_ELEMS and p.ndim >= 2:
            def body(_, sl):
                ps, gs, ms, vs = sl
                return 0, upd(ps, gs, ms, vs, wd)
            _, (np_, nm, nv) = jax.lax.scan(
                body, 0, (p, g, m_leaf, v_leaf))
            return np_, nm, nv
        return upd(p, g, m_leaf, v_leaf, wd)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd_leaf(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
