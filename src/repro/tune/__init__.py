"""repro.tune — recall-targeted empirical parameter search (DESIGN.md §11).

The paper's quality guarantee is probabilistic in L, but L is the single
biggest cost knob: every extra DE-Tree costs build time, memory, and
per-round query work.  Multi-probe rounds (``probe_depth``; core/query.py)
reach the same recall at smaller L by admitting near-miss leaves instead
of growing the forest — and this package picks the operating point:

    result = repro.tune.suggest_params(sample, target_recall=0.9,
                                       key=jax.random.PRNGKey(0))
    index = repro.api.build(data, key, result.spec)       # tuned spec
    # or in one step:
    index, result = repro.tune.tune(data, key, target_recall=0.9)

``suggest_params`` runs every (K, L, beta) build on the sample once, then
measures each ``probe_depth`` as a request-time knob against brute-force
ground truth (``baselines/brute_force.py``), scoring trials on the
``repro.eval.pareto`` work-per-query axis; the winner is the cheapest
config meeting the target, returned as a ``TuneResult`` whose ``spec``
has the chosen probe depth baked in as the index's search-time default.
"""

from repro.tune.tuner import (DEFAULT_GRID, TuneResult, predicted_build_cost,
                              suggest_params, tune)

__all__ = ["TuneResult", "suggest_params", "tune", "predicted_build_cost",
           "DEFAULT_GRID"]
