"""The auto-tuner: empirical (K, L, beta, probe_depth) search on a sample.

Procedure (docs/DESIGN.md §11):

  1. brute-force ground truth on the sample (the exact-scan oracle is the
     only recall reference that needs no assumptions);
  2. one ``api.build`` per (K, L, beta) — probe_depth is a request-time
     knob, so all probe depths share a build;
  3. one ``repro.eval.pareto.measure`` per (build, probe_depth): recall@k
     plus mean candidates/query (the hardware-neutral work axis) through
     the same ``AnnIndex.search`` protocol every benchmark uses;
  4. among trials meeting the target recall, pick the least work per
     query (ties: smaller L, then faster measured build).

The returned ``TuneResult.spec`` is an ordinary ``IndexSpec`` with the
winning probe depth installed as the index's search-time default — build
it with ``repro.api.build`` (or use :func:`tune` for the one-step path)
and plain ``SearchRequest``s inherit the tuned behavior.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.api.request import SearchRequest, _check_positive
from repro.api.spec import IndexSpec

# The default search grid: small K keeps projections cheap, L sweeps the
# "how few trees can we afford" axis, probe depths trade near-miss
# admission against radius growth.  Callers narrow this for smoke runs.
DEFAULT_GRID = dict(Ks=(4,), Ls=(2, 3, 4, 6, 8), betas=(0.05, 0.1),
                    probe_depths=(0, 2, 4, 8))


def predicted_build_cost(n: int, K: int, L: int) -> float:
    """Build-cost model in scale-free work units.

    Per point and tree: K projection multiply-adds plus ~log2(n) sort
    compares (the fused single-sort build; DESIGN.md §8), so
    cost = n * L * (K + log2 n).  Used to rank candidate configs by how
    expensive the *full-size* build will be before any is built, and
    reported on ``TuneResult`` so callers can weigh build against query
    work at their own traffic volume.
    """
    return float(n) * float(L) * (float(K) + math.log2(max(n, 2)))


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """The tuner's verdict: a buildable spec plus the evidence for it."""

    spec: IndexSpec            # chosen build config, probe_depth baked in
    target_recall: float
    achieved: bool             # False: nothing met the target; ``spec`` is
    #                            then the best-recall config found
    recall: float              # measured on the sample, recall@k
    work_per_query: float      # mean candidates/query on the sample
    qps: float                 # sample-batch QPS (CPU smoke: indicative only)
    build_seconds: float       # measured sample build
    predicted_build_cost: float  # work-model units at n_full (or sample n)
    k: int
    n_sample: int
    trials: tuple              # every evaluated CurvePoint, sweep order

    @property
    def probe_depth(self) -> int:
        return self.spec.probe_depth

    def request(self, **overrides: object) -> SearchRequest:
        """A ``SearchRequest`` reproducing the winning measurement."""
        kw = dict(k=self.k, probe_depth=self.spec.probe_depth)
        kw.update(overrides)
        return SearchRequest(**kw)

    def to_dict(self) -> dict:
        """JSON-ready form (the BENCH_tune.json payload)."""
        d = dataclasses.asdict(self)
        d["spec"] = self.spec.to_dict()
        d["trials"] = [t.to_dict() for t in self.trials]
        return d


def _default_queries(sample: jax.Array, key: jax.Array,
                     n_queries: int) -> jax.Array:
    """Workload stand-in when the caller has no real queries: sample rows
    perturbed by 10%-of-data-std noise (near-neighbor queries, the ANN
    regime the guarantee speaks to — exact-copy queries would let every
    config score perfect recall at radius ~0)."""
    n, d = sample.shape
    kc, kn = jax.random.split(key)
    nq = min(n_queries, n)
    idx = jax.random.choice(kc, n, (nq,), replace=False)
    noise = 0.1 * jnp.std(sample) * jax.random.normal(kn, (nq, d))
    return sample[idx] + noise


def suggest_params(sample: jax.Array, target_recall: float = 0.9, *,
                   key: Optional[jax.Array] = None, k: int = 10,
                   queries: Optional[jax.Array] = None, n_queries: int = 32,
                   Ks: Sequence[int] = DEFAULT_GRID["Ks"],
                   Ls: Sequence[int] = DEFAULT_GRID["Ls"],
                   betas: Sequence[Optional[float]] = DEFAULT_GRID["betas"],
                   probe_depths: Sequence[int] = DEFAULT_GRID["probe_depths"],
                   c: float = 1.5, Nr: int = 64, leaf_size: int = 32,
                   max_rounds: int = 48, engine: str = "auto",
                   n_full: Optional[int] = None, repeat: int = 1,
                   spec_base: Optional[IndexSpec] = None) -> TuneResult:
    """Empirically pick (K, L, beta, probe_depth) for a target recall.

    ``sample`` (m, d): a representative data sample — every candidate
    config is built on it and measured against brute-force ground truth.
    ``queries``: real workload queries if available (else perturbed sample
    rows stand in).  ``n_full``: the intended full dataset size, used only
    to extrapolate ``predicted_build_cost``.  ``spec_base``: template for
    non-swept IndexSpec fields (engine, block sizes, ...).

    Returns a :class:`TuneResult`; ``result.achieved`` is False when no
    grid config reached the target (the best-recall config is still
    returned so callers can inspect how close the grid got).
    """
    from repro import api
    from repro.baselines import BruteForce
    from repro.eval.pareto import measure

    if not 0.0 < target_recall <= 1.0:
        raise ValueError(f"target_recall must be in (0, 1], got "
                         f"{target_recall!r}")
    _check_positive("k", k)
    _check_positive("repeat", repeat)
    Ks, Ls = tuple(Ks), tuple(Ls)
    betas, probe_depths = tuple(betas), tuple(probe_depths)
    if not (Ks and Ls and betas and probe_depths):
        raise ValueError(
            f"empty search grid: Ks={Ks} Ls={Ls} betas={betas} "
            f"probe_depths={probe_depths} must all be non-empty")

    sample = jnp.asarray(sample, jnp.float32)
    m = sample.shape[0]
    key = jax.random.PRNGKey(0) if key is None else key
    kq, kb = jax.random.split(key)
    if queries is None:
        queries = _default_queries(sample, kq, n_queries)
    queries = jnp.asarray(queries, jnp.float32)

    bf = BruteForce.build(sample)
    gt = bf.search(queries, SearchRequest(k=k))

    base = spec_base if spec_base is not None else IndexSpec()
    trials, metas = [], []
    # Cheapest builds first: on ties in query work the earlier (cheaper)
    # trial wins the final sort below.
    for K, L, beta in sorted(
            ((K, L, b) for K in Ks for L in Ls for b in betas),
            key=lambda t: predicted_build_cost(m, t[0], t[1])):
        spec = dataclasses.replace(
            base, kind="static", K=K, L=L, c=c, beta_override=beta,
            Nr=Nr, leaf_size=leaf_size, engine=engine, probe_depth=0)
        t0 = time.perf_counter()
        index = api.build(sample, kb, spec)
        index.search(queries[:1], SearchRequest(k=k))      # build + warmup
        t_build = time.perf_counter() - t0
        for pd in probe_depths:
            req = SearchRequest(k=k, max_rounds=max_rounds, probe_depth=pd)
            label = f"K{K}-L{L}-b{beta}-p{pd}"
            pt = measure("det-lsh", label, index, queries, gt.ids, req,
                         build_seconds=t_build, repeat=repeat,
                         params=dict(K=K, L=L, beta=beta, probe_depth=pd))
            trials.append(pt)
            metas.append((spec, pd))

    ok = [i for i, p in enumerate(trials) if p.recall >= target_recall]
    achieved = bool(ok)
    if achieved:
        # Least query work; ties: fewer trees, then faster measured build.
        win = min(ok, key=lambda i: (trials[i].work_per_query,
                                     trials[i].params["L"],
                                     trials[i].build_seconds))
    else:
        win = max(range(len(trials)),
                  key=lambda i: (trials[i].recall,
                                 -trials[i].work_per_query))
    spec, pd = metas[win]
    best = trials[win]
    chosen = dataclasses.replace(spec, probe_depth=pd)
    n_target = n_full if n_full is not None else m
    return TuneResult(
        spec=chosen, target_recall=float(target_recall), achieved=achieved,
        recall=float(best.recall), work_per_query=float(best.work_per_query),
        qps=float(best.qps), build_seconds=float(best.build_seconds),
        predicted_build_cost=predicted_build_cost(n_target, chosen.K,
                                                  chosen.L),
        k=int(k), n_sample=int(m), trials=tuple(trials))


def tune(data: jax.Array, key: jax.Array, target_recall: float = 0.9, *,
         sample_size: int = 4096, k: int = 10,
         queries: Optional[jax.Array] = None,
         **grid: object) -> tuple:
    """target_recall -> a built, tuned index in one call.

    Samples ``sample_size`` rows of ``data`` (without replacement), runs
    :func:`suggest_params` on the sample, then builds the winning spec on
    the *full* data.  Extra kwargs forward to ``suggest_params`` (grid
    axes, c/Nr/leaf_size, ...).  Returns ``(index, TuneResult)``.
    """
    from repro import api

    data = jnp.asarray(data, jnp.float32)
    n = data.shape[0]
    _check_positive("sample_size", sample_size)
    ks, kt, kbuild = jax.random.split(key, 3)
    if sample_size < n:
        idx = jax.random.choice(ks, n, (sample_size,), replace=False)
        sample = data[idx]
    else:
        sample = data
    result = suggest_params(sample, target_recall, key=kt, k=k,
                            queries=queries, n_full=n, **grid)
    index = api.build(data, kbuild, result.spec)
    return index, result
