"""Deterministic, resumable synthetic data pipeline.

Production shape: each host generates only its shard of the global batch
(host-sharded loading); the stream is a pure function of (seed, step), so

  * resume-after-failure is exact: the checkpoint stores only the step
    cursor, and the pipeline regenerates the identical batch stream;
  * elastic restarts re-partition the same global stream over a different
    host count without skew.

The generator synthesizes Zipf-distributed token ids with Markov structure
(so losses actually decrease during training examples/tests), plus the
stubbed modality inputs (frames/patches) required by encdec/vlm archs.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class PipelineConfig:
    seed: int = 0
    vocab_size: int = 512
    seq_len: int = 128
    global_batch: int = 8
    host_index: int = 0
    host_count: int = 1
    zipf_a: float = 1.3


class SyntheticLM:
    """Deterministic (seed, step) -> batch generator."""

    def __init__(self, cfg: PipelineConfig, model_cfg: ModelConfig | None = None):
        assert cfg.global_batch % cfg.host_count == 0
        self.cfg = cfg
        self.model_cfg = model_cfg
        self._local = cfg.global_batch // cfg.host_count

    def _rng_for(self, step: int) -> np.random.Generator:
        # independent, splittable stream per (seed, step, host)
        ss = np.random.SeedSequence(
            entropy=self.cfg.seed,
            spawn_key=(step, self.cfg.host_index))
        return np.random.default_rng(ss)

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = self._rng_for(step)
        v = cfg.vocab_size
        b, s = self._local, cfg.seq_len
        # Markov chain over a zipfian unigram table: learnable structure
        base = rng.zipf(cfg.zipf_a, size=(b, s + 1)).astype(np.int64)
        tokens = (base % (v - 2)) + 1
        # inject copy structure: token[t] sometimes repeats token[t-1]
        copy_mask = rng.random((b, s + 1)) < 0.3
        for t in range(1, s + 1):
            tokens[:, t] = np.where(copy_mask[:, t], tokens[:, t - 1],
                                    tokens[:, t])
        batch = {
            "tokens": jnp.asarray(tokens[:, :s], jnp.int32),
            "labels": jnp.asarray(tokens[:, 1:], jnp.int32),
        }
        mc = self.model_cfg
        if mc is not None and mc.family == "encdec":
            batch["frames"] = jnp.asarray(
                rng.standard_normal((b, mc.enc_len, mc.d_model)) * 0.02,
                jnp.float32)
        if mc is not None and mc.family == "vlm":
            batch["patches"] = jnp.asarray(
                rng.standard_normal((b, mc.vision_len, mc.d_model)) * 0.02,
                jnp.float32)
        return batch

    def stream(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


def make_pipeline(model_cfg: ModelConfig, shape: ShapeConfig, *, seed=0,
                  host_index=0, host_count=1,
                  override_batch: int | None = None,
                  override_seq: int | None = None) -> SyntheticLM:
    return SyntheticLM(PipelineConfig(
        seed=seed, vocab_size=model_cfg.vocab_real or model_cfg.vocab_size,
        seq_len=override_seq or shape.seq_len,
        global_batch=override_batch or shape.global_batch,
        host_index=host_index, host_count=host_count), model_cfg)
