"""MIPS -> L2 reduction (Shrivastava-Li asymmetric augmentation).

Attention retrieval is maximum inner-product search: the positions worth
attending to are argmax q.k, over keys whose norms vary.  The DE-Forest
answers *Euclidean* range queries, so keys and queries are lifted into
R^(d+1) with

    k_hat = [k, sqrt(R^2 - ||k||^2)],      q_hat = [q, 0]

which gives ||q_hat - k_hat||^2 = ||q||^2 + R^2 - 2 q.k — a strictly
decreasing function of q.k for a fixed query, so augmented-L2 nearest ==
inner-product largest (property-tested in tests/test_decode.py).

R is frozen at prefill (``mips_radius`` with a slack factor); keys upserted
later whose norm exceeds R get a clipped (0) augmentation coordinate.  For
a clipped key the identity degrades to an *under*-estimate of its distance
(||q_hat - k_hat||^2 = ||q||^2 + ||k||^2 - 2 q.k <= ||q||^2 + R^2 - 2 q.k),
i.e. clipped keys are ranked at least as close as the exact reduction would
rank them — retrieval can only over-admit them, never lose them behind an
unclipped key with smaller q.k.  ``augment_keys`` reports the clip count so
callers can widen the slack when drift is real (docs/DESIGN.md §10).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

DEFAULT_SLACK = 1e-6


def mips_radius(keys: jax.Array, *, slack: float = DEFAULT_SLACK,
                axis=(-2, -1)) -> jax.Array:
    """Squared augmentation radius R^2 = max ||k||^2 * (1 + slack).

    keys (..., S, d); the max runs over ``axis`` (default: per leading
    batch/head index), so each head freezes its own radius.
    """
    norms2 = jnp.sum(keys.astype(jnp.float32) ** 2, -1)
    return jnp.max(norms2, axis=-1) * (1.0 + slack)


def augment_keys(keys: jax.Array, R2: jax.Array | float
                 ) -> tuple[jax.Array, jax.Array]:
    """keys (..., S, d), R2 broadcastable to (..., S) -> (aug, n_clipped).

    aug (..., S, d+1) f32 with last coordinate sqrt(max(R^2 - ||k||^2, 0));
    n_clipped counts keys whose norm exceeded R (coordinate clipped to 0).
    """
    kf = keys.astype(jnp.float32)
    norms2 = jnp.sum(kf ** 2, -1)
    R2 = jnp.asarray(R2, jnp.float32)
    if R2.ndim:
        R2 = R2[..., None]            # broadcast over the S axis
    gap = R2 - norms2
    extra = jnp.sqrt(jnp.maximum(gap, 0.0))
    n_clipped = jnp.sum(gap < 0.0).astype(jnp.int32)
    return jnp.concatenate([kf, extra[..., None]], -1), n_clipped


def augment_queries(q: jax.Array) -> jax.Array:
    """q (..., d) -> q_hat (..., d+1) with a zero augmentation coordinate."""
    qf = q.astype(jnp.float32)
    return jnp.concatenate([qf, jnp.zeros(qf.shape[:-1] + (1,),
                                          jnp.float32)], -1)


def normalize_queries(q: jax.Array, R2: jax.Array | float) -> jax.Array:
    """Rescale each query lane to the key-norm scale (||q_n|| = R).

    For a fixed lane, augmented-L2 order is a monotone function of q.k for
    *any* positive query scale, so rescaling never changes the ranking —
    but it changes the LSH contrast enormously: with ||q|| >> R the
    distance spread 2(q.k_max - q.k_min) vanishes against the common
    ||q||^2 + R^2 term and every projected leaf looks equidistant, while
    at ||q|| = R near/far separation is maximal (Shrivastava-Li normalize
    their queries for exactly this reason).  q (..., d or d+1 augmented);
    R2 broadcastable to the lane axes.
    """
    qf = q.astype(jnp.float32)
    norms = jnp.sqrt(jnp.sum(qf ** 2, -1, keepdims=True))
    R = jnp.sqrt(jnp.asarray(R2, jnp.float32))
    if R.ndim:
        R = R.reshape(R.shape + (1,) * (qf.ndim - R.ndim))
    return qf * (R / jnp.maximum(norms, 1e-12))
