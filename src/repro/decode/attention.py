"""Sparse-attention assembly over retrieved KV positions (DESIGN.md §10).

``sparse_decode_attention`` is the read side of LSH decode: exact softmax
over the union of {retrieved candidate positions} ∪ {local window} ∪
{attention sinks} — the standard sparse-attention safety set.  Retrieval
decides *which* positions matter; this module computes *exact* attention
over them (no approximation inside the softmax).

``LSHDecoder`` is the step driver that ties the two halves of a decode
step together against a ``KVCacheIndex``:

  write half:  upsert the step's new key into the streaming delta;
  read half:   batched fused retrieval every ``refresh_every`` steps
               (retrieval amortization: decode queries drift slowly, and
               the local window — required to be >= refresh_every — covers
               every key written since the last refresh, so stale
               candidate tables stay safe between refreshes).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.decode.kv_index import KVCacheIndex


@functools.partial(jax.jit, static_argnames=("window", "sinks"))
def sparse_decode_attention(q: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array, positions: jax.Array,
                            length, *, window: int = 64,
                            sinks: int = 4) -> jax.Array:
    """Exact attention over {positions} ∪ {window} ∪ {sinks}.

    q (b, 1, h, dh); caches (b, S, hk, dh); positions (b, hk, g, m) int32
    cache positions (-1 = no candidate); length = attendable prefix.
    Duplicate positions across the three sources are masked (first
    occurrence kept), so the softmax is exactly the dense softmax
    restricted to the survivor set.
    """
    b, _, h, dh = q.shape
    S, hk = k_cache.shape[1], k_cache.shape[2]
    g = h // hk
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    qh = q.reshape(b, hk, g, dh)

    loc = length - 1 - jnp.arange(window)
    snk = jnp.arange(sinks)
    fixed = jnp.concatenate([loc, snk])
    fixed = jnp.broadcast_to(fixed, (b, hk, g, fixed.shape[0]))
    ids = jnp.concatenate([positions.astype(jnp.int32), fixed], axis=-1)
    # mask BEFORE clipping: -1 candidates must not alias position 0
    in_range = (ids >= 0) & (ids < length)
    ids = jnp.clip(ids, 0, S - 1)

    def head(qv, kc, vc, idv, okv):          # (g,dh),(S,dh),(S,dh),(g,m)
        kg = kc[idv.reshape(-1)].reshape(*idv.shape, dh)
        vg = vc[idv.reshape(-1)].reshape(*idv.shape, dh)
        s = jnp.einsum("gd,gmd->gm", qv.astype(jnp.float32) * scale,
                       kg.astype(jnp.float32))

        def mask_dups(row_ids, row_valid):
            order = jnp.argsort(row_ids, stable=True)
            rs = row_ids[order]
            first = jnp.concatenate([jnp.array([True]), rs[1:] != rs[:-1]])
            keep = jnp.zeros_like(row_valid).at[order].set(first)
            return row_valid & keep

        valid = jax.vmap(mask_dups)(idv, okv)
        s = jnp.where(valid, s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("gm,gmd->gd", p, vg.astype(jnp.float32))

    out = jax.vmap(jax.vmap(head))(
        qh, k_cache.transpose(0, 2, 1, 3), v_cache.transpose(0, 2, 1, 3),
        ids, in_range)                                 # (b, hk, g, dh)
    return out.reshape(b, 1, h, dh).astype(q.dtype)


class LSHDecoder:
    """One decode step = streaming upsert + (amortized) fused retrieval +
    sparse assembly, against a prefilled ``KVCacheIndex``.

    ``refresh_every=1`` retrieves every step; larger values reuse the last
    candidate table for R-1 steps, which is the honest throughput lever —
    retrieval cost amortizes to 1/R per token while the local window
    (``window >= refresh_every`` is enforced) keeps all not-yet-retrieved
    fresh keys attendable.
    """

    def __init__(self, index: KVCacheIndex, *, window: int = 64,
                 sinks: int = 4, refresh_every: int = 1):
        if window < refresh_every:
            raise ValueError(
                f"window ({window}) must be >= refresh_every "
                f"({refresh_every}): keys written since the last refresh "
                f"are only attendable through the local window")
        self.index = index
        self.window = window
        self.sinks = sinks
        self.refresh_every = refresh_every
        self.n_refreshes = 0
        self._positions: Optional[jax.Array] = None    # (b, hk, g, m)
        self._since = refresh_every                    # force refresh at t=0

    def step(self, q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
             k_new: jax.Array, length) -> jax.Array:
        """q (b, 1, h, dh); caches (b, S, hk, dh) with the step's k/v
        already written at position length-1; k_new (b, hk, dh) is that
        key (upserted into the index's delta).  Returns (b, 1, h, dh)."""
        self.index.upsert(k_new)
        if self._positions is None or self._since >= self.refresh_every:
            res = self.index.retrieve(q)
            b, hk = self.index.b, self.index.hk
            g, m = res.ids.shape[1], res.ids.shape[2]
            self._positions = res.ids.reshape(b, hk, g, m)
            self._since = 0
            self.n_refreshes += 1
        self._since += 1
        return sparse_decode_attention(q, k_cache, v_cache, self._positions,
                                       length, window=self.window,
                                       sinks=self.sinks)
