"""KVCacheIndex: the KV cache as a MutableAnnIndex (docs/DESIGN.md §10).

Re-platforms DET-LSH attention decode on the production stack:

  * **prefill** is a batched fused build — per (batch, kv-head) the
    augmented keys go through the same ``build_forest`` single-sort
    pipeline every other index uses (PR 5), with per-head frozen
    breakpoints, and a per-head ``FusedPlan`` (code-sorted points +
    inverse permutation) exactly like ``DETLSH``;
  * **each decode step** is an upsert of the new key into a streaming
    delta buffer (``streaming.BatchedMemtable`` — H lockstep heads, one
    cursor) plus a batched fused ``range_rerank`` query over
    {sealed forests + delta}: the round loop drives
    ``kernels.ops.range_rerank_heads`` (one kernel pass for all H
    forests) and folds each round through the engine's single source of
    truth, ``core.query.fused_round_update``;
  * the MIPS -> L2 reduction lives in ``repro.decode.mips`` as a thin
    transform layer: keys are augmented once (radius frozen at prefill),
    queries are zero-extended per step.

Candidate ids ARE cache positions: sealed forests are built over keys in
cache-position order and delta slots carry their position as gid, so the
retrieval output feeds ``repro.decode.attention`` directly.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.request import SearchRequest, SearchResult, SearchStats
from repro.api.request import _check_positive
from repro.api.spec import IndexSpec
from repro.core import hashing
from repro.core.detree import build_forest
from repro.core.query import fused_round_update, fused_topk, make_fused_plan
from repro.core.theory import LSHParams, derive_params
from repro.decode import mips
from repro.kernels import ops as kops
from repro.streaming.memtable import BatchedMemtable


@dataclasses.dataclass(frozen=True)
class KVSpec:
    """Build/search configuration for a ``KVCacheIndex``.

    Validation routes through ``IndexSpec`` (``index_spec()``) so the KV
    path fails with exactly the same eager, actionable messages as every
    other index (Nr <= 256, positive leaf_size, known breakpoint method,
    ...); the decode-only knobs are checked here.
    """

    K: int = 4
    L: int = 4
    c: float = 1.5
    beta_override: Optional[float] = 0.1
    Nr: int = 64
    leaf_size: int = 32
    # full_sort matches the seed ``det_attention`` breakpoint selection,
    # which is what makes the oracle test's forests bit-identical; at KV
    # scale (S ~ thousands) the full sort is cheap.
    breakpoint_method: str = "full_sort"
    build_impl: str = "auto"
    delta_capacity: int = 128     # decode steps between reseals
    m_top: int = 64               # retrieved positions per (kv-head, q-head)
    max_rounds: int = 8           # radius enlargements per retrieval
    radius_slack: float = 1e-6    # headroom on the frozen MIPS radius

    def __post_init__(self):
        self.index_spec()                      # shared eager validation
        _check_positive("m_top", self.m_top)
        _check_positive("max_rounds", self.max_rounds)
        if not self.radius_slack >= 0.0:
            raise ValueError(f"radius_slack must be >= 0, got "
                             f"{self.radius_slack!r} (it is headroom for "
                             f"post-prefill key-norm drift)")

    def index_spec(self) -> IndexSpec:
        """The equivalent ``IndexSpec`` (streaming kind: the KV index is a
        delta-buffered mutable index); constructing it IS the validation."""
        return IndexSpec(kind="streaming", K=self.K, L=self.L, c=self.c,
                         beta_override=self.beta_override, Nr=self.Nr,
                         leaf_size=self.leaf_size,
                         breakpoint_method=self.breakpoint_method,
                         build_impl=self.build_impl,
                         delta_capacity=self.delta_capacity)

    def derive_params(self) -> LSHParams:
        return derive_params(K=self.K, c=self.c, L=self.L,
                             beta_override=self.beta_override)


class HeadForest(NamedTuple):
    """H stacked per-(batch, kv-head) DE-Forests + their fused plans."""
    point_ids: jax.Array      # (H, L, n_pad) int32
    valid: jax.Array          # (H, L, n_pad) bool
    leaf_lo: jax.Array        # (H, L, nl, K) int16
    leaf_hi: jax.Array        # (H, L, nl, K) int16
    leaf_valid: jax.Array     # (H, L, nl) bool
    breakpoints: jax.Array    # (H, L, K, Nr+1) f32
    points_sorted: jax.Array  # (H, L, n_pad, d_aug) f32
    inv_perm: jax.Array       # (H, L, n) int32


class KVRetrieval(NamedTuple):
    ids: jax.Array            # (H, g, m_top + C) int32 positions (-1 = none)
    dists: jax.Array          # (H, g, m_top + C) f32 augmented-L2 (+inf)
    rounds: jax.Array         # (H, g) int32
    n_candidates: jax.Array   # (H, g) int32 — |S| in the sealed forests


class _RoundParams(NamedTuple):
    c: float                  # fused_round_update only reads params.c


@functools.partial(jax.jit, static_argnames=(
    "n", "m_top", "max_rounds", "leaf_size", "eps", "c", "beta"))
def _retrieve_impl(q_aug, A, forest: HeadForest, live_pos, delta_vecs,
                   delta_gids, delta_mask, r_min, *, n, m_top, max_rounds,
                   leaf_size, eps, c, beta):
    """Batched fused retrieval over {sealed forests + delta}.

    q_aug (H, g, d_aug); live_pos (n,) bool position-order tombstones;
    delta_vecs (H, C, d_aug); delta_gids (C,) positions; delta_mask (C,)
    live-and-assigned.  The round loop is the fused engine's: one
    ``range_rerank_heads`` pass per round, ``fused_round_update`` per head.
    """
    H, g, _ = q_aug.shape
    L, K = forest.breakpoints.shape[1], forest.breakpoints.shape[2]
    q_proj = jnp.einsum("hgd,dp->hgp", q_aug, A)
    q_proj = q_proj.reshape(H, g, L, K).transpose(0, 2, 1, 3)   # (H, L, g, K)
    live_sorted = (live_pos[jnp.clip(forest.point_ids, 0, n - 1)]
                   & forest.valid)                              # (H, L, n_pad)
    thresh = jnp.asarray(beta * n + m_top, jnp.float32)
    params = _RoundParams(c=c)
    upd = jax.vmap(functools.partial(fused_round_update, params=params,
                                     k=m_top, thresh=thresh),
                   in_axes=(0, 0, 0, 0, 0, None))

    def cond(state):
        rnd, rounds, r, done, best = state
        return jnp.any(~done) & (rnd < max_rounds)

    def body(state):
        rnd, rounds, r, done, best = state
        r_eff = jnp.where(done, -1.0, eps * r)                  # (H, g)
        dmat = kops.range_rerank_heads(
            q_aug, q_proj, r_eff, forest.leaf_lo, forest.leaf_hi,
            forest.leaf_valid, forest.breakpoints, forest.points_sorted,
            forest.valid, live_sorted, leaf_size=leaf_size)
        by_id = jnp.min(
            jnp.take_along_axis(dmat, forest.inv_perm[:, :, None, :],
                                axis=3), axis=1)                # (H, g, n)
        best, r, done, rounds = upd(best, by_id, r, done, rounds, rnd)
        return rnd + 1, rounds, r, done, best

    state0 = (jnp.asarray(0, jnp.int32), jnp.zeros((H, g), jnp.int32),
              jnp.full((H, g), r_min, jnp.float32),
              jnp.zeros((H, g), jnp.bool_),
              jnp.full((H, g, n), jnp.inf, jnp.float32))
    _, rounds, _, _, best = jax.lax.while_loop(cond, body, state0)

    ids_f, dists_f, count = jax.vmap(
        functools.partial(fused_topk, k=m_top, n=n))(best)
    ids_f = jnp.where(jnp.isfinite(dists_f), ids_f, -1)

    # Delta tier: exact augmented distances over the (tiny) buffer.
    diff = delta_vecs[:, None, :, :] - q_aug[:, :, None, :]     # (H, g, C, d)
    dd = jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, -1), 0.0))   # (H, g, C)
    dd = jnp.where(delta_mask[None, None, :], dd, jnp.inf)
    did = jnp.where(delta_mask, delta_gids.astype(jnp.int32), -1)
    did = jnp.broadcast_to(did[None, None, :], dd.shape)

    ids = jnp.concatenate([ids_f, did], axis=-1)
    dists = jnp.concatenate([dists_f, dd], axis=-1)
    return ids, dists, rounds, count


class KVCacheIndex:
    """Per-(batch, kv-head) DE-Forests over a KV cache's augmented keys.

    Satisfies ``repro.api.MutableAnnIndex``: ``upsert`` appends the next
    decode step's key(s), ``delete`` tombstones evicted positions,
    ``search`` answers the protocol surface (queries in decode layout
    (b, 1, h, dh), ids are cache positions).  ``retrieve`` is the
    decode-native entry returning the full (H, g, m) candidate tables the
    sparse-attention assembler consumes.
    """

    def __init__(self, spec: KVSpec, params: LSHParams, A: jax.Array,
                 b: int, hk: int, dh: int, R2: jax.Array,
                 forest: HeadForest, aug_keys: np.ndarray):
        self.spec = spec
        self.params = params
        self.A = A
        self.b, self.hk, self.dh = b, hk, dh
        self.H = b * hk
        self.d_aug = dh + 1
        self.R2 = R2                                   # (H,) frozen radius^2
        self.forest = forest
        self.n_sealed = aug_keys.shape[1]
        self.next_pos = self.n_sealed
        self._aug = aug_keys                           # (H, n_sealed, d_aug)
        self._live = np.ones(self.n_sealed, bool)
        self.delta = BatchedMemtable(self.H, spec.delta_capacity, self.d_aug)
        self.clip_total = 0                            # upserts beyond R
        self.seals = 0
        self._r_min_cache: Optional[float] = None

    # ------------------------------------------------------------------
    # Build (prefill)
    # ------------------------------------------------------------------

    @classmethod
    def prefill(cls, k_cache: jax.Array, key: jax.Array,
                spec: Optional[KVSpec] = None) -> "KVCacheIndex":
        """k_cache (b, S, hk, dh) -> index over all S prefix positions."""
        spec = spec or KVSpec()
        b, S, hk, dh = k_cache.shape
        params = spec.derive_params()
        keys = jnp.transpose(k_cache, (0, 2, 1, 3)).reshape(b * hk, S, dh)
        R2 = mips.mips_radius(keys, slack=spec.radius_slack)      # (H,)
        aug, _ = mips.augment_keys(keys, R2)                      # (H, S, d+1)
        A = hashing.sample_projections(key, dh + 1, spec.K, spec.L)
        proj = jnp.einsum("hsd,dp->hsp", aug, A)                  # (H, S, LK)
        forest = cls._build_heads(aug, proj, spec)
        return cls(spec, params, A, b, hk, dh, R2, forest,
                   np.asarray(aug))

    @staticmethod
    def _build_heads(aug: jax.Array, proj: jax.Array, spec: KVSpec,
                     breakpoints: Optional[np.ndarray] = None) -> HeadForest:
        """Stack per-head ``build_forest`` + ``make_fused_plan`` outputs.

        ``breakpoints`` ((H, L*K, Nr+1), optional) is the reseal path:
        encode with the prefill quantization (outer edges pre-widened by
        the caller) instead of re-selecting per-head quantiles.
        """
        H = aug.shape[0]
        cols = {f: [] for f in HeadForest._fields}
        for h in range(H):
            f = build_forest(
                proj[h], spec.K, spec.L, Nr=spec.Nr,
                leaf_size=spec.leaf_size,
                breakpoint_method=spec.breakpoint_method,
                breakpoints=(None if breakpoints is None
                             else jnp.asarray(breakpoints[h])),
                build_impl=spec.build_impl)
            plan = make_fused_plan(aug[h], f)
            cols["point_ids"].append(f.point_ids)
            cols["valid"].append(f.valid)
            cols["leaf_lo"].append(f.leaf_lo)
            cols["leaf_hi"].append(f.leaf_hi)
            cols["leaf_valid"].append(f.leaf_valid)
            cols["breakpoints"].append(f.breakpoints)
            cols["points_sorted"].append(plan.points_sorted)
            cols["inv_perm"].append(plan.inv_perm)
        return HeadForest(**{k: jnp.stack(v) for k, v in cols.items()})

    # ------------------------------------------------------------------
    # Mutation (the decode step's write half)
    # ------------------------------------------------------------------

    def upsert(self, vectors, gids=None) -> int:
        """Insert one decode step's keys ((b, hk, dh) or (b, 1, hk, dh));
        returns the assigned cache position.  ``gids`` must be None —
        positions are implicit (the KV cache is append-only)."""
        if gids is not None:
            raise ValueError("KVCacheIndex assigns positions itself; "
                             "gids must be None")
        vec = jnp.asarray(vectors)
        if vec.ndim == 4:                      # (b, 1, hk, dh) decode layout
            vec = vec[:, 0]
        if vec.shape != (self.b, self.hk, self.dh):
            raise ValueError(f"expected one key per (batch, kv-head) "
                             f"({self.b}, {self.hk}, {self.dh}), got "
                             f"{vec.shape}")
        rows = vec.reshape(self.H, 1, self.dh)
        aug, clipped = mips.augment_keys(rows, self.R2)     # frozen radius
        self.clip_total += int(clipped)
        pos = self.next_pos
        self.delta.add_step(pos, np.asarray(aug[:, 0]))
        self._live = np.append(self._live, True)
        self.next_pos += 1
        if self.delta.full:
            self._seal()
        return pos

    def delete(self, gids) -> int:
        """Tombstone cache positions (eviction); returns #newly dead."""
        removed = 0
        for pos in np.atleast_1d(np.asarray(gids, np.int64)):
            if not 0 <= pos < self.next_pos or not self._live[pos]:
                continue
            self._live[pos] = False
            if pos >= self.n_sealed:
                slot = int(np.where(self.delta.gids == pos)[0][0])
                self.delta.kill(slot)
            removed += 1
        return removed

    def maybe_compact(self) -> bool:
        """Seal a full delta (upsert already does; this is the protocol
        hook for callers that batch their mutations)."""
        if self.delta.full:
            self._seal()
            return True
        return False

    def _seal(self) -> None:
        """Rebuild the sealed forests over {old sealed + delta} with the
        prefill breakpoints (frozen quantization, outer edges widened to
        keep leaf boxes admissible for out-of-range new keys)."""
        cnt = self.delta.count
        if cnt == 0:
            return
        self._aug = np.concatenate(
            [self._aug, np.asarray(self.delta.vecs[:, :cnt])], axis=1)
        aug = jnp.asarray(self._aug)                   # (H, n_total, d_aug)
        proj = jnp.einsum("hsd,dp->hsp", aug, self.A)
        E = self.spec.Nr + 1
        bp = np.asarray(self.forest.breakpoints).reshape(
            self.H, self.spec.L * self.spec.K, E).copy()
        pmin = np.asarray(proj.min(axis=1))            # (H, L*K)
        pmax = np.asarray(proj.max(axis=1))
        bp[:, :, 0] = np.minimum(bp[:, :, 0], pmin)
        bp[:, :, E - 1] = np.maximum(bp[:, :, E - 1], pmax)
        self.forest = self._build_heads(aug, proj, self.spec, breakpoints=bp)
        self.n_sealed = self._aug.shape[1]
        self.delta.reset()
        self.seals += 1
        self._r_min_cache = None

    # ------------------------------------------------------------------
    # Retrieval (the decode step's read half)
    # ------------------------------------------------------------------

    def retrieve(self, q: jax.Array,
                 r_min: Optional[float] = None) -> KVRetrieval:
        """q (b, 1, h, dh) decode queries -> per-(kv-head, q-head)
        candidate positions ranked by augmented L2 (monotone in q.k)."""
        b, one, h, dh = q.shape
        if (b, dh) != (self.b, self.dh) or one != 1 or h % self.hk:
            raise ValueError(f"query shape {q.shape} does not match cache "
                             f"(b={self.b}, hk={self.hk}, dh={self.dh})")
        g = h // self.hk
        q_aug = mips.augment_queries(
            q.reshape(b, self.hk, g, dh).reshape(self.H, g, dh))
        # Rescale lanes to the key-norm scale: order-preserving per lane
        # (retrieval ranks by q.k either way) and it restores the LSH
        # contrast that large-norm attention queries otherwise destroy.
        q_aug = mips.normalize_queries(q_aug, self.R2[:, None])
        if r_min is None:
            r_min = self._estimate_r_min(q_aug)
        ids, dists, rounds, count = _retrieve_impl(
            q_aug, self.A, self.forest,
            jnp.asarray(self._live[:self.n_sealed]),
            jnp.asarray(self.delta.vecs), jnp.asarray(self.delta.gids),
            jnp.asarray(self.delta.live
                        & (np.arange(self.delta.capacity)
                           < self.delta.count)),
            jnp.asarray(r_min, jnp.float32),
            n=self.n_sealed, m_top=self.spec.m_top,
            max_rounds=self.spec.max_rounds, leaf_size=self.spec.leaf_size,
            eps=float(self.params.epsilon), c=float(self.params.c),
            beta=float(self.params.beta))
        return KVRetrieval(ids=ids, dists=dists, rounds=rounds,
                           n_candidates=count)

    def _estimate_r_min(self, q_aug: jax.Array) -> float:
        """First-retrieval starting radius: k-th augmented distance from a
        key subsample (paper §V-B1 heuristic), cached until the next seal
        (decode queries drift slowly; pad lanes would only over-search)."""
        if self._r_min_cache is None:
            qa = np.asarray(q_aug)                        # (H, g, d)
            m = min(self.n_sealed, 512)
            sub = self._aug[:, :m]                        # (H, m, d)
            d2 = (((qa[:, :, None, :] - sub[:, None, :, :]) ** 2)
                  .sum(-1))                               # (H, g, m)
            kth = np.sqrt(np.partition(
                d2, min(self.spec.m_top, m - 1), axis=-1)
                [..., min(self.spec.m_top, m - 1)])
            r = float(np.median(kth))
            self._r_min_cache = max(r / (self.params.c ** 2), 1e-6)
        return self._r_min_cache

    # ------------------------------------------------------------------
    # AnnIndex protocol surface
    # ------------------------------------------------------------------

    @property
    def n_points(self) -> int:
        return int(self._live.sum())

    def search(self, queries, request: Optional[SearchRequest] = None
               ) -> SearchResult:
        """Protocol search: queries (b, 1, h, dh) -> per-lane top-k cache
        positions, lanes flattened to (H*g, k)."""
        req = request or SearchRequest()
        res = self.retrieve(queries, r_min=req.r_min)
        k = min(req.k, res.ids.shape[-1])
        neg, sel = jax.lax.top_k(-res.dists, k)
        ids = jnp.take_along_axis(res.ids, sel, axis=-1)
        H, g = res.rounds.shape
        stats = SearchStats(
            engine="fused-kv", r_min=self._r_min_cache or float("nan"),
            r_min_cached=req.r_min is None, rounds=res.rounds.reshape(-1),
            n_candidates=res.n_candidates.reshape(-1), final_r=None)
        return SearchResult(ids=ids.reshape(H * g, k),
                            dists=(-neg).reshape(H * g, k), stats=stats,
                            raw=res)

    def r_min_for(self, k: int) -> float:
        """Starting-radius estimate from key-to-key augmented distances
        (protocol surface; ``retrieve`` refines from the live queries)."""
        if self._r_min_cache is None:
            sub = jnp.asarray(self._aug[:, : min(self.n_sealed, 256)])
            self._estimate_r_min(sub[:, : max(1, min(8, sub.shape[1]))])
        return self._r_min_cache

    def save(self, path) -> None:
        raise NotImplementedError(
            "KV caches are ephemeral: rebuild with KVCacheIndex.prefill "
            "from the cache keys instead of snapshotting")

    def index_size_bytes(self) -> int:
        arrays = sum(int(np.asarray(a).nbytes) for a in self.forest)
        return arrays + int(self.delta.vecs.nbytes)

    @property
    def scan_fraction(self) -> float:
        """Retrieved candidates / attendable positions — the work model the
        decode benchmark reports (docs/DESIGN.md §10)."""
        m = self.spec.m_top + self.delta.capacity
        return m / max(1, self.next_pos)
