"""DET-LSH attention decode on the production stack (docs/DESIGN.md §10).

Re-platforms the seed ``core.det_attention`` prototype: the KV cache is a
``repro.api.MutableAnnIndex`` (``KVCacheIndex``) — prefill is a batched
fused build, each decode step is a streaming-delta upsert plus a batched
fused ``range_rerank`` retrieval, and ``sparse_decode_attention`` computes
exact softmax over the retrieved ∪ window ∪ sink survivor set.  The
MIPS -> L2 reduction (``repro.decode.mips``) is the thin transform layer
between attention scores and the Euclidean engine.
"""

from repro.decode.mips import (DEFAULT_SLACK, augment_keys, augment_queries,
                               mips_radius)
from repro.decode.kv_index import (HeadForest, KVCacheIndex, KVRetrieval,
                                   KVSpec)
from repro.decode.attention import LSHDecoder, sparse_decode_attention

__all__ = ["KVCacheIndex", "KVSpec", "KVRetrieval", "HeadForest",
           "LSHDecoder", "sparse_decode_attention", "mips_radius",
           "augment_keys", "augment_queries", "DEFAULT_SLACK"]
