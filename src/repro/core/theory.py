"""Theoretical parameter machinery of DET-LSH (paper §II-C, §V).

Implements:
  * chi-square upper quantiles (Lemma 2),
  * the Lemma 3 coupling  eps^2 = chi2_{a1}(K) = c^2 * chi2_{a2}(K),
    L = -1/ln(a1),  beta = 2 - 2*a2^L,
  * the success-probability bound 1/2 - 1/e (Theorems 1-3).

These are *configuration-time* host computations (pure scipy/numpy); nothing
here is traced by JAX.  A jax-traceable chi2 CDF (via gammainc) is provided
for in-graph diagnostics.
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np
from jax.scipy.special import gammainc
from scipy.stats import chi2 as _chi2

SUCCESS_PROBABILITY = 0.5 - 1.0 / math.e  # Theorems 1-3 lower bound.


def chi2_upper_quantile(alpha: float, k: int) -> float:
    """chi2_alpha(K): the value y with Pr[Y > y] = alpha for Y ~ chi2(K)."""
    if not 0.0 < alpha < 1.0:
        raise ValueError(f"alpha must be in (0,1), got {alpha}")
    return float(_chi2.ppf(1.0 - alpha, k))


def chi2_sf(y: float, k: int) -> float:
    """Pr[Y > y] for Y ~ chi2(K)."""
    return float(_chi2.sf(y, k))


def chi2_cdf_jax(y, k):
    """Traceable chi2 CDF: regularized lower incomplete gamma(k/2, y/2)."""
    return gammainc(k / 2.0, jnp.asarray(y) / 2.0)


@dataclasses.dataclass(frozen=True)
class LSHParams:
    """Derived DET-LSH parameters (Lemma 3)."""

    K: int          # projected-space dimensionality
    L: int          # number of independent projected spaces / DE-Trees
    c: float        # approximation ratio
    alpha1: float   # per-space miss probability for near points
    alpha2: float   # per-space survival probability for far points
    epsilon: float  # projected-radius inflation: range query uses eps*r
    beta: float     # max false-positive fraction; stop at |S| >= beta*n + k

    @property
    def success_probability(self) -> float:
        return SUCCESS_PROBABILITY


def derive_params(K: int = 16, c: float = 1.5, L: int = 4,
                  beta_override: float | None = None) -> LSHParams:
    """Solve the Lemma 3 system given (K, c, L).

    L = -1/ln(alpha1)        =>  alpha1 = exp(-1/L)
    eps^2 = chi2_{alpha1}(K)
    chi2_{alpha2}(K) = eps^2 / c^2  =>  alpha2 = SF(eps^2/c^2; K)
    beta = 2 - 2*alpha2^L    (so that Markov gives Pr[E3] >= 1/2)

    ``beta_override`` reproduces the paper's experimental setting (beta=0.1)
    while keeping the theoretically coupled (eps, L).
    """
    if K < 1 or L < 1 or c <= 1.0:
        raise ValueError(f"need K>=1, L>=1, c>1; got K={K} L={L} c={c}")
    alpha1 = math.exp(-1.0 / L)
    eps2 = chi2_upper_quantile(alpha1, K)
    epsilon = math.sqrt(eps2)
    alpha2 = chi2_sf(eps2 / (c * c), K)
    beta = 2.0 - 2.0 * (alpha2 ** L)
    if beta_override is not None:
        beta = float(beta_override)
    return LSHParams(K=K, L=L, c=c, alpha1=alpha1, alpha2=alpha2,
                     epsilon=epsilon, beta=beta)


def beta_of_L(K: int, c: float, Ls: np.ndarray) -> np.ndarray:
    """Theoretical beta as a function of L (paper Fig. 6)."""
    out = []
    for L in np.asarray(Ls, dtype=np.int64):
        out.append(derive_params(K=K, c=c, L=int(L)).beta)
    return np.asarray(out)


def event_probabilities(p: LSHParams) -> dict:
    """Pr[E1], upper bound on per-point Pr[E2], Pr[E3] lower bound (Lemma 3)."""
    pr_e1 = 1.0 - p.alpha1 ** p.L
    pr_e2_point = 1.0 - p.alpha2 ** p.L
    pr_e3 = 1.0 - pr_e2_point / p.beta if p.beta > 0 else 0.0
    return {"pr_E1": pr_e1, "pr_E2_per_point": pr_e2_point, "pr_E3": pr_e3}
