"""PDET-LSH: the multi-pod distributed runtime (paper §IV, Alg. 6/7/8).

CPU-thread parallelism -> TPU SPMD mapping (DESIGN.md §2):

  * Alg. 6 (parallel dynamic encoding, dimension-partitioned): breakpoint
    selection runs as *distributed histogram refinement* — per-shard
    histograms are ``psum``-reduced so every device derives the identical,
    globally equi-depth breakpoints.  log2(N_r) rounds of small (D, N_r)
    collectives replace the paper's per-worker QuickSelect.
  * Alg. 7 (parallel index construction, data-partitioned): each device
    builds a complete DE-Forest over its own shard of the dataset.  No
    synchronization at all (the paper needs a barrier + subtree hand-off).
  * Alg. 8 + §IV-C (parallel query): queries are replicated; every device
    range-queries its local forest and reranks its local candidates
    (rerank gathers are shard-local — the dataset is sharded *with* the
    index).  Termination conditions T1/T2 of Alg. 5 are evaluated on
    ``psum``-ed global counts, so all devices advance the radius in
    lockstep and the termination logic — hence Theorem 3 — is preserved.
    The final top-k is an ``all_gather`` of per-shard top-k + a merge.

Determinism/equivalence: ``serial_reference_*`` run the identical sharded
algorithm as plain vmapped code on one device; tests assert the shard_map
version returns exactly the same ids/distances (the PDET == DET claim,
Fig. 20/21).

Two sharded runtimes live here (DESIGN.md §7):

  * ``PDETLSH`` / ``build_pdet`` — the *structure-partitioned* runtime
    above (per-shard forests, work-partitioned build).  Kept for the
    parallel-build benchmarks and the serial-reference equivalence tests.
  * ``PDETIndex`` — the *layout-partitioned* runtime behind ``repro.api``:
    the one global forest sharded across the mesh, queried by the fused
    round with an exact ``pmin`` merge, making PDET == DET a bit-identical
    API contract for any device count.  This is the index ``repro.api.build``
    returns for an ``IndexSpec`` with a ``placement`` and the ``pdet``
    entry in the engine registry.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.api import registry as engine_registry
from repro.sharding.compat import shard_map

from repro.core import encoding as enc
from repro.core import hashing
from repro.core.detree import DEForest, build_tree, fused_forest_arrays
from repro.core.query import (FusedPlan, QueryConfig, QueryResult,
                              _merge_candidates, fused_round_update,
                              fused_topk, knn_query_batch)
from repro.core.theory import LSHParams


# ---------------------------------------------------------------------------
# Distributed breakpoint selection (Alg. 6 analogue)
# ---------------------------------------------------------------------------

def distributed_breakpoints(proj_local: jax.Array, n_global: int,
                            Nr: int, rounds: int,
                            axes: Sequence[str] | None) -> jax.Array:
    """Globally equi-depth breakpoints over data sharded on ``axes``.

    proj_local: (n_local, D).  Inside shard_map, ``axes`` are the mesh axes
    the data is sharded over; pass None for the serial reference.
    """
    def pmin(x):
        return jax.lax.pmin(x, axes) if axes else x

    def pmax(x):
        return jax.lax.pmax(x, axes) if axes else x

    def psum(x):
        return jax.lax.psum(x, axes) if axes else x

    lo = pmin(jnp.min(proj_local, axis=0))
    hi = pmax(jnp.max(proj_local, axis=0))
    t = jnp.arange(Nr + 1, dtype=jnp.float32) / Nr
    edges = lo[:, None] + (hi - lo)[:, None] * t[None, :]

    def body(_, edges):
        counts = psum(enc.histogram_counts(proj_local, edges))
        return enc.refine_breakpoints_from_counts(edges, counts, n_global)

    return jax.lax.fori_loop(0, rounds, body, edges)


# ---------------------------------------------------------------------------
# Shard-local build (Alg. 7 analogue)
# ---------------------------------------------------------------------------

def _build_local_forest(data_local: jax.Array, A: jax.Array, K: int, L: int,
                        Nr: int, leaf_size: int, bp_rounds: int,
                        n_global: int,
                        axes: Sequence[str] | None) -> DEForest:
    """Per-shard forest over the local data (Alg. 7), through the shared
    fused single-sort pipeline (encode + key-pack kernel, one stable sort
    for all L trees — docs/DESIGN.md §8); only the breakpoints are global
    (psum'd histogram refinement).  Bit-identical to the per-tree reference
    builder, which ``serial_reference_build`` still uses as the
    cross-check (tests/test_distributed.py, tests/test_build_fused.py)."""
    n_local = data_local.shape[0]
    proj = hashing.project(data_local, A)
    bp_all = distributed_breakpoints(proj, n_global, Nr, bp_rounds, axes)
    parts = fused_forest_arrays(proj, bp_all, K=K, L=L, leaf_size=leaf_size)
    return DEForest(n=n_local, leaf_size=leaf_size,
                    breakpoints=bp_all.reshape(L, K, Nr + 1), **parts)


# ---------------------------------------------------------------------------
# Shard-local query with global termination (Alg. 5 + Alg. 8 analogue)
# ---------------------------------------------------------------------------

def _knn_local(data_local: jax.Array, forest: DEForest, A: jax.Array,
               params: LSHParams, q: jax.Array, cfg: QueryConfig,
               n_global: int, shard_offset: jax.Array,
               axes: Sequence[str] | None):
    """One query against the local shard, radius loop in global lockstep.

    Returns per-shard top-k (ids globalized via shard_offset) — caller
    all_gathers and merges.
    """
    from repro.core.query import range_query_round, exact_distances

    def psum(x):
        return jax.lax.psum(x, axes) if axes else x

    n_local = data_local.shape[0]
    K, L = params.K, params.L
    M = min(cfg.M, forest.n_leaves)
    round_cap = L * M * forest.leaf_size
    # Local buffer: the global termination threshold can be met by any
    # distribution of candidates over shards, so each shard must be able to
    # hold everything it could contribute before termination.
    cap = min(int(params.beta * n_global) + cfg.k + round_cap,
              n_local + round_cap)
    thresh = jnp.asarray(params.beta * n_global + cfg.k, jnp.float32)
    q_proj = (q @ A).reshape(L, K)

    def cond(state):
        rnd, r, ids, d, done = state
        return (~done) & (rnd < cfg.max_rounds)

    def body(state):
        rnd, r, ids, d, done = state
        new_ids, ok = range_query_round(forest, q_proj, params.epsilon * r,
                                        cfg.M, mode=cfg.mode)
        new_d = exact_distances(data_local, q, new_ids, ok)
        new_ids = jnp.where(ok, new_ids, n_local)
        ids, d, count_local = _merge_candidates(n_local, ids, d, new_ids,
                                                new_d)
        count = psum(count_local.astype(jnp.float32))            # global |S|
        within_local = jnp.sum(d <= params.c * r).astype(jnp.float32)
        within = psum(within_local)                              # global T2
        done = (count >= thresh) | (within >= cfg.k)
        r = jnp.where(done, r, r * params.c)
        return rnd + 1, r, ids, d, done

    state0 = (jnp.asarray(0, jnp.int32), jnp.asarray(cfg.r_min, jnp.float32),
              jnp.full((cap,), n_local, jnp.int32),
              jnp.full((cap,), jnp.inf), jnp.asarray(False))
    rnd, r, ids, d, done = jax.lax.while_loop(cond, body, state0)

    kk = min(cfg.k, cap)
    negd, sel = jax.lax.top_k(-d, kk)
    local_ids = ids[sel]
    gids = jnp.where(local_ids < n_local, local_ids + shard_offset,
                     n_global).astype(jnp.int32)
    return gids, -negd, rnd


def _merge_global_topk(gids: jax.Array, gdists: jax.Array, k: int,
                       axes: Sequence[str] | None):
    """all_gather per-shard top-k and take the global top-k."""
    if axes:
        gids = jax.lax.all_gather(gids, axes, tiled=True)
        gdists = jax.lax.all_gather(gdists, axes, tiled=True)
    negd, sel = jax.lax.top_k(-gdists, k)
    return gids[sel], -negd


# ---------------------------------------------------------------------------
# Public API: shard_map-based build & query over a mesh
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PDETLSH:
    """A PDET-LSH index sharded over mesh ``axes`` (data-parallel)."""

    params: LSHParams
    A: jax.Array
    forest: DEForest          # arrays sharded on their n/leaf axes
    data: jax.Array           # (n, d) sharded on axis 0
    mesh: Mesh
    axes: tuple[str, ...]
    n_global: int

    def query(self, queries: jax.Array, k: int = 50, *,
              r_min: float | None = None, M: int = 8,
              mode: str = "leaf", max_rounds: int = 48):
        if r_min is None:
            from repro.core import estimate_r_min
            r_min = estimate_r_min(
                jax.device_get(self.data)[: min(2048, self.n_global)],
                queries, k, self.params.c)
        cfg = QueryConfig(k=k, M=M, r_min=r_min, mode=mode,
                          max_rounds=max_rounds)
        return query_pdet(self, queries, cfg)


def _shard_spec(mesh: Mesh, axes: tuple[str, ...]):
    data_p = P(axes)
    forest_p = DEForest(
        point_ids=P(None, axes), proj_sorted=P(None, axes, None),
        codes_sorted=P(None, axes, None), valid=P(None, axes),
        leaf_lo=P(None, axes, None), leaf_hi=P(None, axes, None),
        leaf_valid=P(None, axes), breakpoints=P(),
        n=0, leaf_size=0)
    return data_p, forest_p


def build_pdet(data: jax.Array, key: jax.Array, params: LSHParams,
               mesh: Mesh, axes: tuple[str, ...] = ("data",), *,
               Nr: int = enc.DEFAULT_NR, leaf_size: int = 64,
               bp_rounds: int = 8) -> PDETLSH:
    """Build the distributed index.  ``data`` (n, d); n divisible by the
    product of mesh axis sizes in ``axes`` (pad upstream)."""
    n, d = data.shape
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    assert n % n_shards == 0, (n, n_shards)
    A = hashing.sample_projections(key, d, params.K, params.L)

    data_p, forest_p = _shard_spec(mesh, axes)
    forest_specs = dict(point_ids=P(None, axes),
                        proj_sorted=P(None, axes, None),
                        codes_sorted=P(None, axes, None),
                        valid=P(None, axes),
                        leaf_lo=P(None, axes, None),
                        leaf_hi=P(None, axes, None),
                        leaf_valid=P(None, axes),
                        breakpoints=P())

    def build(data_local, A):
        f = _build_local_forest(data_local, A, params.K, params.L, Nr,
                                leaf_size, bp_rounds, n, axes)
        return dict(point_ids=f.point_ids, proj_sorted=f.proj_sorted,
                    codes_sorted=f.codes_sorted, valid=f.valid,
                    leaf_lo=f.leaf_lo, leaf_hi=f.leaf_hi,
                    leaf_valid=f.leaf_valid, breakpoints=f.breakpoints)

    built = shard_map(
        build, mesh=mesh, in_specs=(data_p, P()),
        out_specs=forest_specs, check_vma=False)(data, A)
    n_local = n // n_shards
    forest = DEForest(n=n_local, leaf_size=leaf_size, **built)
    return PDETLSH(params=params, A=A, forest=forest, data=data, mesh=mesh,
                   axes=tuple(axes), n_global=n)


def query_pdet(index: PDETLSH, queries: jax.Array, cfg: QueryConfig):
    """Batched distributed c^2-k-ANN (queries replicated; Theorem 3 path)."""
    mesh, axes = index.mesh, index.axes
    n_global = index.n_global
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    n_local = n_global // n_shards

    data_p, _ = _shard_spec(mesh, axes)
    forest_specs = DEForest(
        point_ids=P(None, axes), proj_sorted=P(None, axes, None),
        codes_sorted=P(None, axes, None), valid=P(None, axes),
        leaf_lo=P(None, axes, None), leaf_hi=P(None, axes, None),
        leaf_valid=P(None, axes), breakpoints=P(), n=index.forest.n,
        leaf_size=index.forest.leaf_size)

    def run(data_local, forest, A, queries):
        # shard offset from the mesh position along the data axes
        # (row-major over ``axes`` — matches jnp.reshape sharding order)
        idx = jnp.asarray(0, jnp.int32)
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        offset = idx * n_local

        def one(q):
            gids, gd, rnd = _knn_local(data_local, forest, A, index.params,
                                       q, cfg, n_global, offset, axes)
            mids, md = _merge_global_topk(gids, gd, cfg.k, axes)
            return mids, md, rnd

        return jax.vmap(one)(queries)

    in_specs = (data_p, forest_specs, P(), P())
    out_specs = (P(), P(), P())
    gids, gdists, rounds = shard_map(
        run, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False)(index.data, index.forest, index.A, queries)
    return gids, gdists, rounds


# ---------------------------------------------------------------------------
# Serial reference: identical sharded semantics on one device (for tests)
# ---------------------------------------------------------------------------

def serial_reference_build(data: jax.Array, key: jax.Array,
                           params: LSHParams, n_shards: int, *,
                           Nr: int = enc.DEFAULT_NR, leaf_size: int = 64,
                           bp_rounds: int = 8):
    """vmap-over-shards build with summed (\"psum\") histogram counts."""
    from repro.core.detree import check_nr
    check_nr(Nr)
    n, d = data.shape
    assert n % n_shards == 0
    A = hashing.sample_projections(key, d, params.K, params.L)
    shards = data.reshape(n_shards, n // n_shards, d)
    proj = jax.vmap(lambda x: hashing.project(x, A))(shards)

    # distributed_breakpoints with psum == sum over the shard axis
    lo = jnp.min(proj, axis=(0, 1))
    hi = jnp.max(proj, axis=(0, 1))
    t = jnp.arange(Nr + 1, dtype=jnp.float32) / Nr
    edges = lo[:, None] + (hi - lo)[:, None] * t[None, :]
    for _ in range(bp_rounds):
        counts = sum(enc.histogram_counts(proj[s], edges)
                     for s in range(n_shards))
        edges = enc.refine_breakpoints_from_counts(edges, counts, n)

    K, L = params.K, params.L

    def build_one(proj_local):
        codes = enc.encode(proj_local, edges)
        nl = proj_local.shape[0]
        proj_t = proj_local.reshape(nl, L, K).transpose(1, 0, 2)
        codes_t = codes.reshape(nl, L, K).transpose(1, 0, 2)
        bp_t = edges.reshape(L, K, Nr + 1)
        return jax.vmap(functools.partial(build_tree, leaf_size=leaf_size))(
            proj_t, codes_t, bp_t)

    parts = jax.vmap(build_one)(proj)      # leading shard axis on everything
    return A, parts, edges


def serial_reference_query(data: jax.Array, A: jax.Array, parts: dict,
                           params: LSHParams, queries: jax.Array,
                           cfg: QueryConfig, n_shards: int, leaf_size: int):
    """Runs _knn_local per shard with psum == sum across shards, serially."""
    from repro.core.query import range_query_round, exact_distances

    n, d = data.shape
    n_local = n // n_shards
    shards = data.reshape(n_shards, n_local, d)
    forests = [
        DEForest(n=n_local, leaf_size=leaf_size,
                 **{k: v[s] for k, v in parts.items()})
        for s in range(n_shards)
    ]
    K, L = params.K, params.L
    out_ids, out_d = [], []
    for q in queries:
        q_proj = (q @ A).reshape(L, K)
        M = min(cfg.M, forests[0].n_leaves)
        round_cap = L * M * leaf_size
        cap = min(int(params.beta * n) + cfg.k + round_cap,
                  n_local + round_cap)
        bufs = [(jnp.full((cap,), n_local, jnp.int32),
                 jnp.full((cap,), jnp.inf)) for _ in range(n_shards)]
        r = cfg.r_min
        for _ in range(cfg.max_rounds):
            counts, withins = [], []
            for s in range(n_shards):
                ids_b, d_b = bufs[s]
                new_ids, ok = range_query_round(
                    forests[s], q_proj, params.epsilon * r, cfg.M,
                    mode=cfg.mode)
                new_d = exact_distances(shards[s], q, new_ids, ok)
                new_ids = jnp.where(ok, new_ids, n_local)
                ids_b, d_b, cnt = _merge_candidates(n_local, ids_b, d_b,
                                                    new_ids, new_d)
                bufs[s] = (ids_b, d_b)
                counts.append(float(cnt))
                withins.append(float(jnp.sum(d_b <= params.c * r)))
            if sum(counts) >= params.beta * n + cfg.k or \
                    sum(withins) >= cfg.k:
                break
            r = r * params.c
        # merge per-shard top-k
        all_ids, all_d = [], []
        for s in range(n_shards):
            ids_b, d_b = bufs[s]
            kk = min(cfg.k, cap)
            negd, sel = jax.lax.top_k(-d_b, kk)
            lids = ids_b[sel]
            all_ids.append(jnp.where(lids < n_local, lids + s * n_local, n))
            all_d.append(-negd)
        cat_i = jnp.concatenate(all_ids)
        cat_d = jnp.concatenate(all_d)
        negd, sel = jax.lax.top_k(-cat_d, cfg.k)
        out_ids.append(cat_i[sel])
        out_d.append(-negd)
    return jnp.stack(out_ids), jnp.stack(out_d)


# ===========================================================================
# PDETIndex: the protocol-level sharded index (repro.api; DESIGN.md §7)
# ===========================================================================
#
# ``PDETLSH`` above partitions the *structure*: each device builds its own
# complete forest over its data shard.  That parallelizes the build (Alg. 7)
# but per-shard leaf partitions admit different candidate sets than the one
# global forest, so its equivalence to DET-LSH is statistical, not exact.
#
# ``PDETIndex`` instead partitions the *layout* of the one global forest
# (paper Alg. 8, the serving-critical phase): the code-sorted point arrays
# and leaf summaries are sharded over the mesh's data axes (a shard owns
# whole leaves), queries/A/breakpoints replicate, and each radius round is
# the fused engine's round run shard-locally, merged across shards with
# ``pmin`` — which is *exact* (min is associative and commutative in fp32,
# unlike add).  Every (tree, point) distance lives on exactly one shard and
# is computed by the identical kernel tile, so the merged per-id table —
# and therefore T1/T2, the lockstep radius schedule, and the final top-k —
# are bit-identical to ``fused_query_batch`` on one device, for ANY shard
# count.  The PDET == DET claim (paper Fig. 20/21) is thereby an exact API
# contract, not a statistical one (tests/test_pdet_api.py).


def _pdet_partition_specs(data_axes: tuple):
    """PartitionSpecs of the PDET layout, logical-name style
    (``sharding/rules.py`` conventions: 'points'/'leaves' shard over the
    placement's data axes, everything else replicates)."""
    ax = tuple(data_axes)
    return {
        "data": P(ax),                      # (n, d) rows
        "points": P(None, ax),              # (L, n_pad) sorted positions
        "points_k": P(None, ax, None),      # (L, n_pad, K|d)
        "leaves": P(None, ax),              # (L, n_leaves)
        "leaves_k": P(None, ax, None),      # (L, n_leaves, K)
        "replicated": P(),
    }


def _forest_pdet_specs(forest: DEForest, specs: dict) -> DEForest:
    return DEForest(
        point_ids=specs["points"], proj_sorted=specs["points_k"],
        codes_sorted=specs["points_k"], valid=specs["points"],
        leaf_lo=specs["leaves_k"], leaf_hi=specs["leaves_k"],
        leaf_valid=specs["leaves"], breakpoints=specs["replicated"],
        n=forest.n, leaf_size=forest.leaf_size)


def _pad_layout_to_shards(forest: DEForest, plan: FusedPlan,
                          n_shards: int) -> tuple:
    """Pad the leaf axis (and the matching point slots) so every shard
    owns the same number of whole leaves.  Padding leaves are invalid
    (never admitted) and padding point slots carry ``valid=False`` and
    the ``n`` sentinel id, so no answer can change; real sorted positions
    keep their indices (padding appends), so ``inv_perm`` is untouched."""
    n_leaves = forest.n_leaves
    pad_l = (-n_leaves) % n_shards
    if pad_l == 0:
        return forest, plan
    pad_p = pad_l * forest.leaf_size

    def pad(x, width, value):
        widths = [(0, 0)] * x.ndim
        widths[1] = (0, width)
        return jnp.pad(x, widths, constant_values=value)

    forest = DEForest(
        n=forest.n, leaf_size=forest.leaf_size,
        point_ids=pad(forest.point_ids, pad_p, forest.n),
        proj_sorted=pad(forest.proj_sorted, pad_p, 0.0),
        codes_sorted=pad(forest.codes_sorted, pad_p, 0),
        valid=pad(forest.valid, pad_p, False),
        leaf_lo=pad(forest.leaf_lo, pad_l, 0),
        leaf_hi=pad(forest.leaf_hi, pad_l, 0),
        leaf_valid=pad(forest.leaf_valid, pad_l, False),
        breakpoints=forest.breakpoints)
    plan = FusedPlan(points_sorted=pad(plan.points_sorted, pad_p, 0.0),
                     inv_perm=plan.inv_perm)
    return forest, plan


def pdet_query_batch(forest: DEForest, A: jax.Array, params: LSHParams,
                     queries: jax.Array, cfg: QueryConfig, plan: FusedPlan,
                     mesh: Mesh, axes: tuple, *,
                     n_active=None):
    """Sharded fused c^2-k-ANN round loop (Alg. 8 over the global layout).

    Per round, each shard runs one ``range_rerank`` pass over its own
    leaves/points, folds its tree rows into id space through the (global)
    inverse permutation, and the shards merge with an exact ``pmin``; the
    replicated best-distance table then steps through the *same*
    ``fused_round_update`` as the single-device fused engine — see the
    section comment for why this makes the result bit-identical.

    Returns ``(QueryResult, shard_candidates)`` where ``shard_candidates``
    is the (n_shards,) count of (tree, point) entries scanned per shard.
    """
    if getattr(cfg, "probe_depth", 0):
        raise NotImplementedError(
            "engine 'pdet' does not support multi-probe (probe_depth > 0): "
            "each shard only sees its own leaves, so a per-shard "
            "slack ranking would admit a different probe set per device "
            "count and break the bit-identical PDET == DET contract; use "
            "engine='fused' or 'vmap' (they run on the sharded arrays), or "
            "probe_depth=0")
    n = forest.n
    B = queries.shape[0]
    K, L = params.K, params.L
    n_pad = forest.point_ids.shape[1]
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    n_local = n_pad // n_shards
    thresh = jnp.asarray(params.beta * n + cfg.k, jnp.float32)
    interpret = cfg.dist_impl == "pallas_interpret"
    q_proj = (queries @ A).reshape(B, L, K).transpose(1, 0, 2)   # (L, B, K)
    done0 = (jnp.zeros((B,), jnp.bool_) if n_active is None
             else jnp.arange(B) >= jnp.asarray(n_active))

    from repro.kernels import ops as kops
    specs = _pdet_partition_specs(axes)

    def run(pts_local, valid_local, lo, hi, lv, bp, inv_perm, q, qp, done0):
        sidx = jnp.asarray(0, jnp.int32)
        for a in axes:          # row-major over axes — matches device_put
            sidx = sidx * mesh.shape[a] + jax.lax.axis_index(a)
        off = sidx * n_local

        def cond(state):
            rnd, rounds, r, done, best, scanned = state
            return jnp.any(~done) & (rnd < cfg.max_rounds)

        def body(state):
            rnd, rounds, r, done, best, scanned = state
            r_eff = jnp.where(done, -1.0, params.epsilon * r)    # lane mask
            dmat = kops.range_rerank(
                q, qp, r_eff, lo, hi, lv, bp, pts_local, valid_local, None,
                leaf_size=forest.leaf_size, interpret=interpret,
                block_q=cfg.block_q, block_l=cfg.block_l)  # (L, B, n_local)
            # f32 accumulator: an int32 count wraps negative on large
            # (L, B, n_local) workloads (int64 needs x64); this is a work
            # counter, so f32's rounding at scale beats wrap-around.
            scanned = scanned + jnp.sum(jnp.isfinite(dmat),
                                        dtype=jnp.float32)
            # Fold this shard's tree rows into id space: a point's sorted
            # position is local iff it falls in [off, off + n_local).
            rel = inv_perm - off                                 # (L, n)
            here = (rel >= 0) & (rel < n_local)
            safe = jnp.clip(rel, 0, n_local - 1)
            g = jnp.take_along_axis(dmat, safe[:, None, :], axis=2)
            g = jnp.where(here[:, None, :], g, jnp.inf)
            by_id = jnp.min(g, axis=0)                           # (B, n)
            by_id = jax.lax.pmin(by_id, axes)    # exact cross-shard merge
            best, r, done, rounds = fused_round_update(
                best, by_id, r, done, rounds, rnd, params=params, k=cfg.k,
                thresh=thresh)
            return rnd + 1, rounds, r, done, best, scanned

        state0 = (jnp.asarray(0, jnp.int32), jnp.zeros((B,), jnp.int32),
                  jnp.full((B,), cfg.r_min, jnp.float32), done0,
                  jnp.full((B, n), jnp.inf, jnp.float32),
                  jnp.asarray(0.0, jnp.float32))
        rnd, rounds, r, done, best, scanned = jax.lax.while_loop(
            cond, body, state0)
        ids, dists, count = fused_topk(best, cfg.k, n)
        return ids, dists, rounds, count, r, scanned[None]

    in_specs = (specs["points_k"], specs["points"], specs["leaves_k"],
                specs["leaves_k"], specs["leaves"], P(), P(), P(), P(), P())
    out_specs = (P(), P(), P(), P(), P(), P(axes))
    ids, dists, rounds, count, r, scanned = shard_map(
        run, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False)(
            plan.points_sorted, forest.valid, forest.leaf_lo,
            forest.leaf_hi, forest.leaf_valid, forest.breakpoints,
            plan.inv_perm, queries, q_proj, done0)
    res = QueryResult(ids=ids, dists=dists, rounds=rounds,
                      n_candidates=count, final_r=r)
    return res, scanned


@dataclasses.dataclass
class PDETIndex:
    """The sharded PDET-LSH index behind the ``repro.api`` surface.

    Satisfies the ``AnnIndex`` protocol end-to-end: built from an
    ``IndexSpec`` whose ``placement`` names the mesh, searched through
    ``SearchRequest``/``SearchResult`` via the ``pdet`` engine (with
    per-shard counters in ``SearchStats``), snapshotted as per-shard files
    (``repro.api.load`` reshards onto whatever device count is present),
    and served by ``LSHService`` purely through the protocols.
    """

    params: LSHParams
    A: jax.Array               # replicated
    forest: DEForest           # the ONE global forest, layout-sharded
    data: jax.Array            # (n, d), rows sharded over the data axes
    plan: FusedPlan            # points_sorted sharded, inv_perm replicated
    mesh: Mesh
    placement: "object"        # repro.api.PlacementSpec
    spec: Optional["object"] = dataclasses.field(
        default=None, repr=False, compare=False)
    _r_min_cache: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    # ------------------------------------------------------------------
    # Build
    # ------------------------------------------------------------------

    @classmethod
    def from_spec(cls, data: jax.Array, key: jax.Array, spec, *,
                  mesh: Optional[Mesh] = None) -> "PDETIndex":
        """Build from an ``IndexSpec`` with a ``placement``.

        The forest is built by the *identical* code path as
        ``DETLSH.from_spec`` on the same spec minus placement (same key,
        same arrays — the foundation of the bit-identity contract), then
        the layout is sharded onto the placement's mesh.
        """
        placement = spec.placement
        if placement is None:
            raise ValueError("PDETIndex.from_spec needs spec.placement "
                             "(use repro.api.build for unplaced specs)")
        from repro.core import DETLSH
        base_spec = dataclasses.replace(spec, placement=None)
        det = DETLSH.from_spec(data, key, base_spec)
        return cls.from_detlsh(det, placement, mesh=mesh, spec=spec)

    @classmethod
    def from_detlsh(cls, det, placement, *, mesh: Optional[Mesh] = None,
                    spec=None) -> "PDETIndex":
        """Shard an already-built single-device index onto a mesh.

        When the leaf count is not a multiple of the shard count, the
        layout is padded with *invalid* leaves (and their empty point
        slots) up to one: invalid leaves are never admitted and padding
        point slots carry ``valid=False``, so the padding changes no
        answer — bit-identity survives any shard count.  Data rows shard
        when divisible, else replicate (they only feed the fallback
        engines, host-side estimates, and snapshots).
        """
        if mesh is None:
            from repro.launch.mesh import mesh_from_placement
            mesh = mesh_from_placement(placement)
        axes = placement.data_axes
        n_shards = placement.n_shards
        forest, plan = _pad_layout_to_shards(det.forest, det.fused_plan(),
                                             n_shards)
        specs = _pdet_partition_specs(axes)

        def put(x, spec_):
            return jax.device_put(x, NamedSharding(mesh, spec_))

        data_spec = (specs["data"] if det.data.shape[0] % n_shards == 0
                     else specs["replicated"])
        fspecs = _forest_pdet_specs(forest, specs)
        sharded_forest = DEForest(
            n=forest.n, leaf_size=forest.leaf_size,
            **{k: put(getattr(forest, k), getattr(fspecs, k))
               for k in ("point_ids", "proj_sorted", "codes_sorted",
                         "valid", "leaf_lo", "leaf_hi", "leaf_valid",
                         "breakpoints")})
        idx = cls(
            params=det.params,
            A=put(det.A, specs["replicated"]),
            forest=sharded_forest,
            data=put(det.data, data_spec),
            plan=FusedPlan(
                points_sorted=put(plan.points_sorted, specs["points_k"]),
                inv_perm=put(plan.inv_perm, specs["replicated"])),
            mesh=mesh, placement=placement,
            spec=spec if spec is not None else det.spec)
        idx._r_min_cache.update(det._r_min_cache)
        return idx

    # ------------------------------------------------------------------
    # AnnIndex protocol
    # ------------------------------------------------------------------

    @property
    def n_points(self) -> int:
        return int(self.data.shape[0])

    @property
    def n_shards(self) -> int:
        return self.placement.n_shards

    def r_min_for(self, k: int, queries: jax.Array | None = None) -> float:
        """Cached per-(index, k) starting radius — the same estimator over
        the same rows as ``DETLSH.r_min_for``, so a PDET and its
        single-device twin start every search at the same radius."""
        if k not in self._r_min_cache:
            from repro.core import estimate_r_min
            probes = (queries if queries is not None
                      else self.data[: min(64, self.data.shape[0])])
            self._r_min_cache[k] = estimate_r_min(self.data, probes, k,
                                                  self.params.c)
        return self._r_min_cache[k]

    def search(self, queries: jax.Array, request=None):
        """Typed batched search (``repro.api``).  Resolves through the
        registry with this index's mesh declared active, so ``'auto'``
        routes to the ``pdet`` engine; mode/explicit-engine fallbacks
        (e.g. 'strict' -> vmap) run on the sharded arrays directly."""
        from repro.api import registry
        from repro.api.request import SearchRequest, SearchResult, \
            SearchStats
        req = request or SearchRequest()
        r_min, cached = req.r_min, False
        if r_min is None:
            cached = req.k in self._r_min_cache
            probes = queries[: req.n_active] if req.n_active else queries
            r_min = self.r_min_for(req.k, probes)
        spec = self.spec
        default_engine = spec.engine if spec is not None else "auto"
        cfg = req.to_query_config(
            default_engine=default_engine, r_min=r_min,
            block_q=spec.block_q if spec is not None else 8,
            block_l=spec.block_l if spec is not None else 8,
            default_probe_depth=spec.probe_depth if spec is not None else 0)
        engine = registry.resolve_engine(
            cfg.engine, mode=cfg.mode, batch=queries.shape[0],
            mesh_devices=self.placement.n_devices)
        if engine == "pdet" and cfg.probe_depth > 0 and \
                (req.engine or default_engine) != "pdet":
            # Multi-probe is not expressible per-shard (see
            # pdet_query_batch); 'auto' falls back to the fused engine on
            # the sharded arrays.  An *explicit* engine='pdet' with
            # probe_depth > 0 falls through and raises there.
            engine = "fused"
        shard_cands = psum_rounds = merge_size = None
        if engine == "pdet":
            res, shard_cands = pdet_query_batch(
                self.forest, self.A, self.params, queries, cfg, self.plan,
                self.mesh, self.placement.data_axes, n_active=req.n_active)
            psum_rounds = jnp.max(res.rounds)
            merge_size = queries.shape[0] * self.forest.n
        else:
            # Mode / explicit-engine fallback: the single-device engines
            # run on the sharded arrays (XLA inserts the collectives).
            cfg = dataclasses.replace(cfg, engine=engine)
            plan = self.plan if engine == "fused" else None
            res = knn_query_batch(self.data, self.forest, self.A,
                                  self.params, queries, cfg, plan=plan,
                                  n_active=req.n_active)
        return SearchResult(
            ids=res.ids, dists=res.dists,
            stats=SearchStats(engine=engine, r_min=float(r_min),
                              r_min_cached=cached, rounds=res.rounds,
                              n_candidates=res.n_candidates,
                              final_r=res.final_r,
                              shard_candidates=shard_cands,
                              psum_rounds=psum_rounds,
                              merge_size=merge_size,
                              probed_leaves=res.probed_leaves,
                              probe_candidates=res.probe_candidates),
            raw=res)

    def save(self, path) -> None:
        """Write a sharded snapshot directory: per-shard npz + shard map
        in MANIFEST.json (``repro.api.load`` reshards on load)."""
        from repro.api import persist
        persist.save_pdet(self, path)

    def index_size_bytes(self) -> int:
        return self.forest.size_bytes() + self.A.size * 4


def _layout_mesh_axes(arr):
    """Recover (mesh, data_axes) from a PDET-sharded array's placement —
    the engine-registry entry point has only the uniform engine signature,
    so the mesh travels with the arrays themselves."""
    sharding = getattr(arr, "sharding", None)
    mesh = getattr(sharding, "mesh", None)
    spec = getattr(sharding, "spec", None)
    if mesh is None or spec is None or len(spec) < 2 or spec[1] is None:
        raise ValueError(
            "engine 'pdet' needs a mesh-sharded index layout (build via "
            "repro.api.build with an IndexSpec placement); the fused-plan "
            "arrays of this index are not sharded")
    axes = spec[1]
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    return mesh, axes


def _run_pdet_engine(data, forest, A, params, queries, cfg, *,
                     plan=None, live=None, live_sorted=None,
                     n_active=None) -> QueryResult:
    """Registry entry point for engine='pdet'."""
    del data
    if live is not None or live_sorted is not None:
        raise NotImplementedError(
            "engine 'pdet' serves the static sharded index; tombstones "
            "(live masks) belong to the streaming index's engines")
    if plan is None:
        raise ValueError("engine 'pdet' needs the index's sharded "
                         "FusedPlan (plan=)")
    mesh, axes = _layout_mesh_axes(plan.points_sorted)
    res, _ = pdet_query_batch(forest, A, params, queries, cfg, plan,
                              mesh, axes, n_active=n_active)
    return res


engine_registry.register_engine(
    "pdet", _run_pdet_engine, modes=("leaf",), min_batch=1, priority=20,
    needs_mesh=True,
    doc="shard_map'd fused round over the mesh-sharded global layout "
        "(Alg. 8); exact pmin merge => bit-identical to 'fused' on one "
        "device for any shard count")
