"""PDET-LSH: the multi-pod distributed runtime (paper §IV, Alg. 6/7/8).

CPU-thread parallelism -> TPU SPMD mapping (DESIGN.md §2):

  * Alg. 6 (parallel dynamic encoding, dimension-partitioned): breakpoint
    selection runs as *distributed histogram refinement* — per-shard
    histograms are ``psum``-reduced so every device derives the identical,
    globally equi-depth breakpoints.  log2(N_r) rounds of small (D, N_r)
    collectives replace the paper's per-worker QuickSelect.
  * Alg. 7 (parallel index construction, data-partitioned): each device
    builds a complete DE-Forest over its own shard of the dataset.  No
    synchronization at all (the paper needs a barrier + subtree hand-off).
  * Alg. 8 + §IV-C (parallel query): queries are replicated; every device
    range-queries its local forest and reranks its local candidates
    (rerank gathers are shard-local — the dataset is sharded *with* the
    index).  Termination conditions T1/T2 of Alg. 5 are evaluated on
    ``psum``-ed global counts, so all devices advance the radius in
    lockstep and the termination logic — hence Theorem 3 — is preserved.
    The final top-k is an ``all_gather`` of per-shard top-k + a merge.

Determinism/equivalence: ``serial_reference_*`` run the identical sharded
algorithm as plain vmapped code on one device; tests assert the shard_map
version returns exactly the same ids/distances (the PDET == DET claim,
Fig. 20/21).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.compat import shard_map

from repro.core import encoding as enc
from repro.core import hashing
from repro.core.detree import DEForest, build_tree
from repro.core.query import QueryConfig, _merge_candidates
from repro.core.theory import LSHParams


# ---------------------------------------------------------------------------
# Distributed breakpoint selection (Alg. 6 analogue)
# ---------------------------------------------------------------------------

def distributed_breakpoints(proj_local: jax.Array, n_global: int,
                            Nr: int, rounds: int,
                            axes: Sequence[str] | None) -> jax.Array:
    """Globally equi-depth breakpoints over data sharded on ``axes``.

    proj_local: (n_local, D).  Inside shard_map, ``axes`` are the mesh axes
    the data is sharded over; pass None for the serial reference.
    """
    def pmin(x):
        return jax.lax.pmin(x, axes) if axes else x

    def pmax(x):
        return jax.lax.pmax(x, axes) if axes else x

    def psum(x):
        return jax.lax.psum(x, axes) if axes else x

    lo = pmin(jnp.min(proj_local, axis=0))
    hi = pmax(jnp.max(proj_local, axis=0))
    t = jnp.arange(Nr + 1, dtype=jnp.float32) / Nr
    edges = lo[:, None] + (hi - lo)[:, None] * t[None, :]

    def body(_, edges):
        counts = psum(enc.histogram_counts(proj_local, edges))
        return enc.refine_breakpoints_from_counts(edges, counts, n_global)

    return jax.lax.fori_loop(0, rounds, body, edges)


# ---------------------------------------------------------------------------
# Shard-local build (Alg. 7 analogue)
# ---------------------------------------------------------------------------

def _build_local_forest(data_local: jax.Array, A: jax.Array, K: int, L: int,
                        Nr: int, leaf_size: int, bp_rounds: int,
                        n_global: int,
                        axes: Sequence[str] | None) -> DEForest:
    n_local = data_local.shape[0]
    proj = hashing.project(data_local, A)
    bp_all = distributed_breakpoints(proj, n_global, Nr, bp_rounds, axes)
    codes_all = enc.encode(proj, bp_all)
    proj_t = proj.reshape(n_local, L, K).transpose(1, 0, 2)
    codes_t = codes_all.reshape(n_local, L, K).transpose(1, 0, 2)
    bp_t = bp_all.reshape(L, K, Nr + 1)
    parts = jax.vmap(functools.partial(build_tree, leaf_size=leaf_size))(
        proj_t, codes_t, bp_t)
    return DEForest(n=n_local, leaf_size=leaf_size, **parts)


# ---------------------------------------------------------------------------
# Shard-local query with global termination (Alg. 5 + Alg. 8 analogue)
# ---------------------------------------------------------------------------

def _knn_local(data_local: jax.Array, forest: DEForest, A: jax.Array,
               params: LSHParams, q: jax.Array, cfg: QueryConfig,
               n_global: int, shard_offset: jax.Array,
               axes: Sequence[str] | None):
    """One query against the local shard, radius loop in global lockstep.

    Returns per-shard top-k (ids globalized via shard_offset) — caller
    all_gathers and merges.
    """
    from repro.core.query import range_query_round, exact_distances

    def psum(x):
        return jax.lax.psum(x, axes) if axes else x

    n_local = data_local.shape[0]
    K, L = params.K, params.L
    M = min(cfg.M, forest.n_leaves)
    round_cap = L * M * forest.leaf_size
    # Local buffer: the global termination threshold can be met by any
    # distribution of candidates over shards, so each shard must be able to
    # hold everything it could contribute before termination.
    cap = min(int(params.beta * n_global) + cfg.k + round_cap,
              n_local + round_cap)
    thresh = jnp.asarray(params.beta * n_global + cfg.k, jnp.float32)
    q_proj = (q @ A).reshape(L, K)

    def cond(state):
        rnd, r, ids, d, done = state
        return (~done) & (rnd < cfg.max_rounds)

    def body(state):
        rnd, r, ids, d, done = state
        new_ids, ok = range_query_round(forest, q_proj, params.epsilon * r,
                                        cfg.M, mode=cfg.mode)
        new_d = exact_distances(data_local, q, new_ids, ok)
        new_ids = jnp.where(ok, new_ids, n_local)
        ids, d, count_local = _merge_candidates(n_local, ids, d, new_ids,
                                                new_d)
        count = psum(count_local.astype(jnp.float32))            # global |S|
        within_local = jnp.sum(d <= params.c * r).astype(jnp.float32)
        within = psum(within_local)                              # global T2
        done = (count >= thresh) | (within >= cfg.k)
        r = jnp.where(done, r, r * params.c)
        return rnd + 1, r, ids, d, done

    state0 = (jnp.asarray(0, jnp.int32), jnp.asarray(cfg.r_min, jnp.float32),
              jnp.full((cap,), n_local, jnp.int32),
              jnp.full((cap,), jnp.inf), jnp.asarray(False))
    rnd, r, ids, d, done = jax.lax.while_loop(cond, body, state0)

    kk = min(cfg.k, cap)
    negd, sel = jax.lax.top_k(-d, kk)
    local_ids = ids[sel]
    gids = jnp.where(local_ids < n_local, local_ids + shard_offset,
                     n_global).astype(jnp.int32)
    return gids, -negd, rnd


def _merge_global_topk(gids: jax.Array, gdists: jax.Array, k: int,
                       axes: Sequence[str] | None):
    """all_gather per-shard top-k and take the global top-k."""
    if axes:
        gids = jax.lax.all_gather(gids, axes, tiled=True)
        gdists = jax.lax.all_gather(gdists, axes, tiled=True)
    negd, sel = jax.lax.top_k(-gdists, k)
    return gids[sel], -negd


# ---------------------------------------------------------------------------
# Public API: shard_map-based build & query over a mesh
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PDETLSH:
    """A PDET-LSH index sharded over mesh ``axes`` (data-parallel)."""

    params: LSHParams
    A: jax.Array
    forest: DEForest          # arrays sharded on their n/leaf axes
    data: jax.Array           # (n, d) sharded on axis 0
    mesh: Mesh
    axes: tuple[str, ...]
    n_global: int

    def query(self, queries: jax.Array, k: int = 50, *,
              r_min: float | None = None, M: int = 8,
              mode: str = "leaf", max_rounds: int = 48):
        if r_min is None:
            from repro.core import estimate_r_min
            r_min = estimate_r_min(
                jax.device_get(self.data)[: min(2048, self.n_global)],
                queries, k, self.params.c)
        cfg = QueryConfig(k=k, M=M, r_min=r_min, mode=mode,
                          max_rounds=max_rounds)
        return query_pdet(self, queries, cfg)


def _shard_spec(mesh: Mesh, axes: tuple[str, ...]):
    data_p = P(axes)
    forest_p = DEForest(
        point_ids=P(None, axes), proj_sorted=P(None, axes, None),
        codes_sorted=P(None, axes, None), valid=P(None, axes),
        leaf_lo=P(None, axes, None), leaf_hi=P(None, axes, None),
        leaf_valid=P(None, axes), breakpoints=P(),
        n=0, leaf_size=0)
    return data_p, forest_p


def build_pdet(data: jax.Array, key: jax.Array, params: LSHParams,
               mesh: Mesh, axes: tuple[str, ...] = ("data",), *,
               Nr: int = enc.DEFAULT_NR, leaf_size: int = 64,
               bp_rounds: int = 8) -> PDETLSH:
    """Build the distributed index.  ``data`` (n, d); n divisible by the
    product of mesh axis sizes in ``axes`` (pad upstream)."""
    n, d = data.shape
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    assert n % n_shards == 0, (n, n_shards)
    A = hashing.sample_projections(key, d, params.K, params.L)

    data_p, forest_p = _shard_spec(mesh, axes)
    forest_specs = dict(point_ids=P(None, axes),
                        proj_sorted=P(None, axes, None),
                        codes_sorted=P(None, axes, None),
                        valid=P(None, axes),
                        leaf_lo=P(None, axes, None),
                        leaf_hi=P(None, axes, None),
                        leaf_valid=P(None, axes),
                        breakpoints=P())

    def build(data_local, A):
        f = _build_local_forest(data_local, A, params.K, params.L, Nr,
                                leaf_size, bp_rounds, n, axes)
        return dict(point_ids=f.point_ids, proj_sorted=f.proj_sorted,
                    codes_sorted=f.codes_sorted, valid=f.valid,
                    leaf_lo=f.leaf_lo, leaf_hi=f.leaf_hi,
                    leaf_valid=f.leaf_valid, breakpoints=f.breakpoints)

    built = shard_map(
        build, mesh=mesh, in_specs=(data_p, P()),
        out_specs=forest_specs, check_vma=False)(data, A)
    n_local = n // n_shards
    forest = DEForest(n=n_local, leaf_size=leaf_size, **built)
    return PDETLSH(params=params, A=A, forest=forest, data=data, mesh=mesh,
                   axes=tuple(axes), n_global=n)


def query_pdet(index: PDETLSH, queries: jax.Array, cfg: QueryConfig):
    """Batched distributed c^2-k-ANN (queries replicated; Theorem 3 path)."""
    mesh, axes = index.mesh, index.axes
    n_global = index.n_global
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    n_local = n_global // n_shards

    data_p, _ = _shard_spec(mesh, axes)
    forest_specs = DEForest(
        point_ids=P(None, axes), proj_sorted=P(None, axes, None),
        codes_sorted=P(None, axes, None), valid=P(None, axes),
        leaf_lo=P(None, axes, None), leaf_hi=P(None, axes, None),
        leaf_valid=P(None, axes), breakpoints=P(), n=index.forest.n,
        leaf_size=index.forest.leaf_size)

    def run(data_local, forest, A, queries):
        # shard offset from the mesh position along the data axes
        # (row-major over ``axes`` — matches jnp.reshape sharding order)
        idx = jnp.asarray(0, jnp.int32)
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        offset = idx * n_local

        def one(q):
            gids, gd, rnd = _knn_local(data_local, forest, A, index.params,
                                       q, cfg, n_global, offset, axes)
            mids, md = _merge_global_topk(gids, gd, cfg.k, axes)
            return mids, md, rnd

        return jax.vmap(one)(queries)

    in_specs = (data_p, forest_specs, P(), P())
    out_specs = (P(), P(), P())
    gids, gdists, rounds = shard_map(
        run, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False)(index.data, index.forest, index.A, queries)
    return gids, gdists, rounds


# ---------------------------------------------------------------------------
# Serial reference: identical sharded semantics on one device (for tests)
# ---------------------------------------------------------------------------

def serial_reference_build(data: jax.Array, key: jax.Array,
                           params: LSHParams, n_shards: int, *,
                           Nr: int = enc.DEFAULT_NR, leaf_size: int = 64,
                           bp_rounds: int = 8):
    """vmap-over-shards build with summed (\"psum\") histogram counts."""
    n, d = data.shape
    assert n % n_shards == 0
    A = hashing.sample_projections(key, d, params.K, params.L)
    shards = data.reshape(n_shards, n // n_shards, d)
    proj = jax.vmap(lambda x: hashing.project(x, A))(shards)

    # distributed_breakpoints with psum == sum over the shard axis
    lo = jnp.min(proj, axis=(0, 1))
    hi = jnp.max(proj, axis=(0, 1))
    t = jnp.arange(Nr + 1, dtype=jnp.float32) / Nr
    edges = lo[:, None] + (hi - lo)[:, None] * t[None, :]
    for _ in range(bp_rounds):
        counts = sum(enc.histogram_counts(proj[s], edges)
                     for s in range(n_shards))
        edges = enc.refine_breakpoints_from_counts(edges, counts, n)

    K, L = params.K, params.L

    def build_one(proj_local):
        codes = enc.encode(proj_local, edges)
        nl = proj_local.shape[0]
        proj_t = proj_local.reshape(nl, L, K).transpose(1, 0, 2)
        codes_t = codes.reshape(nl, L, K).transpose(1, 0, 2)
        bp_t = edges.reshape(L, K, Nr + 1)
        return jax.vmap(functools.partial(build_tree, leaf_size=leaf_size))(
            proj_t, codes_t, bp_t)

    parts = jax.vmap(build_one)(proj)      # leading shard axis on everything
    return A, parts, edges


def serial_reference_query(data: jax.Array, A: jax.Array, parts: dict,
                           params: LSHParams, queries: jax.Array,
                           cfg: QueryConfig, n_shards: int, leaf_size: int):
    """Runs _knn_local per shard with psum == sum across shards, serially."""
    from repro.core.query import range_query_round, exact_distances

    n, d = data.shape
    n_local = n // n_shards
    shards = data.reshape(n_shards, n_local, d)
    forests = [
        DEForest(n=n_local, leaf_size=leaf_size,
                 **{k: v[s] for k, v in parts.items()})
        for s in range(n_shards)
    ]
    K, L = params.K, params.L
    out_ids, out_d = [], []
    for q in queries:
        q_proj = (q @ A).reshape(L, K)
        M = min(cfg.M, forests[0].n_leaves)
        round_cap = L * M * leaf_size
        cap = min(int(params.beta * n) + cfg.k + round_cap,
                  n_local + round_cap)
        bufs = [(jnp.full((cap,), n_local, jnp.int32),
                 jnp.full((cap,), jnp.inf)) for _ in range(n_shards)]
        r = cfg.r_min
        for _ in range(cfg.max_rounds):
            counts, withins = [], []
            for s in range(n_shards):
                ids_b, d_b = bufs[s]
                new_ids, ok = range_query_round(
                    forests[s], q_proj, params.epsilon * r, cfg.M,
                    mode=cfg.mode)
                new_d = exact_distances(shards[s], q, new_ids, ok)
                new_ids = jnp.where(ok, new_ids, n_local)
                ids_b, d_b, cnt = _merge_candidates(n_local, ids_b, d_b,
                                                    new_ids, new_d)
                bufs[s] = (ids_b, d_b)
                counts.append(float(cnt))
                withins.append(float(jnp.sum(d_b <= params.c * r)))
            if sum(counts) >= params.beta * n + cfg.k or \
                    sum(withins) >= cfg.k:
                break
            r = r * params.c
        # merge per-shard top-k
        all_ids, all_d = [], []
        for s in range(n_shards):
            ids_b, d_b = bufs[s]
            kk = min(cfg.k, cap)
            negd, sel = jax.lax.top_k(-d_b, kk)
            lids = ids_b[sel]
            all_ids.append(jnp.where(lids < n_local, lids + s * n_local, n))
            all_d.append(-negd)
        cat_i = jnp.concatenate(all_ids)
        cat_d = jnp.concatenate(all_d)
        negd, sel = jax.lax.top_k(-cat_d, cfg.k)
        out_ids.append(cat_i[sel])
        out_d.append(-negd)
    return jnp.stack(out_ids), jnp.stack(out_d)
