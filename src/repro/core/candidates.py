"""Incremental candidate-set maintenance for the c^2-k-ANN rounds (Alg. 5).

The seed implementation (``query._merge_candidates``) re-sorted the whole
``cap``-sized buffer every round: an O(cap log cap) argsort + top_k per round
*per query*, with cap = beta*n + k + round_cap.  This module replaces it with
an incremental scheme whose per-round cost scales with the *round's*
candidate count m, not the buffer:

  * a packed-uint32 **seen-bitmap** (one bit per dataset point) answers
    "was this id already counted in S?" with one gather + bit test — O(m);
  * the round batch is deduped in-round with one m-sized stable sort and
    compacted with a cumsum — O(m log m);
  * surviving (first-seen) candidates are **appended at a cursor** into the
    fixed-size buffer with a bounded scatter — O(m).  No eviction is ever
    needed: Alg. 5 terminates as soon as the unique count reaches
    beta*n + k, and every round adds at most ``round_cap`` candidates, so
    with cap >= beta*n + k + round_cap the cursor can never pass ``cap``
    (see docs/DESIGN.md §2) — which is exactly the capacity the seed path
    already allocated.

The cursor *is* the unique count |S| (the quantity Theorems 1-3 see), so the
Alg. 5 line-7 termination test is a scalar compare.  The buffer is no longer
kept distance-sorted between rounds — nothing in the round loop needs order:
the T2 test is a masked reduction and the final top-k selection happens once
per query, not once per round.

Equivalence with the seed merge (same kept ids/distances/unique count, after
canonical (distance, id) ordering) holds whenever (a) the capacity invariant
above is respected and (b) duplicate ids carry equal distances — both true
by construction in the query engine, where a candidate's distance is its
deterministic exact distance.  Property-tested in
``tests/test_merge_properties.py``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class CandidateState(NamedTuple):
    """Per-query Alg. 5 candidate set S in incremental form."""

    ids: jax.Array      # (cap,) int32 — appended unique ids; n = empty slot
    dists: jax.Array    # (cap,) f32  — exact distances; +inf in empty slots
    seen: jax.Array     # (ceil(n/32),) uint32 — membership bitmap over ids
    count: jax.Array    # () int32 — cursor == |S| (unique candidates)


def bitmap_words(n: int) -> int:
    return (n + 31) // 32


def init_state(n: int, cap: int) -> CandidateState:
    return CandidateState(
        ids=jnp.full((cap,), n, jnp.int32),
        dists=jnp.full((cap,), jnp.inf, jnp.float32),
        seen=jnp.zeros((bitmap_words(n),), jnp.uint32),
        count=jnp.asarray(0, jnp.int32),
    )


def bitmap_test(seen: jax.Array, ids: jax.Array, n: int) -> jax.Array:
    """True where ``ids`` (int32, may contain the sentinel n) is already set."""
    safe = jnp.clip(ids, 0, n - 1)
    word = seen[safe >> 5]
    bit = (safe & 31).astype(jnp.uint32)
    return ((word >> bit) & 1).astype(jnp.bool_)


def merge_round(n: int, state: CandidateState, new_ids: jax.Array,
                new_d: jax.Array) -> CandidateState:
    """Fold one round's candidates into S.  new_ids/new_d: (m,), id n = invalid.

    Cost: one stable m-sort + O(m) scatters.  Requires the capacity invariant
    in the module docstring; overflowing appends are dropped (mode='drop'),
    which the invariant proves unreachable before termination.
    """
    cap = state.ids.shape[0]
    m = new_ids.shape[0]

    fresh = (new_ids < n) & ~bitmap_test(state.seen, new_ids, n)
    # In-round dedup: stable sort by (masked) id puts duplicates adjacent and
    # invalid entries last; keep first occurrences only.
    ids_m = jnp.where(fresh, new_ids, n)
    order = jnp.argsort(ids_m, stable=True)
    ids_s = ids_m[order]
    d_s = jnp.where(fresh, new_d, jnp.inf)[order]
    first = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), ids_s[1:] != ids_s[:-1]])
    keep = first & (ids_s < n)

    # Append kept entries at the cursor (cumsum assigns dense slots).
    pos = state.count + jnp.cumsum(keep.astype(jnp.int32)) - 1
    pos = jnp.where(keep, pos, cap)                      # 'drop' sentinel
    ids_out = state.ids.at[pos].set(ids_s, mode="drop")
    d_out = state.dists.at[pos].set(d_s, mode="drop")

    # Set bitmap bits.  Kept ids are unique, so bits within a shared word
    # never collide and scatter-add equals scatter-or.
    safe = jnp.clip(ids_s, 0, n - 1)
    word_idx = jnp.where(keep, safe >> 5, state.seen.shape[0])
    bits = jnp.left_shift(jnp.uint32(1), (safe & 31).astype(jnp.uint32))
    seen_out = state.seen.at[word_idx].add(
        jnp.where(keep, bits, jnp.uint32(0)), mode="drop")

    count_out = state.count + jnp.sum(keep).astype(jnp.int32)
    return CandidateState(ids=ids_out, dists=d_out, seen=seen_out,
                          count=count_out)


def canonicalize(n: int, ids: jax.Array, dists: jax.Array
                 ) -> tuple[jax.Array, jax.Array]:
    """Sort a buffer ascending by (distance, id) — the seed merge's output
    order (its top_k tie-broke equal distances by position in id-sorted
    order).  Used for the final extraction and the equivalence tests."""
    d_s, ids_s = jax.lax.sort((dists, ids), num_keys=2, is_stable=True)
    return ids_s, d_s
