"""Seed DET-LSH decode attention — now the *oracle* for ``repro.decode``.

This was the first cut of LSH-accelerated decode: per-(batch, kv-head)
DE-Forests built with the per-tree ``build_tree`` path and a per-head
leaf-LB scan (``retrieve_topm``).  The production implementation lives in
``repro.decode`` (docs/DESIGN.md §10): ``KVCacheIndex.prefill`` builds
through the fused single-sort pipeline, each decode step is a streaming
upsert + one batched fused ``range_rerank`` query, and the MIPS -> L2
augmentation lives in ``repro.decode.mips`` (re-exported here).

What remains here:
  * ``build_kv_index`` / ``det_decode_attention`` — deprecation shims that
    still run the seed path, because it is the bit-level oracle
    (tests/test_decode.py checks the fused engine admits the same
    candidate sets over identical forests);
  * ``retrieve_topm`` — the seed per-head scan, oracle-only.

Do not add new callers: outside oracle tests nothing in-tree may call the
per-head scan path (ISSUE 7 acceptance criterion).
"""

from __future__ import annotations

import functools
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import encoding as enc
from repro.core import hashing
from repro.core.detree import build_tree, leaf_bounds
from repro.core.theory import LSHParams, derive_params


class DETKVIndex(NamedTuple):
    A: jax.Array            # (dh+1, L*K) projections (augmented dim)
    point_ids: jax.Array    # (b, hk, L, n_pad)
    leaf_lo: jax.Array      # (b, hk, L, n_leaves, K)
    leaf_hi: jax.Array
    leaf_valid: jax.Array   # (b, hk, L, n_leaves)
    breakpoints: jax.Array  # (b, hk, L, K, Nr+1)
    radius: jax.Array       # (b, hk) augmentation R per head
    leaf_size: int
    S: int


def _augment_keys(keys: jax.Array):
    """keys (S, dh) -> (S, dh+1) Shrivastava-Li augmentation + R.

    Thin wrapper over ``repro.decode.mips`` (the maintained reduction);
    kept because the oracle tests pin the seed call shape."""
    from repro.decode import mips
    R2 = mips.mips_radius(keys)
    aug, _ = mips.augment_keys(keys, R2)
    return aug, jnp.sqrt(R2)


def build_kv_index(k_cache: jax.Array, key: jax.Array, *,
                   params: LSHParams | None = None, Nr: int = 64,
                   leaf_size: int = 32) -> DETKVIndex:
    """Index cache keys.  k_cache (b, S, hk, dh) -> per-(b,hk) DE-Forests.

    Deprecated oracle path; layout knobs (Nr, leaf_size, and the derived
    K/L/c) route through the same eager validation ``IndexSpec`` runs, so
    a bad Nr or non-positive leaf_size fails here exactly as it would in
    ``repro.decode.KVSpec``.
    """
    warnings.warn("core.det_attention.build_kv_index is deprecated. use "
                  "repro.decode.KVCacheIndex.prefill (docs/DESIGN.md §10)",
                  DeprecationWarning, stacklevel=2)
    b, S, hk, dh = k_cache.shape
    params = params or derive_params(K=4, c=1.5, L=4, beta_override=0.1)
    from repro.decode.kv_index import KVSpec
    KVSpec(K=params.K, L=params.L, c=params.c, Nr=Nr, leaf_size=leaf_size)
    K, L = params.K, params.L
    A = hashing.sample_projections(key, dh + 1, K, L)

    def one(keys):                                   # (S, dh)
        aug, R = _augment_keys(keys)
        proj = aug @ A                               # (S, L*K)
        bp = enc.select_breakpoints(proj, Nr, method="full_sort")
        codes = enc.encode(proj, bp)
        proj_t = proj.reshape(S, L, K).transpose(1, 0, 2)
        codes_t = codes.reshape(S, L, K).transpose(1, 0, 2)
        bp_t = bp.reshape(L, K, Nr + 1)
        parts = jax.vmap(functools.partial(build_tree, leaf_size=leaf_size))(
            proj_t, codes_t, bp_t)
        return (parts["point_ids"], parts["leaf_lo"], parts["leaf_hi"],
                parts["leaf_valid"], parts["breakpoints"], R)

    flat = k_cache.transpose(0, 2, 1, 3)             # (b, hk, S, dh)
    pid, lo, hi, lv, bp, R = jax.vmap(jax.vmap(one))(flat)
    return DETKVIndex(A=A, point_ids=pid, leaf_lo=lo, leaf_hi=hi,
                      leaf_valid=lv, breakpoints=bp, radius=R,
                      leaf_size=leaf_size, S=S)


def retrieve_topm(index: DETKVIndex, q: jax.Array, m_leaves: int):
    """q (b, hk, g, dh) -> candidate position ids (b, hk, g, m_leaves*ls).

    Ranks leaves by LB distance of the augmented query in each tree and
    takes the best m_leaves/L per tree (the paper's optimized leaf-granularity
    admission, ordered by LB)."""
    b, hk, g, dh = q.shape
    L = index.point_ids.shape[2]
    per_tree = max(1, m_leaves // L)

    def one(qv, pid, lo, hi, lv, bp):
        qa = jnp.concatenate([qv.astype(jnp.float32), jnp.zeros((1,))])
        qp = (qa @ index.A).reshape(L, -1)           # (L, K)

        def tree(pid_l, lo_l, hi_l, lv_l, bp_l, qp_l):
            lb, _ = leaf_bounds(qp_l, lo_l, hi_l, lv_l, bp_l)
            _, leaf_idx = jax.lax.top_k(-lb, per_tree)
            gidx = (leaf_idx[:, None] * index.leaf_size
                    + jnp.arange(index.leaf_size)[None, :]).reshape(-1)
            return pid_l[gidx]

        ids = jax.vmap(tree)(pid, lo, hi, lv, bp, qp)     # (L, per*ls)
        return ids.reshape(-1)

    # vmap over (b, hk, g): forests indexed by (b, hk); g shares the forest
    def per_head(qh, pid, lo, hi, lv, bp):
        return jax.vmap(lambda qv: one(qv, pid, lo, hi, lv, bp))(qh)

    return jax.vmap(jax.vmap(per_head))(
        q, index.point_ids, index.leaf_lo, index.leaf_hi,
        index.leaf_valid, index.breakpoints)


def det_decode_attention(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, index: DETKVIndex,
                         length, *, m_leaves: int = 16,
                         window: int = 64, sinks: int = 4) -> jax.Array:
    """Sparse decode attention over DET-LSH-retrieved positions.

    q (b, 1, h, dh); caches (b, S, hk, dh).  Exact softmax over the union of
    {retrieved candidates} + {last ``window`` positions} + {first ``sinks``}.
    """
    warnings.warn("core.det_attention.det_decode_attention is deprecated. "
                  "use repro.decode.LSHDecoder / sparse_decode_attention "
                  "(docs/DESIGN.md §10)", DeprecationWarning, stacklevel=2)
    b, _, h, dh = q.shape
    S, hk = k_cache.shape[1], k_cache.shape[2]
    g = h // hk
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    qh = q.reshape(b, hk, g, dh)

    cand = retrieve_topm(index, qh, m_leaves)        # (b, hk, g, mc)
    loc = length - 1 - jnp.arange(window)            # local window
    snk = jnp.arange(sinks)
    fixed = jnp.concatenate([loc, snk])
    fixed = jnp.broadcast_to(fixed, (b, hk, g, fixed.shape[0]))
    ids = jnp.concatenate([cand, fixed], axis=-1)
    ids = jnp.clip(ids, 0, S - 1)

    def head(qv, kc, vc, idv):                       # (g,dh),(S,dh),(S,dh)
        kg = kc[idv.reshape(-1)].reshape(*idv.shape, dh)   # (g, m, dh)
        vg = vc[idv.reshape(-1)].reshape(*idv.shape, dh)
        s = jnp.einsum("gd,gmd->gm", qv.astype(jnp.float32) * scale,
                       kg.astype(jnp.float32))
        valid = idv < length
        # positions may repeat across sources; mask repeats per row
        def mask_dups(row_ids, row_valid):
            order = jnp.argsort(row_ids, stable=True)
            rs = row_ids[order]
            first = jnp.concatenate([jnp.array([True]), rs[1:] != rs[:-1]])
            keep = jnp.zeros_like(row_valid).at[order].set(first)
            return row_valid & keep
        valid = jax.vmap(mask_dups)(idv, valid)
        s = jnp.where(valid, s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("gm,gmd->gd", p, vg.astype(jnp.float32))

    out = jax.vmap(jax.vmap(head))(
        qh, k_cache.transpose(0, 2, 1, 3), v_cache.transpose(0, 2, 1, 3),
        ids)                                          # (b, hk, g, dh)
    return out.reshape(b, 1, h, dh).astype(q.dtype)
