"""Dynamic encoding (paper §III-A, Alg. 1 + Fig. 2).

Selects per-dimension, data-driven (equi-depth) breakpoints for each of the
K*L projected dimensions and encodes projected coordinates into iSAX symbols
(region ids in [0, N_r), N_r = 256 by default, i.e. an 8-bit alphabet).

Two breakpoint-selection strategies (both avoid a full sort of all n points,
mirroring the paper's QuickSelect + divide-and-conquer design):

  * ``sample_sort``     — sort a random sample (n_s = 0.1 n in the paper) per
                          dimension and read off the N_r+1 order statistics.
                          Sorting is a TPU hardware primitive (bitonic on the
                          VPU), so this is the hardware-appropriate analogue
                          of "select order statistics cheaply".
  * ``histogram_refine``— log-round histogram refinement: every round bins
                          the data by the current breakpoint estimates and
                          re-interpolates all N_r-1 quantiles at once.  This
                          is the direct TPU translation of the paper's
                          divide-and-conquer QuickSelect rounds (Fig. 2): the
                          z-th round refines every bracket simultaneously.
                          Histogram counts are psum-reducible, which is what
                          the distributed (multi-pod) build uses to obtain
                          *global* breakpoints over sharded data.

Encoding itself is a binary search of each coordinate into its dimension's
breakpoints (Alg. 1 lines 5-8) — vectorized here, and available as a Pallas
kernel (``repro.kernels.encode_bins``) for the TPU hot path.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_NR = 256


def _sort_columns(sample: jax.Array) -> jax.Array:
    """Column-wise sort for order statistics, (m, D) -> (m, D).

    XLA's CPU sort is a generic comparator sort, ~10x slower than numpy's
    introsort on the selection sample; the sorted values are identical
    either way (sorting is exact), so outside a trace on the CPU backend
    the sort runs on the host.  Inside a trace (the jitted figure
    benchmarks, shard_map) or on accelerators (hardware-bitonic sort
    beats a host round-trip) it stays ``jnp.sort``.
    """
    if (not isinstance(sample, jax.core.Tracer)
            and jax.default_backend() == "cpu"):
        # jaxlint: disable=unstable-sort -- values-only order statistics:
        #   the permutation is never observed (only the sorted sample feeds
        #   breakpoint selection), and kind='stable' would forfeit the
        #   introsort speedup that justifies this host fast path.
        return jnp.asarray(np.sort(np.asarray(sample), axis=0))
    return jnp.sort(sample, axis=0, stable=True)


# ---------------------------------------------------------------------------
# Breakpoint selection
# ---------------------------------------------------------------------------

def _order_statistic_breakpoints(coords_sorted: jax.Array, Nr: int) -> jax.Array:
    """Equi-depth breakpoints from per-dimension sorted coords (m, D)->(D, Nr+1).

    B(1)=min, B(Nr+1)=max, B(z)=C_sorted[floor(m/Nr)*(z-1)], z=2..Nr
    (paper §III-A, 0-based here).
    """
    m, D = coords_sorted.shape
    step = m // Nr
    idx = jnp.clip(jnp.arange(1, Nr) * step, 0, m - 1)            # (Nr-1,)
    inner = coords_sorted[idx, :]                                  # (Nr-1, D)
    lo = coords_sorted[0:1, :]
    hi = coords_sorted[m - 1:m, :]
    return jnp.concatenate([lo, inner, hi], axis=0).T              # (D, Nr+1)


def breakpoints_sample_sort(coords: jax.Array, Nr: int = DEFAULT_NR, *,
                            key: jax.Array | None = None,
                            sample_fraction: float = 0.1,
                            min_sample: int = 4096) -> jax.Array:
    """Breakpoints via sorting a sample.  coords: (n, D) -> (D, Nr+1).

    Determinism contract: with ``key=None`` the sample is the first ``n_s``
    rows of the fixed-stride subsequence ``coords[::max(1, n//n_s)]`` —
    exactly (n_s, D), deterministic for a given input, and unbiased for
    *any* row order (a prefix slice, the previous behavior, is a biased
    sample when rows arrive sorted or clustered: quantiles of the first 10%
    are not quantiles of the data).  Pass ``key`` for an i.i.d. random
    sample of the same shape.
    """
    n, D = coords.shape
    n_s = min(n, max(min_sample, int(n * sample_fraction)))
    if key is not None and n_s < n:
        sel = jax.random.choice(key, n, (n_s,), replace=False)
        sample = coords[sel, :]
    else:
        stride = max(1, n // n_s)                 # floor: >= n_s rows remain
        sample = coords[::stride][:n_s, :]
    sample_sorted = _sort_columns(sample)
    bp = _order_statistic_breakpoints(sample_sorted, Nr)
    # True min/max must come from the full data so every point is coverable.
    bp = bp.at[:, 0].set(jnp.min(coords, axis=0))
    bp = bp.at[:, Nr].set(jnp.max(coords, axis=0))
    return _enforce_monotone(bp)


def _enforce_monotone(bp: jax.Array) -> jax.Array:
    """Make each row non-decreasing (guards against degenerate duplicates)."""
    return jax.lax.cummax(bp, axis=1)


def _searchsorted_rows(edges: jax.Array, x: jax.Array) -> jax.Array:
    """Row-wise searchsorted: edges (D, E), x (n, D) -> bin ids (n, D)."""
    def one(e, col):
        return jnp.searchsorted(e, col, side="right")
    return jax.vmap(one, in_axes=(0, 1), out_axes=1)(edges, x)


def histogram_counts(coords: jax.Array, edges: jax.Array) -> jax.Array:
    """Per-dimension histogram over ``edges``: (n, D), (D, Nr+1) -> (D, Nr).

    Bin b counts points with edges[d, b] <= x < edges[d, b+1] (last bin
    right-closed).  This is the psum-reducible quantity for the distributed
    (multi-pod) breakpoint build.
    """
    D, E = edges.shape
    Nr = E - 1
    bins = _searchsorted_rows(edges[:, 1:Nr], coords)              # (n, D) in [0, Nr]
    bins = jnp.clip(bins, 0, Nr - 1)
    # scatter-add (vmapped bincount): O(n*D) memory — a one-hot formulation
    # materializes (n, D, Nr) and dominated the distributed build's memory
    return jax.vmap(lambda b: jnp.bincount(b, length=Nr), in_axes=1)(
        bins).astype(jnp.int32)                                    # (D, Nr)


def refine_breakpoints_from_counts(edges: jax.Array, counts: jax.Array,
                                   n_total: jax.Array | int) -> jax.Array:
    """One refinement round: re-interpolate all Nr-1 quantiles from counts.

    edges: (D, Nr+1) current estimates; counts: (D, Nr) histogram over edges.
    Returns updated (D, Nr+1) edges (min/max endpoints preserved).
    """
    D, Nr = counts.shape
    cum = jnp.concatenate(
        [jnp.zeros((D, 1), jnp.float32), jnp.cumsum(counts, axis=1, dtype=jnp.float32)],
        axis=1)                                                    # (D, Nr+1)
    targets = (jnp.arange(1, Nr, dtype=jnp.float32) / Nr) * jnp.asarray(
        n_total, jnp.float32)                                      # (Nr-1,)

    def per_dim(cum_d, edges_d):
        # bin containing each target: largest b with cum[b] <= t
        b = jnp.clip(jnp.searchsorted(cum_d, targets, side="right") - 1, 0, Nr - 1)
        c0 = cum_d[b]
        c1 = cum_d[b + 1]
        w = (targets - c0) / jnp.maximum(c1 - c0, 1e-9)
        w = jnp.clip(w, 0.0, 1.0)
        e = edges_d[b] + w * (edges_d[b + 1] - edges_d[b])
        return e

    inner = jax.vmap(per_dim)(cum, edges)                          # (D, Nr-1)
    out = jnp.concatenate([edges[:, :1], inner, edges[:, -1:]], axis=1)
    return _enforce_monotone(out)


def breakpoints_histogram_refine(coords: jax.Array, Nr: int = DEFAULT_NR, *,
                                 rounds: int = 8) -> jax.Array:
    """Breakpoints via iterative histogram refinement.  (n, D) -> (D, Nr+1).

    log2(Nr) = 8 rounds mirrors the paper's divide-and-conquer depth; each
    round narrows every quantile bracket by ~the local bin resolution, so 8
    rounds give equi-depth buckets accurate to O(n / Nr^2).
    """
    n, D = coords.shape
    lo = jnp.min(coords, axis=0)
    hi = jnp.max(coords, axis=0)
    t = jnp.arange(Nr + 1, dtype=jnp.float32) / Nr
    edges = lo[:, None] + (hi - lo)[:, None] * t[None, :]          # uniform init

    def body(_, edges):
        counts = histogram_counts(coords, edges)
        return refine_breakpoints_from_counts(edges, counts, n)

    return jax.lax.fori_loop(0, rounds, body, edges)


def select_breakpoints(coords: jax.Array, Nr: int = DEFAULT_NR, *,
                       method: str = "sample_sort",
                       key: jax.Array | None = None,
                       sample_fraction: float = 0.1,
                       rounds: int = 8) -> jax.Array:
    """Dispatch: (n, D) projected coords -> (D, Nr+1) breakpoints."""
    if method == "sample_sort":
        return breakpoints_sample_sort(coords, Nr, key=key,
                                       sample_fraction=sample_fraction)
    if method == "full_sort":  # the paper's strawman (used as benchmark ref)
        return _enforce_monotone(
            _order_statistic_breakpoints(_sort_columns(coords), Nr))
    if method == "histogram_refine":
        return breakpoints_histogram_refine(coords, Nr, rounds=rounds)
    raise ValueError(f"unknown breakpoint method: {method}")


# ---------------------------------------------------------------------------
# iSAX encoding (Alg. 1 lines 5-8)
# ---------------------------------------------------------------------------

def encode(coords: jax.Array, breakpoints: jax.Array, *,
           impl: str = "auto") -> jax.Array:
    """Encode coords (n, D) with breakpoints (D, Nr+1) -> region ids (n, D).

    Region b satisfies B[d, b] <= x <= B[d, b+1] (int32 in [0, Nr-1]).
    """
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops
        return kops.encode_bins(coords, breakpoints,
                                interpret=(impl == "pallas_interpret"))
    D, E = breakpoints.shape
    Nr = E - 1
    bins = _searchsorted_rows(breakpoints[:, 1:Nr], coords)
    return jnp.clip(bins, 0, Nr - 1).astype(jnp.int32)
