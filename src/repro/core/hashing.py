"""p-stable LSH projections (paper §II-B, Eq. 1).

h(o) = a . o with a ~ N(0, I_d).  DET-LSH uses K*L such functions, giving L
independent K-dimensional projected spaces:  H_i(o) in R^K, i = 1..L.

The projection is a tall-skinny matmul — the hashing hot spot.  The Pallas
kernel lives in ``repro.kernels.lsh_project``; this module provides the
weight sampling and the jnp fallback used on CPU / in dry-runs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_projections(key: jax.Array, d: int, K: int, L: int,
                       dtype=jnp.float32) -> jax.Array:
    """Sample the (d, L*K) projection matrix A with i.i.d. N(0,1) entries."""
    return jax.random.normal(key, (d, L * K), dtype=dtype)


def project(data: jax.Array, A: jax.Array, *, impl: str = "auto") -> jax.Array:
    """Project ``data`` (n, d) -> (n, L*K) with the p-stable family.

    impl: 'auto' | 'xla' | 'pallas' | 'pallas_interpret'.
    """
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops
        return kops.lsh_project(data, A,
                                interpret=(impl == "pallas_interpret"))
    # XLA path (used by dry-run lowering and CPU execution).
    return jnp.dot(data, A, preferred_element_type=jnp.float32)


def project_query(q: jax.Array, A: jax.Array) -> jax.Array:
    """Project one query or a batch of queries: (..., d) -> (..., L*K)."""
    return jnp.dot(q, A, preferred_element_type=jnp.float32)
