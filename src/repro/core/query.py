"""DET-LSH query phase (paper §III-C: Alg. 3, 4, 5).

The c^2-k-ANN query issues (r,c)-ANN rounds with radii r, c*r, c^2*r, ...
Each round performs a range query with projected radius eps*r in all L
DE-Trees, accumulates unique candidates into S, computes their *exact*
original-space distances, and terminates when

    (T1)  |S| >= beta*n + k                                   (Alg. 5 line 7)
    (T2)  at least k candidates satisfy ||o, q|| <= c * r     (Alg. 5 line 9)

returning the top-k of S by exact distance.  Both conditions — and the use of
*unique* candidate counts — match the paper exactly, so Theorems 1-3 apply.

TPU adaptation of the range query (Alg. 3 + the §VI-B2 optimizations):
  * leaf LB distances are computed vectorized over all leaf summaries;
  * the paper's "priority queue of leaves ordered by LB" becomes
    ``lax.top_k(-LB, M)``;
  * the paper's optimization #1 ("add all points of a leaf whenever its LB
    does not exceed r") is the default admission rule (``mode='leaf'``);
    ``mode='strict'`` reproduces the unoptimized Alg. 3 (filter by exact
    projected distance), used by the Fig. 8 benchmark.

The round structure checks termination after each round of L trees rather
than after every tree; this can only make S larger at return time, which
preserves the guarantee (see docs/DESIGN.md §2).

Two query engines (docs/DESIGN.md §3):

  * ``engine='fused'`` (default for batches in ``mode='leaf'``) — the whole
    batch advances through radius rounds together.  Each round is ONE fused
    ``range_rerank`` kernel pass (leaf LB + radius admission + candidate
    gather + exact rerank, tiled query-block x leaf-block over all L trees),
    and the candidate set is maintained as a per-query dense
    best-exact-distance table, so merging a round costs one gather + min —
    no per-round sort.  Done lanes carry a -1 radius and admit nothing
    (active-lane masking).  Admission is leaf-granular without the top-M
    cut: a superset of the vmap engine's candidates, so Theorems 1-3 still
    apply.
  * ``engine='vmap'`` — the seed per-query ``while_loop``, vmapped.  Kept
    for ``mode='strict'``, single queries, and as the benchmark baseline.
    Its per-round candidate merge is the incremental bitmap+cursor scheme
    of ``core.candidates`` (the seed's O(cap log cap) argsort-per-round,
    ``_merge_candidates``, is retained below as the semantics-of-record
    oracle for the property tests).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.api import registry as engine_registry
from repro.core import candidates as cand
from repro.core.detree import DEForest, leaf_bounds
from repro.core.theory import LSHParams


class QueryResult(NamedTuple):
    ids: jax.Array        # (k,) int32 — candidate point indices (n = invalid)
    dists: jax.Array      # (k,) f32   — exact original-space distances
    rounds: jax.Array     # ()  int32  — number of radius enlargements + 1
    n_candidates: jax.Array  # () int32 — |S| (unique) at termination
    final_r: jax.Array    # ()  f32
    # Multi-probe counters (appended, defaulted: paths that never probe —
    # rc_ann, the legacy distributed query — leave them None).
    probed_leaves: Optional[jax.Array] = None    # () int32 — near-miss leaves
    probe_candidates: Optional[jax.Array] = None  # () int32 — their candidates


# ---------------------------------------------------------------------------
# Range query over the forest (one round, all L trees)
# ---------------------------------------------------------------------------

def range_query_round(forest: DEForest, q_proj: jax.Array, r_proj: jax.Array,
                      M: int, *, mode: str = "leaf",
                      bounds_impl: str = "auto",
                      live: Optional[jax.Array] = None,
                      probe_depth: int = 0, with_stats: bool = False):
    """Range query with projected radius ``r_proj`` in all L trees.

    q_proj: (L, K) projected query.  ``live`` is an optional (n,) bool
    tombstone mask in point-id order (None = all live); dead points are
    rejected at admission, before the exact rerank.

    ``probe_depth > 0`` additionally admits, per tree, the probe_depth
    near-miss leaves — the smallest-LB valid leaves with LB *above* the
    radius, within the same top-M LB cut the engine already takes (the
    multi-probe sequence; docs/DESIGN.md §11).  With probe_depth=0 the
    admitted set is exactly the pre-probe rule.

    Returns (ids, ok): ids (L*M*leaf_size,) int32 candidate point ids, ok
    bool mask.  With ``with_stats=True`` also returns scalar int32 counters
    (probed_leaves, probe_candidates) summed over trees.
    """
    leaf_size = forest.leaf_size
    M = min(M, forest.n_leaves)

    def per_tree(pids, proj_s, lo, hi, lvalid, bp, qp):
        lb, _ = leaf_bounds(qp, lo, hi, lvalid, bp, impl=bounds_impl)
        neg, leaf_idx = jax.lax.top_k(-lb, M)                 # best-M by LB
        lb_m = -neg                                           # ascending LB
        leaf_ok = lb_m <= r_proj                              # LB <= eps*r
        if probe_depth > 0:
            outside = (~leaf_ok) & jnp.isfinite(lb_m)
            rank = jnp.cumsum(outside.astype(jnp.int32))      # slack order
            probe_ok = outside & (rank <= probe_depth)
            admit = leaf_ok | probe_ok
        else:
            probe_ok = jnp.zeros_like(leaf_ok)
            admit = leaf_ok
        gidx = leaf_idx[:, None] * leaf_size + jnp.arange(leaf_size)[None, :]
        gidx = gidx.reshape(-1)                               # (M*leaf_size,)
        ids = pids[gidx]
        ok = jnp.repeat(admit, leaf_size) & (ids < forest.n)
        if live is not None:
            ok = ok & live[jnp.clip(ids, 0, forest.n - 1)]
        if mode == "strict":
            pts = proj_s[gidx]                                # (M*ls, K)
            d = jnp.sqrt(jnp.sum((pts - qp[None, :]) ** 2, axis=1))
            ok = ok & (d <= r_proj)
        probed = probe_ok.sum().astype(jnp.int32)
        pcand = (ok & jnp.repeat(probe_ok, leaf_size)).sum().astype(jnp.int32)
        return ids, ok, probed, pcand

    ids, ok, probed, pcand = jax.vmap(per_tree)(
        forest.point_ids, forest.proj_sorted, forest.leaf_lo, forest.leaf_hi,
        forest.leaf_valid, forest.breakpoints, q_proj)
    if with_stats:
        return ids.reshape(-1), ok.reshape(-1), probed.sum(), pcand.sum()
    return ids.reshape(-1), ok.reshape(-1)


# ---------------------------------------------------------------------------
# Candidate set maintenance (unique ids, exact distances)
# ---------------------------------------------------------------------------

def _merge_candidates(n: int, buf_ids: jax.Array, buf_d: jax.Array,
                      new_ids: jax.Array, new_d: jax.Array) -> tuple[
                          jax.Array, jax.Array, jax.Array]:
    """Seed sort-based merge — kept as the semantics-of-record oracle.

    The query engines now use ``core.candidates.merge_round`` (per-round cost
    scales with the round size, not the buffer; see that module).  This
    function re-sorts the whole buffer every call and remains only as the
    reference the incremental scheme is property-tested against, and for the
    distributed (multi-shard) path.

    Merges new candidates into the fixed-size buffer, dedup by id.  Buffer
    keeps the ``cap`` smallest-distance unique candidates; returns
    (ids, dists, unique_count_in_buffer).  Invalid slots carry id = n and
    dist = +inf.  Because the loop terminates as soon as the unique count
    reaches beta*n + k and cap >= beta*n + k + round_cap, no unique candidate
    is ever dropped before termination triggers.
    """
    cap = buf_ids.shape[0]
    ids = jnp.concatenate([buf_ids, new_ids])
    d = jnp.concatenate([buf_d, new_d])
    order = jnp.argsort(ids, stable=True)                     # sentinels last
    ids_s = ids[order]
    d_s = d[order]
    first = jnp.concatenate([jnp.array([True]), ids_s[1:] != ids_s[:-1]])
    is_real = ids_s < n
    keep = first & is_real
    d_s = jnp.where(keep, d_s, jnp.inf)
    ids_s = jnp.where(keep, ids_s, n)
    # Retain the cap best by distance.
    negd, sel = jax.lax.top_k(-d_s, cap)
    out_ids = ids_s[sel]
    out_d = -negd
    count = jnp.sum(out_ids < n).astype(jnp.int32)
    return out_ids, out_d, count


def exact_distances(data: jax.Array, q: jax.Array, ids: jax.Array,
                    ok: jax.Array, *, impl: str = "auto") -> jax.Array:
    """Exact original-space distances for candidate ids ((paper's rerank)."""
    n = data.shape[0]
    safe = jnp.clip(ids, 0, n - 1)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops
        pts = jnp.take(data, safe, axis=0)
        d = kops.l2_rerank(q[None, :], pts,
                           interpret=(impl == "pallas_interpret"))[0]
    else:
        pts = jnp.take(data, safe, axis=0)
        d = jnp.sqrt(jnp.maximum(jnp.sum((pts - q[None, :]) ** 2, axis=1), 0.0))
    return jnp.where(ok, d, jnp.inf)


# ---------------------------------------------------------------------------
# c^2-k-ANN query (Alg. 5)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QueryConfig:
    k: int = 50
    M: int = 8                 # leaves fetched per tree per round (vmap engine)
    cap: int = 0               # candidate buffer (0 = auto: beta*n + k + round)
    r_min: float = 1.0
    max_rounds: int = 48
    mode: str = "leaf"         # 'leaf' (optimized, default) | 'strict'
    dist_impl: str = "auto"
    bounds_impl: str = "auto"
    engine: str = "auto"       # batch engine: 'auto' or a registered name
    block_q: int = 8           # fused kernel query-tile
    block_l: int = 8           # fused kernel leaf-tile
    probe_depth: int = 0       # near-miss leaves admitted per (tree, round)

    def __post_init__(self):
        # Eager validation: a typo'd engine/mode/impl or a non-positive
        # count must fail here with the valid choices, not silently
        # misbehave deep in the radius-round loop.
        from repro.api.request import IMPLS, MODES, _check_choice, \
            _check_positive
        _check_positive("k", self.k)
        _check_positive("M", self.M)
        _check_positive("max_rounds", self.max_rounds)
        _check_positive("cap", self.cap, minimum=0)
        _check_positive("block_q", self.block_q)
        _check_positive("block_l", self.block_l)
        _check_positive("probe_depth", self.probe_depth, minimum=0)
        if not self.r_min > 0.0:
            raise ValueError(f"r_min must be positive, got {self.r_min!r}")
        _check_choice("mode", self.mode, MODES)
        _check_choice("dist_impl", self.dist_impl, IMPLS)
        _check_choice("bounds_impl", self.bounds_impl, IMPLS)
        engine_registry.validate_engine_name(self.engine)
        if self.probe_depth and self.mode == "strict":
            raise ValueError(
                "mode='strict' reproduces the unoptimized Alg. 3 per-point "
                "filter and admits no near-miss leaves; probe_depth must be "
                f"0 in strict mode (got {self.probe_depth})")


def _auto_cap(n: int, params: LSHParams, cfg: QueryConfig,
              forest: DEForest) -> int:
    round_cap = params.L * min(cfg.M, forest.n_leaves) * forest.leaf_size
    need = int(params.beta * n) + cfg.k
    return max(cfg.cap, need + round_cap) if cfg.cap else need + round_cap


def knn_query(data: jax.Array, forest: DEForest, A: jax.Array,
              params: LSHParams, q: jax.Array,
              cfg: QueryConfig, *, live: Optional[jax.Array] = None,
              active: jax.Array | bool = True) -> QueryResult:
    """Answer one c^2-k-ANN query (Alg. 5).  q: (d,).

    ``live`` is an optional (n,) bool tombstone mask (streaming index
    deletes); ``active=False`` marks the lane done from round 0 (used for
    pad lanes in partial batches — the radius loop never runs for them).
    """
    n = data.shape[0]
    K, L = params.K, params.L
    cap = _auto_cap(n, params, cfg, forest)
    q_proj = (q @ A).reshape(L, K)                              # Alg. 5 line 4
    thresh = jnp.asarray(params.beta * n + cfg.k, jnp.float32)

    def cond(state):
        rnd, r, cs, done, probed, pcand = state
        return (~done) & (rnd < cfg.max_rounds)

    def body(state):
        rnd, r, cs, done, probed, pcand = state
        new_ids, ok, pl, pc = range_query_round(
            forest, q_proj, params.epsilon * r, cfg.M, mode=cfg.mode,
            bounds_impl=cfg.bounds_impl, live=live,
            probe_depth=cfg.probe_depth, with_stats=True)       # line 5
        new_d = exact_distances(data, q, new_ids, ok, impl=cfg.dist_impl)
        new_ids = jnp.where(ok, new_ids, n)
        cs = cand.merge_round(n, cs, new_ids, new_d)
        t1 = cs.count.astype(jnp.float32) >= thresh             # line 7
        within = jnp.sum(cs.dists <= params.c * r).astype(jnp.int32)
        t2 = within >= cfg.k                                    # line 9
        done = t1 | t2
        r = jnp.where(done, r, r * params.c)                    # line 11
        return rnd + 1, r, cs, done, probed + pl, pcand + pc

    state0 = (jnp.asarray(0, jnp.int32), jnp.asarray(cfg.r_min, jnp.float32),
              cand.init_state(n, cap), ~jnp.asarray(active),
              jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32))
    rnd, r, cs, done, probed, pcand = jax.lax.while_loop(cond, body, state0)

    negd, sel = jax.lax.top_k(-cs.dists, cfg.k)                 # final rerank
    return QueryResult(ids=cs.ids[sel], dists=-negd, rounds=rnd,
                       n_candidates=cs.count, final_r=r,
                       probed_leaves=probed, probe_candidates=pcand)


# ---------------------------------------------------------------------------
# Fused batched engine (docs/DESIGN.md §3)
# ---------------------------------------------------------------------------

class FusedPlan(NamedTuple):
    """Per-index constants of the fused engine, computed once per forest.

    points_sorted: (L, n_pad, d) original-space points in each tree's
        code-sorted order — turns the candidate gather into contiguous
        streaming (a leaf is a contiguous block).
    inv_perm: (L, n) int32 — position of point i in tree l's sorted order;
        lets a round's per-tree distance rows fold into the id-indexed
        candidate table with a gather instead of a scatter.
    """
    points_sorted: jax.Array
    inv_perm: jax.Array


def make_fused_plan(data: jax.Array, forest: DEForest) -> FusedPlan:
    n = forest.n
    safe = jnp.clip(forest.point_ids, 0, n - 1)                  # (L, n_pad)
    pts = jnp.take(data, safe, axis=0)                           # (L, n_pad, d)
    pts = pts * forest.valid[..., None].astype(pts.dtype)
    positions = jnp.arange(forest.point_ids.shape[1], dtype=jnp.int32)

    def inv_one(ids_l, valid_l):
        tgt = jnp.where(valid_l, ids_l, n)
        return jnp.zeros((n,), jnp.int32).at[tgt].set(positions, mode="drop")

    inv = jax.vmap(inv_one)(forest.point_ids, forest.valid)      # (L, n)
    return FusedPlan(points_sorted=pts, inv_perm=inv)


def fused_round_update(best: jax.Array, by_id: jax.Array, r: jax.Array,
                       done: jax.Array, rounds: jax.Array, rnd: jax.Array,
                       *, params: LSHParams, k: int, thresh: jax.Array):
    """Fold one round's per-id distance table into the loop state.

    The single source of truth for the fused-style T1/T2 bookkeeping: both
    ``fused_query_batch`` and the sharded ``pdet`` engine
    (core/distributed.py) run exactly this update, which is what makes the
    PDET == DET bit-identity contract hold by construction — the sharded
    round merges shards with ``pmin`` (min is exact), then steps through
    the identical state transition.
    """
    best = jnp.minimum(best, by_id)
    count = jnp.sum(best < jnp.inf, axis=1).astype(jnp.int32)
    t1 = count.astype(jnp.float32) >= thresh                 # line 7
    within = jnp.sum(best <= params.c * r[:, None], axis=1)
    t2 = within >= k                                         # line 9
    rounds = jnp.where(done, rounds, rnd + 1)                # per lane
    done = done | t1 | t2
    r = jnp.where(done, r, r * params.c)                     # line 11
    return best, r, done, rounds


def fused_topk(best: jax.Array, k: int, n: int) -> tuple[
        jax.Array, jax.Array, jax.Array]:
    """Final (ids, dists, unique-count) over the dense best-distance table
    (shared by the fused and pdet engines)."""
    negd, sel = jax.lax.top_k(-best, k)
    dists = -negd
    ids = jnp.where(jnp.isfinite(dists), sel.astype(jnp.int32), n)
    count = jnp.sum(best < jnp.inf, axis=1).astype(jnp.int32)
    return ids, dists, count


def fused_query_batch(data: jax.Array, forest: DEForest, A: jax.Array,
                      params: LSHParams, queries: jax.Array,
                      cfg: QueryConfig,
                      plan: Optional[FusedPlan] = None, *,
                      live_sorted: Optional[jax.Array] = None,
                      n_active: Optional[jax.Array | int] = None
                      ) -> QueryResult:
    """Batched c^2-k-ANN: all lanes advance through radius rounds together.

    Per round: ONE fused range_rerank pass over (L trees x query blocks x
    leaf blocks) returns exact distances for every point whose leaf is
    admitted at each lane's current radius (-1 for done lanes => no work),
    then the round folds into a per-query dense best-distance table with a
    gather + elementwise min.  |S| is the table's finite count — the same
    unique-candidate count Alg. 5 tracks, so T1/T2 and Theorems 1-3 are
    unchanged (the admitted set is a superset of the vmap engine's;
    docs/DESIGN.md §3).

    ``live_sorted`` is an optional (L, n_pad) bool tombstone mask in each
    tree's code-sorted order (the streaming index's delete path): dead
    points emit +inf inside the kernel and never become candidates.
    ``n_active`` (int or scalar array) marks lanes >= n_active done from
    round 0 with r_eff = -1 — pad lanes of a partial batch admit nothing
    and skip all MXU work (see serving/lsh_service.py).

    With ``cfg.probe_depth > 0`` the leaf-LB table (radius-independent) is
    computed once up front and every round widens each lane's radius
    *per tree* to also admit the probe_depth nearest near-miss leaves
    (docs/DESIGN.md §11).  Unlike the vmap engine there is no top-M cut, so
    the probe set ranges over all leaves of the tree.  probe_depth=0 takes
    the exact pre-probe path (1-D radii, no LB pre-pass) — bit-identical.
    """
    n = data.shape[0]
    B = queries.shape[0]
    K, L = params.K, params.L
    if plan is None:
        plan = make_fused_plan(data, forest)
    q_proj = (queries @ A).reshape(B, L, K).transpose(1, 0, 2)   # (L, B, K)
    thresh = jnp.asarray(params.beta * n + cfg.k, jnp.float32)
    interpret = cfg.dist_impl == "pallas_interpret"
    nl, ls = forest.n_leaves, forest.leaf_size

    from repro.kernels import ops as kops
    from repro.kernels import ref as kref

    if cfg.probe_depth > 0:
        # Leaf LBs depend only on (query, leaf), not the radius: one
        # (L, B, nl) pre-pass ranks probe candidates for every round.
        probe_lb = kref.forest_leaf_lb(
            q_proj, forest.leaf_lo.astype(jnp.int32),
            forest.leaf_hi.astype(jnp.int32), forest.leaf_valid,
            forest.breakpoints)

    def cond(state):
        rnd, rounds, r, done, best, probed, pcand = state
        return jnp.any(~done) & (rnd < cfg.max_rounds)

    def body(state):
        rnd, rounds, r, done, best, probed, pcand = state
        r_eff = jnp.where(done, -1.0, params.epsilon * r)        # lane mask
        if cfg.probe_depth > 0:
            r_adm, probe_mask = kref.probe_radii_from_lb(
                probe_lb, r_eff, cfg.probe_depth)                # (L, B)
        else:
            r_adm = r_eff                                        # (B,) shared
        dmat = kops.range_rerank(
            queries, q_proj, r_adm, forest.leaf_lo, forest.leaf_hi,
            forest.leaf_valid, forest.breakpoints, plan.points_sorted,
            forest.valid, live_sorted,
            leaf_size=forest.leaf_size, interpret=interpret,
            block_q=cfg.block_q, block_l=cfg.block_l)            # (L, B, n_pad)
        if cfg.probe_depth > 0:
            probed = probed + probe_mask.sum((0, 2)).astype(jnp.int32)
            per_leaf = jnp.isfinite(dmat.reshape(L, B, nl, ls)).sum(-1)
            pcand = pcand + jnp.where(probe_mask, per_leaf,
                                      0).sum((0, 2)).astype(jnp.int32)
        # Fold the round into the id-indexed table: inv_perm turns each
        # tree's sorted-order row into id order (gather, not scatter).
        by_id = jnp.min(
            jnp.take_along_axis(dmat, plan.inv_perm[:, None, :], axis=2),
            axis=0)                                              # (B, n)
        best, r, done, rounds = fused_round_update(
            best, by_id, r, done, rounds, rnd, params=params, k=cfg.k,
            thresh=thresh)
        return rnd + 1, rounds, r, done, best, probed, pcand

    done0 = (jnp.zeros((B,), jnp.bool_) if n_active is None
             else jnp.arange(B) >= jnp.asarray(n_active))
    state0 = (jnp.asarray(0, jnp.int32),
              jnp.zeros((B,), jnp.int32),
              jnp.full((B,), cfg.r_min, jnp.float32),
              done0,
              jnp.full((B, n), jnp.inf, jnp.float32),
              jnp.zeros((B,), jnp.int32),
              jnp.zeros((B,), jnp.int32))
    rnd, rounds, r, done, best, probed, pcand = jax.lax.while_loop(
        cond, body, state0)

    ids, dists, count = fused_topk(best, cfg.k, n)
    return QueryResult(ids=ids, dists=dists, rounds=rounds,
                       n_candidates=count, final_r=r,
                       probed_leaves=probed, probe_candidates=pcand)


# Below this batch size the fused engine's full-forest streaming pass is not
# amortized and the per-query vmap path wins (measured in BENCH_query.json).
_FUSED_MIN_BATCH = 8


def live_in_sorted_order(forest: DEForest,
                         live: jax.Array) -> jax.Array:
    """Translate an (n,) id-order tombstone mask to each tree's code-sorted
    order: (L, n_pad) bool, padding rows dead.  This is the layout the fused
    kernel's per-tile live mask consumes."""
    safe = jnp.clip(forest.point_ids, 0, forest.n - 1)
    return live[safe] & forest.valid


def _run_vmap_engine(data, forest, A, params, queries, cfg, *,
                     plan=None, live=None, live_sorted=None,
                     n_active=None) -> QueryResult:
    """Registry entry point for engine='vmap' (ignores plan/live_sorted)."""
    del plan, live_sorted
    B = queries.shape[0]
    active = (jnp.ones((B,), jnp.bool_) if n_active is None
              else jnp.arange(B) < jnp.asarray(n_active))
    fn = functools.partial(knn_query, data, forest, A, params, cfg=cfg,
                           live=live)
    return jax.vmap(lambda q, a: fn(q, active=a))(queries, active)


def _run_fused_engine(data, forest, A, params, queries, cfg, *,
                      plan=None, live=None, live_sorted=None,
                      n_active=None) -> QueryResult:
    """Registry entry point for engine='fused' (derives live_sorted)."""
    if live_sorted is None and live is not None:
        live_sorted = live_in_sorted_order(forest, live)
    return fused_query_batch(data, forest, A, params, queries, cfg,
                             plan=plan, live_sorted=live_sorted,
                             n_active=n_active)


engine_registry.register_engine(
    "vmap", _run_vmap_engine, modes=("leaf", "strict"), min_batch=1,
    priority=0,
    doc="per-query while_loop, vmapped; the only engine reproducing the "
        "unoptimized strict Alg. 3 per-point filter")
engine_registry.register_engine(
    "fused", _run_fused_engine, modes=("leaf",),
    min_batch=_FUSED_MIN_BATCH, priority=10,
    doc="one-pass Pallas range_rerank over all L trees; leaf-granular "
        "admission (a superset of vmap's — Theorems 1-3 unchanged)")


def knn_query_batch(data: jax.Array, forest: DEForest, A: jax.Array,
                    params: LSHParams, queries: jax.Array,
                    cfg: QueryConfig,
                    plan: Optional[FusedPlan] = None, *,
                    live: Optional[jax.Array] = None,
                    live_sorted: Optional[jax.Array] = None,
                    n_active: Optional[jax.Array | int] = None
                    ) -> QueryResult:
    """Batched c^2-k-ANN over a (b, d) query batch.

    Dispatches through the ``repro.api.registry`` engine registry (fused
    by default at batch >= 8, vmap otherwise / for 'strict') according to
    ``cfg.engine`` / ``cfg.mode`` and the (static) batch size.

    ``live`` ((n,) bool, id order) / ``live_sorted`` ((L, n_pad) bool,
    code-sorted order) carry the streaming index's tombstones — pass either
    (the other is derived); None means every point is live.  ``n_active``
    marks trailing pad lanes of a partial batch done from round 0.
    """
    engine = engine_registry.get_engine(
        engine_registry.resolve_engine(cfg.engine, mode=cfg.mode,
                                       batch=queries.shape[0]))
    return engine.run(data, forest, A, params, queries, cfg, plan=plan,
                      live=live, live_sorted=live_sorted, n_active=n_active)


# ---------------------------------------------------------------------------
# (r,c)-ANN query (Alg. 4) — single fixed radius; used by tests/benchmarks
# ---------------------------------------------------------------------------

def rc_ann_query(data: jax.Array, forest: DEForest, A: jax.Array,
                 params: LSHParams, q: jax.Array, r: float,
                 cfg: QueryConfig) -> QueryResult:
    """Answer one (r,c)-ANN query (Alg. 4): returns the closest candidate
    found, or an invalid id (= n) when the algorithm would return nothing."""
    n = data.shape[0]
    cap = _auto_cap(n, params, cfg, forest)
    q_proj = (q @ A).reshape(params.L, params.K)
    ids, ok = range_query_round(forest, q_proj,
                                jnp.asarray(params.epsilon * r), cfg.M,
                                mode=cfg.mode, bounds_impl=cfg.bounds_impl,
                                probe_depth=cfg.probe_depth)
    d = exact_distances(data, q, ids, ok, impl=cfg.dist_impl)
    ids = jnp.where(ok, ids, n)
    cs = cand.merge_round(n, cand.init_state(n, cap), ids, d)
    best = jnp.argmin(cs.dists)
    t1 = cs.count >= jnp.asarray(params.beta * n + 1, jnp.int32)  # line 6
    t2 = jnp.sum(cs.dists <= params.c * r) >= 1                   # line 8
    give = t1 | t2
    out_id = jnp.where(give, cs.ids[best], n).astype(jnp.int32)
    out_d = jnp.where(give, cs.dists[best], jnp.inf)
    return QueryResult(ids=out_id[None], dists=out_d[None],
                       rounds=jnp.asarray(1, jnp.int32), n_candidates=cs.count,
                       final_r=jnp.asarray(r, jnp.float32))
