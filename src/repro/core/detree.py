"""DE-Tree / DE-Forest (paper §III-B, Alg. 2) — TPU-native array form.

A DE-Tree organizes iSAX-encoded points so that range queries can prune via
per-node lower/upper-bound distances (paper Fig. 5).  Pointer-based trees do
not map to TPUs, so we store each tree as a *code-sorted array*:

  * points are sorted by the bit-interleaved (MSB-first, round-robin) iSAX
    code — exactly the order a DE-Tree's recursive binary splits induce, so a
    contiguous block of the sorted array corresponds to a subtree;
  * leaves are fixed-size blocks of ``leaf_size`` consecutive sorted points;
  * each leaf stores its per-dimension region interval [lo, hi] (the node's
    bounding iSAX prefix, tightened to the actual occupied regions).

LB/UB distances computed from a leaf's [lo, hi] intervals and the breakpoint
coordinates are identical in form to the paper's Fig. 5 bounds and remain
admissible (LB <= true projected distance <= UB for every point in the leaf;
property-tested), so all pruning/guarantee arguments carry over.

All L trees are built in one shot (vectorized over the leading L axis) — the
PDET-LSH parallel build (Alg. 7) falls out of data sharding: each device
builds a complete local forest over its shard (see ``core.distributed``).

Build pipeline (docs/DESIGN.md §8).  The hot path is the *fused, single-sort*
builder: the ``kernels/build_fused.py`` Pallas kernel streams row chunks of
the input through project -> encode -> key-pack in one grid pass, emitting
per-tree layouts directly (no (n, L*K) intermediates or transposed copies),
then ONE stable variadic sort per forest (``code_sort_orders``) orders all L
trees at once.  The two packed uint32 key words compared lexicographically
ARE the 64-bit interleaved key — an x64-safe uint64 — and for K <= 4 the
whole key fits the hi word and the low word is statically dropped.  The
stable (hi, lo) sort produces the *identical* permutation as the seed's
double stable argsort (stable radix argument; property-tested in
tests/test_build_fused.py), so fused-built forests are bit-identical to
reference-built ones.  ``build_impl='reference'`` keeps the seed per-tree
path as the semantics-of-record oracle and the benchmark baseline.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import encoding as enc

# Storage dtypes of the code-side index arrays (docs/DESIGN.md §8): region
# ids are 8-bit symbols (Nr <= 256) and leaf bounds are small region
# indices, so the resident index keeps them narrow — uint8 codes, int16
# bounds — and every consumer casts at use (the kernels' ops wrappers
# widen to int32 on entry).
CODE_DTYPE = jnp.uint8
LEAF_DTYPE = jnp.int16
MAX_NR = 256          # uint8 code storage: region ids must fit [0, 255]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DEForest:
    """L DE-Trees over one (shard of a) dataset, in array form."""

    point_ids: jax.Array     # (L, n_pad) int32 — original index; n = padding
    proj_sorted: jax.Array   # (L, n_pad, K) f32 — projected coords, sorted order
    codes_sorted: jax.Array  # (L, n_pad, K) uint8 — region ids, sorted order
    valid: jax.Array         # (L, n_pad) bool
    leaf_lo: jax.Array       # (L, n_leaves, K) int16 — occupied region interval
    leaf_hi: jax.Array       # (L, n_leaves, K) int16
    leaf_valid: jax.Array    # (L, n_leaves) bool
    breakpoints: jax.Array   # (L, K, Nr+1) f32
    n: int = dataclasses.field(metadata=dict(static=True))
    leaf_size: int = dataclasses.field(metadata=dict(static=True))

    @property
    def L(self) -> int:
        return self.point_ids.shape[0]

    @property
    def K(self) -> int:
        return self.breakpoints.shape[1]

    @property
    def n_leaves(self) -> int:
        return self.leaf_lo.shape[1]

    @property
    def Nr(self) -> int:
        return self.breakpoints.shape[2] - 1

    def size_bytes(self) -> int:
        """Resident code-side footprint (actual dtypes: codes 1B, ids 4B,
        bounds 2B, breakpoints 4B — proj_sorted excluded, as in the paper's
        index-size accounting)."""
        return int(sum(a.size * a.dtype.itemsize
                       for a in (self.codes_sorted, self.point_ids,
                                 self.leaf_lo, self.leaf_hi,
                                 self.breakpoints)))


# ---------------------------------------------------------------------------
# Interleaved sort keys
# ---------------------------------------------------------------------------

def key_bit_budget(K: int) -> tuple[int, int, int]:
    """(bits_per_dim, hi_bits, lo_bits) of the interleaved key for K dims.

    Up to 64 total bits split over two uint32 words; deeper bits than 64/K
    per dim do not affect leaf grouping materially.  For K <= 4 the whole
    key fits the hi word (lo_bits == 0) and the sort drops the low word
    statically.
    """
    bits_total = min(8, max(1, 64 // K))     # bits per dim that fit 2 words
    hi_bits = min(bits_total, max(1, 32 // K))
    return bits_total, hi_bits, bits_total - hi_bits


def interleave_keys(codes: jax.Array, K: int) -> tuple[jax.Array, jax.Array]:
    """Bit-interleaved sort keys from (..., K) region ids in [0, 256).

    Returns (key_hi, key_lo) uint32 of shape ``codes.shape[:-1]``: MSB-first,
    round-robin over dimensions — the linearization of the DE-Tree's split
    order ("each split performs a binary refinement on a single dimension",
    §III-B).  The (hi, lo) pair compared lexicographically is the packed
    64-bit key.  Fully vectorized (one shift/mask/sum over a (nbits, K)
    weight table — no per-bit Python loop), batches over any leading axes,
    and produces bit-identical words to the seed per-bit packing.
    """
    _, hi_bits, lo_bits = key_bit_budget(K)

    def pack(start_bit: int, nbits: int) -> jax.Array:
        if nbits == 0:
            return jnp.zeros(codes.shape[:-1], dtype=jnp.uint32)
        shift = jnp.arange(7 - start_bit, 7 - start_bit - nbits, -1,
                           dtype=jnp.uint32)                   # (nbits,)
        # Bit level b of dim j lands at position nbits*K - 1 - (b*K + j);
        # positions >= 32 overflow the word and are dropped *explicitly*
        # (weights built host-side at trace time), not via backend
        # shift-overflow behavior — the compactor's host keys mirror this.
        import numpy as _np
        pos = (nbits * K - 1
               - (_np.arange(nbits)[:, None] * K + _np.arange(K)[None, :]))
        weight = jnp.asarray(
            _np.where(pos < 32,
                      _np.uint64(1) << _np.minimum(pos, 31).astype(_np.uint64),
                      0).astype(_np.uint32))                   # (nbits, K)
        bits = (codes[..., None, :].astype(jnp.uint32)
                >> shift[:, None]) & jnp.uint32(1)             # (..., nbits, K)
        return jnp.sum(bits * weight, axis=(-2, -1), dtype=jnp.uint32)

    return pack(0, hi_bits), pack(hi_bits, lo_bits)


def _interleave_keys(codes: jax.Array, K: int) -> tuple[jax.Array, jax.Array]:
    """Seed-compatible alias of :func:`interleave_keys` ((n, K) -> (n,))."""
    return interleave_keys(codes, K)


def code_sort_orders(key_hi: jax.Array, key_lo: jax.Array,
                     K: int) -> jax.Array:
    """Sorting permutations for every tree from (L, n) packed key words.

    ONE stable variadic sort (``lax.sort`` with the two key words compared
    lexicographically — i.e. a 64-bit key compare — and an iota payload that
    becomes the permutation) replaces the seed's two stable argsorts per
    tree; all L trees sort in the same call (batched over the leading axis).
    Stability makes the permutation identical to the seed composition
    "stable-by-lo then stable-by-hi" (radix argument, property-tested).

    Off-trace on the CPU backend the sort runs as numpy's stable
    ``lexsort`` (radix on integer keys, ~5x faster than XLA CPU's
    comparator sort; the permutation is identical — both are the stable
    lexicographic (hi, lo) order), mirroring ``encoding._sort_columns``.
    """
    if (not isinstance(key_hi, jax.core.Tracer)
            and jax.default_backend() == "cpu"):
        import numpy as _np
        hi = _np.asarray(key_hi)
        lo = _np.asarray(key_lo)
        order = _np.empty(hi.shape, _np.int32)
        for l in range(hi.shape[0]):        # lexsort: last key is primary
            order[l] = _np.lexsort((lo[l], hi[l]))
        return jnp.asarray(order)
    n = key_hi.shape[-1]
    iota = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), key_hi.shape)
    if key_bit_budget(K)[2] == 0:       # key fits one word: drop the low one
        _, order = jax.lax.sort((key_hi, iota), dimension=-1,
                                is_stable=True, num_keys=1)
    else:
        _, _, order = jax.lax.sort((key_hi, key_lo, iota), dimension=-1,
                                   is_stable=True, num_keys=2)
    return order


def _sort_by_code(codes: jax.Array, K: int) -> jax.Array:
    """Seed path: permutation sorting (n, K) codes by interleaved key via
    two stable argsorts.  Kept as the semantics-of-record oracle for the
    single-sort equivalence property tests (and ``build_impl='reference'``).
    """
    key_hi, key_lo = interleave_keys(codes, K)
    order = jnp.argsort(key_lo, stable=True)
    order = order[jnp.argsort(key_hi[order], stable=True)]
    return order


# ---------------------------------------------------------------------------
# Build
# ---------------------------------------------------------------------------

def assemble_sorted_forest(proj_t: jax.Array, codes_t: jax.Array,
                           order: jax.Array, *, n: int,
                           leaf_size: int) -> dict:
    """Gather per-tree sorted layouts + leaf summaries for all L trees.

    proj_t/codes_t (L, n, K) in input row order, order (L, n) sorting
    permutations.  Returns the DEForest arrays (minus breakpoints/statics)
    in their storage dtypes (codes uint8, bounds int16).
    """
    L, _, K = proj_t.shape
    n_leaves = -(-n // leaf_size)
    n_pad = n_leaves * leaf_size
    pad = n_pad - n

    proj_s = jnp.take_along_axis(proj_t, order[..., None], axis=1)
    codes_s = jnp.take_along_axis(codes_t.astype(jnp.int32),
                                  order[..., None], axis=1)
    proj_s = jnp.pad(proj_s, ((0, 0), (0, pad), (0, 0)))
    codes_s = jnp.pad(codes_s, ((0, 0), (0, pad), (0, 0)))
    ids = jnp.pad(order.astype(jnp.int32), ((0, 0), (0, pad)),
                  constant_values=n)
    valid = jnp.broadcast_to(jnp.arange(n_pad) < n, (L, n_pad))

    blocks = codes_s.reshape(L, n_leaves, leaf_size, K)
    bmask = valid.reshape(L, n_leaves, leaf_size)
    big = jnp.iinfo(jnp.int32).max
    lo = jnp.where(bmask[..., None], blocks, big).min(axis=2)
    hi = jnp.where(bmask[..., None], blocks, -1).max(axis=2)
    leaf_valid = bmask.any(axis=2)
    lo = jnp.where(leaf_valid[..., None], lo, 0).astype(LEAF_DTYPE)
    hi = jnp.where(leaf_valid[..., None], hi, 0).astype(LEAF_DTYPE)

    return dict(point_ids=ids, proj_sorted=proj_s,
                codes_sorted=codes_s.astype(CODE_DTYPE), valid=valid,
                leaf_lo=lo, leaf_hi=hi, leaf_valid=leaf_valid)


def check_nr(Nr: int) -> None:
    """uint8 code storage: every builder entry point must refuse Nr > 256
    or codes would silently wrap mod 256."""
    if Nr > MAX_NR:
        raise ValueError(f"Nr={Nr} > {MAX_NR}: region ids are stored as "
                         f"uint8 symbols (paper's 8-bit alphabet)")


def fused_forest_arrays(proj_all: jax.Array, bp_all: jax.Array, *, K: int,
                        L: int, leaf_size: int, impl: str = "auto",
                        chunk: int = 512) -> dict:
    """Fused encode+key-pack -> single sort -> assemble, from (n, L*K)
    projections.  Trace-compatible (used inside the PDET shard_map build);
    ``impl`` picks the encode+pack kernel ('auto' = Pallas on TPU, the pure
    XLA oracle elsewhere), ``chunk`` its row-block size.
    """
    check_nr(bp_all.shape[1] - 1)
    n = proj_all.shape[0]
    if impl == "xla":
        from repro.kernels import ref as kref
        proj_t, codes_t, key_hi, key_lo = kref.encode_pack(
            proj_all, bp_all, K=K, L=L)
    else:
        from repro.kernels import ops as kops
        proj_t, codes_t, key_hi, key_lo = kops.encode_pack(
            proj_all, bp_all, K=K, L=L, block_n=chunk,
            interpret=(impl == "pallas_interpret"))
    order = code_sort_orders(key_hi, key_lo, K)
    return assemble_sorted_forest(proj_t, codes_t, order, n=n,
                                  leaf_size=leaf_size)


@functools.partial(jax.jit,
                   static_argnames=("K", "L", "leaf_size", "impl", "chunk"))
def _fused_build_jit(proj_all, bp_all, *, K, L, leaf_size, impl, chunk):
    return fused_forest_arrays(proj_all, bp_all, K=K, L=L,
                               leaf_size=leaf_size, impl=impl, chunk=chunk)


@functools.partial(jax.jit, static_argnames=("K", "L", "impl", "chunk"))
def _encode_pack_jit(proj_all, bp_all, *, K, L, impl, chunk):
    if impl == "xla":
        from repro.kernels import ref as kref
        return kref.encode_pack(proj_all, bp_all, K=K, L=L)
    from repro.kernels import ops as kops
    return kops.encode_pack(proj_all, bp_all, K=K, L=L, block_n=chunk,
                            interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("n", "leaf_size"))
def _assemble_jit(proj_t, codes_t, order, *, n, leaf_size):
    return assemble_sorted_forest(proj_t, codes_t, order, n=n,
                                  leaf_size=leaf_size)


def _fused_build_arrays(proj_all, bp_all, *, K, L, leaf_size, impl,
                        chunk) -> dict:
    """Eager fused-build entry: on the CPU backend the key sort runs on
    the host (``code_sort_orders``' lexsort fast path) between the two
    jitted stages; elsewhere (and under an outer trace) everything fuses
    into the single jitted pipeline."""
    if (not isinstance(proj_all, jax.core.Tracer)
            and jax.default_backend() == "cpu"):
        proj_t, codes_t, key_hi, key_lo = _encode_pack_jit(
            proj_all, bp_all, K=K, L=L, impl=impl, chunk=chunk)
        order = code_sort_orders(key_hi, key_lo, K)
        return _assemble_jit(proj_t, codes_t, order,
                             n=proj_all.shape[0], leaf_size=leaf_size)
    return _fused_build_jit(proj_all, bp_all, K=K, L=L,
                            leaf_size=leaf_size, impl=impl, chunk=chunk)


def build_tree(proj: jax.Array, codes: jax.Array, breakpoints: jax.Array,
               leaf_size: int) -> dict:
    """Build one DE-Tree (array form) from (n, K) projections + codes.

    The seed per-tree path (double stable argsort), kept as the reference
    builder (``build_impl='reference'``), the oracle the fused pipeline is
    property-tested against, and the per-(batch, head) builder of
    ``det_attention``.
    """
    n, K = proj.shape
    order = _sort_by_code(codes, K)
    n_leaves = -(-n // leaf_size)
    n_pad = n_leaves * leaf_size
    pad = n_pad - n

    ids = jnp.pad(order.astype(jnp.int32), (0, pad), constant_values=n)
    valid = jnp.arange(n_pad) < n
    proj_s = jnp.pad(proj[order], ((0, pad), (0, 0)), constant_values=0.0)
    codes_s = jnp.pad(codes[order].astype(jnp.int32), ((0, pad), (0, 0)),
                      constant_values=0)

    blocks = codes_s.reshape(n_leaves, leaf_size, K)
    bmask = valid.reshape(n_leaves, leaf_size)
    big = jnp.iinfo(jnp.int32).max
    lo = jnp.where(bmask[..., None], blocks, big).min(axis=1)
    hi = jnp.where(bmask[..., None], blocks, -1).max(axis=1)
    leaf_valid = bmask.any(axis=1)
    lo = jnp.where(leaf_valid[:, None], lo, 0).astype(LEAF_DTYPE)
    hi = jnp.where(leaf_valid[:, None], hi, 0).astype(LEAF_DTYPE)

    return dict(point_ids=ids, proj_sorted=proj_s,
                codes_sorted=codes_s.astype(CODE_DTYPE),
                valid=valid, leaf_lo=lo, leaf_hi=hi, leaf_valid=leaf_valid,
                breakpoints=breakpoints)


def build_forest(proj_all: jax.Array, K: int, L: int, *,
                 Nr: int = enc.DEFAULT_NR, leaf_size: int = 64,
                 breakpoint_method: str = "sample_sort",
                 key: jax.Array | None = None,
                 encode_impl: str = "auto",
                 breakpoints: jax.Array | None = None,
                 build_impl: str = "auto",
                 build_chunk: int = 512) -> DEForest:
    """Build L DE-Trees from projections (n, L*K) (paper Alg. 1 + Alg. 2).

    ``breakpoints`` ((L*K, Nr+1), optional) bypasses breakpoint selection
    and encodes with the given *frozen* edges — the streaming index's seal
    path, which must encode new points into the base build's quantization so
    segment codes stay mutually comparable (docs/DESIGN.md §5).

    ``build_impl`` selects the builder: 'auto'/'xla'/'pallas'/
    'pallas_interpret' run the fused single-sort pipeline (one jitted call:
    encode+key-pack kernel, one stable sort for all L trees, vectorized
    gather + leaf summaries), with ``build_chunk`` as the kernel's row-block
    size; 'reference' runs the seed per-tree double-argsort path.  Both
    produce bit-identical forests (tests/test_build_fused.py).
    """
    n = proj_all.shape[0]
    assert proj_all.shape[1] == L * K, (proj_all.shape, L, K)
    check_nr(Nr)
    if breakpoints is None:
        bp_all = enc.select_breakpoints(proj_all, Nr,
                                        method=breakpoint_method,
                                        key=key)                   # (L*K, Nr+1)
    else:
        bp_all = breakpoints
        assert bp_all.shape == (L * K, Nr + 1), (bp_all.shape, L * K, Nr)
    bp_t = bp_all.reshape(L, K, Nr + 1)

    if build_impl == "reference":
        codes_all = enc.encode(proj_all, bp_all, impl=encode_impl)  # (n, L*K)
        proj_t = proj_all.reshape(n, L, K).transpose(1, 0, 2)       # (L, n, K)
        codes_t = codes_all.reshape(n, L, K).transpose(1, 0, 2)
        parts = jax.vmap(functools.partial(build_tree,
                                           leaf_size=leaf_size))(
            proj_t, codes_t, bp_t)
        return DEForest(n=n, leaf_size=leaf_size, **parts)

    impl = build_impl
    if impl == "auto" and encode_impl != "auto":
        impl = encode_impl            # an explicit encode impl wins on auto
    arrays = _fused_build_arrays(
        proj_all, bp_all, K=K, L=L, leaf_size=leaf_size, impl=impl,
        chunk=int(build_chunk) if build_chunk else 512)
    return DEForest(n=n, leaf_size=leaf_size, breakpoints=bp_t, **arrays)


# ---------------------------------------------------------------------------
# Leaf LB/UB bounds (paper Fig. 5)
# ---------------------------------------------------------------------------

def leaf_bounds(q_proj: jax.Array, leaf_lo: jax.Array, leaf_hi: jax.Array,
                leaf_valid: jax.Array, breakpoints: jax.Array, *,
                impl: str = "auto") -> tuple[jax.Array, jax.Array]:
    """LB/UB distances from a projected query to every leaf of one tree.

    q_proj: (K,); leaf_lo/hi: (n_leaves, K); breakpoints: (K, Nr+1).
    Returns (lb, ub), each (n_leaves,).  Invalid leaves get lb = ub = +inf.
    """
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops
        return kops.leaf_bounds(q_proj, leaf_lo, leaf_hi, leaf_valid,
                                breakpoints,
                                interpret=(impl == "pallas_interpret"))
    # Coordinates of the leaf's bounding box edges (int16 indices widen in
    # the gather).
    b_lo = _gather_edges(breakpoints, leaf_lo)                     # (n_leaves, K)
    b_hi = _gather_edges(breakpoints, leaf_hi.astype(jnp.int32) + 1)
    d_lo = b_lo - q_proj[None, :]
    d_hi = q_proj[None, :] - b_hi
    lb_dim = jnp.maximum(jnp.maximum(d_lo, d_hi), 0.0)
    ub_dim = jnp.maximum(jnp.abs(q_proj[None, :] - b_lo),
                         jnp.abs(q_proj[None, :] - b_hi))
    lb = jnp.sqrt(jnp.sum(lb_dim * lb_dim, axis=1))
    ub = jnp.sqrt(jnp.sum(ub_dim * ub_dim, axis=1))
    inf = jnp.inf
    lb = jnp.where(leaf_valid, lb, inf)
    ub = jnp.where(leaf_valid, ub, inf)
    return lb, ub


def _gather_edges(breakpoints: jax.Array, idx: jax.Array) -> jax.Array:
    """breakpoints (K, Nr+1), idx (n_leaves, K) -> coords (n_leaves, K)."""
    E = breakpoints.shape[1]
    idx = jnp.clip(idx.astype(jnp.int32), 0, E - 1)
    return jax.vmap(lambda bp_k, i_k: bp_k[i_k], in_axes=(0, 1), out_axes=1)(
        breakpoints, idx)
