"""DE-Tree / DE-Forest (paper §III-B, Alg. 2) — TPU-native array form.

A DE-Tree organizes iSAX-encoded points so that range queries can prune via
per-node lower/upper-bound distances (paper Fig. 5).  Pointer-based trees do
not map to TPUs, so we store each tree as a *code-sorted array*:

  * points are sorted by the bit-interleaved (MSB-first, round-robin) iSAX
    code — exactly the order a DE-Tree's recursive binary splits induce, so a
    contiguous block of the sorted array corresponds to a subtree;
  * leaves are fixed-size blocks of ``leaf_size`` consecutive sorted points;
  * each leaf stores its per-dimension region interval [lo, hi] (the node's
    bounding iSAX prefix, tightened to the actual occupied regions).

LB/UB distances computed from a leaf's [lo, hi] intervals and the breakpoint
coordinates are identical in form to the paper's Fig. 5 bounds and remain
admissible (LB <= true projected distance <= UB for every point in the leaf;
property-tested), so all pruning/guarantee arguments carry over.

All L trees are built in one shot (vectorized over the leading L axis) — the
PDET-LSH parallel build (Alg. 7) falls out of data sharding: each device
builds a complete local forest over its shard (see ``core.distributed``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import encoding as enc


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DEForest:
    """L DE-Trees over one (shard of a) dataset, in array form."""

    point_ids: jax.Array     # (L, n_pad) int32 — original index; n = padding
    proj_sorted: jax.Array   # (L, n_pad, K) f32 — projected coords, sorted order
    codes_sorted: jax.Array  # (L, n_pad, K) int32 — region ids, sorted order
    valid: jax.Array         # (L, n_pad) bool
    leaf_lo: jax.Array       # (L, n_leaves, K) int32 — occupied region interval
    leaf_hi: jax.Array       # (L, n_leaves, K) int32
    leaf_valid: jax.Array    # (L, n_leaves) bool
    breakpoints: jax.Array   # (L, K, Nr+1) f32
    n: int = dataclasses.field(metadata=dict(static=True))
    leaf_size: int = dataclasses.field(metadata=dict(static=True))

    @property
    def L(self) -> int:
        return self.point_ids.shape[0]

    @property
    def K(self) -> int:
        return self.breakpoints.shape[1]

    @property
    def n_leaves(self) -> int:
        return self.leaf_lo.shape[1]

    @property
    def Nr(self) -> int:
        return self.breakpoints.shape[2] - 1

    def size_bytes(self) -> int:
        """Index footprint (codes as 1-byte symbols on TPU; ids 4B; bounds 1B)."""
        L, n_pad, K = self.proj_sorted.shape
        n_leaves = self.n_leaves
        return int(L * (n_pad * K * 1 + n_pad * 4 + n_leaves * K * 2
                        + K * (self.Nr + 1) * 4))


# ---------------------------------------------------------------------------
# Build
# ---------------------------------------------------------------------------

def _interleave_keys(codes: jax.Array, K: int) -> tuple[jax.Array, jax.Array]:
    """Bit-interleaved sort keys from (n, K) region ids in [0, 256).

    Returns (key_hi, key_lo) uint32: MSB-first, round-robin over dimensions —
    the linearization of the DE-Tree's split order ("each split performs a
    binary refinement on a single dimension", §III-B).  Up to 64 total bits;
    deeper bits than 64/K per dim do not affect leaf grouping materially.
    """
    bits_total = min(8, max(1, 64 // K))     # bits per dim that fit in 2 words
    hi_bits = min(bits_total, max(1, 32 // K))
    lo_bits = bits_total - hi_bits

    def pack(start_bit: int, nbits: int) -> jax.Array:
        key = jnp.zeros(codes.shape[0], dtype=jnp.uint32)
        pos = nbits * K
        for b in range(nbits):                # bit level (MSB first)
            for j in range(K):                # round-robin over dims
                pos -= 1
                bit = (codes[:, j] >> (7 - (start_bit + b))) & 1
                key = key | (bit.astype(jnp.uint32) << pos)
        return key

    key_hi = pack(0, hi_bits)
    key_lo = pack(hi_bits, lo_bits) if lo_bits > 0 else jnp.zeros(
        codes.shape[0], dtype=jnp.uint32)
    return key_hi, key_lo


def _sort_by_code(codes: jax.Array, K: int) -> jax.Array:
    """Return permutation sorting points by interleaved code (lexicographic)."""
    key_hi, key_lo = _interleave_keys(codes, K)
    order = jnp.argsort(key_lo, stable=True)
    order = order[jnp.argsort(key_hi[order], stable=True)]
    return order


def build_tree(proj: jax.Array, codes: jax.Array, breakpoints: jax.Array,
               leaf_size: int) -> dict:
    """Build one DE-Tree (array form) from (n, K) projections + codes."""
    n, K = proj.shape
    order = _sort_by_code(codes, K)
    n_leaves = -(-n // leaf_size)
    n_pad = n_leaves * leaf_size
    pad = n_pad - n

    ids = jnp.pad(order.astype(jnp.int32), (0, pad), constant_values=n)
    valid = jnp.arange(n_pad) < n
    proj_s = jnp.pad(proj[order], ((0, pad), (0, 0)), constant_values=0.0)
    codes_s = jnp.pad(codes[order], ((0, pad), (0, 0)), constant_values=0)

    blocks = codes_s.reshape(n_leaves, leaf_size, K)
    bmask = valid.reshape(n_leaves, leaf_size)
    big = jnp.iinfo(jnp.int32).max
    lo = jnp.where(bmask[..., None], blocks, big).min(axis=1)
    hi = jnp.where(bmask[..., None], blocks, -1).max(axis=1)
    leaf_valid = bmask.any(axis=1)
    lo = jnp.where(leaf_valid[:, None], lo, 0).astype(jnp.int32)
    hi = jnp.where(leaf_valid[:, None], hi, 0).astype(jnp.int32)

    return dict(point_ids=ids, proj_sorted=proj_s, codes_sorted=codes_s,
                valid=valid, leaf_lo=lo, leaf_hi=hi, leaf_valid=leaf_valid,
                breakpoints=breakpoints)


def build_forest(proj_all: jax.Array, K: int, L: int, *,
                 Nr: int = enc.DEFAULT_NR, leaf_size: int = 64,
                 breakpoint_method: str = "sample_sort",
                 key: jax.Array | None = None,
                 encode_impl: str = "auto",
                 breakpoints: jax.Array | None = None) -> DEForest:
    """Build L DE-Trees from projections (n, L*K) (paper Alg. 1 + Alg. 2).

    ``breakpoints`` ((L*K, Nr+1), optional) bypasses breakpoint selection
    and encodes with the given *frozen* edges — the streaming index's seal
    path, which must encode new points into the base build's quantization so
    segment codes stay mutually comparable (docs/DESIGN.md §5).
    """
    n = proj_all.shape[0]
    assert proj_all.shape[1] == L * K, (proj_all.shape, L, K)
    if breakpoints is None:
        bp_all = enc.select_breakpoints(proj_all, Nr,
                                        method=breakpoint_method,
                                        key=key)                   # (L*K, Nr+1)
    else:
        bp_all = breakpoints
        assert bp_all.shape == (L * K, Nr + 1), (bp_all.shape, L * K, Nr)
    codes_all = enc.encode(proj_all, bp_all, impl=encode_impl)     # (n, L*K)

    proj_t = proj_all.reshape(n, L, K).transpose(1, 0, 2)          # (L, n, K)
    codes_t = codes_all.reshape(n, L, K).transpose(1, 0, 2)
    bp_t = bp_all.reshape(L, K, Nr + 1)

    parts = jax.vmap(functools.partial(build_tree, leaf_size=leaf_size))(
        proj_t, codes_t, bp_t)
    return DEForest(n=n, leaf_size=leaf_size, **parts)


# ---------------------------------------------------------------------------
# Leaf LB/UB bounds (paper Fig. 5)
# ---------------------------------------------------------------------------

def leaf_bounds(q_proj: jax.Array, leaf_lo: jax.Array, leaf_hi: jax.Array,
                leaf_valid: jax.Array, breakpoints: jax.Array, *,
                impl: str = "auto") -> tuple[jax.Array, jax.Array]:
    """LB/UB distances from a projected query to every leaf of one tree.

    q_proj: (K,); leaf_lo/hi: (n_leaves, K); breakpoints: (K, Nr+1).
    Returns (lb, ub), each (n_leaves,).  Invalid leaves get lb = ub = +inf.
    """
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops
        return kops.leaf_bounds(q_proj, leaf_lo, leaf_hi, leaf_valid,
                                breakpoints,
                                interpret=(impl == "pallas_interpret"))
    # Coordinates of the leaf's bounding box edges.
    b_lo = _gather_edges(breakpoints, leaf_lo)                     # (n_leaves, K)
    b_hi = _gather_edges(breakpoints, leaf_hi + 1)
    d_lo = b_lo - q_proj[None, :]
    d_hi = q_proj[None, :] - b_hi
    lb_dim = jnp.maximum(jnp.maximum(d_lo, d_hi), 0.0)
    ub_dim = jnp.maximum(jnp.abs(q_proj[None, :] - b_lo),
                         jnp.abs(q_proj[None, :] - b_hi))
    lb = jnp.sqrt(jnp.sum(lb_dim * lb_dim, axis=1))
    ub = jnp.sqrt(jnp.sum(ub_dim * ub_dim, axis=1))
    inf = jnp.inf
    lb = jnp.where(leaf_valid, lb, inf)
    ub = jnp.where(leaf_valid, ub, inf)
    return lb, ub


def _gather_edges(breakpoints: jax.Array, idx: jax.Array) -> jax.Array:
    """breakpoints (K, Nr+1), idx (n_leaves, K) -> coords (n_leaves, K)."""
    E = breakpoints.shape[1]
    idx = jnp.clip(idx, 0, E - 1)
    return jax.vmap(lambda bp_k, i_k: bp_k[i_k], in_axes=(0, 1), out_axes=1)(
        breakpoints, idx)
