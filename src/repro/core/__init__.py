"""DET-LSH / PDET-LSH — the paper's primary contribution, in JAX.

High-level API (see ``repro.api`` for the protocol surface)::

    import repro
    spec = repro.api.IndexSpec(kind="static", K=16, c=1.5, L=4)
    index = repro.api.build(data, key, spec)
    res = index.search(queries, repro.api.SearchRequest(k=50))
    index.save("snap/"); index = repro.api.load("snap/")

Submodules: theory, hashing, encoding, detree, query, distributed,
det_attention.
"""

from __future__ import annotations

import dataclasses
import warnings

import jax
import numpy as np

from typing import Optional

from repro.core.theory import LSHParams, derive_params, SUCCESS_PROBABILITY
from repro.core import hashing, encoding, detree
from repro.core.detree import DEForest, build_forest
from repro.core.query import (FusedPlan, QueryConfig, QueryResult,
                              knn_query_batch, make_fused_plan)


def estimate_r_min(data: jax.Array, queries: jax.Array, k: int,
                   c: float, *, sample: int = 2048) -> float:
    """Pick the initial search radius (paper §V-B1, following PM-LSH [9]).

    Heuristic realization of the "magic r_min": estimate the k-NN distance
    scale on a subsample and start one c-step below it, so the first rounds
    neither trivially satisfy T1 nor waste many enlargements.
    """
    ns = min(sample, data.shape[0])
    nq = min(64, queries.shape[0])
    sub = np.asarray(data[:ns])
    qs = np.asarray(queries[:nq])
    d2 = ((qs[:, None, :] - sub[None, :, :]) ** 2).sum(-1)
    kth = np.sqrt(np.partition(d2, min(k, ns - 1), axis=1)[:, min(k, ns - 1)])
    r = float(np.median(kth))
    return max(r / (c * c), 1e-6)


@dataclasses.dataclass
class DETLSH:
    """A built DET-LSH index (single shard; see core.distributed for pods).

    Satisfies the ``repro.api.AnnIndex`` protocol: ``search`` is the typed
    query surface, ``save``/``repro.api.load`` the snapshot round-trip.
    """

    params: LSHParams
    A: jax.Array           # (d, L*K) projection matrix
    forest: DEForest
    data: jax.Array        # (n, d) — kept resident for exact rerank (paper §VI-C4)
    # The IndexSpec this index was built from (None for direct .build calls).
    spec: Optional["object"] = dataclasses.field(
        default=None, repr=False, compare=False)
    # Fused-engine constants (code-sorted points + inverse permutations),
    # built lazily once per index and reused across query batches.
    _plan: Optional[FusedPlan] = dataclasses.field(
        default=None, repr=False, compare=False)
    # Per-k cached r_min estimates: estimate_r_min is an O(nq*sample*d)
    # host-side numpy pass — once per (index, k), not once per batch.
    _r_min_cache: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    @classmethod
    def build(cls, data: jax.Array, key: jax.Array,
              params: LSHParams | None = None, *,
              Nr: int = encoding.DEFAULT_NR, leaf_size: int = 64,
              breakpoint_method: str = "sample_sort",
              project_impl: str = "auto",
              encode_impl: str = "auto",
              build_impl: str = "auto",
              build_chunk: int = 512) -> "DETLSH":
        """One-shot static build (Alg. 1 + 2).  ``build_impl`` /
        ``build_chunk`` select the fused single-sort build pipeline and its
        row-chunk size ('reference' = the seed per-tree double-argsort
        path; both produce bit-identical forests — docs/DESIGN.md §8)."""
        params = params or derive_params()
        d = data.shape[1]
        kp, kb = jax.random.split(key)
        A = hashing.sample_projections(kp, d, params.K, params.L)
        proj = hashing.project(data, A, impl=project_impl)
        forest = build_forest(proj, params.K, params.L, Nr=Nr,
                              leaf_size=leaf_size,
                              breakpoint_method=breakpoint_method, key=kb,
                              encode_impl=encode_impl,
                              build_impl=build_impl, build_chunk=build_chunk)
        return cls(params=params, A=A, forest=forest, data=data)

    @classmethod
    def from_spec(cls, data: jax.Array, key: jax.Array,
                  spec) -> "DETLSH":
        """Build from one declarative ``repro.api.IndexSpec``."""
        if spec.kind != "static":
            raise ValueError(f"DETLSH.from_spec needs kind='static', got "
                             f"{spec.kind!r} (use repro.api.build)")
        idx = cls.build(data, key, spec.derive_params(), Nr=spec.Nr,
                        leaf_size=spec.leaf_size,
                        breakpoint_method=spec.breakpoint_method,
                        project_impl=spec.project_impl,
                        encode_impl=spec.encode_impl,
                        build_impl=spec.build_impl,
                        build_chunk=spec.build_chunk)
        idx.spec = spec
        return idx

    @property
    def n_points(self) -> int:
        return int(self.data.shape[0])

    def fused_plan(self) -> FusedPlan:
        if self._plan is None:
            self._plan = make_fused_plan(self.data, self.forest)
        return self._plan

    def r_min_for(self, k: int, queries: jax.Array | None = None) -> float:
        """Cached per-(index, k) starting radius.

        ``estimate_r_min`` is an O(nq·sample·d) host-side numpy pass; it
        now runs once per (index, k) — on the first ``r_min=None`` search,
        estimated from that batch's queries (the paper's PM-LSH heuristic)
        — and every later search with the same k reuses the cached value
        for free.  With no queries yet seen for this k, data rows stand in
        as probes.  Any estimate only shifts the starting radius; the
        c²-guarantee holds for every r_min (docs/DESIGN.md §6).
        """
        if k not in self._r_min_cache:
            probes = (queries if queries is not None
                      else self.data[: min(64, self.data.shape[0])])
            self._r_min_cache[k] = estimate_r_min(self.data, probes, k,
                                                  self.params.c)
        return self._r_min_cache[k]

    def search(self, queries: jax.Array, request=None):
        """Typed batched search (``repro.api.SearchRequest`` in,
        ``repro.api.SearchResult`` out).  Trace-compatible when the
        request carries an explicit ``r_min``."""
        from repro.api import registry
        from repro.api.request import SearchRequest, SearchResult, \
            SearchStats
        req = request or SearchRequest()
        r_min, cached = req.r_min, False
        if r_min is None:
            cached = req.k in self._r_min_cache    # hit vs first estimate
            # Zero-vector pad lanes must not skew the cached estimate
            # (n_active == 0 keeps the full batch: no real lanes to probe).
            probes = queries[: req.n_active] if req.n_active else queries
            r_min = self.r_min_for(req.k, probes)
        spec = self.spec
        default_engine = spec.engine if spec is not None else "auto"
        cfg = req.to_query_config(
            default_engine=default_engine, r_min=r_min,
            block_q=spec.block_q if spec is not None else 8,
            block_l=spec.block_l if spec is not None else 8,
            default_probe_depth=spec.probe_depth if spec is not None else 0)
        engine = registry.resolve_engine(cfg.engine, mode=cfg.mode,
                                         batch=queries.shape[0])
        plan = self.fused_plan() if engine == "fused" else None
        res = knn_query_batch(self.data, self.forest, self.A, self.params,
                              queries, cfg, plan=plan, n_active=req.n_active)
        return SearchResult(
            ids=res.ids, dists=res.dists,
            stats=SearchStats(engine=engine, r_min=float(r_min),
                              r_min_cached=cached, rounds=res.rounds,
                              n_candidates=res.n_candidates,
                              final_r=res.final_r,
                              probed_leaves=res.probed_leaves,
                              probe_candidates=res.probe_candidates),
            raw=res)

    def query(self, queries: jax.Array, k: int = 50, *,
              r_min: float | None = None, M: int = 8,
              mode: str = "leaf", max_rounds: int = 48,
              engine: str = "auto",
              n_active: int | None = None) -> QueryResult:
        """Deprecated kwarg surface — use ``search(queries,
        repro.api.SearchRequest(...))``.  Kept as a thin shim for the
        seed-era callers; returns the engine-level ``QueryResult``."""
        warnings.warn(
            "DETLSH.query(**kwargs) is deprecated; use "
            "DETLSH.search(queries, repro.api.SearchRequest(...))",
            DeprecationWarning, stacklevel=2)
        from repro.api.request import SearchRequest
        req = SearchRequest(k=k, r_min=r_min, M=M, mode=mode,
                            max_rounds=max_rounds, engine=engine,
                            n_active=n_active)
        return self.search(queries, req).raw

    def save(self, path) -> None:
        """Write a versioned snapshot directory (``repro.api.load``)."""
        from repro.api import persist
        persist.save_static(self, path)

    def index_size_bytes(self) -> int:
        return self.forest.size_bytes() + self.A.size * 4


__all__ = [
    "DETLSH", "DEForest", "FusedPlan", "LSHParams", "QueryConfig",
    "QueryResult", "derive_params", "build_forest", "knn_query_batch",
    "make_fused_plan", "estimate_r_min", "SUCCESS_PROBABILITY",
]
