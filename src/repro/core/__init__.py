"""DET-LSH / PDET-LSH — the paper's primary contribution, in JAX.

High-level API::

    from repro.core import DETLSH, derive_params
    index = DETLSH.build(data, key, params=derive_params(K=16, c=1.5, L=4))
    res = index.query(queries, k=50)

Submodules: theory, hashing, encoding, detree, query, distributed,
det_attention.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from typing import Optional

from repro.core.theory import LSHParams, derive_params, SUCCESS_PROBABILITY
from repro.core import hashing, encoding, detree, query as query_mod
from repro.core.detree import DEForest, build_forest
from repro.core.query import (FusedPlan, QueryConfig, QueryResult,
                              knn_query_batch, make_fused_plan)


def estimate_r_min(data: jax.Array, queries: jax.Array, k: int,
                   c: float, *, sample: int = 2048) -> float:
    """Pick the initial search radius (paper §V-B1, following PM-LSH [9]).

    Heuristic realization of the "magic r_min": estimate the k-NN distance
    scale on a subsample and start one c-step below it, so the first rounds
    neither trivially satisfy T1 nor waste many enlargements.
    """
    ns = min(sample, data.shape[0])
    nq = min(64, queries.shape[0])
    sub = np.asarray(data[:ns])
    qs = np.asarray(queries[:nq])
    d2 = ((qs[:, None, :] - sub[None, :, :]) ** 2).sum(-1)
    kth = np.sqrt(np.partition(d2, min(k, ns - 1), axis=1)[:, min(k, ns - 1)])
    r = float(np.median(kth))
    return max(r / (c * c), 1e-6)


@dataclasses.dataclass
class DETLSH:
    """A built DET-LSH index (single shard; see core.distributed for pods)."""

    params: LSHParams
    A: jax.Array           # (d, L*K) projection matrix
    forest: DEForest
    data: jax.Array        # (n, d) — kept resident for exact rerank (paper §VI-C4)
    # Fused-engine constants (code-sorted points + inverse permutations),
    # built lazily once per index and reused across query batches.
    _plan: Optional[FusedPlan] = dataclasses.field(
        default=None, repr=False, compare=False)

    @classmethod
    def build(cls, data: jax.Array, key: jax.Array,
              params: LSHParams | None = None, *,
              Nr: int = encoding.DEFAULT_NR, leaf_size: int = 64,
              breakpoint_method: str = "sample_sort",
              project_impl: str = "auto",
              encode_impl: str = "auto") -> "DETLSH":
        params = params or derive_params()
        d = data.shape[1]
        kp, kb = jax.random.split(key)
        A = hashing.sample_projections(kp, d, params.K, params.L)
        proj = hashing.project(data, A, impl=project_impl)
        forest = build_forest(proj, params.K, params.L, Nr=Nr,
                              leaf_size=leaf_size,
                              breakpoint_method=breakpoint_method, key=kb,
                              encode_impl=encode_impl)
        return cls(params=params, A=A, forest=forest, data=data)

    def fused_plan(self) -> FusedPlan:
        if self._plan is None:
            self._plan = make_fused_plan(self.data, self.forest)
        return self._plan

    def query(self, queries: jax.Array, k: int = 50, *,
              r_min: float | None = None, M: int = 8,
              mode: str = "leaf", max_rounds: int = 48,
              engine: str = "auto",
              n_active: int | None = None) -> QueryResult:
        """``n_active``: number of leading real lanes in a padded batch —
        trailing pad lanes are marked done from round 0 and cost ~nothing."""
        if r_min is None:
            r_min = estimate_r_min(self.data, queries, k, self.params.c)
        cfg = QueryConfig(k=k, M=M, r_min=r_min, mode=mode,
                          max_rounds=max_rounds, engine=engine)
        engine_used = query_mod._pick_engine(cfg, queries.shape[0])
        plan = self.fused_plan() if engine_used == "fused" else None
        return knn_query_batch(self.data, self.forest, self.A, self.params,
                               queries, cfg, plan=plan, n_active=n_active)

    def index_size_bytes(self) -> int:
        return self.forest.size_bytes() + self.A.size * 4


__all__ = [
    "DETLSH", "DEForest", "FusedPlan", "LSHParams", "QueryConfig",
    "QueryResult", "derive_params", "build_forest", "knn_query_batch",
    "make_fused_plan", "estimate_r_min", "SUCCESS_PROBABILITY",
]
