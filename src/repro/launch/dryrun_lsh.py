import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])
os.environ.setdefault("JAX_PLATFORMS", "cpu")

"""PDET-LSH dry-run: lower + compile the paper's own workload (distributed
index build and batched c^2-k-ANN query) on the production meshes.

Scenario sized for a 500M-point deployment (Table II scale: SPACEV500M,
d=100) sharded over the (pod,) data axes; queries replicated; candidate
rerank local to each shard; global top-k merge.

  PYTHONPATH=src python -m repro.launch.dryrun_lsh --mesh both
"""

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.api import SearchRequest
from repro.core.distributed import build_pdet, query_pdet, PDETLSH, DEForest
from repro.core.theory import derive_params
from repro.launch.dryrun import _cost_record, _mem_record, collective_bytes
from repro.launch.mesh import make_mesh, make_production_mesh


def run(mesh, mesh_tag, n=500_000_000, d=100, nq=64, k=50):
    from jax.sharding import NamedSharding, PartitionSpec as P
    params = derive_params(K=4, c=1.5, L=16, beta_override=0.1)
    # the index shards over every mesh axis (pure data-parallel
    # storage; the model axis would otherwise idle)
    axes = tuple(mesh.shape.keys())
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    n = (n // n_shards) * n_shards

    data_sds = jax.ShapeDtypeStruct((n, d), jnp.float32)
    data_sh = NamedSharding(mesh, P(axes))
    key_sds = jax.ShapeDtypeStruct((), jnp.uint32)

    rec = {"workload": "pdet_build", "mesh": mesh_tag, "n": n, "d": d,
           "devices": int(mesh.size)}
    t0 = time.time()

    def build_step(data):
        idx = build_pdet(data, jax.random.key(0), params, mesh, axes=axes,
                         leaf_size=256, bp_rounds=8)
        return (idx.forest.point_ids, idx.forest.leaf_lo,
                idx.forest.leaf_hi, idx.forest.breakpoints)

    lowered = jax.jit(build_step, in_shardings=(data_sh,)).lower(data_sds)
    compiled = lowered.compile()
    rec["lower_compile_s"] = round(time.time() - t0, 2)
    rec["memory"] = _mem_record(compiled)
    rec["cost"] = _cost_record(compiled)
    rec["collectives"] = collective_bytes(compiled.as_text())
    yield rec

    # query step: abstract index pieces with build-output shardings
    forest_specs = dict(point_ids=P(None, axes),
                        proj_sorted=P(None, axes, None),
                        codes_sorted=P(None, axes, None),
                        valid=P(None, axes), leaf_lo=P(None, axes, None),
                        leaf_hi=P(None, axes, None),
                        leaf_valid=P(None, axes), breakpoints=P())

    rec2 = {"workload": "pdet_query", "mesh": mesh_tag, "n": n, "d": d,
            "nq": nq, "k": k, "devices": int(mesh.size)}
    # Typed request surface; the PDET query step consumes the lowered
    # engine-level config (the shard_map path predates the registry).
    cfg = SearchRequest(k=k, M=8, r_min=1.0,
                        max_rounds=16).to_query_config()
    n_local = n // n_shards
    leaf_size = 256
    n_leaves = -(-n_local // leaf_size)
    n_pad = n_leaves * leaf_size
    sds = jax.ShapeDtypeStruct
    K, L = params.K, params.L
    # Storage dtypes must match what the build now emits (detree's narrow
    # layout: uint8 codes, int16 bounds) or the query executable's input
    # signature — and the memory model — drift from the real index.
    from repro.core.detree import CODE_DTYPE, LEAF_DTYPE
    forest_sds = DEForest(
        point_ids=sds((L, n_shards * n_pad), jnp.int32),
        proj_sorted=sds((L, n_shards * n_pad, K), jnp.float32),
        codes_sorted=sds((L, n_shards * n_pad, K), CODE_DTYPE),
        valid=sds((L, n_shards * n_pad), jnp.bool_),
        leaf_lo=sds((L, n_shards * n_leaves, K), LEAF_DTYPE),
        leaf_hi=sds((L, n_shards * n_leaves, K), LEAF_DTYPE),
        leaf_valid=sds((L, n_shards * n_leaves), jnp.bool_),
        breakpoints=sds((L, K, 257), jnp.float32),
        n=n_local, leaf_size=leaf_size)
    q_sds = sds((nq, d), jnp.float32)

    t0 = time.time()

    def query_step(data, forest, A, queries):
        idx = PDETLSH(params=params, A=A, forest=forest, data=data,
                      mesh=mesh, axes=axes, n_global=n)
        return query_pdet(idx, queries, cfg)

    f_sh = jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), forest_specs)
    forest_sh = DEForest(n=n_local, leaf_size=leaf_size, **f_sh)
    lowered = jax.jit(query_step,
                      in_shardings=(data_sh, forest_sh,
                                    NamedSharding(mesh, P()),
                                    NamedSharding(mesh, P()))).lower(
        data_sds, forest_sds, sds((d, L * K), jnp.float32), q_sds)
    compiled = lowered.compile()
    rec2["lower_compile_s"] = round(time.time() - t0, 2)
    rec2["memory"] = _mem_record(compiled)
    rec2["cost"] = _cost_record(compiled)
    rec2["collectives"] = collective_bytes(compiled.as_text())
    yield rec2


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both", "custom"])
    ap.add_argument("--mesh-shape", default="",
                    help="custom mesh, e.g. '4,2:data,model'")
    ap.add_argument("--n", type=int, default=500_000_000)
    ap.add_argument("--out", default="experiments/dryrun_lsh.json")
    args = ap.parse_args(argv)

    meshes = []
    if args.mesh == "custom":
        shp, axs = args.mesh_shape.split(":")
        meshes.append((f"custom_{shp}",
                       make_mesh([int(x) for x in shp.split(",")],
                                 axs.split(","))))
    if args.mesh in ("single", "both"):
        meshes.append(("pod_16x16", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multipod_2x16x16",
                       make_production_mesh(multi_pod=True)))
    results = []
    for tag, mesh in meshes:
        for rec in run(mesh, tag, n=args.n):
            print(f"=== {rec['workload']} x {tag}: "
                  f"{rec['memory']['live_bytes'] / 2**30:.1f} GiB/device, "
                  f"compile {rec['lower_compile_s']}s", flush=True)
            results.append(rec)
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    print("pdet-lsh dry-run complete")
    return 0


if __name__ == "__main__":
    sys.exit(main())
