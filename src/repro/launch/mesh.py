"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state.  The production target is TPU v5e:
  * single pod : 16 x 16  = 256 chips, axes ('data', 'model')
  * multi-pod  : 2 x 16 x 16 = 512 chips, axes ('pod', 'data', 'model')
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit-sharding axis types
    from jax.sharding import AxisType

    def _axis_kw(n_axes: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n_axes}
except ImportError:  # older jax: every mesh axis is implicitly Auto
    def _axis_kw(n_axes: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / reduced dry-runs)."""
    return jax.make_mesh(tuple(shape), tuple(axes), **_axis_kw(len(axes)))


def mesh_from_placement(placement, *, devices=None):
    """Build the device mesh a ``repro.api.PlacementSpec`` names.

    Uses the first ``placement.n_devices`` of ``devices`` (default: all
    local devices) so a placement smaller than the machine still works —
    e.g. loading a 2-shard snapshot on a 4-device host.  Raises with the
    ``--xla_force_host_platform_device_count`` hint when the machine has
    too few devices, since that is the usual CPU-test fix.
    """
    import numpy as np
    from jax.sharding import Mesh

    devices = list(jax.devices() if devices is None else devices)
    need = placement.n_devices
    if len(devices) < need:
        raise ValueError(
            f"placement {placement.mesh_shape} over {placement.mesh_axes} "
            f"needs {need} devices but only {len(devices)} are available; "
            f"shrink the placement or force a host-device mesh with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
    grid = np.array(devices[:need]).reshape(placement.mesh_shape)
    return Mesh(grid, placement.mesh_axes)


# TPU v5e hardware constants (roofline targets; see EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (intra-pod)
HBM_BYTES = 16 * 1024 ** 3      # 16 GiB per chip
