"""Training driver: config -> mesh -> sharded train loop, fault-tolerant.

Features exercised end-to-end (reduced scale on CPU; production mesh via
--mesh single/multi on a real pod):

  * auto-resume from the newest committed checkpoint (crash-safe commits),
  * periodic checkpointing + garbage collection,
  * deterministic (seed, step)-keyed data pipeline (resume is exact),
  * elastic restarts: --mesh may differ across runs; restore re-shards,
  * per-step timeout watchdog (straggler/hang mitigation: on a real
    cluster this aborts the step so the scheduler can reassign hosts).

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --steps 30 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt --ckpt-every 10
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import signal
import sys
import time

import jax

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import make_pipeline
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.launch.specs import apply_mesh_padding
from repro.models import transformer as T
from repro.sharding.rules import ShardingRules, param_shardings, use_rules
from repro.train import checkpoint as ckpt
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step


class StepWatchdog:
    """SIGALRM-based per-step timeout (straggler/hang mitigation hook)."""

    def __init__(self, timeout_s: float | None):
        self.timeout_s = timeout_s

    def __enter__(self):
        if self.timeout_s:
            def handler(signum, frame):
                raise TimeoutError(
                    f"step exceeded {self.timeout_s}s — aborting for "
                    "reschedule (straggler mitigation)")
            self._prev = signal.signal(signal.SIGALRM, handler)
            signal.setitimer(signal.ITIMER_REAL, self.timeout_s)
        return self

    def __exit__(self, *exc):
        if self.timeout_s:
            signal.setitimer(signal.ITIMER_REAL, 0)
            signal.signal(signal.SIGALRM, self._prev)
        return False


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--step-timeout", type=float, default=0.0)
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.mesh == "host":
        n = jax.device_count()
        mesh = make_mesh((n, 1), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    rules = ShardingRules(mesh, {
        "residual_seq": "model" if cfg.parallel.seq_parallel else None})
    cfg = apply_mesh_padding(cfg, rules)
    cfg = dataclasses.replace(cfg, parallel=dataclasses.replace(
        cfg.parallel, accum_steps=1))

    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    pipe = make_pipeline(cfg, shape, seed=args.seed)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=5,
                          total_steps=max(args.steps, 10),
                          state_dtype=cfg.parallel.opt_state_dtype)

    with use_rules(rules), mesh:
        params = T.init_params(cfg, jax.random.key(args.seed))
        opt_state = adamw_init(params, opt_cfg)
        p_sh = param_shardings(rules, params)
        step_fn = make_train_step(cfg, opt_cfg, grad_shardings=p_sh)
        o_sh = param_shardings(rules, opt_state)
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(opt_state, o_sh)

        start = 0
        if args.ckpt_dir:
            latest = ckpt.latest_step(args.ckpt_dir)
            if latest is not None:
                (params, opt_state), extra = ckpt.restore(
                    args.ckpt_dir, latest, (params, opt_state),
                    shardings=(p_sh, o_sh))
                start = int(extra.get("next_step", latest))
                print(f"[train] resumed from step {latest} "
                      f"(next_step={start})", flush=True)

        jit_step = jax.jit(step_fn, in_shardings=(p_sh, o_sh, None),
                           out_shardings=(p_sh, o_sh, None),
                           donate_argnums=(0, 1))

        history = []
        for step in range(start, args.steps):
            batch = pipe.batch_at(step)
            t0 = time.time()
            with StepWatchdog(args.step_timeout or None):
                params, opt_state, metrics = jit_step(params, opt_state,
                                                      batch)
                loss = float(metrics["loss"])
            dt = time.time() - t0
            history.append({"step": step, "loss": loss, "sec": dt})
            print(f"[train] step={step} loss={loss:.4f} "
                  f"({dt * 1e3:.0f} ms)", flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                ckpt.save(args.ckpt_dir, step + 1, (params, opt_state),
                          extra={"next_step": step + 1,
                                 "arch": args.arch, "seed": args.seed})
                ckpt.garbage_collect(args.ckpt_dir, keep=args.keep)

        if args.ckpt_dir:
            ckpt.save(args.ckpt_dir, args.steps, (params, opt_state),
                      extra={"next_step": args.steps, "arch": args.arch,
                             "seed": args.seed})
        if args.metrics_out:
            with open(args.metrics_out, "w") as f:
                json.dump(history, f)
        if len(history) >= 5:
            first = sum(h["loss"] for h in history[:3]) / 3
            last = sum(h["loss"] for h in history[-3:]) / 3
            print(f"[train] loss {first:.4f} -> {last:.4f}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
