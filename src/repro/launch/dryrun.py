import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (dry-run only) reduced-device override for CI/tests — must happen before
# jax initializes, hence before any other import.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])
os.environ.setdefault("JAX_PLATFORMS", "cpu")

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape x mesh) cell this lowers + compiles
the appropriate step (train_step / prefill_step / decode_step) against
ShapeDtypeStruct inputs (no allocation), records

  * memory_analysis()      — per-device bytes (args/outputs/temps/aliased),
  * cost_analysis()        — per-device HLO FLOPs & bytes accessed
                             (NOTE: XLA counts while-loop bodies ONCE; the
                             roofline derivation corrects for trip counts),
  * the collective schedule — per-kind byte totals parsed from the compiled
    HLO, split into top-level vs while-body (body collectives execute
    layers x accum times; see benchmarks/roofline.py),

and writes one JSON record per cell (incremental; --skip-existing resumes).

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out experiments/dryrun.json
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.configs import get_config, list_archs
from repro.configs.base import ALL_SHAPES
from repro.launch.mesh import (HBM_BYTES, make_mesh, make_production_mesh)
from repro.launch.specs import (apply_mesh_padding, batch_shardings)
from repro.sharding.rules import ShardingRules, param_shardings, use_rules
from repro.train.train_step import (abstract_opt_state, abstract_params,
                                    batch_specs, make_decode_step,
                                    make_prefill_step, make_train_step)

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(ty: str) -> int:
    m = re.match(r"(\w+)\[([0-9,]*)\]", ty)
    if not m:
        return 0
    dt, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dt, 4)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def collective_bytes(hlo_text: str) -> dict:
    """Per-kind operand bytes, split by top-level ('entry') vs while bodies.

    HLO computations appear as '%name (args) -> ty {' blocks; collectives
    inside non-entry computations are (conservatively) attributed to loop
    bodies.  Operand types are parsed from the call parentheses.
    """
    out = {k: {"entry": 0, "body": 0} for k in _COLLECTIVES}
    current = "entry"
    is_entry = True
    for line in hlo_text.splitlines():
        mm = re.match(r"\s*(ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->.*\{", line)
        if mm:
            is_entry = bool(mm.group(1))
            current = mm.group(2)
            continue
        for kind in _COLLECTIVES:
            # matches: %x = ty kind(ty %a, ty %b), ...  (incl. -start ops)
            m = re.search(kind + r"(?:-start)?\(([^)]*)\)", line)
            if m and ("=" in line):
                ops = re.findall(r"\w+\[[0-9,]*\]", m.group(1))
                nbytes = sum(_shape_bytes(t) for t in ops)
                if nbytes == 0:
                    # operand types not printed: fall back to result type
                    res = re.search(r"=\s*\(?([\w]+\[[0-9,]*\])", line)
                    if res:
                        nbytes = _shape_bytes(res.group(1))
                out[kind]["entry" if is_entry else "body"] += nbytes
    return out


def _mem_record(compiled) -> dict:
    ma = compiled.memory_analysis()
    rec = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        rec[f] = int(getattr(ma, f, 0) or 0)
    rec["live_bytes"] = (rec["argument_size_in_bytes"]
                         + rec["output_size_in_bytes"]
                         + rec["temp_size_in_bytes"]
                         - rec["alias_size_in_bytes"])
    rec["fits_16GiB"] = rec["live_bytes"] <= HBM_BYTES
    return rec


def _cost_dict(compiled) -> dict:
    """compiled.cost_analysis() returns a dict on current jax, a one-element
    list of dicts on older versions — normalize to a dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def _cost_record(compiled) -> dict:
    ca = _cost_dict(compiled)
    return {"hlo_flops_once": float(ca.get("flops", 0.0)),
            "hlo_bytes_once": float(ca.get("bytes accessed", 0.0))}


def run_cell(arch: str, shape_name: str, mesh, mesh_tag: str) -> dict:
    shape = ALL_SHAPES[shape_name]
    cfg0 = get_config(arch)
    rules = ShardingRules(mesh, {
        "residual_seq": "model" if cfg0.parallel.seq_parallel else None})
    cfg = apply_mesh_padding(cfg0, rules)
    rec = {"arch": arch, "shape": shape_name, "kind": shape.kind,
           "mesh": mesh_tag, "devices": int(mesh.size),
           "padded_heads": cfg.n_heads != cfg0.n_heads,
           "n_heads": cfg.n_heads, "vocab_size": cfg.vocab_size}

    t0 = time.time()
    with use_rules(rules), mesh:
        params_sds = abstract_params(cfg)
        p_sh = param_shardings(rules, params_sds)
        if shape.kind == "train":
            step = make_train_step(cfg, grad_shardings=p_sh)
            opt_sds = abstract_opt_state(cfg)
            o_sh = param_shardings(rules, opt_sds)
            b_sds = batch_specs(cfg, shape)
            b_sh = batch_shardings(rules, b_sds)
            fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
            lowered = fn.lower(params_sds, opt_sds, b_sds)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg)
            b_sds = batch_specs(cfg, shape)
            b_sh = batch_shardings(rules, b_sds)
            out_sds = jax.eval_shape(step, params_sds, b_sds)
            out_sh = (None, batch_shardings(rules, out_sds[1]), None)
            fn = jax.jit(step, in_shardings=(p_sh, b_sh),
                         out_shardings=out_sh)
            lowered = fn.lower(params_sds, b_sds)
        else:  # decode
            step = make_decode_step(cfg)
            d_sds = batch_specs(cfg, shape)
            tok_sh = batch_shardings(rules, d_sds["token"])
            cache_sh = batch_shardings(rules, d_sds["cache"])
            fn = jax.jit(step,
                         in_shardings=(p_sh, tok_sh, cache_sh, None),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(2,))
            lowered = fn.lower(params_sds, d_sds["token"], d_sds["cache"],
                               d_sds["length"])
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    rec["memory"] = _mem_record(compiled)
    rec["cost"] = _cost_record(compiled)
    rec["collectives"] = collective_bytes(compiled.as_text())
    print(compiled.memory_analysis())
    ca = _cost_dict(compiled)
    print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})
    return rec


def cells_for(arch: str):
    cfg = get_config(arch)
    return list(cfg.shape_names)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both", "custom"])
    ap.add_argument("--mesh-shape", default="",
                    help="custom mesh, e.g. '4,2:data,model'")
    ap.add_argument("--out", default="experiments/dryrun.json")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    meshes = []
    if args.mesh == "custom":
        shp, axs = args.mesh_shape.split(":")
        meshes.append((f"custom_{shp}",
                       make_mesh([int(x) for x in shp.split(",")],
                                 axs.split(","))))
    else:
        if args.mesh in ("single", "both"):
            meshes.append(("pod_16x16", make_production_mesh()))
        if args.mesh in ("multi", "both"):
            meshes.append(("multipod_2x16x16",
                           make_production_mesh(multi_pod=True)))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    # always merge into an existing results file (reruns replace their own
    # cells); --skip-existing additionally skips cells already done OK
    results = []
    if os.path.exists(args.out):
        results = json.load(open(args.out))
    done = set()
    if args.skip_existing:
        done = {(r["arch"], r["shape"], r["mesh"]) for r in results
                if "error" not in r}

    n_fail = 0
    for mesh_tag, mesh in meshes:
        for arch in archs:
            shapes = (cells_for(arch) if args.shape == "all"
                      else args.shape.split(","))
            for shape_name in shapes:
                if shape_name not in cells_for(arch):
                    continue
                key = (arch, shape_name, mesh_tag)
                if key in done:
                    continue
                print(f"=== {arch} x {shape_name} x {mesh_tag} ===",
                      flush=True)
                try:
                    rec = run_cell(arch, shape_name, mesh, mesh_tag)
                except Exception as e:
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_tag, "error": str(e)[:2000]}
                    n_fail += 1
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"]) != key]
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                print(f"--- done ({rec.get('compile_s', '?')}s compile, "
                      f"err={'error' in rec})", flush=True)
    print(f"dry-run complete: {len(results)} cells, {n_fail} failures")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
