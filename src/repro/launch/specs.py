"""Input sharding specs + mesh-divisibility padding for the launchers."""

from __future__ import annotations

import dataclasses

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.sharding.rules import ShardingRules


def apply_mesh_padding(cfg: ModelConfig, rules: ShardingRules) -> ModelConfig:
    """Pad vocab (to 128-multiples) and q-heads (to model-axis multiples)
    for sharding divisibility.  Megatron-style; padded vocab logits are
    masked via ``vocab_real``; padded heads are real (zero-init extra) —
    both recorded so the roofline can report padding overhead."""
    model_size = rules.axis_size(rules.rules.get("heads"))
    changes = {}
    v = cfg.vocab_size
    vpad = -(-v // 128) * 128
    if vpad != v:
        changes["vocab_size"] = vpad
        changes["vocab_real"] = v
    h = cfg.n_heads
    if model_size > 1 and h >= model_size and h % model_size != 0:
        h_pad = -(-h // model_size) * model_size
        changes["n_heads"] = h_pad
        # GQA grouping requires h' % hk' == 0: lift kv heads to the smallest
        # divisor of h' that is >= hk (qwen1.5: 40->48 with kv 40->48;
        # hymba: 25->32 with kv 5->8).  KV padding costs cache bytes and is
        # reported in the roofline as padding overhead.
        hk = cfg.n_kv_heads
        if h_pad % hk != 0:
            hk_pad = next(c for c in range(hk, h_pad + 1) if h_pad % c == 0)
            changes["n_kv_heads"] = hk_pad
    if changes:
        cfg = dataclasses.replace(cfg, **changes)
    return cfg


_BATCH_SPECS = {
    "tokens": ("batch", None),
    "labels": ("batch", None),
    "frames": ("batch", None, None),
    "patches": ("batch", None, None),
    "token": ("batch", None),
    "length": (),
}

_CACHE_SPECS = {
    # (layers, b, s, hk, dh)
    "k": (None, "batch", "kv_seq", "kv_heads", None),
    "v": (None, "batch", "kv_seq", "kv_heads", None),
    "mem_k": (None, "batch", None, "kv_heads", None),
    "mem_v": (None, "batch", None, "kv_heads", None),
    # vlm: (blocks, n_self, b, s, hk, dh)
    "vis_k": (None, "batch", None, "kv_heads", None),
    "vis_v": (None, "batch", None, "kv_heads", None),
    # ssm states
    "conv": (None, "batch", None, "ssm_inner"),
    "ssm": (None, "batch", None, None, None),
}


def _names_for(key: str, leaf) -> tuple:
    if key in _CACHE_SPECS:
        names = _CACHE_SPECS[key]
        if leaf.ndim == len(names) + 1:      # vlm adds a leading block dim
            names = (None,) + names
        return names
    if key in _BATCH_SPECS:
        return _BATCH_SPECS[key]
    return (None,) * leaf.ndim


def batch_shardings(rules: ShardingRules, specs) -> object:
    """NamedShardings for a batch/cache spec pytree (dict-keyed)."""
    def resolve(path, leaf):
        key = None
        for pp in reversed(path):
            k = getattr(pp, "key", None)
            if isinstance(k, str):
                key = k
                break
        names = _names_for(key, leaf) if key else (None,) * leaf.ndim
        if len(names) != leaf.ndim:
            names = (None,) * leaf.ndim
        return NamedSharding(rules.mesh, rules.spec(names, leaf.shape))

    return jax.tree_util.tree_map_with_path(resolve, specs)


def replicated(rules: ShardingRules, tree):
    return jax.tree.map(
        lambda _: NamedSharding(rules.mesh, P()), tree)
