"""Fault injection for the serving runtime (docs/DESIGN.md §9).

A ``FaultPlan`` arms failures at the three boundaries where a production
ANN service actually breaks, so tests (and the example driver) can prove
the recovery paths instead of asserting them:

  * ``ENGINE_CALL``      — fired by the runtime immediately before every
    engine dispatch (including the retry dispatch, so ``times=2`` models a
    persistently failing engine).
  * ``COMPACTION_SWAP``  — fired by ``Manifest.swap`` *before* any
    mutation (the runtime installs the hook), so an armed fault models a
    compaction crashing mid-install: the manifest — and every pinned
    epoch — must come through untouched.
  * ``SNAPSHOT_LOAD``    — fired by ``repro.api.persist.load`` on entry
    while the plan is installed there (``installed_on_load``), modelling
    an unreadable snapshot store.

Durability sites (docs/DESIGN.md §13 — the crash-point injection matrix):

  * ``WAL_APPEND``       — fired by ``durability.WriteAheadLog.append``
    *before* any byte is written, so a crashed append was never logged.
  * ``WAL_FSYNC``        — fired before each ``os.fsync`` of the log; the
    record is already written + flushed, so it survives the crash.
  * ``SNAPSHOT_WRITE``   — fired by ``persist._publish_snapshot`` once per
    staged file while ``installed_on_save`` holds the plan, before the
    file's bytes are written.
  * ``CHECKPOINT_INSTALL`` — fired by ``DurableIndex.checkpoint`` twice:
    before publishing the snapshot and before the WAL commit record
    (``arm(..., skip=1)`` targets the second crossing).

The plan is deliberately deterministic: ``arm(site, times=n)`` makes the
next ``n`` fires at that site raise ``InjectedFault`` and every fire
(raising or not) is counted in ``fired``, so a test can assert both that
the fault happened and that the runtime's recovery consumed it.  Arming
an unknown site raises ``ValueError`` naming the valid set — a typo'd
site must fail the test loudly, not silently never fire.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Type

ENGINE_CALL = "engine_call"
COMPACTION_SWAP = "compaction_swap"
SNAPSHOT_LOAD = "snapshot_load"
WAL_APPEND = "wal_append"
WAL_FSYNC = "wal_fsync"
SNAPSHOT_WRITE = "snapshot_write"
CHECKPOINT_INSTALL = "checkpoint_install"

SITES = (ENGINE_CALL, COMPACTION_SWAP, SNAPSHOT_LOAD, WAL_APPEND,
         WAL_FSYNC, SNAPSHOT_WRITE, CHECKPOINT_INSTALL)


class InjectedFault(RuntimeError):
    """An armed ``FaultPlan`` fault fired at a runtime boundary."""

    def __init__(self, site: str, detail: str = ""):
        self.site = site
        self.detail = detail
        super().__init__(f"injected fault at {site}"
                         + (f" ({detail})" if detail else ""))


class FaultPlan:
    """Deterministic fault schedule over the runtime's injection sites."""

    def __init__(self):
        self._lock = threading.Lock()
        self._armed: Dict[str, int] = {}
        self._skip: Dict[str, int] = {}
        self._exc: Dict[str, Type[BaseException]] = {}
        # every fire() call per site, whether or not it raised — the
        # "did the boundary actually get exercised" observability counter
        self.fired: Dict[str, int] = {s: 0 for s in SITES}
        self.raised: Dict[str, int] = {s: 0 for s in SITES}

    def _check_site(self, site: str) -> None:
        if site not in SITES:
            raise ValueError(f"unknown fault site {site!r}; valid: {SITES}")

    def arm(self, site: str, times: int = 1, skip: int = 0,
            exc: Optional[Type[BaseException]] = None) -> "FaultPlan":
        """Make the next ``times`` fires at ``site`` raise (chainable).

        ``times`` counts *crossings of that one site*, not operations —
        sites nested inside a larger op consume one charge per crossing.
        Concretely: ``times=2`` on ENGINE_CALL spans the original dispatch
        and its vmap retry; one checkpoint crosses CHECKPOINT_INSTALL
        twice (publish, then commit) and SNAPSHOT_WRITE once per staged
        file; one multi-record flush crosses WAL_APPEND once per record.

        ``skip`` lets the first ``skip`` crossings through unharmed before
        the armed charges start raising — ``arm(CHECKPOINT_INSTALL,
        skip=1)`` crashes the commit crossing while letting the publish
        crossing pass, and ``skip=k`` on WAL_APPEND kills the (k+1)-th
        logged op of an interleaving.  Skips are only consumed while the
        site is armed.
        """
        self._check_site(site)
        if times < 1:
            raise ValueError(f"times must be >= 1, got {times}")
        if skip < 0:
            raise ValueError(f"skip must be >= 0, got {skip}")
        with self._lock:
            self._armed[site] = self._armed.get(site, 0) + int(times)
            if skip:
                self._skip[site] = self._skip.get(site, 0) + int(skip)
            if exc is not None:
                self._exc[site] = exc
        return self

    def armed(self, site: str) -> int:
        """How many future fires at ``site`` will still raise."""
        self._check_site(site)
        with self._lock:
            return self._armed.get(site, 0)

    def fire(self, site: str, detail: str = "") -> None:
        """Cross the boundary: raises iff the site is armed (consuming one
        armed charge); always counts the crossing."""
        self._check_site(site)
        with self._lock:
            self.fired[site] += 1
            remaining = self._armed.get(site, 0)
            if remaining <= 0:
                return
            if self._skip.get(site, 0) > 0:
                self._skip[site] -= 1
                return
            self._armed[site] = remaining - 1
            self.raised[site] += 1
            exc = self._exc.get(site, InjectedFault)
        if exc is InjectedFault:
            raise InjectedFault(site, detail)
        raise exc(f"injected fault at {site}"
                  + (f" ({detail})" if detail else ""))

    @contextlib.contextmanager
    def installed_on_load(self):
        """Install this plan at the snapshot-load boundary
        (``repro.api.persist.load`` fires SNAPSHOT_LOAD on entry)."""
        from repro.api import persist
        prev = persist.load_fault_hook
        persist.load_fault_hook = lambda path: self.fire(SNAPSHOT_LOAD,
                                                         str(path))
        try:
            yield self
        finally:
            persist.load_fault_hook = prev

    @contextlib.contextmanager
    def installed_on_save(self):
        """Install this plan at the snapshot-write boundary
        (``repro.api.persist._publish_snapshot`` fires SNAPSHOT_WRITE
        before each staged file's bytes are written)."""
        from repro.api import persist
        prev = persist.write_fault_hook
        persist.write_fault_hook = lambda fname: self.fire(SNAPSHOT_WRITE,
                                                           str(fname))
        try:
            yield self
        finally:
            persist.write_fault_hook = prev
