"""Batched vector-search serving on top of (P)DET-LSH.

In-process model of the production service: requests arrive on a queue,
are micro-batched up to ``max_batch``/``max_wait``, answered with one
jitted batched c^2-k-ANN call, and latency percentiles are tracked.
The sharded ``PDETIndex`` serves through the same loop with zero service
code — it satisfies ``AnnIndex``, so the typed ``search`` path (including
pad-lane ``n_active``) just works on a mesh (tests/test_pdet_api.py).

Partial batches are padded up to the next ``pad_to`` bucket so the jitted
query fn sees a bounded set of shapes, and the pad lanes are passed as
``n_active`` so both engines mark them done from round 0 (r_eff = -1 in
the fused kernel: they admit nothing and skip all MXU work).  Pad lanes
are tracked in ``stats.pad_queries`` and never counted as served queries.

The service talks only to the ``repro.api`` protocols: searches go through
``AnnIndex.search`` with a typed ``SearchRequest``, and the mutation path
(``upsert()``/``delete()``, with the ``maybe_compact`` compaction trigger —
the in-process stand-in for the background compactor thread) is gated by an
``isinstance`` check against ``MutableAnnIndex`` — no ``hasattr`` duck
typing.  Pre-protocol indexes are adapted by ``repro.api.as_ann_index``.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.protocol import LegacyIndexAdapter, MutableAnnIndex, \
    as_ann_index
from repro.api.request import SearchRequest
from repro.serving.runtime import LatencyRing


@dataclasses.dataclass
class ServiceStats:
    # Bounded ring (docs/DESIGN.md §9): a long-running service records
    # latencies forever, so the metrics path must be O(1) memory.  The
    # ring keeps the most recent window; len()/iteration/percentile all
    # behave like the old list of samples.
    latencies_ms: LatencyRing = dataclasses.field(
        default_factory=lambda: LatencyRing(4096))
    batches: int = 0
    queries: int = 0          # real served queries only — never pad lanes
    pad_queries: int = 0      # pad lanes issued across all partial batches
    upserts: int = 0
    deletes: int = 0
    noop_deletes: int = 0     # deletes of never-inserted gids (counted no-op)
    compactions: int = 0

    def percentile(self, p: float) -> float:
        return float(np.percentile(self.latencies_ms, p)) \
            if len(self.latencies_ms) else float("nan")

    def summary(self) -> dict:
        return {"queries": self.queries, "batches": self.batches,
                "pad_queries": self.pad_queries,
                "upserts": self.upserts, "deletes": self.deletes,
                "compactions": self.compactions,
                "p50_ms": self.percentile(50), "p99_ms": self.percentile(99)}


class LSHService:
    def __init__(self, index, k: int = 10, max_batch: int = 32,
                 pad_to: int = 32):
        self.index = index
        # The service talks only to the repro.api.AnnIndex protocol.
        # Pre-protocol indexes (PDET shard_map, baselines, user duck types)
        # are wrapped once here — pad-lane masking stays an optimization
        # the adapter drops when the legacy query() can't accept it.
        self._index = as_ann_index(index)
        self.k = k
        self.max_batch = max_batch
        self.pad_to = pad_to
        self._fn = None
        self.stats = ServiceStats()

    @property
    def _supports_n_active(self) -> bool:
        """Whether pad-lane masking reaches the index (always, for protocol
        indexes; the adapter decides for legacy ones)."""
        return (self._index.supports_n_active
                if isinstance(self._index, LegacyIndexAdapter) else True)

    def _query_fn(self, queries, n_valid: int):
        res = self._index.search(
            queries, SearchRequest(k=self.k, n_active=n_valid))
        return res.ids, res.dists

    def _bucket(self, size: int) -> int:
        """Pad-bucket for a partial batch: the next multiple of ``pad_to``.

        Every batch shape the jitted query fn ever sees is one of the
        ceil(max_batch / pad_to) bucket sizes, so steady-state serving pays
        at most that many compilations — not one per distinct batch size.
        """
        return min(self.max_batch, -(-size // self.pad_to) * self.pad_to)

    def warmup(self, d: int):
        # Pre-populate the per-(index, k) radius cache from the index's own
        # data probes first: the zero-vector warmup batches below must
        # compile the query shapes, not seed r_min with origin distances.
        if not isinstance(self._index, LegacyIndexAdapter):
            self._index.r_min_for(self.k)
        buckets = sorted({self._bucket(s)
                          for s in range(1, self.max_batch + 1)})
        for size in buckets:
            q = jnp.zeros((size, d), jnp.float32)
            jax.block_until_ready(self._query_fn(q, size))

    # ------------------------------------------------------------------
    # Mutation path (streaming index only)
    # ------------------------------------------------------------------

    def _mutable_index(self):
        if not isinstance(self._index, MutableAnnIndex):
            raise TypeError(
                f"{type(self.index).__name__} is immutable — serve a "
                f"streaming.StreamingDETLSH for upsert/delete")
        return self._index

    def upsert(self, vectors, ids=None) -> np.ndarray:
        """Insert/overwrite points in the live index; returns global ids.
        Triggers compaction when the segment fan-out exceeds the index's
        ``max_segments``."""
        idx = self._mutable_index()
        out = idx.upsert(vectors, ids)
        self.stats.upserts += len(out)
        if idx.maybe_compact():
            self.stats.compactions += 1
        return out

    def delete(self, ids) -> int:
        idx = self._mutable_index()
        requested = int(np.atleast_1d(np.asarray(ids)).size)
        removed = idx.delete(ids)
        self.stats.deletes += removed
        self.stats.noop_deletes += requested - removed
        if idx.maybe_compact():
            self.stats.compactions += 1
        return removed

    # ------------------------------------------------------------------
    # Query loop
    # ------------------------------------------------------------------

    def serve(self, request_stream) -> list:
        """request_stream: iterable of (arrival_time, query vector)."""
        out = []
        pending: deque = deque(request_stream)
        while pending:
            batch = [pending.popleft()
                     for _ in range(min(self.max_batch, len(pending)))]
            arrivals = [b[0] for b in batch]
            qs = np.stack([b[1] for b in batch])
            pad = self._bucket(len(qs)) - len(qs)
            if pad:
                qs = np.concatenate([qs, np.zeros((pad, qs.shape[1]),
                                                  qs.dtype)])
            t0 = time.perf_counter()
            ids, dists = self._query_fn(jnp.asarray(qs), len(arrivals))
            jax.block_until_ready(dists)
            done = time.perf_counter()
            for i, arr in enumerate(arrivals):
                self.stats.latencies_ms.append((done - arr) * 1e3)
                out.append((np.asarray(ids[i]), np.asarray(dists[i])))
            self.stats.batches += 1
            self.stats.queries += len(arrivals)
            self.stats.pad_queries += pad
        return out
