"""Batched vector-search serving on top of (P)DET-LSH.

In-process model of the production service: requests arrive on a queue,
are micro-batched up to ``max_batch``/``max_wait``, answered with one
jitted batched c^2-k-ANN call, and latency percentiles are tracked.
On a pod the same loop runs with the PDET (shard_map) index; here the
single-device index keeps the example CPU-friendly.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ServiceStats:
    latencies_ms: list
    batches: int = 0
    queries: int = 0

    def percentile(self, p: float) -> float:
        return float(np.percentile(self.latencies_ms, p)) \
            if self.latencies_ms else float("nan")

    def summary(self) -> dict:
        return {"queries": self.queries, "batches": self.batches,
                "p50_ms": self.percentile(50), "p99_ms": self.percentile(99)}


class LSHService:
    def __init__(self, index, k: int = 10, max_batch: int = 32,
                 pad_to: int = 32):
        self.index = index
        self.k = k
        self.max_batch = max_batch
        self.pad_to = pad_to
        self._fn = None
        self.stats = ServiceStats(latencies_ms=[])

    def _query_fn(self, queries):
        res = self.index.query(queries, k=self.k)
        return res.ids, res.dists

    def _bucket(self, size: int) -> int:
        """Pad-bucket for a partial batch: the next multiple of ``pad_to``.

        Every batch shape the jitted query fn ever sees is one of the
        ceil(max_batch / pad_to) bucket sizes, so steady-state serving pays
        at most that many compilations — not one per distinct batch size.
        """
        return min(self.max_batch, -(-size // self.pad_to) * self.pad_to)

    def warmup(self, d: int):
        buckets = sorted({self._bucket(s)
                          for s in range(1, self.max_batch + 1)})
        for size in buckets:
            q = jnp.zeros((size, d), jnp.float32)
            jax.block_until_ready(self._query_fn(q))

    def serve(self, request_stream) -> list:
        """request_stream: iterable of (arrival_time, query vector)."""
        out = []
        pending: deque = deque(request_stream)
        while pending:
            batch = [pending.popleft()
                     for _ in range(min(self.max_batch, len(pending)))]
            arrivals = [b[0] for b in batch]
            qs = np.stack([b[1] for b in batch])
            pad = self._bucket(len(qs)) - len(qs)
            if pad:
                qs = np.concatenate([qs, np.zeros((pad, qs.shape[1]),
                                                  qs.dtype)])
            t0 = time.perf_counter()
            ids, dists = self._query_fn(jnp.asarray(qs))
            jax.block_until_ready(dists)
            done = time.perf_counter()
            for i, arr in enumerate(arrivals):
                self.stats.latencies_ms.append((done - arr) * 1e3)
                out.append((np.asarray(ids[i]), np.asarray(dists[i])))
            self.stats.batches += 1
            self.stats.queries += len(arrivals)
        return out
