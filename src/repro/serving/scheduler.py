"""Deadline-aware micro-batching + admission control (docs/DESIGN.md §9).

Requests carry an absolute deadline (or none).  The ``MicroBatcher``
coalesces arrivals into the bounded pad-to-bucket batch shapes the jitted
query path already compiles for, and decides *when* to flush and *what* to
admit:

  flush when   batch is full · the oldest request has waited ``max_wait``
               · deadline pressure (waiting longer would make the earliest
                 deadline unmeetable under the current latency model)
  admit        requests predicted to meet their deadline
  degrade      when a full-effort batch would miss deadlines, re-plan the
               batch at a capped ``max_rounds`` (recorded ``degraded=True``)
               — graceful degradation strictly *before* shedding
  shed         only requests that still cannot meet their deadline (or that
               overflow the bounded queue) — always an explicit ``Rejected``
               outcome, never a silent drop

The latency model is an EWMA per (pad bucket, degraded) key, seeded by the
runtime's warmup measurements, so admission decisions are driven by what
this process actually measured, not constants.  The scheduler holds no jax
state and never touches the index — it is pure queueing logic, unit-tested
with a fake clock (tests/test_serving_runtime.py).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Request:
    """One enqueued query: vector + arrival + optional absolute deadline
    (same clock domain as the runtime's ``clock``)."""

    rid: int
    query: np.ndarray
    arrival: float
    deadline: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class Rejected:
    """Explicit load-shed outcome — the runtime never silently drops."""

    rid: int
    reason: str          # 'deadline' | 'queue_full' | 'engine_failure'
    detail: str = ""


@dataclasses.dataclass(frozen=True)
class Answer:
    rid: int
    ids: np.ndarray
    dists: np.ndarray
    epoch: int           # epoch id the batch was pinned to
    degraded: bool       # answered at capped max_rounds
    latency_ms: float


REJECT_REASONS = ("deadline", "queue_full", "engine_failure")


class LatencyModel:
    """EWMA service-time estimates per (pad bucket, degraded) key."""

    def __init__(self, alpha: float = 0.3, degrade_guess: float = 0.5):
        self.alpha = alpha
        self.degrade_guess = degrade_guess     # degraded/normal ratio prior
        self._ewma: dict = {}

    def observe(self, bucket: int, degraded: bool, seconds: float) -> None:
        key = (bucket, degraded)
        prev = self._ewma.get(key)
        self._ewma[key] = seconds if prev is None else \
            (1 - self.alpha) * prev + self.alpha * seconds

    def predict(self, bucket: int, degraded: bool = False) -> float:
        """Expected service seconds; optimistic 0.0 before any sample (we
        admit until the model has measured — a cold service must not shed
        its very first requests on a guess)."""
        got = self._ewma.get((bucket, degraded))
        if got is not None:
            return got
        if degraded:
            base = self._ewma.get((bucket, False))
            if base is not None:
                return base * self.degrade_guess
        return 0.0


class MicroBatcher:
    """Bounded FIFO request queue + the flush/admit/degrade/shed policy."""

    def __init__(self, *, max_batch: int = 32, pad_to: int = 32,
                 max_wait: float = 0.002, deadline_headroom: float = 1.0,
                 queue_cap: Optional[int] = None,
                 latency_model: Optional[LatencyModel] = None):
        if max_batch < 1 or pad_to < 1:
            raise ValueError(f"max_batch/pad_to must be >= 1, got "
                             f"{max_batch}/{pad_to}")
        self.max_batch = max_batch
        self.pad_to = pad_to
        self.max_wait = max_wait
        self.deadline_headroom = deadline_headroom
        self.queue_cap = queue_cap
        self.model = latency_model or LatencyModel()
        self._queue: deque = deque()

    # ------------------------------------------------------------------
    # Queue
    # ------------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._queue)

    @property
    def depth(self) -> int:
        return len(self._queue)

    def bucket(self, size: int) -> int:
        """Pad bucket for a partial batch: next multiple of ``pad_to``
        (bounded compile set — serving/lsh_service.py's contract)."""
        return min(self.max_batch, -(-size // self.pad_to) * self.pad_to)

    def enqueue(self, req: Request) -> Optional[Rejected]:
        """Append; returns a ``Rejected('queue_full')`` instead of growing
        past the bounded queue (explicit backpressure, never OOM)."""
        if self.queue_cap is not None and len(self._queue) >= self.queue_cap:
            return Rejected(req.rid, "queue_full",
                            f"queue depth {len(self._queue)} at cap "
                            f"{self.queue_cap}")
        self._queue.append(req)
        return None

    # ------------------------------------------------------------------
    # Flush policy
    # ------------------------------------------------------------------

    def _head(self, count: Optional[int] = None) -> list:
        count = len(self._queue) if count is None else count
        return [self._queue[i] for i in range(min(count, len(self._queue)))]

    def ready(self, now: float) -> bool:
        """Should the head batch flush now?"""
        if not self._queue:
            return False
        if len(self._queue) >= self.max_batch:
            return True
        if now - self._queue[0].arrival >= self.max_wait:
            return True
        head = self._head(self.max_batch)
        deadlines = [r.deadline for r in head if r.deadline is not None]
        if deadlines:
            pred = self.model.predict(self.bucket(len(head)))
            # waiting longer would push the earliest deadline past its
            # predicted completion — flush under deadline pressure
            if min(deadlines) - now <= pred * self.deadline_headroom:
                return True
        return False

    def next_batch(self, now: float
                   ) -> Tuple[List[Request], bool, List[Rejected]]:
        """Pop the head batch and run admission control on it.

        Returns ``(admitted, degraded, shed)``: the requests to run, at
        full effort or degraded, plus the explicit rejections.  Degrade is
        always tried before shedding a deadline: a capped-``max_rounds``
        batch is predicted cheaper, so requests that would miss at full
        effort may still be served degraded.
        """
        batch = [self._queue.popleft()
                 for _ in range(min(self.max_batch, len(self._queue)))]
        shed: List[Rejected] = []

        def misses(reqs, degraded):
            pred = self.model.predict(self.bucket(len(reqs)), degraded)
            lat = pred * self.deadline_headroom
            return [r for r in reqs
                    if r.deadline is not None and now + lat > r.deadline]

        degraded = False
        missing = misses(batch, degraded=False)
        if missing:
            # graceful degradation before any shed: can a capped-effort
            # batch bring the misses back inside their deadlines?
            still = misses(batch, degraded=True)
            if len(still) < len(missing):
                degraded = True
                missing = still
        if missing:
            dead = set(r.rid for r in missing)
            shed = [Rejected(r.rid, "deadline",
                             f"predicted completion past deadline by "
                             f"{max(0.0, now - (r.deadline or now)):.4f}s "
                             f"queue+service") for r in missing]
            batch = [r for r in batch if r.rid not in dead]
            if degraded and not misses(batch, degraded=False):
                degraded = False       # shed freed enough budget: full effort
        return batch, degraded, shed

    def drain(self) -> List[Request]:
        """Remove and return everything still queued (shutdown path)."""
        out = list(self._queue)
        self._queue.clear()
        return out
