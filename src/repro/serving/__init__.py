"""repro.serving: the serving layer over (P)DET-LSH indexes.

``LSHService`` is the synchronous pad-to-bucket loop (the seed-era
surface, kept); ``ServingRuntime`` is the concurrent runtime — epoch/RCU
snapshot pinning, deadline-aware micro-batching with admission control,
fault injection + retry, and lock-free metrics (docs/DESIGN.md §9).
"""

from repro.serving.faults import (CHECKPOINT_INSTALL, COMPACTION_SWAP,
                                  ENGINE_CALL, SNAPSHOT_LOAD,
                                  SNAPSHOT_WRITE, WAL_APPEND, WAL_FSYNC,
                                  FaultPlan, InjectedFault)
from repro.serving.lsh_service import LSHService, ServiceStats
from repro.serving.runtime import (Epoch, EpochManager, LatencyRing,
                                   RuntimeStats, ServingRuntime)
from repro.serving.scheduler import (Answer, LatencyModel, MicroBatcher,
                                     Rejected, Request)

__all__ = [
    "LSHService", "ServiceStats",
    "ServingRuntime", "RuntimeStats", "LatencyRing", "Epoch",
    "EpochManager",
    "MicroBatcher", "LatencyModel", "Request", "Answer", "Rejected",
    "FaultPlan", "InjectedFault",
    "ENGINE_CALL", "COMPACTION_SWAP", "SNAPSHOT_LOAD",
    "WAL_APPEND", "WAL_FSYNC", "SNAPSHOT_WRITE", "CHECKPOINT_INSTALL",
]
