"""ServingRuntime: concurrent queries, upserts, deletes, and compaction
over one (P)DET-LSH index (docs/DESIGN.md §9).

The runtime composes three orthogonal pieces:

  * **Epoch pinning (RCU)** — every query batch pins an immutable epoch of
    the index (``StreamingDETLSH.pin_state()`` + a manifest refcount).
    Mutators install the next epoch atomically (manifest swap / memtable
    version bump) and an old epoch retires only when its reader count
    drains, so readers never block writers, writers never invalidate
    in-flight readers, and no reader can observe a half-swapped manifest.
  * **Deadline-aware micro-batching** — ``scheduler.MicroBatcher`` decides
    when a batch flushes and which requests are admitted / served degraded
    (capped ``max_rounds``) / shed with an explicit ``Rejected``.
  * **Fault injection + retry** — a ``faults.FaultPlan`` fires at the
    engine-call and compaction-swap boundaries.  A failed engine call is
    retried once on the vmap semantics-of-record engine; a second failure
    rejects only that batch's requests.  A compaction that crashes at the
    swap leaves the manifest — and every pinned epoch — untouched.

Serialized-oracle equivalence (the §9 correctness argument): mutations are
*barriers* — ``upsert``/``delete`` flush the queue before touching the
index — and every batch answers on the epoch it pinned, so the sequence of
answers is bit-identical to running each operation to completion in
submission order.  Compaction is *not* a barrier: it only reorganizes the
surviving set, and pinned epochs keep answering on pre-compaction
structure, which is exactly what the property test checks
(tests/test_runtime_properties.py).

Metrics are lock-free on the read path: latencies land in a bounded
``LatencyRing`` (fixed numpy buffer, monotonic write index) and counters
are plain ints — single-writer in this in-process model, and safe to read
at any time without coordination.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.protocol import LegacyIndexAdapter, MutableAnnIndex, \
    as_ann_index
from repro.api.request import SearchRequest
from repro.serving import faults as flt
from repro.serving.scheduler import Answer, LatencyModel, MicroBatcher, \
    Rejected, Request

Outcome = Union[Answer, Rejected]


class LatencyRing:
    """Bounded latency buffer: fixed numpy storage, monotonic write index.

    Drop-in for the old unbounded ``latencies_ms`` list on the metrics
    path — ``append``/``len``/iteration/``np.percentile`` all behave like
    a list of the most recent ``capacity`` samples, but memory is O(1) for
    the lifetime of the service.  ``total`` counts every sample ever
    recorded (``len`` saturates at capacity).
    """

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._buf = np.zeros(capacity, np.float64)
        self.total = 0

    def append(self, value: float) -> None:
        self._buf[self.total % self.capacity] = value
        self.total += 1

    def __len__(self) -> int:
        return min(self.total, self.capacity)

    def values(self) -> np.ndarray:
        """Retained samples, oldest first."""
        n = len(self)
        if self.total <= self.capacity:
            return self._buf[:n].copy()
        split = self.total % self.capacity
        return np.concatenate([self._buf[split:], self._buf[:split]])

    def __iter__(self):
        return iter(self.values())

    def __array__(self, dtype=None, copy=None):
        vals = self.values()
        return vals.astype(dtype) if dtype is not None else vals

    def percentile(self, p: float) -> float:
        if len(self) == 0:
            return float("nan")
        return float(np.percentile(self.values(), p))


@dataclasses.dataclass
class RuntimeStats:
    """Counters + bounded latency ring; everything lands in ``summary()``."""

    latencies: LatencyRing = dataclasses.field(
        default_factory=lambda: LatencyRing(4096))
    queries: int = 0            # real served queries — never pad lanes
    batches: int = 0
    pad_queries: int = 0
    degraded_batches: int = 0
    upserts: int = 0
    deletes: int = 0
    noop_deletes: int = 0       # delete() of never-inserted gids
    compactions: int = 0
    compaction_crashes: int = 0
    retries: int = 0            # engine-call retries on the vmap engine
    deadline_misses: int = 0    # answered, but past the stated deadline
    epochs_pinned: int = 0
    epochs_retired: int = 0
    max_queue_depth: int = 0
    # durability counters (docs/DESIGN.md §13) — zero unless the served
    # index is a durability.DurableIndex; mirrored from its WAL
    wal_bytes: int = 0
    fsyncs: int = 0
    checkpoints: int = 0
    checkpoint_failures: int = 0
    recovery_replayed: int = 0  # WAL records replayed by recovery-on-start
    shed: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"deadline": 0, "queue_full": 0,
                                 "engine_failure": 0})

    def record_shed(self, rejected: Rejected) -> None:
        self.shed[rejected.reason] = self.shed.get(rejected.reason, 0) + 1

    @property
    def shed_total(self) -> int:
        return sum(self.shed.values())

    def percentile(self, p: float) -> float:
        return self.latencies.percentile(p)

    def summary(self) -> dict:
        return {
            "queries": self.queries, "batches": self.batches,
            "pad_queries": self.pad_queries,
            "degraded_batches": self.degraded_batches,
            "upserts": self.upserts, "deletes": self.deletes,
            "noop_deletes": self.noop_deletes,
            "compactions": self.compactions,
            "compaction_crashes": self.compaction_crashes,
            "retries": self.retries,
            "deadline_misses": self.deadline_misses,
            "shed": dict(self.shed), "shed_total": self.shed_total,
            "epochs_pinned": self.epochs_pinned,
            "epochs_retired": self.epochs_retired,
            "max_queue_depth": self.max_queue_depth,
            "wal_bytes": self.wal_bytes, "fsyncs": self.fsyncs,
            "checkpoints": self.checkpoints,
            "checkpoint_failures": self.checkpoint_failures,
            "recovery_replayed": self.recovery_replayed,
            "p50_ms": self.percentile(50.0),
            "p99_ms": self.percentile(99.0),
            "p999_ms": self.percentile(99.9),
        }


class Epoch:
    """One pinned, immutable read view.  Created by ``EpochManager.pin``;
    must be released exactly once (the runtime does so in a finally)."""

    def __init__(self, epoch_id: int, index, view, token: Optional[int]):
        self.epoch_id = epoch_id
        self._index = index
        self.view = view                 # streaming PinnedView, or None
        self._token = token              # manifest.retain() version token
        self.released = False

    @property
    def fingerprint(self) -> Optional[tuple]:
        return self.view.fingerprint if self.view is not None else None

    def search(self, queries, request: SearchRequest):
        """Answer on the pinned structure, regardless of mutations since."""
        if self.view is not None:
            return self._index.search(queries, request, view=self.view)
        return self._index.search(queries, request)


class EpochManager:
    """Epoch lifecycle: pin / release / advance, with retire-on-drain.

    For a ``StreamingDETLSH`` each pin captures a fresh ``pin_state()``
    view (fresh because sealed-row deletes mutate host bitmaps without
    bumping a version — a cached view could silently go stale) and takes a
    manifest refcount, so ``manifest.pinned_versions()`` makes the drain
    state observable.  Immutable indexes (static DET-LSH, sharded PDET)
    get trivial epochs: every state they will ever have *is* an immutable
    snapshot.
    """

    def __init__(self, index, stats: RuntimeStats):
        self._index = index
        self._stats = stats
        self._streaming = hasattr(index, "pin_state")
        self.current_id = 0
        self._readers: Dict[int, int] = {}   # epoch_id -> outstanding pins

    def pin(self) -> Epoch:
        if self._streaming:
            view = self._index.pin_state()
            token = self._index.manifest.retain()
        else:
            view, token = None, None
        eid = self.current_id
        self._readers[eid] = self._readers.get(eid, 0) + 1
        self._stats.epochs_pinned += 1
        return Epoch(eid, self._index, view, token)

    def release(self, epoch: Epoch) -> None:
        if epoch.released:
            raise ValueError(f"epoch {epoch.epoch_id} released twice")
        epoch.released = True
        if epoch._token is not None:
            self._index.manifest.release(epoch._token)
        eid = epoch.epoch_id
        remaining = self._readers.get(eid, 0) - 1
        if remaining > 0:
            self._readers[eid] = remaining
            return
        self._readers.pop(eid, None)
        if eid != self.current_id:
            self._stats.epochs_retired += 1   # superseded + drained

    def advance(self) -> int:
        """Install the next epoch (called by mutators after success).  The
        superseded epoch retires immediately if it has no readers."""
        old = self.current_id
        self.current_id += 1
        if old not in self._readers:
            pass                              # never pinned — nothing drains
        return self.current_id

    def outstanding(self) -> Dict[int, int]:
        return dict(self._readers)


class ServingRuntime:
    """Deadline-aware, epoch-pinned, fault-tolerant serving loop.

    In-process model of the production service: ``submit`` enqueues,
    ``pump`` flushes batches the scheduler says are ready, ``flush``
    drains.  Mutations (``upsert``/``delete``) are barriers; ``compact``
    is not (pinned epochs survive it).  All answers and rejections are
    explicit ``Answer``/``Rejected`` outcomes keyed by request id.
    """

    def __init__(self, index, k: int = 10, *, max_batch: int = 32,
                 pad_to: int = 32, max_wait_ms: float = 2.0,
                 deadline_headroom: float = 1.0,
                 degraded_max_rounds: int = 8,
                 queue_cap: Optional[int] = None,
                 fault_plan: Optional[flt.FaultPlan] = None,
                 clock=time.perf_counter,
                 request: Optional[SearchRequest] = None,
                 latency_ring_capacity: int = 4096):
        self.index = index
        self._index = as_ann_index(index)
        self.k = k
        self.clock = clock
        self.degraded_max_rounds = degraded_max_rounds
        self.plan = fault_plan or flt.FaultPlan()
        self.stats = RuntimeStats(
            latencies=LatencyRing(latency_ring_capacity))
        self.batcher = MicroBatcher(
            max_batch=max_batch, pad_to=pad_to, max_wait=max_wait_ms / 1e3,
            deadline_headroom=deadline_headroom, queue_cap=queue_cap,
            latency_model=LatencyModel())
        self.epochs = EpochManager(self._index, self.stats)
        # template request: k/n_active/max_rounds are runtime-controlled
        self._request = request or SearchRequest()
        if self._request.k != k:
            self._request = dataclasses.replace(self._request, k=k)
        self._rid = 0
        self.outcomes: Dict[int, Outcome] = {}
        # compaction-swap fault boundary: the manifest fires the plan
        # before mutating, so an armed fault models a mid-install crash
        if hasattr(self._index, "manifest"):
            self._index.manifest.swap_hook = \
                lambda: self.plan.fire(flt.COMPACTION_SWAP)
        self.last_compaction_error: Optional[BaseException] = None
        self.last_checkpoint_error: Optional[BaseException] = None
        # recovery-on-start: a recovered DurableIndex carries its report
        recovery = getattr(self._index, "last_recovery", None)
        if recovery is not None:
            self.stats.recovery_replayed = recovery.n_replayed
        self._sync_durability_stats()

    # ------------------------------------------------------------------
    # Query path
    # ------------------------------------------------------------------

    def submit(self, query, deadline: Optional[float] = None,
               arrival: Optional[float] = None) -> int:
        """Enqueue one query; returns its request id.  The outcome
        (``Answer`` or ``Rejected``) appears in ``self.outcomes[rid]``
        once a ``pump``/``flush`` runs its batch — a queue-full rejection
        appears immediately."""
        rid = self._rid
        self._rid += 1
        req = Request(rid=rid, query=np.asarray(query, np.float32),
                      arrival=self.clock() if arrival is None else arrival,
                      deadline=deadline)
        rejected = self.batcher.enqueue(req)
        if rejected is not None:
            self.outcomes[rid] = rejected
            self.stats.record_shed(rejected)
        else:
            self.stats.max_queue_depth = max(self.stats.max_queue_depth,
                                             self.batcher.depth)
        return rid

    def pump(self) -> int:
        """Run every batch the scheduler considers ready; returns how many
        batches ran."""
        ran = 0
        while self.batcher.ready(self.clock()):
            self._run_batch()
            ran += 1
        return ran

    def flush(self) -> int:
        """Drain the queue completely (mutation barrier / shutdown)."""
        ran = 0
        while len(self.batcher):
            self._run_batch()
            ran += 1
        return ran

    def _make_request(self, n_valid: int, degraded: bool) -> SearchRequest:
        req = dataclasses.replace(self._request, n_active=n_valid)
        if degraded:
            req = dataclasses.replace(
                req, max_rounds=min(req.max_rounds, self.degraded_max_rounds))
        return req

    def _run_batch(self) -> None:
        now = self.clock()
        batch, degraded, shed = self.batcher.next_batch(now)
        for rej in shed:
            self.outcomes[rej.rid] = rej
            self.stats.record_shed(rej)
        if not batch:
            return

        qs = np.stack([r.query for r in batch])
        pad = self.batcher.bucket(len(qs)) - len(qs)
        if pad:
            qs = np.concatenate([qs, np.zeros((pad, qs.shape[1]), qs.dtype)])
        bucket = qs.shape[0]
        req = self._make_request(len(batch), degraded)

        epoch = self.epochs.pin()
        try:
            t0 = self.clock()
            try:
                self.plan.fire(flt.ENGINE_CALL)
                res = epoch.search(jnp.asarray(qs), req)
                jax.block_until_ready(res.dists)
            except Exception as first:
                # retry once on the vmap semantics-of-record engine; a
                # second failure rejects only this batch's requests
                self.stats.retries += 1
                retry_req = dataclasses.replace(req, engine="vmap")
                try:
                    self.plan.fire(flt.ENGINE_CALL)
                    res = epoch.search(jnp.asarray(qs), retry_req)
                    jax.block_until_ready(res.dists)
                except Exception as second:
                    for r in batch:
                        rej = Rejected(
                            r.rid, "engine_failure",
                            f"engine call failed twice: {first!r}; "
                            f"retry on vmap: {second!r}")
                        self.outcomes[r.rid] = rej
                        self.stats.record_shed(rej)
                    self.stats.batches += 1
                    return
            done = self.clock()
        finally:
            self.epochs.release(epoch)

        self.batcher.model.observe(bucket, degraded, max(0.0, done - t0))
        ids = np.asarray(res.ids)
        dists = np.asarray(res.dists)
        for i, r in enumerate(batch):
            latency_ms = (done - r.arrival) * 1e3
            self.stats.latencies.append(latency_ms)
            if r.deadline is not None and done > r.deadline:
                self.stats.deadline_misses += 1
            self.outcomes[r.rid] = Answer(
                rid=r.rid, ids=ids[i], dists=dists[i],
                epoch=epoch.epoch_id, degraded=degraded,
                latency_ms=latency_ms)
        self.stats.batches += 1
        self.stats.queries += len(batch)
        self.stats.pad_queries += pad
        if degraded:
            self.stats.degraded_batches += 1

    def serve(self, request_stream) -> List[Outcome]:
        """Closed-loop convenience: feed ``(arrival, vec)`` or ``(arrival,
        vec, deadline)`` tuples, pump as they arrive, drain, and return the
        outcomes in submission order."""
        rids = []
        for item in request_stream:
            arrival, vec = item[0], item[1]
            deadline = item[2] if len(item) > 2 else None
            rids.append(self.submit(vec, deadline=deadline, arrival=arrival))
            self.pump()
        self.flush()
        return [self.outcomes.pop(rid) for rid in rids]

    # ------------------------------------------------------------------
    # Epoch surface (tests pin across mutations)
    # ------------------------------------------------------------------

    def pin(self) -> Epoch:
        return self.epochs.pin()

    def release(self, epoch: Epoch) -> None:
        self.epochs.release(epoch)

    # ------------------------------------------------------------------
    # Mutation path (barriers — docs/DESIGN.md §9 oracle argument)
    # ------------------------------------------------------------------

    def _mutable_index(self):
        if not isinstance(self._index, MutableAnnIndex):
            raise TypeError(
                f"{type(self.index).__name__} is immutable — serve a "
                f"streaming.StreamingDETLSH for upsert/delete")
        return self._index

    def upsert(self, vectors, gids=None) -> np.ndarray:
        """Flush queued queries (mutation barrier), then insert/overwrite.
        A validation failure (gid exhaustion) raises *after* the flush and
        *before* any index mutation, so no queued request is ever lost —
        recover with ``index.grow_id_capacity`` and resubmit the upsert."""
        idx = self._mutable_index()
        self.flush()
        out = idx.upsert(vectors, gids)
        self.stats.upserts += len(out)
        self.epochs.advance()
        if self._maybe_compact():
            self.stats.compactions += 1
        self._maybe_checkpoint()
        return out

    def delete(self, gids) -> int:
        """Flush, then tombstone; never-inserted gids are a counted no-op
        (``stats.noop_deletes``), not an error."""
        idx = self._mutable_index()
        self.flush()
        requested = int(np.atleast_1d(np.asarray(gids)).size)
        removed = idx.delete(gids)
        self.stats.deletes += removed
        self.stats.noop_deletes += requested - removed
        self.epochs.advance()
        if self._maybe_compact():
            self.stats.compactions += 1
        self._maybe_checkpoint()
        return removed

    def compact(self, force: bool = True) -> bool:
        """Run compaction concurrently with pinned epochs (NOT a barrier:
        merging the surviving set changes no answer, and pinned epochs keep
        answering on the pre-compaction structure).  A crash at the swap
        boundary leaves the manifest on the pre-swap epoch; the runtime
        records it and keeps serving."""
        idx = self._mutable_index()
        try:
            did = idx.compact() if force else idx.maybe_compact()
        except Exception as exc:
            self.stats.compaction_crashes += 1
            self.last_compaction_error = exc
            return False
        if did:
            self.stats.compactions += 1
            self.epochs.advance()
        return did

    def _maybe_compact(self) -> bool:
        try:
            did = self._index.maybe_compact()
        except Exception as exc:
            self.stats.compaction_crashes += 1
            self.last_compaction_error = exc
            return False
        if did:
            self.epochs.advance()
        return did

    # ------------------------------------------------------------------
    # Durability (docs/DESIGN.md §13) — active when the served index is a
    # durability.DurableIndex; a no-op otherwise
    # ------------------------------------------------------------------

    def _sync_durability_stats(self) -> None:
        wal = getattr(self._index, "wal", None)
        if wal is not None:
            self.stats.wal_bytes = wal.appended_bytes
            self.stats.fsyncs = wal.fsyncs

    def _maybe_checkpoint(self) -> bool:
        """Background checkpoint policy: let the index decide (WAL bytes /
        age thresholds).  A checkpoint failure is recorded and served
        around, like a compaction crash — the WAL still has every op, so
        durability degrades to a longer replay, not data loss."""
        mc = getattr(self._index, "maybe_checkpoint", None)
        if mc is None:
            return False
        try:
            did = bool(mc())
        except Exception as exc:
            self.stats.checkpoint_failures += 1
            self.last_checkpoint_error = exc
            self._sync_durability_stats()
            return False
        if did:
            self.stats.checkpoints += 1
        self._sync_durability_stats()
        return did

    # ------------------------------------------------------------------
    # Warmup
    # ------------------------------------------------------------------

    def warmup(self, d: int) -> None:
        """Compile every pad bucket and seed the scheduler's latency model
        with measured (post-compile) service times, so the first real
        admission decisions run on data, not guesses."""
        if not isinstance(self._index, LegacyIndexAdapter):
            self._index.r_min_for(self.k)
        buckets = sorted({self.batcher.bucket(s)
                          for s in range(1, self.batcher.max_batch + 1)})
        for size in buckets:
            q = jnp.zeros((size, d), jnp.float32)
            for degraded in (False, True):
                req = self._make_request(size, degraded)
                jax.block_until_ready(
                    self._index.search(q, req).dists)     # compile pass
                t0 = self.clock()
                jax.block_until_ready(self._index.search(q, req).dists)
                self.batcher.model.observe(size, degraded,
                                           max(0.0, self.clock() - t0))
