"""Pallas kernel: exact-distance rerank (the paper's SIMD distance hot spot).

Computes all pairwise Euclidean distances between a query block and a
candidate block — the fine-grained verification step of the two-step query
strategy ("compute the real distance of each candidate point", O(beta*n*d)).

Tiling: grid (b/bq, m/bc); each program holds a (bq, d) query tile and a
(bc, d) candidate tile in VMEM, computes the cross term on the MXU
(dot(q, c^T)) and fuses the norm terms and sqrt on the VPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, c_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)                  # (bq, d)
    c = c_ref[...].astype(jnp.float32)                  # (bc, d)
    qq = jnp.sum(q * q, axis=1, keepdims=True)          # (bq, 1)
    cc = jnp.sum(c * c, axis=1)[None, :]                # (1, bc)
    qc = jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    o_ref[...] = jnp.sqrt(jnp.maximum(qq - 2.0 * qc + cc, 0.0))


def l2_rerank(q: jax.Array, c: jax.Array, *, block_q: int = 128,
              block_c: int = 256, interpret: bool = False) -> jax.Array:
    """q (b, d), c (m, d) -> distances (b, m) f32 (block-aligned; ops pads)."""
    b, d = q.shape
    m = c.shape[0]
    assert b % block_q == 0 and m % block_c == 0, (b, m, block_q, block_c)
    grid = (b // block_q, m // block_c)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_c, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        interpret=interpret,
    )(q, c)
