"""Pallas kernel: fused build pipeline — project -> encode -> key-pack.

The indexing phase of DET-LSH (the paper's headline speedup: "up to 6x for
DET-LSH, 40x for PDET-LSH over SOTA") was three separate HBM passes in the
seed build: the projection matmul, the encode compare-sweep, and a per-bit
Python loop packing interleaved sort keys — each materializing an (n, L*K)
intermediate plus its (L, n, K) transposed copy.  This kernel streams row
chunks of the input through all three stages in ONE grid pass:

  1. project: the (bn, d) row tile against the full (d, L*K) panel on the
     MXU (identical tiling to ``lsh_project``) — or skipped when the caller
     already has projections (the static build projects first because
     breakpoint *selection* needs the projected coordinates);
  2. encode: the compare-accumulate sweep over the Nr-1 internal breakpoint
     edges (identical formulation to ``encode_bins``), entirely on the VPU
     tile — region ids never round-trip through HBM before packing;
  3. key-pack: the MSB-first round-robin bit-interleave of each tree's K
     region ids into two uint32 words (the packed 64-bit sort key; see
     ``core.detree.interleave_keys``), unrolled over the static (level,
     dim) table.

Outputs land directly in the per-tree (L, n, K) layout the sorted forest
needs (the per-tree column slices are static — no transpose op), so the
build never materializes (n, L*K) arrays or their transposed copies at
once: peak intermediate memory is O(chunk), not O(n * L * K * passes).

Grid: (n / block_n,) row chunks — ``block_n`` is the build's chunk size,
plumbed from ``IndexSpec.build_chunk``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.detree import key_bit_budget


def _encode_pack_tile(proj, bp_ref, proj_ref, codes_ref, hi_ref, lo_ref, *,
                      K: int, L: int, Nr: int):
    """Shared tile body: proj (bn, L*K) f32 resident in VMEM -> outputs."""
    def body(b, acc):
        edges = bp_ref[:, b]                           # (L*K,) internal edge b
        return acc + (proj >= edges[None, :]).astype(jnp.int32)

    acc = jax.lax.fori_loop(1, Nr, body, jnp.zeros(proj.shape, jnp.int32))
    codes = jnp.clip(acc, 0, Nr - 1)                   # (bn, L*K)

    _, hi_bits, lo_bits = key_bit_budget(K)

    def pack(codes_l, start_bit, nbits):
        key = jnp.zeros((proj.shape[0],), jnp.uint32)
        pos = nbits * K
        for b in range(nbits):                         # bit level (MSB first)
            for j in range(K):                         # round-robin over dims
                pos -= 1
                if pos >= 32:      # overflows the word: dropped, explicitly
                    continue       # (mirrors detree.interleave_keys)
                bit = (codes_l[:, j] >> (7 - (start_bit + b))) & 1
                key = key | (bit.astype(jnp.uint32) << pos)
        return key

    for l in range(L):                                 # static per-tree slices
        sl = slice(l * K, (l + 1) * K)
        proj_ref[l] = proj[:, sl]
        codes_l = codes[:, sl]
        codes_ref[l] = codes_l
        hi_ref[l] = pack(codes_l, 0, hi_bits)
        lo_ref[l] = (pack(codes_l, hi_bits, lo_bits) if lo_bits > 0
                     else jnp.zeros((proj.shape[0],), jnp.uint32))


def _kernel_from_proj(p_ref, bp_ref, proj_ref, codes_ref, hi_ref, lo_ref, *,
                      K, L, Nr):
    _encode_pack_tile(p_ref[...], bp_ref, proj_ref, codes_ref, hi_ref,
                      lo_ref, K=K, L=L, Nr=Nr)


def _kernel_from_data(x_ref, a_ref, bp_ref, proj_ref, codes_ref, hi_ref,
                      lo_ref, *, K, L, Nr):
    proj = jax.lax.dot_general(x_ref[...], a_ref[...],
                               (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    _encode_pack_tile(proj[:, :L * K], bp_ref, proj_ref, codes_ref, hi_ref,
                      lo_ref, K=K, L=L, Nr=Nr)


def _out_shapes(n: int, K: int, L: int, block_n: int):
    specs = [
        pl.BlockSpec((L, block_n, K), lambda i: (0, i, 0)),    # proj_t
        pl.BlockSpec((L, block_n, K), lambda i: (0, i, 0)),    # codes_t
        pl.BlockSpec((L, block_n), lambda i: (0, i)),          # key_hi
        pl.BlockSpec((L, block_n), lambda i: (0, i)),          # key_lo
    ]
    shapes = [
        jax.ShapeDtypeStruct((L, n, K), jnp.float32),
        jax.ShapeDtypeStruct((L, n, K), jnp.int32),
        jax.ShapeDtypeStruct((L, n), jnp.uint32),
        jax.ShapeDtypeStruct((L, n), jnp.uint32),
    ]
    return specs, shapes


def encode_pack(proj: jax.Array, breakpoints: jax.Array, *, K: int, L: int,
                block_n: int = 512, interpret: bool = False):
    """proj (n, L*K), breakpoints (L*K, Nr+1) ->
    (proj_t (L, n, K) f32, codes_t (L, n, K) i32, key_hi/lo (L, n) u32).
    n must be a block_n multiple (ops.py pads)."""
    n, D = proj.shape
    assert D == L * K, (proj.shape, L, K)
    E = breakpoints.shape[1]
    assert n % block_n == 0, (n, block_n)
    out_specs, out_shape = _out_shapes(n, K, L, block_n)
    return pl.pallas_call(
        lambda *refs: _kernel_from_proj(*refs, K=K, L=L, Nr=E - 1),
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, D), lambda i: (i, 0)),
            pl.BlockSpec((D, E), lambda i: (0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(proj, breakpoints)


def project_encode_pack(x: jax.Array, a: jax.Array, breakpoints: jax.Array,
                        *, K: int, L: int, block_n: int = 256,
                        interpret: bool = False):
    """x (n, d), a (d, L*K), breakpoints (L*K, Nr+1) -> same outputs as
    :func:`encode_pack` with the projection matmul fused into the pass
    (the streaming seal / frozen-breakpoint path, where no breakpoint
    selection sits between projection and encoding).  n and d must be
    block-aligned (ops.py pads rows to block_n and the feature dim to the
    128-lane MXU width)."""
    n, d = x.shape
    D = a.shape[1]
    assert D == L * K, (a.shape, L, K)
    E = breakpoints.shape[1]
    assert n % block_n == 0, (n, block_n)
    out_specs, out_shape = _out_shapes(n, K, L, block_n)
    return pl.pallas_call(
        lambda *refs: _kernel_from_data(*refs, K=K, L=L, Nr=E - 1),
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, D), lambda i: (0, 0)),
            pl.BlockSpec((D, E), lambda i: (0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x, a, breakpoints)
