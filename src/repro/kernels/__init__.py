"""Pallas TPU kernels for the paper's compute hot spots.

Per kernel: ``<name>.py`` holds the ``pl.pallas_call`` + BlockSpec VMEM
tiling; ``ops.py`` is the jit'd public wrapper (padding + impl dispatch);
``ref.py`` the pure-jnp oracle each kernel is validated against
(interpret mode on CPU; compiled Mosaic on TPU).

  lsh_project      — hashing matmul (MXU), the indexing-phase hot spot
  encode_bins      — iSAX region assignment (VPU compare-accumulate)
  build_fused      — one-pass build pipeline: project -> encode -> packed
                     interleaved sort keys, emitted straight into the
                     per-tree (L, n, K) layout (the indexing-phase engine;
                     docs/DESIGN.md §8)
  leaf_bounds      — DE-Tree LB/UB pruning distances (fused VPU)
  l2_rerank        — exact-distance rerank (MXU + fused norms)
  range_rerank     — fused batched range query: leaf LB + radius admission +
                     candidate gather + exact rerank in one grid pass (the
                     query-phase engine; grid carries the tree axis), with a
                     per-tile point validity/tombstone mask (streaming
                     deletes cost no extra pass)
  flash_attention  — online-softmax attention for the serving path
"""
