"""jit'd public wrappers for the Pallas kernels.

Each wrapper pads inputs to hardware-aligned block multiples, dispatches to
the Pallas kernel (TPU) / interpret mode (CPU tests) / the pure-jnp reference
(dry-run lowering), and slices the padding back off.

Implementation selection:
  * explicit ``interpret=True``  -> Pallas in interpret mode (CPU-correct);
  * backend == 'tpu'             -> compiled Pallas kernel;
  * otherwise                    -> ``repro.kernels.ref`` oracle (pure XLA).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import build_fused as _bf
from repro.kernels import lsh_project as _proj
from repro.kernels import encode_bins as _enc
from repro.kernels import leaf_bounds as _lb
from repro.kernels import l2_rerank as _l2
from repro.kernels import flash_attention as _fa
from repro.kernels import range_rerank as _rr


def _use_pallas(interpret: bool) -> bool:
    return interpret or jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int, value=0.0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def lsh_project(x, a, *, interpret: bool = False, block_n: int = 256):
    if not _use_pallas(interpret):
        return _ref.lsh_project(x, a)
    n, d = x.shape
    m = a.shape[1]
    xp = _pad_to(_pad_to(x, 0, block_n), 1, 128)
    ap = _pad_to(_pad_to(a, 0, 128), 1, 128)
    out = _proj.lsh_project(xp, ap, block_n=block_n, interpret=interpret)
    return out[:n, :m]


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def encode_bins(coords, breakpoints, *, interpret: bool = False,
                block_n: int = 512):
    if not _use_pallas(interpret):
        return _ref.encode_bins(coords, breakpoints)
    n = coords.shape[0]
    cp = _pad_to(coords, 0, block_n)
    out = _enc.encode_bins(cp, breakpoints, block_n=block_n,
                           interpret=interpret)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("K", "L", "interpret",
                                             "block_n"))
def encode_pack(proj, breakpoints, *, K: int, L: int,
                interpret: bool = False, block_n: int = 512):
    """Fused encode + interleaved-key pack (build pipeline; see
    kernels/build_fused.py).  proj (n, L*K) -> per-tree layouts
    (proj_t, codes_t, key_hi, key_lo); rows padded to ``block_n`` (the
    build chunk size) and sliced back off."""
    if not _use_pallas(interpret):
        return _ref.encode_pack(proj, breakpoints, K=K, L=L)
    n = proj.shape[0]
    pp = _pad_to(proj, 0, block_n)
    outs = _bf.encode_pack(pp, breakpoints, K=K, L=L, block_n=block_n,
                           interpret=interpret)
    return tuple(o[:, :n] for o in outs)


@functools.partial(jax.jit, static_argnames=("K", "L", "interpret",
                                             "block_n"))
def project_encode_pack(x, a, breakpoints, *, K: int, L: int,
                        interpret: bool = False, block_n: int = 256):
    """One-pass project -> encode -> key-pack (the frozen-breakpoint seal
    path; see kernels/build_fused.py).  x (n, d), a (d, L*K) -> per-tree
    layouts; rows padded to ``block_n``, the feature dim to the 128-lane
    MXU width (zero padding preserves the projection)."""
    if not _use_pallas(interpret):
        return _ref.project_encode_pack(x, a, breakpoints, K=K, L=L)
    n = x.shape[0]
    xp = _pad_to(_pad_to(x, 0, block_n), 1, 128)
    ap = _pad_to(a, 0, 128)
    outs = _bf.project_encode_pack(xp, ap, breakpoints, K=K, L=L,
                                   block_n=block_n, interpret=interpret)
    return tuple(o[:, :n] for o in outs)


@functools.partial(jax.jit, static_argnames=("interpret", "block_l"))
def leaf_bounds(q, leaf_lo, leaf_hi, leaf_valid, breakpoints, *,
                interpret: bool = False, block_l: int = 256):
    """Leaf bounds take int16 (storage-dtype) bounds; the kernel consumes
    int32, so the cast happens here at use."""
    if not _use_pallas(interpret):
        return _ref.leaf_bounds(q, leaf_lo, leaf_hi, leaf_valid, breakpoints)
    nl = leaf_lo.shape[0]
    lo = _pad_to(leaf_lo.astype(jnp.int32), 0, block_l)
    hi = _pad_to(leaf_hi.astype(jnp.int32), 0, block_l)
    va = _pad_to(leaf_valid, 0, block_l, value=False)
    lb, ub = _lb.leaf_bounds(q, lo, hi, va, breakpoints, block_l=block_l,
                             interpret=interpret)
    return lb[:nl], ub[:nl]


@functools.partial(jax.jit, static_argnames=("interpret", "block_q", "block_c"))
def l2_rerank(q, c, *, interpret: bool = False, block_q: int = 128,
              block_c: int = 256):
    if not _use_pallas(interpret):
        return _ref.l2_rerank(q, c)
    b, m = q.shape[0], c.shape[0]
    qp = _pad_to(q, 0, block_q)
    cp = _pad_to(c, 0, block_c)
    out = _l2.l2_rerank(qp, cp, block_q=block_q, block_c=block_c,
                        interpret=interpret)
    return out[:b, :m]


@functools.partial(jax.jit, static_argnames=("leaf_size", "probe_depth",
                                             "interpret", "block_q",
                                             "block_l"))
def range_rerank(q, q_proj, r_eff, leaf_lo, leaf_hi, leaf_valid, breakpoints,
                 points, point_valid, live=None, *, leaf_size: int,
                 probe_depth: int = 0, interpret: bool = False,
                 block_q: int = 8, block_l: int = 8):
    """Fused batched range query + rerank; see kernels/range_rerank.py.

    ``r_eff`` is (B,) per-lane radii shared across trees, or (L, B) per-tree
    radii (the multi-probe engine passes pre-widened per-tree radii).  With
    ``probe_depth > 0`` and 1-D radii the wrapper widens them itself via
    :func:`repro.kernels.ref.probe_radii` so the probe_depth best near-miss
    leaves per (tree, lane) are admitted alongside the radius box.

    Pads the query batch to ``block_q`` (padded lanes get r_eff = -1 so they
    admit nothing), the leaf axis to ``block_l`` (padded leaves invalid) and
    the feature dim to the 128-lane MXU width (zero padding preserves
    distances).  ``live`` is the optional (L, nl*leaf_size) per-point
    tombstone mask in sorted order (None = all live); dead points emit +inf
    inside the kernel tile, so deletes cost no extra pass.  Returns
    (L, B, nl*leaf_size).
    """
    if live is None:
        # pv & pv == pv: reusing the validity buffer as the live operand
        # keeps the all-live case allocation-free (no ones tensor).
        live = point_valid
    if probe_depth and r_eff.ndim == 1:
        r_eff = _ref.probe_radii(q_proj, leaf_lo.astype(jnp.int32),
                                 leaf_hi.astype(jnp.int32), leaf_valid,
                                 breakpoints, r_eff, probe_depth)
    if not _use_pallas(interpret):
        return _ref.range_rerank(q, q_proj, r_eff, leaf_lo, leaf_hi,
                                 leaf_valid, breakpoints, points, point_valid,
                                 live, leaf_size=leaf_size)
    L, B, K = q_proj.shape
    nl = leaf_lo.shape[1]
    npts = nl * leaf_size
    qp_b = _pad_to(_pad_to(q, 0, block_q), 1, 128)
    qproj_b = _pad_to(q_proj, 1, block_q)
    r2 = jnp.broadcast_to(r_eff, (L, B)) if r_eff.ndim == 1 else r_eff
    r_b = _pad_to(r2, 1, block_q, value=-1.0)
    lo_b = _pad_to(leaf_lo.astype(jnp.int32), 1, block_l)
    hi_b = _pad_to(leaf_hi.astype(jnp.int32), 1, block_l)
    lv_b = _pad_to(leaf_valid.astype(jnp.int32), 1, block_l)
    pts_b = _pad_to(_pad_to(points, 1, block_l * leaf_size), 2, 128)
    pv_b = _pad_to(point_valid.astype(jnp.int32), 1, block_l * leaf_size)
    lm_b = _pad_to(live.astype(jnp.int32), 1, block_l * leaf_size)
    out = _rr.range_rerank(qp_b, qproj_b, r_b, lo_b, hi_b, lv_b, breakpoints,
                           pts_b, pv_b, lm_b, leaf_size=leaf_size,
                           block_q=block_q, block_l=block_l,
                           interpret=interpret)
    return out[:, :B, :npts]


def range_rerank_heads(q, q_proj, r_eff, leaf_lo, leaf_hi, leaf_valid,
                       breakpoints, points, point_valid, live=None, *,
                       leaf_size: int, interpret: bool = False,
                       block_q: int = 8, block_l: int = 8):
    """Batched-*forest* fused range query + rerank (the KV-decode entry).

    Same contract as :func:`range_rerank` with one extra leading axis ``H``
    on every array argument: H independent forests (one per (batch,
    kv-head) in ``repro.decode``), each answering its own query batch.
    q (H, B, d); q_proj (H, L, B, K); r_eff (H, B); leaf arrays
    (H, L, nl, ...); points (H, L, nl*leaf_size, d).  Returns
    (H, L, B, nl*leaf_size).

    Implemented as ``jax.vmap`` over the single-forest wrapper: on CPU the
    ref oracle vmaps as plain XLA; on TPU the vmap lifts into a leading
    ``pallas_call`` grid dimension, so all H forests share one kernel
    launch instead of H dispatches.
    """
    if live is None:
        live = point_valid
    fn = functools.partial(range_rerank, leaf_size=leaf_size,
                           interpret=interpret, block_q=block_q,
                           block_l=block_l)
    return jax.vmap(fn)(q, q_proj, r_eff, leaf_lo, leaf_hi, leaf_valid,
                        breakpoints, points, point_valid, live)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "interpret",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = False,
                    scale: float | None = None, interpret: bool = False,
                    block_q: int = 128, block_k: int = 128):
    """q (b, h, sq, dh), k/v (b, h, sk, dh) -> (b, h, sq, dh)."""
    if not _use_pallas(interpret):
        return _ref.flash_attention(q, k, v, causal=causal, scale=scale)
    b, h, sq, dh = q.shape
    sk = k.shape[2]
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    qp = _pad_to(_pad_to(q.reshape(b * h, sq, dh), 1, block_q), 2, 128)
    kp = _pad_to(_pad_to(k.reshape(b * h, sk, dh), 1, block_k), 2, 128)
    vp = _pad_to(_pad_to(v.reshape(b * h, sk, dh), 1, block_k), 2, 128)
    out = _fa.flash_attention(qp, kp, vp, causal=causal, scale=scale,
                              block_q=block_q, block_k=block_k, sk_real=sk,
                              interpret=interpret)
    return out[:, :sq, :dh].reshape(b, h, sq, dh)
