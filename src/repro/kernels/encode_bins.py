"""Pallas kernel: iSAX dynamic encoding (paper Alg. 1 lines 5-8).

Assigns every projected coordinate its region id among N_r equi-depth
regions.  The paper uses a per-coordinate binary search; on the TPU VPU the
natural formulation is a compare-accumulate over the N_r-1 internal
breakpoints, fully vectorized over a (block_n, D) coordinate tile resident
in VMEM:  code = sum_b [x >= B[d, b]].  The breakpoint panel (D, Nr+1) also
sits in VMEM; the loop over b is a fori_loop so the kernel body stays small.

Identical output to jnp.searchsorted(side='right') per dimension.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(coords_ref, bp_ref, o_ref, *, Nr: int):
    x = coords_ref[...]                         # (bn, D)

    def body(b, acc):
        edges = bp_ref[:, b]                    # (D,) internal breakpoint b
        return acc + (x >= edges[None, :]).astype(jnp.int32)

    acc = jax.lax.fori_loop(1, Nr, body, jnp.zeros(x.shape, jnp.int32))
    o_ref[...] = jnp.clip(acc, 0, Nr - 1)


def encode_bins(coords: jax.Array, breakpoints: jax.Array, *,
                block_n: int = 512, interpret: bool = False) -> jax.Array:
    """coords (n, D), breakpoints (D, Nr+1) -> codes (n, D) int32."""
    n, D = coords.shape
    E = breakpoints.shape[1]
    Nr = E - 1
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    return pl.pallas_call(
        lambda c, b, o: _kernel(c, b, o, Nr=Nr),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, D), lambda i: (i, 0)),
            pl.BlockSpec((D, E), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, D), jnp.int32),
        interpret=interpret,
    )(coords, breakpoints)
