"""Pallas kernel: fused batched range query + exact rerank (one-pass).

The seed query round was three HBM round-trips per query per tree: leaf LB
pruning (``leaf_bounds``), candidate gather, then exact rerank
(``l2_rerank``).  This kernel fuses all of them into one grid pass per
(query-block, leaf-block) tile:

  1. leaf LB distances from the (block_l, K) leaf-summary tile (edge sweep,
     VPU — same formulation as ``leaf_bounds``);
  2. radius admission  LB <= r_eff[q]  (per-lane radii; a *done* query lane
     carries r_eff = -1 and admits nothing — the active-lane mask costs no
     extra input);
  3. the "gather" is free: leaves are contiguous blocks of the code-sorted
     point array, so the leaf-block grid index *is* the candidate gather;
  4. exact original-space distances of the (block_q, d) query tile against
     the (block_l*leaf_size, d) point tile on the MXU, masked to +inf
     outside admitted leaves.

Leaf summaries and sorted points therefore stream through VMEM once per
query *block* instead of once per query.  Admission is leaf-granular
(paper §VI-B2 optimization #1) without the seed's top-M truncation: every
leaf whose LB passes the radius contributes, which admits a superset of the
strict Alg. 3 rule and preserves the quality guarantees
(docs/DESIGN.md §3).

Grid: (L, B/block_q, nl/block_l) — the tree axis rides the grid, so one
pallas_call serves the whole forest.  When every lane of a query tile is
inactive (or no leaf is admitted) the MXU work is skipped via ``pl.when``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, qp_ref, r_ref, lo_ref, hi_ref, lv_ref, bp_ref, pts_ref,
            pv_ref, live_ref, o_ref, *, E: int, K: int, leaf_size: int):
    lo = lo_ref[0]                                     # (bl, K) int32
    hi = hi_ref[0] + 1                                 # upper edge index
    qp = qp_ref[0]                                     # (bq, K) f32
    r_eff = r_ref[0]                                   # (bq,) f32; -1 = done

    # Edge sweep: materialize the leaf bounding-box edge coordinates without
    # a gather (bp[k, lo[j,k]] expressed as select-accumulate over E edges).
    def body(b, carry):
        b_lo, b_hi = carry
        edge = bp_ref[0, :, b]                         # (K,)
        b_lo = jnp.where(lo == b, edge[None, :], b_lo)
        b_hi = jnp.where(hi == b, edge[None, :], b_hi)
        return b_lo, b_hi

    zeros = jnp.zeros(lo.shape, jnp.float32)
    b_lo, b_hi = jax.lax.fori_loop(0, E, body, (zeros, zeros))

    # LB distance per (query, leaf): accumulate per-dimension clamped gaps.
    # K is small and static — unrolled 2D VPU ops, no (bq, bl, K) tensor.
    acc = jnp.zeros((qp.shape[0], lo.shape[0]), jnp.float32)
    for k in range(K):
        d_lo = b_lo[:, k][None, :] - qp[:, k][:, None]     # (bq, bl)
        d_hi = qp[:, k][:, None] - b_hi[:, k][None, :]
        t = jnp.maximum(jnp.maximum(d_lo, d_hi), 0.0)
        acc = acc + t * t
    lb = jnp.sqrt(acc)

    valid = lv_ref[0] != 0                             # (bl,)
    admit = (lb <= r_eff[:, None]) & valid[None, :]    # (bq, bl)

    inf = jnp.float32(jnp.inf)

    @pl.when(jnp.any(admit))
    def _compute():
        q = q_ref[...].astype(jnp.float32)             # (bq, d)
        pts = pts_ref[0].astype(jnp.float32)           # (bl*ls, d)
        qq = jnp.sum(q * q, axis=1, keepdims=True)
        pp = jnp.sum(pts * pts, axis=1)[None, :]
        qc = jax.lax.dot_general(q, pts, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        dist = jnp.sqrt(jnp.maximum(qq - 2.0 * qc + pp, 0.0))
        mask = jnp.repeat(admit, leaf_size, axis=1)    # (bq, bl*ls)
        mask = mask & ((pv_ref[0] != 0) & (live_ref[0] != 0))[None, :]
        o_ref[0] = jnp.where(mask, dist, inf)

    @pl.when(~jnp.any(admit))
    def _skip():
        o_ref[0] = jnp.full(o_ref.shape[1:], inf, jnp.float32)


def range_rerank(q: jax.Array, q_proj: jax.Array, r_eff: jax.Array,
                 leaf_lo: jax.Array, leaf_hi: jax.Array,
                 leaf_valid: jax.Array, breakpoints: jax.Array,
                 points: jax.Array, point_valid: jax.Array,
                 live: jax.Array, *,
                 leaf_size: int, block_q: int = 8, block_l: int = 8,
                 interpret: bool = False) -> jax.Array:
    """Fused range query + rerank over all L trees.

    q (B, d) original-space queries; q_proj (L, B, K); r_eff (L, B)
    per-(tree, lane) projected admission radii (eps*r broadcast over trees
    for plain radius rounds; per-tree probe-widened radii for multi-probe
    rounds; -1 for done lanes); leaf_lo/hi (L, nl, K) int32;
    leaf_valid (L, nl) int32; breakpoints (L, K, E); points (L, nl*ls, d)
    code-sorted original-space points; point_valid (L, nl*ls) int32;
    live (L, nl*ls) int32 — per-point tombstone mask in sorted order (0 =
    deleted; the streaming index's delete path, same tiling as point_valid).

    Returns (L, B, nl*ls) f32: exact distance where the covering leaf is
    admitted at radius r_eff and the point is valid and live, +inf
    elsewhere.  B and nl must be block multiples (ops.py pads).
    """
    L, B, K = q_proj.shape
    d = q.shape[1]
    nl = leaf_lo.shape[1]
    E = breakpoints.shape[2]
    npts = nl * leaf_size
    assert B % block_q == 0 and nl % block_l == 0, (B, nl, block_q, block_l)
    assert points.shape == (L, npts, d), (points.shape, L, npts, d)
    assert r_eff.shape == (L, B), (r_eff.shape, L, B)
    grid = (L, B // block_q, nl // block_l)
    return pl.pallas_call(
        lambda *refs: _kernel(*refs, E=E, K=K, leaf_size=leaf_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda l, i, j: (i, 0)),
            pl.BlockSpec((1, block_q, K), lambda l, i, j: (l, i, 0)),
            pl.BlockSpec((1, block_q), lambda l, i, j: (l, i)),
            pl.BlockSpec((1, block_l, K), lambda l, i, j: (l, j, 0)),
            pl.BlockSpec((1, block_l, K), lambda l, i, j: (l, j, 0)),
            pl.BlockSpec((1, block_l), lambda l, i, j: (l, j)),
            pl.BlockSpec((1, K, E), lambda l, i, j: (l, 0, 0)),
            pl.BlockSpec((1, block_l * leaf_size, d),
                         lambda l, i, j: (l, j, 0)),
            pl.BlockSpec((1, block_l * leaf_size), lambda l, i, j: (l, j)),
            pl.BlockSpec((1, block_l * leaf_size), lambda l, i, j: (l, j)),
        ],
        out_specs=pl.BlockSpec((1, block_q, block_l * leaf_size),
                               lambda l, i, j: (l, i, j)),
        out_shape=jax.ShapeDtypeStruct((L, B, npts), jnp.float32),
        interpret=interpret,
    )(q, q_proj, r_eff, leaf_lo, leaf_hi, leaf_valid.astype(jnp.int32),
      breakpoints, points, point_valid.astype(jnp.int32),
      live.astype(jnp.int32))
