"""Pallas kernel: p-stable LSH projection — X(n,d) @ A(d, K*L).

The hashing phase of DET-LSH (paper: "computing hash values for n points",
O(L*K*n*d), the dominant indexing FLOPs).  A tall-skinny matmul: n is large,
m = K*L is small (typically 64).  Tiling: grid over row blocks of X; each
program loads an (bn, d) X tile and the full (d, m) A panel into VMEM and
issues one MXU matmul.  m and d are padded to the 128-lane boundary by the
ops.py wrapper so every matmul dimension is hardware-aligned.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, a_ref, o_ref):
    x = x_ref[...]
    a = a_ref[...]
    o_ref[...] = jax.lax.dot_general(
        x, a, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def lsh_project(x: jax.Array, a: jax.Array, *, block_n: int = 256,
                interpret: bool = False) -> jax.Array:
    """x (n, d), a (d, m) -> (n, m) f32.  n, d, m must be block-aligned
    (the ops.py wrapper pads)."""
    n, d = x.shape
    m = a.shape[1]
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(x, a)
