"""Pallas kernel: DE-Tree node LB/UB distances (paper Fig. 5).

For each leaf with per-dimension occupied-region interval [lo, hi], computes
the lower/upper bound Euclidean distances between a projected query and any
point in the leaf.  This is the pruning hot loop of the range query: one
evaluation per (query, leaf) pair.

TPU formulation: the breakpoint-coordinate gather (bp[k, lo[i,k]]) is
re-expressed as a select-accumulate sweep over the Nr+1 edges so the whole
computation is dense VPU math on VMEM tiles — no scatter/gather.  The edge
sweep, subtraction, clamp, square, row-sum and sqrt are all fused in one
kernel pass over a (block_l, K) leaf tile.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, lo_ref, hi_ref, valid_ref, bp_ref, lb_ref, ub_ref, *,
            E: int):
    lo = lo_ref[...]                                   # (bl, K) int32
    hi = hi_ref[...] + 1                               # upper edge index
    q = q_ref[...]                                     # (1, K)

    def body(b, carry):
        b_lo, b_hi = carry
        edge = bp_ref[:, b]                            # (K,)
        b_lo = jnp.where(lo == b, edge[None, :], b_lo)
        b_hi = jnp.where(hi == b, edge[None, :], b_hi)
        return b_lo, b_hi

    zeros = jnp.zeros(lo.shape, jnp.float32)
    b_lo, b_hi = jax.lax.fori_loop(0, E, body, (zeros, zeros))

    d_lo = b_lo - q
    d_hi = q - b_hi
    lb_dim = jnp.maximum(jnp.maximum(d_lo, d_hi), 0.0)
    ub_dim = jnp.maximum(jnp.abs(q - b_lo), jnp.abs(q - b_hi))
    lb = jnp.sqrt(jnp.sum(lb_dim * lb_dim, axis=1))
    ub = jnp.sqrt(jnp.sum(ub_dim * ub_dim, axis=1))
    valid = valid_ref[...] != 0
    big = jnp.float32(jnp.inf)
    lb_ref[...] = jnp.where(valid, lb, big)
    ub_ref[...] = jnp.where(valid, ub, big)


def leaf_bounds(q: jax.Array, leaf_lo: jax.Array, leaf_hi: jax.Array,
                leaf_valid: jax.Array, breakpoints: jax.Array, *,
                block_l: int = 256, interpret: bool = False
                ) -> tuple[jax.Array, jax.Array]:
    """q (K,), leaf_lo/hi (nl, K) int32, valid (nl,), bp (K, Nr+1)
    -> (lb, ub) each (nl,) f32.  nl must be block-aligned (ops.py pads)."""
    nl, K = leaf_lo.shape
    E = breakpoints.shape[1]
    assert nl % block_l == 0, (nl, block_l)
    grid = (nl // block_l,)
    lb, ub = pl.pallas_call(
        lambda qr, lo, hi, va, bp, lbr, ubr: _kernel(
            qr, lo, hi, va, bp, lbr, ubr, E=E),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, K), lambda i: (0, 0)),
            pl.BlockSpec((block_l, K), lambda i: (i, 0)),
            pl.BlockSpec((block_l, K), lambda i: (i, 0)),
            pl.BlockSpec((block_l,), lambda i: (i,)),
            pl.BlockSpec((K, E), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_l,), lambda i: (i,)),
            pl.BlockSpec((block_l,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nl,), jnp.float32),
            jax.ShapeDtypeStruct((nl,), jnp.float32),
        ],
        interpret=interpret,
    )(q[None, :], leaf_lo, leaf_hi, leaf_valid.astype(jnp.int32), breakpoints)
    return lb, ub
