"""Pallas kernel: FlashAttention forward (online softmax, VMEM-tiled).

Used by the serving path (prefill/decode exact attention and the exact
re-scoring step of DET-attention).  Never materializes the (sq, sk) score
matrix: grid = (batch*heads, sq/block_q, sk/block_k) with the k-dimension
iterated sequentially ("arbitrary" semantics) while running max / sum /
accumulator tiles persist in VMEM scratch.

MXU alignment: block_q/block_k default to 128; dh is padded to a multiple of
128 by the ops.py wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            sk: int, block_q: int, block_k: int, nk: int, causal: bool,
            scale: float):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    q = q_ref[0].astype(jnp.float32) * scale            # (bq, dh)
    k = k_ref[0].astype(jnp.float32)                    # (bk, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < sk                                    # padding
    if causal:
        q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        mask = mask & (k_pos <= q_pos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                  # (bq, 1)
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)                               # (bq, bk)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha + pv
    m_scr[...] = m_cur

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = False, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128, sk_real: int = 0,
                    interpret: bool = False) -> jax.Array:
    """q (bh, sq, dh), k/v (bh, sk, dh) -> (bh, sq, dh).

    sq, sk, dh must be block-aligned (ops.py pads); ``sk_real`` masks key
    padding (0 = no padding).
    """
    bh, sq, dh = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk)
    nq, nk = sq // block_q, sk // block_k
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    sk_real = sk_real or sk

    kern = functools.partial(_kernel, sk=sk_real, block_q=block_q,
                             block_k=block_k, nk=nk, causal=causal,
                             scale=scale)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    return pl.pallas_call(
        kern,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(q, k, v)
