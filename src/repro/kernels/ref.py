"""Pure-jnp oracles for every Pallas kernel.

These are the semantics of record: each kernel's test sweeps shapes/dtypes
and asserts allclose against the function here.  They are also the
implementations the multi-pod dry-run lowers (the CPU backend cannot compile
Mosaic/TPU custom calls), so they are written to be XLA-memory-sane
(blockwise attention never materializes the full score matrix).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp


def lsh_project(x: jax.Array, a: jax.Array) -> jax.Array:
    """(n, d) @ (d, m) -> (n, m) in f32 accumulation."""
    return jnp.dot(x, a, preferred_element_type=jnp.float32)


def encode_bins(coords: jax.Array, breakpoints: jax.Array) -> jax.Array:
    """coords (n, D), breakpoints (D, Nr+1) -> region ids (n, D) int32.

    Region b = #(internal breakpoints <= x), clipped to [0, Nr-1]; identical
    to ``repro.core.encoding.encode``.
    """
    D, E = breakpoints.shape
    Nr = E - 1
    inner = breakpoints[:, 1:Nr]                         # (D, Nr-1)
    ge = coords[:, :, None] >= inner[None, :, :]         # (n, D, Nr-1)
    return jnp.clip(ge.sum(-1), 0, Nr - 1).astype(jnp.int32)


def encode_pack(proj: jax.Array, breakpoints: jax.Array, *, K: int,
                L: int) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused build pipeline oracle: encode + interleaved key-pack.

    proj (n, L*K), breakpoints (L*K, Nr+1) -> (proj_t (L, n, K) f32,
    codes_t (L, n, K) int32, key_hi (L, n) uint32, key_lo (L, n) uint32).
    Codes are identical to ``encode_bins``; key words are identical to
    ``repro.core.detree.interleave_keys`` per tree.
    """
    from repro.core.detree import interleave_keys
    n = proj.shape[0]
    # Same codes as ``encode_bins`` (tested), via the O(n D log Nr)
    # searchsorted form: this oracle IS the CPU build path, and the
    # kernel's O(Nr) compare-sweep formulation is an XLA memory/time hog
    # off-TPU (it materializes the (n, D, Nr-1) compare tensor).
    D, E = breakpoints.shape
    Nr = E - 1
    inner = breakpoints[:, 1:Nr]
    bins = jax.vmap(lambda e, col: jnp.searchsorted(e, col, side="right"),
                    in_axes=(0, 1), out_axes=1)(inner, proj)
    codes = jnp.clip(bins, 0, Nr - 1).astype(jnp.int32)  # (n, L*K)
    proj_t = proj.reshape(n, L, K).transpose(1, 0, 2)
    codes_t = codes.reshape(n, L, K).transpose(1, 0, 2)
    key_hi, key_lo = interleave_keys(codes_t, K)         # (L, n) each
    return proj_t, codes_t, key_hi, key_lo


def project_encode_pack(x: jax.Array, a: jax.Array, breakpoints: jax.Array,
                        *, K: int, L: int):
    """Projection-fused variant of :func:`encode_pack` (the frozen-
    breakpoint seal path): x (n, d), a (d, L*K) -> same outputs."""
    return encode_pack(lsh_project(x, a), breakpoints, K=K, L=L)


def leaf_bounds(q: jax.Array, leaf_lo: jax.Array, leaf_hi: jax.Array,
                leaf_valid: jax.Array,
                breakpoints: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Fig. 5 LB/UB.  q (K,), leaf_lo/hi (nl, K) int32, bp (K, Nr+1)."""
    E = breakpoints.shape[1]

    def gather(idx):
        idx = jnp.clip(idx, 0, E - 1)
        return jax.vmap(lambda bk, ik: bk[ik], in_axes=(0, 1), out_axes=1)(
            breakpoints, idx)

    b_lo = gather(leaf_lo)
    # Widen at use even though ops.py already widens at the kernel boundary:
    # int16 leaf_hi would wrap at 32767 here, and this reference path is
    # also called directly by the equivalence tests.
    b_hi = gather(leaf_hi.astype(jnp.int32) + 1)
    d_lo = b_lo - q[None, :]
    d_hi = q[None, :] - b_hi
    lb_dim = jnp.maximum(jnp.maximum(d_lo, d_hi), 0.0)
    ub_dim = jnp.maximum(jnp.abs(q[None, :] - b_lo), jnp.abs(q[None, :] - b_hi))
    lb = jnp.sqrt((lb_dim * lb_dim).sum(-1))
    ub = jnp.sqrt((ub_dim * ub_dim).sum(-1))
    lb = jnp.where(leaf_valid, lb, jnp.inf)
    ub = jnp.where(leaf_valid, ub, jnp.inf)
    return lb, ub


def forest_leaf_lb(q_proj: jax.Array, leaf_lo: jax.Array, leaf_hi: jax.Array,
                   leaf_valid: jax.Array,
                   breakpoints: jax.Array) -> jax.Array:
    """Leaf LB distances for the whole forest at once.

    q_proj (L, B, K); leaf_lo/hi (L, nl, K); leaf_valid (L, nl);
    breakpoints (L, K, E) -> (L, B, nl) f32, +inf for invalid leaves.
    Radius-independent: the fused engine computes this once per batch and
    reuses it across rounds to rank probe candidates.
    """
    def per_tree(qp_t, lo_t, hi_t, lv_t, bp_t):
        return jax.vmap(
            lambda qp: leaf_bounds(qp, lo_t, hi_t, lv_t, bp_t)[0])(qp_t)

    return jax.vmap(per_tree)(q_proj, leaf_lo, leaf_hi,
                              leaf_valid.astype(jnp.bool_), breakpoints)


def probe_radii_from_lb(lb: jax.Array, r_eff: jax.Array,
                        probe_depth: int) -> tuple[jax.Array, jax.Array]:
    """Probe-widened admission radii from a leaf-LB table.

    lb (L, B, nl) leaf LBs (+inf for invalid leaves); r_eff (B,) radius per
    lane (-1 = done).  Per (tree, lane), widen the radius to also admit the
    ``probe_depth`` valid leaves with the smallest LB *above* r_eff — the
    near-miss leaves ranked by LB slack.  Done lanes keep r_eff = -1 and
    never probe.

    Returns (r_adm (L, B), probe_mask (L, B, nl)).  ``lb <= r_adm`` admits
    exactly the within-radius leaves plus the probe set (LB ties can admit
    a few more — a superset, which preserves the quality guarantees).  When
    a (tree, lane) has fewer than probe_depth near-miss leaves the k-th
    slack is +inf and every valid leaf is admitted.
    """
    L, B, nl = lb.shape
    outside = lb > r_eff[None, :, None]                # invalid leaves too
    slack = jnp.where(outside & jnp.isfinite(lb), lb, jnp.inf)
    depth = min(int(probe_depth), nl)
    kth = -jax.lax.top_k(-slack, depth)[0][..., -1]    # depth-th smallest
    # The depth-th probe leaf sits exactly ON the widened radius (r_adm is
    # its LB by construction), and the fused kernel recomputes leaf LBs
    # in-tile with a different accumulation order — a 1-ulp discrepancy
    # would silently drop the boundary leaf.  One relative-epsilon nudge
    # keeps it in; epsilon ties admit at most a few extra leaves (still a
    # superset, so the quality guarantees are untouched).
    kth = jnp.where(jnp.isfinite(kth), kth * (1 + 1e-5) + 1e-6, kth)
    r_adm = jnp.maximum(r_eff[None, :], kth)
    r_adm = jnp.where(r_eff[None, :] < 0, r_eff[None, :], r_adm)
    probe_mask = outside & jnp.isfinite(lb) & (lb <= r_adm[..., None])
    return r_adm, probe_mask


def probe_radii(q_proj: jax.Array, leaf_lo: jax.Array, leaf_hi: jax.Array,
                leaf_valid: jax.Array, breakpoints: jax.Array,
                r_eff: jax.Array, probe_depth: int) -> jax.Array:
    """Convenience composition: leaf-LB table -> probe-widened (L, B) radii."""
    lb = forest_leaf_lb(q_proj, leaf_lo, leaf_hi, leaf_valid, breakpoints)
    return probe_radii_from_lb(lb, r_eff, probe_depth)[0]


def l2_rerank(q: jax.Array, c: jax.Array) -> jax.Array:
    """Exact Euclidean distances: q (b, d), c (m, d) -> (b, m)."""
    qq = (q.astype(jnp.float32) ** 2).sum(-1, keepdims=True)      # (b, 1)
    cc = (c.astype(jnp.float32) ** 2).sum(-1)[None, :]            # (1, m)
    qc = jnp.dot(q, c.T, preferred_element_type=jnp.float32)
    return jnp.sqrt(jnp.maximum(qq - 2.0 * qc + cc, 0.0))


def range_rerank(q: jax.Array, q_proj: jax.Array, r_eff: jax.Array,
                 leaf_lo: jax.Array, leaf_hi: jax.Array,
                 leaf_valid: jax.Array, breakpoints: jax.Array,
                 points: jax.Array, point_valid: jax.Array,
                 live: jax.Array | None = None, *,
                 leaf_size: int, probe_depth: int = 0) -> jax.Array:
    """Fused batched range query + exact rerank (semantics of record).

    q (B, d); q_proj (L, B, K); r_eff projected admission radii — either
    (B,) shared across trees or (L, B) per-tree (-1 = inactive lane);
    leaf_lo/hi (L, nl, K); leaf_valid (L, nl); breakpoints (L, K, E);
    points (L, nl*leaf_size, d) code-sorted original-space points;
    point_valid (L, nl*leaf_size); live (L, nl*leaf_size) per-point
    tombstone mask in sorted order (None = all live).

    With probe_depth > 0 and 1-D r_eff the radii are first widened per
    (tree, lane) via :func:`probe_radii` so the ``probe_depth`` nearest
    near-miss leaves are admitted too (multi-probe rounds).

    Returns (L, B, nl*leaf_size) f32: the exact original-space distance for
    every live point whose covering leaf has LB <= r_eff (leaf-granular
    admission, paper §VI-B2 opt. #1, *without* a top-M cut), +inf elsewhere.
    """
    if live is None:
        live = jnp.ones_like(point_valid)
    L = q_proj.shape[0]
    B = q_proj.shape[1]
    if probe_depth and r_eff.ndim == 1:
        r_eff = probe_radii(q_proj, leaf_lo, leaf_hi, leaf_valid,
                            breakpoints, r_eff, probe_depth)
    r2 = jnp.broadcast_to(r_eff, (L, B)) if r_eff.ndim == 1 else r_eff

    def per_tree(qp_t, r_t, lo_t, hi_t, lv_t, bp_t, pts_t, pv_t, lm_t):
        lb, _ = jax.vmap(
            lambda qp: leaf_bounds(qp, lo_t, hi_t, lv_t, bp_t))(qp_t)
        admit = (lb <= r_t[:, None]) & lv_t[None, :]         # (B, nl)
        dist = l2_rerank(q, pts_t)                           # (B, nl*ls)
        mask = jnp.repeat(admit, leaf_size, axis=1) & (pv_t & lm_t)[None, :]
        return jnp.where(mask, dist, jnp.inf)

    return jax.vmap(per_tree)(q_proj, r2, leaf_lo, leaf_hi,
                              leaf_valid.astype(jnp.bool_), breakpoints,
                              points, point_valid.astype(jnp.bool_),
                              live.astype(jnp.bool_))


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = False, scale: float | None = None,
                    block_k: int = 512) -> jax.Array:
    """Blockwise (online-softmax) attention — never materializes (sq, sk).

    q (b, h, sq, dh); k/v (b, h, sk, dh).  This is both the oracle for the
    Pallas kernel and the XLA implementation the dry-run compiles.
    """
    b, h, sq, dh = q.shape
    sk = k.shape[2]
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    qf = (q * scale).astype(jnp.float32)
    nblk = -(-sk // block_k)
    pad = nblk * block_k - sk
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = kp.reshape(b, h, nblk, block_k, dh)
    vb = vp.reshape(b, h, nblk, block_k, dh)
    kpos = jnp.arange(nblk * block_k).reshape(nblk, block_k)
    qpos = jnp.arange(sq)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        kblk, vblk, kp_blk = inp                     # (b,h,bk,dh) etc.
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kblk.astype(jnp.float32))
        mask = kp_blk[None, :] < sk                  # padding
        if causal:
            mask = mask & (kp_blk[None, :] <= qpos[:, None])
        s = jnp.where(mask[None, None, :, :], s, -jnp.inf)
        m_cur = jnp.maximum(m_prev, s.max(-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[..., None])
        l_cur = l_prev * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vblk.astype(jnp.float32))
        return (m_cur, l_cur, acc), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0),
        (kb.transpose(2, 0, 1, 3, 4), vb.transpose(2, 0, 1, 3, 4), kpos))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype)


def attention_reference(q, k, v, *, causal=False, scale=None):
    """Naive softmax attention (materializes scores) — oracle's oracle."""
    b, h, sq, dh = q.shape
    sk = k.shape[2]
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
