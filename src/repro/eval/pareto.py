"""Recall/QPS Pareto harness over the AnnIndex protocol (DESIGN.md §10).

One measurement path for every method: build an index (timed), drive it
through ``AnnIndex.search`` with a ``SearchRequest``, and record a
``CurvePoint`` per (index, request) knob setting:

  * ``recall``          — recall@k against exact ground truth;
  * ``qps``             — queries/s of the batched search (best of
    ``repeat`` runs, post-warmup, device-synchronized);
  * ``work_per_query``  — mean ``SearchStats.n_candidates``: the method's
    exact-distance-equivalent evaluations per query.  This is the
    hardware-neutral cost axis — on CPU smoke shapes a brute-force scan is
    one BLAS matmul and wall clock rewards it unconditionally, so QPS
    alone cannot rank algorithms at benchmark scale (the paper's candidate
    counts, Fig. 17-18, play the same role);
  * ``build_seconds`` / ``index_bytes``.

``detlsh_points`` sweeps IndexSpecs (K, L, leaf_size, ...) x SearchRequests
(M, max_rounds, engine); ``baseline_points`` sweeps prebuilt protocol
baselines (knob variants via ``dataclasses.replace``); ``pareto_front``
extracts the non-dominated set; ``dominates_at_recall`` is the smoke
gate's sanity predicate.  ``run_pareto`` bundles everything into the
JSON-ready dict ``benchmarks/pareto_smoke.py`` writes to BENCH_pareto.json.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Optional, Sequence

import numpy as np

from repro.api.request import SearchRequest


@dataclasses.dataclass(frozen=True)
class CurvePoint:
    method: str               # 'det-lsh' | 'brute-force' | 'hnsw' | ...
    label: str                # knob setting, e.g. 'K4-L4-M8'
    recall: float
    qps: float
    work_per_query: float     # mean exact-distance-equivalent evals
    build_seconds: float
    index_bytes: int
    params: dict              # the knobs that produced this point
    probe_depth: int = 0      # multi-probe near-miss leaves per (tree, round)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _block(res) -> None:
    ids = res.ids
    if hasattr(ids, "block_until_ready"):
        ids.block_until_ready()


def _recall_at_k(ids, gt_ids) -> float:
    ids = np.asarray(ids)
    gt = np.asarray(gt_ids)[:, : ids.shape[1]]
    hits = (ids[:, :, None] == gt[:, None, :]).any(axis=1)
    return float(hits.mean())


def measure(method: str, label: str, index: Any, queries, gt_ids,
            request: SearchRequest, *, build_seconds: float,
            repeat: int = 3, params: Optional[dict] = None) -> CurvePoint:
    """One protocol-driven measurement: recall from a scored run, QPS as
    the best of ``repeat`` timed runs (run 0 doubles as compile warmup)."""
    res = index.search(queries, request)
    _block(res)
    rec = _recall_at_k(res.ids, gt_ids)
    nc = res.stats.n_candidates
    work = float(np.mean(np.asarray(nc))) if nc is not None \
        else float(index.n_points)
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        _block(index.search(queries, request))
        best = min(best, time.perf_counter() - t0)
    nq = int(np.asarray(queries).shape[0])
    return CurvePoint(method=method, label=label, recall=rec,
                      qps=nq / max(best, 1e-9), work_per_query=work,
                      build_seconds=build_seconds,
                      index_bytes=int(index.index_size_bytes()),
                      params=dict(params or {}, k=request.k),
                      probe_depth=int(request.probe_depth or 0))


def detlsh_points(data, queries, gt_ids, key, *, k: int = 10,
                  specs: Sequence = (), Ms: Sequence[int] = (8,),
                  max_rounds: Sequence[int] = (48,),
                  engines: Sequence[str] = ("fused",),
                  probe_depths: Sequence[int] = (0,),
                  repeat: int = 3) -> list[CurvePoint]:
    """Sweep (IndexSpec) x (M, max_rounds, engine, probe_depth) through
    ``api.build``.

    ``M`` (the per-round leaf budget) only steers the vmap engine;
    ``probe_depth`` steers both engines (multi-probe near-miss admission;
    0 = classic radius rounds).  Pairing axes is the caller's sweep design.
    """
    from repro import api
    points = []
    for spec in specs:
        t0 = time.perf_counter()
        index = api.build(data, key, spec)
        _block(index.search(queries[:1], SearchRequest(k=k)))   # build+warm
        t_build = time.perf_counter() - t0
        for M, mr, eng, pd in itertools.product(Ms, max_rounds, engines,
                                                probe_depths):
            req = SearchRequest(k=k, M=M, max_rounds=mr, engine=eng,
                                probe_depth=pd)
            label = (f"K{spec.K}-L{spec.L}-ls{spec.leaf_size}-M{M}-r{mr}"
                     f"-p{pd}-{eng}")
            points.append(measure(
                "det-lsh", label, index, queries, gt_ids, req,
                build_seconds=t_build, repeat=repeat,
                params=dict(K=spec.K, L=spec.L, leaf_size=spec.leaf_size,
                            Nr=spec.Nr, M=M, max_rounds=mr, engine=eng,
                            probe_depth=pd)))
    return points


def baseline_points(method: str, variants, queries, gt_ids, *, k: int = 10,
                    repeat: int = 3) -> list[CurvePoint]:
    """``variants``: iterable of (label, index, build_seconds, params);
    each index must carry the AnnIndex surface (ProtocolBaseline)."""
    req = SearchRequest(k=k)
    return [measure(method, label, index, queries, gt_ids, req,
                    build_seconds=t_build, repeat=repeat, params=params)
            for label, index, t_build, params in variants]


def pareto_front(points: Sequence[CurvePoint],
                 y: str = "qps") -> list[int]:
    """Indices of the non-dominated points on (recall up, ``y``);
    ``y='qps'`` maximizes, ``y='work_per_query'`` minimizes."""
    sign = -1.0 if y == "work_per_query" else 1.0
    front = []
    for i, p in enumerate(points):
        dominated = any(
            q.recall >= p.recall
            and sign * getattr(q, y) >= sign * getattr(p, y)
            and (q.recall > p.recall
                 or sign * getattr(q, y) > sign * getattr(p, y))
            for q in points)
        if not dominated:
            front.append(i)
    return front


def dominates_at_recall(points: Sequence[CurvePoint], *,
                        method: str = "det-lsh",
                        reference: str = "brute-force",
                        min_recall: float = 0.9) -> dict:
    """The smoke gate: does ``method`` reach ``min_recall`` doing strictly
    less work per query than ``reference``?  Returns the evidence."""
    ref_work = [p.work_per_query for p in points if p.method == reference]
    ok_pts = [p for p in points
              if p.method == method and p.recall >= min_recall]
    if not ref_work or not ok_pts:
        return {"ok": False, "reason": f"missing {reference} points"
                if not ref_work else f"no {method} point with recall >= "
                f"{min_recall}", "min_recall": min_recall}
    ref = min(ref_work)
    best = min(ok_pts, key=lambda p: p.work_per_query)
    return {"ok": best.work_per_query < ref, "min_recall": min_recall,
            "reference_work": ref, "best_work": best.work_per_query,
            "best_label": best.label, "best_recall": best.recall}


def run_pareto(data, queries, key, *, k: int = 10, specs: Sequence = (),
               Ms: Sequence[int] = (8,), max_rounds: Sequence[int] = (48,),
               engines: Sequence[str] = ("fused",),
               probe_depths: Sequence[int] = (0,),
               baselines: Optional[dict] = None, repeat: int = 3,
               min_recall: float = 0.9) -> dict:
    """Full sweep -> JSON-ready dict (the BENCH_pareto.json payload).

    ``baselines``: {method: variants} as ``baseline_points`` expects.
    Ground truth comes from the BruteForce oracle (which then also
    contributes its own curve points).
    """
    from repro.baselines import BruteForce

    bf = BruteForce.build(data)
    gt = bf.search(queries, SearchRequest(k=k))
    _block(gt)
    points = detlsh_points(data, queries, gt.ids, key, k=k, specs=specs,
                           Ms=Ms, max_rounds=max_rounds, engines=engines,
                           probe_depths=probe_depths, repeat=repeat)
    points += baseline_points(
        "brute-force", [("scan", bf, 0.0, {})], queries, gt.ids, k=k,
        repeat=repeat)
    for name, variants in (baselines or {}).items():
        points += baseline_points(name, variants, queries, gt.ids, k=k,
                                  repeat=repeat)
    gate = dominates_at_recall(points, min_recall=min_recall)
    return {
        "k": k, "n": int(np.asarray(data).shape[0]),
        "d": int(np.asarray(data).shape[1]),
        "n_queries": int(np.asarray(queries).shape[0]),
        "methods": sorted({p.method for p in points}),
        "points": [p.to_dict() for p in points],
        "front_qps": pareto_front(points, y="qps"),
        "front_work": pareto_front(points, y="work_per_query"),
        "det_dominates_brute": gate,
    }
