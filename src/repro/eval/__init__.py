"""Evaluation harnesses over the ``repro.api`` protocol surface.

``repro.eval.pareto`` sweeps build/search knobs for DET-LSH and the
baselines — every method driven through ``AnnIndex.search`` — and emits
(recall@k, QPS, work/query, build-time) curves plus their Pareto front.
"""

from repro.eval.pareto import (CurvePoint, baseline_points, detlsh_points,
                               dominates_at_recall, measure, pareto_front,
                               run_pareto)

__all__ = ["CurvePoint", "measure", "detlsh_points", "baseline_points",
           "pareto_front", "dominates_at_recall", "run_pareto"]
