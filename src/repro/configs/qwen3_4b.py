"""qwen3-4b [dense] — GQA + qk_norm.  [hf:Qwen/Qwen3-8B family; hf]"""

from repro.configs.base import ModelConfig, ParallelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b",
        family="dense",
        n_layers=36,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=9728,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        parallel=ParallelConfig(accum_steps=4),
        shape_names=("train_4k", "prefill_32k", "decode_32k"),
    )
