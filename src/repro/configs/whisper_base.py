"""whisper-base [audio] — enc-dec backbone, conv frontend stubbed.

[arXiv:2212.04356; unverified]  6L (enc+dec) d_model=512 8H (kv=8)
d_ff=2048 vocab=51865.  The audio conv frontend is a STUB per assignment:
``input_specs()`` supplies precomputed frame embeddings (B, enc_len, d).
"""

from repro.configs.base import ModelConfig, ParallelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        family="encdec",
        n_layers=6,                 # decoder layers
        enc_layers=6,               # encoder layers
        enc_len=1536,               # stubbed frame-embedding length (~1500)
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_head=64,
        d_ff=2048,
        vocab_size=51865,
        norm_eps=1e-5,
        pos_emb="learned",
        max_pos=32768,
        parallel=ParallelConfig(fsdp=False),
        shape_names=("train_4k", "prefill_32k", "decode_32k"),
    )
