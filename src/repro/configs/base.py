"""Config system: architecture + shape + parallelism configs.

Every assigned architecture is a ``ModelConfig`` in ``repro/configs/<id>.py``
(exact public-literature hyperparameters) and is selectable via
``--arch <id>`` in the launchers.  ``reduced()`` returns the family-preserving
small config used by CPU smoke tests; full configs are exercised only through
the dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # 'train' | 'prefill' | 'decode'


# The assigned shape grid (LM family): seq_len x global_batch.
TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = {s.name: s for s in
              (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """Parallelism & memory policy knobs (per arch defaults; CLI-overridable)."""

    fsdp: bool = True               # shard weights over 'data' too (ZeRO-3)
    remat: bool = True              # per-layer activation checkpointing
    accum_steps: int = 1            # gradient accumulation microbatches
    opt_state_dtype: str = "float32"  # 'float32' | 'int8' (compressed AdamW)
    grad_compression: bool = False  # int8 all-reduce w/ error feedback
    kv_cache_dtype: str = "bfloat16"
    seq_shard_kv: bool = True       # decode: shard KV seq over 'model' (CP)
    # Megatron-style sequence parallelism for the residual stream.  Wins
    # when weight-gather traffic dominates activation-gather traffic
    # (N_params*2*3*accum  >  6*tokens*d_model*2*layers roughly) — i.e. the
    # 90B/480B class; for 2-8B dense models gradient accumulation is the
    # cheaper memory lever (Perf iteration 12).
    seq_parallel: bool = False
    pipeline_stages: int = 1        # GPipe over 'pod' (demo feature)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense|moe|ssm|hybrid|encdec|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                # 0 -> d_model // n_heads
    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    pos_emb: str = "rope"          # 'rope' | 'learned'
    max_pos: int = 0               # learned-pos table size (0 = max shape)
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    d_ff_expert: int = 0
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    # SSM (mamba2 / hymba)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4
    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_len: int = 0               # stubbed frontend sequence length
    # VLM cross-attention
    cross_attn_every: int = 0      # 0 = none; else 1 cross per this many
    vision_len: int = 0            # stubbed patch sequence length
    # numerics
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    vocab_real: int = 0            # 0 = vocab_size; set when vocab is padded
                                   # for sharding divisibility (Megatron-style)
    # long-context capability marker (sub-quadratic mixer present)
    sub_quadratic: bool = False
    # dtypes
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # parallel/memory defaults
    parallel: ParallelConfig = ParallelConfig()
    # shapes this arch runs (names into ALL_SHAPES); decode/long follow rules
    shape_names: tuple = ("train_4k", "prefill_32k", "decode_32k")

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm_only(self) -> bool:
        return self.family == "ssm"

    def shapes(self):
        return [ALL_SHAPES[s] for s in self.shape_names]

    def reduced(self) -> "ModelConfig":
        """Family-preserving tiny config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=max(2, min(4, self.n_heads)),
            n_kv_heads=max(1, min(2, self.n_kv_heads)),
            d_head=32,
            d_ff=256,
            d_ff_expert=64 if self.d_ff_expert else 0,
            vocab_size=512,
            n_experts=min(self.n_experts, 4),
            moe_top_k=min(self.moe_top_k, 2),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=16,
            enc_layers=min(self.enc_layers, 2),
            enc_len=min(self.enc_len, 24) if self.enc_len else 0,
            cross_attn_every=min(self.cross_attn_every, 2)
            if self.cross_attn_every else 0,
            vision_len=min(self.vision_len, 16) if self.vision_len else 0,
            param_dtype="float32",
            compute_dtype="float32",
            parallel=dataclasses.replace(self.parallel, fsdp=False,
                                         accum_steps=1,
                                         opt_state_dtype="float32"),
        )


def param_count(cfg: ModelConfig) -> int:
    """Approximate parameter count (used for 6*N*D roofline terms)."""
    d, dh = cfg.d_model, cfg.head_dim
    attn = d * cfg.n_heads * dh * 2 + d * cfg.n_kv_heads * dh * 2
    ffn = 3 * d * cfg.d_ff if cfg.d_ff else 0
    moe = 0
    if cfg.is_moe:
        moe = cfg.n_experts * 3 * d * cfg.d_ff_expert
        if not cfg.dense_residual:
            ffn = 0
        moe += d * cfg.n_experts  # router
    ssm = 0
    if cfg.ssm_state:
        d_in = cfg.ssm_expand * d
        nh = d_in // cfg.ssm_head_dim
        ssm = d * (2 * d_in + 2 * cfg.ssm_state + nh) + d_in * d
        if cfg.family == "ssm":
            attn = 0
            ffn = 0
    per_layer = attn + ffn + moe + ssm
    cross = 0
    if cfg.cross_attn_every:
        n_cross = cfg.n_layers // cfg.cross_attn_every
        cross = n_cross * (d * cfg.n_heads * dh * 2
                           + d * cfg.n_kv_heads * dh * 2)
    enc = cfg.enc_layers * (attn + ffn)
    embed = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    return cfg.n_layers * per_layer + cross + enc + embed


def active_param_count(cfg: ModelConfig) -> int:
    """Active parameters per token (MoE: top_k experts only)."""
    if not cfg.is_moe:
        return param_count(cfg)
    full = param_count(cfg)
    moe_all = cfg.n_layers * cfg.n_experts * 3 * cfg.d_model * cfg.d_ff_expert
    moe_active = cfg.n_layers * cfg.moe_top_k * 3 * cfg.d_model * cfg.d_ff_expert
    return full - moe_all + moe_active
