"""llama-3.2-vision-90b [vlm] — cross-attn image layers every 5th layer.

[hf:meta-llama/Llama-3.2-11B-Vision family; unverified]  100L d=8192 64H
(kv=8) d_ff=28672 vocab=128256.  The vision encoder is a STUB per
assignment: ``input_specs()`` supplies precomputed patch embeddings
(B, vision_len, d).
"""

from repro.configs.base import ModelConfig, ParallelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        n_layers=100,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_head=128,
        d_ff=28672,
        vocab_size=128256,
        rope_theta=500_000.0,
        cross_attn_every=5,        # 20 cross-attention layers of 100
        vision_len=1600,           # stubbed patch-embedding length
        parallel=ParallelConfig(accum_steps=8, opt_state_dtype="int8",
                                seq_parallel=True),
        shape_names=("train_4k", "prefill_32k", "decode_32k"),
    )
