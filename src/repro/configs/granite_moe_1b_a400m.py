"""granite-moe-1b-a400m [moe] — 32 experts top-8, pure-MoE FFN.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]  24L d=1024 16H (kv=8)
expert d_ff=512 vocab=49155.
"""

from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        d_head=64,
        d_ff=0,                    # no dense FFN path
        d_ff_expert=512,
        n_experts=32,
        moe_top_k=8,
        vocab_size=49155,
        shape_names=("train_4k", "prefill_32k", "decode_32k"),
    )
