"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer.

[arXiv:2411.13676; hf]  32L d=1600 25H (kv=5) d_ff=5504 vocab=32001,
ssm_state=16.  Hybrid mixer => sub-quadratic capable => runs long_500k.
"""

from repro.configs.base import ModelConfig, ParallelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        d_head=64,
        d_ff=5504,
        vocab_size=32001,
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=64,
        sub_quadratic=True,
        parallel=ParallelConfig(accum_steps=4),
        shape_names=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    )
