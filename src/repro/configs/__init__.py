"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``."""

from __future__ import annotations

import importlib

from repro.configs.base import (ALL_SHAPES, LONG_500K, ModelConfig,
                                ParallelConfig, ShapeConfig, active_param_count,
                                param_count)

_ARCHS = {
    "whisper-base": "whisper_base",
    "qwen3-1.7b": "qwen3_1_7b",
    "qwen3-4b": "qwen3_4b",
    "qwen1.5-32b": "qwen1_5_32b",
    "deepseek-7b": "deepseek_7b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "hymba-1.5b": "hymba_1_5b",
    "arctic-480b": "arctic_480b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "mamba2-780m": "mamba2_780m",
}


def list_archs() -> list[str]:
    return sorted(_ARCHS)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_ARCHS[arch]}")
    return mod.get_config()


__all__ = ["get_config", "list_archs", "ModelConfig", "ParallelConfig",
           "ShapeConfig", "ALL_SHAPES", "LONG_500K", "param_count",
           "active_param_count"]
