"""arctic-480b [moe] — 128 experts top-2 + dense residual FFN.

[hf:Snowflake/snowflake-arctic-base; hf]  35L d=7168 56H (kv=8)
expert d_ff=4864 vocab=32000.
"""

from repro.configs.base import ModelConfig, ParallelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_head=128,
        d_ff=4864,                 # dense residual path
        d_ff_expert=4864,
        n_experts=128,
        moe_top_k=2,
        dense_residual=True,
        vocab_size=32000,
        parallel=ParallelConfig(accum_steps=8, opt_state_dtype="int8",
                                seq_parallel=True),
        shape_names=("train_4k", "prefill_32k", "decode_32k"),
    )
