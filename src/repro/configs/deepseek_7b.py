"""deepseek-7b [dense] — llama-arch (RoPE + SwiGLU).  [arXiv:2401.02954; hf]"""

from repro.configs.base import ModelConfig, ParallelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b",
        family="dense",
        n_layers=30,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        d_head=128,
        d_ff=11008,
        vocab_size=102400,
        parallel=ParallelConfig(accum_steps=4),
        shape_names=("train_4k", "prefill_32k", "decode_32k"),
    )
