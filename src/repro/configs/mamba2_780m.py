"""mamba2-780m [ssm] — pure SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified]  48L d=1536 vocab=50280 ssm_state=128.
Attention-free => the paper's KV-retrieval technique is INAPPLICABLE to the
sequence mixer (DESIGN.md §4); sub-quadratic => runs long_500k.
"""

from repro.configs.base import ModelConfig, ParallelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m",
        family="ssm",
        n_layers=48,
        d_model=1536,
        n_heads=1,                 # unused (attention-free)
        n_kv_heads=1,
        d_ff=0,                    # mamba block includes its own expansion
        vocab_size=50280,
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        sub_quadratic=True,
        parallel=ParallelConfig(accum_steps=4),
        shape_names=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    )
