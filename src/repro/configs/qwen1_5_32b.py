"""qwen1.5-32b [dense] — full-MHA (kv=40) with QKV bias.
[hf:Qwen/Qwen1.5 family; hf]"""


from repro.configs.base import ModelConfig, ParallelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=40,
        n_kv_heads=40,
        d_head=128,
        d_ff=27392,
        vocab_size=152064,
        qkv_bias=True,
        parallel=ParallelConfig(accum_steps=8,
                                kv_cache_dtype="float8_e4m3fn",
                                seq_parallel=True),
        shape_names=("train_4k", "prefill_32k", "decode_32k"),
    )
