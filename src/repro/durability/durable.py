"""DurableIndex: WAL + checkpoint durability for StreamingDETLSH
(docs/DESIGN.md §13).

Layout::

    <root>/
      wal/                      segmented write-ahead log (wal.py)
      checkpoints/
        ckpt_00000000/          full atomic snapshot (api/persist.py) whose
        ckpt_00000001/          MANIFEST carries {"durability": {"wal_lsn",
        ...                     "checkpoint_id"}}

Discipline:

  * **log-before-apply** for the ops that change the answer set — upsert
    (with *resolved* gids, so replay never re-allocates), delete, and
    grow_id_capacity.  The WAL_APPEND fault site fires before any byte is
    written, so an op that crashed inside ``append`` was neither logged
    nor applied.
  * **log-after-success** for answer-preserving reorganizations — seal and
    compact.  A crash between apply and log loses only the reorganization
    (the recovered index answers identically; it just re-seals/compacts
    later).  ``requantile`` draws fresh breakpoints (optionally from a PRNG
    key), so it is made durable by an immediate checkpoint instead of a
    log record.
  * **checkpoints never overwrite** — each one publishes atomically into a
    fresh numbered directory, and the previous checkpoint is deleted only
    after the new one is durable and its WAL commit record is fsynced.  At
    every injectable crash boundary at least one valid checkpoint exists.

Recovery (``recover(root)``): load the newest checkpoint that passes
digest verification (skipping partial/corrupt ones), repair the WAL's torn
tail, and re-apply every record with ``lsn > checkpoint.wal_lsn``.  Every
logged op is deterministic given its logged inputs (resolved gids, frozen
breakpoints, host-side merges), and checkpoint load is bit-identical by
the persistence contract — so recovery is bit-identical to the pre-crash
index over the acked ops (the crash-matrix property test asserts this on
both engines).
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import math
import os
import re
import shutil
import time
from typing import Any, Dict, Iterator, Optional, Tuple

import numpy as np

from repro.durability.wal import (FSYNC_INTERVAL, WalRecord, WriteAheadLog,
                                  scan_wal)

_CKPT_RE = re.compile(r"^ckpt_(\d{8})$")


class RecoveryError(RuntimeError):
    """``recover`` cannot produce an index from what is on disk."""


@dataclasses.dataclass(frozen=True)
class RecoveryReport:
    """What one ``recover`` call did — which checkpoint it stood on, which
    WAL records it replayed, and what the torn-tail repair discarded."""

    checkpoint: str                      # directory name used
    checkpoint_id: int
    checkpoint_lsn: int                  # ops with lsn <= this were skipped
    replayed: Tuple[Tuple[int, str], ...]   # (lsn, op) actually re-applied
    skipped_checkpoints: Tuple[Tuple[str, str], ...]  # (name, why)
    torn_bytes: int                      # WAL bytes the repair truncated
    dropped_wal_segments: int

    @property
    def n_replayed(self) -> int:
        return len(self.replayed)


def _apply_record(index: Any, record: WalRecord) -> None:
    """Replay one WAL record onto a loaded index.  Each branch re-invokes
    the exact mutation the original process ran, with the logged inputs."""
    op = record.op
    if op == "upsert":
        index.upsert(record.arrays["vecs"], record.arrays["gids"])
    elif op == "delete":
        index.delete(record.arrays["gids"])
    elif op == "seal":
        index.seal()
    elif op == "compact":
        index.compact()
    elif op == "grow":
        index.grow_id_capacity(int(record.fields["capacity"]))
    elif op == "checkpoint":
        pass                             # a marker, not a mutation
    else:
        raise RecoveryError(
            f"unknown WAL op {op!r} at lsn {record.lsn} — the log was "
            f"written by a newer build; upgrade before recovering")


class DurableIndex:
    """Write-ahead-logged wrapper around a ``StreamingDETLSH``.

    Satisfies ``repro.api.MutableAnnIndex`` (mutations are logged, reads
    delegate) — construct with ``DurableIndex.create(index, root)`` for a
    fresh directory or ``repro.durability.recover(root)`` after a crash.
    Attributes not defined here (``manifest``, ``pin_state``, ``spec``,
    ``stats``, ...) delegate to the wrapped index, so the serving runtime
    treats a DurableIndex exactly like the index it wraps.
    """

    def __init__(self, index: Any, root: str, *, wal: WriteAheadLog,
                 next_checkpoint_id: int,
                 checkpoint_bytes: int = 1 << 20,
                 checkpoint_age_s: float = math.inf,
                 keep_checkpoints: int = 2,
                 fault_plan: Any = None,
                 last_recovery: Optional[RecoveryReport] = None):
        if keep_checkpoints < 1:
            raise ValueError(f"keep_checkpoints must be >= 1, "
                             f"got {keep_checkpoints}")
        self._index = index
        self.root = os.fspath(root)
        self.wal = wal
        self.checkpoint_bytes = int(checkpoint_bytes)
        self.checkpoint_age_s = float(checkpoint_age_s)
        self.keep_checkpoints = int(keep_checkpoints)
        self._plan = fault_plan
        self.last_recovery = last_recovery
        self._next_ckpt_id = int(next_checkpoint_id)
        self._ckpt_dir = os.path.join(self.root, "checkpoints")
        self.checkpoints_written = 0
        self.last_checkpoint_path: Optional[str] = None
        self._last_ckpt_bytes = wal.appended_bytes
        self._last_ckpt_time = time.monotonic()
        self._last_ckpt_lsn = wal.next_lsn - 1

    # ------------------------------------------------------------------
    # Construction
    # ------------------------------------------------------------------

    @classmethod
    def create(cls, index: Any, root: str, *,
               fsync: str = FSYNC_INTERVAL,
               fsync_interval_bytes: int = 1 << 20,
               segment_bytes: int = 1 << 22,
               checkpoint_bytes: int = 1 << 20,
               checkpoint_age_s: float = math.inf,
               keep_checkpoints: int = 2,
               fault_plan: Any = None) -> "DurableIndex":
        """Wrap ``index`` with a fresh durability root: writes checkpoint 0
        (the current state, made durable immediately) and an empty WAL.
        ``root`` must not already hold a durability layout — recover an
        existing one with ``repro.durability.recover(root)`` instead."""
        root = os.fspath(root)
        ckpts = os.path.join(root, "checkpoints")
        if os.path.isdir(ckpts) and any(
                _CKPT_RE.match(n) for n in os.listdir(ckpts)):
            raise ValueError(
                f"{root!r} already holds checkpoints — use "
                f"repro.durability.recover(root) to resume it")
        os.makedirs(root, exist_ok=True)
        wal = WriteAheadLog(os.path.join(root, "wal"), fsync=fsync,
                            fsync_interval_bytes=fsync_interval_bytes,
                            segment_bytes=segment_bytes,
                            fault_plan=fault_plan)
        durable = cls(index, root, wal=wal, next_checkpoint_id=0,
                      checkpoint_bytes=checkpoint_bytes,
                      checkpoint_age_s=checkpoint_age_s,
                      keep_checkpoints=keep_checkpoints,
                      fault_plan=fault_plan)
        durable.checkpoint()
        return durable

    # ------------------------------------------------------------------
    # Logged mutations (MutableAnnIndex)
    # ------------------------------------------------------------------

    def upsert(self, vectors: Any, gids: Any = None) -> np.ndarray:
        """Validate → log (with resolved gids) → apply.  Validation runs
        first so a rejected op (gid exhaustion, negative gids) is neither
        logged nor applied — replay never has to reproduce a failure."""
        vecs = np.asarray(vectors, np.float32)
        if vecs.ndim == 1:
            vecs = vecs[None, :]
        m = len(vecs)
        if gids is None:
            gids = np.arange(self._index.next_gid,
                             self._index.next_gid + m, dtype=np.int64)
        else:
            gids = np.asarray(gids, np.int64).reshape(-1)
            if len(gids) != m:
                raise ValueError(f"{len(gids)} gids for {m} vectors")
        if m == 0:
            return gids.astype(np.int32)
        self._index.check_upsert(gids)
        self.wal.append("upsert", arrays={"gids": gids, "vecs": vecs})
        return self._index.upsert(vecs, gids)

    def delete(self, gids: Any) -> int:
        g = np.atleast_1d(np.asarray(gids, np.int64)).reshape(-1)
        self.wal.append("delete", arrays={"gids": g})
        return self._index.delete(g)

    def grow_id_capacity(self, new_capacity: int) -> None:
        new_capacity = int(new_capacity)
        if new_capacity < self._index.id_capacity:
            raise ValueError(f"cannot shrink id_capacity ({new_capacity} "
                             f"< {self._index.id_capacity})")
        self.wal.append("grow", {"capacity": new_capacity})
        self._index.grow_id_capacity(new_capacity)

    def seal(self) -> Any:
        """Apply-then-log: sealing preserves answers, so a crash between
        the two loses only the reorganization, never a row."""
        seg = self._index.seal()
        if seg is not None:
            self.wal.append("seal")
        return seg

    flush = seal

    def compact(self) -> bool:
        did = self._index.compact()
        if did:
            self.wal.append("compact")
        return did

    def maybe_compact(self) -> bool:
        did = self._index.maybe_compact()
        if did:
            self.wal.append("compact")
        return did

    def requantile(self, key: Any = None) -> None:
        """Rebuild with fresh breakpoints, then checkpoint immediately —
        the new quantization is not a replayable delta (it may depend on a
        PRNG key), so durability comes from the snapshot, not the log."""
        self._index.requantile(key)
        self.checkpoint()

    # ------------------------------------------------------------------
    # Checkpoints
    # ------------------------------------------------------------------

    @contextlib.contextmanager
    def _snapshot_faults(self) -> Iterator[None]:
        if self._plan is None:
            yield
            return
        with self._plan.installed_on_save():
            yield

    def checkpoint(self) -> str:
        """Write an atomic snapshot of the current state into a *fresh*
        numbered directory, commit it with a fsynced WAL marker, then
        truncate covered WAL segments and GC old checkpoints.

        Crash-safety by construction: the new directory publishes via
        temp + ``os.replace`` (never partially visible), and nothing that
        was valid before is touched until after the commit record is
        durable — so no crash point can leave the root without a loadable
        checkpoint.  CHECKPOINT_INSTALL fires twice: before publish and
        before commit (``FaultPlan.arm(..., skip=1)`` targets the second).
        """
        from repro.api import persist
        cid = self._next_ckpt_id
        covers = self.wal.next_lsn - 1
        name = f"ckpt_{cid:08d}"
        target = os.path.join(self._ckpt_dir, name)
        if self._plan is not None:
            from repro.serving import faults as flt
            self._plan.fire(flt.CHECKPOINT_INSTALL, f"{name}:publish")
        with self._snapshot_faults():
            persist.save_streaming(
                self._index, target,
                extra={"durability": {"checkpoint_id": cid,
                                      "wal_lsn": covers}})
        if self._plan is not None:
            from repro.serving import faults as flt
            self._plan.fire(flt.CHECKPOINT_INSTALL, f"{name}:commit")
        self._next_ckpt_id = cid + 1
        self.wal.rotate()
        self.wal.append("checkpoint",
                        {"checkpoint_id": cid, "covers_lsn": covers})
        self.wal.sync()
        self._gc_checkpoints(keep_from=cid)
        # Truncate only through the OLDEST retained checkpoint's covered
        # lsn: records above it are still needed if recovery ever has to
        # fall back past the newest checkpoint (digest failure).
        self.wal.truncate_through(self._retained_covers(covers))
        self.checkpoints_written += 1
        self.last_checkpoint_path = target
        self._last_ckpt_bytes = self.wal.appended_bytes
        self._last_ckpt_time = time.monotonic()
        self._last_ckpt_lsn = covers
        return target

    def maybe_checkpoint(self) -> bool:
        """Background checkpoint policy: checkpoint when enough WAL bytes
        accumulated since the last one, or it is old enough — and there is
        at least one new record to cover.  Returns whether it ran."""
        if self.wal.next_lsn - 1 <= self._last_ckpt_lsn:
            return False
        due = (self.wal.appended_bytes - self._last_ckpt_bytes
               >= self.checkpoint_bytes
               or time.monotonic() - self._last_ckpt_time
               >= self.checkpoint_age_s)
        if not due:
            return False
        self.checkpoint()
        return True

    def _retained_covers(self, newest_covers: int) -> int:
        """The smallest covered lsn over the retained, *readable*
        checkpoints.  Unreadable ones contribute nothing — recovery would
        skip them too, so their records need not be kept."""
        lo = newest_covers
        for name in _checkpoint_names(self._ckpt_dir):
            try:
                meta = _durability_meta(os.path.join(self._ckpt_dir, name))
            except (RecoveryError, OSError, json.JSONDecodeError):
                continue
            lo = min(lo, meta["wal_lsn"])
        return lo

    def _gc_checkpoints(self, keep_from: int) -> None:
        """Remove checkpoints older than the retention window.  Runs only
        after the new checkpoint is durable; a crash mid-removal leaves a
        partial old directory, which recovery skips (it never gets that
        far — the newer checkpoint verifies first)."""
        keep = set(range(max(0, keep_from - self.keep_checkpoints + 1),
                         keep_from + 1))
        for fname in os.listdir(self._ckpt_dir):
            m = _CKPT_RE.match(fname)
            if m and int(m.group(1)) not in keep:
                shutil.rmtree(os.path.join(self._ckpt_dir, fname),
                              ignore_errors=True)

    # ------------------------------------------------------------------
    # Read path / AnnIndex delegation
    # ------------------------------------------------------------------

    def search(self, queries: Any, request: Any = None, *,
               view: Any = None) -> Any:
        return self._index.search(queries, request, view=view)

    def r_min_for(self, k: int, queries: Any = None) -> float:
        return self._index.r_min_for(k, queries)

    def pin_state(self) -> Any:
        return self._index.pin_state()

    def save(self, path: Any) -> None:
        """A plain (non-checkpoint) snapshot of the wrapped index."""
        with self._snapshot_faults():
            self._index.save(path)

    def index_size_bytes(self) -> int:
        return self._index.index_size_bytes()

    def state_digest(self) -> str:
        return self._index.state_digest()

    @property
    def n_points(self) -> int:
        return self._index.n_points

    @property
    def index(self) -> Any:
        """The wrapped ``StreamingDETLSH``."""
        return self._index

    def __getattr__(self, name: str) -> Any:
        if name.startswith("_"):
            raise AttributeError(name)
        return getattr(self._index, name)

    # ------------------------------------------------------------------
    # Introspection / lifecycle
    # ------------------------------------------------------------------

    def durability_stats(self) -> dict:
        return {
            "wal_bytes": self.wal.appended_bytes,
            "wal_records": self.wal.appended_records,
            "wal_size_bytes": self.wal.size_bytes(),
            "fsyncs": self.wal.fsyncs,
            "checkpoints_written": self.checkpoints_written,
            "last_checkpoint": self.last_checkpoint_path,
            "recovery_replayed": (self.last_recovery.n_replayed
                                  if self.last_recovery else 0),
        }

    def close(self) -> None:
        self.wal.close()


# ---------------------------------------------------------------------------
# Recovery
# ---------------------------------------------------------------------------

def _checkpoint_names(ckpt_dir: str) -> list:
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(n for n in os.listdir(ckpt_dir) if _CKPT_RE.match(n))


def _durability_meta(path: str) -> Dict[str, int]:
    """The {"wal_lsn", "checkpoint_id"} section a checkpoint's MANIFEST
    carries.  Raises ``RecoveryError`` when absent — a plain snapshot is
    not a checkpoint (there is no lsn to anchor replay on)."""
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    meta = manifest.get("durability")
    if (not isinstance(meta, dict) or "wal_lsn" not in meta
            or "checkpoint_id" not in meta):
        raise RecoveryError(
            f"{path!r}: snapshot carries no 'durability' section — it is "
            f"a plain save, not a DurableIndex checkpoint")
    return {"wal_lsn": int(meta["wal_lsn"]),
            "checkpoint_id": int(meta["checkpoint_id"])}


def recover(root: str, *, fsync: str = FSYNC_INTERVAL,
            fsync_interval_bytes: int = 1 << 20,
            segment_bytes: int = 1 << 22,
            checkpoint_bytes: int = 1 << 20,
            checkpoint_age_s: float = math.inf,
            keep_checkpoints: int = 2,
            fault_plan: Any = None) -> DurableIndex:
    """Rebuild a ``DurableIndex`` from ``root`` after a crash (or a clean
    shutdown — the two are indistinguishable and both must work).

    Loads the newest checkpoint that passes sha256 verification (corrupt
    or partially-installed ones are skipped, and recorded in the report),
    repairs the WAL's torn tail, replays every record past the
    checkpoint's covered lsn, and returns a ``DurableIndex`` ready to
    serve and mutate.  ``index.last_recovery`` holds the
    ``RecoveryReport``.

    Raises ``RecoveryError`` when no valid checkpoint exists: WAL records
    are deltas against a checkpoint base, so a WAL alone cannot rebuild
    an index.
    """
    from repro.api import persist
    root = os.fspath(root)
    ckpt_dir = os.path.join(root, "checkpoints")
    names = _checkpoint_names(ckpt_dir)
    if not names:
        raise RecoveryError(
            f"{root!r}: no checkpoints found — a WAL alone cannot rebuild "
            f"the index (records are deltas against a checkpoint base); "
            f"was DurableIndex.create() ever run on this root?")
    skipped = []
    index = None
    meta: Dict[str, int] = {}
    used = ""
    for name in reversed(names):
        path = os.path.join(ckpt_dir, name)
        try:
            index = persist.load(path)
            meta = _durability_meta(path)
            used = name
            break
        except (persist.SnapshotFormatError, RecoveryError, OSError,
                json.JSONDecodeError) as exc:
            skipped.append((name, f"{type(exc).__name__}: {exc}"))
    if index is None:
        detail = "; ".join(f"{n}: {why}" for n, why in skipped)
        raise RecoveryError(
            f"{root!r}: no checkpoint passed verification ({detail})")

    scan = scan_wal(os.path.join(root, "wal"), repair=True)
    covers = meta["wal_lsn"]
    replayed = []
    for record in scan.records:
        if record.lsn <= covers or record.op == "checkpoint":
            continue
        _apply_record(index, record)
        replayed.append((record.lsn, record.op))

    report = RecoveryReport(
        checkpoint=used, checkpoint_id=meta["checkpoint_id"],
        checkpoint_lsn=covers, replayed=tuple(replayed),
        skipped_checkpoints=tuple(skipped),
        torn_bytes=scan.truncated_bytes,
        dropped_wal_segments=scan.dropped_segments)
    wal = WriteAheadLog(os.path.join(root, "wal"), fsync=fsync,
                        fsync_interval_bytes=fsync_interval_bytes,
                        segment_bytes=segment_bytes,
                        start_lsn=max(covers, scan.last_lsn) + 1,
                        fault_plan=fault_plan)
    next_cid = max(int(_CKPT_RE.match(n).group(1))  # type: ignore[union-attr]
                   for n in names) + 1
    return DurableIndex(index, root, wal=wal, next_checkpoint_id=next_cid,
                        checkpoint_bytes=checkpoint_bytes,
                        checkpoint_age_s=checkpoint_age_s,
                        keep_checkpoints=keep_checkpoints,
                        fault_plan=fault_plan, last_recovery=report)
