"""Process-crash durability for the streaming index (docs/DESIGN.md §13):
a checksummed segmented write-ahead log, atomic verified checkpoints, and
bit-identical ``recover(root)``."""

from repro.durability.durable import (DurableIndex, RecoveryError,
                                      RecoveryReport, recover)
from repro.durability.wal import (FSYNC_ALWAYS, FSYNC_INTERVAL, FSYNC_OFF,
                                  FSYNC_POLICIES, WalError, WalRecord,
                                  WalScan, WriteAheadLog, scan_wal)

__all__ = [
    "DurableIndex", "RecoveryError", "RecoveryReport", "recover",
    "FSYNC_ALWAYS", "FSYNC_INTERVAL", "FSYNC_OFF", "FSYNC_POLICIES",
    "WalError", "WalRecord", "WalScan", "WriteAheadLog", "scan_wal",
]
