"""Segmented, checksummed write-ahead log (docs/DESIGN.md §13).

The WAL is a directory of append-only segment files::

    <root>/wal/
      seg_00000000.wal
      seg_00000001.wal
      ...

Each segment starts with a 16-byte header (magic ``RWAL``, format version,
first lsn) followed by length-framed records::

    +----------------+----------------+-----------------------------+
    | crc32(payload) | len(payload)   | payload                     |
    | u32 LE         | u32 LE         | len(payload) bytes          |
    +----------------+----------------+-----------------------------+

    payload := u32 LE header_len | header JSON | raw array bytes...

The JSON header carries ``{"lsn", "op", "fields", "arrays"}`` where
``arrays`` lists ``[name, dtype, shape]`` for each raw-byte block that
follows (in name-sorted order) — so a record round-trips numpy arrays
bit-exactly without pickling.

Torn-tail discipline: ``scan_wal`` walks segments in order and stops at the
*first* bad record (short frame, short payload, CRC mismatch, undecodable
header).  With ``repair=True`` it truncates the torn file at that offset
and deletes every later segment — a crash mid-append loses at most the
record being written, never the ability to recover.

Fsync policy: ``always`` syncs after every append, ``interval`` after every
``fsync_interval_bytes`` of unsynced appends, ``off`` only on explicit
``sync()``.  Every append ``flush()``\\ es regardless, so an in-process
crash (exception, injected fault) never loses buffered records — fsync
policy only bounds what a *power* loss can take.

Fault injection (serving/faults.py): ``WAL_APPEND`` fires *before* any
byte of the record is written (a crashed append is never in the log);
``WAL_FSYNC`` fires before ``os.fsync`` (the record is already written and
flushed, so it survives an in-process crash).
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import re
import struct
import zlib
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

WAL_MAGIC = b"RWAL"
WAL_VERSION = 1
_SEG_HEADER = struct.Struct("<4sIQ")     # magic, version, first lsn
_FRAME = struct.Struct("<II")            # crc32(payload), len(payload)
_U32 = struct.Struct("<I")

FSYNC_ALWAYS = "always"
FSYNC_INTERVAL = "interval"
FSYNC_OFF = "off"
FSYNC_POLICIES = (FSYNC_ALWAYS, FSYNC_INTERVAL, FSYNC_OFF)

_SEG_RE = re.compile(r"^seg_(\d{8})\.wal$")


class WalError(ValueError):
    """The write-ahead log was configured or used incorrectly."""


@dataclasses.dataclass(frozen=True)
class WalRecord:
    """One logged event: a monotonically increasing ``lsn``, the op name,
    JSON-able scalar ``fields``, and bit-exact numpy ``arrays``."""

    lsn: int
    op: str
    fields: Dict[str, Any] = dataclasses.field(default_factory=dict)
    arrays: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)


def encode_record(record: WalRecord) -> bytes:
    """Frame one record (crc + length + payload); see module docstring."""
    meta = []
    chunks = []
    for name in sorted(record.arrays):
        a = np.ascontiguousarray(record.arrays[name])
        meta.append([name, a.dtype.str, list(a.shape)])
        chunks.append(a.tobytes())
    header = json.dumps({"lsn": record.lsn, "op": record.op,
                         "fields": record.fields, "arrays": meta},
                        sort_keys=True).encode()
    payload = _U32.pack(len(header)) + header + b"".join(chunks)
    return _FRAME.pack(zlib.crc32(payload) & 0xFFFFFFFF,
                       len(payload)) + payload


def decode_payload(payload: bytes) -> WalRecord:
    """Inverse of ``encode_record``'s payload part.  Raises ``ValueError``
    on any structural mismatch (the scanner treats that as a torn tail —
    CRC already vouched for the bytes, so a failure here means a framing
    bug or a CRC collision, and stopping is the safe answer either way)."""
    if len(payload) < _U32.size:
        raise ValueError("payload shorter than its header-length field")
    (hlen,) = _U32.unpack_from(payload, 0)
    if _U32.size + hlen > len(payload):
        raise ValueError("payload shorter than its declared header")
    header = json.loads(payload[_U32.size:_U32.size + hlen])
    off = _U32.size + hlen
    arrays: Dict[str, np.ndarray] = {}
    for name, dtype, shape in header["arrays"]:
        dt = np.dtype(dtype)
        nbytes = dt.itemsize * math.prod(shape)
        if off + nbytes > len(payload):
            raise ValueError(f"array {name!r} extends past the payload")
        arrays[name] = np.frombuffer(
            payload, dtype=dt, count=math.prod(shape), offset=off
        ).reshape(shape).copy()
        off += nbytes
    if off != len(payload):
        raise ValueError(f"{len(payload) - off} trailing payload bytes")
    return WalRecord(lsn=int(header["lsn"]), op=str(header["op"]),
                     fields=dict(header["fields"]), arrays=arrays)


@dataclasses.dataclass
class WalScan:
    """Result of walking a WAL directory: every valid record in lsn order,
    plus what the torn-tail pass found (and, with ``repair=True``, fixed)."""

    records: List[WalRecord] = dataclasses.field(default_factory=list)
    segments: List[Tuple[int, str]] = dataclasses.field(default_factory=list)
    truncated_bytes: int = 0         # bytes cut from the torn segment
    dropped_segments: int = 0        # whole segments after the torn point
    torn: bool = False

    @property
    def last_lsn(self) -> int:
        return self.records[-1].lsn if self.records else -1


def _segment_files(path: str) -> List[Tuple[int, str]]:
    if not os.path.isdir(path):
        return []
    out = []
    for fname in os.listdir(path):
        m = _SEG_RE.match(fname)
        if m:
            out.append((int(m.group(1)), fname))
    out.sort()
    return out


def _scan_segment(data: bytes) -> Tuple[List[WalRecord], int, bool]:
    """(records, first_bad_offset, clean) for one segment's bytes."""
    if (len(data) < _SEG_HEADER.size
            or data[:4] != WAL_MAGIC
            or _SEG_HEADER.unpack_from(data)[1] != WAL_VERSION):
        return [], 0, False
    records: List[WalRecord] = []
    off = _SEG_HEADER.size
    while off < len(data):
        if off + _FRAME.size > len(data):
            return records, off, False
        crc, ln = _FRAME.unpack_from(data, off)
        start = off + _FRAME.size
        if start + ln > len(data):
            return records, off, False
        payload = data[start:start + ln]
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            return records, off, False
        try:
            records.append(decode_payload(payload))
        except (ValueError, KeyError, TypeError, json.JSONDecodeError):
            return records, off, False
        off = start + ln
    return records, off, True


def scan_wal(path: str, repair: bool = False) -> WalScan:
    """Read every segment in order, stopping at the first bad record.

    ``repair=True`` additionally truncates the torn segment at the bad
    record's offset (or removes it outright when its own header is bad)
    and deletes every later segment — after which a re-scan is clean.
    """
    scan = WalScan()
    files = _segment_files(path)
    for i, (seq, fname) in enumerate(files):
        fpath = os.path.join(path, fname)
        with open(fpath, "rb") as f:
            data = f.read()
        records, good_off, clean = _scan_segment(data)
        scan.records.extend(records)
        if clean:
            scan.segments.append((seq, fname))
            continue
        scan.torn = True
        scan.truncated_bytes += len(data) - good_off
        later = files[i + 1:]
        scan.dropped_segments = len(later)
        for _, lname in later:
            lpath = os.path.join(path, lname)
            scan.truncated_bytes += os.path.getsize(lpath)
            if repair:
                os.remove(lpath)
        if repair:
            if good_off == 0:
                os.remove(fpath)
            else:
                with open(fpath, "r+b") as f:
                    f.truncate(good_off)
                scan.segments.append((seq, fname))
        break
    return scan


class WriteAheadLog:
    """Appender over a WAL directory (one writer at a time).

    Opening always repairs any torn tail (``scan_wal(repair=True)``) and
    starts a *fresh* segment, so an append never continues a file a crash
    may have left mid-frame.  ``start_lsn`` must be greater than every lsn
    already on disk (recovery passes ``last replayed + 1``).
    """

    def __init__(self, path: str, *, fsync: str = FSYNC_INTERVAL,
                 fsync_interval_bytes: int = 1 << 20,
                 segment_bytes: int = 1 << 22,
                 start_lsn: int = 0,
                 fault_plan: Any = None):
        if fsync not in FSYNC_POLICIES:
            raise WalError(f"unknown fsync policy {fsync!r}; "
                           f"valid: {FSYNC_POLICIES}")
        self.path = os.fspath(path)
        self.fsync_policy = fsync
        self.fsync_interval_bytes = int(fsync_interval_bytes)
        self.segment_bytes = int(segment_bytes)
        self._plan = fault_plan
        os.makedirs(self.path, exist_ok=True)
        scan = scan_wal(self.path, repair=True)
        self.next_lsn = max(int(start_lsn), scan.last_lsn + 1)
        # closed segments: seq -> [fname, first_lsn|None, last_lsn|None]
        self._closed: Dict[int, list] = {
            seq: [fname, None, None] for seq, fname in scan.segments}
        self._index_closed()
        last_seq = max((s for s, _ in scan.segments), default=-1)
        self._seq = last_seq + 1
        self._open_segment()
        # counters (docs/DESIGN.md §13): bytes/records appended since open,
        # fsync syscalls issued — RuntimeStats mirrors these
        self.appended_bytes = 0
        self.appended_records = 0
        self.fsyncs = 0
        self._unsynced = 0

    # ------------------------------------------------------------------
    # Segment bookkeeping
    # ------------------------------------------------------------------

    def _index_closed(self) -> None:
        """Record each closed segment's (first, last) lsn range by reading
        its records — cheap (files are bounded by segment_bytes) and only
        runs once at open; ``truncate_through`` needs the ranges."""
        for seq, entry in self._closed.items():
            fpath = os.path.join(self.path, entry[0])
            with open(fpath, "rb") as f:
                records, _, _ = _scan_segment(f.read())
            if records:
                entry[1], entry[2] = records[0].lsn, records[-1].lsn

    def _open_segment(self) -> None:
        self._cur_fname = f"seg_{self._seq:08d}.wal"
        fpath = os.path.join(self.path, self._cur_fname)
        self._f = open(fpath, "wb")
        self._f.write(_SEG_HEADER.pack(WAL_MAGIC, WAL_VERSION,
                                       max(self.next_lsn, 0)))
        self._f.flush()
        self._size = _SEG_HEADER.size
        self._first: Optional[int] = None
        self._last: Optional[int] = None

    def rotate(self) -> None:
        """Close the current segment and start the next one."""
        self._f.flush()
        if self.fsync_policy != FSYNC_OFF:
            self._do_fsync()
        self._f.close()
        self._closed[self._seq] = [self._cur_fname, self._first, self._last]
        self._seq += 1
        self._open_segment()

    def truncate_through(self, lsn: int) -> int:
        """Delete every *closed* segment whose records are all <= ``lsn``
        (checkpoint truncation); returns how many files were removed.
        Empty closed segments (no records) are removed too — nothing can
        ever replay from them."""
        removed = 0
        for seq in sorted(self._closed):
            fname, _, last = self._closed[seq]
            if last is not None and last > lsn:
                continue
            os.remove(os.path.join(self.path, fname))
            del self._closed[seq]
            removed += 1
        return removed

    # ------------------------------------------------------------------
    # Append path
    # ------------------------------------------------------------------

    def append(self, op: str, fields: Optional[Dict[str, Any]] = None,
               arrays: Optional[Dict[str, np.ndarray]] = None) -> int:
        """Durably (per policy) log one record; returns its lsn.

        The WAL_APPEND fault site fires before any byte is written, so a
        crashed append is never in the log — callers apply the op only
        after ``append`` returns (log-before-apply)."""
        lsn = self.next_lsn
        record = WalRecord(lsn=lsn, op=op, fields=dict(fields or {}),
                           arrays=dict(arrays or {}))
        blob = encode_record(record)
        if self._plan is not None:
            from repro.serving import faults as flt
            self._plan.fire(flt.WAL_APPEND, f"{op}@lsn={lsn}")
        if self._size + len(blob) > self.segment_bytes and \
                self._first is not None:
            self.rotate()
        self._f.write(blob)
        self._f.flush()
        self._size += len(blob)
        if self._first is None:
            self._first = lsn
        self._last = lsn
        self.next_lsn = lsn + 1
        self.appended_bytes += len(blob)
        self.appended_records += 1
        self._unsynced += len(blob)
        if self.fsync_policy == FSYNC_ALWAYS or (
                self.fsync_policy == FSYNC_INTERVAL
                and self._unsynced >= self.fsync_interval_bytes):
            self._do_fsync()
        return lsn

    def _do_fsync(self) -> None:
        if self._plan is not None:
            from repro.serving import faults as flt
            self._plan.fire(flt.WAL_FSYNC, self._cur_fname)
        os.fsync(self._f.fileno())
        self.fsyncs += 1
        self._unsynced = 0

    def sync(self) -> None:
        """Explicit durability barrier: flush + fsync under *every* policy
        (checkpoint commit calls this even with ``fsync='off'``)."""
        self._f.flush()
        self._do_fsync()

    def size_bytes(self) -> int:
        """Total on-disk WAL bytes (all segments)."""
        total = self._size
        for fname, _, _ in self._closed.values():
            total += os.path.getsize(os.path.join(self.path, fname))
        return total

    def close(self) -> None:
        if self._f.closed:
            return
        self._f.flush()
        if self.fsync_policy != FSYNC_OFF:
            self._do_fsync()
        self._f.close()
