"""repro: PDET-LSH on TPU pods — JAX + Pallas implementation.

Pillars:
  * ``repro.api``       — the unified index surface (AnnIndex protocol,
    IndexSpec, SearchRequest/SearchResult, engine registry, snapshots).
  * ``repro.core``      — the paper's contribution (DET-LSH / PDET-LSH).
  * ``repro.streaming`` — the mutable LSM-style segmented index.
  * ``repro.decode``    — LSH attention decode: the KV cache as an index.
  * ``repro.kernels``   — Pallas TPU kernels for the compute hot spots.
  * ``repro.models``    — the assigned LM architecture zoo.
  * ``repro.train`` / ``repro.serving`` / ``repro.data`` — substrate.
  * ``repro.launch``    — mesh construction, multi-pod dry-run, drivers.

Top-level re-exports resolve lazily (PEP 562), so ``import repro`` stays
cheap and ``repro.api.load(...)``, ``repro.DETLSH``,
``repro.StreamingDETLSH``, and ``repro.derive_params`` all work as
documented without eagerly importing the kernel stack.
"""

from __future__ import annotations

import importlib

__version__ = "1.0.0"

__all__ = ["__version__", "api", "decode", "durability", "tune", "DETLSH",
           "StreamingDETLSH", "derive_params", "DurableIndex", "recover",
           "KVCacheIndex", "suggest_params", "TuneResult"]

_LAZY = {
    "api": ("repro.api", None),
    "decode": ("repro.decode", None),
    "durability": ("repro.durability", None),
    "tune": ("repro.tune", None),
    "DurableIndex": ("repro.durability", "DurableIndex"),
    "recover": ("repro.durability", "recover"),
    "DETLSH": ("repro.core", "DETLSH"),
    "StreamingDETLSH": ("repro.streaming", "StreamingDETLSH"),
    "derive_params": ("repro.core.theory", "derive_params"),
    "KVCacheIndex": ("repro.decode", "KVCacheIndex"),
    "suggest_params": ("repro.tune", "suggest_params"),
    "TuneResult": ("repro.tune", "TuneResult"),
}


def __getattr__(name):
    if name in _LAZY:
        module, attr = _LAZY[name]
        mod = importlib.import_module(module)
        value = mod if attr is None else getattr(mod, attr)
        globals()[name] = value       # cache: resolve once per process
        return value
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(set(__all__) | set(globals()))
