"""repro: PDET-LSH on TPU pods — JAX + Pallas implementation.

Pillars:
  * ``repro.core``      — the paper's contribution (DET-LSH / PDET-LSH).
  * ``repro.kernels``   — Pallas TPU kernels for the compute hot spots.
  * ``repro.models``    — the assigned LM architecture zoo.
  * ``repro.train`` / ``repro.serving`` / ``repro.data`` — substrate.
  * ``repro.launch``    — mesh construction, multi-pod dry-run, drivers.
"""

__version__ = "1.0.0"
