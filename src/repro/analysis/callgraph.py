"""Trace-region call graph for jaxlint (docs/DESIGN.md §12).

Builds an over-approximate "reachable from a trace entry" set over every
function in the project.  Trace entries are

  * functions decorated with ``jax.jit`` (directly or through
    ``functools.partial(jax.jit, ...)``), ``jax.vmap``, ``shard_map`` or any
    other decorator whose terminal name is in :data:`TRACE_ENTRY_NAMES`;
  * functions *passed to* a trace-entry call — ``jax.jit(f)``,
    ``pl.pallas_call(kernel, ...)``, ``shard_map(f, ...)``,
    ``jax.lax.while_loop(cond, body, ...)``, ``jax.vmap(f)``, … — including
    nested (closure) functions, lambdas referencing known functions, and the
    ``fn = functools.partial(known_fn, ...); jax.vmap(fn)`` idiom.

Anything a reachable function references (call or bare function reference —
references are traced when the value is later called) is reachable too.
Nested ``def``s are indexed as their own nodes (``module.outer.<locals>.f``)
so a host-side driver whose *loop bodies* are traced contributes only those
bodies to the trace region, not its own host statements.

Name resolution is intra-repo only and purely syntactic: module aliases from
``import``/``from .. import`` tables (collected at any nesting depth — the
repo imports kernels function-locally), ``self.method`` within a class, and
module-level names.  Unresolvable references (third-party calls, closure
variables) contribute no edges.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Iterator, Optional, Union

from repro.analysis.engine import Project, SourceFile

FunctionNode = Union[ast.FunctionDef, ast.AsyncFunctionDef]

#: Terminal attribute/function names whose call arguments enter a trace.
TRACE_ENTRY_NAMES = frozenset({
    "jit", "pallas_call", "shard_map", "vmap", "pmap", "grad",
    "value_and_grad", "while_loop", "fori_loop", "scan", "cond", "switch",
    "checkpoint", "remat", "custom_jvp", "custom_vjp", "named_call",
})

#: Decorator terminal names that make the decorated function itself a seed.
TRACE_DECORATOR_NAMES = frozenset({
    "jit", "vmap", "pmap", "shard_map", "custom_jvp", "custom_vjp",
    "checkpoint", "remat",
})


def dotted_parts(node: ast.AST) -> Optional[list[str]]:
    """``a.b.c`` -> ["a", "b", "c"]; None for non-Name-rooted chains."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return None


def terminal_name(node: ast.AST) -> Optional[str]:
    """Last component of a Name/Attribute chain (``jax.lax.scan`` -> scan)."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


@dataclasses.dataclass
class FunctionInfo:
    """One function (module-level, method, or nested def)."""

    qualname: str
    module: str
    file: SourceFile
    node: FunctionNode
    cls: Optional[str] = None
    parent: Optional[str] = None          # enclosing function qualname
    static_params: frozenset[str] = frozenset()
    # Local named nested defs: name -> qualname.
    nested: dict[str, str] = dataclasses.field(default_factory=dict)


class ModuleIndex:
    """Per-module symbol tables: imports, functions, classes."""

    def __init__(self, file: SourceFile) -> None:
        self.file = file
        self.name = file.module or file.rel
        self.import_modules: dict[str, str] = {}      # alias -> dotted module
        self.import_symbols: dict[str, tuple[str, str]] = {}  # alias->(mod, a)
        self.functions: dict[str, FunctionInfo] = {}  # local key -> info
        self.classes: set[str] = set()
        if file.tree is not None:
            self._index(file.tree)

    def _index(self, tree: ast.Module) -> None:
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    local = alias.asname or alias.name.split(".")[0]
                    target = alias.name if alias.asname else \
                        alias.name.split(".")[0]
                    self.import_modules[local] = target
            elif isinstance(node, ast.ImportFrom):
                if node.level:      # relative imports: not used in-tree
                    continue
                base = node.module or ""
                for alias in node.names:
                    local = alias.asname or alias.name
                    self.import_symbols[local] = (base, alias.name)
        for stmt in tree.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._add_function(stmt, cls=None, parent=None)
            elif isinstance(stmt, ast.ClassDef):
                self.classes.add(stmt.name)
                for sub in stmt.body:
                    if isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                        self._add_function(sub, cls=stmt.name, parent=None)

    def _add_function(self, node: FunctionNode, cls: Optional[str],
                      parent: Optional[str]) -> FunctionInfo:
        if parent is not None:
            qual = f"{parent}.<locals>.{node.name}"
            local_key = qual.split(f"{self.name}.", 1)[-1]
        elif cls is not None:
            qual = f"{self.name}.{cls}.{node.name}"
            local_key = f"{cls}.{node.name}"
        else:
            qual = f"{self.name}.{node.name}"
            local_key = node.name
        info = FunctionInfo(qualname=qual, module=self.name, file=self.file,
                            node=node, cls=cls, parent=parent,
                            static_params=_static_params(node))
        self.functions[local_key] = info
        # Index named nested defs (one level of nesting is what the repo
        # uses: while_loop/vmap bodies defined inside the driver).
        for sub in ast.walk(node):
            if sub is node:
                continue
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                owner = _enclosing_function(node, sub)
                if owner is node:
                    child = self._add_function(sub, cls=cls, parent=qual)
                    info.nested[sub.name] = child.qualname
        return info


def _enclosing_function(root: FunctionNode,
                        target: FunctionNode) -> Optional[FunctionNode]:
    """Innermost function of ``root``'s subtree that directly encloses
    ``target`` (root itself when target is directly nested)."""
    found: list[FunctionNode] = []

    def visit(node: ast.AST, owner: FunctionNode) -> None:
        for child in ast.iter_child_nodes(node):
            if child is target:
                found.append(owner)
                return
            next_owner = owner
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                next_owner = child
            visit(child, next_owner)

    visit(root, root)
    return found[0] if found else None


def _static_params(node: FunctionNode) -> frozenset[str]:
    """Parameter names declared static via ``jax.jit(static_argnames=...)``
    style decorators."""
    names: set[str] = set()
    for dec in node.decorator_list:
        if isinstance(dec, ast.Call):
            for kw in dec.keywords:
                if kw.arg == "static_argnames":
                    names.update(_string_values(kw.value))
    return frozenset(names)


def _string_values(node: ast.AST) -> Iterator[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        yield node.value
    elif isinstance(node, (ast.Tuple, ast.List)):
        for e in node.elts:
            yield from _string_values(e)


class CallGraph:
    """Project-wide function index + jit-reachability."""

    def __init__(self) -> None:
        self.modules: dict[str, ModuleIndex] = {}
        self.functions: dict[str, FunctionInfo] = {}
        self.edges: dict[str, set[str]] = {}
        self.seeds: dict[str, str] = {}          # qualname -> reason
        self.reachable: dict[str, str] = {}      # qualname -> seed qualname

    # -- construction -------------------------------------------------------

    @classmethod
    def build(cls, project: Project) -> "CallGraph":
        cg = cls()
        for f in project.files:
            mi = ModuleIndex(f)
            cg.modules[mi.name] = mi
            for info in mi.functions.values():
                cg.functions[info.qualname] = info
        for mi in cg.modules.values():
            cg._scan_module(mi)
        cg._propagate()
        return cg

    def _scan_module(self, mi: ModuleIndex) -> None:
        if mi.file.tree is None:
            return
        for info in mi.functions.values():
            self._scan_function(mi, info)
            for dec in info.node.decorator_list:
                if self._is_trace_decorator(dec):
                    self.seeds.setdefault(
                        info.qualname,
                        f"decorated trace entry at {mi.file.rel}:"
                        f"{info.node.lineno}")
        # Module-level trace-entry calls (e.g. ``f = jax.jit(g)``).
        for node in ast.walk(mi.file.tree):
            if isinstance(node, ast.Call):
                self._scan_trace_entry_call(mi, node, owner=None,
                                            local_refs={})

    @staticmethod
    def _is_trace_decorator(dec: ast.AST) -> bool:
        for node in ast.walk(dec):
            name = terminal_name(node)
            if name in TRACE_DECORATOR_NAMES:
                return True
        return False

    def _scan_function(self, mi: ModuleIndex, info: FunctionInfo) -> None:
        """Collect reference edges and trace-entry seeds for one function.

        The scan covers the function's own statements only — nested defs are
        separate nodes reached through their own references/seeds."""
        refs: set[str] = set()
        # local name -> known functions referenced in its assignment RHS
        # (catches ``fn = functools.partial(knn_query, ...)``).
        local_refs: dict[str, set[str]] = {}

        for node in self._own_nodes(info.node):
            if isinstance(node, ast.Assign):
                targets = [t.id for t in node.targets
                           if isinstance(t, ast.Name)]
                if targets:
                    rhs = set(self._known_refs(mi, info, node.value))
                    for t in targets:
                        local_refs.setdefault(t, set()).update(rhs)
            if isinstance(node, (ast.Name, ast.Attribute)):
                q = self._resolve(mi, info, node)
                if q is not None:
                    refs.add(q)
            if isinstance(node, ast.Call):
                self._scan_trace_entry_call(mi, node, owner=info,
                                            local_refs=local_refs)
        self.edges[info.qualname] = refs

    def _own_nodes(self, fn: FunctionNode) -> Iterator[ast.AST]:
        """Walk a function's body, excluding nested named-def subtrees."""
        stack: list[ast.AST] = list(ast.iter_child_nodes(fn))
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            yield node
            stack.extend(ast.iter_child_nodes(node))

    def _known_refs(self, mi: ModuleIndex, owner: Optional[FunctionInfo],
                    tree: ast.AST) -> Iterator[str]:
        for node in ast.walk(tree):
            if isinstance(node, (ast.Name, ast.Attribute)):
                q = self._resolve(mi, owner, node)
                if q is not None:
                    yield q

    def _scan_trace_entry_call(self, mi: ModuleIndex, call: ast.Call,
                               owner: Optional[FunctionInfo],
                               local_refs: dict[str, set[str]]) -> None:
        if terminal_name(call.func) not in TRACE_ENTRY_NAMES:
            return
        where = (f"{mi.file.rel}:{call.lineno}")
        args: list[ast.expr] = list(call.args) + [
            kw.value for kw in call.keywords if kw.arg != "static_argnames"]
        statics = set()
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                statics.update(_string_values(kw.value))
        for arg in args:
            for q in self._trace_arg_targets(mi, owner, local_refs, arg):
                self.seeds.setdefault(q, f"passed to trace entry at {where}")
                if statics and q in self.functions:
                    self.functions[q].static_params = frozenset(
                        self.functions[q].static_params | statics)

    def _trace_arg_targets(self, mi: ModuleIndex,
                           owner: Optional[FunctionInfo],
                           local_refs: dict[str, set[str]],
                           arg: ast.expr) -> Iterator[str]:
        if isinstance(arg, ast.Name) and arg.id in local_refs:
            yield from local_refs[arg.id]
        if isinstance(arg, ast.Lambda):
            for node in ast.walk(arg.body):
                if isinstance(node, ast.Name) and node.id in local_refs:
                    yield from local_refs[node.id]
                if isinstance(node, (ast.Name, ast.Attribute)):
                    q = self._resolve(mi, owner, node)
                    if q is not None:
                        yield q
            return
        if isinstance(arg, (ast.Name, ast.Attribute)):
            q = self._resolve(mi, owner, arg)
            if q is not None:
                yield q
        elif isinstance(arg, ast.Call):
            # functools.partial(known_fn, ...) passed inline.
            yield from self._known_refs(mi, owner, arg)

    # -- name resolution ----------------------------------------------------

    def _resolve(self, mi: ModuleIndex, owner: Optional[FunctionInfo],
                 node: ast.AST) -> Optional[str]:
        parts = dotted_parts(node)
        if parts is None:
            return None
        # self.method -> method of the same class.
        if owner is not None and owner.cls and parts[0] == "self" \
                and len(parts) == 2:
            q = f"{mi.name}.{owner.cls}.{parts[1]}"
            return q if q in self.functions else None
        # Nested defs of the owning function (while_loop cond/body).
        if owner is not None and len(parts) == 1 \
                and parts[0] in owner.nested:
            return owner.nested[parts[0]]
        if len(parts) == 1:
            name = parts[0]
            if name in mi.functions:
                return mi.functions[name].qualname
            if name in mi.import_symbols:
                smod, sattr = mi.import_symbols[name]
                return self._resolve_in_module(smod, [sattr])
            return None
        head, rest = parts[0], parts[1:]
        if head in mi.import_modules:
            return self._resolve_in_module(mi.import_modules[head], rest)
        if head in mi.import_symbols:
            smod, sattr = mi.import_symbols[head]
            sub = f"{smod}.{sattr}"
            if sub in self.modules:
                return self._resolve_in_module(sub, rest)
            return None
        return None

    def _resolve_in_module(self, module: str,
                           attrs: list[str]) -> Optional[str]:
        # Extend the module prefix as far as real modules go.
        while len(attrs) > 1 and f"{module}.{attrs[0]}" in self.modules:
            module = f"{module}.{attrs[0]}"
            attrs = attrs[1:]
        if module not in self.modules:
            return None
        mi = self.modules[module]
        if len(attrs) == 1 and f"{module}.{attrs[0]}" in self.modules:
            return None                       # a module reference, not a fn
        key = ".".join(attrs)
        if key in mi.functions:
            return mi.functions[key].qualname
        return None

    # -- reachability -------------------------------------------------------

    def _propagate(self) -> None:
        frontier = [q for q in self.seeds if q in self.functions]
        for q in frontier:
            self.reachable[q] = q
        while frontier:
            q = frontier.pop()
            seed = self.reachable[q]
            for tgt in self.edges.get(q, ()):
                if tgt not in self.reachable and tgt in self.functions:
                    self.reachable[tgt] = seed
                    frontier.append(tgt)

    def reach_reason(self, qualname: str) -> str:
        seed = self.reachable.get(qualname)
        if seed is None:
            return "not reachable"
        if seed == qualname:
            return self.seeds.get(qualname, "trace entry")
        return (f"reachable from trace entry '{seed}' "
                f"({self.seeds.get(seed, '?')})")
