"""jaxlint rule engine (docs/DESIGN.md §12).

The analyzer is a pure-AST pass: no file under analysis is ever imported or
executed.  ``load_project`` parses every ``.py`` file under the given roots
into :class:`SourceFile` objects, :class:`Project` groups them (and lazily
builds the jit-reachability call graph, ``repro.analysis.callgraph``), and
``run_rules`` applies every :class:`Rule`, filters findings through inline
suppressions, and returns a :class:`Report`.

Suppressions
------------
A finding is suppressed by an inline comment on the finding's line (or on a
comment-only line directly above it)::

    order = np.argsort(v)  # jaxlint: disable=unstable-sort -- values-only \
                           #   sort; the permutation is never used

The justification text after ``--`` is REQUIRED: a suppression without one
is inert and itself reported (rule ``suppression``), so a contract can never
be waived silently.  Multiple rules separate with commas; ``disable=all``
suppresses every rule on that line.

Fixture corpora
---------------
A directory containing a ``.jaxlint-fixtures`` sentinel file is skipped when
reached by directory *walking* (so ``python -m repro.analysis tests/`` does
not flag the known-bad corpus), but is analyzed normally when passed as an
explicit root (which is how the corpus tests drive the analyzer).
"""

from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
import sys
from pathlib import Path
from typing import Iterator, Optional, Protocol, Sequence

FIXTURE_SENTINEL = ".jaxlint-fixtures"

SEVERITY_ERROR = "error"
SEVERITY_WARNING = "warning"

_SUPPRESS_RE = re.compile(
    r"#\s*jaxlint:\s*disable=([A-Za-z0-9_,\-]+)"
    r"(?:\s+--\s*(\S[^#]*))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to file:line:col."""

    rule: str
    severity: str
    path: str
    line: int
    col: int
    message: str

    @property
    def anchor(self) -> str:
        return f"{self.path}:{self.line}:{self.col}"

    def to_dict(self) -> dict[str, object]:
        return {"rule": self.rule, "severity": self.severity,
                "path": self.path, "line": self.line, "col": self.col,
                "message": self.message}


@dataclasses.dataclass(frozen=True)
class Suppression:
    """One ``# jaxlint: disable=...`` comment (attached to a code line)."""

    line: int                 # the code line this suppression governs
    comment_line: int         # where the comment physically sits
    rules: tuple[str, ...]    # rule names, or ("all",)
    justification: str        # text after ``--`` ("" = unjustified, inert)

    def covers(self, rule: str) -> bool:
        return "all" in self.rules or rule in self.rules


class SourceFile:
    """One parsed source file: text, AST, line table, suppressions."""

    def __init__(self, path: Path, rel: str, module: Optional[str]) -> None:
        self.path = path
        self.rel = rel
        self.module = module
        self.text = path.read_text(encoding="utf-8")
        self.lines = self.text.splitlines()
        self.tree: Optional[ast.Module] = None
        self.syntax_error: Optional[SyntaxError] = None
        try:
            self.tree = ast.parse(self.text, filename=str(path))
        except SyntaxError as e:  # surfaced as a finding by run_rules
            self.syntax_error = e
        self.suppressions: dict[int, list[Suppression]] = {}
        self._scan_suppressions()

    def _scan_suppressions(self) -> None:
        for i, raw in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(raw)
            if m is None:
                continue
            rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
            just = (m.group(2) or "").strip()
            target = i
            if raw.lstrip().startswith("#"):
                # Comment-only line: governs the next non-comment code line.
                j = i + 1
                while j <= len(self.lines) and (
                        not self.lines[j - 1].strip()
                        or self.lines[j - 1].lstrip().startswith("#")):
                    j += 1
                target = j
            sup = Suppression(line=target, comment_line=i, rules=rules,
                              justification=just)
            self.suppressions.setdefault(target, []).append(sup)

    def suppressed(self, rule: str, line: int) -> bool:
        return any(s.covers(rule) and s.justification
                   for s in self.suppressions.get(line, ()))


class Project:
    """All files under analysis plus the lazily-built call graph."""

    def __init__(self, files: Sequence[SourceFile]) -> None:
        self.files = list(files)
        self.modules: dict[str, SourceFile] = {
            f.module: f for f in self.files if f.module is not None}
        self._callgraph: Optional[object] = None

    def callgraph(self) -> "object":
        if self._callgraph is None:
            from repro.analysis.callgraph import CallGraph
            self._callgraph = CallGraph.build(self)
        return self._callgraph


class Rule(Protocol):
    """One static check.  ``name`` is the suppression token."""

    name: str
    code: str
    severity: str
    doc: str

    def check(self, project: Project) -> Iterator[Finding]:
        """Yield findings over the whole project (pre-suppression)."""
        ...  # pragma: no cover - protocol


@dataclasses.dataclass(frozen=True)
class Report:
    findings: tuple[Finding, ...]
    files_scanned: int

    @property
    def errors(self) -> tuple[Finding, ...]:
        return tuple(f for f in self.findings
                     if f.severity == SEVERITY_ERROR)

    def to_dict(self) -> dict[str, object]:
        return {"files_scanned": self.files_scanned,
                "findings": [f.to_dict() for f in self.findings],
                "errors": len(self.errors)}


# ---------------------------------------------------------------------------
# File collection
# ---------------------------------------------------------------------------

_SKIP_DIRS = frozenset({"__pycache__", ".git", ".hypothesis", "node_modules",
                        ".venv", "venv"})


def iter_python_files(roots: Sequence[Path]) -> Iterator[Path]:
    """Yield ``.py`` files under ``roots``; fixture-sentinel directories are
    pruned during walking but honored when given as an explicit root."""
    seen: set[Path] = set()
    for root in roots:
        root = root.resolve()
        if root.is_file():
            if root.suffix == ".py" and root not in seen:
                seen.add(root)
                yield root
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            d = Path(dirpath)
            dirnames[:] = sorted(
                name for name in dirnames
                if name not in _SKIP_DIRS
                and not (d / name / FIXTURE_SENTINEL).exists())
            for name in sorted(filenames):
                if not name.endswith(".py"):
                    continue
                p = (d / name).resolve()
                if p not in seen:
                    seen.add(p)
                    yield p


def _module_name(path: Path) -> Optional[str]:
    """Dotted module name: src-layout packages resolve to their import path
    (``repro.core.query``); anything else gets a unique path-derived
    pseudo-name so the call graph can index it."""
    parts = list(path.parts)
    if "src" in parts:
        i = len(parts) - 1 - parts[::-1].index("src")
        rel = parts[i + 1:]
    else:
        cwd = Path.cwd().resolve()
        try:
            rel = list(path.relative_to(cwd).parts)
        except ValueError:
            rel = parts[-3:]
    if not rel:
        return None
    rel = list(rel)
    rel[-1] = rel[-1][:-3] if rel[-1].endswith(".py") else rel[-1]
    if rel[-1] == "__init__":
        rel = rel[:-1]
    if not rel:
        return None
    return ".".join(rel)


def load_project(paths: Sequence[str | Path]) -> Project:
    files = []
    for p in iter_python_files([Path(p) for p in paths]):
        try:
            rel = str(p.relative_to(Path.cwd().resolve()))
        except ValueError:
            rel = str(p)
        files.append(SourceFile(p, rel, _module_name(p)))
    return Project(files)


# ---------------------------------------------------------------------------
# Running rules + suppression filtering
# ---------------------------------------------------------------------------

def run_rules(project: Project,
              rules: Sequence[Rule]) -> Report:
    known = {"all", "suppression", "syntax-error"}
    for r in rules:
        known.add(r.name)
        known.update(getattr(r, "emits", ()))
    findings: list[Finding] = []

    for f in project.files:
        if f.syntax_error is not None:
            findings.append(Finding(
                rule="syntax-error", severity=SEVERITY_ERROR, path=f.rel,
                line=f.syntax_error.lineno or 1,
                col=(f.syntax_error.offset or 1) - 1,
                message=f"file does not parse: {f.syntax_error.msg}"))
        for sups in f.suppressions.values():
            for s in sups:
                if not s.justification:
                    findings.append(Finding(
                        rule="suppression", severity=SEVERITY_ERROR,
                        path=f.rel, line=s.comment_line, col=0,
                        message="suppression without justification is inert: "
                                "append ' -- <why this is safe>' "
                                f"(disable={','.join(s.rules)})"))
                unknown = [r for r in s.rules if r not in known]
                if unknown:
                    findings.append(Finding(
                        rule="suppression", severity=SEVERITY_ERROR,
                        path=f.rel, line=s.comment_line, col=0,
                        message="suppression names unknown rule(s) "
                                f"{unknown}: it disables nothing "
                                f"(known: {sorted(known - {'all'})})"))

    by_rel = {f.rel: f for f in project.files}
    for rule in rules:
        for finding in rule.check(project):
            src = by_rel.get(finding.path)
            if src is not None and src.suppressed(finding.rule, finding.line):
                continue
            findings.append(finding)

    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return Report(findings=tuple(findings),
                  files_scanned=len(project.files))


def format_human(report: Report) -> str:
    out = []
    for f in report.findings:
        out.append(f"{f.anchor}: {f.severity} [{f.rule}] {f.message}")
    n_err = len(report.errors)
    out.append(f"{len(report.findings)} finding(s) ({n_err} error(s)) "
               f"in {report.files_scanned} file(s)")
    return "\n".join(out)


def format_json(report: Report) -> str:
    return json.dumps(report.to_dict(), indent=2, sort_keys=True)


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    from repro.analysis.rules import ALL_RULES

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxlint: trace-safety & bit-identity static analysis "
                    "(docs/DESIGN.md §12)")
    ap.add_argument("paths", nargs="*", default=["src", "tests"],
                    help="files or directories to analyze "
                         "(default: src tests)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable JSON output")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule names to run (default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule battery and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in ALL_RULES:
            print(f"{r.code} {r.name} [{r.severity}] - {r.doc}")
        return 0

    rules: Sequence[Rule] = ALL_RULES
    if args.select:
        wanted = {s.strip() for s in args.select.split(",") if s.strip()}
        unknown = wanted - {r.name for r in ALL_RULES} - {r.code
                                                          for r in ALL_RULES}
        if unknown:
            print(f"unknown rule(s): {sorted(unknown)}", file=sys.stderr)
            return 2
        rules = [r for r in ALL_RULES
                 if r.name in wanted or r.code in wanted]

    project = load_project(args.paths)
    report = run_rules(project, rules)
    print(format_json(report) if args.as_json else format_human(report))
    return 1 if report.errors else 0
