"""Source-hygiene rules (JX701/JX702).

A pyflakes-lite pair that keeps the tree clean even where CI's ruff step
cannot run (the local container has no ruff; jaxlint is always available):

  JX701 unused-import     an imported name never referenced in the module
                          (Name loads, attribute roots, __all__ strings,
                          and string annotations all count as uses)
  JX702 pointless-fstring an f-string with no placeholders — usually a
                          leftover from deleting the interpolation

Both mirror the corresponding ruff rules (F401, F541) so local jaxlint and
CI ruff agree on the same findings.
"""

from __future__ import annotations

import ast
import re
from typing import Iterator

from repro.analysis.engine import (SEVERITY_ERROR, Finding, Project,
                                   SourceFile)

_IDENT_RE = re.compile(r"[A-Za-z_][A-Za-z0-9_]*")


class UnusedImportRule:
    name = "unused-import"
    code = "JX701"
    severity = SEVERITY_ERROR
    doc = ("imported names must be referenced somewhere in the module "
           "(mirrors ruff F401; 'import x as x' re-exports are exempt)")

    def check(self, project: Project) -> Iterator[Finding]:
        for f in project.files:
            if f.tree is None:
                continue
            yield from self._check_file(f)

    def _check_file(self, f: SourceFile) -> Iterator[Finding]:
        assert f.tree is not None
        imported: list[tuple[str, str, ast.AST]] = []  # (local, what, node)
        used: set[str] = set()

        # Availability probes: `try: import x / except ImportError:` import
        # for the side effect of the check, not the binding.
        probes: set[int] = set()
        for node in ast.walk(f.tree):
            if isinstance(node, ast.Try) and any(
                    h.type is not None
                    and any(isinstance(t, ast.Name) and t.id in
                            ("ImportError", "ModuleNotFoundError")
                            for t in ast.walk(h.type))
                    for h in node.handlers):
                probes.update(id(n) for n in ast.walk(node)
                              if isinstance(n, (ast.Import, ast.ImportFrom)))

        for node in ast.walk(f.tree):
            if id(node) in probes:
                continue
            if isinstance(node, ast.Import):
                for a in node.names:
                    local = a.asname or a.name.split(".")[0]
                    if a.asname == a.name:
                        continue              # explicit re-export idiom
                    imported.append((local, a.name, node))
            elif isinstance(node, ast.ImportFrom):
                if node.module == "__future__":
                    continue
                for a in node.names:
                    if a.name == "*":
                        continue
                    local = a.asname or a.name
                    if a.asname == a.name:
                        continue              # explicit re-export idiom
                    what = f"{node.module or ''}.{a.name}".lstrip(".")
                    imported.append((local, what, node))
            elif isinstance(node, ast.Name):
                used.add(node.id)
            elif isinstance(node, ast.Attribute):
                pass                          # root Name is walked separately
            elif isinstance(node, ast.Constant) \
                    and isinstance(node.value, str):
                # __all__ entries, quoted annotations, getattr strings.
                used.update(_IDENT_RE.findall(node.value))

        for local, what, node in imported:
            if local not in used:
                yield Finding(
                    rule=self.name, severity=self.severity, path=f.rel,
                    line=node.lineno, col=node.col_offset,
                    message=f"'{local}' (from '{what}') is imported but "
                            "never used; remove it or re-export explicitly "
                            "via __all__ / 'import x as x'")


class PointlessFStringRule:
    name = "pointless-fstring"
    code = "JX702"
    severity = SEVERITY_ERROR
    doc = ("f-strings with no placeholders are leftovers from deleted "
           "interpolations (mirrors ruff F541)")

    def check(self, project: Project) -> Iterator[Finding]:
        for f in project.files:
            if f.tree is None:
                continue
            assert f.tree is not None
            # Format specs ({x:06d}) are themselves JoinedStr nodes with no
            # FormattedValue children; they are not f-strings in the source.
            specs = {id(n.format_spec) for n in ast.walk(f.tree)
                     if isinstance(n, ast.FormattedValue)
                     and n.format_spec is not None}
            for node in ast.walk(f.tree):
                if id(node) in specs:
                    continue
                if isinstance(node, ast.JoinedStr) and not any(
                        isinstance(v, ast.FormattedValue)
                        for v in node.values):
                    yield Finding(
                        rule=self.name, severity=self.severity, path=f.rel,
                        line=node.lineno, col=node.col_offset,
                        message="f-string without any placeholder: drop the "
                                "'f' prefix (or restore the interpolation)")
