"""Registry-discipline rules (JX401/JX402, docs/DESIGN.md §12).

PR 7 centralized engine selection in ``repro.api.registry.resolve_engine``
so that capability fallbacks (e.g. pdet refusing multi-probe and falling
back to fused) happen in exactly one place.  Two drift modes erode that:

  JX401 engine-bypass     comparing a variable against engine-name string
                          literals ("fused"/"vmap"/"pdet"/"auto") outside
                          the registry module or a function that itself
                          calls resolve_engine/validate_engine_name — that
                          is ad-hoc dispatch the registry cannot see
  JX402 deprecated-shim   calling the legacy ``.query(...)`` shim with its
                          pre-PR-7 keyword surface (r_min/M/mode/...); the
                          shim survives for external callers only and emits
                          DeprecationWarning (an error under this repo's
                          pytest filterwarnings)
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.engine import (SEVERITY_ERROR, SEVERITY_WARNING, Finding,
                                   Project, SourceFile)

#: Literals that mark a comparison as engine dispatch.  "auto" is not in
#: the set: it is also the sentinel for kernel-impl selection
#: (build_impl/encode_impl) and flags nothing but false positives.
ENGINE_NAMES = frozenset({"fused", "vmap", "pdet"})

#: Functions whose presence in a body marks it as registry-aware: comparing
#: engine names immediately around a resolve call is the sanctioned pattern
#: (the registry itself, and thin wrappers that dispatch on its result).
_REGISTRY_FNS = frozenset({"resolve_engine", "validate_engine_name",
                           "resolve", "available_engines"})

#: Keyword surface of the deprecated pre-PR-7 ``query()`` shim.
_SHIM_KWARGS = frozenset({"r_min", "M", "mode", "max_rounds", "engine",
                          "n_active"})


def _enclosing_bodies(tree: ast.Module) -> Iterator[tuple[ast.AST, bool]]:
    """Yield (function node, calls_registry) for every def; module level is
    yielded as (tree, calls_registry_at_module_level)."""
    def calls_registry(node: ast.AST) -> bool:
        for n in ast.walk(node):
            if isinstance(n, ast.Call):
                fn = n.func
                name = fn.attr if isinstance(fn, ast.Attribute) else (
                    fn.id if isinstance(fn, ast.Name) else None)
                if name in _REGISTRY_FNS:
                    return True
        return False

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node, calls_registry(node)


class EngineBypassRule:
    name = "engine-bypass"
    code = "JX401"
    severity = SEVERITY_ERROR
    doc = ("engine-name string comparisons outside repro.api.registry (or a "
           "function that itself calls resolve_engine) are ad-hoc dispatch "
           "the registry's capability fallbacks cannot see")

    def check(self, project: Project) -> Iterator[Finding]:
        for f in project.files:
            if f.tree is None:
                continue
            base = f.path.name
            if base in ("registry.py",):
                continue                      # the registry compares freely
            yield from self._check_file(f)

    def _check_file(self, f: SourceFile) -> Iterator[Finding]:
        assert f.tree is not None
        allowed_spans: list[tuple[int, int]] = []
        for fn, ok in _enclosing_bodies(f.tree):
            if ok:
                end = getattr(fn, "end_lineno", fn.lineno)
                allowed_spans.append((fn.lineno, end or fn.lineno))

        # Asserting which engine ran is verification, not dispatch — the
        # rule targets control flow that *selects* an engine.
        in_assert = {id(c) for n in ast.walk(f.tree)
                     if isinstance(n, ast.Assert)
                     for c in ast.walk(n) if isinstance(c, ast.Compare)}

        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Compare) or id(node) in in_assert:
                continue
            if not any(isinstance(op, (ast.Eq, ast.NotEq, ast.In, ast.NotIn))
                       for op in node.ops):
                continue
            literals = [n for n in [node.left, *node.comparators]
                        for c in ast.walk(n)
                        if isinstance(c, ast.Constant)
                        and c.value in ENGINE_NAMES]
            if not literals:
                continue
            # All-literal comparisons (e.g. parametrized test ids) are not
            # dispatch: no Name/Attribute means nothing is being selected on.
            sides = [node.left, *node.comparators]
            if not any(isinstance(e, (ast.Name, ast.Attribute, ast.Call))
                       for s in sides for e in ast.walk(s)):
                continue
            if any(lo <= node.lineno <= hi for lo, hi in allowed_spans):
                continue
            yield Finding(
                rule=self.name, severity=self.severity, path=f.rel,
                line=node.lineno, col=node.col_offset,
                message="engine-name comparison outside the registry: route "
                        "selection through repro.api.registry.resolve_engine "
                        "so capability fallbacks stay centralized")


class DeprecatedShimRule:
    name = "deprecated-shim"
    code = "JX402"
    severity = SEVERITY_WARNING
    doc = ("in-tree calls to the legacy .query(...) shim keyword surface "
           "(r_min/M/mode/max_rounds/engine/n_active) must migrate to "
           "search()/QueryRequest; the shim exists for external callers "
           "only and warns DeprecationWarning")

    def check(self, project: Project) -> Iterator[Finding]:
        for f in project.files:
            if f.tree is None:
                continue
            assert f.tree is not None
            for node in ast.walk(f.tree):
                if not isinstance(node, ast.Call):
                    continue
                fn = node.func
                if not (isinstance(fn, ast.Attribute) and fn.attr == "query"):
                    continue
                shim_kw = sorted(kw.arg for kw in node.keywords
                                 if kw.arg in _SHIM_KWARGS)
                if not shim_kw:
                    continue
                yield Finding(
                    rule=self.name, severity=self.severity, path=f.rel,
                    line=node.lineno, col=node.col_offset,
                    message=f".query(..., {', '.join(shim_kw)}=...) uses the "
                            "deprecated pre-registry shim surface; call "
                            "search()/QueryRequest instead (the shim raises "
                            "under this repo's DeprecationWarning-as-error "
                            "pytest config)")
