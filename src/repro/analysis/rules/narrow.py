"""Narrow-storage widening rule (JX301, docs/DESIGN.md §12).

PR 5 shrank the build artifacts to their information-theoretic widths:
``codes_sorted`` is uint8 (2^w <= 256 breakpoint ids) and ``leaf_lo`` /
``leaf_hi`` are int16 (leaf counts < 2^15).  The contract that keeps that
safe lives at the *use* sites: every consumer widens via
``.astype(jnp.int32)`` before arithmetic, because uint8/int16 arithmetic
wraps silently under JAX's default dtype promotion (e.g. ``leaf_hi + 1``
at 32767, or a uint8 difference of codes).  Until this rule, that contract
lived only in reviewers' heads.

The rule flags arithmetic (``+ - * // % ** << >>`` and unary ``-``) where a
*naked* read of a narrow-storage name participates — a bare ``codes_sorted``
/ ``leaf_lo`` / ``leaf_hi`` name, an attribute whose terminal is one
(``index.leaf_hi``), or a subscript of either (``leaf_lo[i]``).  A
``.astype(...)`` call between the read and the arithmetic stops the taint
(that is the widening), as does any other intervening call (its result is
the callee's contract, not raw narrow storage).
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.engine import (SEVERITY_ERROR, Finding, Project,
                                   SourceFile)

#: Storage names narrowed in PR 5; see detree.CODE_DTYPE / LEAF_DTYPE.
NARROW_NAMES = frozenset({"codes_sorted", "leaf_lo", "leaf_hi"})

_ARITH_OPS = (ast.Add, ast.Sub, ast.Mult, ast.FloorDiv, ast.Mod, ast.Pow,
              ast.LShift, ast.RShift)


def _naked_narrow_read(node: ast.expr) -> Optional[str]:
    """Name of the narrow buffer read *without* an intervening widening
    cast / call, or None."""
    if isinstance(node, ast.Name):
        return node.id if node.id in NARROW_NAMES else None
    if isinstance(node, ast.Attribute):
        # index.leaf_hi is a narrow read; leaf_hi.shape is not (metadata).
        return node.attr if node.attr in NARROW_NAMES else None
    if isinstance(node, ast.Subscript):
        return _naked_narrow_read(node.value)
    if isinstance(node, (ast.UnaryOp,)):
        return _naked_narrow_read(node.operand)
    # Calls (including .astype(...)) break the taint: their result carries
    # the callee's dtype contract.  Everything else is not a raw read.
    return None


def _operands(node: ast.expr) -> Iterator[ast.expr]:
    if isinstance(node, ast.BinOp):
        yield node.left
        yield node.right
    elif isinstance(node, ast.UnaryOp):
        yield node.operand


class NarrowWideningRule:
    name = "narrow-arith"
    code = "JX301"
    severity = SEVERITY_ERROR
    doc = ("arithmetic on the narrow build buffers (codes_sorted uint8, "
           "leaf_lo/leaf_hi int16) requires a prior .astype(jnp.int32) "
           "widening cast — narrow integer arithmetic wraps silently")

    def check(self, project: Project) -> Iterator[Finding]:
        for f in project.files:
            if f.tree is None:
                continue
            yield from self._check_file(f)

    def _check_file(self, f: SourceFile) -> Iterator[Finding]:
        assert f.tree is not None
        for node in ast.walk(f.tree):
            if isinstance(node, ast.BinOp) \
                    and isinstance(node.op, _ARITH_OPS):
                pass
            elif isinstance(node, ast.UnaryOp) \
                    and isinstance(node.op, ast.USub):
                pass
            elif isinstance(node, ast.AugAssign) \
                    and isinstance(node.op, _ARITH_OPS):
                name = _naked_narrow_read(node.target)
                if name is None:
                    name = _naked_narrow_read(node.value)
                if name is not None:
                    yield self._finding(f, node, name)
                continue
            else:
                continue
            for operand in _operands(node):
                name = _naked_narrow_read(operand)
                if name is not None:
                    yield self._finding(f, node, name)
                    break

    def _finding(self, f: SourceFile, node: ast.AST, name: str) -> Finding:
        return Finding(
            rule=self.name, severity=self.severity, path=f.rel,
            line=node.lineno, col=node.col_offset,
            message=f"arithmetic on narrow-storage '{name}' without a "
                    "widening cast: insert .astype(jnp.int32) before the "
                    "operation (uint8/int16 arithmetic wraps silently; "
                    "docs/DESIGN.md §12 narrow-storage contract)")
