"""Bit-identity hazard: sorts without an explicit stability contract (JX201).

Every permutation-producing sort in this repo is load-bearing for a
bit-identity contract (fused==vmap candidate order, fused-build==reference-
build, PDET==DET merge order — docs/DESIGN.md §8/§12): ties are the common
case (interleaved integer keys, duplicate ids, equal distances), and an
unstable sort reorders them differently across backends/versions, silently
breaking the contract the way dimensionality silently degrades data-oriented
trees.  The rule requires the stability kwarg to be *explicit* at every
sort/argsort call site:

  * ``jnp.sort``/``jnp.argsort``      -> ``stable=True`` (or kind='stable')
  * ``np.sort``/``np.argsort``        -> ``kind='stable'``
  * ``jax.lax.sort``                  -> ``is_stable=True``

``np.lexsort`` is always stable and passes.  Sorts whose permutation is
genuinely unused (values-only order statistics) suppress with a
justification, which is exactly the documentation the contract wants.
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.callgraph import dotted_parts
from repro.analysis.engine import (SEVERITY_ERROR, Finding, Project,
                                   SourceFile)

_SORT_ATTRS = frozenset({"sort", "argsort"})


def _np_aliases(tree: ast.Module) -> tuple[frozenset[str], frozenset[str],
                                           frozenset[str]]:
    """(numpy aliases, jax.numpy aliases, jax/jax.lax aliases)."""
    np_a, jnp_a, jax_a = set(), set(), set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                local = alias.asname or alias.name.split(".")[0]
                if alias.name == "numpy" or alias.name.startswith("numpy."):
                    np_a.add(local)
                elif alias.name == "jax.numpy":
                    jnp_a.add(local)
                elif alias.name == "jax" or alias.name.startswith("jax."):
                    jax_a.add(local)
        elif isinstance(node, ast.ImportFrom) and node.module:
            for alias in node.names:
                local = alias.asname or alias.name
                if node.module == "jax" and alias.name == "numpy":
                    jnp_a.add(local)
                elif node.module == "jax" and alias.name == "lax":
                    jax_a.add(local)
    return frozenset(np_a), frozenset(jnp_a), frozenset(jax_a)


def _kw(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _is_const(node: Optional[ast.expr], value: object) -> bool:
    return isinstance(node, ast.Constant) and node.value == value


class StableSortRule:
    name = "unstable-sort"
    code = "JX201"
    severity = SEVERITY_ERROR
    doc = ("every sort/argsort call must carry an explicit stability kwarg "
           "(jnp: stable=True, np: kind='stable', lax.sort: is_stable=True)"
           " — permutation stability is what makes the bit-identity "
           "contracts hold")

    def check(self, project: Project) -> Iterator[Finding]:
        for f in project.files:
            if f.tree is None:
                continue
            yield from self._check_file(f)

    def _check_file(self, f: SourceFile) -> Iterator[Finding]:
        np_a, jnp_a, jax_a = _np_aliases(f.tree)  # type: ignore[arg-type]
        assert f.tree is not None
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            parts = dotted_parts(node.func)
            if not parts or len(parts) < 2:
                continue
            root, attr = parts[0], parts[-1]
            if attr not in _SORT_ATTRS and attr != "lexsort":
                continue
            if root in np_a:
                if attr == "lexsort":
                    continue                      # lexsort is always stable
                kind = _kw(node, "kind")
                if not (_is_const(kind, "stable")
                        or _is_const(kind, "mergesort")):
                    yield self._finding(
                        f, node,
                        f"np.{attr} without kind='stable': numpy defaults "
                        "to an unstable introsort; ties reorder across "
                        "platforms and break bit-identity")
            elif root in jnp_a and attr in _SORT_ATTRS:
                stable = _kw(node, "stable")
                kind = _kw(node, "kind")
                if not (_is_const(stable, True)
                        or _is_const(kind, "stable")):
                    yield self._finding(
                        f, node,
                        f"jnp.{attr} without an explicit stable=True: the "
                        "stability this contract depends on must be stated "
                        "at the call site, not inherited from a default")
            elif root in jax_a and attr == "sort" \
                    and ("lax" in parts or root == "lax"):
                if not _is_const(_kw(node, "is_stable"), True):
                    yield self._finding(
                        f, node,
                        "lax.sort without an explicit is_stable=True: the "
                        "variadic key sort is only bit-identical to the "
                        "reference double argsort when stable")

    def _finding(self, f: SourceFile, node: ast.AST,
                 message: str) -> Finding:
        return Finding(rule=self.name, severity=self.severity, path=f.rel,
                       line=node.lineno, col=node.col_offset,
                       message=message)
