"""Export-surface drift rule (JX501, docs/DESIGN.md §12).

``repro/__init__`` and ``repro/api/__init__`` use lazy ``__getattr__``
re-export tables so that importing the package does not pull in JAX.  The
public surface is therefore spread across three places that must agree:

  * ``__all__`` — the advertised names,
  * the lazy table(s) read inside ``__getattr__`` (dicts like ``_LAZY`` /
    ``_EXPORTS`` mapping name -> source module),
  * eager module-level defs / imports.

Drift between them produces the worst kind of bug: ``from repro import X``
works interactively (``__getattr__`` resolves it) while ``import *`` /
tooling that trusts ``__all__`` misses it — or vice versa, ``__all__``
advertises a name whose lazy entry was deleted and every access raises.
The rule checks, per ``__init__`` file that defines ``__getattr__``:

  * every ``__all__`` name is resolvable (eager def/import OR lazy key),
  * every lazy-table key is advertised in ``__all__``.
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.engine import (SEVERITY_ERROR, Finding, Project,
                                   SourceFile)


def _string_elts(node: ast.expr) -> Optional[list[tuple[str, int]]]:
    """(value, lineno) for a list/tuple/set of string constants, else None."""
    if not isinstance(node, (ast.List, ast.Tuple, ast.Set)):
        return None
    out = []
    for e in node.elts:
        if isinstance(e, ast.Constant) and isinstance(e.value, str):
            out.append((e.value, e.lineno))
        else:
            return None
    return out


class ExportDriftRule:
    name = "export-drift"
    code = "JX501"
    severity = SEVERITY_ERROR
    doc = ("__all__, the lazy __getattr__ table, and eager defs must agree "
           "in every __init__ that uses lazy re-exports — drift makes names "
           "import-able but invisible to tooling, or advertised but broken")

    def check(self, project: Project) -> Iterator[Finding]:
        for f in project.files:
            if f.tree is None or f.path.name != "__init__.py":
                continue
            yield from self._check_file(f)

    def _check_file(self, f: SourceFile) -> Iterator[Finding]:
        assert f.tree is not None
        tree = f.tree

        getattr_fn: Optional[ast.FunctionDef] = None
        lazy_dicts: dict[str, list[tuple[str, int]]] = {}
        all_names: Optional[list[tuple[str, int]]] = None
        eager: set[str] = set()

        for node in tree.body:
            if isinstance(node, ast.FunctionDef):
                if node.name == "__getattr__":
                    getattr_fn = node
                eager.add(node.name)
            elif isinstance(node, ast.ClassDef):
                eager.add(node.name)
            elif isinstance(node, ast.Import):
                for a in node.names:
                    eager.add(a.asname or a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom):
                for a in node.names:
                    if a.name != "*":
                        eager.add(a.asname or a.name)
            elif isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        eager.add(t.id)
                        if t.id == "__all__":
                            all_names = _string_elts(node.value)
                        elif isinstance(node.value, ast.Dict):
                            keys = []
                            ok = True
                            for k in node.value.keys:
                                if isinstance(k, ast.Constant) \
                                        and isinstance(k.value, str):
                                    keys.append((k.value, k.lineno))
                                else:
                                    ok = False
                            if ok and keys:
                                lazy_dicts[t.id] = keys

        if getattr_fn is None:
            return                         # eager-only __init__: out of scope
        if all_names is None:
            yield Finding(
                rule=self.name, severity=self.severity, path=f.rel,
                line=getattr_fn.lineno, col=getattr_fn.col_offset,
                message="module defines a lazy __getattr__ but no literal "
                        "__all__; the advertised surface is unauditable")
            return

        # Which dicts does __getattr__ actually consult?
        read_names = {n.id for n in ast.walk(getattr_fn)
                      if isinstance(n, ast.Name)}
        lazy_keys: dict[str, int] = {}
        for dict_name, keys in lazy_dicts.items():
            if dict_name in read_names:
                for k, line in keys:
                    lazy_keys.setdefault(k, line)

        advertised = {n for n, _ in all_names}
        for nm, line in all_names:
            if nm not in eager and nm not in lazy_keys:
                yield Finding(
                    rule=self.name, severity=self.severity, path=f.rel,
                    line=line, col=0,
                    message=f"__all__ advertises '{nm}' but it has no eager "
                            "definition and no lazy __getattr__ entry: "
                            "accessing it will raise AttributeError")
        for nm, line in sorted(lazy_keys.items()):
            if nm not in advertised:
                yield Finding(
                    rule=self.name, severity=self.severity, path=f.rel,
                    line=line, col=0,
                    message=f"lazy export '{nm}' resolves via __getattr__ "
                            "but is missing from __all__: tooling and "
                            "'import *' cannot see it")
