"""Trace-safety rules (docs/DESIGN.md §12, rules JX101-JX104).

Inside any function reachable from a ``jax.jit`` / ``pallas_call`` /
``shard_map`` / ``lax.*`` trace region (``repro.analysis.callgraph``), the
following break tracing — either loudly (TracerConversionError) or, worse,
silently (a host value baked in at trace time that should have been data):

  JX101 trace-np-call      host ``np.*`` call on device-tainted data
  JX102 trace-scalar-coerce  ``float()``/``int()``/``bool()`` of a device value
  JX103 trace-item-call    ``.item()`` / ``.tolist()`` on a device value
  JX104 trace-py-branch    Python ``if``/``while`` on a device value

"Device-tainted" is a per-function syntactic taint: parameters are tainted
unless their annotation is a plain Python scalar type / a config struct
(anything not mentioning ``Array``) or they are listed in the enclosing
jit's ``static_argnames``; every ``jnp.*``/``jax.*`` call result is tainted;
``.shape``/``.ndim``/``.size``/``.dtype``/``len()`` launder taint (static
under trace); locals inherit taint from their right-hand sides.  ``np.*``
calls on purely static values (e.g. precomputed weight tables built at
trace time from shapes) are fine and not flagged.

An ``if`` statement whose test mentions ``jax.core.Tracer`` (the repo's
host-fast-path guard idiom, e.g. ``encoding._sort_columns``) exempts its
entire subtree: the author is explicitly branching on trace-ness, and the
bit-identity of both branches is covered by dynamic tests.
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.callgraph import (CallGraph, FunctionInfo, ModuleIndex,
                                      dotted_parts, terminal_name)
from repro.analysis.engine import (SEVERITY_ERROR, Finding, Project)

#: np.* attributes that are safe under trace (dtype objects and dtype
#: queries — they never touch traced data).
NP_SAFE_ATTRS = frozenset({
    "float16", "float32", "float64", "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64", "bool_", "dtype", "iinfo",
    "finfo", "promote_types", "result_type", "errstate", "integer",
    "floating", "ndarray", "generic",
})

#: Attribute reads that turn any value static (shape metadata under trace).
STATIC_ATTRS = frozenset({"shape", "ndim", "size", "dtype", "itemsize"})

_SCALAR_ANNOTATIONS = frozenset({"int", "float", "bool", "str", "bytes",
                                 "None"})


def _module_aliases(mi: ModuleIndex, target: str) -> frozenset[str]:
    return frozenset(a for a, mod in mi.import_modules.items()
                     if mod == target or mod.startswith(target + "."))


def _annotation_is_static(ann: Optional[ast.expr]) -> Optional[bool]:
    """True = static, False = device array, None = unannotated."""
    if ann is None:
        return None
    text = ast.dump(ann)
    if "Array" in text or "ndarray" in text:
        return False
    return True


def _mentions_tracer(test: ast.expr) -> bool:
    for node in ast.walk(test):
        if terminal_name(node) == "Tracer":
            return True
    return False


#: Taint levels: the rules only fire on DEVICE (definitely a tracer), so an
#: unannotated parameter (UNKNOWN — often a Python int/config/pytree) never
#: produces a finding by itself.  Precision over recall: a lint gate that
#: cries wolf on every config branch gets suppressed wholesale.
STATIC, UNKNOWN, DEVICE = 0, 1, 2


class _FunctionTaint:
    """Syntactic static/unknown/device taint over one function body."""

    def __init__(self, mi: ModuleIndex, info: FunctionInfo) -> None:
        self.np_aliases = _module_aliases(mi, "numpy")
        self.device_call_roots = (
            _module_aliases(mi, "jax")
            | frozenset(a for a, m in mi.import_modules.items()
                        if m.startswith("jax.")))
        self.levels: dict[str, int] = {}
        args = info.node.args
        all_args = (list(args.posonlyargs) + list(args.args)
                    + list(args.kwonlyargs))
        if args.vararg:
            all_args.append(args.vararg)
        if args.kwarg:
            all_args.append(args.kwarg)
        for a in all_args:
            if a.arg in ("self", "cls") or a.arg in info.static_params:
                self.levels[a.arg] = STATIC
                continue
            static = _annotation_is_static(a.annotation)
            if static is True:
                self.levels[a.arg] = STATIC
            elif static is False:
                self.levels[a.arg] = DEVICE     # Array-annotated parameter
            else:
                self.levels[a.arg] = UNKNOWN    # unannotated: could be either

    # -- expression classification -----------------------------------------

    def level(self, node: ast.expr) -> int:
        """How device-tainted is evaluating ``node``?"""
        if isinstance(node, ast.Constant):
            return STATIC
        if isinstance(node, ast.Name):
            return self.levels.get(node.id, STATIC)  # closures: config-like
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return STATIC                 # .shape etc. launder taint
            return self.level(node.value)
        if isinstance(node, ast.Call):
            fn = node.func
            tname = terminal_name(fn)
            if tname in ("len", "isinstance", "range", "enumerate", "zip"):
                return STATIC
            parts = dotted_parts(fn)
            if parts and parts[0] in self.device_call_roots:
                return DEVICE                 # jnp./jax. result: a tracer
            levels = ([self.level(fn.value)]
                      if isinstance(fn, ast.Attribute) else [])
            levels += [self.level(a) for a in node.args]
            levels += [self.level(kw.value) for kw in node.keywords]
            return max(levels, default=STATIC)
        if isinstance(node, ast.Subscript):
            return max(self.level(node.value), self.level(node.slice))
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return max((self.level(e) for e in node.elts), default=STATIC)
        if isinstance(node, ast.BinOp):
            return max(self.level(node.left), self.level(node.right))
        if isinstance(node, ast.UnaryOp):
            return self.level(node.operand)
        if isinstance(node, ast.BoolOp):
            return max((self.level(v) for v in node.values), default=STATIC)
        if isinstance(node, ast.Compare):
            # Identity tests are host-safe on anything ('x is None'), and
            # string-literal comparisons are trace-time config dispatch.
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return STATIC
            sides = [node.left, *node.comparators]
            if any(isinstance(s, ast.Constant) and isinstance(s.value, str)
                   for s in sides):
                return STATIC
            return max(self.level(s) for s in sides)
        if isinstance(node, ast.IfExp):
            return max(self.level(node.test), self.level(node.body),
                       self.level(node.orelse))
        if isinstance(node, ast.Starred):
            return self.level(node.value)
        if isinstance(node, ast.JoinedStr):
            return STATIC
        # Lambdas, comprehensions, etc.: not a direct device read.
        return STATIC

    def is_device(self, node: ast.expr) -> bool:
        return self.level(node) >= DEVICE

    def note_assignment(self, node: ast.stmt) -> None:
        value: Optional[ast.expr] = None
        targets: list[ast.expr] = []
        if isinstance(node, ast.Assign):
            value, targets = node.value, list(node.targets)
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            value, targets = node.value, [node.target]
        elif isinstance(node, ast.AugAssign):
            value, targets = node.value, [node.target]
        if value is None:
            return
        lvl = self.level(value)
        for t in targets:
            for leaf in ast.walk(t):
                if isinstance(leaf, ast.Name):
                    self.levels[leaf.id] = lvl


class TraceSafetyRules:
    """JX101-JX104 as one pass (they share the call graph and taint)."""

    name = "trace-safety"
    code = "JX100"
    severity = SEVERITY_ERROR
    doc = ("no host np.* calls, scalar coercions, .item()/.tolist(), or "
           "Python branching on device values inside jit/pallas/shard_map-"
           "reachable functions")

    RULE_NP = "trace-np-call"
    RULE_COERCE = "trace-scalar-coerce"
    RULE_ITEM = "trace-item-call"
    RULE_BRANCH = "trace-py-branch"

    #: Sub-rule names this pass emits (suppression tokens the engine must
    #: recognize beyond ``name``).
    emits = (RULE_NP, RULE_COERCE, RULE_ITEM, RULE_BRANCH)

    def check(self, project: Project) -> Iterator[Finding]:
        cg = project.callgraph()
        assert isinstance(cg, CallGraph)
        for qual, info in sorted(cg.functions.items()):
            if qual not in cg.reachable:
                continue
            mi = cg.modules[info.module]
            yield from self._check_function(cg, mi, info)

    # -- per-function scan --------------------------------------------------

    def _check_function(self, cg: CallGraph, mi: ModuleIndex,
                        info: FunctionInfo) -> Iterator[Finding]:
        taint = _FunctionTaint(mi, info)
        reason = cg.reach_reason(info.qualname)
        findings: list[Finding] = []

        def visit(node: ast.AST) -> None:
            # Nested named defs are separate call-graph nodes with their own
            # reachability; tracer-guarded subtrees are author-handled.
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return
            if isinstance(node, (ast.If, ast.While)) \
                    and _mentions_tracer(node.test):
                return
            if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                taint.note_assignment(node)
            if isinstance(node, (ast.If, ast.While)) \
                    and taint.is_device(node.test):
                findings.append(self._finding(
                    self.RULE_BRANCH, mi, node,
                    "Python branch on a device value inside a traced "
                    f"function ('{info.qualname}' is {reason}); use "
                    "jnp.where/lax.cond, or guard the host path with an "
                    "isinstance(..., jax.core.Tracer) check"))
            if isinstance(node, ast.Call):
                findings.extend(
                    self._check_call(taint, mi, info, reason, node))
            for child in ast.iter_child_nodes(node):
                visit(child)

        for stmt in info.node.body:
            visit(stmt)
        yield from findings

    def _check_call(self, taint: _FunctionTaint, mi: ModuleIndex,
                    info: FunctionInfo, reason: str,
                    call: ast.Call) -> Iterator[Finding]:
        fn = call.func
        parts = dotted_parts(fn)
        # JX101: np.* on device-tainted arguments.
        if parts and parts[0] in taint.np_aliases and len(parts) > 1 \
                and parts[-1] not in NP_SAFE_ATTRS:
            if any(taint.is_device(a) for a in call.args) or any(
                    taint.is_device(kw.value) for kw in call.keywords):
                yield self._finding(
                    self.RULE_NP, mi, call,
                    f"host numpy call '{'.'.join(parts)}' on a device value "
                    f"inside a traced function ('{info.qualname}' is "
                    f"{reason}); use jnp, or guard the host path with an "
                    "isinstance(..., jax.core.Tracer) check")
        # JX102: float()/int()/bool() of a device value.
        if isinstance(fn, ast.Name) and fn.id in ("float", "int", "bool") \
                and call.args and taint.is_device(call.args[0]):
            yield self._finding(
                self.RULE_COERCE, mi, call,
                f"Python {fn.id}() coercion of a device value inside a "
                f"traced function ('{info.qualname}' is {reason}); keep it "
                "an array (jnp.asarray / astype)")
        # JX103: .item()/.tolist() on a device value.
        if isinstance(fn, ast.Attribute) and fn.attr in ("item", "tolist") \
                and not call.args and taint.is_device(fn.value):
            yield self._finding(
                self.RULE_ITEM, mi, call,
                f".{fn.attr}() forces a host sync and breaks under trace "
                f"('{info.qualname}' is {reason}); keep the value on device")

    def _finding(self, rule: str, mi: ModuleIndex, node: ast.AST,
                 message: str) -> Finding:
        return Finding(rule=rule, severity=self.severity, path=mi.file.rel,
                       line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0), message=message)


def _end_line(node: ast.AST) -> int:
    end = getattr(node, "end_lineno", None)
    if isinstance(end, int):
        return end
    return max((getattr(n, "lineno", 0) for n in ast.walk(node)),
               default=getattr(node, "lineno", 1))
