"""PR 8 refusal guard (JX601, docs/DESIGN.md §12 + ROADMAP follow-on).

PR 8 deliberately *refused* multi-probe on the ``pdet`` engine: probe slack
ranking is currently per-shard, so plumbing ``probe_depth`` into the
sharded path would make results depend on device count — breaking the
PDET==DET bit-identity contract (Theorem 3's quality guarantee only
transfers because sharding is invisible).  The registry encodes the refusal
as a capability fallback (pdet + probes -> fused), and ``distributed.py``
must stay probe-free until a device-count-invariant *global* slack ranking
lands (see ROADMAP).

This rule keeps the documented refusal from silently eroding: any
``probe_depth`` plumbing inside ``distributed.py`` — a function parameter,
a call keyword, or an assignment target — is flagged.  Reading the name to
*reject* it (e.g. ``if request.probe_depth: raise``) is the sanctioned
pattern and is not flagged.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.engine import (SEVERITY_ERROR, Finding, Project,
                                   SourceFile)

_GUARDED_BASENAME = "distributed.py"
_NAME = "probe_depth"


class PdetProbePlumbingRule:
    name = "pdet-probe-plumbing"
    code = "JX601"
    severity = SEVERITY_ERROR
    doc = ("probe_depth must not be plumbed into the pdet/distributed "
           "engine until a device-count-invariant global slack ranking "
           "lands (PR 8 refusal; ROADMAP follow-on) — per-shard probe "
           "ranking breaks PDET==DET bit-identity")

    def check(self, project: Project) -> Iterator[Finding]:
        for f in project.files:
            if f.tree is None or f.path.name != _GUARDED_BASENAME:
                continue
            yield from self._check_file(f)

    def _check_file(self, f: SourceFile) -> Iterator[Finding]:
        assert f.tree is not None
        for node in ast.walk(f.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                args = node.args
                for a in (list(args.posonlyargs) + list(args.args)
                          + list(args.kwonlyargs)):
                    if a.arg == _NAME:
                        yield self._finding(
                            f, a, f"function '{node.name}' takes a "
                            f"'{_NAME}' parameter")
            elif isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg == _NAME:
                        yield self._finding(
                            f, kw.value,
                            f"call forwards '{_NAME}=' into the sharded "
                            "path")
            elif isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    for leaf in ast.walk(t):
                        if isinstance(leaf, ast.Name) and leaf.id == _NAME:
                            yield self._finding(
                                f, leaf,
                                f"assignment creates a '{_NAME}' binding")
                        elif isinstance(leaf, ast.Attribute) \
                                and leaf.attr == _NAME:
                            yield self._finding(
                                f, leaf,
                                f"assignment writes a '.{_NAME}' attribute")

    def _finding(self, f: SourceFile, node: ast.AST, what: str) -> Finding:
        return Finding(
            rule=self.name, severity=self.severity, path=f.rel,
            line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0),
            message=f"{what}: multi-probe on the sharded pdet engine is "
                    "refused until a device-count-invariant global slack "
                    "ranking exists (per-shard ranking breaks PDET==DET "
                    "bit-identity; see ROADMAP follow-on / registry "
                    "fallback)")
