"""The jaxlint rule battery (docs/DESIGN.md §12).

``ALL_RULES`` is the default battery run by ``python -m repro.analysis``.
Rule names double as suppression tokens (``# jaxlint: disable=<name>``);
codes group related rules (JX1xx trace-safety, JX2xx bit-identity, JX3xx
narrow storage, JX4xx registry, JX5xx exports, JX6xx refusal guards, JX7xx
hygiene).
"""

from __future__ import annotations

from repro.analysis.engine import Rule
from repro.analysis.rules.exports import ExportDriftRule
from repro.analysis.rules.hygiene import (PointlessFStringRule,
                                          UnusedImportRule)
from repro.analysis.rules.narrow import NarrowWideningRule
from repro.analysis.rules.probe import PdetProbePlumbingRule
from repro.analysis.rules.registry_rules import (DeprecatedShimRule,
                                                 EngineBypassRule)
from repro.analysis.rules.stability import StableSortRule
from repro.analysis.rules.trace_safety import TraceSafetyRules

ALL_RULES: tuple[Rule, ...] = (
    TraceSafetyRules(),
    StableSortRule(),
    NarrowWideningRule(),
    EngineBypassRule(),
    DeprecatedShimRule(),
    ExportDriftRule(),
    PdetProbePlumbingRule(),
    UnusedImportRule(),
    PointlessFStringRule(),
)

#: Suppression tokens accepted by the engine in addition to rule names:
#: trace-safety emits per-sub-rule names, not its umbrella ``name``.
EXTRA_RULE_NAMES: tuple[str, ...] = (
    TraceSafetyRules.RULE_NP,
    TraceSafetyRules.RULE_COERCE,
    TraceSafetyRules.RULE_ITEM,
    TraceSafetyRules.RULE_BRANCH,
    "syntax-error",
)

__all__ = [
    "ALL_RULES",
    "EXTRA_RULE_NAMES",
    "DeprecatedShimRule",
    "EngineBypassRule",
    "ExportDriftRule",
    "NarrowWideningRule",
    "PdetProbePlumbingRule",
    "PointlessFStringRule",
    "StableSortRule",
    "TraceSafetyRules",
    "UnusedImportRule",
]
