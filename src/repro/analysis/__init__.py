"""jaxlint: trace-safety & bit-identity static analysis for this repo.

Run ``python -m repro.analysis src/ tests/`` (exit 0 = clean) or use the
library surface::

    from repro.analysis import load_project, run_rules, ALL_RULES
    report = run_rules(load_project(["src"]), ALL_RULES)

See docs/DESIGN.md §12 for the invariant-to-rule table, the suppression
policy, and how to add a rule.
"""

from __future__ import annotations

from repro.analysis.engine import (FIXTURE_SENTINEL, SEVERITY_ERROR,
                                   SEVERITY_WARNING, Finding, Project,
                                   Report, Rule, SourceFile, Suppression,
                                   format_human, format_json, load_project,
                                   main, run_rules)
from repro.analysis.rules import ALL_RULES

__all__ = [
    "ALL_RULES",
    "FIXTURE_SENTINEL",
    "Finding",
    "Project",
    "Report",
    "Rule",
    "SEVERITY_ERROR",
    "SEVERITY_WARNING",
    "SourceFile",
    "Suppression",
    "format_human",
    "format_json",
    "load_project",
    "main",
    "run_rules",
]
