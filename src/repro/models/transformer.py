"""Model assembly for all assigned architecture families.

Families:
  dense   — [attn, mlp] x L                  (qwen3-*, qwen1.5, deepseek)
  moe     — [attn, moe(+dense mlp)] x L      (arctic, granite)
  ssm     — [mamba] x L                      (mamba2)
  hybrid  — [0.5*(attn+mamba), mlp] x L      (hymba)
  encdec  — encoder [attn, mlp] x Le + decoder [attn, cross, mlp] x L (whisper)
  vlm     — blocks of (1 cross + N self) scanned                    (llama-vision)

All stacks are ``lax.scan`` over layer-stacked parameters (HLO size is
layer-count independent) with optional per-layer remat for training.

Three entry points per model, matching the assigned shapes:
  ``loss_fn``      (train_4k)    — causal LM loss (+ MoE aux)
  ``prefill``      (prefill_32k) — forward building the KV/SSM cache
  ``decode_step``  (decode_32k / long_500k) — one token against the cache
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.sharding.rules import constrain


def _dtype(name):
    return dict(float32=jnp.float32, bfloat16=jnp.bfloat16,
                float8_e4m3fn=jnp.float8_e4m3fn)[name]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _layer_init(cfg: ModelConfig, key, dtype):
    """One decoder layer's params for the arch family."""
    ks = jax.random.split(key, 4)
    p = {}
    fam = cfg.family
    if fam in ("dense", "moe", "hybrid", "encdec", "vlm"):
        p["attn"] = L.attention_init(ks[0], cfg, dtype)
    if fam in ("ssm", "hybrid"):
        p["mamba"] = S.mamba_init(ks[1], cfg, dtype)
    if fam == "moe":
        p["moe"] = L.moe_init(ks[2], cfg, dtype)
        if cfg.d_ff and cfg.dense_residual:
            p["mlp"] = L.mlp_init(ks[3], cfg, dtype)
    elif fam != "ssm" and cfg.d_ff:
        p["mlp"] = L.mlp_init(ks[3], cfg, dtype)
    if fam == "encdec":
        p["cross"] = L.attention_init(ks[2], cfg, dtype)
    return p


def init_params(cfg: ModelConfig, key: jax.Array):
    dtype = _dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    params: dict = {"tok": L.embedding_init(keys[0], cfg, dtype)}
    if cfg.pos_emb == "learned":
        max_pos = cfg.max_pos or 32768
        params["tok"]["pos_embed"] = L.dense_init(
            keys[6], (max_pos, cfg.d_model), dtype, scale=0.02)

    def stack(key, n, fn):
        return jax.vmap(fn)(jax.random.split(key, n))

    if cfg.family == "vlm":
        every = cfg.cross_attn_every
        n_blocks = cfg.n_layers // every
        n_self = every - 1

        def block_init(k):
            kc, ks_ = jax.random.split(k)
            return {
                "cross": L.attention_init(kc, cfg, dtype),
                "selfs": stack(ks_, n_self,
                               lambda kk: _layer_init(
                                   dataclasses.replace(cfg, family="dense"),
                                   kk, dtype)),
            }

        params["blocks"] = stack(keys[1], n_blocks, block_init)
    else:
        params["layers"] = stack(keys[1], cfg.n_layers,
                                 lambda k: _layer_init(cfg, k, dtype))

    if cfg.family == "encdec":
        enc_cfg = dataclasses.replace(cfg, family="dense")
        params["enc_layers"] = stack(
            keys[2], cfg.enc_layers, lambda k: _layer_init(enc_cfg, k, dtype))
        params["enc_norm"] = L.rmsnorm_init(cfg.d_model, dtype)
        if cfg.pos_emb == "learned":
            params["enc_pos_embed"] = L.dense_init(
                keys[7], (cfg.enc_len, cfg.d_model), dtype, scale=0.02)

    params["final_norm"] = L.rmsnorm_init(cfg.d_model, dtype)
    return params


# ---------------------------------------------------------------------------
# Layer application (full-sequence: train / prefill)
# ---------------------------------------------------------------------------

def _apply_layer(cfg: ModelConfig, p, x, *, causal=True, memory=None,
                 conv_state=None, ssm_state=None):
    """Returns (x, cache_out) where cache_out carries this layer's KV/states."""
    fam = cfg.family
    cache = {}
    if fam in ("dense", "moe", "encdec", "vlm"):
        y, (k, v) = L.self_attention(p["attn"], cfg, x, causal=causal)
        x = x + y
        cache["k"], cache["v"] = k, v
    if fam == "hybrid":
        ya, (k, v) = L.self_attention(p["attn"], cfg, x, causal=causal)
        ym, (new_conv, new_ssm) = S.mamba_block(
            p["mamba"], cfg, x, conv_state=conv_state, ssm_state=ssm_state)
        x = x + 0.5 * (ya + ym)
        cache.update(k=k, v=v, conv=new_conv, ssm=new_ssm)
    if fam == "ssm":
        ym, (new_conv, new_ssm) = S.mamba_block(
            p["mamba"], cfg, x, conv_state=conv_state, ssm_state=ssm_state)
        x = x + ym
        cache.update(conv=new_conv, ssm=new_ssm)
    if fam == "encdec" and memory is not None:
        y, (mk, mv) = L.cross_attention(p["cross"], cfg, x, memory)
        x = x + y
        cache["mem_k"], cache["mem_v"] = mk, mv
    moe_aux = jnp.zeros((), jnp.float32)
    if fam == "moe":
        y, moe_aux = L.moe(p["moe"], cfg, x)
        if "mlp" in p:
            y = y + L.mlp(p["mlp"], cfg, x)
        x = x + y
    elif "mlp" in p:
        x = x + L.mlp(p["mlp"], cfg, x)
    x = constrain(x, ("batch", "residual_seq", "d_model"))
    return x, cache, moe_aux


def _scan_stack(cfg: ModelConfig, stacked, x, *, causal=True, memory=None,
                remat=False, collect_cache=False):
    """Scan x through layer-stacked params; optionally collect per-layer cache."""

    def body(carry, p):
        x, aux = carry
        x, cache, moe_aux = _apply_layer(cfg, p, x, causal=causal,
                                         memory=memory)
        out = cache if collect_cache else None
        return (x, aux + moe_aux), out

    fn = jax.checkpoint(body) if remat else body
    (x, aux), caches = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)),
                                    stacked)
    return x, aux, caches


def _scan_vlm(cfg: ModelConfig, blocks, x, patches, *, remat=False,
              collect_cache=False):
    dense_cfg = dataclasses.replace(cfg, family="dense")

    def body(carry, p):
        x, aux = carry
        yc, (vk, vv) = L.cross_attention(p["cross"], cfg, x, patches)
        x = x + yc
        caches = {"vis_k": vk, "vis_v": vv} if collect_cache else None
        ks, vs = [], []
        n_self = cfg.cross_attn_every - 1
        for i in range(n_self):
            pi = jax.tree.map(lambda a: a[i], p["selfs"])
            x, cache, _ = _apply_layer(dense_cfg, pi, x, causal=True)
            if collect_cache:
                ks.append(cache["k"])
                vs.append(cache["v"])
        if collect_cache:
            caches["k"] = jnp.stack(ks)
            caches["v"] = jnp.stack(vs)
        return (x, aux), caches

    fn = jax.checkpoint(body) if remat else body
    (x, aux), caches = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)),
                                    blocks)
    return x, aux, caches


def _encode(cfg: ModelConfig, params, frames):
    """Whisper encoder over stubbed frame embeddings (b, enc_len, d)."""
    x = frames
    if "enc_pos_embed" in params:
        x = x + params["enc_pos_embed"][None, :x.shape[1]]
    enc_cfg = dataclasses.replace(cfg, family="dense")
    x, _, _ = _scan_stack(enc_cfg, params["enc_layers"], x, causal=False)
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _embed_tokens(cfg, params, tokens, offset=0):
    x = L.embed(params["tok"], cfg, tokens)
    if cfg.pos_emb == "learned":
        pe = jax.lax.dynamic_slice_in_dim(params["tok"]["pos_embed"], offset,
                                          tokens.shape[1], axis=0)
        x = x + pe[None]
    return x.astype(_dtype(cfg.compute_dtype))


def forward(cfg: ModelConfig, params, batch, *, remat=False,
            collect_cache=False):
    """Full-sequence forward.  batch: dict(tokens[, frames | patches])."""
    x = _embed_tokens(cfg, params, batch["tokens"])
    memory = None
    if cfg.family == "encdec":
        memory = _encode(cfg, params, batch["frames"].astype(x.dtype))
    if cfg.family == "vlm":
        x, aux, caches = _scan_vlm(cfg, params["blocks"], x,
                                   batch["patches"].astype(x.dtype),
                                   remat=remat, collect_cache=collect_cache)
    else:
        x, aux, caches = _scan_stack(cfg, params["layers"], x, causal=True,
                                     memory=memory, remat=remat,
                                     collect_cache=collect_cache)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux, caches


# ---------------------------------------------------------------------------
# Training loss
# ---------------------------------------------------------------------------

def _chunked_xent(cfg: ModelConfig, tok_params, x, labels, *,
                  chunk: int = 512):
    """Cross-entropy without materializing full (B, S, V) logits.

    Scans over sequence chunks with a rematerialized body: the backward pass
    recomputes each chunk's logits, bounding live logits memory to one chunk
    (the vocab matmul dominates otherwise: 1M tokens x 152k vocab in f32 is
    hundreds of GB).
    """
    B, S, D = x.shape
    n_chunks = max(1, S // chunk)
    chunk = S // n_chunks if S % n_chunks == 0 else S
    n_chunks = S // chunk
    xc = x.reshape(B, n_chunks, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, inp):
        nll_sum, cnt = carry
        xi, li = inp
        lg = L.logits(tok_params, cfg, xi).astype(jnp.float32)
        logz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(
            lg, jnp.maximum(li, 0)[..., None], axis=-1)[..., 0]
        mask = (li >= 0).astype(jnp.float32)
        nll_sum = nll_sum + ((logz - gold) * mask).sum()
        cnt = cnt + mask.sum()
        return (nll_sum, cnt), None

    (nll_sum, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, lc))
    return nll_sum / jnp.maximum(cnt, 1.0)


def loss_fn(cfg: ModelConfig, params, batch, *, aux_weight=0.01):
    x, moe_aux, _ = forward(cfg, params, batch, remat=cfg.parallel.remat)
    loss = _chunked_xent(cfg, params["tok"], x, batch["labels"])
    total = loss + aux_weight * moe_aux / max(cfg.n_layers, 1)
    return total, {"loss": loss, "moe_aux": moe_aux,
                   "perplexity": jnp.exp(jnp.minimum(loss, 20.0))}


# ---------------------------------------------------------------------------
# Prefill: build the cache for decode
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params, batch):
    """Forward building the cache; returns (last-token logits, cache, length)."""
    x, _, caches = forward(cfg, params, batch, collect_cache=True)
    last = x[:, -1:, :]
    lg = L.logits(params["tok"], cfg, last)
    kv_dtype = _dtype(cfg.parallel.kv_cache_dtype)
    cache = {}
    if caches:
        for k_, v_ in caches.items():
            if k_ in ("k", "v", "mem_k", "mem_v", "vis_k", "vis_v"):
                cache[k_] = _constrain_cache(v_.astype(kv_dtype))
            else:
                cache[k_] = v_
    length = jnp.asarray(batch["tokens"].shape[1], jnp.int32)
    return lg, cache, length


def _constrain_cache(c):
    # (layers, b, s, hk, dh) — batch over data, kv seq over model (CP)
    if c.ndim == 5:
        return constrain(c, (None, "batch", "kv_seq", "kv_heads", None))
    return c


# ---------------------------------------------------------------------------
# Decode: one token against the cache
# ---------------------------------------------------------------------------

def decode_step(cfg: ModelConfig, params, token, cache, length):
    """token (b, 1) int32; cache from ``prefill``/``cache_spec``; length ().

    Returns (logits (b, 1, vocab), new cache).  The cache rides in the scan
    *carry* (updated in place with dynamic_update_index) rather than as
    xs->ys: a ys output cannot alias the xs input, which double-buffers the
    entire multi-GB cache (EXPERIMENTS.md §Perf iteration 5).
    """
    x = _embed_tokens(cfg, params, token, offset=length)

    if cfg.family == "vlm":
        return _decode_vlm(cfg, params, x, cache, length)

    nl = cfg.n_layers

    def body(carry, inp):
        x, cache = carry
        p, l_idx = inp
        cache_l = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, l_idx, 0,
                                                   keepdims=False), cache)
        new_cache = dict(cache_l)
        fam = cfg.family
        if fam in ("dense", "moe", "encdec"):
            y, ck, cv = L.decode_self_attention(p["attn"], cfg, x,
                                                cache_l["k"], cache_l["v"],
                                                length)
            x = x + y
            new_cache.update(k=ck, v=cv)
        if fam == "hybrid":
            ya, ck, cv = L.decode_self_attention(p["attn"], cfg, x,
                                                 cache_l["k"], cache_l["v"],
                                                 length)
            ym, (nc, ns) = S.mamba_block(p["mamba"], cfg, x,
                                         conv_state=cache_l["conv"],
                                         ssm_state=cache_l["ssm"],
                                         decode=True)
            x = x + 0.5 * (ya + ym)
            new_cache.update(k=ck, v=cv, conv=nc, ssm=ns)
        if fam == "ssm":
            ym, (nc, ns) = S.mamba_block(p["mamba"], cfg, x,
                                         conv_state=cache_l["conv"],
                                         ssm_state=cache_l["ssm"],
                                         decode=True)
            x = x + ym
            new_cache.update(conv=nc, ssm=ns)
        if fam == "encdec":
            y = L.decode_cross_attention(p["cross"], cfg, x,
                                         cache_l["mem_k"], cache_l["mem_v"])
            x = x + y
        if fam == "moe":
            # decode never drops tokens: full capacity (T*k per expert)
            y, _ = L.moe(p["moe"], cfg, x,
                         capacity_factor=float(cfg.n_experts))
            if "mlp" in p:
                y = y + L.mlp(p["mlp"], cfg, x)
            x = x + y
        elif "mlp" in p:
            x = x + L.mlp(p["mlp"], cfg, x)
        cache = jax.tree.map(
            lambda c, n: jax.lax.dynamic_update_index_in_dim(
                c, n.astype(c.dtype), l_idx, 0), cache, new_cache)
        return (x, cache), None

    (x, new_cache), _ = jax.lax.scan(
        body, (x, cache), (params["layers"], jnp.arange(nl)))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    lg = L.logits(params["tok"], cfg, x)
    return lg, new_cache


def _decode_vlm(cfg, params, x, cache, length):
    dense_cfg = dataclasses.replace(cfg, family="dense")
    n_self = cfg.cross_attn_every - 1
    nb = cfg.n_layers // cfg.cross_attn_every

    def body(carry, inp):
        x, cache = carry
        p, b_idx = inp
        cache_b = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, b_idx, 0,
                                                   keepdims=False), cache)
        y = L.decode_cross_attention(p["cross"], cfg, x, cache_b["vis_k"],
                                     cache_b["vis_v"])
        x = x + y
        ks, vs = [], []
        for i in range(n_self):
            pi = jax.tree.map(lambda a: a[i], p["selfs"])
            ci_k = cache_b["k"][i]
            ci_v = cache_b["v"][i]
            ya, ck, cv = L.decode_self_attention(pi["attn"], dense_cfg, x,
                                                 ci_k, ci_v, length)
            x = x + ya
            x = x + L.mlp(pi["mlp"], dense_cfg, x)
            ks.append(ck)
            vs.append(cv)
        new_b = dict(cache_b, k=jnp.stack(ks), v=jnp.stack(vs))
        cache = jax.tree.map(
            lambda c, n: jax.lax.dynamic_update_index_in_dim(
                c, n.astype(c.dtype), b_idx, 0), cache, new_b)
        return (x, cache), None

    (x, new_cache), _ = jax.lax.scan(
        body, (x, cache), (params["blocks"], jnp.arange(nb)))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    lg = L.logits(params["tok"], cfg, x)
    return lg, new_cache


# ---------------------------------------------------------------------------
# Cache specs (for decode dry-runs without running prefill)
# ---------------------------------------------------------------------------

def cache_spec(cfg: ModelConfig, batch: int, cache_len: int):
    """ShapeDtypeStruct pytree of the decode cache."""
    kv_dtype = _dtype(cfg.parallel.kv_cache_dtype)
    hk, dh = cfg.n_kv_heads, cfg.head_dim
    nl = cfg.n_layers
    sds = jax.ShapeDtypeStruct
    fam = cfg.family
    out = {}
    if fam in ("dense", "moe", "encdec", "hybrid"):
        out["k"] = sds((nl, batch, cache_len, hk, dh), kv_dtype)
        out["v"] = sds((nl, batch, cache_len, hk, dh), kv_dtype)
    if fam in ("ssm", "hybrid"):
        conv, ssm_ = S.mamba_state_shapes(cfg, batch)
        out["conv"] = sds((nl,) + conv, _dtype(cfg.compute_dtype))
        out["ssm"] = sds((nl,) + ssm_, jnp.float32)
    if fam == "encdec":
        out["mem_k"] = sds((nl, batch, cfg.enc_len, hk, dh), kv_dtype)
        out["mem_v"] = sds((nl, batch, cfg.enc_len, hk, dh), kv_dtype)
    if fam == "vlm":
        every = cfg.cross_attn_every
        nb, ns = cfg.n_layers // every, every - 1
        out["k"] = sds((nb, ns, batch, cache_len, hk, dh), kv_dtype)
        out["v"] = sds((nb, ns, batch, cache_len, hk, dh), kv_dtype)
        out["vis_k"] = sds((nb, batch, cfg.vision_len, hk, dh), kv_dtype)
        out["vis_v"] = sds((nb, batch, cfg.vision_len, hk, dh), kv_dtype)
    return out
