"""Transformer building blocks: norms, RoPE, GQA attention, MLP, MoE.

Functional style: ``init_*`` returns a param dict; ``apply`` functions are
pure.  Activations carry logical sharding annotations via
``repro.sharding.rules.constrain`` (no-ops outside a mesh context).

Attention is blockwise (online softmax) in XLA — the dry-run-compilable
path — with the Pallas flash kernel as the TPU production path selected by
``repro.kernels.ops``.  GQA is handled natively (KV never repeated).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.rules import constrain


def _dtype(name: str):
    return dict(float32=jnp.float32, bfloat16=jnp.bfloat16,
                float16=jnp.float16,
                float8_e4m3fn=jnp.float8_e4m3fn)[name]


def dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) >= 1 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., s, h, dh); positions (..., s) or (s,)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs[None, :]
    cos = jnp.cos(ang)[..., :, None, :]     # (..., s, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (blockwise, XLA) — prefill/train path
# ---------------------------------------------------------------------------

def _fa_blocks(k, v, block_k):
    b, sk, hk, dh = k.shape
    nblk = -(-sk // block_k)
    pad = nblk * block_k - sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = kp.reshape(b, nblk, block_k, hk, dh).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(b, nblk, block_k, hk, dh).transpose(1, 0, 2, 3, 4)
    kpos = jnp.arange(nblk * block_k).reshape(nblk, block_k)
    return kb.astype(jnp.float32), vb.astype(jnp.float32), kpos


def _fa_forward(q, k, v, causal, block_k, q_offset):
    """Online-softmax forward.  Returns (out_f32 (b,sq,g,hk,dh), m, l)."""
    b, sq, h, dh = q.shape
    sk, hk = k.shape[1], k.shape[2]
    g = h // hk
    scale = 1.0 / math.sqrt(dh)
    qf = (q.astype(jnp.float32) * scale).reshape(b, sq, hk, g, dh)
    kb, vb, kpos = _fa_blocks(k, v, block_k)
    qpos = q_offset + jnp.arange(sq)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        kblk, vblk, kp_blk = inp
        s = jnp.einsum("bqkgd,bckd->bqgkc", qf, kblk)   # (b,sq,g,hk,block)
        mask = kp_blk[None, :] < sk
        if causal:
            mask = mask & (kp_blk[None, :] <= qpos[:, None])
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        m_cur = jnp.maximum(m_prev, s.max(-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[..., None])
        l_cur = l_prev * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bqgkc,bckd->bqgkd", p, vblk)
        return (m_cur, l_cur, acc), None

    m0 = jnp.full((b, sq, g, hk), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, sq, g, hk), jnp.float32)
    acc0 = jnp.zeros((b, sq, g, hk, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kb, vb, kpos))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out, m, l


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def blockwise_gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                            causal: bool = True, block_k: int = 1024,
                            q_offset: int = 0) -> jax.Array:
    """FlashAttention in XLA with a block-recomputing backward (custom_vjp).

    q (b, sq, h, dh); k/v (b, sk, hk, dh), h % hk == 0 (GQA native — KV is
    never repeated).  Neither pass materializes (sq, sk): the forward is an
    online-softmax scan over KV blocks; the backward recomputes each block's
    probabilities from the saved (m, l) statistics — the standard flash
    backward, which is what keeps train_4k activation memory linear in S.
    """
    out, _, _ = _fa_forward(q, k, v, causal, block_k, q_offset)
    b, sq, h, dh = q.shape
    # out is (b, sq, g, hk, dh); input head order is (hk, g)
    return out.transpose(0, 1, 3, 2, 4).reshape(b, sq, h, dh).astype(q.dtype)


def _fa_vjp_fwd(q, k, v, causal, block_k, q_offset):
    out, m, l = _fa_forward(q, k, v, causal, block_k, q_offset)
    b, sq, h, dh = q.shape
    return (out.transpose(0, 1, 3, 2, 4).reshape(b, sq, h, dh).astype(q.dtype),
            (q, k, v, out, m, l))


def _fa_vjp_bwd(causal, block_k, q_offset, res, dout):
    q, k, v, out, m, l = res
    b, sq, h, dh = q.shape
    sk, hk = k.shape[1], k.shape[2]
    g = h // hk
    scale = 1.0 / math.sqrt(dh)
    qf = (q.astype(jnp.float32) * scale).reshape(b, sq, hk, g, dh)
    do = dout.astype(jnp.float32).reshape(b, sq, hk, g, dh)
    kb, vb, kpos = _fa_blocks(k, v, block_k)
    qpos = q_offset + jnp.arange(sq)
    lsafe = jnp.maximum(l, 1e-30)
    # D = rowsum(dout * out)  (out here is the normalized f32 output)
    D = jnp.sum(do.transpose(0, 1, 3, 2, 4) * out, axis=-1)  # (b,sq,g,hk)

    def step(dq_acc, inp):
        kblk, vblk, kp_blk = inp
        s = jnp.einsum("bqkgd,bckd->bqgkc", qf, kblk)
        mask = kp_blk[None, :] < sk
        if causal:
            mask = mask & (kp_blk[None, :] <= qpos[:, None])
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        p = jnp.exp(s - m[..., None]) / lsafe[..., None]      # (b,q,g,hk,c)
        dv_blk = jnp.einsum("bqgkc,bqkgd->bckd", p, do)
        dp = jnp.einsum("bqkgd,bckd->bqgkc", do, vblk)
        ds = p * (dp - D[..., None])                          # (b,q,g,hk,c)
        dq_blk = jnp.einsum("bqgkc,bckd->bqkgd", ds, kblk) * scale
        dk_blk = jnp.einsum("bqgkc,bqkgd->bckd", ds, qf)  # qf carries scale
        return dq_acc + dq_blk, (dk_blk, dv_blk)

    dq0 = jnp.zeros((b, sq, hk, g, dh), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(step, dq0, (kb, vb, kpos))
    nblk = kb.shape[0]
    dk = dk_b.transpose(1, 0, 2, 3, 4).reshape(b, nblk * block_k, hk, dh)
    dv = dv_b.transpose(1, 0, 2, 3, 4).reshape(b, nblk * block_k, hk, dh)
    return (dq.reshape(b, sq, h, dh).astype(q.dtype),
            dk[:, :sk].astype(k.dtype), dv[:, :sk].astype(v.dtype))


blockwise_gqa_attention.defvjp(_fa_vjp_fwd, _fa_vjp_bwd)


def flash_attention_xla(q, k, v, causal=True, *, block_q: int = 1024,
                        block_k: int = 512, q_offset: int = 0):
    """Query-and-key tiled flash attention (XLA scan over q chunks).

    Bounds live score memory to (block_q x block_k) per step in both passes;
    dk/dv accumulate across q chunks via the scan transpose.
    """
    b, sq, h, dh = q.shape
    if sq <= block_q:
        return blockwise_gqa_attention(q, k, v, causal, min(block_k,
                                       max(k.shape[1], 1)), q_offset)
    nq = -(-sq // block_q)
    pad = nq * block_q - sq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qc = qp.reshape(b, nq, block_q, h, dh).transpose(1, 0, 2, 3, 4)
    # per-chunk position offsets, scanned (f32 so the custom_vjp can emit a
    # zero cotangent); one HLO body regardless of nq.
    offs = (q_offset + jnp.arange(nq) * block_q).astype(jnp.float32)
    outs = jax.lax.map(
        lambda args: _fa_offset_attention(args[0], k, v, causal, block_k,
                                          args[1]),
        (qc, offs))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nq * block_q, h, dh)
    return out[:, :sq]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _fa_offset_attention(q, k, v, causal, block_k, q_offset):
    out, _, _ = _fa_forward_dyn(q, k, v, causal, block_k, q_offset)
    b, sq, h, dh = q.shape
    return out.transpose(0, 1, 3, 2, 4).reshape(b, sq, h, dh).astype(q.dtype)


def _fa_forward_dyn(q, k, v, causal, block_k, q_offset):
    """_fa_forward with a *traced* q_offset (for q-chunked scans)."""
    b, sq, h, dh = q.shape
    sk, hk = k.shape[1], k.shape[2]
    g = h // hk
    scale = 1.0 / math.sqrt(dh)
    qf = (q.astype(jnp.float32) * scale).reshape(b, sq, hk, g, dh)
    kb, vb, kpos = _fa_blocks(k, v, block_k)
    qpos = q_offset.astype(jnp.int32) + jnp.arange(sq)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        kblk, vblk, kp_blk = inp
        s = jnp.einsum("bqkgd,bckd->bqgkc", qf, kblk)
        mask = kp_blk[None, :] < sk
        if causal:
            mask = mask & (kp_blk[None, :] <= qpos[:, None])
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        m_cur = jnp.maximum(m_prev, s.max(-1))
        m_cur = jnp.maximum(m_cur, -1e30)   # fully-masked rows stay finite
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[..., None])
        l_cur = l_prev * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bqgkc,bckd->bqgkd", p, vblk)
        return (m_cur, l_cur, acc), None

    m0 = jnp.full((b, sq, g, hk), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, sq, g, hk), jnp.float32)
    acc0 = jnp.zeros((b, sq, g, hk, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kb, vb, kpos))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out, m, l


def _fa_dyn_fwd(q, k, v, causal, block_k, q_offset):
    out, m, l = _fa_forward_dyn(q, k, v, causal, block_k, q_offset)
    b, sq, h, dh = q.shape
    return (out.transpose(0, 1, 3, 2, 4).reshape(b, sq, h, dh).astype(q.dtype),
            (q, k, v, out, m, l, q_offset))


def _fa_dyn_bwd(causal, block_k, res, dout):
    q, k, v, out, m, l, q_offset = res
    b, sq, h, dh = q.shape
    sk, hk = k.shape[1], k.shape[2]
    g = h // hk
    scale = 1.0 / math.sqrt(dh)
    qf = (q.astype(jnp.float32) * scale).reshape(b, sq, hk, g, dh)
    do = dout.astype(jnp.float32).reshape(b, sq, hk, g, dh)
    kb, vb, kpos = _fa_blocks(k, v, block_k)
    qpos = q_offset.astype(jnp.int32) + jnp.arange(sq)
    lsafe = jnp.maximum(l, 1e-30)
    D = jnp.sum(do.transpose(0, 1, 3, 2, 4) * out, axis=-1)

    def step(dq_acc, inp):
        kblk, vblk, kp_blk = inp
        s = jnp.einsum("bqkgd,bckd->bqgkc", qf, kblk)
        mask = kp_blk[None, :] < sk
        if causal:
            mask = mask & (kp_blk[None, :] <= qpos[:, None])
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        p = jnp.exp(s - m[..., None]) / lsafe[..., None]
        dv_blk = jnp.einsum("bqgkc,bqkgd->bckd", p, do)
        dp = jnp.einsum("bqkgd,bckd->bqgkc", do, vblk)
        ds = p * (dp - D[..., None])
        dq_blk = jnp.einsum("bqgkc,bckd->bqkgd", ds, kblk) * scale
        dk_blk = jnp.einsum("bqgkc,bqkgd->bckd", ds, qf)
        return dq_acc + dq_blk, (dk_blk, dv_blk)

    dq0 = jnp.zeros((b, sq, hk, g, dh), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(step, dq0, (kb, vb, kpos))
    nblk = kb.shape[0]
    dk = dk_b.transpose(1, 0, 2, 3, 4).reshape(b, nblk * block_k, hk, dh)
    dv = dv_b.transpose(1, 0, 2, 3, 4).reshape(b, nblk * block_k, hk, dh)
    return (dq.reshape(b, sq, h, dh).astype(q.dtype),
            dk[:, :sk].astype(k.dtype), dv[:, :sk].astype(v.dtype),
            jnp.zeros_like(q_offset))


_fa_offset_attention.defvjp(_fa_dyn_fwd, _fa_dyn_bwd)


def _decode_attention_cp(q, k_cache, v_cache, length, rules):
    """Explicit context-parallel flash-decode via shard_map.

    The cache's seq dim is sharded over 'model'; each rank attends over its
    local span and the softmax statistics merge with pmax/psum (log-sum-exp
    combine).  A scan/reshape formulation lets GSPMD serialize or replicate
    the cache across ranks (observed as 'involuntary full rematerialization'
    — §Perf iteration 11); shard_map pins the local-compute + tiny-merge
    structure explicitly."""
    from jax.sharding import PartitionSpec as P

    from repro.sharding.compat import shard_map

    b, _, h, dh = q.shape
    S, hk = k_cache.shape[1], k_cache.shape[2]
    g = h // hk
    scale = 1.0 / math.sqrt(dh)
    ax = rules.rules.get("kv_seq")
    m_size = rules.axis_size(ax)
    batch_ax = rules.rules.get("batch")
    b_ax = batch_ax if (b % rules.axis_size(batch_ax) == 0) else None
    S_loc = S // m_size

    def inner(qv, kl, vl, ln):
        # qv (b_l, 1, h, dh); kl/vl (b_l, S_loc, hk, dh); ln ()
        idx = jax.lax.axis_index(ax)
        pos = idx * S_loc + jnp.arange(S_loc)
        qf = (qv.astype(jnp.float32) * scale).reshape(-1, hk, g, dh)
        s = jnp.einsum("bkgd,bskd->bkgs", qf, kl.astype(jnp.float32))
        mask = pos[None, None, None, :] < ln
        s = jnp.where(mask, s, -jnp.inf)
        m_loc = jnp.maximum(s.max(-1), -1e30)           # (b_l, hk, g)
        p = jnp.exp(s - m_loc[..., None])
        l_loc = jnp.where(mask.any(-1), p.sum(-1), 0.0)
        acc = jnp.einsum("bkgs,bskd->bkgd", p * mask, vl.astype(jnp.float32))
        m_g = jax.lax.pmax(m_loc, ax)
        corr = jnp.exp(m_loc - m_g)
        l_g = jax.lax.psum(l_loc * corr, ax)
        acc_g = jax.lax.psum(acc * corr[..., None], ax)
        out = acc_g / jnp.maximum(l_g[..., None], 1e-30)
        return out.reshape(-1, 1, h, dh).astype(qv.dtype)

    return shard_map(
        inner, mesh=rules.mesh,
        in_specs=(P(b_ax), P(b_ax, ax), P(b_ax, ax), P()),
        out_specs=P(b_ax), check_vma=False,
    )(q, k_cache, v_cache,
      jnp.asarray(length, jnp.int32))


def decode_gqa_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         length: jax.Array | int, *,
                         block_s: int = 4096) -> jax.Array:
    """Single-token decode: q (b, 1, h, dh); caches (b, S, hk, dh).

    Blockwise over the cache sequence with online softmax: the low-precision
    cache (bf16 / fp8) is upcast one block at a time — a monolithic
    ``cache.astype(f32)`` materializes the whole cache again in f32, which
    dominated decode_32k memory (EXPERIMENTS.md §Perf iteration 4).

    The cache's sequence dim may be sharded over the 'model' axis (context
    parallelism): the running max/sum reductions become cross-shard
    collectives inserted by GSPMD — the distributed flash-decode pattern.
    """
    b, _, h, dh = q.shape
    S, hk = k_cache.shape[1], k_cache.shape[2]
    from repro.sharding.rules import active_rules
    r = active_rules()
    if r is not None:
        ax = r.rules.get("kv_seq")
        ms = r.axis_size(ax)
        if isinstance(ax, str) and ms > 1 and S % ms == 0 and S >= 8 * ms:
            return _decode_attention_cp(q, k_cache, v_cache, length, r)
    g = h // hk
    scale = 1.0 / math.sqrt(dh)
    qf = (q.astype(jnp.float32) * scale).reshape(b, hk, g, dh)

    if S <= block_s:
        kb = k_cache[:, None]
        vb = v_cache[:, None]
        nb, bs = 1, S
    else:
        nb = -(-S // block_s)
        pad = nb * block_s - S
        kb = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0))) \
            .reshape(b, nb, block_s, hk, dh)
        vb = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0))) \
            .reshape(b, nb, block_s, hk, dh)
        bs = block_s
    kpos = jnp.arange(nb * bs).reshape(nb, bs)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        kblk, vblk, pos = inp                          # (b,bs,hk,dh), (bs,)
        s = jnp.einsum("bkgd,bskd->bkgs", qf, kblk.astype(jnp.float32))
        mask = pos[None, None, None, :] < length
        s = jnp.where(mask, s, -jnp.inf)
        m_cur = jnp.maximum(m_prev, s.max(-1))
        m_cur = jnp.maximum(m_cur, -1e30)
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[..., None])
        l_cur = l_prev * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgs,bskd->bkgd", p, vblk.astype(jnp.float32))
        return (m_cur, l_cur, acc), None

    m0 = jnp.full((b, hk, g), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hk, g), jnp.float32)
    acc0 = jnp.zeros((b, hk, g, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0),
        (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4), kpos))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, 1, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (self or cross)
# ---------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig, dtype, *, n_heads=None,
                   n_kv_heads=None):
    h = n_heads or cfg.n_heads
    hk = n_kv_heads or cfg.n_kv_heads
    d, dh = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], (d, h, dh), dtype),
        "wk": dense_init(ks[1], (d, hk, dh), dtype),
        "wv": dense_init(ks[2], (d, hk, dh), dtype),
        "wo": dense_init(ks[3], (h, dh, d), dtype,
                         scale=1.0 / math.sqrt(h * dh * 2 * cfg.n_layers)),
        "ln": rmsnorm_init(d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh), dtype)
        p["bk"] = jnp.zeros((hk, dh), dtype)
        p["bv"] = jnp.zeros((hk, dh), dtype)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(dh, dtype)
        p["k_norm"] = rmsnorm_init(dh, dtype)
    return p


def _qkv(params, cfg: ModelConfig, x, kv_x=None):
    kv_x = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"][None, None]
        k = k + params["bk"][None, None]
        v = v + params["bv"][None, None]
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    return q, k, v


def _maybe_flatten_gqa(k, v, h):
    """Repeat KV to full q-heads when q-heads shard over 'model' but the
    (hk, g) factorization would break sharding propagation.

    GSPMD cannot re-split a 16-way head sharding across an (hk=8, g=2)
    reshape and falls back to full replication ("involuntary full
    rematerialization" — the dominant collective term in the baseline
    roofline; §Perf iteration 10).  With KV repeated, attention stays in
    flat-head layout and every tensor keeps its 'model' sharding."""
    from repro.sharding.rules import active_rules
    r = active_rules()
    if r is None:
        return k, v
    axs = r.axis_size(r.rules.get("heads"))
    hk = k.shape[2]
    # g <= 4 only: at g = 8 the repeated KV is 8x the compact cache and the
    # seq-unshard gathers on it cost more than the (hk, g)-reshape
    # replication it avoids (measured: llama-90b train all-gather body
    # bytes 4.6G -> 23.9G with flat-head at g=8; §Perf iteration 13).
    if axs > 1 and h % axs == 0 and hk % axs != 0 and h != hk \
            and h // hk <= 4:
        g = h // hk
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        k = constrain(k, ("batch", "seq", "heads", None))
        v = constrain(v, ("batch", "seq", "heads", None))
    return k, v


def self_attention(params, cfg: ModelConfig, x, *, causal=True,
                   positions=None):
    """Full-sequence self-attention (train / encoder / prefill core)."""
    xn = rmsnorm(params["ln"], x, cfg.norm_eps)
    q, k, v = _qkv(params, cfg, xn)
    q = constrain(q, ("batch", "seq", "heads", None))
    k = constrain(k, ("batch", "seq", "kv_heads", None))
    if cfg.pos_emb == "rope":
        pos = positions if positions is not None else jnp.arange(x.shape[1])
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    kv_cache = (k, v)          # cache keeps the compact GQA layout
    k, v = _maybe_flatten_gqa(k, v, q.shape[2])
    out = flash_attention_xla(q, k, v, causal)
    out = constrain(out, ("batch", "seq", "heads", None))
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return constrain(y, ("batch", "residual_seq", "d_model")), kv_cache


def cross_attention(params, cfg: ModelConfig, x, memory):
    """Cross-attention to a (b, m, d) memory (whisper decoder / VLM)."""
    xn = rmsnorm(params["ln"], x, cfg.norm_eps)
    q, k, v = _qkv(params, cfg, xn, kv_x=memory)
    out = flash_attention_xla(q, k, v, False)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return constrain(y, ("batch", "residual_seq", "d_model")), (k, v)


def decode_self_attention(params, cfg: ModelConfig, x, cache_k, cache_v,
                          length, kv_decoder=None):
    """One-token decode against a (b, S, hk, dh) cache; writes slot ``length``.

    ``kv_decoder`` (a ``repro.decode.LSHDecoder`` over this layer's cache,
    optional) swaps the dense cache scan for LSH sparse decode: the new
    key is upserted into the decoder's ``KVCacheIndex`` and attention runs
    over the retrieved ∪ window ∪ sink set.  The decoder mutates host
    state, so this path is host-loop only — do not jit/scan over it (the
    default dense path stays fully traceable).
    """
    xn = rmsnorm(params["ln"], x, cfg.norm_eps)
    q, k, v = _qkv(params, cfg, xn)
    if cfg.pos_emb == "rope":
        pos = jnp.full((1,), length, jnp.int32)
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), length, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), length, axis=1)
    if kv_decoder is not None:
        out = kv_decoder.step(q, cache_k, cache_v, k[:, 0], length + 1)
    else:
        out = decode_gqa_attention(q, cache_k, cache_v, length + 1)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, cache_k, cache_v


def decode_cross_attention(params, cfg: ModelConfig, x, mem_k, mem_v):
    """Decode-time cross-attention against precomputed memory KV."""
    xn = rmsnorm(params["ln"], x, cfg.norm_eps)
    q, _, _ = _qkv(params, cfg, xn)   # memory K/V precomputed at prefill
    out = decode_gqa_attention(q, mem_k, mem_v, mem_k.shape[1])
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, dtype, d_ff=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "ln": rmsnorm_init(d, dtype),
        "w_gate": dense_init(ks[0], (d, f), dtype),
        "w_up": dense_init(ks[1], (d, f), dtype),
        "w_down": dense_init(ks[2], (f, d), dtype,
                             scale=1.0 / math.sqrt(f * 2 * cfg.n_layers)),
    }


def mlp(params, cfg: ModelConfig, x):
    xn = rmsnorm(params["ln"], x, cfg.norm_eps)
    g = jnp.einsum("bsd,df->bsf", xn, params["w_gate"])
    u = jnp.einsum("bsd,df->bsf", xn, params["w_up"])
    h = jax.nn.silu(g) * u
    h = constrain(h, ("batch", "seq", "d_ff"))
    y = jnp.einsum("bsf,fd->bsd", h, params["w_down"])
    return constrain(y, ("batch", "residual_seq", "d_model"))


# ---------------------------------------------------------------------------
# Mixture of Experts (capacity-dropping, sort-based grouped matmul)
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig, dtype):
    d, f, E = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "ln": rmsnorm_init(d, dtype),
        "router": dense_init(ks[0], (d, E), dtype, scale=0.02),
        "we_gate": dense_init(ks[1], (E, d, f), dtype),
        "we_up": dense_init(ks[2], (E, d, f), dtype),
        "we_down": dense_init(ks[3], (E, f, d), dtype,
                              scale=1.0 / math.sqrt(f * 2 * cfg.n_layers)),
    }


def _moe_local_dispatch(xt, router, we_gate, we_up, we_down, E, k, C, *,
                        axis=None):
    """Routed FFN on a flat (T, d) token block with per-expert capacity C.

    With ``axis`` set (inside shard_map), the expert dim is exchanged via
    all_to_all so each rank computes only E/ranks experts over all ranks'
    dispatched tokens (expert parallelism), then a second all_to_all
    returns the outputs.
    """
    T, d = xt.shape
    logits = jnp.einsum("td,de->te", xt, router).astype(jnp.float32)
    gate_all = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(gate_all, k)             # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = eids.reshape(-1)                            # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    starts = jnp.searchsorted(se, jnp.arange(E), side="left")
    pos = jnp.arange(T * k) - starts[se]                 # rank within expert
    keep = pos < C
    pos_c = jnp.clip(pos, 0, C - 1)

    buf = jnp.zeros((E, C, d), xt.dtype)
    buf = buf.at[se, pos_c].add(jnp.where(keep[:, None], xt[st], 0))

    if axis is not None:
        buf = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=1,
                                 tiled=True)             # (E_loc, C*m, d)
    else:
        buf = constrain(buf, ("experts", None, None))
    h_g = jnp.einsum("ecd,edf->ecf", buf, we_gate)
    h_u = jnp.einsum("ecd,edf->ecf", buf, we_up)
    h = jax.nn.silu(h_g) * h_u
    out_buf = jnp.einsum("ecf,efd->ecd", h, we_down)
    if axis is not None:
        out_buf = jax.lax.all_to_all(out_buf, axis, split_axis=1,
                                     concat_axis=0, tiled=True)  # (E, C, d)
    else:
        out_buf = constrain(out_buf, ("experts", None, None))

    contrib = out_buf[se, pos_c] * (sg * keep)[:, None]
    y = jnp.zeros((T, d), xt.dtype).at[st].add(contrib.astype(xt.dtype))
    aux = moe_load_balance_loss(gate_all, eids, E)
    return y, aux


def _moe_sharded(params, cfg: ModelConfig, x, rules, cf):
    """Expert-parallel MoE via nested shard_map (the production path).

    Tokens shard (batch over the data axes, sequence over 'model'); each
    rank dispatches its own tokens into an (E, C_loc, d) buffer; all_to_all
    moves expert rows to their owning rank for the grouped matmul and back.
    Dispatch buffers are per-rank sized (C_loc = T_loc*k*cf/E) — with the
    GSPMD-propagated global scatter they were the dominant memory term at
    train_4k (EXPERIMENTS.md §Perf iteration 2).
    """
    from jax.sharding import PartitionSpec as P

    from repro.sharding.compat import shard_map

    mesh = rules.mesh
    b, s, d = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    batch_ax = rules.rules.get("batch")
    model_ax = rules.rules.get("experts")
    xn = rmsnorm(params["ln"], x, cfg.norm_eps)

    m_size = rules.axis_size(model_ax)
    b_size = rules.axis_size(batch_ax)
    T_loc = (b // b_size) * (s // m_size)
    C_loc = max(1, int(T_loc * k * cf / E))
    batch_axes = batch_ax if isinstance(batch_ax, tuple) else (batch_ax,)

    # ZeRO-3 for expert weights *inside* the shard_map: weights enter
    # sharded on (experts x fsdp) and are all-gathered over the fsdp axis
    # just-in-time; autodiff turns the gather into a reduce-scatter, so the
    # expert grads leave 2-D sharded instead of transiently materializing
    # model-sharded-only f32 tensors (§Perf iteration 9 — arctic train).
    fsdp_ax = rules.rules.get("fsdp")
    use_fsdp = (isinstance(fsdp_ax, str) and fsdp_ax != model_ax
                and d % rules.axis_size(fsdp_ax) == 0)
    w_spec = P(model_ax, fsdp_ax, None) if use_fsdp \
        else P(model_ax, None, None)

    def inner(xs, router, we_g, we_u, we_d):
        bl, sl, _ = xs.shape
        if use_fsdp:
            we_g = jax.lax.all_gather(we_g, fsdp_ax, axis=1, tiled=True)
            we_u = jax.lax.all_gather(we_u, fsdp_ax, axis=1, tiled=True)
            # we_down's fsdp dim is d (last): gather along axis 2
            we_d = jax.lax.all_gather(we_d, fsdp_ax, axis=2, tiled=True)
        y, aux = _moe_local_dispatch(xs.reshape(bl * sl, d), router, we_g,
                                     we_u, we_d, E, k, C_loc, axis=model_ax)
        aux = jax.lax.pmean(aux, batch_axes + (model_ax,))
        return y.reshape(bl, sl, d), aux

    wd_spec = P(model_ax, None, fsdp_ax) if use_fsdp \
        else P(model_ax, None, None)
    y, aux = shard_map(
        inner, mesh=mesh,
        in_specs=(P(batch_ax, model_ax, None), P(), w_spec, w_spec,
                  wd_spec),
        out_specs=(P(batch_ax, model_ax, None), P()),
        check_vma=False,
    )(xn, params["router"], params["we_gate"], params["we_up"],
      params["we_down"])
    return constrain(y.astype(x.dtype), ("batch", "residual_seq", "d_model")), aux


def moe(params, cfg: ModelConfig, x, *, capacity_factor=None):
    """Top-k routed MoE with per-expert capacity (tokens over capacity drop).

    Dispatches to the expert-parallel shard_map path when a mesh is active
    and shapes divide; falls back to the single-device formulation (tests,
    decode, CPU examples) otherwise.
    """
    from repro.sharding.rules import active_rules

    b, s, d = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    cf = capacity_factor or cfg.capacity_factor

    rules = active_rules()
    if rules is not None:
        batch_ax = rules.rules.get("batch")
        model_ax = rules.rules.get("experts")
        m_size = rules.axis_size(model_ax)
        b_size = rules.axis_size(batch_ax)
        if (isinstance(model_ax, str) and m_size > 1 and E % m_size == 0
                and s % m_size == 0 and b % b_size == 0):
            return _moe_sharded(params, cfg, x, rules, cf)

    xn = rmsnorm(params["ln"], x, cfg.norm_eps)
    T = b * s
    C = max(1, int(T * k * cf / E))
    y, aux = _moe_local_dispatch(xn.reshape(T, d), params["router"],
                                 params["we_gate"], params["we_up"],
                                 params["we_down"], E, k, C)
    return constrain(y.reshape(b, s, d).astype(x.dtype),
                     ("batch", "residual_seq", "d_model")), aux


def moe_dense_reference(params, cfg: ModelConfig, x):
    """Every expert processes every token (oracle for tests; O(E) compute)."""
    b, s, d = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    xn = rmsnorm(params["ln"], x, cfg.norm_eps)
    xt = xn.reshape(b * s, d)
    logits = jnp.einsum("td,de->te", xt, params["router"]).astype(jnp.float32)
    gate_all = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(gate_all, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    w = jnp.zeros((xt.shape[0], E), jnp.float32)
    w = w.at[jnp.arange(xt.shape[0])[:, None], eids].set(gates)
    h_g = jnp.einsum("td,edf->tef", xt, params["we_gate"])
    h_u = jnp.einsum("td,edf->tef", xt, params["we_up"])
    h = jax.nn.silu(h_g) * h_u
    out = jnp.einsum("tef,efd->ted", h, params["we_down"])
    y = jnp.einsum("ted,te->td", out.astype(jnp.float32), w)
    return y.reshape(b, s, d).astype(x.dtype)


def moe_load_balance_loss(gate_all, eids, E):
    """Switch-style auxiliary load-balancing loss."""
    T, k = eids.shape
    me = jnp.zeros((E,), jnp.float32).at[eids.reshape(-1)].add(1.0) / (T * k)
    pe = gate_all.mean(axis=0)
    return E * jnp.sum(me * pe)


# ---------------------------------------------------------------------------
# Embeddings / logits
# ---------------------------------------------------------------------------

def embedding_init(key, cfg: ModelConfig, dtype, vocab=None):
    v = vocab or cfg.vocab_size
    k1, k2 = jax.random.split(key)
    p = {"embed": dense_init(k1, (v, cfg.d_model), dtype, scale=0.02)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k2, (cfg.d_model, v), dtype,
                                  scale=1.0 / math.sqrt(cfg.d_model))
    return p


def embed(params, cfg: ModelConfig, tokens):
    y = jnp.take(params["embed"], tokens, axis=0)
    return constrain(y, ("batch", "residual_seq", "d_model"))


def logits(params, cfg: ModelConfig, x):
    w = params.get("unembed")
    if w is None:
        w = params["embed"].T
    y = jnp.einsum("bsd,dv->bsv", x, w)
    v = y.shape[-1]
    if cfg.vocab_real and cfg.vocab_real < v:
        # vocab was padded for sharding divisibility: mask padded entries
        mask = jnp.arange(v) < cfg.vocab_real
        y = jnp.where(mask, y, -1e30)
    return constrain(y, ("batch", "seq", "vocab"))
