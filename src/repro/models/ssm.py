"""Mamba-2 (SSD — state-space duality) block: chunked scan + O(1) decode.

Implements the SSD algorithm of arXiv:2405.21060 in pure JAX:
  * ``ssd_chunked``    — training/prefill: quadratic within chunks (MXU
    friendly), linear recurrence across chunks (associative over chunk
    states), O(S * Q) compute for chunk size Q.
  * ``ssd_decode_step``— decode: h <- h * exp(dt*A) + dt * (B outer x);
    y = C . h + D * x.  O(1) per token — the sub-quadratic mixer that makes
    long_500k decode feasible.

Oracle: ``ssd_recurrent_reference`` (step-by-step recurrence) — tests assert
the chunked form matches it.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init
from repro.sharding.rules import constrain


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, B, C, chunk: int, *, remat_body: bool = True):
    """x (b,s,h,p); dt (b,s,h); A (h,); B,C (b,s,n) [group-broadcast].

    Returns (y (b,s,h,p), final_state (b,h,p,n)).

    Memory discipline: a ``lax.scan`` over chunks computes each chunk's
    quadratic intra-block AND its state contribution inside the scan body,
    so only one (Q, Q, h) decay block is ever live (the first version
    materialized all of them at once — tens of GB/device at train_4k; see
    EXPERIMENTS.md §Perf iteration 1).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))

    Q = chunk
    xc = x.reshape(b, nc, Q, h, p).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(b, nc, Q, h).transpose(1, 0, 2, 3).astype(jnp.float32)
    Bc = B.reshape(b, nc, Q, n).transpose(1, 0, 2, 3).astype(jnp.float32)
    Cc = C.reshape(b, nc, Q, n).transpose(1, 0, 2, 3).astype(jnp.float32)
    causal = jnp.tril(jnp.ones((Q, Q), bool))

    def body(h_prev, inp):
        xq, dtq, Bq, Cq = inp              # (b,Q,h,p) (b,Q,h) (b,Q,n) (b,Q,n)
        dA = dtq * A[None, None, :]                         # (b,Q,h) < 0
        dA_cum = jnp.cumsum(dA, axis=1)
        # intra-chunk: mask BEFORE exp (overflow poisons where() backward)
        diff = dA_cum[:, :, None, :] - dA_cum[:, None, :, :]   # (b,Q,Q,h)
        diff = jnp.where(causal[None, :, :, None], diff, -jnp.inf)
        Lmat = jnp.exp(diff)
        scores = jnp.einsum("bin,bjn->bij", Cq, Bq)            # (b,Q,Q)
        y_intra = jnp.einsum("bij,bijh,bjh,bjhp->bihp",
                             scores, Lmat, dtq, xq.astype(jnp.float32))
        # inter-chunk: contribution of the carried state
        state_decay = jnp.exp(dA_cum)                          # (b,Q,h)
        y_inter = jnp.einsum("bqn,bqh,bhpn->bqhp", Cq, state_decay, h_prev)
        # state update for the next chunk
        decay_to_end = jnp.exp(dA_cum[:, -1:, :] - dA_cum)     # (b,Q,h)
        st = jnp.einsum("bqn,bqh,bqhp->bhpn", Bq, dtq * decay_to_end,
                        xq.astype(jnp.float32))
        chunk_decay = jnp.exp(dA_cum[:, -1, :])                # (b,h)
        h_new = h_prev * chunk_decay[..., None, None] + st
        return h_new, (y_intra + y_inter).astype(x.dtype)

    fn = jax.checkpoint(body) if remat_body else body
    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    h_final, yc = jax.lax.scan(fn, h0, (xc, dtc, Bc, Cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(b, nc * Q, h, p)[:, :s]
    return y.astype(x.dtype), h_final


def ssd_recurrent_reference(x, dt, A, B, C):
    """Step-by-step recurrence oracle (tests only)."""
    b, s, h, p = x.shape
    n = B.shape[-1]

    def step(hst, inp):
        xt, dtt, Bt, Ct = inp
        decay = jnp.exp(dtt * A)[..., None, None]              # (b,h,1,1)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dtt, Bt, xt)
        hst = hst * decay + upd
        y = jnp.einsum("bn,bhpn->bhp", Ct, hst)
        return hst, y

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, h0,
                         (x.transpose(1, 0, 2, 3).astype(jnp.float32),
                          dt.transpose(1, 0, 2).astype(jnp.float32),
                          B.transpose(1, 0, 2).astype(jnp.float32),
                          C.transpose(1, 0, 2).astype(jnp.float32)))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)


def ssd_decode_step(state, x, dt, A, B, C):
    """state (b,h,p,n); x (b,h,p); dt (b,h); B,C (b,n) -> (y, state)."""
    decay = jnp.exp(dt * A)[..., None, None]
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt, B, x.astype(jnp.float32))
    state = state * decay + upd
    y = jnp.einsum("bn,bhpn->bhp", C, state)
    return y.astype(x.dtype), state


# ---------------------------------------------------------------------------
# Mamba-2 block (in_proj -> conv -> SSD -> gate -> out_proj)
# ---------------------------------------------------------------------------

def mamba_dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    n = cfg.ssm_state
    # in_proj emits [z, x, B, C, dt]
    d_proj = 2 * d_inner + 2 * n + nheads
    return d_inner, nheads, n, d_proj


def mamba_init(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    d_inner, nheads, n, d_proj = mamba_dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "ln": rmsnorm_init(d, dtype),
        "in_proj": dense_init(ks[0], (d, d_proj), dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, d_inner + 2 * n), dtype,
                             scale=1.0 / math.sqrt(cfg.ssm_conv)),
        "out_proj": dense_init(ks[2], (d_inner, d), dtype,
                               scale=1.0 / math.sqrt(d_inner * 2 * cfg.n_layers)),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "ssm_d": jnp.ones((nheads,), jnp.float32),
        "ssm_norm": rmsnorm_init(d_inner, dtype),
    }


def _split_proj(cfg, zxbcdt):
    d_inner, nheads, n, _ = mamba_dims(cfg)
    z = zxbcdt[..., :d_inner]
    xin = zxbcdt[..., d_inner:2 * d_inner]
    Bv = zxbcdt[..., 2 * d_inner:2 * d_inner + n]
    Cv = zxbcdt[..., 2 * d_inner + n:2 * d_inner + 2 * n]
    dt = zxbcdt[..., 2 * d_inner + 2 * n:]
    return z, xin, Bv, Cv, dt


def _causal_conv(xbc, w, conv_state=None):
    """Depthwise causal conv over (b, s, ch); w (kw, ch)."""
    kw = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], kw - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(kw))
    new_state = xp[:, -(kw - 1):, :] if kw > 1 else pad
    return jax.nn.silu(out), new_state


def mamba_block(params, cfg: ModelConfig, x, *, conv_state=None,
                ssm_state=None, decode: bool = False):
    """x (b, s, d) -> (y, (conv_state, ssm_state)).

    decode=True requires s == 1 and both states; otherwise runs chunked SSD
    (prefill/train) and returns the final states for cache handoff.
    """
    d_inner, nheads, n, _ = mamba_dims(cfg)
    xn = rmsnorm(params["ln"], x, cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,dp->bsp", xn, params["in_proj"])
    zxbcdt = constrain(zxbcdt, ("batch", "seq", "ssm_inner"))
    z, xin, Bv, Cv, dt = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["a_log"])                          # (h,) negative

    xbc = jnp.concatenate([xin, Bv, Cv], axis=-1)
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], conv_state)
    xin = xbc[..., :d_inner]
    Bv = xbc[..., d_inner:d_inner + n]
    Cv = xbc[..., d_inner + n:]

    b, s, _ = x.shape
    xh = xin.reshape(b, s, nheads, cfg.ssm_head_dim)

    if decode:
        y1, new_ssm = ssd_decode_step(ssm_state, xh[:, 0], dt[:, 0],
                                      A, Bv[:, 0], Cv[:, 0])
        y = y1[:, None]
    else:
        y, new_ssm = ssd_chunked(xh, dt, A, Bv, Cv, cfg.ssm_chunk)

    y = y + xh * params["ssm_d"][None, None, :, None]
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = rmsnorm(params["ssm_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bsp,pd->bsd", y, params["out_proj"]).astype(x.dtype)
    return constrain(out, ("batch", "residual_seq", "d_model")), (new_conv, new_ssm)


def mamba_state_shapes(cfg: ModelConfig, batch: int):
    d_inner, nheads, n, _ = mamba_dims(cfg)
    conv = (batch, cfg.ssm_conv - 1, d_inner + 2 * n)
    ssm = (batch, nheads, cfg.ssm_head_dim, n)
    return conv, ssm
