"""C2LSH/QALSH-style collision-counting (C2) baseline [22], [23].

m one-dimensional hash functions; a point collides with the query under
hash j at radius r if |h_j(o) - h_j(q)| <= w*r/2.  Candidates are points
whose collision count reaches the threshold t.  Virtual rehashing = growing
r geometrically.  TPU-style realization: per-hash sorted projections, the
collision window is a searchsorted interval, and counting is a segmented
add over interval memberships for a capped window.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class C2LSH:
    data: jax.Array
    A: jax.Array              # (d, m)
    m: int
    w: float
    threshold_frac: float
    proj_sorted: jax.Array    # (m, n)
    order: jax.Array          # (m, n)
    window_cap: int

    @classmethod
    def build(cls, data, key, m: int = 32, w: float = 2.0,
              threshold_frac: float = 0.5, window_cap: int = 512):
        n, d = data.shape
        A = jax.random.normal(key, (d, m))
        proj = data @ A                                  # (n, m)
        order = jnp.argsort(proj, axis=0, stable=True).T.astype(jnp.int32)  # (m, n)
        proj_sorted = jnp.take_along_axis(proj.T, order, axis=1)
        return cls(data=data, A=A, m=m, w=w,
                   threshold_frac=threshold_frac, proj_sorted=proj_sorted,
                   order=order, window_cap=window_cap)

    def query(self, queries, k: int, r: float = 1.0, max_rounds: int = 8):
        n = self.data.shape[0]
        t = max(1, int(self.m * self.threshold_frac))
        out_i, out_d = [], []
        for q in queries:
            qp = q @ self.A                              # (m,)
            counts = jnp.zeros((n,), jnp.int32)
            rr = r
            found = None
            for _ in range(max_rounds):
                half = self.w * rr / 2
                counts = jnp.zeros((n,), jnp.int32)
                for j in range(self.m):
                    lo = jnp.searchsorted(self.proj_sorted[j], qp[j] - half)
                    idx = lo + jnp.arange(self.window_cap)
                    okm = (idx < n)
                    idxc = jnp.clip(idx, 0, n - 1)
                    okm = okm & (self.proj_sorted[j][idxc] <= qp[j] + half)
                    ids = self.order[j][idxc]
                    counts = counts.at[ids].add(okm.astype(jnp.int32))
                cand = counts >= t
                if int(cand.sum()) >= k:
                    found = cand
                    break
                rr *= 2.0
            cand = found if found is not None else (counts >= 1)
            d = jnp.sqrt(jnp.sum((self.data - q[None, :]) ** 2, -1))
            d = jnp.where(cand, d, jnp.inf)
            neg, sel = jax.lax.top_k(-d, k)
            out_i.append(sel.astype(jnp.int32))
            out_d.append(-neg)
        return jnp.stack(out_i), jnp.stack(out_d)

    def size_bytes(self):
        return int(self.proj_sorted.size * 4 + self.order.size * 4
                   + self.A.size * 4)
