"""IVF-PQ baseline (IMI/OPQ-family [45]) in JAX.

k-means coarse quantizer (IVF, nlist cells) + product quantization of
residuals (M subspaces x 256 codes).  Query: probe the nprobe nearest
cells, score candidates by asymmetric PQ distance (lookup tables), rerank
the top candidates exactly.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.baselines.common import ProtocolBaseline


def _kmeans(key, x, k, iters=10):
    n = x.shape[0]
    init = jax.random.choice(key, n, (k,), replace=False)
    cent = x[init]
    for _ in range(iters):
        d2 = (jnp.sum(x ** 2, -1, keepdims=True) - 2 * x @ cent.T
              + jnp.sum(cent ** 2, -1)[None, :])
        assign = jnp.argmin(d2, -1)
        one = jax.nn.one_hot(assign, k, dtype=x.dtype)
        counts = one.sum(0)
        cent = jnp.where(counts[:, None] > 0,
                         (one.T @ x) / jnp.maximum(counts[:, None], 1),
                         cent)
    return cent, assign


@dataclasses.dataclass
class IVFPQ(ProtocolBaseline):
    data: jax.Array
    coarse: jax.Array        # (nlist, d)
    assign: jax.Array        # (n,)
    codebooks: jax.Array     # (M, 256, d/M)
    codes: jax.Array         # (n, M) int32
    order: jax.Array         # points sorted by cell
    cell_start: jax.Array    # (nlist+1,)
    nprobe: int
    rerank: int

    engine_name = "ivf-pq"

    def work_per_query(self, k: int):
        # coarse scan (nlist centroid dists) + PQ-scored candidates in the
        # probed cells + exact reranks; PQ scoring is table lookups but we
        # count it 1:1 to stay conservative on IVF-PQ's behalf
        cap = max(self.rerank, k)
        return (self.coarse.shape[0] + self.nprobe * cap
                + min(self.rerank, self.nprobe * cap))

    @classmethod
    def build(cls, data, key, nlist: int = 64, M: int = 4,
              nprobe: int = 8, rerank: int = 256, iters: int = 8):
        n, d = data.shape
        assert d % M == 0
        k1, k2 = jax.random.split(key)
        coarse, assign = _kmeans(k1, data, nlist, iters)
        resid = data - coarse[assign]
        sub = resid.reshape(n, M, d // M)
        cbs, codes = [], []
        for m in range(M):
            cb, code = _kmeans(jax.random.fold_in(k2, m), sub[:, m], 256,
                               iters)
            cbs.append(cb)
            codes.append(code)
        order = jnp.argsort(assign, stable=True).astype(jnp.int32)
        sorted_assign = assign[order]
        cell_start = jnp.searchsorted(sorted_assign, jnp.arange(nlist + 1))
        return cls(data=data, coarse=coarse, assign=assign,
                   codebooks=jnp.stack(cbs),
                   codes=jnp.stack(codes, 1).astype(jnp.int32),
                   order=order, cell_start=cell_start.astype(jnp.int32),
                   nprobe=nprobe, rerank=rerank)

    def query(self, queries, k: int):
        n, d = self.data.shape
        M = self.codebooks.shape[0]
        nlist = self.coarse.shape[0]
        cap = max(self.rerank, k)
        out_i, out_d = [], []
        for q in queries:
            dc = jnp.sum((self.coarse - q[None, :]) ** 2, -1)
            _, cells = jax.lax.top_k(-dc, self.nprobe)
            # PQ lookup tables against residual q - centroid, per probed cell
            cand_ids, cand_score = [], []
            for c in cells:
                start = self.cell_start[c]
                idx = start + jnp.arange(cap)
                ok = idx < self.cell_start[c + 1]
                ids = self.order[jnp.clip(idx, 0, n - 1)]
                r = (q - self.coarse[c]).reshape(M, d // M)
                lut = jnp.sum((self.codebooks - r[:, None, :]) ** 2, -1)
                code = self.codes[ids]                     # (cap, M)
                score = sum(lut[m][code[:, m]] for m in range(M))
                cand_ids.append(jnp.where(ok, ids, n))
                cand_score.append(jnp.where(ok, score, jnp.inf))
            ids = jnp.concatenate(cand_ids)
            score = jnp.concatenate(cand_score)
            neg, sel = jax.lax.top_k(-score, min(self.rerank, ids.shape[0]))
            top = ids[sel]
            safe = jnp.clip(top, 0, n - 1)
            dd = jnp.sqrt(jnp.sum((self.data[safe] - q[None, :]) ** 2, -1))
            dd = jnp.where(top < n, dd, jnp.inf)
            neg2, sel2 = jax.lax.top_k(-dd, k)
            out_i.append(top[sel2])
            out_d.append(-neg2)
        return jnp.stack(out_i), jnp.stack(out_d)

    def size_bytes(self):
        return int(self.codes.size * 1 + self.coarse.size * 4
                   + self.codebooks.size * 4 + self.order.size * 4)
