"""Native ``AnnIndex`` surface for the baselines (docs/DESIGN.md §6).

The Pareto harness (``repro.eval.pareto``) drives every method through the
protocol, so the baselines grow the surface natively instead of riding the
``LegacyIndexAdapter``: ``ProtocolBaseline`` builds ``search``/``n_points``/
``r_min_for``/``index_size_bytes`` on top of each baseline's existing
``query``/``size_bytes``, which keeps ``isinstance(x, AnnIndex)`` true and
``as_ann_index`` a no-op.

``work_per_query`` is the harness's method-agnostic cost model: (roughly)
exact-distance-equivalent evaluations per query, surfaced through
``SearchStats.n_candidates`` so recall/work Pareto curves compare methods
on the same axis wall clock can't provide (a brute-force matmul saturates
BLAS; graph walks don't).
"""

from __future__ import annotations

from typing import Any, Optional

import jax.numpy as jnp
import numpy as np

from repro.api.request import SearchRequest, SearchResult, SearchStats


class ProtocolBaseline:
    """Mixin: the ``AnnIndex`` protocol surface over ``query``/``size_bytes``.

    Subclasses may override ``work_per_query`` (scalar or per-lane array)
    and ``engine_name``; everything else derives from the legacy methods.
    """

    engine_name = "baseline"

    @property
    def n_points(self) -> int:
        return int(self.data.shape[0])

    def work_per_query(self, k: int):
        """Exact-distance-equivalent evaluations per query (cost model for
        the Pareto harness); default: a full scan."""
        return self.n_points

    def search(self, queries: Any,
               request: Optional[SearchRequest] = None) -> SearchResult:
        req = request or SearchRequest()
        ids, dists = self.query(queries, k=req.k)
        ids, dists = jnp.asarray(ids), jnp.asarray(dists)
        work = np.asarray(self.work_per_query(req.k))
        if work.ndim == 0:
            work = np.full(ids.shape[0], int(work))
        stats = SearchStats(engine=self.engine_name, r_min=float("nan"),
                            r_min_cached=False, rounds=None,
                            n_candidates=jnp.asarray(work, jnp.int32),
                            final_r=None)
        return SearchResult(ids=ids, dists=dists, stats=stats)

    def r_min_for(self, k: int) -> float:
        """Data-scale radius estimate (baselines run no radius loop; this
        keeps the protocol surface total for harness code that probes it)."""
        sub = np.asarray(self.data[: min(self.n_points, 64)], np.float32)
        d = np.linalg.norm(sub - sub[:1], axis=-1)
        pos = d[d > 0]
        return float(np.median(pos)) if pos.size else 1.0

    def save(self, path: Any) -> None:
        raise NotImplementedError(
            f"{type(self).__name__} is a benchmark-only baseline: rebuild "
            f"from the data instead of snapshotting")

    def index_size_bytes(self) -> int:
        return int(self.size_bytes())
