"""PM-LSH-style distance-metric (DM) baseline [9].

Projects to a K-dim space (K ~ 15), estimates original distances from
projected distances (chi-square relation, §II-C), selects the beta*n + k
candidates nearest in the projected space, then reranks exactly.  PM-LSH
uses a PM-Tree for the projected-space range query; at benchmark scale the
projected space scan is the fair in-memory analogue (the tree is exactly
what DET-LSH's DE-Tree replaces — that comparison is the paper's Fig. 17/18).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.baselines.common import ProtocolBaseline


@dataclasses.dataclass
class PMLSH(ProtocolBaseline):
    data: jax.Array
    A: jax.Array
    proj: jax.Array
    beta: float

    engine_name = "pm-lsh"

    def work_per_query(self, k: int):
        # exact reranks = the candidate budget beta*n + k (the paper's
        # candidate-count metric; the K-dim projected scan is ~K/d of an
        # exact evaluation and is dominated by the rerank)
        n = self.n_points
        return min(n, int(self.beta * n) + k)

    @classmethod
    def build(cls, data, key, K: int = 15, beta: float = 0.1):
        A = jax.random.normal(key, (data.shape[1], K))
        return cls(data=data, A=A, proj=data @ A, beta=beta)

    def query(self, queries, k: int):
        n = self.data.shape[0]
        ncand = min(n, int(self.beta * n) + k)
        qp = queries @ self.A                       # (b, K)
        d2p = (jnp.sum(qp ** 2, -1, keepdims=True) - 2 * qp @ self.proj.T
               + jnp.sum(self.proj ** 2, -1)[None, :])
        _, cand = jax.lax.top_k(-d2p, ncand)        # projected-space nearest
        out_i, out_d = [], []
        for bi in range(queries.shape[0]):
            pts = self.data[cand[bi]]
            d = jnp.sqrt(jnp.sum((pts - queries[bi][None, :]) ** 2, -1))
            neg, sel = jax.lax.top_k(-d, k)
            out_i.append(cand[bi][sel])
            out_d.append(-neg)
        return jnp.stack(out_i), jnp.stack(out_d)

    def size_bytes(self):
        return int(self.proj.size * 4 + self.A.size * 4)
