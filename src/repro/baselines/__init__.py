"""Baselines the paper compares against (§VI): LSH-family and non-LSH.

Common API: ``build(data, key, **kw) -> index``; ``index.query(q, k) ->
(ids, dists)`` plus ``index.size_bytes()``.  JAX implementations except HNSW
(graph construction is inherently pointer-based; NumPy).

The Pareto-harness baselines (brute_force, pmlsh, hnsw, ivfpq) also carry
the native ``repro.api.AnnIndex`` surface via ``common.ProtocolBaseline``
(``search``/``n_points``/``work_per_query``/...), so ``as_ann_index`` is a
no-op on them and ``eval/pareto.py`` drives every method through one
protocol.

  brute_force — exact oracle
  e2lsh       — boundary-constraint (BC) multi-table bucket LSH [19]
  c2lsh       — collision-counting (C2) with virtual rehashing [22]-like
  pmlsh       — distance-metric (DM): projected-space range filter [9]-like
  hnsw        — graph-based [44] (small-scale NumPy)
  ivfpq       — quantization-based (IMI/OPQ-family) [45]: IVF + PQ
"""

from repro.baselines.common import ProtocolBaseline
from repro.baselines.brute_force import BruteForce
from repro.baselines.e2lsh import E2LSH
from repro.baselines.c2lsh import C2LSH
from repro.baselines.pmlsh import PMLSH
from repro.baselines.hnsw import HNSW
from repro.baselines.ivfpq import IVFPQ

__all__ = ["BruteForce", "E2LSH", "C2LSH", "PMLSH", "HNSW", "IVFPQ",
           "ProtocolBaseline"]
