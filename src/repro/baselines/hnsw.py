"""HNSW baseline [44] — small-scale NumPy implementation.

Graph construction is pointer-chasing by nature (no TPU-idiomatic analogue;
the paper also treats it as a CPU competitor), so this baseline is NumPy and
only used by the comparison benchmarks.  Standard algorithm: multi-layer
skip-list of proximity graphs, greedy descent + beam search (efSearch).
"""

from __future__ import annotations

import dataclasses
import heapq
import math

import numpy as np

from repro.baselines.common import ProtocolBaseline


@dataclasses.dataclass
class HNSW(ProtocolBaseline):
    data: np.ndarray
    M: int
    ef_construction: int
    levels: list          # per-level adjacency dict: {node: [neighbors]}
    entry: int
    max_level: int
    ef_search: int = 64   # default beam width (dataclasses.replace to sweep)
    n_dist: int = 0       # distance evaluations since build (work metric)

    engine_name = "hnsw"

    @classmethod
    def build(cls, data, key=None, M: int = 16, ef_construction: int = 64,
              seed: int = 0):
        data = np.asarray(data)
        n = data.shape[0]
        rng = np.random.default_rng(seed)
        ml = 1.0 / math.log(M)
        levels: list[dict] = []
        entry, max_level = 0, -1
        obj = cls(data=data, M=M, ef_construction=ef_construction,
                  levels=levels, entry=entry, max_level=max_level)
        for i in range(n):
            lvl = int(-math.log(max(rng.random(), 1e-12)) * ml)
            while len(levels) <= lvl:
                levels.append({})
            if obj.max_level < 0:
                for l in range(lvl + 1):
                    levels[l][i] = []
                obj.entry, obj.max_level = i, lvl
                continue
            cur = obj.entry
            for l in range(obj.max_level, lvl, -1):
                cur = obj._greedy(data[i], cur, l)
            for l in range(min(lvl, obj.max_level), -1, -1):
                cands = obj._search_layer(data[i], cur, l,
                                          obj.ef_construction)
                nbrs = [c for _, c in sorted(cands)[:M]]
                levels[l][i] = list(nbrs)
                for nb in nbrs:
                    lst = levels[l].setdefault(nb, [])
                    lst.append(i)
                    if len(lst) > 2 * M:        # prune by distance
                        dd = np.linalg.norm(data[lst] - data[nb], axis=1)
                        keep = np.argsort(dd, kind="stable")[:M]
                        levels[l][nb] = [lst[j] for j in keep]
                cur = nbrs[0] if nbrs else cur
            if lvl > obj.max_level:
                obj.entry, obj.max_level = i, lvl
        return obj

    def _dist(self, q, i):
        self.n_dist += 1
        return float(np.linalg.norm(self.data[i] - q))

    def _greedy(self, q, start, level):
        cur = start
        cur_d = self._dist(q, cur)
        improved = True
        while improved:
            improved = False
            for nb in self.levels[level].get(cur, []):
                d = self._dist(q, nb)
                if d < cur_d:
                    cur, cur_d, improved = nb, d, True
        return cur

    def _search_layer(self, q, entry, level, ef):
        visited = {entry}
        d0 = self._dist(q, entry)
        cand = [(d0, entry)]              # min-heap
        best = [(-d0, entry)]             # max-heap of size ef
        while cand:
            d, c = heapq.heappop(cand)
            if d > -best[0][0]:
                break
            for nb in self.levels[level].get(c, []):
                if nb in visited:
                    continue
                visited.add(nb)
                dn = self._dist(q, nb)
                if len(best) < ef or dn < -best[0][0]:
                    heapq.heappush(cand, (dn, nb))
                    heapq.heappush(best, (-dn, nb))
                    if len(best) > ef:
                        heapq.heappop(best)
        return [(-d, c) for d, c in best]

    def query(self, queries, k: int, ef_search: int | None = None):
        ef = self.ef_search if ef_search is None else ef_search
        queries = np.asarray(queries)
        ids = np.zeros((len(queries), k), np.int32)
        ds = np.zeros((len(queries), k), np.float32)
        work = np.zeros(len(queries), np.int64)
        for bi, q in enumerate(queries):
            before = self.n_dist
            cur = self.entry
            for l in range(self.max_level, 0, -1):
                cur = self._greedy(q, cur, l)
            found = sorted(self._search_layer(q, cur, 0,
                                              max(ef, k)))[:k]
            for j, (d, c) in enumerate(found):
                ids[bi, j], ds[bi, j] = c, d
            work[bi] = self.n_dist - before
        self._last_work = work     # measured per-lane evals (work metric)
        return ids, ds

    def work_per_query(self, k: int):
        return getattr(self, "_last_work", np.asarray(self.n_points))

    def size_bytes(self):
        return sum(4 * (len(v) + 1) for lvl in self.levels
                   for v in lvl.values())
