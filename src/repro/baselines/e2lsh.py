"""E2LSH-style boundary-constraint (BC) baseline [19].

L hash tables; table i hashes a point to the K-dim bucket
floor((a.x + b) / w) per dimension.  Two points collide if they share a
bucket in ANY table.  Query examines all points in the query's buckets and
reranks exactly.  Bucket membership is realized TPU-style: bucket ids are
hashed to a single int, points sorted by it, lookup via searchsorted —
no pointer-chained hash tables.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def _bucket_hash(codes: jax.Array) -> jax.Array:
    """(n, K) int32 bucket coords -> (n,) int32 hashed bucket id."""
    PRIMES = jnp.asarray([73856093, 19349663, 83492791, 32452843, 67867967,
                          49979687, 86028121, 15485863], jnp.uint32)
    K = codes.shape[1]
    pr = jnp.tile(PRIMES, (K + 7) // 8)[:K]
    h = jnp.zeros(codes.shape[0], jnp.uint32)
    for j in range(K):
        h = h ^ (codes[:, j].astype(jnp.uint32) * pr[j])
    return h.astype(jnp.int32)


@dataclasses.dataclass
class E2LSH:
    data: jax.Array
    A: jax.Array            # (d, L*K)
    B: jax.Array            # (L*K,)
    w: float
    K: int
    L: int
    order: jax.Array        # (L, n) point ids sorted by bucket hash
    hashes: jax.Array       # (L, n) sorted bucket hashes
    probe_cap: int

    @classmethod
    def build(cls, data, key, K: int = 8, L: int = 8, w: float = 4.0,
              probe_cap: int = 4096):
        n, d = data.shape
        k1, k2 = jax.random.split(key)
        A = jax.random.normal(k1, (d, L * K))
        B = jax.random.uniform(k2, (L * K,)) * w
        proj = data @ A + B
        codes = jnp.floor(proj / w).astype(jnp.int32)       # (n, L*K)
        order, hashes = [], []
        for i in range(L):
            h = _bucket_hash(codes[:, i * K:(i + 1) * K])
            o = jnp.argsort(h, stable=True)
            order.append(o.astype(jnp.int32))
            hashes.append(h[o])
        return cls(data=data, A=A, B=B, w=w, K=K, L=L,
                   order=jnp.stack(order), hashes=jnp.stack(hashes),
                   probe_cap=probe_cap)

    def query(self, queries, k: int):
        n = self.data.shape[0]
        out_i, out_d = [], []
        for q in queries:
            proj = q @ self.A + self.B
            codes = jnp.floor(proj / self.w).astype(jnp.int32)
            cand = []
            for i in range(self.L):
                h = _bucket_hash(codes[None, i * self.K:(i + 1) * self.K])[0]
                lo = jnp.searchsorted(self.hashes[i], h, side="left")
                idx = lo + jnp.arange(self.probe_cap // self.L)
                ok = (idx < n) & (self.hashes[i][jnp.clip(idx, 0, n - 1)] == h)
                ids = jnp.where(ok, self.order[i][jnp.clip(idx, 0, n - 1)], n)
                cand.append(ids)
            ids = jnp.concatenate(cand)
            safe = jnp.clip(ids, 0, n - 1)
            d = jnp.sqrt(jnp.sum((self.data[safe] - q[None, :]) ** 2, -1))
            d = jnp.where(ids < n, d, jnp.inf)
            # dedup by id
            order = jnp.argsort(ids, stable=True)
            ids_s, d_s = ids[order], d[order]
            first = jnp.concatenate([jnp.array([True]),
                                     ids_s[1:] != ids_s[:-1]])
            d_s = jnp.where(first, d_s, jnp.inf)
            neg, sel = jax.lax.top_k(-d_s, k)
            out_i.append(ids_s[sel])
            out_d.append(-neg)
        return jnp.stack(out_i), jnp.stack(out_d)

    def size_bytes(self):
        return int(self.order.size * 4 + self.hashes.size * 4
                   + self.A.size * 4)
