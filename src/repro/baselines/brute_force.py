"""Exact k-NN by full scan — the ground-truth oracle for all benchmarks."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.baselines.common import ProtocolBaseline


@dataclasses.dataclass
class BruteForce(ProtocolBaseline):
    data: jax.Array

    engine_name = "brute-force"

    @classmethod
    def build(cls, data, key=None, **kw):
        return cls(data=data)

    def query(self, queries, k: int):
        d2 = (jnp.sum(queries ** 2, -1, keepdims=True)
              - 2 * queries @ self.data.T
              + jnp.sum(self.data ** 2, -1)[None, :])
        d2 = jnp.maximum(d2, 0.0)
        neg, ids = jax.lax.top_k(-d2, k)
        return ids, jnp.sqrt(-neg)

    def size_bytes(self):
        return 0
