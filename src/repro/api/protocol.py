"""The AnnIndex protocol — the one index surface (docs/DESIGN.md §6-7).

``core.DETLSH`` (static), ``streaming.StreamingDETLSH`` (mutable), and
``core.distributed.PDETIndex`` (sharded) all satisfy ``AnnIndex``; the
streaming index additionally satisfies ``MutableAnnIndex``.
``serving.LSHService`` talks only to these protocols — capability checks
are ``isinstance`` against a protocol, never ``hasattr`` duck-typing.

``as_ann_index`` adapts pre-protocol objects (anything with a
``query(queries, k=...)`` method — the legacy per-shard ``PDETLSH``,
baselines, user code) so legacy indexes keep serving; the adapter is
where the old signature introspection now lives, in one place.
"""

from __future__ import annotations

import inspect
from typing import Any, Optional, Protocol, runtime_checkable

from repro.api.request import SearchRequest, SearchResult, SearchStats


@runtime_checkable
class AnnIndex(Protocol):
    """A built ANN index answering batched c^2-k-ANN searches."""

    @property
    def n_points(self) -> int:
        """Number of (live) points the index answers over."""
        ...

    def search(self, queries: Any,
               request: Optional[SearchRequest] = None) -> SearchResult:
        """Batched search; ``request=None`` means ``SearchRequest()``."""
        ...

    def r_min_for(self, k: int) -> float:
        """The cached per-(index, k) starting-radius estimate."""
        ...

    def save(self, path: Any) -> None:
        """Write a versioned snapshot directory (repro.api.load reads it)."""
        ...

    def index_size_bytes(self) -> int:
        ...


@runtime_checkable
class MutableAnnIndex(AnnIndex, Protocol):
    """An AnnIndex that additionally supports live mutation."""

    def upsert(self, vectors: Any, gids: Any = None) -> Any:
        ...

    def delete(self, gids: Any) -> int:
        ...

    def maybe_compact(self) -> bool:
        ...


class LegacyIndexAdapter:
    """Wraps a pre-protocol index (``query(queries, k=...)`` and optionally
    ``n_active=``) behind the ``search`` surface.

    Pad-lane masking stays an optimization: if the wrapped ``query`` lacks
    the ``n_active`` kwarg the adapter simply drops it (the index runs the
    radius loop on pad lanes — correct, just not free).  Tuple-returning
    ``query`` implementations (the baselines) are normalized too.
    """

    def __init__(self, index: Any) -> None:
        if not callable(getattr(index, "query", None)):
            raise TypeError(
                f"{type(index).__name__} is not an AnnIndex and has no "
                f"query() method to adapt")
        self.index = index
        try:
            params = inspect.signature(index.query).parameters
            self.supports_n_active = "n_active" in params
        except (TypeError, ValueError):
            self.supports_n_active = False

    def search(self, queries: Any,
               request: Optional[SearchRequest] = None) -> SearchResult:
        req = request or SearchRequest()
        kwargs = {}
        if self.supports_n_active and req.n_active is not None:
            kwargs["n_active"] = req.n_active
        res = self.index.query(queries, k=req.k, **kwargs)
        if hasattr(res, "ids"):                        # QueryResult-style
            ids, dists, raw = res.ids, res.dists, res
            rounds = getattr(res, "rounds", None)
            n_cands = getattr(res, "n_candidates", None)
            final_r = getattr(res, "final_r", None)
        else:                                          # baseline (ids, dists)
            ids, dists = res
            raw = None
            rounds = n_cands = final_r = None
        stats = SearchStats(engine="legacy", r_min=float("nan"),
                            r_min_cached=False, rounds=rounds,
                            n_candidates=n_cands, final_r=final_r)
        return SearchResult(ids=ids, dists=dists, stats=stats, raw=raw)

    # ------------------------------------------------------------------
    # Full AnnIndex surface: delegate where the wrapped index has the
    # capability, fail with a capability error (not AttributeError) where
    # it doesn't — harness code (eval/pareto.py) probes these uniformly.
    # ------------------------------------------------------------------

    @property
    def n_points(self) -> int:
        if hasattr(self.index, "n_points"):
            return int(self.index.n_points)
        data = getattr(self.index, "data", None)
        if data is not None:
            return int(data.shape[0])
        raise TypeError(f"{type(self.index).__name__} exposes neither "
                        f"n_points nor data; cannot report a point count")

    def r_min_for(self, k: int) -> float:
        if hasattr(self.index, "r_min_for"):
            return float(self.index.r_min_for(k))
        raise TypeError(f"{type(self.index).__name__} has no radius-loop "
                        f"state; r_min_for is not adaptable")

    def save(self, path: Any) -> None:
        if hasattr(self.index, "save"):
            return self.index.save(path)
        raise NotImplementedError(
            f"{type(self.index).__name__} has no snapshot format; adapt-"
            f"and-save is not supported (build a protocol index instead)")

    def index_size_bytes(self) -> int:
        if hasattr(self.index, "index_size_bytes"):
            return int(self.index.index_size_bytes())
        if hasattr(self.index, "size_bytes"):
            return int(self.index.size_bytes())
        raise TypeError(f"{type(self.index).__name__} reports no size")


def as_ann_index(index: Any) -> Any:
    """Return ``index`` if it satisfies ``AnnIndex``, else adapt it."""
    if isinstance(index, AnnIndex):
        return index
    return LegacyIndexAdapter(index)
