"""IndexSpec: one declarative, validated build configuration (DESIGN.md §6).

Replaces the scattered build kwargs (`K/L/c` through ``derive_params``,
``Nr/leaf_size/breakpoint_method/*_impl`` through ``DETLSH.build``, the
streaming knobs through ``StreamingDETLSH.build``) with a single frozen
record that validates eagerly, lowers to ``LSHParams`` via
``derive_params``, and round-trips through the snapshot manifest
(``to_dict``/``from_dict``), so a persisted index remembers exactly how it
was built.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.api import registry
from repro.api.request import IMPLS, _check_choice, _check_positive

KINDS = ("static", "streaming")
BREAKPOINT_METHODS = ("sample_sort", "full_sort", "histogram_refine")


@dataclasses.dataclass(frozen=True)
class IndexSpec:
    """Everything needed to build (and rebuild) an index.

    Theory knobs (K/L/c/beta_override) feed ``derive_params`` (Lemma 3);
    layout knobs (Nr/leaf_size/breakpoint_method) shape the DE-Forest;
    impl knobs pick kernel implementations; ``engine``/``block_*`` set the
    search-time defaults; the ``delta_capacity``/``max_segments``/
    ``id_capacity`` group applies to ``kind='streaming'`` only.
    """

    kind: str = "static"                 # 'static' | 'streaming'
    # --- theory (Lemma 3 inputs) ---
    K: int = 16
    L: int = 4
    c: float = 1.5
    beta_override: Optional[float] = None
    # --- DE-Forest layout ---
    Nr: int = 256
    leaf_size: int = 64
    breakpoint_method: str = "sample_sort"
    # --- kernel implementations ---
    project_impl: str = "auto"
    encode_impl: str = "auto"
    # --- search-time defaults ---
    engine: str = "auto"
    block_q: int = 8
    block_l: int = 8
    # --- streaming only ---
    delta_capacity: int = 512
    max_segments: int = 4
    id_capacity: Optional[int] = None

    def __post_init__(self):
        _check_choice("kind", self.kind, KINDS)
        _check_positive("K", self.K)
        _check_positive("L", self.L)
        if not self.c > 1.0:
            raise ValueError(f"approximation ratio c must be > 1, got "
                             f"{self.c!r} (Lemma 3 needs c > 1)")
        if self.beta_override is not None and not 0.0 < self.beta_override:
            raise ValueError(f"beta_override must be positive, got "
                             f"{self.beta_override!r}")
        _check_positive("Nr", self.Nr, minimum=2)
        _check_positive("leaf_size", self.leaf_size)
        _check_choice("breakpoint_method", self.breakpoint_method,
                      BREAKPOINT_METHODS)
        _check_choice("project_impl", self.project_impl, IMPLS)
        _check_choice("encode_impl", self.encode_impl, IMPLS)
        _check_positive("block_q", self.block_q)
        _check_positive("block_l", self.block_l)
        registry.validate_engine_name(self.engine)
        _check_positive("delta_capacity", self.delta_capacity)
        _check_positive("max_segments", self.max_segments)
        if self.id_capacity is not None:
            _check_positive("id_capacity", self.id_capacity)

    def derive_params(self):
        """Solve the Lemma 3 system for this spec -> ``LSHParams``."""
        from repro.core.theory import derive_params
        return derive_params(K=self.K, c=self.c, L=self.L,
                             beta_override=self.beta_override)

    # ------------------------------------------------------------------
    # Snapshot round-trip
    # ------------------------------------------------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "IndexSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown IndexSpec fields in snapshot: "
                             f"{sorted(unknown)} (format drift?)")
        return cls(**d)
