"""IndexSpec: one declarative, validated build configuration (DESIGN.md §6).

Replaces the scattered build kwargs (`K/L/c` through ``derive_params``,
``Nr/leaf_size/breakpoint_method/*_impl`` through ``DETLSH.build``, the
streaming knobs through ``StreamingDETLSH.build``) with a single frozen
record that validates eagerly, lowers to ``LSHParams`` via
``derive_params``, and round-trips through the snapshot manifest
(``to_dict``/``from_dict``), so a persisted index remembers exactly how it
was built.

Device placement is part of the spec (DESIGN.md §7): ``PlacementSpec``
names the mesh axes and per-axis device counts, and says which axes the
index layout shards over (everything else — A, breakpoints, queries —
replicates, following the ``sharding/rules.py`` convention of logical
names mapped to mesh axes).  A spec with a placement builds the sharded
``PDETIndex``; the same spec minus placement builds the single-device
``DETLSH`` that the PDET == DET equivalence contract compares against.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.api import registry
from repro.api.request import IMPLS, _check_choice, _check_positive

KINDS = ("static", "streaming")
BREAKPOINT_METHODS = ("sample_sort", "full_sort", "histogram_refine")
BUILD_IMPLS = IMPLS + ("reference",)

# Logical array axes the PDET layout knows how to place.  'points' (data
# rows / code-sorted positions) and 'leaves' (leaf summaries) shard over
# the placement's data axes; everything else replicates.  Mirrors the
# logical-name -> mesh-axes convention of ``sharding/rules.py``.
PLACEMENT_LOGICAL_AXES = ("points", "leaves")


@dataclasses.dataclass(frozen=True)
class PlacementSpec:
    """Where a sharded index lives: mesh shape/axes + shard-vs-replicate.

    ``mesh_shape``/``mesh_axes`` define the device mesh (e.g. ``(4,)`` over
    ``('data',)``, or ``(2, 2)`` over ``('pod', 'data')``).  ``data_axes``
    is the subset of mesh axes the index layout shards over (default: all
    of them).  An explicit placement counts as a "forced mesh" for the
    ``pdet`` engine's registry rule even at one device — constructing it
    is the opt-in.
    """

    mesh_shape: tuple = (1,)
    mesh_axes: tuple = ("data",)
    data_axes: Optional[tuple] = None      # default: all mesh axes

    def __post_init__(self) -> None:
        shape = tuple(int(s) for s in self.mesh_shape)
        axes = tuple(self.mesh_axes)
        object.__setattr__(self, "mesh_shape", shape)
        object.__setattr__(self, "mesh_axes", axes)
        if len(shape) != len(axes):
            raise ValueError(
                f"mesh_shape {shape} and mesh_axes {axes} must have the "
                f"same length (one device count per axis name)")
        if not shape:
            raise ValueError("placement needs at least one mesh axis")
        for s in shape:
            if s < 1:
                raise ValueError(f"mesh axis sizes must be >= 1, got {shape}")
        for a in axes:
            if not isinstance(a, str) or not a:
                raise ValueError(f"mesh axis names must be non-empty "
                                 f"strings, got {axes!r}")
        if len(set(axes)) != len(axes):
            raise ValueError(f"duplicate mesh axis names in {axes!r}")
        data_axes = axes if self.data_axes is None \
            else tuple(self.data_axes)
        unknown = [a for a in data_axes if a not in axes]
        if unknown:
            raise ValueError(f"data_axes {unknown} are not mesh axes "
                             f"(mesh has {axes})")
        if len(set(data_axes)) != len(data_axes) or not data_axes:
            raise ValueError(f"data_axes must be a non-empty subset of the "
                             f"mesh axes without repeats, got {data_axes!r}")
        object.__setattr__(self, "data_axes", data_axes)

    @property
    def n_devices(self) -> int:
        out = 1
        for s in self.mesh_shape:
            out *= s
        return out

    @property
    def n_shards(self) -> int:
        """Product of mesh sizes over the data axes — the shard count the
        index layout (and the sharded snapshot) is cut into."""
        sizes = dict(zip(self.mesh_axes, self.mesh_shape))
        out = 1
        for a in self.data_axes:
            out *= sizes[a]
        return out

    def rules(self) -> dict:
        """Logical-axis -> mesh-axes map, ``sharding/rules.py`` style."""
        return {name: self.data_axes for name in PLACEMENT_LOGICAL_AXES}

    def to_dict(self) -> dict:
        return {"mesh_shape": list(self.mesh_shape),
                "mesh_axes": list(self.mesh_axes),
                "data_axes": list(self.data_axes)}

    @classmethod
    def from_dict(cls, d: dict) -> "PlacementSpec":
        known = {"mesh_shape", "mesh_axes", "data_axes"}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown PlacementSpec fields: "
                             f"{sorted(unknown)} (format drift?)")
        return cls(mesh_shape=tuple(d["mesh_shape"]),
                   mesh_axes=tuple(d["mesh_axes"]),
                   data_axes=tuple(d["data_axes"]) if d.get("data_axes")
                   else None)


@dataclasses.dataclass(frozen=True)
class IndexSpec:
    """Everything needed to build (and rebuild) an index.

    Theory knobs (K/L/c/beta_override) feed ``derive_params`` (Lemma 3);
    layout knobs (Nr/leaf_size/breakpoint_method) shape the DE-Forest;
    impl knobs pick kernel implementations; ``engine``/``block_*`` set the
    search-time defaults; the ``delta_capacity``/``max_segments``/
    ``id_capacity`` group applies to ``kind='streaming'`` only.
    """

    kind: str = "static"                 # 'static' | 'streaming'
    # --- theory (Lemma 3 inputs) ---
    K: int = 16
    L: int = 4
    c: float = 1.5
    beta_override: Optional[float] = None
    # --- DE-Forest layout ---
    Nr: int = 256
    leaf_size: int = 64
    breakpoint_method: str = "sample_sort"
    # --- kernel implementations ---
    project_impl: str = "auto"
    encode_impl: str = "auto"
    # --- search-time defaults ---
    engine: str = "auto"
    block_q: int = 8
    block_l: int = 8
    # --- streaming only ---
    delta_capacity: int = 512
    max_segments: int = 4
    id_capacity: Optional[int] = None
    # --- device placement (None = single device; DESIGN.md §7) ---
    placement: Optional[PlacementSpec] = None
    # --- build pipeline (DESIGN.md §8): fused single-sort builder impl
    # ('reference' = the seed per-tree double-argsort oracle) and the
    # fused kernel's row-chunk size ---
    build_impl: str = "auto"
    build_chunk: int = 512
    # --- search-time default: near-miss leaves admitted per (tree, round)
    # (multi-probe, docs/DESIGN.md §11; 0 = classic radius rounds).  A
    # request's explicit probe_depth overrides it.  This is the knob the
    # auto-tuner (repro.tune) bakes into its suggested spec. ---
    probe_depth: int = 0

    def __post_init__(self) -> None:
        _check_choice("kind", self.kind, KINDS)
        _check_positive("K", self.K)
        _check_positive("L", self.L)
        if not self.c > 1.0:
            raise ValueError(f"approximation ratio c must be > 1, got "
                             f"{self.c!r} (Lemma 3 needs c > 1)")
        if self.beta_override is not None and not 0.0 < self.beta_override:
            raise ValueError(f"beta_override must be positive, got "
                             f"{self.beta_override!r}")
        _check_positive("Nr", self.Nr, minimum=2)
        from repro.core.detree import check_nr
        check_nr(self.Nr)            # codes are stored as uint8 symbols
        _check_positive("leaf_size", self.leaf_size)
        _check_choice("build_impl", self.build_impl, BUILD_IMPLS)
        _check_positive("build_chunk", self.build_chunk)
        _check_choice("breakpoint_method", self.breakpoint_method,
                      BREAKPOINT_METHODS)
        _check_choice("project_impl", self.project_impl, IMPLS)
        _check_choice("encode_impl", self.encode_impl, IMPLS)
        _check_positive("block_q", self.block_q)
        _check_positive("block_l", self.block_l)
        _check_positive("probe_depth", self.probe_depth, minimum=0)
        registry.validate_engine_name(self.engine)
        _check_positive("delta_capacity", self.delta_capacity)
        _check_positive("max_segments", self.max_segments)
        if self.id_capacity is not None:
            _check_positive("id_capacity", self.id_capacity)
        if self.placement is not None:
            if isinstance(self.placement, dict):
                object.__setattr__(self, "placement",
                                   PlacementSpec.from_dict(self.placement))
            elif not isinstance(self.placement, PlacementSpec):
                raise ValueError(
                    f"placement must be a PlacementSpec (or its dict form), "
                    f"got {type(self.placement).__name__}")
            if self.kind != "static":
                raise ValueError(
                    f"placement is only supported for kind='static' (the "
                    f"sharded PDET index); kind={self.kind!r} cannot be "
                    f"placed on a mesh yet")

    def derive_params(self) -> Any:
        """Solve the Lemma 3 system for this spec -> ``LSHParams``."""
        from repro.core.theory import derive_params
        return derive_params(K=self.K, c=self.c, L=self.L,
                             beta_override=self.beta_override)

    # ------------------------------------------------------------------
    # Snapshot round-trip
    # ------------------------------------------------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "IndexSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown IndexSpec fields in snapshot: "
                             f"{sorted(unknown)} (format drift?)")
        return cls(**d)
