"""Query-engine registry (docs/DESIGN.md §6-7).

Engines are the batched c^2-k-ANN execution strategies.  ``core/query.py``
and ``core/distributed.py`` register the built-in ones at import time:

  * ``vmap``  — the per-query ``while_loop``, vmapped; supports both
    admission modes ('leaf' and the unoptimized 'strict' Alg. 3 filter).
  * ``fused`` — the one-pass Pallas range_rerank engine; 'leaf' mode only,
    amortized at batch >= its ``min_batch``.
  * ``pdet``  — the shard_map'd fused round over a mesh-sharded layout
    (paper Alg. 8); 'leaf' mode only, and only available when an active
    mesh is declared (``needs_mesh``).

``resolve_engine`` replaces the old ``_pick_engine`` string matching with
explicit, documented rules:

  1. an unknown name raises immediately (with the valid names);
  2. an explicitly requested engine that does not support the requested
     mode falls back to the best engine that does — this is the
     strict-mode fallback (fused/pdet -> vmap), now a registry rule
     rather than a special case buried in the dispatcher;
  3. ``'auto'`` picks the highest-priority engine supporting the mode
     whose ``min_batch`` the (static) batch size meets, falling back to
     the lowest-``min_batch`` eligible engine;
  4. a ``needs_mesh`` engine is eligible only when the caller declares an
     active mesh (``mesh_devices=``) — a multi-device mesh or an
     explicitly forced single/host-device one both count (constructing a
     ``PlacementSpec`` is the opt-in); ``'auto'`` therefore prefers
     ``pdet`` exactly when a mesh is active, and an *explicit*
     ``engine='pdet'`` without a mesh raises (running the sharded round
     without a placement cannot mean anything).

The registry is deliberately dependency-free so ``repro.api`` stays
importable without pulling the kernel stack; resolving lazily imports
``repro.core.query`` / ``repro.core.distributed`` to guarantee the
built-ins are registered.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

AUTO = "auto"


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """One registered query engine.

    ``run`` has the uniform batched signature
    ``run(data, forest, A, params, queries, cfg, *, plan, live,
    live_sorted, n_active) -> QueryResult``; engines ignore the inputs
    they do not consume (e.g. the vmap engine ignores ``plan``).
    """

    name: str
    run: Callable
    modes: frozenset
    min_batch: int = 1
    priority: int = 0
    doc: str = ""
    needs_mesh: bool = False   # eligible only with a declared active mesh


_ENGINES: dict = {}


def register_engine(name: str, run: Callable, *,
                    modes: Sequence[str] = ("leaf",),
                    min_batch: int = 1, priority: int = 0,
                    doc: str = "", needs_mesh: bool = False) -> EngineSpec:
    """Register (or replace) a query engine under ``name``."""
    if name == AUTO:
        raise ValueError(f"'{AUTO}' is reserved for engine resolution")
    spec = EngineSpec(name=name, run=run, modes=frozenset(modes),
                      min_batch=int(min_batch), priority=int(priority),
                      doc=doc, needs_mesh=bool(needs_mesh))
    _ENGINES[name] = spec
    return spec


_builtins_loaded = False


def _ensure_builtins() -> None:
    # core/query.py registers 'vmap' and 'fused', core/distributed.py
    # registers 'pdet', both as import side effects.  Guarded by a flag,
    # not by `_ENGINES` being empty: a custom engine registered before the
    # first resolve must not mask the built-ins.
    global _builtins_loaded
    if not _builtins_loaded:
        _builtins_loaded = True
        import repro.core.query  # noqa: F401
        import repro.core.distributed  # noqa: F401


def available_engines() -> tuple:
    """Registered engine names, highest priority first."""
    _ensure_builtins()
    return tuple(s.name for s in
                 sorted(_ENGINES.values(), key=lambda s: -s.priority))


def get_engine(name: str) -> EngineSpec:
    _ensure_builtins()
    if name not in _ENGINES:
        raise ValueError(
            f"unknown engine {name!r}; valid: "
            f"{(AUTO,) + available_engines()}")
    return _ENGINES[name]


def validate_engine_name(name: Optional[str]) -> None:
    """Eager validation for config objects: None / 'auto' / registered."""
    if name is None or name == AUTO:
        return
    get_engine(name)  # raises with the valid names


def resolve_engine(requested: Optional[str], *, mode: str = "leaf",
                   batch: Optional[int] = None,
                   mesh_devices: Optional[int] = None) -> str:
    """Map a requested engine (or 'auto' / None) to a concrete engine name.

    See the module docstring for the four rules.  ``batch`` is the static
    batch size when known; None means "assume large enough".
    ``mesh_devices`` declares an active device mesh (its device count);
    None means "no mesh" and excludes ``needs_mesh`` engines (rule 4).
    An explicitly constructed single-device (forced host) mesh counts —
    pass ``mesh_devices=1``.
    """
    _ensure_builtins()
    requested = AUTO if requested is None else requested
    eligible = sorted(
        (s for s in _ENGINES.values()
         if mode in s.modes and (mesh_devices is not None
                                 or not s.needs_mesh)),
        key=lambda s: -s.priority)
    if not eligible:
        raise ValueError(f"no registered engine supports mode={mode!r}")
    if requested != AUTO:
        spec = get_engine(requested)
        if spec.needs_mesh and mesh_devices is None:
            raise ValueError(
                f"engine {requested!r} needs an active device mesh; build "
                f"the index with an IndexSpec placement (or pass "
                f"mesh_devices=) — without a mesh the sharded round has "
                f"nothing to shard over")
        if mode in spec.modes:
            return spec.name
        return eligible[0].name          # explicit mode fallback (rule 2)
    for spec in eligible:                # rule 3: priority + min_batch
        if batch is None or batch >= spec.min_batch:
            return spec.name
    return min(eligible, key=lambda s: s.min_batch).name
