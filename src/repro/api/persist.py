"""Snapshot persistence: ``index.save(path)`` / ``repro.api.load(path)``.

A snapshot is a versioned *directory* (docs/DESIGN.md §6):

    <path>/
      MANIFEST.json            format + version, kind, LSHParams, IndexSpec,
                               static shapes, per-segment catalog, cached
                               r_min estimates
      arrays.npz               (static) A, data, DE-Forest arrays
      plan.npz                 (static, optional) fused-plan constants
      common.npz               (streaming) A, frozen breakpoints bp_all
      segment_<id>.npz         (streaming) rows, gids, tombstones, forest
                               [+ fused-plan constants when materialized]
      memtable.npz             (streaming) delta rows / gids / live bitmap

The contract is *loaded-index ≡ original*: a reloaded index answers every
search with bit-identical ids and distances on both engines (enforced by
``tests/test_persistence.py``), including pre-compaction tombstones and
un-sealed delta rows for the streaming index.  Everything derivable is
rebuilt deterministically on load (locators, gid maps); everything that is
state (tombstones, memtable cursor, next_gid, cached radius estimates) is
persisted.

``load`` refuses snapshots whose ``format_version`` it does not understand
(``SnapshotFormatError``), so a format change can never be silently
misread as garbage arrays.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Optional

import numpy as np

FORMAT_NAME = "repro-ann-snapshot"
FORMAT_VERSION = 1


class SnapshotFormatError(ValueError):
    """The directory is not a snapshot this build can read."""


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------

_FOREST_KEYS = ("point_ids", "proj_sorted", "codes_sorted", "valid",
                "leaf_lo", "leaf_hi", "leaf_valid", "breakpoints")


def _forest_arrays(forest, prefix: str = "forest.") -> dict:
    return {prefix + k: np.asarray(getattr(forest, k))
            for k in _FOREST_KEYS}


def _forest_from(arrays, n: int, leaf_size: int, prefix: str = "forest."):
    import jax.numpy as jnp
    from repro.core.detree import DEForest
    return DEForest(n=int(n), leaf_size=int(leaf_size),
                    **{k: jnp.asarray(arrays[prefix + k])
                       for k in _FOREST_KEYS})


def _plan_arrays(plan, prefix: str = "plan.") -> dict:
    return {prefix + "points_sorted": np.asarray(plan.points_sorted),
            prefix + "inv_perm": np.asarray(plan.inv_perm)}


def _plan_from(arrays, prefix: str = "plan."):
    import jax.numpy as jnp
    from repro.core.query import FusedPlan
    return FusedPlan(points_sorted=jnp.asarray(arrays[prefix +
                                                      "points_sorted"]),
                     inv_perm=jnp.asarray(arrays[prefix + "inv_perm"]))


def _spec_dict(index) -> Optional[dict]:
    spec = getattr(index, "spec", None)
    return spec.to_dict() if spec is not None else None


def _rmin_dump(cache: dict) -> dict:
    return {str(k): float(v) for k, v in cache.items()}


def _rmin_load(d: dict) -> dict:
    return {int(k): float(v) for k, v in (d or {}).items()}


def _write_manifest(path: str, manifest: dict) -> None:
    with open(os.path.join(path, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)


def _drop_stale_npz(path: str, keep: set) -> None:
    """Re-saving into an existing snapshot directory must not leave .npz
    files a previous save wrote but the new manifest no longer references
    (e.g. pre-compaction segments, a dropped plan.npz) — the directory
    would grow without bound and mislead readers."""
    for fname in os.listdir(path):
        if fname.endswith(".npz") and fname not in keep:
            os.remove(os.path.join(path, fname))


def _read_manifest(path: str) -> dict:
    mpath = os.path.join(path, "MANIFEST.json")
    if not os.path.isfile(mpath):
        raise SnapshotFormatError(f"{path!r} is not a snapshot directory "
                                  f"(no MANIFEST.json)")
    with open(mpath) as f:
        manifest = json.load(f)
    if manifest.get("format") != FORMAT_NAME:
        raise SnapshotFormatError(
            f"{path!r}: manifest format {manifest.get('format')!r} is not "
            f"{FORMAT_NAME!r}")
    ver = manifest.get("format_version")
    if ver != FORMAT_VERSION:
        raise SnapshotFormatError(
            f"{path!r}: snapshot format_version {ver!r} is not supported "
            f"by this build (wants {FORMAT_VERSION}); re-save the index "
            f"with a matching version of repro")
    return manifest


def _params_from(d: dict):
    from repro.core.theory import LSHParams
    return LSHParams(**d)


def _spec_from(d: Optional[dict]):
    from repro.api.spec import IndexSpec
    return IndexSpec.from_dict(d) if d is not None else None


# ---------------------------------------------------------------------------
# Static index
# ---------------------------------------------------------------------------

def save_static(index, path: str) -> None:
    """Snapshot a ``core.DETLSH``: A, data, forest, fused-plan constants."""
    os.makedirs(path, exist_ok=True)
    arrays = {"A": np.asarray(index.A), "data": np.asarray(index.data)}
    arrays.update(_forest_arrays(index.forest))
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    has_plan = index._plan is not None
    if has_plan:
        np.savez(os.path.join(path, "plan.npz"),
                 **_plan_arrays(index._plan))
    _drop_stale_npz(path, {"arrays.npz"} | ({"plan.npz"} if has_plan
                                            else set()))
    _write_manifest(path, {
        "format": FORMAT_NAME, "format_version": FORMAT_VERSION,
        "kind": "static",
        "params": dataclasses.asdict(index.params),
        "forest": {"n": index.forest.n,
                   "leaf_size": index.forest.leaf_size},
        "spec": _spec_dict(index),
        "has_plan": has_plan,
        "r_min_cache": _rmin_dump(index._r_min_cache),
    })


def _load_static(path: str, manifest: dict):
    from repro.core import DETLSH
    arrays = np.load(os.path.join(path, "arrays.npz"))
    import jax.numpy as jnp
    forest = _forest_from(arrays, **manifest["forest"])
    index = DETLSH(params=_params_from(manifest["params"]),
                   A=jnp.asarray(arrays["A"]),
                   forest=forest,
                   data=jnp.asarray(arrays["data"]),
                   spec=_spec_from(manifest.get("spec")))
    if manifest.get("has_plan"):
        index._plan = _plan_from(np.load(os.path.join(path, "plan.npz")))
    index._r_min_cache.update(_rmin_load(manifest.get("r_min_cache")))
    return index


# ---------------------------------------------------------------------------
# Streaming index
# ---------------------------------------------------------------------------

def save_streaming(index, path: str) -> None:
    """Snapshot a ``streaming.StreamingDETLSH``: segments (with tombstone
    bitmaps), memtable survivors, frozen breakpoints, and the manifest —
    a restart resumes serving (and mutating) exactly where it left off."""
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, "common.npz"),
             A=np.asarray(index.A), bp_all=np.asarray(index.bp_all))
    seg_entries = []
    for seg in index.manifest.segments:
        fname = f"segment_{seg.seg_id:06d}.npz"
        arrays = {"data": np.asarray(seg.data),
                  "gids": np.asarray(seg.gids),
                  "live": np.asarray(seg.live)}
        arrays.update(_forest_arrays(seg.forest))
        has_plan = seg._plan is not None
        if has_plan:
            arrays.update(_plan_arrays(seg._plan))
        np.savez(os.path.join(path, fname), **arrays)
        seg_entries.append({
            "seg_id": seg.seg_id, "file": fname,
            "clip_fraction": seg.clip_fraction,
            "forest": {"n": seg.forest.n,
                       "leaf_size": seg.forest.leaf_size},
            "has_plan": has_plan,
        })
    mt = index.memtable
    np.savez(os.path.join(path, "memtable.npz"),
             vecs=mt.vecs, gids=mt.gids, live=mt.live)
    _drop_stale_npz(path, {"common.npz", "memtable.npz"}
                    | {e["file"] for e in seg_entries})
    # Only persist the r_min cache when it is current for this structure —
    # a stale (pre-mutation) cache must not be resurrected as fresh.
    rmin_tag, rmin_entries = index._rmin_cache
    if rmin_tag != (index.manifest.version, mt.version):
        rmin_entries = {}
    _write_manifest(path, {
        "format": FORMAT_NAME, "format_version": FORMAT_VERSION,
        "kind": "streaming",
        "params": dataclasses.asdict(index.params),
        "Nr": index.Nr, "leaf_size": index.leaf_size,
        "max_segments": index.max_segments,
        "id_capacity": index.id_capacity,
        "next_gid": index.next_gid,
        "next_seg_id": index._next_seg_id,
        "segments": seg_entries,
        "memtable": {"capacity": mt.capacity, "d": mt.d,
                     "count": mt.count},
        "spec": _spec_dict(index),
        "r_min_cache": _rmin_dump(rmin_entries),
    })


def _load_streaming(path: str, manifest: dict):
    import jax.numpy as jnp
    from repro.streaming.index import StreamingDETLSH, _DELTA
    from repro.streaming.segment import Segment

    common = np.load(os.path.join(path, "common.npz"))
    mt_meta = manifest["memtable"]
    index = StreamingDETLSH(
        params=_params_from(manifest["params"]),
        A=jnp.asarray(common["A"]),
        bp_all=jnp.asarray(common["bp_all"]),
        base=None,
        Nr=int(manifest["Nr"]), leaf_size=int(manifest["leaf_size"]),
        delta_capacity=int(mt_meta["capacity"]),
        max_segments=int(manifest["max_segments"]),
        id_capacity=int(manifest["id_capacity"]))
    index.spec = _spec_from(manifest.get("spec"))

    for entry in manifest["segments"]:
        arrays = np.load(os.path.join(path, entry["file"]))
        seg = Segment(seg_id=int(entry["seg_id"]),
                      data=jnp.asarray(arrays["data"]),
                      gids=np.asarray(arrays["gids"]),
                      live=np.asarray(arrays["live"]).copy(),
                      forest=_forest_from(arrays, **entry["forest"]),
                      clip_fraction=float(entry["clip_fraction"]))
        if entry.get("has_plan"):
            seg._plan = _plan_from(arrays)
        index.manifest.add(seg)
        live_rows = np.flatnonzero(seg.live)
        index.locator.update(
            (int(g), (seg.seg_id, int(r)))
            for g, r in zip(seg.gids[live_rows], live_rows))

    mt = index.memtable
    saved = np.load(os.path.join(path, "memtable.npz"))
    mt.vecs[:] = saved["vecs"]
    mt.gids[:] = saved["gids"]
    mt.live[:] = saved["live"]
    mt.count = int(mt_meta["count"])
    mt.version += 1
    live_slots = np.flatnonzero(mt.live[: mt.count])
    index.locator.update((int(mt.gids[s]), (_DELTA, int(s)))
                         for s in live_slots)

    index.next_gid = int(manifest["next_gid"])
    index._next_seg_id = int(manifest["next_seg_id"])
    index._rmin_cache = ((index.manifest.version, mt.version),
                         _rmin_load(manifest.get("r_min_cache")))
    return index


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def save(index, path: str) -> None:
    """Snapshot any AnnIndex (dispatch lives on the index: calls
    ``index.save``)."""
    index.save(path)


def load(path: str) -> Any:
    """Read a snapshot directory back into a live index.

    Returns a ``core.DETLSH`` or ``streaming.StreamingDETLSH`` according
    to the manifest's ``kind``; raises ``SnapshotFormatError`` on any
    format/version mismatch.
    """
    manifest = _read_manifest(path)
    kind = manifest.get("kind")
    if kind == "static":
        return _load_static(path, manifest)
    if kind == "streaming":
        return _load_streaming(path, manifest)
    raise SnapshotFormatError(f"{path!r}: unknown snapshot kind {kind!r}")
