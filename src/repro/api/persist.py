"""Snapshot persistence: ``index.save(path)`` / ``repro.api.load(path)``.

A snapshot is a versioned *directory* (docs/DESIGN.md §6-7):

    <path>/
      MANIFEST.json            format + version, kind, LSHParams, IndexSpec,
                               static shapes, per-segment/per-shard catalog,
                               placement, cached r_min estimates
      arrays.npz               (static) A, data, DE-Forest arrays
      plan.npz                 (static, optional) fused-plan constants
      common.npz               (streaming) A, frozen breakpoints bp_all
                               (pdet) A, breakpoints
      segment_<id>.npz         (streaming) rows, gids, tombstones, forest
                               [+ fused-plan constants when materialized]
      memtable.npz             (streaming) delta rows / gids / live bitmap
      shard_<i>.npz            (pdet) one shard's data rows + its slice of
                               the sharded forest arrays

The contract is *loaded-index ≡ original*: a reloaded index answers every
search with bit-identical ids and distances on both engines (enforced by
``tests/test_persistence.py``), including pre-compaction tombstones and
un-sealed delta rows for the streaming index.  Everything derivable is
rebuilt deterministically on load (locators, gid maps, fused plans);
everything that is state (tombstones, memtable cursor, next_gid, cached
radius estimates) is persisted.  A ``pdet`` snapshot can be loaded onto a
*different* device count: the shard files concatenate back into the one
global layout and are resharded onto whatever mesh fits (answers are
device-count invariant by construction — DESIGN.md §7).

``load`` refuses snapshots whose ``format_version`` it does not understand
(``SnapshotFormatError``), so a format change can never be silently
misread as garbage arrays.  Version 2 added the sharded ``pdet`` kind;
version 3 (docs/DESIGN.md §13) made every save *atomic* (files are staged
into a temp sibling directory, fsynced, and published with ``os.replace``,
so a crashed save can never shadow a previously valid snapshot) and added
per-file sha256 ``digests`` to MANIFEST.json, verified on load — a
silently bit-flipped file raises ``SnapshotIntegrityError`` naming it.
Pre-digest snapshots (version <= 2) still load, with a warning.
"""

from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import os
import shutil
import tempfile
import warnings
from typing import Any, Optional

import numpy as np

FORMAT_NAME = "repro-ann-snapshot"
FORMAT_VERSION = 3
# The stamp records the version that defined the kind's *layout*.  Every
# kind stamps 3 now: version 3 added the manifest 'digests' map (integral
# to the integrity contract — a reader that ignored it would also skip
# verification, so older builds refusing v3 is correct).  Reading accepts
# the whole supported set, so an upgrade never forces the rebuild the
# persistence feature exists to avoid.
SUPPORTED_FORMAT_VERSIONS = (1, 2, 3)
_KIND_FORMAT_VERSIONS = {"static": 3, "streaming": 3, "pdet": 3}
# First version whose manifests must carry digests; earlier snapshots
# load with a warning instead of an integrity error.
DIGEST_FORMAT_VERSION = 3


class SnapshotFormatError(ValueError):
    """The directory is not a snapshot this build can read."""


class SnapshotIntegrityError(SnapshotFormatError):
    """A snapshot file's bytes do not match the digest its MANIFEST
    recorded at save time — bit rot, truncation, or tampering."""


# Test seam (serving/faults.py): when set, called with the snapshot path at
# the top of ``load`` — the SNAPSHOT_LOAD fault-injection boundary.
load_fault_hook = None

# Test seam (serving/faults.py): when set, called with each staged file
# name during a save — the SNAPSHOT_WRITE fault-injection boundary.
write_fault_hook = None


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------

_FOREST_KEYS = ("point_ids", "proj_sorted", "codes_sorted", "valid",
                "leaf_lo", "leaf_hi", "leaf_valid", "breakpoints")

def _forest_dtypes() -> dict:
    """Storage dtypes of the forest arrays, derived from detree's narrow
    layout (the single source of truth).  Loading casts into these, so
    pre-narrowing snapshots that wrote f32/int32 arrays keep loading
    bit-compatibly (the values always fit — codes are 8-bit symbols,
    bounds are region indices < Nr <= 256)."""
    from repro.core.detree import CODE_DTYPE, LEAF_DTYPE
    return {"point_ids": np.int32, "proj_sorted": np.float32,
            "codes_sorted": np.dtype(CODE_DTYPE), "valid": np.bool_,
            "leaf_lo": np.dtype(LEAF_DTYPE), "leaf_hi": np.dtype(LEAF_DTYPE),
            "leaf_valid": np.bool_, "breakpoints": np.float32}


def _forest_arrays(forest: Any, prefix: str = "forest.") -> dict:
    return {prefix + k: np.asarray(getattr(forest, k))
            for k in _FOREST_KEYS}


def _forest_from(arrays: Any, n: int, leaf_size: int,
                 prefix: str = "forest.") -> Any:
    import jax.numpy as jnp
    from repro.core.detree import DEForest
    dtypes = _forest_dtypes()
    return DEForest(n=int(n), leaf_size=int(leaf_size),
                    **{k: jnp.asarray(np.asarray(arrays[prefix + k])
                                      .astype(dtypes[k]))
                       for k in _FOREST_KEYS})


def _plan_arrays(plan: Any, prefix: str = "plan.") -> dict:
    return {prefix + "points_sorted": np.asarray(plan.points_sorted),
            prefix + "inv_perm": np.asarray(plan.inv_perm)}


def _plan_from(arrays: Any, prefix: str = "plan.") -> Any:
    import jax.numpy as jnp
    from repro.core.query import FusedPlan
    return FusedPlan(points_sorted=jnp.asarray(arrays[prefix +
                                                      "points_sorted"]),
                     inv_perm=jnp.asarray(arrays[prefix + "inv_perm"]))


def _spec_dict(index: Any) -> Optional[dict]:
    spec = getattr(index, "spec", None)
    return spec.to_dict() if spec is not None else None


def _rmin_dump(cache: dict) -> dict:
    return {str(k): float(v) for k, v in cache.items()}


def _rmin_load(d: dict) -> dict:
    return {int(k): float(v) for k, v in (d or {}).items()}


def _atomic_write_bytes(fpath: str, data: bytes) -> None:
    """Temp file + fsync + ``os.replace``: a reader of ``fpath`` sees the
    old bytes or the new bytes, never a torn write."""
    tmp = fpath + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, fpath)


def _fsync_dir(path: str) -> None:
    """Directory fsync (commits renames/creates on POSIX); best-effort on
    platforms whose directories cannot be opened."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _write_manifest(path: str, manifest: dict) -> None:
    """Atomic manifest write (temp + ``os.replace``): a crash mid-write
    can orphan a temp file, never truncate MANIFEST.json itself."""
    _atomic_write_bytes(
        os.path.join(path, "MANIFEST.json"),
        json.dumps(manifest, indent=1, sort_keys=True).encode())


def _npz_bytes(arrays: dict) -> bytes:
    """One snapshot .npz, staged in memory so its sha256 digest can be
    recorded in MANIFEST.json before any byte reaches disk."""
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    return buf.getvalue()


def _sha256_hex(data: bytes) -> str:
    return "sha256:" + hashlib.sha256(data).hexdigest()


def _publish_snapshot(path: str, files: dict, manifest: dict) -> None:
    """Write a snapshot directory *atomically* (docs/DESIGN.md §13).

    The files — plus MANIFEST.json carrying their sha256 digests — are
    staged into a temp sibling directory, fsynced, and published with
    ``os.replace``: a crash at any point leaves either the old directory
    or the new one, never a mix, and stale files from an earlier save
    cannot survive (the published directory is always freshly built).
    Re-publishing over an existing snapshot swaps via a second rename
    (a directory cannot atomically replace a non-empty directory): the
    old tree moves aside, the staged tree renames in, the old tree is
    removed — the only non-atomic window is between the two renames, and
    by then the staged tree is already complete and durable on disk.
    The SNAPSHOT_WRITE fault site fires once per staged file, before its
    bytes are written.
    """
    path = os.fspath(path)
    manifest = dict(manifest)
    manifest["digests"] = {fname: _sha256_hex(data)
                           for fname, data in sorted(files.items())}
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    tmp = tempfile.mkdtemp(prefix=os.path.basename(path) + ".stage-",
                           dir=parent)
    try:
        for fname in sorted(files):
            if write_fault_hook is not None:
                write_fault_hook(fname)    # SNAPSHOT_WRITE boundary
            _atomic_write_bytes(os.path.join(tmp, fname), files[fname])
        if write_fault_hook is not None:
            write_fault_hook("MANIFEST.json")
        _write_manifest(tmp, manifest)
        _fsync_dir(tmp)
        if os.path.isdir(path):
            old = tmp + ".old"
            os.rename(path, old)
            try:
                os.replace(tmp, path)
            except BaseException:
                os.rename(old, path)       # restore the prior snapshot
                raise
            _fsync_dir(parent)
            shutil.rmtree(old, ignore_errors=True)
        else:
            os.replace(tmp, path)
            _fsync_dir(parent)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def _verify_digests(path: str, manifest: dict) -> None:
    """Check every file against the manifest's recorded sha256 before any
    loader touches it.  Pre-digest snapshots (format_version < 3) warn;
    a v3 manifest *without* digests is malformed."""
    digests = manifest.get("digests")
    if digests is None:
        ver = manifest.get("format_version")
        if isinstance(ver, int) and ver >= DIGEST_FORMAT_VERSION:
            raise SnapshotFormatError(
                f"{path!r}: format_version {ver} snapshot carries no "
                f"'digests' map — the manifest is malformed")
        warnings.warn(
            f"{path!r}: pre-digest snapshot (format_version {ver!r}) — "
            f"file integrity cannot be verified; re-save to record sha256 "
            f"digests", UserWarning, stacklevel=3)
        return
    if not isinstance(digests, dict):
        raise SnapshotFormatError(
            f"{path!r}: manifest field 'digests' must be an object, got "
            f"{type(digests).__name__}")
    for fname in sorted(digests):
        want = digests[fname]
        if not isinstance(want, str):
            raise SnapshotFormatError(
                f"{path!r}: digest for {fname!r} must be a string, got "
                f"{type(want).__name__}")
        fpath = os.path.join(path, fname)
        if not os.path.isfile(fpath):
            raise SnapshotIntegrityError(
                f"{fpath!r}: snapshot file is missing (the manifest's "
                f"digests reference it — the directory is incomplete or "
                f"was partially copied)")
        h = hashlib.sha256()
        with open(fpath, "rb") as f:
            for chunk in iter(lambda: f.read(1 << 20), b""):
                h.update(chunk)
        got = "sha256:" + h.hexdigest()
        if got != want:
            raise SnapshotIntegrityError(
                f"{fpath!r}: snapshot file is truncated or corrupt on "
                f"disk — sha256 {got} != recorded {want}")


class _SnapshotArrays(dict):
    """Eagerly-read npz contents; a missing key is a format error naming
    the offending file, never a raw ``KeyError`` from deep in a loader."""

    def __init__(self, path: str, values: dict) -> None:
        super().__init__(values)
        self.path = path

    def __missing__(self, key: str) -> Any:
        raise SnapshotFormatError(
            f"{self.path!r}: snapshot array {key!r} is missing "
            f"(have: {sorted(self.keys())})")


def _load_npz(path: str, fname: str) -> _SnapshotArrays:
    """Read one snapshot .npz completely, translating every failure mode
    (missing file, truncated/corrupt zip, bad array payload) into a
    ``SnapshotFormatError`` that names the offending path.

    Arrays are read *eagerly*: ``np.load`` of an npz is lazy, so a
    truncated member would otherwise surface as a raw ``zipfile``/EOF
    error at first access, far from the load call."""
    fpath = os.path.join(path, fname)
    if not os.path.isfile(fpath):
        raise SnapshotFormatError(
            f"{fpath!r}: snapshot file is missing (the manifest references "
            f"it — the directory is incomplete or was partially copied)")
    try:
        with np.load(fpath, allow_pickle=False) as npz:
            values = {k: npz[k] for k in npz.files}
    except SnapshotFormatError:
        raise
    except Exception as exc:
        raise SnapshotFormatError(
            f"{fpath!r}: snapshot file is truncated or corrupt "
            f"({type(exc).__name__}: {exc})") from exc
    return _SnapshotArrays(fpath, values)


def _typed_field(mapping: Any, key: str, types: Any, where: str,
                 kind: str) -> Any:
    """Manifest field access with a format-error taxonomy: missing keys and
    wrong-type values both raise ``SnapshotFormatError`` naming the path
    and field, never ``KeyError``/``TypeError`` from a loader internals."""
    if not isinstance(mapping, dict):
        raise SnapshotFormatError(
            f"{where}: manifest section holding {key!r} must be an object, "
            f"got {type(mapping).__name__}")
    if key not in mapping:
        raise SnapshotFormatError(f"{where}: manifest field {key!r} is "
                                  f"missing")
    val = mapping[key]
    # bool is an int subclass; a JSON true/false where a count belongs is
    # a wrong-type field, not a usable integer
    if not isinstance(val, types) or (int in (types if isinstance(
            types, tuple) else (types,)) and isinstance(val, bool)):
        want = "/".join(t.__name__ for t in
                        (types if isinstance(types, tuple) else (types,)))
        raise SnapshotFormatError(
            f"{where}: manifest field {key!r} must be {want}, got "
            f"{type(val).__name__} ({val!r})")
    return val


def _int_field(manifest: dict, key: str, where: str) -> int:
    return _typed_field(manifest, key, int, where, "int")


def _dict_field(manifest: dict, key: str, where: str) -> dict:
    return _typed_field(manifest, key, dict, where, "dict")


def _list_field(manifest: dict, key: str, where: str) -> list:
    return _typed_field(manifest, key, list, where, "list")


def _read_manifest(path: str) -> dict:
    mpath = os.path.join(path, "MANIFEST.json")
    if not os.path.isfile(mpath):
        raise SnapshotFormatError(f"{path!r} is not a snapshot directory "
                                  f"(no MANIFEST.json)")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError, OSError) as exc:
        raise SnapshotFormatError(
            f"{mpath!r}: MANIFEST.json is unreadable or not valid JSON "
            f"({type(exc).__name__}: {exc})") from exc
    if not isinstance(manifest, dict):
        raise SnapshotFormatError(
            f"{mpath!r}: MANIFEST.json must hold a JSON object, got "
            f"{type(manifest).__name__}")
    if manifest.get("format") != FORMAT_NAME:
        raise SnapshotFormatError(
            f"{path!r}: manifest format {manifest.get('format')!r} is not "
            f"{FORMAT_NAME!r}")
    ver = manifest.get("format_version")
    if ver not in SUPPORTED_FORMAT_VERSIONS:
        raise SnapshotFormatError(
            f"{path!r}: snapshot format_version {ver!r} is not supported "
            f"by this build (supported: {SUPPORTED_FORMAT_VERSIONS}); "
            f"re-save the index with a matching version of repro")
    return manifest


def _params_from(manifest: dict, where: str) -> Any:
    from repro.core.theory import LSHParams
    d = _dict_field(manifest, "params", where)
    try:
        return LSHParams(**d)
    except (TypeError, ValueError) as exc:
        raise SnapshotFormatError(
            f"{where}: manifest field 'params' does not describe LSHParams "
            f"({type(exc).__name__}: {exc})") from exc


def _spec_from(d: Optional[dict]) -> Any:
    from repro.api.spec import IndexSpec
    return IndexSpec.from_dict(d) if d is not None else None


# ---------------------------------------------------------------------------
# Static index
# ---------------------------------------------------------------------------

def save_static(index: Any, path: str) -> None:
    """Snapshot a ``core.DETLSH``: A, data, forest, fused-plan constants.
    Published atomically with per-file digests (``_publish_snapshot``)."""
    arrays = {"A": np.asarray(index.A), "data": np.asarray(index.data)}
    arrays.update(_forest_arrays(index.forest))
    files = {"arrays.npz": _npz_bytes(arrays)}
    has_plan = index._plan is not None
    if has_plan:
        files["plan.npz"] = _npz_bytes(_plan_arrays(index._plan))
    _publish_snapshot(path, files, {
        "format": FORMAT_NAME,
        "format_version": _KIND_FORMAT_VERSIONS["static"],
        "kind": "static",
        "params": dataclasses.asdict(index.params),
        "forest": {"n": index.forest.n,
                   "leaf_size": index.forest.leaf_size},
        "spec": _spec_dict(index),
        "has_plan": has_plan,
        "r_min_cache": _rmin_dump(index._r_min_cache),
    })


def _load_static(path: str, manifest: dict) -> Any:
    from repro.core import DETLSH
    arrays = _load_npz(path, "arrays.npz")
    import jax.numpy as jnp
    fmeta = _dict_field(manifest, "forest", path)
    forest = _forest_from(arrays, n=_int_field(fmeta, "n", path),
                          leaf_size=_int_field(fmeta, "leaf_size", path))
    index = DETLSH(params=_params_from(manifest, path),
                   A=jnp.asarray(arrays["A"]),
                   forest=forest,
                   data=jnp.asarray(arrays["data"]),
                   spec=_spec_from(manifest.get("spec")))
    if manifest.get("has_plan"):
        index._plan = _plan_from(_load_npz(path, "plan.npz"))
    index._r_min_cache.update(_rmin_load(manifest.get("r_min_cache")))
    return index


# ---------------------------------------------------------------------------
# Streaming index
# ---------------------------------------------------------------------------

def save_streaming(index: Any, path: str,
                   extra: Optional[dict] = None) -> None:
    """Snapshot a ``streaming.StreamingDETLSH``: segments (with tombstone
    bitmaps), memtable survivors, frozen breakpoints, and the manifest —
    a restart resumes serving (and mutating) exactly where it left off.
    ``extra`` merges additional top-level manifest keys (the durability
    subsystem records its checkpoint lsn there; loaders ignore keys they
    do not know)."""
    files = {"common.npz": _npz_bytes(
        {"A": np.asarray(index.A), "bp_all": np.asarray(index.bp_all)})}
    seg_entries = []
    for seg in index.manifest.segments:
        fname = f"segment_{seg.seg_id:06d}.npz"
        arrays = {"data": np.asarray(seg.data),
                  "gids": np.asarray(seg.gids),
                  "live": np.asarray(seg.live)}
        arrays.update(_forest_arrays(seg.forest))
        has_plan = seg._plan is not None
        if has_plan:
            arrays.update(_plan_arrays(seg._plan))
        files[fname] = _npz_bytes(arrays)
        seg_entries.append({
            "seg_id": seg.seg_id, "file": fname,
            "clip_fraction": seg.clip_fraction,
            "forest": {"n": seg.forest.n,
                       "leaf_size": seg.forest.leaf_size},
            "has_plan": has_plan,
        })
    mt = index.memtable
    files["memtable.npz"] = _npz_bytes(
        {"vecs": mt.vecs, "gids": mt.gids, "live": mt.live})
    # Only persist the r_min cache when it is current for this structure —
    # a stale (pre-mutation) cache must not be resurrected as fresh.
    rmin_tag, rmin_entries = index._rmin_cache
    if rmin_tag != (index.manifest.version, mt.version):
        rmin_entries = {}
    _publish_snapshot(path, files, {**(extra or {}), **{
        "format": FORMAT_NAME,
        "format_version": _KIND_FORMAT_VERSIONS["streaming"],
        "kind": "streaming",
        "params": dataclasses.asdict(index.params),
        "Nr": index.Nr, "leaf_size": index.leaf_size,
        "max_segments": index.max_segments,
        "id_capacity": index.id_capacity,
        "next_gid": index.next_gid,
        "next_seg_id": index._next_seg_id,
        "segments": seg_entries,
        "memtable": {"capacity": mt.capacity, "d": mt.d,
                     "count": mt.count},
        "spec": _spec_dict(index),
        "r_min_cache": _rmin_dump(rmin_entries),
    }})


def _load_streaming(path: str, manifest: dict) -> Any:
    import jax.numpy as jnp
    from repro.streaming.index import StreamingDETLSH, _DELTA
    from repro.streaming.segment import Segment

    common = _load_npz(path, "common.npz")
    mt_meta = _dict_field(manifest, "memtable", path)
    index = StreamingDETLSH(
        params=_params_from(manifest, path),
        A=jnp.asarray(common["A"]),
        bp_all=jnp.asarray(common["bp_all"]),
        base=None,
        Nr=_int_field(manifest, "Nr", path),
        leaf_size=_int_field(manifest, "leaf_size", path),
        delta_capacity=_int_field(mt_meta, "capacity", path),
        max_segments=_int_field(manifest, "max_segments", path),
        id_capacity=_int_field(manifest, "id_capacity", path))
    index.spec = _spec_from(manifest.get("spec"))
    if index.spec is not None:      # seal path keeps the spec'd builder
        index.build_impl = index.spec.build_impl
        index.build_chunk = index.spec.build_chunk

    for entry in _list_field(manifest, "segments", path):
        fname = _typed_field(entry, "file", str, path, "str")
        arrays = _load_npz(path, fname)
        fmeta = _dict_field(entry, "forest", path)
        seg = Segment(seg_id=_int_field(entry, "seg_id", path),
                      data=jnp.asarray(arrays["data"]),
                      gids=np.asarray(arrays["gids"]),
                      live=np.asarray(arrays["live"]).copy(),
                      forest=_forest_from(
                          arrays, n=_int_field(fmeta, "n", path),
                          leaf_size=_int_field(fmeta, "leaf_size", path)),
                      clip_fraction=float(entry["clip_fraction"]))
        if entry.get("has_plan"):
            seg._plan = _plan_from(arrays)
        index.manifest.add(seg)
        live_rows = np.flatnonzero(seg.live)
        index.locator.update(
            (int(g), (seg.seg_id, int(r)))
            for g, r in zip(seg.gids[live_rows], live_rows))

    mt = index.memtable
    saved = _load_npz(path, "memtable.npz")
    try:
        mt.vecs[:] = saved["vecs"]
        mt.gids[:] = saved["gids"]
        mt.live[:] = saved["live"]
    except (ValueError, TypeError) as exc:
        raise SnapshotFormatError(
            f"{saved.path!r}: memtable arrays do not match the manifest's "
            f"capacity/d ({type(exc).__name__}: {exc})") from exc
    mt.count = _int_field(mt_meta, "count", path)
    mt.version += 1
    live_slots = np.flatnonzero(mt.live[: mt.count])
    index.locator.update((int(mt.gids[s]), (_DELTA, int(s)))
                         for s in live_slots)

    index.next_gid = _int_field(manifest, "next_gid", path)
    index._next_seg_id = _int_field(manifest, "next_seg_id", path)
    index._rmin_cache = ((index.manifest.version, mt.version),
                         _rmin_load(manifest.get("r_min_cache")))
    return index


# ---------------------------------------------------------------------------
# Sharded (pdet) index
# ---------------------------------------------------------------------------

_PDET_POINT_KEYS = ("point_ids", "proj_sorted", "codes_sorted", "valid")
_PDET_LEAF_KEYS = ("leaf_lo", "leaf_hi", "leaf_valid")


def save_pdet(index: Any, path: str) -> None:
    """Snapshot a ``core.distributed.PDETIndex`` as per-shard files.

    One ``shard_<i>.npz`` per layout shard (its data rows + its slice of
    every position/leaf-sharded forest array) plus the shard map in
    MANIFEST.json — each file is one device's working set, so a shard
    never has to be materialized whole on another host to be written."""
    forest = index.forest
    S = index.placement.n_shards
    n = index.data.shape[0]
    n_pad = forest.point_ids.shape[1]
    n_leaves = forest.leaf_valid.shape[1]
    # Positions/leaves divide exactly (the layout is padded to a shard
    # multiple at build); data rows may not — split as evenly as possible.
    pos, leaves = n_pad // S, n_leaves // S
    row_bounds = [round(s * n / S) for s in range(S + 1)]
    files = {"common.npz": _npz_bytes(
        {"A": np.asarray(index.A),
         "breakpoints": np.asarray(forest.breakpoints)})}
    shard_entries = []
    for s in range(S):
        fname = f"shard_{s:05d}.npz"
        arrays = {"data": np.asarray(
            index.data[row_bounds[s]:row_bounds[s + 1]])}
        for k in _PDET_POINT_KEYS:
            arrays[k] = np.asarray(
                getattr(forest, k)[:, s * pos:(s + 1) * pos])
        for k in _PDET_LEAF_KEYS:
            arrays[k] = np.asarray(
                getattr(forest, k)[:, s * leaves:(s + 1) * leaves])
        files[fname] = _npz_bytes(arrays)
        shard_entries.append({
            "shard": s, "file": fname,
            "rows": [row_bounds[s], row_bounds[s + 1]],
            "positions": [s * pos, (s + 1) * pos],
            "leaves": [s * leaves, (s + 1) * leaves],
        })
    _publish_snapshot(path, files, {
        "format": FORMAT_NAME,
        "format_version": _KIND_FORMAT_VERSIONS["pdet"],
        "kind": "pdet",
        "params": dataclasses.asdict(index.params),
        "forest": {"n": forest.n, "leaf_size": forest.leaf_size},
        "spec": _spec_dict(index),
        "placement": index.placement.to_dict(),
        "shards": shard_entries,
        "r_min_cache": _rmin_dump(index._r_min_cache),
    })


def _fit_placement(saved: Any) -> Any:
    """Reshard-on-load policy: keep the saved placement when this process
    has enough devices for it, else fall back to the widest single-axis
    ('data',) placement — so a pdet snapshot loads anywhere (the layout
    pads itself to any shard count; answers are identical regardless)."""
    import jax
    from repro.api.spec import PlacementSpec
    avail = len(jax.devices())
    if saved is not None and saved.n_devices <= avail:
        return saved
    return PlacementSpec(mesh_shape=(avail,), mesh_axes=("data",))


def _load_pdet(path: str, manifest: dict, placement: Any = None) -> Any:
    import jax.numpy as jnp
    from repro.api.spec import PlacementSpec
    from repro.core import DETLSH
    from repro.core.detree import DEForest
    from repro.core.distributed import PDETIndex

    common = _load_npz(path, "common.npz")
    entries = sorted(_list_field(manifest, "shards", path),
                     key=lambda e: _int_field(e, "shard", path))
    shards = [_load_npz(path, _typed_field(e, "file", str, path, "str"))
              for e in entries]
    dtypes = _forest_dtypes()
    parts = {k: np.concatenate([sh[k] for sh in shards], axis=1)
             .astype(dtypes[k])
             for k in _PDET_POINT_KEYS + _PDET_LEAF_KEYS}
    meta = _dict_field(manifest, "forest", path)
    forest = DEForest(n=_int_field(meta, "n", path),
                      leaf_size=_int_field(meta, "leaf_size", path),
                      breakpoints=jnp.asarray(np.asarray(
                          common["breakpoints"], np.float32)),
                      **{k: jnp.asarray(v) for k, v in parts.items()})
    data = jnp.asarray(np.concatenate([sh["data"] for sh in shards],
                                      axis=0))
    spec = _spec_from(manifest.get("spec"))
    base_spec = (dataclasses.replace(spec, placement=None)
                 if spec is not None else None)
    det = DETLSH(params=_params_from(manifest, path),
                 A=jnp.asarray(common["A"]), forest=forest, data=data,
                 spec=base_spec)
    det._r_min_cache.update(_rmin_load(manifest.get("r_min_cache")))
    try:
        saved = PlacementSpec.from_dict(
            _dict_field(manifest, "placement", path))
    except SnapshotFormatError:
        raise
    except (TypeError, ValueError, KeyError) as exc:
        raise SnapshotFormatError(
            f"{path!r}: manifest field 'placement' does not describe a "
            f"PlacementSpec ({type(exc).__name__}: {exc})") from exc
    eff = placement if placement is not None else _fit_placement(saved)
    # The attached spec must describe the index as it now lives: a
    # resharded load carries the *effective* placement, not the saved one
    # (otherwise spec.placement would contradict index.placement and the
    # contradiction would be written back into the manifest on re-save).
    if spec is not None and spec.placement != eff:
        spec = dataclasses.replace(spec, placement=eff)
    return PDETIndex.from_detlsh(det, eff, spec=spec)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def save(index: Any, path: str) -> None:
    """Snapshot any AnnIndex (dispatch lives on the index: calls
    ``index.save``)."""
    index.save(path)


def load(path: str, placement: Any = None) -> Any:
    """Read a snapshot directory back into a live index.

    Returns a ``core.DETLSH``, ``streaming.StreamingDETLSH``, or
    ``core.distributed.PDETIndex`` according to the manifest's ``kind``;
    raises ``SnapshotFormatError`` on any format/version mismatch and
    ``SnapshotIntegrityError`` when a file's bytes no longer match the
    sha256 digest recorded at save time (pre-digest snapshots, version
    <= 2, load with a warning instead).

    ``placement`` applies to sharded (pdet) snapshots only: it overrides
    the reshard-on-load policy (default: the saved placement when it fits
    this process's devices, else the widest fitting ('data',) mesh).
    Answers are identical either way — the pdet layout is device-count
    invariant (DESIGN.md §7).
    """
    if load_fault_hook is not None:
        load_fault_hook(path)          # SNAPSHOT_LOAD injection boundary
    manifest = _read_manifest(path)
    _verify_digests(os.fspath(path), manifest)
    kind = manifest.get("kind")
    # jaxlint: disable=engine-bypass -- 'kind' is the snapshot FORMAT tag
    #   (which loader parses the files), not engine dispatch; the engine for
    #   a loaded index is still resolved through the registry at query time.
    if kind == "pdet":
        return _load_pdet(path, manifest, placement)
    if placement is not None:
        raise ValueError(f"placement= only applies to sharded (pdet) "
                         f"snapshots; this one is kind={kind!r}")
    if kind == "static":
        return _load_static(path, manifest)
    if kind == "streaming":
        return _load_streaming(path, manifest)
    raise SnapshotFormatError(f"{path!r}: unknown snapshot kind {kind!r}")
