"""Typed search requests and results — the one query surface (DESIGN.md §6).

``SearchRequest`` carries every per-request override the engines accept
(k, r_min, M, mode, engine, n_active, ...), eagerly validated so a typo'd
engine or a non-positive k fails at construction with an actionable message
instead of silently misbehaving deep in the radius-round loop.

``SearchResult`` is what every ``AnnIndex.search`` returns: ids + exact
distances plus a ``SearchStats`` record (which engine actually ran, the
r_min used and whether it came from the per-index cache, per-lane round /
candidate counts).  ``raw`` retains the engine-level ``QueryResult`` for
the deprecation shims and for callers that need the untyped tuple.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Sequence

from repro.api import registry

MODES = ("leaf", "strict")
IMPLS = ("auto", "xla", "pallas", "pallas_interpret")


def _check_positive(name: str, value: float, minimum: float = 1) -> None:
    if value < minimum:
        raise ValueError(
            f"{name} must be >= {minimum}, got {value!r} — a non-positive "
            f"{name} would make the round loop return empty/garbage results")


def _check_choice(name: str, value: str, choices: Sequence[str]) -> None:
    if value not in choices:
        raise ValueError(f"unknown {name} {value!r}; valid: {choices}")


@dataclasses.dataclass(frozen=True)
class SearchRequest:
    """Per-request overrides for one batched c^2-k-ANN search.

    ``engine=None`` means "use the index's default" (its ``IndexSpec``
    engine, itself defaulting to 'auto'); ``r_min=None`` means "use the
    index's cached per-k estimate" (see ``AnnIndex.r_min_for``).
    ``n_active`` marks trailing pad lanes of a partial batch done from
    round 0 (the serving path's padding contract).
    """

    k: int = 10
    r_min: Optional[float] = None
    M: int = 8
    mode: str = "leaf"
    engine: Optional[str] = None
    n_active: Optional[int] = None
    max_rounds: int = 48
    dist_impl: str = "auto"
    bounds_impl: str = "auto"
    # Absolute deadline (same clock domain as the serving runtime).  The
    # engines ignore it; the scheduler uses it for flush/admission/shed
    # decisions (docs/DESIGN.md §9).  None = best-effort, never shed.
    deadline: Optional[float] = None
    # Multi-probe: near-miss leaves admitted per (tree, round), ranked by
    # leaf-LB slack (docs/DESIGN.md §11).  None = the index's default
    # (``IndexSpec.probe_depth``, itself 0 = classic radius rounds).
    probe_depth: Optional[int] = None

    def __post_init__(self) -> None:
        _check_positive("k", self.k)
        _check_positive("M", self.M)
        _check_positive("max_rounds", self.max_rounds)
        if self.r_min is not None and not self.r_min > 0.0:
            raise ValueError(f"r_min must be positive, got {self.r_min!r} "
                             f"(radii only grow by factors of c)")
        if self.n_active is not None:
            _check_positive("n_active", self.n_active, minimum=0)
        if self.probe_depth is not None:
            _check_positive("probe_depth", self.probe_depth, minimum=0)
        _check_choice("mode", self.mode, MODES)
        _check_choice("dist_impl", self.dist_impl, IMPLS)
        _check_choice("bounds_impl", self.bounds_impl, IMPLS)
        registry.validate_engine_name(self.engine)
        if self.probe_depth and self.mode == "strict":
            raise ValueError(
                "mode='strict' (the unoptimized Alg. 3 per-point filter) "
                "admits no near-miss leaves; probe_depth must be 0/None in "
                f"strict mode (got {self.probe_depth})")

    def to_query_config(self, *, default_engine: str = "auto",
                        r_min: Optional[float] = None,
                        k: Optional[int] = None,
                        block_q: int = 8, block_l: int = 8,
                        default_probe_depth: int = 0) -> Any:
        """Lower to the engine-level ``core.query.QueryConfig``.

        ``r_min`` / ``k`` override the request's values — the index fills
        in its cached radius estimate and per-segment k clamps here.
        ``default_probe_depth`` is the index's configured probe depth
        (``IndexSpec.probe_depth``), used when the request leaves
        ``probe_depth=None``.
        """
        from repro.core.query import QueryConfig
        rm = self.r_min if r_min is None else r_min
        if rm is None:
            raise ValueError("r_min unresolved: pass r_min= or set it on "
                             "the request")
        pd = (self.probe_depth if self.probe_depth is not None
              else default_probe_depth)
        return QueryConfig(
            k=self.k if k is None else k, M=self.M, r_min=float(rm),
            mode=self.mode, max_rounds=self.max_rounds,
            engine=self.engine or default_engine,
            dist_impl=self.dist_impl, bounds_impl=self.bounds_impl,
            block_q=block_q, block_l=block_l,
            probe_depth=0 if self.mode == "strict" else int(pd))


class SearchStats(NamedTuple):
    """Per-search diagnostics surfaced by every ``AnnIndex.search``.

    The last three fields are populated by the sharded ``pdet`` engine
    only (None elsewhere): per-shard work counters that make the Alg. 8
    fan-out observable through the typed surface (DESIGN.md §7).
    """

    engine: str              # concrete engine that ran ('fused' | 'vmap' ...)
    r_min: float             # starting radius actually used
    r_min_cached: bool       # True when it came from the per-(index,k) cache
    rounds: Any              # (B,) int32 — radius enlargements + 1 per lane
    n_candidates: Any        # (B,) int32 — |S| at termination
    final_r: Any             # (B,) f32
    shard_candidates: Any = None  # (n_shards,) f32 — (point, tree) entries
    #                               scanned per shard, summed over lanes/rounds
    #                               (f32: an int32 count would wrap at scale)
    psum_rounds: Any = None       # () int32 — lockstep radius rounds, i.e.
    #                               cross-shard termination reductions issued
    merge_size: Any = None        # int — elements in each cross-shard merge
    #                               (the pmin'd B x n candidate table)
    degraded: bool = False        # answered at the serving runtime's capped
    #                               max_rounds under overload (§9)
    probed_leaves: Any = None     # (B,) int32 — near-miss leaves admitted by
    #                               multi-probe, summed over trees/rounds
    #                               (None when the path never probes)
    probe_candidates: Any = None  # (B,) int32 — candidates contributed by
    #                               probe-admitted leaves


class SearchResult(NamedTuple):
    ids: Any                 # (B, k) int32 — point / global ids
    dists: Any               # (B, k) f32  — exact distances
    stats: SearchStats
    raw: Any = None          # engine-level core.query.QueryResult
