"""repro.api — the unified index surface (docs/DESIGN.md §6).

One protocol (``AnnIndex`` / ``MutableAnnIndex``), one build config
(``IndexSpec``), one typed request/result pair (``SearchRequest`` /
``SearchResult``), one engine registry, and snapshot persistence::

    import repro

    spec = repro.api.IndexSpec(kind="static", K=4, L=16, c=1.5)
    index = repro.api.build(data, jax.random.key(0), spec)
    res = index.search(queries, repro.api.SearchRequest(k=10))
    index.save("snapshots/my-index")
    ...
    index = repro.api.load("snapshots/my-index")   # no rebuild

Device placement is part of the spec (DESIGN.md §7): add a
``PlacementSpec`` and the same calls build/search/save/load the sharded
``PDETIndex`` instead — bit-identical answers to the unplaced build::

    spec = repro.api.IndexSpec(
        K=4, L=16, c=1.5,
        placement=repro.api.PlacementSpec(mesh_shape=(4,),
                                          mesh_axes=("data",)))

Deprecation policy: the pre-protocol kwarg surfaces
(``DETLSH.query`` / ``StreamingDETLSH.query``) remain as thin shims that
emit ``DeprecationWarning`` and delegate to ``search``; they will be
removed once nothing in-tree calls them.

Submodules import lazily (PEP 562) so ``repro.api`` itself stays cheap and
free of import cycles with ``repro.core``.
"""

from __future__ import annotations

import importlib
from typing import Any

__all__ = [
    "AnnIndex",
    "MutableAnnIndex",
    "LegacyIndexAdapter",
    "as_ann_index",
    "IndexSpec",
    "PlacementSpec",
    "PDETIndex",
    "SearchRequest",
    "SearchResult",
    "SearchStats",
    "Rejected",
    "EngineSpec",
    "register_engine",
    "resolve_engine",
    "available_engines",
    "get_engine",
    "build",
    "tune",
    "suggest_params",
    "TuneResult",
    "load",
    "save",
    "SnapshotFormatError",
    "SnapshotIntegrityError",
    "FORMAT_VERSION",
]

_EXPORTS = {
    "AnnIndex": "repro.api.protocol",
    "MutableAnnIndex": "repro.api.protocol",
    "LegacyIndexAdapter": "repro.api.protocol",
    "as_ann_index": "repro.api.protocol",
    "IndexSpec": "repro.api.spec",
    "PlacementSpec": "repro.api.spec",
    "PDETIndex": "repro.core.distributed",
    "SearchRequest": "repro.api.request",
    "SearchResult": "repro.api.request",
    "SearchStats": "repro.api.request",
    "Rejected": "repro.serving.scheduler",
    "EngineSpec": "repro.api.registry",
    "register_engine": "repro.api.registry",
    "resolve_engine": "repro.api.registry",
    "available_engines": "repro.api.registry",
    "get_engine": "repro.api.registry",
    "tune": "repro.tune",
    "suggest_params": "repro.tune",
    "TuneResult": "repro.tune",
    "load": "repro.api.persist",
    "save": "repro.api.persist",
    "SnapshotFormatError": "repro.api.persist",
    "SnapshotIntegrityError": "repro.api.persist",
    "FORMAT_VERSION": "repro.api.persist",
}


def build(data: Any, key: Any, spec: Any = None) -> Any:
    """Build an index from an ``IndexSpec`` (the one declarative config).

    Dispatches on ``spec.kind`` and ``spec.placement``: a static spec
    with a placement -> the sharded ``core.distributed.PDETIndex``;
    'static' -> ``core.DETLSH.from_spec``; 'streaming' ->
    ``streaming.StreamingDETLSH.from_spec``.
    """
    from repro.api.spec import IndexSpec
    spec = spec or IndexSpec()
    if spec.placement is not None:
        from repro.core.distributed import PDETIndex
        return PDETIndex.from_spec(data, key, spec)
    if spec.kind == "static":
        from repro.core import DETLSH
        return DETLSH.from_spec(data, key, spec)
    from repro.streaming import StreamingDETLSH
    return StreamingDETLSH.from_spec(data, key, spec)


def __getattr__(name: str) -> Any:
    if name in _EXPORTS:
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module 'repro.api' has no attribute {name!r}")


def __dir__() -> list[str]:
    return sorted(set(__all__) | set(globals()))
