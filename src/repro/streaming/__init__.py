"""Streaming mutable DET-LSH: an LSM-style segmented index (docs/DESIGN.md §5).

The paper's DE-Tree is *Dynamic* by construction — cheap incremental
maintenance is its selling point — but the static reproduction could only
build once over a frozen dataset.  This package adds the live-traffic
workload:

  * inserts land in a bounded delta buffer (``Memtable``) that is answered
    exactly (brute-force over <= capacity rows) until it fills, then is
    hashed + encoded with the base build's **frozen breakpoints** (no
    re-quantiling) and sealed into an immutable code-sorted ``Segment``;
  * deletes are tombstone bitmaps, honored by both query engines before
    compaction ever runs (the fused Pallas kernel masks per tile, the vmap
    engine masks at admission);
  * a compactor merges sealed segments by *merging* their already
    code-sorted arrays (O(n) stable merge on the interleaved iSAX keys —
    never a re-projection/re-encode/re-sort) and drops tombstoned rows;
  * queries fan out over {sealed segments + delta} and combine through the
    existing ``core/candidates.py`` incremental merge.

``StreamingDETLSH`` is the user-facing index; ``serving.LSHService`` wires
it to ``upsert()``/``delete()`` with a compaction trigger.
"""

from repro.streaming.segment import Segment, build_segment
from repro.streaming.memtable import BatchedMemtable, Memtable
from repro.streaming.manifest import Manifest
from repro.streaming.compactor import merge_segments
from repro.streaming.index import StreamingDETLSH

__all__ = ["StreamingDETLSH", "Segment", "build_segment", "Memtable",
           "BatchedMemtable", "Manifest", "merge_segments"]
