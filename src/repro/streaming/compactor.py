"""Compaction: merge sealed segments without rebuilding anything.

Every segment's per-tree arrays are already sorted by the bit-interleaved
iSAX key, and all segments share the same *inner* breakpoint edges (frozen
at the base build), so their key spaces are directly comparable.  Merging
two segments is therefore a stable **merge of sorted arrays** — positions
come from two ``searchsorted`` calls, O(n log n) comparisons and O(n)
moves, with no re-projection, no re-encoding, and no re-sort.  Tombstoned
rows are dropped before the merge, leaf summaries (lo/hi boxes) are
recomputed from the merged codes in one O(n) blockwise pass, and the outer
breakpoint edges of the merged forest are the union (min/max) of the
inputs' — which, as in ``segment.build_segment``, changes no code.

Runs on the host (numpy): compaction is the background maintenance path,
and host-side merging keeps dynamic result shapes out of the jitted query
graph entirely — the query path only ever sees the swapped-in segment.
"""

from __future__ import annotations

from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.detree import DEForest, _interleave_keys
from repro.streaming.segment import Segment


def interleave_keys64(codes: np.ndarray, K: int) -> np.ndarray:
    """(m, K) region ids -> uint64 interleaved sort keys (detree's order)."""
    hi, lo = _interleave_keys(jnp.asarray(codes), K)
    return ((np.asarray(hi).astype(np.uint64) << np.uint64(32))
            | np.asarray(lo).astype(np.uint64))


def stable_merge_positions(keys_a: np.ndarray,
                           keys_b: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Output positions of two key-sorted runs in their stable merge
    (ties: all of A before B).  pos_a[i] = i + #{b < a_i}; pos_b[j] =
    j + #{a <= b_j}.  Disjoint and complete by construction."""
    pos_a = np.arange(len(keys_a)) + np.searchsorted(keys_b, keys_a, "left")
    pos_b = np.arange(len(keys_b)) + np.searchsorted(keys_a, keys_b, "right")
    return pos_a, pos_b


def _merge_two(a: dict, b: dict) -> dict:
    """Merge two per-tree runs of (keys, gids, proj, codes)."""
    pos_a, pos_b = stable_merge_positions(a["keys"], b["keys"])
    m = len(pos_a) + len(pos_b)
    out = {}
    for name in ("keys", "gids", "proj", "codes"):
        arr = np.empty((m,) + a[name].shape[1:], a[name].dtype)
        arr[pos_a] = a[name]
        arr[pos_b] = b[name]
        out[name] = arr
    return out


def _tree_run(seg: Segment, l: int, K: int) -> dict:
    """Extract tree l's surviving rows in sorted order (tombstones dropped)."""
    f = seg.forest
    pid = np.asarray(f.point_ids[l])
    sel = np.asarray(f.valid[l]).copy()
    sel[sel] = seg.live[pid[sel]]
    rows = pid[sel]
    codes = np.asarray(f.codes_sorted[l])[sel]
    return dict(keys=interleave_keys64(codes, K),
                gids=seg.gids[rows].astype(np.int64),
                proj=np.asarray(f.proj_sorted[l])[sel],
                codes=codes)


def _leaf_summaries(codes_pad: np.ndarray, valid: np.ndarray,
                    leaf_size: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Numpy mirror of detree.build_tree's blockwise lo/hi computation."""
    n_pad, K = codes_pad.shape
    n_leaves = n_pad // leaf_size
    blocks = codes_pad.reshape(n_leaves, leaf_size, K)
    bmask = valid.reshape(n_leaves, leaf_size)
    big = np.iinfo(np.int32).max
    lo = np.where(bmask[..., None], blocks, big).min(axis=1)
    hi = np.where(bmask[..., None], blocks, -1).max(axis=1)
    leaf_valid = bmask.any(axis=1)
    lo = np.where(leaf_valid[:, None], lo, 0).astype(np.int32)
    hi = np.where(leaf_valid[:, None], hi, 0).astype(np.int32)
    return lo, hi, leaf_valid


def merge_segments(segments: List[Segment], *, leaf_size: int,
                   seg_id: int) -> Optional[Segment]:
    """Merge sealed segments into one, dropping tombstoned rows.

    Returns the merged Segment, or None when no row survives (the caller
    then just drops the inputs).  Correctness invariant: for every tree,
    the merged array is the stable key-sorted interleaving of the inputs'
    surviving rows — exactly what ``build_forest`` would produce for the
    surviving union encoded with the same (frozen-inner-edge) breakpoints,
    up to equal-key orderings, which the leaf bounds never depend on.
    """
    assert segments
    f0 = segments[0].forest
    L, K = f0.L, f0.K
    bps = [np.asarray(s.forest.breakpoints) for s in segments]
    for bp in bps[1:]:   # shared key space: inner edges must be identical
        np.testing.assert_allclose(bp[..., 1:-1], bps[0][..., 1:-1],
                                   rtol=0, atol=0)

    # Survivor rows in segment-list order define the merged local id space.
    datas = [np.asarray(s.data)[s.live] for s in segments]
    gid_parts = [s.gids[s.live].astype(np.int64) for s in segments]
    data_m = (np.concatenate(datas) if datas else
              np.zeros((0, np.asarray(segments[0].data).shape[1]), np.float32))
    gids_m = np.concatenate(gid_parts) if gid_parts else np.zeros(0, np.int64)
    m = len(gids_m)
    if m == 0:
        return None
    order = np.argsort(gids_m, kind="stable")
    gids_sorted = gids_m[order]

    def local_ids(tree_gids: np.ndarray) -> np.ndarray:
        return order[np.searchsorted(gids_sorted, tree_gids)].astype(np.int32)

    n_leaves = -(-m // leaf_size)
    n_pad = n_leaves * leaf_size
    pad = n_pad - m
    valid = np.arange(n_pad) < m

    pids, projs, codess = [], [], []
    leaf_los, leaf_his, leaf_vs = [], [], []
    for l in range(L):
        run = _tree_run(segments[0], l, K)
        for seg in segments[1:]:
            run = _merge_two(run, _tree_run(seg, l, K))
        assert len(run["gids"]) == m, (l, len(run["gids"]), m)
        pids.append(np.concatenate(
            [local_ids(run["gids"]), np.full(pad, m, np.int32)]))
        projs.append(np.concatenate(
            [run["proj"], np.zeros((pad, K), np.float32)]))
        codes_pad = np.concatenate(
            [run["codes"], np.zeros((pad, K), np.int32)]).astype(np.int32)
        codess.append(codes_pad)
        lo, hi, lv = _leaf_summaries(codes_pad, valid, leaf_size)
        leaf_los.append(lo)
        leaf_his.append(hi)
        leaf_vs.append(lv)

    bp_stack = np.stack(bps)                       # (S, L, K, Nr+1)
    bp_m = bps[0].copy()
    bp_m[..., 0] = bp_stack[..., 0].min(axis=0)    # widened union outer edges
    bp_m[..., -1] = bp_stack[..., -1].max(axis=0)

    forest = DEForest(
        point_ids=jnp.asarray(np.stack(pids)),
        proj_sorted=jnp.asarray(np.stack(projs), jnp.float32),
        codes_sorted=jnp.asarray(np.stack(codess)),
        valid=jnp.asarray(np.tile(valid, (L, 1))),
        leaf_lo=jnp.asarray(np.stack(leaf_los)),
        leaf_hi=jnp.asarray(np.stack(leaf_his)),
        leaf_valid=jnp.asarray(np.stack(leaf_vs)),
        breakpoints=jnp.asarray(bp_m, jnp.float32),
        n=m, leaf_size=leaf_size)

    live_rows = sum(int(s.n_live) for s in segments)
    clip = (sum(s.clip_fraction * max(s.n_live, 1) for s in segments)
            / max(live_rows, 1))
    return Segment(seg_id=seg_id, data=jnp.asarray(data_m),
                   gids=gids_m.astype(np.int32), live=np.ones(m, bool),
                   forest=forest, clip_fraction=clip)
