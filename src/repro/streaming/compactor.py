"""Compaction: merge sealed segments without rebuilding anything.

Every segment's per-tree arrays are already sorted by the bit-interleaved
iSAX key, and all segments share the same *inner* breakpoint edges (frozen
at the base build), so their key spaces are directly comparable.  Merging
two segments is therefore a stable **merge of sorted arrays** — positions
come from two ``searchsorted`` calls, O(n log n) comparisons and O(n)
moves, with no re-projection, no re-encoding, and no re-sort.  Tombstoned
rows are dropped before the merge, leaf summaries (lo/hi boxes) are
recomputed from the merged codes in one O(n) blockwise pass, and the outer
breakpoint edges of the merged forest are the union (min/max) of the
inputs' — which, as in ``segment.build_segment``, changes no code.

All data movement is vectorized over the L trees at once: survivor
extraction, the merge scatter, the padded assembly, and the leaf summaries
operate on stacked (L, m, ...) arrays (every tree holds the same survivor
set, so the per-tree survivor counts are equal and the stacked extraction
is a single boolean take + reshape).  Only the two ``searchsorted`` calls
per merge remain per-tree (numpy's searchsorted is 1-D) — O(m log m) each
over a tiny L, not the former per-tree Python assembly of every array.

Runs on the host (numpy): compaction is the background maintenance path,
and host-side merging keeps dynamic result shapes out of the jitted query
graph entirely — the query path only ever sees the swapped-in segment.
"""

from __future__ import annotations

import functools
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.detree import DEForest, key_bit_budget
from repro.streaming.segment import Segment


@functools.lru_cache(maxsize=None)
def _key_lut(K: int) -> np.ndarray:
    """(K, 256) uint64: the joined-word key contribution of code value v
    in dimension j — ``(hi << 32) | lo`` of ``detree.interleave_keys``,
    precomputed per 8-bit symbol so packing a run is one gather + OR per
    dimension instead of a per-bit shift sweep."""
    _, hi_bits, lo_bits = key_bit_budget(K)
    v = np.arange(256, dtype=np.uint64)
    lut = np.zeros((K, 256), np.uint64)
    # Positions >= 32 within a word overflow the device's uint32 shift and
    # are dropped there (e.g. K=9: lo positions reach 35); the host keys
    # must drop them identically or the merge order diverges from the
    # device sort order the segment arrays are actually in.
    for b in range(hi_bits):                       # hi word, shifted up 32
        bit = (v >> np.uint64(7 - b)) & np.uint64(1)
        for j in range(K):
            pos = hi_bits * K - 1 - (b * K + j)
            if pos < 32:
                lut[j] |= bit << np.uint64(32 + pos)
    for b in range(lo_bits):                       # lo word
        bit = (v >> np.uint64(7 - hi_bits - b)) & np.uint64(1)
        for j in range(K):
            pos = lo_bits * K - 1 - (b * K + j)
            if pos < 32:
                lut[j] |= bit << np.uint64(pos)
    return lut


def interleave_keys64(codes: np.ndarray, K: int) -> np.ndarray:
    """(..., m, K) region ids -> (..., m) uint64 interleaved sort keys
    (the two packed uint32 words of ``detree.interleave_keys`` joined —
    detree's exact order; asserted identical in tests/test_build_fused.py).
    Pure numpy: the compactor is the host maintenance path and must not
    round-trip keys through the device."""
    lut = _key_lut(K)
    c = np.asarray(codes, np.intp)
    out = lut[0][c[..., 0]]
    for j in range(1, K):
        out = out | lut[j][c[..., j]]
    return out


def stable_merge_positions(keys_a: np.ndarray,
                           keys_b: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Output positions of two key-sorted runs in their stable merge
    (ties: all of A before B).  pos_a[i] = i + #{b < a_i}; pos_b[j] =
    j + #{a <= b_j}.  Disjoint and complete by construction."""
    pos_a = np.arange(len(keys_a)) + np.searchsorted(keys_b, keys_a, "left")
    pos_b = np.arange(len(keys_b)) + np.searchsorted(keys_a, keys_b, "right")
    return pos_a, pos_b


_RUN_FIELDS = ("keys", "gids", "proj", "codes")


def _merge_two(a: dict, b: dict) -> dict:
    """Merge two stacked per-tree runs of (L, m, ...) arrays in one scatter
    per field (positions per tree, assembly vectorized over trees)."""
    L, ma = a["keys"].shape
    mb = b["keys"].shape[1]
    pos_a = np.empty((L, ma), np.intp)
    pos_b = np.empty((L, mb), np.intp)
    for l in range(L):                      # searchsorted is 1-D only
        pos_a[l], pos_b[l] = stable_merge_positions(a["keys"][l],
                                                    b["keys"][l])
    rows = np.arange(L)[:, None]
    out = {}
    for name in _RUN_FIELDS:
        arr = np.empty((L, ma + mb) + a[name].shape[2:], a[name].dtype)
        arr[rows, pos_a] = a[name]
        arr[rows, pos_b] = b[name]
        out[name] = arr
    return out


def _tree_runs(seg: Segment, K: int) -> dict:
    """All L trees' surviving rows in sorted order, stacked (L, m, ...)
    (tombstones dropped).  Every tree keeps the same survivor set, so the
    per-tree counts are equal and one boolean take + reshape extracts all
    trees at once."""
    f = seg.forest
    pid = np.asarray(f.point_ids)                      # (L, n_pad)
    valid = np.asarray(f.valid)
    sel = valid.copy()
    sel[valid] = seg.live[pid[valid]]                  # (L, n_pad)
    L = pid.shape[0]
    m = int(sel[0].sum())
    rows = pid[sel].reshape(L, m)
    codes = np.asarray(f.codes_sorted)[sel].reshape(L, m, K)
    return dict(keys=interleave_keys64(codes, K),
                gids=seg.gids[rows].astype(np.int64),
                proj=np.asarray(f.proj_sorted)[sel].reshape(L, m, K),
                codes=codes)


def _leaf_summaries(codes_pad: np.ndarray, valid: np.ndarray,
                    leaf_size: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Numpy mirror of detree.assemble_sorted_forest's blockwise lo/hi
    computation, for all L trees at once: codes_pad (L, n_pad, K),
    valid (n_pad,) -> lo/hi (L, n_leaves, K) int16, leaf_valid bool."""
    L, n_pad, K = codes_pad.shape
    n_leaves = n_pad // leaf_size
    blocks = codes_pad.reshape(L, n_leaves, leaf_size, K).astype(np.int32)
    bmask = valid.reshape(n_leaves, leaf_size)[None]
    big = np.iinfo(np.int32).max
    lo = np.where(bmask[..., None], blocks, big).min(axis=2)
    hi = np.where(bmask[..., None], blocks, -1).max(axis=2)
    leaf_valid = np.broadcast_to(bmask.any(axis=2), (L, n_leaves))
    lo = np.where(leaf_valid[..., None], lo, 0).astype(np.int16)
    hi = np.where(leaf_valid[..., None], hi, 0).astype(np.int16)
    return lo, hi, leaf_valid


def merge_segments(segments: List[Segment], *, leaf_size: int,
                   seg_id: int) -> Optional[Segment]:
    """Merge sealed segments into one, dropping tombstoned rows.

    Returns the merged Segment, or None when no row survives (the caller
    then just drops the inputs).  Correctness invariant: for every tree,
    the merged array is the stable key-sorted interleaving of the inputs'
    surviving rows — exactly what ``build_forest`` would produce for the
    surviving union encoded with the same (frozen-inner-edge) breakpoints,
    up to equal-key orderings, which the leaf bounds never depend on.
    """
    assert segments
    f0 = segments[0].forest
    L, K = f0.L, f0.K
    bps = [np.asarray(s.forest.breakpoints) for s in segments]
    for bp in bps[1:]:   # shared key space: inner edges must be identical
        np.testing.assert_allclose(bp[..., 1:-1], bps[0][..., 1:-1],
                                   rtol=0, atol=0)

    # Survivor rows in segment-list order define the merged local id space.
    datas = [np.asarray(s.data)[s.live] for s in segments]
    gid_parts = [s.gids[s.live].astype(np.int64) for s in segments]
    data_m = (np.concatenate(datas) if datas else
              np.zeros((0, np.asarray(segments[0].data).shape[1]), np.float32))
    gids_m = np.concatenate(gid_parts) if gid_parts else np.zeros(0, np.int64)
    m = len(gids_m)
    if m == 0:
        return None
    order = np.argsort(gids_m, kind="stable")
    gids_sorted = gids_m[order]

    run = _tree_runs(segments[0], K)
    for seg in segments[1:]:
        run = _merge_two(run, _tree_runs(seg, K))
    assert run["gids"].shape == (L, m), (run["gids"].shape, m)

    n_leaves = -(-m // leaf_size)
    n_pad = n_leaves * leaf_size
    pad = n_pad - m
    valid = np.arange(n_pad) < m

    # gid -> merged local id, all trees at once (searchsorted broadcasts
    # over the stacked (L, m) lookup).
    local = order[np.searchsorted(gids_sorted, run["gids"])].astype(np.int32)
    pids = np.concatenate(
        [local, np.full((L, pad), m, np.int32)], axis=1)
    projs = np.concatenate(
        [run["proj"].astype(np.float32), np.zeros((L, pad, K), np.float32)],
        axis=1)
    codes_pad = np.concatenate(
        [run["codes"].astype(np.uint8), np.zeros((L, pad, K), np.uint8)],
        axis=1)
    leaf_lo, leaf_hi, leaf_valid = _leaf_summaries(codes_pad, valid,
                                                   leaf_size)

    bp_stack = np.stack(bps)                       # (S, L, K, Nr+1)
    bp_m = bps[0].copy()
    bp_m[..., 0] = bp_stack[..., 0].min(axis=0)    # widened union outer edges
    bp_m[..., -1] = bp_stack[..., -1].max(axis=0)

    forest = DEForest(
        point_ids=jnp.asarray(pids),
        proj_sorted=jnp.asarray(projs, jnp.float32),
        codes_sorted=jnp.asarray(codes_pad),
        valid=jnp.asarray(np.tile(valid, (L, 1))),
        leaf_lo=jnp.asarray(leaf_lo),
        leaf_hi=jnp.asarray(leaf_hi),
        leaf_valid=jnp.asarray(leaf_valid),
        breakpoints=jnp.asarray(bp_m, jnp.float32),
        n=m, leaf_size=leaf_size)

    live_rows = sum(int(s.n_live) for s in segments)
    clip = (sum(s.clip_fraction * max(s.n_live, 1) for s in segments)
            / max(live_rows, 1))
    return Segment(seg_id=seg_id, data=jnp.asarray(data_m),
                   gids=gids_m.astype(np.int32), live=np.ones(m, bool),
                   forest=forest, clip_fraction=clip)
