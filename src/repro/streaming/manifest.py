"""Manifest: the versioned catalog of sealed segments.

The query path reads ``segments`` (fan-out order: oldest first); mutators
go through ``add`` / ``swap`` so every structural change bumps ``version``
— the invalidation key for anything derived from the segment list (jit
caches, warmed shapes).  ``swap`` is the compactor's atomic install: the
replacement segment appears in the same pass that removes its inputs, so a
reader never sees a point twice or not at all.
"""

from __future__ import annotations

import dataclasses
from typing import List

from repro.streaming.segment import Segment


@dataclasses.dataclass
class Manifest:
    segments: List[Segment] = dataclasses.field(default_factory=list)
    version: int = 0

    def add(self, seg: Segment) -> None:
        self.segments.append(seg)
        self.version += 1

    def swap(self, remove_ids, add: List[Segment]) -> None:
        """Atomically replace segments ``remove_ids`` with ``add``."""
        remove_ids = set(remove_ids)
        kept = [s for s in self.segments if s.seg_id not in remove_ids]
        self.segments = kept + list(add)
        self.version += 1

    @property
    def n_rows(self) -> int:
        return sum(s.m for s in self.segments)

    @property
    def n_live(self) -> int:
        return sum(s.n_live for s in self.segments)

    def describe(self) -> dict:
        return {
            "version": self.version,
            "segments": [
                {"seg_id": s.seg_id, "rows": s.m, "live": s.n_live,
                 "clip_fraction": round(s.clip_fraction, 6)}
                for s in self.segments],
        }
