"""Manifest: the versioned catalog of sealed segments.

The query path reads ``segments`` (fan-out order: oldest first); mutators
go through ``add`` / ``swap`` so every structural change bumps ``version``
— the invalidation key for anything derived from the segment list (jit
caches, warmed shapes).  ``swap`` is the compactor's atomic install: the
replacement segment appears in the same pass that removes its inputs, so a
reader never sees a point twice or not at all.

Epoch refcounts (docs/DESIGN.md §9).  The serving runtime pins an
*epoch* — an immutable view of one manifest version — for the lifetime of
every query batch, so compaction can swap the next version in underneath
without invalidating in-flight readers (RCU: readers never block writers
and vice versa).  ``retain``/``release`` track how many pinned epochs
still reference each version; ``pinned_versions`` makes the drain state
observable (``describe()`` reports it, tests assert on it).  The refcount
is bookkeeping, not a lock: old ``Segment`` objects stay alive through the
epoch's own references, and a version retires (drops out of the pin table)
exactly when its last reader releases.

``swap_hook`` is the fault-injection boundary for the compaction swap
(serving/faults.py): it runs *before* any mutation, so a hook that raises
models a compaction crashing mid-install — the manifest is left exactly
as it was, which is what makes the swap atomic under injected faults.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.streaming.segment import Segment


@dataclasses.dataclass
class Manifest:
    segments: List[Segment] = dataclasses.field(default_factory=list)
    version: int = 0
    # version -> number of pinned epochs still reading it (serving runtime)
    _pins: Dict[int, int] = dataclasses.field(default_factory=dict,
                                              repr=False)
    # fault-injection point: called at the top of swap(), before mutation
    swap_hook: Optional[Callable[[], None]] = dataclasses.field(
        default=None, repr=False, compare=False)

    def add(self, seg: Segment) -> None:
        self.segments.append(seg)
        self.version += 1

    def swap(self, remove_ids, add: List[Segment]) -> None:
        """Atomically replace segments ``remove_ids`` with ``add``.

        The hook (if any) fires first: an exception there leaves the
        manifest untouched — the compaction-crash recovery contract."""
        if self.swap_hook is not None:
            self.swap_hook()
        remove_ids = set(remove_ids)
        kept = [s for s in self.segments if s.seg_id not in remove_ids]
        self.segments = kept + list(add)
        self.version += 1

    # ------------------------------------------------------------------
    # Epoch refcounts
    # ------------------------------------------------------------------

    def retain(self) -> int:
        """Pin the current version for a reader epoch; returns the version
        token to pass back to ``release``."""
        self._pins[self.version] = self._pins.get(self.version, 0) + 1
        return self.version

    def release(self, version: int) -> None:
        """Drop one reader pin on ``version``; the version retires (leaves
        the pin table) when its count drains to zero."""
        count = self._pins.get(version)
        if count is None:
            raise ValueError(f"release of unpinned manifest version "
                             f"{version} (double release?)")
        if count <= 1:
            del self._pins[version]
        else:
            self._pins[version] = count - 1

    def pinned_versions(self) -> tuple:
        """Versions with live reader epochs, oldest first."""
        return tuple(sorted(self._pins))

    @property
    def n_rows(self) -> int:
        return sum(s.m for s in self.segments)

    @property
    def n_live(self) -> int:
        return sum(s.n_live for s in self.segments)

    def describe(self) -> dict:
        return {
            "version": self.version,
            "pinned": {v: c for v, c in sorted(self._pins.items())},
            "segments": [
                {"seg_id": s.seg_id, "rows": s.m, "live": s.n_live,
                 "clip_fraction": round(s.clip_fraction, 6)}
                for s in self.segments],
        }
