"""Immutable sealed segment: one DE-Forest over a batch of accepted points.

A segment is the unit of the streaming index's LSM structure.  Its rows are
frozen at seal time; the only mutable state is the tombstone bitmap
(``live``), which both query engines honor (docs/DESIGN.md §5).

Frozen-breakpoint encoding.  New points are encoded with the *base build's*
breakpoints so codes stay comparable across segments (the compactor's O(n)
merge depends on a shared key space).  ``encode`` reads only the Nr-1
*inner* edges, so per-segment **outer-edge widening** — stretching edge 0 /
edge Nr to cover the segment's actual projected min/max — changes no code
but keeps every point inside its leaf's bounding box, which is what the
Fig. 5 LB admissibility (and hence Theorems 1-3) needs.  The fraction of
coordinates that needed widening is recorded as ``clip_fraction`` — the
breakpoint-drift signal that tells the operator when a re-quantile
(``StreamingDETLSH.requantile``) is worth it.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.core.detree import (DEForest, assemble_sorted_forest, build_forest,
                               code_sort_orders)
from repro.core.query import FusedPlan, live_in_sorted_order, make_fused_plan
from repro.core.theory import LSHParams


@dataclasses.dataclass
class Segment:
    """One sealed, code-sorted segment (rows immutable, tombstones mutable)."""

    seg_id: int
    data: jax.Array            # (m, d) f32 — segment rows, local order
    gids: np.ndarray           # (m,) int32 — global point ids (host truth)
    live: np.ndarray           # (m,) bool — tombstone bitmap (host truth)
    forest: DEForest           # DE-Forest over local row ids 0..m-1
    clip_fraction: float       # coords outside the frozen outer edges at seal

    # Device-side caches, invalidated on delete (None = stale).  Caches are
    # only populated OUTSIDE a jax trace (see _cacheable): populating them
    # while a caller jits query() would store tracers and leak them.
    _plan: Optional[FusedPlan] = dataclasses.field(
        default=None, repr=False, compare=False)
    _live_dev: Optional[jax.Array] = dataclasses.field(
        default=None, repr=False, compare=False)
    _live_sorted_dev: Optional[jax.Array] = dataclasses.field(
        default=None, repr=False, compare=False)
    _gid_map: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def m(self) -> int:
        return int(self.data.shape[0])

    @property
    def n_live(self) -> int:
        return int(self.live.sum())

    @property
    def has_tombstones(self) -> bool:
        return bool((~self.live).any())

    def mark_dead(self, local_rows) -> None:
        self.live[np.asarray(local_rows)] = False
        self._live_dev = None
        self._live_sorted_dev = None
        self._gid_map = None

    @staticmethod
    def _cacheable(x) -> bool:
        leaves = jax.tree_util.tree_leaves(x)
        return not any(isinstance(v, jax.core.Tracer) for v in leaves)

    def plan(self) -> FusedPlan:
        if self._plan is None:
            plan = make_fused_plan(self.data, self.forest)
            if not self._cacheable(plan):
                return plan
            self._plan = plan
        return self._plan

    def live_dev(self) -> Optional[jax.Array]:
        """(m,) bool device mask, or None when every row is live."""
        if not self.has_tombstones:
            return None
        if self._live_dev is None:
            self._live_dev = jnp.array(self.live)    # copy: host bitmap mutates
        return self._live_dev

    def live_sorted_dev(self) -> Optional[jax.Array]:
        """(L, n_pad) bool mask in code-sorted order for the fused kernel."""
        live = self.live_dev()
        if live is None:
            return None
        if self._live_sorted_dev is None:
            sorted_mask = live_in_sorted_order(self.forest, live)
            if not self._cacheable(sorted_mask):
                return sorted_mask
            self._live_sorted_dev = sorted_mask
        return self._live_sorted_dev

    def gid_map_dev(self, sentinel: int) -> jax.Array:
        """(m+1,) int32: local id -> global id; dead rows and the local
        sentinel m map to ``sentinel`` (the combine step's invalid id)."""
        if self._gid_map is None or self._gid_map[0] != sentinel:
            gids = np.where(self.live, self.gids, sentinel).astype(np.int32)
            self._gid_map = (sentinel, jnp.asarray(
                np.concatenate([gids, [sentinel]]).astype(np.int32)))
        return self._gid_map[1]

    def warm_caches(self, sentinel: int) -> None:
        """Materialize all device caches eagerly (call before jitting a
        query closure over this segment, so the closure captures concrete
        arrays instead of re-staging them as graph constants)."""
        self.plan()
        self.live_dev()
        self.live_sorted_dev()
        self.gid_map_dev(sentinel)


@functools.partial(jax.jit,
                   static_argnames=("K", "L", "leaf_size", "impl", "chunk"))
def _fused_seal(data, A, bp_all, *, K, L, leaf_size, impl, chunk):
    """One jitted pass for the whole seal: project -> encode -> key-pack
    (one fused kernel — encoding reads only the *inner* edges, so it can
    run with the frozen breakpoints while the outer-edge widening is
    computed from the same pass's projections) -> single sort -> forest
    arrays.  Returns (arrays, bp_seg (L*K, Nr+1) widened, clip_fraction).
    """
    if impl == "xla":
        from repro.kernels import ref as kref
        proj_t, codes_t, key_hi, key_lo = kref.project_encode_pack(
            data, A, bp_all, K=K, L=L)
    else:
        from repro.kernels import ops as kops
        proj_t, codes_t, key_hi, key_lo = kops.project_encode_pack(
            data, A, bp_all, K=K, L=L, block_n=chunk,
            interpret=(impl == "pallas_interpret"))
    # Dimension D = l*K + j maps to proj_t[l, :, j]: (L, K) stats -> (L*K,).
    pmin = jnp.min(proj_t, axis=1).reshape(-1)
    pmax = jnp.max(proj_t, axis=1).reshape(-1)
    bp_lo = bp_all[:, 0].reshape(L, 1, K)
    bp_hi = bp_all[:, -1].reshape(L, 1, K)
    clip = jnp.mean(((proj_t < bp_lo) | (proj_t > bp_hi))
                    .astype(jnp.float32))
    bp_seg = bp_all.at[:, 0].set(jnp.minimum(bp_all[:, 0], pmin))
    bp_seg = bp_seg.at[:, -1].set(jnp.maximum(bp_all[:, -1], pmax))
    order = code_sort_orders(key_hi, key_lo, K)
    arrays = assemble_sorted_forest(proj_t, codes_t, order,
                                    n=data.shape[0], leaf_size=leaf_size)
    return arrays, bp_seg, clip


def build_segment(data: jax.Array, gids: np.ndarray, A: jax.Array,
                  params: LSHParams, bp_all: jax.Array, *,
                  Nr: int, leaf_size: int, seg_id: int,
                  live: np.ndarray | None = None,
                  proj: jax.Array | None = None,
                  project_impl: str = "auto",
                  encode_impl: str = "auto",
                  build_impl: str = "auto",
                  build_chunk: int = 512) -> Segment:
    """Seal rows into a Segment, encoding with the frozen breakpoints.

    bp_all: (L*K, Nr+1) — the base build's breakpoints.  Outer edges are
    widened per dimension to the segment's projected min/max (no code
    changes; restores Fig. 5 box containment for out-of-range inserts).
    ``proj`` skips re-projection when the caller already has it.

    With no precomputed ``proj`` and a fused ``build_impl``, the entire
    seal — projection, encoding, key packing, widening stats, the sort and
    the leaf summaries — is ONE jitted call around the one-pass
    ``project_encode_pack`` kernel (frozen breakpoints mean no selection
    step splits the pipeline; docs/DESIGN.md §8), which is what makes
    steady-state ingest dispatch-bound no longer.
    """
    # jnp.array (not asarray): the CPU backend may zero-copy alias a numpy
    # buffer, and seal() hands us the memtable's arrays which are zeroed
    # right after — the segment must own its rows.
    data = jnp.array(data, jnp.float32)
    m = data.shape[0]
    K, L = params.K, params.L
    from repro.core.detree import check_nr
    check_nr(Nr)
    if proj is None and build_impl != "reference":
        impl = build_impl
        if impl == "auto" and project_impl != "auto":
            impl = project_impl       # an explicit project impl wins on auto
        arrays, bp_seg, clip = _fused_seal(
            data, A, bp_all, K=K, L=L, leaf_size=leaf_size, impl=impl,
            chunk=int(build_chunk) if build_chunk else 512)
        forest = DEForest(n=m, leaf_size=leaf_size,
                          breakpoints=bp_seg.reshape(L, K, Nr + 1), **arrays)
        clip_fraction = float(clip)
    else:
        if proj is None:
            proj = hashing.project(data, A, impl=project_impl)  # (m, L*K)
        out_lo = proj < bp_all[:, 0][None, :]
        out_hi = proj > bp_all[:, -1][None, :]
        clip_fraction = float(jnp.mean((out_lo | out_hi).astype(jnp.float32)))
        bp_seg = bp_all.at[:, 0].set(jnp.minimum(bp_all[:, 0],
                                                 jnp.min(proj, axis=0)))
        bp_seg = bp_seg.at[:, -1].set(jnp.maximum(bp_all[:, -1],
                                                  jnp.max(proj, axis=0)))
        forest = build_forest(proj, K, L, Nr=Nr,
                              leaf_size=leaf_size, breakpoints=bp_seg,
                              encode_impl=encode_impl,
                              build_impl=build_impl,
                              build_chunk=build_chunk)
    live = np.ones(m, bool) if live is None else np.asarray(live, bool).copy()
    return Segment(seg_id=seg_id, data=data,
                   gids=np.asarray(gids, np.int32).copy(), live=live,
                   forest=forest, clip_fraction=clip_fraction)
