"""Delta buffer: the mutable head of the streaming index.

Fixed-capacity host-side arrays (stable device shapes => one compile for
the delta's exact query path).  Inserts append at a cursor; deletes of
not-yet-sealed rows just clear the slot's live bit.  When the buffer is
full the index seals *all* capacity rows into a Segment (dead slots become
tombstoned rows there — the compactor drops them), so every sealed-from-
delta segment has the same shape and reuses the same compiled kernels.
"""

from __future__ import annotations

import numpy as np


class Memtable:
    def __init__(self, capacity: int, d: int):
        assert capacity >= 1
        self.capacity = capacity
        self.d = d
        self.vecs = np.zeros((capacity, d), np.float32)
        self.gids = np.full(capacity, -1, np.int64)
        self.live = np.zeros(capacity, bool)
        self.count = 0            # slots assigned (monotone until reset)
        self.version = 0          # bumped on every mutation (device-cache key)

    @property
    def full(self) -> bool:
        return self.count >= self.capacity

    @property
    def n_live(self) -> int:
        return int(self.live.sum())

    def add(self, gid: int, vec: np.ndarray) -> int:
        """Append one row; returns its slot.  Caller checks ``full`` first."""
        return int(self.add_block(np.asarray([gid], np.int64),
                                  np.asarray(vec, np.float32)[None, :])[0])

    def add_block(self, gids: np.ndarray, vecs: np.ndarray) -> np.ndarray:
        """Append a block of rows with one vectorized write; returns the
        assigned slots.  Caller ensures the block fits (seal first)."""
        m = len(gids)
        assert self.count + m <= self.capacity, (self.count, m, self.capacity)
        slots = np.arange(self.count, self.count + m)
        self.vecs[slots] = vecs
        self.gids[slots] = gids
        self.live[slots] = True
        self.count += m
        self.version += 1
        return slots

    def kill(self, slot: int) -> None:
        self.live[slot] = False
        self.version += 1

    def reset(self) -> None:
        self.vecs[:] = 0.0
        self.gids[:] = -1
        self.live[:] = False
        self.count = 0
        self.version += 1


class BatchedMemtable:
    """H parallel delta buffers advancing in lockstep (the KV-decode delta).

    ``repro.decode`` keeps one DE-Forest per (batch, kv-head); a decode
    step inserts exactly one new key into *every* head's delta at the same
    cache position, so the H buffers share one cursor, one gid (position)
    array, and one live bitmap — only the vectors carry a head axis.
    Same fixed-capacity / stable-shape contract as ``Memtable`` (one
    compile for the exact delta-distance path).
    """

    def __init__(self, heads: int, capacity: int, d: int):
        assert heads >= 1 and capacity >= 1
        self.heads = heads
        self.capacity = capacity
        self.d = d
        self.vecs = np.zeros((heads, capacity, d), np.float32)
        self.gids = np.full(capacity, -1, np.int64)
        self.live = np.zeros(capacity, bool)
        self.count = 0
        self.version = 0

    @property
    def full(self) -> bool:
        return self.count >= self.capacity

    @property
    def n_live(self) -> int:
        return int(self.live.sum())

    def add_step(self, gid: int, vecs: np.ndarray) -> int:
        """Append one row per head (vecs (H, d)); returns the slot."""
        assert self.count < self.capacity, (self.count, self.capacity)
        assert vecs.shape == (self.heads, self.d), vecs.shape
        slot = self.count
        self.vecs[:, slot] = vecs
        self.gids[slot] = gid
        self.live[slot] = True
        self.count += 1
        self.version += 1
        return slot

    def kill(self, slot: int) -> None:
        self.live[slot] = False
        self.version += 1

    def reset(self) -> None:
        self.vecs[:] = 0.0
        self.gids[:] = -1
        self.live[:] = False
        self.count = 0
        self.version += 1
