"""StreamingDETLSH: the mutable, segmented DET-LSH index.

Structure (docs/DESIGN.md §5): a ``Manifest`` of sealed ``Segment``s plus
one mutable ``Memtable`` delta.  Inserts append to the delta (answered
exactly until sealed); deletes tombstone wherever the point lives; sealing
encodes the delta with the base build's frozen breakpoints; compaction
merges sealed segments on the host and atomically swaps the result in.

Queries fan out over {segments + delta}: each sealed segment runs the
ordinary batched c^2-k-ANN (fused or vmap engine) over its own forest with
its tombstone mask, the delta is answered by exact brute force over its
<= capacity rows, and the per-source top-k lists — in *global* id space —
are combined through ``core/candidates.py`` (merge_round dedup +
canonicalize), so the cross-source merge is the same property-tested
machinery the round loop uses.

Guarantee argument (docs/DESIGN.md §5): each segment query is a standard
DET-LSH query over that segment's live points (T1 uses the segment's total
row count n_seg >= n_live, which only delays termination — a superset, safe
by the §2 argument), the delta is exact, and the final k is the best-of-
union — so recall over the surviving union is bounded below by the paper's
per-segment guarantee.

Note on jit: ``query`` is trace-compatible (pure jnp on device state) when
``r_min`` is passed explicitly; the default estimates r_min host-side.
Mutations change device buffers, so re-trace after upsert/seal/compact if
you wrapped ``query`` in ``jax.jit``.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import warnings
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import registry as engine_registry
from repro.core import estimate_r_min, hashing
from repro.core import candidates as cand
from repro.core import encoding as enc
from repro.core.query import QueryResult, knn_query_batch
from repro.core.theory import LSHParams, derive_params
from repro.streaming.compactor import merge_segments
from repro.streaming.manifest import Manifest
from repro.streaming.memtable import Memtable
from repro.streaming.segment import Segment, build_segment

_DELTA = "delta"     # locator tag for rows still in the memtable


class _SegView(NamedTuple):
    """One segment's pinned query inputs.

    Device arrays are immutable, so pinning = holding references taken at
    pin time: a later ``mark_dead`` replaces the segment's *caches* but
    never mutates the arrays an earlier pin captured.  ``live_host`` is a
    copy (the host bitmap does mutate in place) — it exists for
    ``PinnedView.survivors()``, the oracle input, not for the query path.
    """

    seg: Segment
    live_dev: Optional[jax.Array]         # (m,) bool, None = all live
    live_sorted_dev: Optional[jax.Array]  # (L, n_pad) bool, None = all live
    gmap: jax.Array                       # (m+1,) int32 local -> global id
    live_host: np.ndarray                 # (m,) bool copy at pin time


@dataclasses.dataclass(frozen=True)
class PinnedView:
    """An immutable epoch of a ``StreamingDETLSH`` (docs/DESIGN.md §9).

    Everything a query needs is captured by reference-to-immutable (device
    arrays, sealed segment rows) or by copy (host bitmaps, delta rows), so
    any interleaving of upsert/delete/seal/compact after the pin leaves
    this view answering exactly as the index did at pin time.  The view is
    what the serving runtime's epoch wraps; ``search(queries, request,
    view=...)`` runs the ordinary fan-out against it.
    """

    manifest_version: int
    memtable_version: int
    id_capacity: int                      # combine sentinel / bitmap width
    segs: tuple                           # of _SegView (n_live > 0 only)
    delta: Optional[tuple]                # (vecs, live, gmap) device arrays
    delta_n_live: int
    delta_capacity: int
    delta_host: Optional[tuple]           # (vecs, gids, live) host copies
    # per-view r_min cache (the index cache is keyed by *current* versions)
    _rmin: dict = dataclasses.field(default_factory=dict, repr=False,
                                    compare=False)

    @property
    def fingerprint(self) -> tuple:
        return (self.manifest_version, self.memtable_version)

    @property
    def n_live(self) -> int:
        return (sum(int(v.live_host.sum()) for v in self.segs)
                + self.delta_n_live)

    def survivors(self) -> tuple:
        """(vectors, gids) alive at pin time — the from-scratch-rebuild
        oracle input for the epoch equivalence property test."""
        vecs = [np.asarray(v.seg.data)[v.live_host] for v in self.segs]
        gids = [v.seg.gids[v.live_host].astype(np.int64) for v in self.segs]
        if self.delta_host is not None:
            dv, dg, dl = self.delta_host
            vecs.append(dv[dl])
            gids.append(dg[dl])
        if not vecs:
            d = (self.segs[0].seg.data.shape[1] if self.segs
                 else (self.delta_host[0].shape[1] if self.delta_host
                       else 0))
            return np.zeros((0, d), np.float32), np.zeros(0, np.int64)
        return np.concatenate(vecs), np.concatenate(gids)


class StreamingDETLSH:
    """Mutable segmented DET-LSH index with upsert / delete / compaction.

    Satisfies ``repro.api.MutableAnnIndex``: the typed ``search`` surface
    plus ``upsert``/``delete``/``maybe_compact`` and snapshot ``save``.
    """

    def __init__(self, params: LSHParams, A: jax.Array, bp_all: jax.Array,
                 base: Optional[Segment], *, Nr: int, leaf_size: int,
                 delta_capacity: int = 512, max_segments: int = 4,
                 id_capacity: int = 1 << 20,
                 build_impl: str = "auto", build_chunk: int = 512):
        self.params = params
        self.A = A
        self.bp_all = bp_all              # (L*K, Nr+1) frozen breakpoints
        self.Nr = Nr
        self.leaf_size = leaf_size
        self.build_impl = build_impl      # seal-path builder (DESIGN.md §8)
        self.build_chunk = build_chunk
        self.max_segments = max_segments
        self.id_capacity = int(id_capacity)
        self.manifest = Manifest()
        self.locator: Dict[int, Tuple] = {}   # gid -> (_DELTA, slot) | (seg_id, row)
        self.next_gid = 0
        self._next_seg_id = 0
        d = A.shape[0]
        self.memtable = Memtable(delta_capacity, d)
        self._delta_cache = None          # (memtable.version, device arrays)
        self.spec = None                  # IndexSpec when built via from_spec
        # ((manifest.version, memtable.version), {k: r_min}) — the per-k
        # radius-estimate cache, invalidated by structural mutation.
        self._rmin_cache: Tuple[Tuple[int, int], Dict[int, float]] = \
            ((-1, -1), {})
        if base is not None:
            self.manifest.add(base)
            self._next_seg_id = base.seg_id + 1
            for row, gid in enumerate(base.gids):
                self.locator[int(gid)] = (base.seg_id, row)
            self.next_gid = int(base.gids.max()) + 1 if base.m else 0

    # ------------------------------------------------------------------
    # Build
    # ------------------------------------------------------------------

    @classmethod
    def build(cls, data: jax.Array, key: jax.Array,
              params: LSHParams | None = None, *,
              Nr: int = enc.DEFAULT_NR, leaf_size: int = 64,
              delta_capacity: int = 512, max_segments: int = 4,
              id_capacity: int | None = None,
              breakpoint_method: str = "sample_sort",
              project_impl: str = "auto",
              encode_impl: str = "auto",
              build_impl: str = "auto",
              build_chunk: int = 512) -> "StreamingDETLSH":
        """Static base build (Alg. 1 + 2) that also freezes the breakpoints
        every later seal will encode with.  ``build_impl``/``build_chunk``
        select the fused single-sort builder for the base build and every
        later seal (docs/DESIGN.md §8)."""
        params = params or derive_params()
        data = jnp.asarray(data, jnp.float32)
        n, d = data.shape
        kp, kb = jax.random.split(key)
        A = hashing.sample_projections(kp, d, params.K, params.L)
        proj = hashing.project(data, A, impl=project_impl)
        bp_all = enc.select_breakpoints(proj, Nr, method=breakpoint_method,
                                        key=kb)
        base = build_segment(data, np.arange(n, dtype=np.int64), A, params,
                             bp_all, Nr=Nr, leaf_size=leaf_size, seg_id=0,
                             proj=proj, encode_impl=encode_impl,
                             build_impl=build_impl, build_chunk=build_chunk)
        if id_capacity is None:
            id_capacity = max(2 * n, n + 16 * delta_capacity, 1024)
        return cls(params, A, bp_all, base, Nr=Nr, leaf_size=leaf_size,
                   delta_capacity=delta_capacity, max_segments=max_segments,
                   id_capacity=id_capacity, build_impl=build_impl,
                   build_chunk=build_chunk)

    @classmethod
    def from_spec(cls, data: jax.Array, key: jax.Array,
                  spec) -> "StreamingDETLSH":
        """Build from one declarative ``repro.api.IndexSpec``."""
        if spec.kind != "streaming":
            raise ValueError(f"StreamingDETLSH.from_spec needs "
                             f"kind='streaming', got {spec.kind!r} "
                             f"(use repro.api.build)")
        idx = cls.build(data, key, spec.derive_params(), Nr=spec.Nr,
                        leaf_size=spec.leaf_size,
                        delta_capacity=spec.delta_capacity,
                        max_segments=spec.max_segments,
                        id_capacity=spec.id_capacity,
                        breakpoint_method=spec.breakpoint_method,
                        project_impl=spec.project_impl,
                        encode_impl=spec.encode_impl,
                        build_impl=spec.build_impl,
                        build_chunk=spec.build_chunk)
        idx.spec = spec
        return idx

    # ------------------------------------------------------------------
    # Mutation
    # ------------------------------------------------------------------

    def upsert(self, vectors, gids=None) -> np.ndarray:
        """Insert (or overwrite) rows; returns their global ids (int32).

        Overwrite semantics: an existing gid is tombstoned wherever it
        lives and re-inserted into the delta.  Sealing triggers itself when
        the delta fills; compaction is the caller's trigger
        (``maybe_compact``, wired into serving.LSHService).
        """
        vecs = np.asarray(vectors, np.float32)
        if vecs.ndim == 1:
            vecs = vecs[None, :]
        m = len(vecs)
        if gids is None:
            gids = np.arange(self.next_gid, self.next_gid + m, dtype=np.int64)
        else:
            gids = np.asarray(gids, np.int64).reshape(-1)
            assert len(gids) == m, (len(gids), m)
        if m == 0:
            return gids.astype(np.int32)
        # Validate before mutating any state so the caller can recover.
        self.next_gid = self.check_upsert(gids)

        # Last write wins within one call: keep only each gid's final row.
        _, last_rev = np.unique(gids[::-1], return_index=True)
        keep = np.sort(m - 1 - last_rev, kind="stable")
        ins_gids, ins_vecs = gids[keep], vecs[keep]
        for gid in ins_gids:                       # overwrite semantics
            if int(gid) in self.locator:
                self._tombstone(int(gid))
        # Bulk-copy into the delta in capacity-sized blocks (the per-row
        # Python loop made ingest interpreter-bound), sealing at each fill.
        pos = 0
        while pos < len(ins_gids):
            if self.memtable.full:
                self.seal()
            take = min(self.memtable.capacity - self.memtable.count,
                       len(ins_gids) - pos)
            slots = self.memtable.add_block(ins_gids[pos:pos + take],
                                            ins_vecs[pos:pos + take])
            self.locator.update(
                (int(g), (_DELTA, int(s)))
                for g, s in zip(ins_gids[pos:pos + take], slots))
            pos += take
        if self.memtable.full:
            self.seal()
        return gids.astype(np.int32)

    def check_upsert(self, gids) -> int:
        """Validate an upsert's global ids *without mutating anything*;
        returns the post-insert ``next_gid``.  Shared by ``upsert`` and by
        write-ahead wrappers (durability/durable.py) that must know an op
        will be accepted before logging it."""
        gids = np.asarray(gids, np.int64).reshape(-1)
        if len(gids) == 0:
            return self.next_gid
        if gids.min() < 0:
            raise ValueError(f"gids must be non-negative, got {gids.min()}")
        new_next = max(self.next_gid, int(gids.max()) + 1)
        if new_next > self.id_capacity:
            raise ValueError(
                f"gid space exhausted ({new_next} > id_capacity="
                f"{self.id_capacity}); call grow_id_capacity() (one-time "
                f"recompile of the combine step) or build a larger index")
        return new_next

    def delete(self, gids) -> int:
        """Tombstone points by global id; returns how many existed."""
        return sum(self._tombstone(int(g)) for g in np.atleast_1d(gids))

    def _tombstone(self, gid: int) -> bool:
        loc = self.locator.pop(gid, None)
        if loc is None:
            return False
        where, pos = loc
        if where == _DELTA:
            self.memtable.kill(pos)
        else:
            self._segment(where).mark_dead(pos)
        return True

    def _segment(self, seg_id: int) -> Segment:
        for s in self.manifest.segments:
            if s.seg_id == seg_id:
                return s
        raise KeyError(seg_id)

    def seal(self) -> Optional[Segment]:
        """Freeze the delta into a sealed segment (frozen-breakpoint encode).

        All ``capacity`` slots seal — already-dead slots become tombstoned
        rows (compaction drops them) — so every sealed-from-delta segment
        has identical shapes and reuses the same compiled query kernels.
        """
        mt = self.memtable
        if mt.count == 0:
            return None
        seg = build_segment(mt.vecs, mt.gids, self.A, self.params,
                            self.bp_all, Nr=self.Nr,
                            leaf_size=self.leaf_size,
                            seg_id=self._next_seg_id, live=mt.live,
                            build_impl=self.build_impl,
                            build_chunk=self.build_chunk)
        self._next_seg_id += 1
        self.manifest.add(seg)
        for slot in range(mt.count):
            if mt.live[slot]:
                self.locator[int(mt.gids[slot])] = (seg.seg_id, slot)
        mt.reset()
        return seg

    flush = seal

    def compact(self) -> bool:
        """Merge all sealed segments into one, dropping tombstones (O(n)
        sorted-array merge on the host; see streaming/compactor.py)."""
        segs = self.manifest.segments
        if len(segs) <= 1 and not any(s.has_tombstones for s in segs):
            return False
        merged = merge_segments(segs, leaf_size=self.leaf_size,
                                seg_id=self._next_seg_id)
        self._next_seg_id += 1
        self.manifest.swap([s.seg_id for s in segs],
                           [merged] if merged is not None else [])
        if merged is not None:
            for row, gid in enumerate(merged.gids):
                self.locator[int(gid)] = (merged.seg_id, row)
        return True

    def grow_id_capacity(self, new_capacity: int) -> None:
        """Enlarge the global id space (the combine step's bitmap width and
        invalid-id sentinel).  Existing gids are untouched; the next query
        recompiles once for the new shapes."""
        if new_capacity < self.id_capacity:
            raise ValueError(f"cannot shrink id_capacity "
                             f"({new_capacity} < {self.id_capacity})")
        self.id_capacity = int(new_capacity)
        self._delta_cache = None          # gmap sentinel baked the old value

    def maybe_compact(self) -> bool:
        """The service's compaction trigger: compact when the fan-out width
        exceeds ``max_segments`` (in production this runs on a background
        thread; the swap itself is atomic either way)."""
        if len(self.manifest.segments) > self.max_segments:
            return self.compact()
        return False

    def requantile(self, key: jax.Array | None = None) -> None:
        """Full rebuild with fresh breakpoints over the surviving points —
        the escape hatch when ``clip_fraction()`` says the frozen
        quantization has drifted too far (docs/DESIGN.md §5)."""
        vecs, gids = self._survivors()
        if len(gids) == 0:
            raise ValueError("cannot requantile an empty index")
        data = jnp.asarray(vecs)
        proj = hashing.project(data, self.A)
        self.bp_all = enc.select_breakpoints(
            proj, self.Nr, key=key)
        base = build_segment(data, gids, self.A, self.params, self.bp_all,
                             Nr=self.Nr, leaf_size=self.leaf_size,
                             seg_id=self._next_seg_id, proj=proj,
                             build_impl=self.build_impl,
                             build_chunk=self.build_chunk)
        self._next_seg_id += 1
        self.manifest = Manifest()
        self.manifest.add(base)
        self.memtable.reset()
        self._delta_cache = None
        self.locator = {int(g): (base.seg_id, row)
                        for row, g in enumerate(base.gids)}

    def _survivors(self) -> tuple[np.ndarray, np.ndarray]:
        vecs = [np.asarray(s.data)[s.live] for s in self.manifest.segments]
        gids = [s.gids[s.live].astype(np.int64)
                for s in self.manifest.segments]
        mt = self.memtable
        if mt.n_live:
            vecs.append(mt.vecs[mt.live])
            gids.append(mt.gids[mt.live])
        if not vecs:
            return (np.zeros((0, self.A.shape[0]), np.float32),
                    np.zeros(0, np.int64))
        return np.concatenate(vecs), np.concatenate(gids)

    # ------------------------------------------------------------------
    # Query
    # ------------------------------------------------------------------

    def _delta_device(self):
        mt = self.memtable
        if self._delta_cache is None or self._delta_cache[0] != mt.version:
            gmap = np.where(mt.live, mt.gids,
                            self.id_capacity).astype(np.int32)
            # jnp.array copies: the memtable buffers mutate in place and the
            # CPU backend may otherwise alias them zero-copy.
            self._delta_cache = (mt.version,
                                 (jnp.array(mt.vecs), jnp.array(mt.live),
                                  jnp.asarray(gmap)))
        return self._delta_cache[1]

    # ------------------------------------------------------------------
    # Epoch views (docs/DESIGN.md §9)
    # ------------------------------------------------------------------

    def _current_view(self) -> PinnedView:
        """The view of the *current* structure — the ordinary query path
        (one code path: a plain ``search`` is a search on a just-pinned
        view, so epoch answers can never drift from live answers)."""
        mt = self.memtable
        return PinnedView(
            manifest_version=self.manifest.version,
            memtable_version=mt.version,
            id_capacity=self.id_capacity,
            segs=tuple(
                _SegView(seg, seg.live_dev(), seg.live_sorted_dev(),
                         seg.gid_map_dev(self.id_capacity), seg.live)
                for seg in self.manifest.segments if seg.n_live > 0),
            delta=self._delta_device() if mt.n_live > 0 else None,
            delta_n_live=mt.n_live, delta_capacity=mt.capacity,
            delta_host=None)

    def pin_state(self) -> PinnedView:
        """Pin the current epoch: an immutable view that keeps answering
        exactly as of now, across any later upsert/delete/seal/compact.

        Device arrays are pinned by reference (they never mutate — later
        deletes replace segment *caches*, old arrays survive through the
        view); host bitmaps and delta rows are pinned by copy, so the
        view's ``survivors()`` oracle stays frozen too."""
        cur = self._current_view()
        mt = self.memtable
        return dataclasses.replace(
            cur,
            segs=tuple(v._replace(live_host=v.live_host.copy())
                       for v in cur.segs),
            delta_host=((mt.vecs.copy(), mt.gids.copy(), mt.live.copy())
                        if mt.count > 0 else None))

    def _query_delta(self, view: PinnedView, queries: jax.Array, k: int,
                     n_active: Optional[jax.Array | int] = None):
        """Exact top-k over the delta rows (bounded, one stable shape).

        Direct (q - v)^2 differences, not the qq - 2qc + pp expansion: the
        delta is small enough that the O(B*cap*d) intermediate is cheap, and
        the direct form avoids the expansion's cancellation error (the delta
        is the 'exact' tier of the index — keep it exact).  Pad lanes
        (>= n_active) admit nothing, matching the segment engines."""
        vecs, live, gmap = view.delta
        diff = queries[:, None, :] - vecs[None, :, :]
        dist = jnp.sqrt(jnp.sum(diff * diff, axis=-1))
        dist = jnp.where(live[None, :], dist, jnp.inf)
        if n_active is not None:
            lane_ok = jnp.arange(queries.shape[0]) < jnp.asarray(n_active)
            dist = jnp.where(lane_ok[:, None], dist, jnp.inf)
        kk = min(k, view.delta_capacity)
        negd, sel = jax.lax.top_k(-dist, kk)
        # +inf slots (dead rows, masked pad lanes) must not leak their gid.
        ids = jnp.where(jnp.isfinite(negd), gmap[sel], view.id_capacity)
        return ids, -negd

    def _combine(self, sources: List[Tuple[jax.Array, jax.Array]],
                 k: int, B: int, nid: int):
        """Fold per-source (global ids, exact dists) top-k lists into the
        overall top-k via the incremental candidate merge.  ``nid`` is the
        view's pinned invalid-id sentinel / bitmap width."""
        cap = sum(int(ids.shape[1]) for ids, _ in sources)
        state = cand.CandidateState(
            ids=jnp.full((B, cap), nid, jnp.int32),
            dists=jnp.full((B, cap), jnp.inf, jnp.float32),
            seen=jnp.zeros((B, cand.bitmap_words(nid)), jnp.uint32),
            count=jnp.zeros((B,), jnp.int32))
        mr = jax.vmap(functools.partial(cand.merge_round, nid))
        for ids_s, d_s in sources:
            state = mr(state, ids_s.astype(jnp.int32), d_s)
        ids_c, d_c = jax.vmap(functools.partial(cand.canonicalize, nid))(
            state.ids, state.dists)
        if cap < k:
            ids_c = jnp.pad(ids_c, ((0, 0), (0, k - cap)),
                            constant_values=nid)
            d_c = jnp.pad(d_c, ((0, 0), (0, k - cap)),
                          constant_values=jnp.inf)
        return ids_c[:, :k], d_c[:, :k]

    def _rmin_entries(self) -> Dict[int, float]:
        """The per-k radius cache for the *current* structure version —
        the single place the (manifest, memtable) cache key lives.
        Resets the cache when the tag is stale."""
        tag = (self.manifest.version, self.memtable.version)
        if self._rmin_cache[0] != tag:
            self._rmin_cache = (tag, {})
        return self._rmin_cache[1]

    def _rmin_hit(self, k: int) -> bool:
        """Whether ``r_min_for(k)`` would be a cache hit right now."""
        return k in self._rmin_entries()

    def r_min_for(self, k: int, queries: jax.Array | None = None) -> float:
        """Cached per-(index, k) starting radius over the current structure.

        Estimated once per (index state, k) — on the first ``r_min=None``
        search, from that batch's queries (segment rows stand in as probes
        when no queries are given) — and keyed by (manifest, memtable)
        versions so structural mutations invalidate it.  Segment-internal
        tombstones don't bump a version — a slightly stale estimate only
        shifts the starting radius, never correctness (the guarantee holds
        for any r_min)."""
        cache = self._rmin_entries()
        if k not in cache:
            segs = [s for s in self.manifest.segments if s.n_live > 0]
            ref = (segs[0].data if segs else jnp.asarray(self.memtable.vecs))
            probes = (queries if queries is not None
                      else ref[: min(64, ref.shape[0])])
            cache[k] = estimate_r_min(ref, probes, k, self.params.c)
        return cache[k]

    def _fanout_query(self, queries: jax.Array, req, r_min: float,
                      view: PinnedView) -> QueryResult:
        """Batched c^2-k-ANN over a view's live point set (fan-out +
        combine).  Returned ids are *global* ids; invalid slots carry the
        view's ``id_capacity`` and +inf."""
        queries = jnp.asarray(queries, jnp.float32)
        B = queries.shape[0]
        k, n_active = req.k, req.n_active

        spec = self.spec
        block_q = spec.block_q if spec is not None else 8
        block_l = spec.block_l if spec is not None else 8
        probe_default = spec.probe_depth if spec is not None else 0
        sources, rounds, n_cands, final_r = [], [], [], []
        probed, pcand = [], []
        for sv in view.segs:
            seg = sv.seg
            cfg = req.to_query_config(k=min(k, seg.m), r_min=r_min,
                                      block_q=block_q, block_l=block_l,
                                      default_probe_depth=probe_default)
            fused = engine_registry.resolve_engine(
                cfg.engine, mode=cfg.mode, batch=B) == "fused"
            res = knn_query_batch(
                seg.data, seg.forest, self.A, self.params, queries, cfg,
                plan=seg.plan() if fused else None, live=sv.live_dev,
                live_sorted=sv.live_sorted_dev, n_active=n_active)
            sources.append((sv.gmap[res.ids], res.dists))
            rounds.append(res.rounds)
            n_cands.append(res.n_candidates)
            final_r.append(res.final_r)
            if res.probed_leaves is not None:
                probed.append(res.probed_leaves)
                pcand.append(res.probe_candidates)
        if view.delta is not None:
            ids_d, d_d = self._query_delta(view, queries, k, n_active)
            sources.append((ids_d, d_d))
            delta_cand = jnp.full((B,), view.delta_n_live, jnp.int32)
            if n_active is not None:
                delta_cand = jnp.where(jnp.arange(B) < jnp.asarray(n_active),
                                       delta_cand, 0)
            n_cands.append(delta_cand)

        if not sources:
            return QueryResult(
                ids=jnp.full((B, k), view.id_capacity, jnp.int32),
                dists=jnp.full((B, k), jnp.inf, jnp.float32),
                rounds=jnp.zeros((B,), jnp.int32),
                n_candidates=jnp.zeros((B,), jnp.int32),
                final_r=jnp.full((B,), r_min, jnp.float32),
                probed_leaves=jnp.zeros((B,), jnp.int32),
                probe_candidates=jnp.zeros((B,), jnp.int32))

        ids, dists = self._combine(sources, k, B, view.id_capacity)
        zero = jnp.zeros((B,), jnp.int32)
        return QueryResult(
            ids=ids, dists=dists,
            rounds=functools.reduce(jnp.maximum, rounds, zero),
            n_candidates=functools.reduce(jnp.add, n_cands, zero),
            final_r=functools.reduce(
                jnp.maximum, final_r, jnp.full((B,), r_min, jnp.float32)),
            probed_leaves=functools.reduce(jnp.add, probed, zero),
            probe_candidates=functools.reduce(jnp.add, pcand, zero))

    def _view_rmin(self, view: PinnedView, k: int,
                   probes: jax.Array) -> float:
        """Per-(view, k) starting-radius estimate — cached *on the view*
        (the index cache is keyed by current versions, which a pinned
        epoch must not consult after a mutation)."""
        if k not in view._rmin:
            if view.segs:
                ref = view.segs[0].seg.data
            elif view.delta is not None:
                ref = view.delta[0]
            else:
                view._rmin[k] = 1.0                    # empty view
                return 1.0
            probes = probes if probes is not None and len(probes) \
                else ref[: min(64, ref.shape[0])]
            view._rmin[k] = estimate_r_min(ref, probes, k, self.params.c)
        return view._rmin[k]

    def search(self, queries: jax.Array, request=None, *,
               view: Optional[PinnedView] = None):
        """Typed batched search over the live point set
        (``repro.api.SearchRequest`` in, ``repro.api.SearchResult`` out).
        Trace-compatible when the request carries an explicit ``r_min``.

        ``view`` pins the search to an epoch from ``pin_state()``: the
        answer is computed over the view's frozen structure regardless of
        any mutation since the pin (the serving runtime's RCU read path).
        """
        from repro.api.request import SearchRequest, SearchResult, \
            SearchStats
        req = request or SearchRequest()
        if req.engine is None and self.spec is not None:
            req = dataclasses.replace(req, engine=self.spec.engine)
        r_min, cached = req.r_min, False
        current = (view is None
                   or view.fingerprint == (self.manifest.version,
                                           self.memtable.version))
        if r_min is None:
            # Zero-vector pad lanes must not skew the cached estimate
            # (n_active == 0 keeps the full batch: no real lanes to probe).
            probes = queries[: req.n_active] if req.n_active else queries
            if current:
                cached = self._rmin_hit(req.k)        # hit vs first estimate
                r_min = self.r_min_for(req.k, probes)
                if view is not None:
                    view._rmin.setdefault(req.k, r_min)
            else:
                cached = req.k in view._rmin
                r_min = self._view_rmin(view, req.k, probes)
        res = self._fanout_query(queries, req, float(r_min),
                                 view if view is not None
                                 else self._current_view())
        engine = engine_registry.resolve_engine(
            req.engine, mode=req.mode, batch=jnp.asarray(queries).shape[0])
        return SearchResult(
            ids=res.ids, dists=res.dists,
            stats=SearchStats(engine=engine, r_min=float(r_min),
                              r_min_cached=cached, rounds=res.rounds,
                              n_candidates=res.n_candidates,
                              final_r=res.final_r,
                              probed_leaves=res.probed_leaves,
                              probe_candidates=res.probe_candidates),
            raw=res)

    def query(self, queries: jax.Array, k: int = 10, *,
              r_min: float | None = None, M: int = 8, mode: str = "leaf",
              max_rounds: int = 48, engine: str = "auto",
              n_active: int | None = None) -> QueryResult:
        """Deprecated kwarg surface — use ``search(queries,
        repro.api.SearchRequest(...))``.  Kept as a thin shim for the
        seed-era callers; returns the engine-level ``QueryResult``."""
        warnings.warn(
            "StreamingDETLSH.query(**kwargs) is deprecated; use "
            "StreamingDETLSH.search(queries, repro.api.SearchRequest(...))",
            DeprecationWarning, stacklevel=2)
        from repro.api.request import SearchRequest
        req = SearchRequest(k=k, r_min=r_min, M=M, mode=mode,
                            max_rounds=max_rounds, engine=engine,
                            n_active=n_active)
        return self.search(queries, req).raw

    def save(self, path) -> None:
        """Write a versioned snapshot directory (``repro.api.load``):
        segments (rows, gids, tombstones, forests), memtable survivors,
        frozen breakpoints, and the manifest."""
        from repro.api import persist
        persist.save_streaming(self, path)

    def warmup_query_caches(self) -> None:
        """Eagerly materialize per-segment device caches (fused plans,
        tombstone masks, gid maps) and the delta snapshot.  Call after
        mutations and before jitting ``query`` so the trace captures
        concrete arrays rather than re-staging them as constants."""
        for seg in self.manifest.segments:
            seg.warm_caches(self.id_capacity)
        self._delta_device()

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------

    @property
    def n_live(self) -> int:
        return self.manifest.n_live + self.memtable.n_live

    @property
    def n_points(self) -> int:
        """AnnIndex protocol: the live point count."""
        return self.n_live

    @property
    def n_total(self) -> int:
        return self.manifest.n_rows + self.memtable.count

    def clip_fraction(self) -> float:
        """Rows-weighted breakpoint-drift signal over sealed segments
        (coords of sealed inserts outside the frozen outer edges)."""
        total = sum(s.m for s in self.manifest.segments)
        if total == 0:
            return 0.0
        return sum(s.clip_fraction * s.m
                   for s in self.manifest.segments) / total

    def index_size_bytes(self) -> int:
        return (sum(s.forest.size_bytes() for s in self.manifest.segments)
                + self.A.size * 4)

    def state_digest(self) -> str:
        """sha256 fingerprint of the complete *logical* state: every array
        and counter that determines answers or future mutations (segments
        with their tombstone bitmaps and forests, memtable buffers, id
        allocation, frozen breakpoints).  Caches and version counters are
        excluded — they are performance state.  Equal digests mean two
        indexes are bit-identical; this is the recovered ≡ pre-crash
        oracle in tests/test_durability*.py (docs/DESIGN.md §13)."""
        h = hashlib.sha256()

        def put(a, dtype=None):
            x = np.asarray(a)
            if dtype is not None:
                x = x.astype(dtype)
            h.update(np.ascontiguousarray(x).tobytes())

        for v in (self.next_gid, self._next_seg_id, self.id_capacity,
                  self.Nr, self.leaf_size, self.memtable.count):
            h.update(int(v).to_bytes(8, "little", signed=True))
        put(self.A, np.float32)
        put(self.bp_all, np.float32)
        for seg in sorted(self.manifest.segments, key=lambda s: s.seg_id):
            h.update(int(seg.seg_id).to_bytes(8, "little", signed=True))
            h.update(np.float64(seg.clip_fraction).tobytes())
            put(seg.data, np.float32)
            put(seg.gids, np.int64)
            put(seg.live, np.uint8)
            for name in ("point_ids", "proj_sorted", "codes_sorted",
                         "valid", "leaf_lo", "leaf_hi", "leaf_valid",
                         "breakpoints"):
                put(getattr(seg.forest, name))
        mt = self.memtable
        put(mt.vecs, np.float32)
        put(mt.gids, np.int64)
        put(mt.live, np.uint8)
        return h.hexdigest()

    def stats(self) -> dict:
        return {
            "n_live": self.n_live, "n_total": self.n_total,
            "delta_rows": self.memtable.count,
            "delta_live": self.memtable.n_live,
            "clip_fraction": round(self.clip_fraction(), 6),
            "manifest": self.manifest.describe(),
        }
