"""Shared benchmark utilities: datasets, timing, ground truth, CSV."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

# Reduced-scale stand-ins for the paper's datasets (Table II), preserving
# their character: audio features (correlated gaussians), image descriptors
# (clustered, non-negative), text embeddings (heavy-tailed).
DATASETS = {
    "msong-like": dict(kind="corr", d=64),
    "deep-like": dict(kind="clustered", d=96),
    "sift-like": dict(kind="sift", d=128),
    "turing-like": dict(kind="heavy", d=100),
}


def make_dataset(name: str, n: int, seed: int = 0) -> np.ndarray:
    spec = DATASETS[name]
    d = spec["d"]
    rng = np.random.default_rng(seed)
    if spec["kind"] == "corr":
        base = rng.standard_normal((n, d // 4)).astype(np.float32)
        mix = rng.standard_normal((d // 4, d)).astype(np.float32) * 0.6
        return base @ mix + 0.3 * rng.standard_normal((n, d)).astype(
            np.float32)
    if spec["kind"] == "clustered":
        nc = 64
        centers = rng.standard_normal((nc, d)).astype(np.float32)
        a = rng.integers(0, nc, n)
        return centers[a] + 0.2 * rng.standard_normal((n, d)).astype(
            np.float32)
    if spec["kind"] == "sift":
        nc = 128
        centers = np.abs(rng.standard_normal((nc, d))).astype(np.float32)
        a = rng.integers(0, nc, n)
        return np.abs(centers[a] + 0.25 * rng.standard_normal((n, d))
                      ).astype(np.float32)
    if spec["kind"] == "heavy":
        return rng.standard_t(4, size=(n, d)).astype(np.float32)
    raise ValueError(name)


def make_queries(data: np.ndarray, nq: int, seed: int = 1) -> np.ndarray:
    """Paper §VI-A: queries are data points (we perturb slightly instead of
    removing, which only makes recall@k harder)."""
    rng = np.random.default_rng(seed)
    sel = rng.choice(len(data), nq, replace=False)
    return (data[sel] + 0.05 * rng.standard_normal(
        (nq, data.shape[1]))).astype(np.float32)


def ground_truth(data, queries, k):
    d2 = ((queries[:, None, :] - data[None, :, :]) ** 2).sum(-1)
    idx = np.argsort(d2, axis=1)[:, :k]
    return idx, np.sqrt(np.take_along_axis(d2, idx, axis=1))


def recall(ids, gt_i):
    ids = np.asarray(ids)
    k = gt_i.shape[1]
    return float(np.mean([len(set(ids[i][:k]) & set(gt_i[i])) / k
                          for i in range(len(gt_i))]))


def overall_ratio(dists, gt_d):
    d = np.asarray(dists)
    return float(np.mean(np.minimum(d / np.maximum(gt_d, 1e-9), 1e3)))


def timed(fn, *args, repeat: int = 1, **kw):
    """(result, seconds) with block_until_ready on jax outputs."""
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / repeat


def timed_once(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    return out, time.perf_counter() - t0


class Table:
    """Collects rows, prints the run.py CSV contract, writes a csv file."""

    def __init__(self, name: str, header: list[str]):
        self.name = name
        self.header = header
        self.rows: list[list] = []

    def add(self, *row):
        self.rows.append(list(row))

    def emit(self, out_dir: str | None = None):
        lines = [",".join(str(x) for x in self.header)]
        for r in self.rows:
            lines.append(",".join(
                f"{x:.6g}" if isinstance(x, float) else str(x) for x in r))
        if out_dir:
            import os
            os.makedirs(out_dir, exist_ok=True)
            with open(f"{out_dir}/{self.name}.csv", "w") as f:
                f.write("\n".join(lines) + "\n")
        return lines
