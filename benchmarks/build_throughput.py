"""Indexing-time benchmark: static build vs streaming ingest (BENCH_build.json).

The paper's headline claim is indexing speed (up to 6x DET / 40x PDET over
SOTA).  This benchmark times the whole indexing phase and the fused
single-sort build pipeline against the seed path (docs/DESIGN.md §8):

  * static one-shot build — cold (trace + compile) and warm (steady-state
    rebuild, the paper's regime) for BOTH builders: ``build_impl='auto'``
    (fused: one-pass encode+key-pack kernel, one sort per forest) vs
    ``build_impl='reference'`` (the seed per-tree double-argsort path).
    The warm new/old ratio is the CI speedup gate (ratios, not absolute
    times, so shared runners don't flake).
  * per-phase breakdown of the fused warm build: project / encode+pack /
    sort / gather+leaf-summary (each phase jitted and timed separately
    over the same arrays).
  * streaming ingest of the *same* points (base build on half, the rest
    upserted through the delta with seals, plus the final compaction) for
    both builders — the seal path is where the fused one-pass kernel pays.
  * query-QPS parity: batched fused queries, streaming vs static, gate
    >= 0.75x at batch 32.

  PYTHONPATH=src python -m benchmarks.run --only build_throughput
  PYTHONPATH=src python -m benchmarks.run --smoke       # small + JSON only
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, make_dataset, make_queries, timed, \
    timed_once

DEFAULT = dict(n=16384, dataset="deep-like", K=4, L=8, c=1.5, beta=0.1,
               leaf_size=64, delta_capacity=2048, batch=32, k=10, repeat=3)
# repeat=5: the QPS-parity and build-speedup ratios are hard CI gates, and
# single-shot timings on shared runners flake; five repeats average out
# scheduler noise for pennies (each call is ~10 ms).
SMOKE = dict(n=4096, dataset="deep-like", K=4, L=8, c=1.5, beta=0.1,
             leaf_size=64, delta_capacity=1024, batch=32, k=10, repeat=5)


def _phase_breakdown(data_dev, A, cfg, repeat):
    """Fused warm-build per-phase seconds: project / encode+pack / sort /
    gather+leaf-summary, each stage jitted separately over the same
    arrays (the production build runs them fused in ONE jitted call —
    this is the diagnostic split, so the sum slightly exceeds the fused
    wall-clock)."""
    from repro.core import detree, hashing
    from repro.core import encoding as enc
    K, L, ls = cfg["K"], cfg["L"], cfg["leaf_size"]

    project = jax.jit(lambda x: hashing.project(x, A))
    proj, sec_project = timed(project, data_dev, repeat=repeat)
    # Same Nr as the gated build (DETLSH.build's default).
    bp_all = enc.select_breakpoints(proj, enc.DEFAULT_NR)

    def encode_pack(pr):
        from repro.kernels import ops as kops
        return kops.encode_pack(pr, bp_all, K=K, L=L)

    encode_pack = jax.jit(encode_pack)
    (proj_t, codes_t, key_hi, key_lo), sec_encode = timed(
        encode_pack, proj, repeat=repeat)

    sort = jax.jit(lambda hi, lo: detree.code_sort_orders(hi, lo, K))
    order, sec_sort = timed(sort, key_hi, key_lo, repeat=repeat)

    assemble = jax.jit(lambda pt, ct, o: detree.assemble_sorted_forest(
        pt, ct, o, n=int(data_dev.shape[0]), leaf_size=ls))
    _, sec_assemble = timed(assemble, proj_t, codes_t, order, repeat=repeat)

    return {"project": sec_project, "encode_pack": sec_encode,
            "sort": sec_sort, "gather_leaf_summary": sec_assemble}


def run_build_throughput(cfg=None, json_path: str = "BENCH_build.json",
                         out_dir: str | None = "benchmarks/out") -> Table:
    from repro.api import SearchRequest
    from repro.core import DETLSH, derive_params, estimate_r_min, hashing
    from repro.streaming import StreamingDETLSH

    cfg = dict(DEFAULT, **(cfg or {}))
    n, dc = cfg["n"], cfg["delta_capacity"]
    assert (n // 2) % dc == 0, "delta_capacity must divide n/2"
    data = make_dataset(cfg["dataset"], n, seed=0)
    p = derive_params(K=cfg["K"], c=cfg["c"], L=cfg["L"],
                      beta_override=cfg["beta"])
    data_dev = jnp.asarray(data)

    def static_build(impl):
        idx = DETLSH.build(data_dev, jax.random.key(0), p,
                           leaf_size=cfg["leaf_size"], build_impl=impl)
        jax.block_until_ready(idx.forest.point_ids)
        return idx

    # Old (seed) path first, then the fused path — cold once, warm as the
    # *best of* `repeat` rebuilds (the warm ratio is a hard CI gate; means
    # absorb scheduler/GC outliers on shared runners, the minimum is the
    # standard noise-robust wall-clock estimator).
    def best_of(impl, repeat):
        best = float("inf")
        for _ in range(repeat):
            _, sec = timed_once(static_build, impl)
            best = min(best, sec)
        return best

    _, t_cold_old = timed_once(static_build, "reference")
    t_warm_old = best_of("reference", cfg["repeat"])
    sidx_static, t_cold = timed_once(static_build, "auto")
    t_warm = best_of("auto", cfg["repeat"])
    warm_speedup = t_warm_old / t_warm

    phases = _phase_breakdown(data_dev, sidx_static.A, cfg,
                              repeat=cfg["repeat"])

    # Streaming ingest of the same points: base on the first half, the
    # second half upserted in delta-sized chunks (sealing as it goes).
    def ingest(impl):
        idx = StreamingDETLSH.build(data_dev[:n // 2], jax.random.key(0), p,
                                    leaf_size=cfg["leaf_size"],
                                    delta_capacity=dc,
                                    max_segments=1 + n // (2 * dc),
                                    build_impl=impl)
        jax.block_until_ready(idx.manifest.segments[0].forest.point_ids)
        t0 = time.perf_counter()
        for start in range(n // 2, n, dc):
            idx.upsert(data[start:start + dc])
        for seg in idx.manifest.segments:
            jax.block_until_ready(seg.forest.point_ids)
        t_ing = time.perf_counter() - t0
        t0 = time.perf_counter()
        idx.compact()
        jax.block_until_ready(idx.manifest.segments[0].forest.point_ids)
        return idx, t_ing, time.perf_counter() - t0

    # One discarded warm-up ingest per impl, then best-of-`repeat` timed
    # runs, so the gated ratio compares steady state to steady state (the
    # first fused ingest pays seal-kernel compiles; the first reference
    # ingest pays eager op-cache fills) and a scheduler hiccup in a single
    # run can't skew it.
    def best_ingest(impl):
        ingest(impl)                                   # discarded warm-up
        best = (None, float("inf"), float("inf"))
        for _ in range(cfg["repeat"]):
            idx, t_ing, t_cmp = ingest(impl)
            if t_ing + t_cmp < best[1] + best[2]:
                best = (idx, t_ing, t_cmp)
        return best

    _, t_ingest_old, t_compact_old = best_ingest("reference")
    sidx, t_ingest, t_compact = best_ingest("auto")
    assert sidx.n_live == n, (sidx.n_live, n)
    stream_speedup = ((t_ingest_old + t_compact_old)
                      / (t_ingest + t_compact))

    # Query-QPS parity at equal live point count, batch `batch`, fused.
    # Best-of-`repeat` per-call wall-clock on both sides for the same
    # reason as the warm builds: the parity ratio is a hard CI gate and
    # the two measurement blocks run at different times — a scheduler
    # hiccup in either block skews a mean-based ratio both ways.
    b, k = cfg["batch"], cfg["k"]
    queries = jnp.asarray(make_queries(data, b, seed=1))
    r0 = estimate_r_min(data_dev, queries, k, p.c)
    req = SearchRequest(k=k, r_min=r0, engine="fused")
    sidx_static.fused_plan()         # materialize once, outside the timing
    sidx.warmup_query_caches()
    fn_static = jax.jit(lambda q: sidx_static.search(q, req).ids)
    fn_stream = jax.jit(lambda q: sidx.search(q, req).ids)

    def best_call(fn):
        best = float("inf")
        for _ in range(cfg["repeat"]):
            _, sec = timed(fn, queries, repeat=1)
            best = min(best, sec)
        return best

    sec_static = best_call(fn_static)
    sec_stream = best_call(fn_stream)
    qps_static = b / sec_static
    qps_stream = b / sec_stream
    ratio = qps_stream / qps_static

    table = Table("build_throughput", ["phase", "seconds", "points_per_sec"])
    rows = []
    for phase, sec, pts in (
            ("static_build_cold_old", t_cold_old, n),
            ("static_build_warm_old", t_warm_old, n),
            ("static_build_cold", t_cold, n),
            ("static_build_warm", t_warm, n),
            ("streaming_ingest_old", t_ingest_old, n // 2),
            ("compaction_old", t_compact_old, n),
            ("ingest_plus_compact_old", t_ingest_old + t_compact_old,
             n // 2),
            ("streaming_ingest", t_ingest, n // 2),
            ("compaction", t_compact, n),
            ("ingest_plus_compact", t_ingest + t_compact, n // 2)):
        pps = pts / sec
        table.add(phase, sec, pps)
        rows.append(dict(phase=phase, seconds=sec, points_per_sec=pps))
    for phase, sec in phases.items():
        table.add("phase_" + phase, sec, n / sec)
        rows.append(dict(phase="phase_" + phase, seconds=sec,
                         points_per_sec=n / sec))
    table.add("warm_build_speedup_new_over_old", float("nan"), warm_speedup)
    table.add("ingest_compact_speedup_new_over_old", float("nan"),
              stream_speedup)
    table.add("query_qps_static_b%d" % b, sec_static, qps_static)
    table.add("query_qps_stream_b%d" % b, sec_stream, qps_stream)
    table.add("qps_ratio_stream_over_static", float("nan"), ratio)
    rows += [dict(phase="query_qps_static", seconds=sec_static,
                  qps=qps_static),
             dict(phase="query_qps_stream", seconds=sec_stream,
                  qps=qps_stream)]

    payload = dict(
        bench="build_throughput",
        workload={kk: v for kk, v in cfg.items()},
        backend=jax.default_backend(),
        rows=rows,
        static_build_warm_pps=n / t_warm,
        streaming_ingest_pps=(n // 2) / (t_ingest + t_compact),
        build_phases=phases,
        # Old-vs-new build-pipeline speedups: the CI gate asserts these
        # ratios stay >= 1.0 (ratios, not absolute times — runner-noise
        # proof); the PR-5 acceptance targets were 1.5x warm static and
        # 2x ingest+compact.
        build_speedup={
            "static_warm_new_over_old": warm_speedup,
            "ingest_compact_new_over_old": stream_speedup,
        },
        query_qps={"static": qps_static, "stream": qps_stream,
                   "ratio_stream_over_static": ratio},
        segments_after_compact=len(sidx.manifest.segments),
    )
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    if out_dir:
        table.emit(out_dir)
    return table


def build_throughput() -> Table:
    """run.py figure entry point (full size)."""
    return run_build_throughput()


def build_throughput_smoke() -> Table:
    """CI smoke: small index, still writes BENCH_build.json."""
    return run_build_throughput(SMOKE)
