"""Indexing-time benchmark: static build vs streaming ingest (BENCH_build.json).

The paper's headline claim is indexing speed, but only the query-phase
trajectory (BENCH_query.json) was recorded.  This benchmark times

  * the static one-shot build (``DETLSH.build``) — cold (includes trace +
    compile) and warm (steady-state rebuild, the paper's regime);
  * streaming ingest of the *same* points: base build on half the data,
    the other half upserted through the delta buffer (seals included),
    plus the final compaction — i.e. the full cost of arriving at the same
    live point set incrementally;
  * query-QPS parity: batched fused queries against the compacted
    streaming index vs a static index over the identical live point set.
    The acceptance gate is streaming QPS >= 0.75x static at batch 32.

  PYTHONPATH=src python -m benchmarks.run --only build_throughput
  PYTHONPATH=src python -m benchmarks.run --smoke       # small + JSON only
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, make_dataset, make_queries, timed, \
    timed_once

DEFAULT = dict(n=16384, dataset="deep-like", K=4, L=8, c=1.5, beta=0.1,
               leaf_size=64, delta_capacity=2048, batch=32, k=10, repeat=3)
# repeat=5: the QPS-parity ratio is a hard CI gate, and single-shot timings
# on shared runners flake; five repeats average out scheduler noise for
# pennies (each call is ~10 ms).
SMOKE = dict(n=4096, dataset="deep-like", K=4, L=8, c=1.5, beta=0.1,
             leaf_size=64, delta_capacity=1024, batch=32, k=10, repeat=5)


def run_build_throughput(cfg=None, json_path: str = "BENCH_build.json",
                         out_dir: str | None = "benchmarks/out") -> Table:
    from repro.api import SearchRequest
    from repro.core import DETLSH, derive_params, estimate_r_min
    from repro.streaming import StreamingDETLSH

    cfg = dict(DEFAULT, **(cfg or {}))
    n, dc = cfg["n"], cfg["delta_capacity"]
    assert (n // 2) % dc == 0, "delta_capacity must divide n/2"
    data = make_dataset(cfg["dataset"], n, seed=0)
    p = derive_params(K=cfg["K"], c=cfg["c"], L=cfg["L"],
                      beta_override=cfg["beta"])
    data_dev = jnp.asarray(data)

    def static_build():
        idx = DETLSH.build(data_dev, jax.random.key(0), p,
                           leaf_size=cfg["leaf_size"])
        jax.block_until_ready(idx.forest.point_ids)
        return idx

    sidx_static, t_cold = timed_once(static_build)
    _, t_warm = timed_once(static_build)

    # Streaming ingest of the same points: base on the first half, the
    # second half upserted in delta-sized chunks (sealing as it goes).
    def ingest():
        idx = StreamingDETLSH.build(data_dev[:n // 2], jax.random.key(0), p,
                                    leaf_size=cfg["leaf_size"],
                                    delta_capacity=dc,
                                    max_segments=1 + n // (2 * dc))
        jax.block_until_ready(idx.manifest.segments[0].forest.point_ids)
        t0 = time.perf_counter()
        for start in range(n // 2, n, dc):
            idx.upsert(data[start:start + dc])
        for seg in idx.manifest.segments:
            jax.block_until_ready(seg.forest.point_ids)
        t_ing = time.perf_counter() - t0
        t0 = time.perf_counter()
        idx.compact()
        jax.block_until_ready(idx.manifest.segments[0].forest.point_ids)
        return idx, t_ing, time.perf_counter() - t0

    sidx, t_ingest, t_compact = ingest()
    assert sidx.n_live == n, (sidx.n_live, n)

    # Query-QPS parity at equal live point count, batch `batch`, fused.
    b, k = cfg["batch"], cfg["k"]
    queries = jnp.asarray(make_queries(data, b, seed=1))
    r0 = estimate_r_min(data_dev, queries, k, p.c)
    req = SearchRequest(k=k, r_min=r0, engine="fused")
    sidx_static.fused_plan()         # materialize once, outside the timing
    sidx.warmup_query_caches()
    fn_static = jax.jit(lambda q: sidx_static.search(q, req).ids)
    fn_stream = jax.jit(lambda q: sidx.search(q, req).ids)
    _, sec_static = timed(fn_static, queries, repeat=cfg["repeat"])
    _, sec_stream = timed(fn_stream, queries, repeat=cfg["repeat"])
    qps_static = b / sec_static
    qps_stream = b / sec_stream
    ratio = qps_stream / qps_static

    table = Table("build_throughput", ["phase", "seconds", "points_per_sec"])
    rows = []
    for phase, sec, pts in (
            ("static_build_cold", t_cold, n),
            ("static_build_warm", t_warm, n),
            ("streaming_ingest", t_ingest, n // 2),
            ("compaction", t_compact, n),
            ("ingest_plus_compact", t_ingest + t_compact, n // 2)):
        pps = pts / sec
        table.add(phase, sec, pps)
        rows.append(dict(phase=phase, seconds=sec, points_per_sec=pps))
    table.add("query_qps_static_b%d" % b, sec_static, qps_static)
    table.add("query_qps_stream_b%d" % b, sec_stream, qps_stream)
    table.add("qps_ratio_stream_over_static", float("nan"), ratio)
    rows += [dict(phase="query_qps_static", seconds=sec_static,
                  qps=qps_static),
             dict(phase="query_qps_stream", seconds=sec_stream,
                  qps=qps_stream)]

    payload = dict(
        bench="build_throughput",
        workload={kk: v for kk, v in cfg.items()},
        backend=jax.default_backend(),
        rows=rows,
        static_build_warm_pps=n / t_warm,
        streaming_ingest_pps=(n // 2) / (t_ingest + t_compact),
        query_qps={"static": qps_static, "stream": qps_stream,
                   "ratio_stream_over_static": ratio},
        segments_after_compact=len(sidx.manifest.segments),
    )
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    if out_dir:
        table.emit(out_dir)
    return table


def build_throughput() -> Table:
    """run.py figure entry point (full size)."""
    return run_build_throughput()


def build_throughput_smoke() -> Table:
    """CI smoke: small index, still writes BENCH_build.json."""
    return run_build_throughput(SMOKE)
