"""Query-phase throughput: fused batched engine vs the seed vmap baseline.

Measures steady-state batched c^2-k-ANN throughput (queries/second, post
warm-up) for both engines over a sweep of batch sizes, and records the
trajectory in BENCH_query.json at the repo root (plus the usual CSV under
benchmarks/out/).  The acceptance gate for the fused engine is >= 2x the
vmap baseline at batch >= 32 on the default synthetic workload.

  PYTHONPATH=src python -m benchmarks.run --only query_throughput
  PYTHONPATH=src python -m benchmarks.run --smoke       # small + JSON only
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (Table, ground_truth, make_dataset,
                               make_queries, recall, timed)

# Default workload: clustered "deep-like" vectors (d=96), index sized so the
# candidate buffer (beta*n + k + round) dominates the vmap engine's per-round
# sort — the regime the paper's query-phase numbers live in.
DEFAULT = dict(n=8192, dataset="deep-like", K=4, L=8, c=1.5, beta=0.1,
               leaf_size=64, k=10, batches=(1, 8, 32, 64), repeat=3)
SMOKE = dict(n=4096, dataset="deep-like", K=4, L=8, c=1.5, beta=0.1,
             leaf_size=64, k=10, batches=(32,), repeat=1)


def _build(cfg):
    from repro.core import DETLSH, derive_params, estimate_r_min
    data = make_dataset(cfg["dataset"], cfg["n"], seed=0)
    queries = make_queries(data, max(cfg["batches"]), seed=1)
    p = derive_params(K=cfg["K"], c=cfg["c"], L=cfg["L"],
                      beta_override=cfg["beta"])
    idx = DETLSH.build(jnp.asarray(data), jax.random.key(0), p,
                       leaf_size=cfg["leaf_size"])
    r0 = estimate_r_min(idx.data, jnp.asarray(queries), cfg["k"], p.c)
    return idx, data, queries, r0


def run_query_throughput(cfg=None, json_path: str = "BENCH_query.json",
                         out_dir: str | None = "benchmarks/out") -> Table:
    from repro.api import SearchRequest
    cfg = dict(DEFAULT, **(cfg or {}))
    idx, data, queries, r0 = _build(cfg)
    gt_i, _ = ground_truth(data, queries, cfg["k"])
    idx.fused_plan()                 # materialize once, outside the timing

    table = Table("query_throughput",
                  ["batch", "engine", "ms_per_batch", "qps", "recall"])
    rows = []
    for b in cfg["batches"]:
        qb = jnp.asarray(queries[:b])
        per_engine = {}
        for engine in ("vmap", "fused"):
            req = SearchRequest(k=cfg["k"], M=8, r_min=r0, engine=engine)
            fn = jax.jit(lambda q, r=req: idx.search(q, r).raw)
            res, sec = timed(fn, qb, repeat=cfg["repeat"])
            rec = recall(np.asarray(res.ids), gt_i[:b])
            qps = b / sec
            per_engine[engine] = qps
            table.add(b, engine, sec * 1e3, qps, rec)
            rows.append(dict(batch=b, engine=engine, ms_per_batch=sec * 1e3,
                             qps=qps, recall=rec))
        speedup = per_engine["fused"] / per_engine["vmap"]
        table.add(b, "speedup", float("nan"), speedup, float("nan"))
        rows.append(dict(batch=b, engine="speedup", qps=speedup))

    payload = dict(
        bench="query_throughput",
        workload={k: v for k, v in cfg.items() if k != "batches"},
        batches=list(cfg["batches"]),
        backend=jax.default_backend(),
        rows=rows,
        speedup_fused_over_vmap={
            str(b): next(r["qps"] for r in rows
                         if r["batch"] == b and r["engine"] == "speedup")
            for b in cfg["batches"]},
    )
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    if out_dir:
        table.emit(out_dir)
    return table


def query_throughput() -> Table:
    """run.py figure entry point (full sweep)."""
    return run_query_throughput()


def query_throughput_smoke() -> Table:
    """CI smoke: one batch size, small index, still writes BENCH_query.json."""
    return run_query_throughput(SMOKE)
