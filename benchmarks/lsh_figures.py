"""Paper figure benchmarks (DET-LSH / PDET-LSH core).

One ``fig*`` function per paper table/figure; each returns a
``common.Table``.  Scales are reduced to container limits; the *structure*
of each experiment matches the paper's.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import SearchRequest
from repro.core import DETLSH, derive_params, estimate_r_min
from repro.core import encoding as enc
from repro.core.query import QueryConfig, knn_query_batch
from repro.core.theory import beta_of_L
from repro.baselines import HNSW, IVFPQ, BruteForce, C2LSH, E2LSH, PMLSH

from benchmarks.common import (Table, ground_truth, make_dataset,
                               make_queries, overall_ratio, recall, timed,
                               timed_once)

DEFAULT_N = 40000
DEFAULT_NQ = 32
K_ANN = 20


def _setup(name="deep-like", n=DEFAULT_N, nq=DEFAULT_NQ, k=K_ANN, seed=0):
    data = make_dataset(name, n, seed)
    queries = make_queries(data, nq)
    gt_i, gt_d = ground_truth(data, queries, k)
    return jnp.asarray(data), jnp.asarray(queries), gt_i, gt_d


def _build(data, K=4, L=16, beta=0.1, leaf_size=64, method="sample_sort"):
    p = derive_params(K=K, c=1.5, L=L, beta_override=beta)
    return DETLSH.build(data, jax.random.key(0), p, leaf_size=leaf_size,
                        breakpoint_method=method)


# --------------------------------------------------------------------- Fig 2
def fig02_breakpoints() -> Table:
    """Breakpoint selection: full sort vs sample-sort vs histogram-refine
    (paper: QuickSelect+d&c gives 3x over full sorting)."""
    t = Table("fig02_breakpoints", ["method", "n", "D", "seconds",
                                    "speedup_vs_full_sort"])
    data = make_dataset("deep-like", 60000)
    proj = jnp.asarray(np.random.default_rng(0).standard_normal(
        (60000, 64)).astype(np.float32))
    base = None
    for method in ("full_sort", "sample_sort", "histogram_refine"):
        fn = jax.jit(lambda x, m=method: enc.select_breakpoints(
            x, 256, method=m))
        _, sec = timed(fn, proj, repeat=3)
        if base is None:
            base = sec
        t.add(method, proj.shape[0], proj.shape[1], sec, base / sec)
    return t


# --------------------------------------------------------------------- Fig 6
def fig06_beta_L() -> Table:
    t = Table("fig06_beta_L", ["L", "beta_theory"])
    for L, b in zip(range(1, 13), beta_of_L(16, 1.5, np.arange(1, 13))):
        t.add(L, float(b))
    return t


# --------------------------------------------------------------------- Fig 7
def fig07_index_breakdown() -> Table:
    """Encoding vs indexing time breakdown per dataset."""
    t = Table("fig07_index_breakdown",
              ["dataset", "n", "hash_s", "breakpoints_s", "encode_s",
               "build_s", "total_s"])
    from repro.core import hashing
    p = derive_params(K=4, c=1.5, L=16, beta_override=0.1)
    for name in ("msong-like", "deep-like", "sift-like"):
        data = jnp.asarray(make_dataset(name, DEFAULT_N))
        A = hashing.sample_projections(jax.random.key(0), data.shape[1],
                                       p.K, p.L)
        proj, t_hash = timed(jax.jit(lambda d: hashing.project(d, A)), data,
                             repeat=2)
        bp, t_bp = timed(jax.jit(lambda pr: enc.select_breakpoints(
            pr, 256, method="sample_sort")), proj, repeat=2)
        codes, t_enc = timed(jax.jit(lambda pr: enc.encode(pr, bp)), proj,
                             repeat=2)
        from repro.core.detree import build_forest
        _, t_build = timed(jax.jit(lambda pr: build_forest(
            pr, p.K, p.L, leaf_size=64, breakpoint_method="sample_sort")),
            proj, repeat=1)
        t.add(name, data.shape[0], t_hash, t_bp, t_enc, t_build,
              t_hash + t_bp + t_enc + t_build)
    return t


# --------------------------------------------------------------------- Fig 8
def fig08_query_opt() -> Table:
    """Optimized (leaf-granularity) vs unoptimized (strict) query."""
    t = Table("fig08_query_opt", ["mode", "query_s_per_q", "recall",
                                  "ratio"])
    data, queries, gt_i, gt_d = _setup()
    idx = _build(data)
    r0 = estimate_r_min(idx.data, queries, K_ANN, idx.params.c)
    for mode in ("strict", "leaf"):
        cfg = QueryConfig(k=K_ANN, M=12, r_min=r0, mode=mode)
        fn = jax.jit(lambda q: knn_query_batch(idx.data, idx.forest, idx.A,
                                               idx.params, q, cfg))
        res, sec = timed(fn, queries, repeat=2)
        t.add(mode, sec / len(queries), recall(res.ids, gt_i),
              overall_ratio(res.dists, gt_d))
    return t


# ---------------------------------------------------------------- Fig 13/14
def fig13_vary_L() -> Table:
    t = Table("fig13_vary_L", ["L", "K", "index_s", "index_MB",
                               "query_s_per_q", "recall", "ratio"])
    data, queries, gt_i, gt_d = _setup()
    for L in (4, 8, 16, 32):
        _vary_row(t, data, queries, gt_i, gt_d, K=4, L=L)
    return t


def fig14_vary_K() -> Table:
    t = Table("fig14_vary_K", ["L", "K", "index_s", "index_MB",
                               "query_s_per_q", "recall", "ratio"])
    data, queries, gt_i, gt_d = _setup()
    for K in (2, 4, 8, 16):
        _vary_row(t, data, queries, gt_i, gt_d, K=K, L=16)
    return t


def _vary_row(t, data, queries, gt_i, gt_d, K, L):
    idx, bsec = timed_once(_build, data, K=K, L=L)
    r0 = estimate_r_min(idx.data, queries, K_ANN, idx.params.c)
    cfg = QueryConfig(k=K_ANN, M=12, r_min=r0)
    fn = jax.jit(lambda q: knn_query_batch(idx.data, idx.forest, idx.A,
                                           idx.params, q, cfg))
    res, qsec = timed(fn, queries, repeat=2)
    t.add(L, K, bsec, idx.index_size_bytes() / 1e6, qsec / len(queries),
          recall(res.ids, gt_i), overall_ratio(res.dists, gt_d))


# ---------------------------------------------------------------- Fig 16/17
def _all_methods(data, k):
    key = jax.random.key(0)
    yield "det-lsh", lambda: _build(data), \
        lambda idx, q: idx.search(q, SearchRequest(k=k, M=12))
    yield "e2lsh(BC)", lambda: E2LSH.build(data, key, K=6, L=8, w=4.0), \
        lambda idx, q: idx.query(q, k)
    yield "c2lsh(C2)", lambda: C2LSH.build(data, key, m=24, w=2.0), \
        lambda idx, q: idx.query(q, k)
    yield "pm-lsh(DM)", lambda: PMLSH.build(data, key, K=15, beta=0.1), \
        lambda idx, q: idx.query(q, k)
    yield "hnsw", lambda: HNSW.build(np.asarray(data), M=12,
                                     ef_construction=48), \
        lambda idx, q: idx.query(np.asarray(q), k, ef_search=96)
    yield "ivf-pq", lambda: IVFPQ.build(data, key, nlist=64, M=4,
                                        nprobe=8), \
        lambda idx, q: idx.query(q, k)


def fig16_17_indexing() -> Table:
    """Index size (Fig 16) + indexing time (Fig 17) + query quality."""
    t = Table("fig16_17_indexing",
              ["method", "n", "index_s", "index_MB", "query_s_per_q",
               "recall", "ratio"])
    data, queries, gt_i, gt_d = _setup()
    for name, build, query in _all_methods(data, K_ANN):
        idx, bsec = timed_once(build)
        res, qsec = timed_once(query, idx, queries)
        if hasattr(res, "ids"):                    # DET-LSH QueryResult
            ids, dists = res.ids, res.dists
        else:
            ids, dists = res
        t.add(name, data.shape[0], bsec, idx.size_bytes() / 1e6
              if hasattr(idx, "size_bytes") else idx.index_size_bytes() / 1e6,
              qsec / len(queries), recall(ids, gt_i),
              overall_ratio(dists, gt_d))
    return t


# ---------------------------------------------------------------- Fig 18/19
def fig18_19_quality() -> Table:
    """Recall-time / ratio-time tradeoff curves (one knob per method)."""
    t = Table("fig18_19_quality",
              ["method", "knob", "query_s_per_q", "recall", "ratio"])
    data, queries, gt_i, gt_d = _setup()
    idx = _build(data)
    r0 = estimate_r_min(idx.data, queries, K_ANN, idx.params.c)
    for M in (2, 4, 8, 16, 32):
        cfg = QueryConfig(k=K_ANN, M=M, r_min=r0)
        fn = jax.jit(lambda q, c=cfg: knn_query_batch(
            idx.data, idx.forest, idx.A, idx.params, q, c))
        res, sec = timed(fn, queries, repeat=2)
        t.add("det-lsh", M, sec / len(queries), recall(res.ids, gt_i),
              overall_ratio(res.dists, gt_d))
    pm = PMLSH.build(data, jax.random.key(0), K=15, beta=0.02)
    for beta in (0.02, 0.05, 0.1, 0.2):
        pm.beta = beta
        (ids, d), sec = timed_once(pm.query, queries, K_ANN)
        t.add("pm-lsh(DM)", beta, sec / len(queries), recall(ids, gt_i),
              overall_ratio(d, gt_d))
    hn = HNSW.build(np.asarray(data), M=12, ef_construction=48)
    for ef in (16, 48, 128):
        (ids, d), sec = timed_once(hn.query, np.asarray(queries), K_ANN,
                                   ef_search=ef)
        t.add("hnsw", ef, sec / len(queries), recall(ids, gt_i),
              overall_ratio(d, gt_d))
    return t


# ------------------------------------------------------------------- Fig 20
def fig20_scalability() -> Table:
    """Indexing/query time vs cardinality n."""
    t = Table("fig20_scalability",
              ["n", "det_index_s", "det_query_s_per_q", "pm_index_s",
               "pm_query_s_per_q", "det_recall", "pm_recall"])
    for n in (10000, 20000, 40000, 80000):
        data = jnp.asarray(make_dataset("sift-like", n))
        queries = jnp.asarray(make_queries(np.asarray(data), 16))
        gt_i, gt_d = ground_truth(np.asarray(data), np.asarray(queries),
                                  K_ANN)
        det, det_b = timed_once(_build, data)
        res, det_q = timed_once(det.search, queries,
                                SearchRequest(k=K_ANN, M=12))
        pm, pm_b = timed_once(PMLSH.build, data, jax.random.key(0), 15, 0.1)
        (pids, pd), pm_q = timed_once(pm.query, queries, K_ANN)
        t.add(n, det_b, det_q / len(queries), pm_b, pm_q / len(queries),
              recall(res.ids, gt_i), recall(pids, gt_i))
    return t


# ------------------------------------------------------------------- Fig 21
def fig21_vary_k() -> Table:
    t = Table("fig21_vary_k", ["k", "recall", "ratio"])
    data, queries, _, _ = _setup()
    idx = _build(data)
    for k in (1, 10, 25, 50):
        gt_i, gt_d = ground_truth(np.asarray(data), np.asarray(queries), k)
        res = idx.search(queries, SearchRequest(k=k, M=12))
        t.add(k, recall(res.ids, gt_i), overall_ratio(res.dists, gt_d))
    return t


# ---------------------------------------------------------------- Fig 22/23
def fig22_23_cumulative() -> Table:
    """Cumulative cost = index time + q * per-query time: how many queries
    the LSH methods answer before graph/quantization methods finish
    building (the paper's rapid-deployment story)."""
    t = Table("fig22_23_cumulative",
              ["method", "index_s", "query_s_per_q",
               "queries_before_hnsw_ready", "queries_before_ivfpq_ready"])
    data, queries, gt_i, gt_d = _setup()
    rows = {}
    for name, build, query in _all_methods(data, K_ANN):
        idx, bsec = timed_once(build)
        _, qsec = timed_once(query, idx, queries)
        rows[name] = (bsec, qsec / len(queries))
    for name, (bsec, qper) in rows.items():
        ahead_h = max(0.0, rows["hnsw"][0] - bsec) / max(qper, 1e-9)
        ahead_q = max(0.0, rows["ivf-pq"][0] - bsec) / max(qper, 1e-9)
        t.add(name, bsec, qper, ahead_h, ahead_q)
    return t
