"""LSH-decode vs full-attention decode throughput smoke (CI gate).

Multi-step decode loop at long-context smoke shapes: the LSH path runs the
real ``repro.decode`` step (streaming upsert every step + batched fused
retrieval every ``refresh_every`` steps + sparse assembly) against a
``decode_gqa_attention`` full scan of the same cache.  Steps are sized to
stay inside one delta window (no reseal mid-timing) — reseal cost is a
build-throughput concern, measured there.

Writes BENCH_decode.json; run.py --smoke gates on it:

  * ratio_lsh_over_full >= 1.0 — at S >= 4096 sparse decode must at least
    match the dense scan on CPU (on TPU the gap widens: retrieval is one
    batched Pallas kernel, the dense scan reads the whole cache);
  * planted_recall >= 0.9 — retrieval must actually find planted
    strong-attention positions (speed via misses is not acceptable).

  PYTHONPATH=src python -m benchmarks.run --smoke
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table

# L=3/refresh_every=12 is the tuned CPU operating point: L=2 dips below
# the recall gate (0.88), L=4 pays ~2x retrieval for no recall headroom,
# and refresh_every=8 leaves the throughput ratio near 1.0x on CPU where
# the ref-path retrieval is memory-bound against a BLAS dense scan.
SMOKE = dict(b=1, S=8192, hk=2, g=2, dh=64, steps=32, warmup=4,
             refresh_every=12, window=64, sinks=4,
             K=4, L=3, m_top=64, delta_capacity=64, max_rounds=4,
             leaf_size=32, recall_trials=8, query_scale=4.0)


def _planted_recall(index, k_cache, cfg, rng) -> float:
    """Fraction of (head, lane, trial) retrievals that surface a planted
    strong-attention position."""
    b, hk, g, dh = cfg["b"], cfg["hk"], cfg["g"], cfg["dh"]
    n = index.n_sealed
    hits = []
    for _ in range(cfg["recall_trials"]):
        planted = int(rng.integers(0, n))
        q = np.repeat(np.asarray(k_cache[:, planted])[:, :, None, :], g, 2)
        q = jnp.asarray((q * cfg["query_scale"]).reshape(b, 1, hk * g, dh))
        res = index.retrieve(q)
        hits.append((np.asarray(res.ids) == planted).any(axis=-1).mean())
    return float(np.mean(hits))


def decode_throughput_smoke() -> Table:
    from repro.decode import KVCacheIndex, KVSpec, LSHDecoder
    from repro.models import layers as L

    cfg = SMOKE
    b, S, hk, g, dh = cfg["b"], cfg["S"], cfg["hk"], cfg["g"], cfg["dh"]
    h = hk * g
    steps, warmup = cfg["steps"], cfg["warmup"]
    prefill_len = S - steps - warmup
    rng = np.random.default_rng(0)
    k_cache = jnp.asarray(rng.standard_normal((b, S, hk, dh))
                          .astype(np.float32) * 0.3)
    v_cache = jnp.asarray(rng.standard_normal((b, S, hk, dh))
                          .astype(np.float32))
    q = jnp.asarray(rng.standard_normal((b, 1, h, dh)).astype(np.float32))

    spec = KVSpec(K=cfg["K"], L=cfg["L"], m_top=cfg["m_top"],
                  delta_capacity=cfg["delta_capacity"],
                  max_rounds=cfg["max_rounds"], leaf_size=cfg["leaf_size"])
    t0 = time.perf_counter()
    index = KVCacheIndex.prefill(k_cache[:, :prefill_len],
                                 jax.random.key(0), spec)
    jax.block_until_ready(index.forest.points_sorted)
    t_prefill = time.perf_counter() - t0

    decoder = LSHDecoder(index, window=cfg["window"], sinks=cfg["sinks"],
                         refresh_every=cfg["refresh_every"])

    full = jax.jit(lambda qq, kk, vv, ln: L.decode_gqa_attention(
        qq, kk, vv, ln))

    # warmup: compile retrieval, upsert-augment, sparse assembly, full path
    for t in range(warmup):
        ln = prefill_len + t + 1
        jax.block_until_ready(decoder.step(q, k_cache, v_cache,
                                           k_cache[:, ln - 1], ln))
        jax.block_until_ready(full(q, k_cache, v_cache, ln))

    base = prefill_len + warmup
    t0 = time.perf_counter()
    for t in range(steps):
        ln = base + t + 1
        jax.block_until_ready(decoder.step(q, k_cache, v_cache,
                                           k_cache[:, ln - 1], ln))
    t_lsh = (time.perf_counter() - t0) / steps

    t0 = time.perf_counter()
    for t in range(steps):
        jax.block_until_ready(full(q, k_cache, v_cache, base + t + 1))
    t_full = (time.perf_counter() - t0) / steps

    recall = _planted_recall(index, k_cache[:, :index.n_sealed], cfg, rng)

    ratio = t_full / max(t_lsh, 1e-12)
    out = {
        "S": S, "b": b, "hk": hk, "g": g, "dh": dh, "steps": steps,
        "refresh_every": cfg["refresh_every"],
        "spec": {k: cfg[k] for k in
                 ("K", "L", "m_top", "delta_capacity", "max_rounds",
                  "leaf_size", "window", "sinks")},
        "prefill_seconds": t_prefill,
        "us_full_per_step": t_full * 1e6,
        "us_lsh_per_step": t_lsh * 1e6,
        "ratio_lsh_over_full": ratio,
        "planted_recall": recall,
        "n_refreshes": decoder.n_refreshes,
        "scan_fraction": index.scan_fraction,
        "backend": jax.default_backend(),
    }
    with open("BENCH_decode.json", "w") as f:
        json.dump(out, f, indent=2)

    tab = Table("decode_throughput_smoke",
                ["path", "us_per_step", "tokens_per_s", "note"])
    tab.add(["full", f"{t_full * 1e6:.0f}", f"{1.0 / t_full:.1f}",
             f"S={S}"])
    tab.add(["lsh", f"{t_lsh * 1e6:.0f}", f"{1.0 / t_lsh:.1f}",
             f"ratio={ratio:.2f}x recall={recall:.2f} "
             f"refresh={cfg['refresh_every']}"])
    return tab
