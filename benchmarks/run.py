"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV summary lines plus the full tables,
and writes per-figure CSVs under benchmarks/out/.

  PYTHONPATH=src python -m benchmarks.run            # all LSH figures
  PYTHONPATH=src python -m benchmarks.run --fast     # skip slow subprocess
  PYTHONPATH=src python -m benchmarks.run --only fig08_query_opt
  PYTHONPATH=src python -m benchmarks.run --smoke    # CI: query + build
                                                     # throughput, writes
                                                     # BENCH_query.json and
                                                     # BENCH_build.json
"""

from __future__ import annotations

import argparse
import sys
import time


def _figures(fast: bool):
    from benchmarks import build_throughput as B
    from benchmarks import lsh_figures as F
    from benchmarks import query_throughput as Q
    figs = [
        Q.query_throughput,
        B.build_throughput,
        F.fig02_breakpoints,
        F.fig06_beta_L,
        F.fig07_index_breakdown,
        F.fig08_query_opt,
        F.fig13_vary_L,
        F.fig14_vary_K,
        F.fig16_17_indexing,
        F.fig18_19_quality,
        F.fig20_scalability,
        F.fig21_vary_k,
        F.fig22_23_cumulative,
    ]
    if not fast:
        from benchmarks import parallel_scaling as P
        figs.append(P.fig09_10_12_scaling)
    return figs


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip multi-process scaling benchmarks")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: query/build throughput, snapshot "
                         "round-trip, PDET worker scaling, the serving-"
                         "runtime mixed-load check, LSH-decode vs full "
                         "attention, the recall/QPS Pareto sweep on "
                         "small indexes, the auto-tuner shrink-L check, "
                         "and the WAL ingest/recovery check; "
                         "writes BENCH_{query,build,snapshot,parallel,"
                         "serving,decode,pareto,tune,recovery}.json and the "
                         "benchmarks/out/smoke_snapshot artifact")
    ap.add_argument("--only", default="")
    ap.add_argument("--out-dir", default="benchmarks/out")
    args = ap.parse_args(argv)

    if args.smoke:
        from benchmarks import build_throughput as B
        from benchmarks import decode_throughput as D
        from benchmarks import parallel_scaling as P
        from benchmarks import pareto_smoke as PS
        from benchmarks import query_throughput as Q
        from benchmarks import recovery_smoke as R
        from benchmarks import serving_load as V
        from benchmarks import snapshot_smoke as S
        from benchmarks import tune_smoke as T
        figures = [Q.query_throughput_smoke, B.build_throughput_smoke,
                   S.snapshot_smoke, P.parallel_scaling_smoke,
                   V.serving_load, D.decode_throughput_smoke,
                   PS.pareto_smoke, T.tune_smoke, R.recovery_smoke]
    else:
        figures = _figures(args.fast)

    summary = ["name,us_per_call,derived"]
    failed, ran = [], []
    for fig in figures:
        if args.only and fig.__name__ != args.only:
            continue
        t0 = time.perf_counter()
        try:
            table = fig()
            ran.append(fig.__name__)
        except Exception as e:  # keep the harness running
            print(f"[bench] {fig.__name__} FAILED: {e}", file=sys.stderr)
            summary.append(f"{fig.__name__},nan,error")
            failed.append(fig.__name__)
            continue
        sec = time.perf_counter() - t0
        lines = table.emit(args.out_dir)
        print(f"\n### {table.name}  ({sec:.1f}s)")
        for ln in lines:
            print(ln)
        us = sec * 1e6 / max(len(table.rows), 1)
        summary.append(f"{table.name},{us:.1f},rows={len(table.rows)}")

    print("\n### summary")
    for ln in summary:
        print(ln)

    if args.smoke:
        _enforce_smoke_gates(failed, ran)


def _enforce_smoke_gates(failed, ran) -> None:
    """--smoke is the CI entry point: a failed smoke figure or a build-
    pipeline regression must fail the run, not just print.  Gates are
    *ratios* measured within the same run (old-vs-new build speedup >= 1.0),
    not absolute times, so shared CI runners don't flake.  The build gate
    only fires when this run actually produced BENCH_build.json (--only may
    have selected a different figure — never gate on a stale file)."""
    import json
    if failed:
        raise SystemExit(f"[bench] smoke figures failed: {failed}")
    if "serving_load" in ran:
        with open("BENCH_serving.json") as f:
            srv = json.load(f)
        if not srv["identical_to_oracle"]:
            raise SystemExit("[bench] serving gate: answers diverged from "
                             "the serialized oracle")
        if srv["stats"]["shed_total"] != 0:
            raise SystemExit(f"[bench] serving gate: shed at smoke load: "
                             f"{srv['stats']['shed']}")
        print(f"[bench] serving gates OK: oracle-identical, zero shed, "
              f"p99={srv['stats']['p99_ms']:.1f}ms "
              f"({srv['closed_loop_qps']:.0f} qps closed-loop)")
    if "decode_throughput_smoke" in ran:
        with open("BENCH_decode.json") as f:
            dec = json.load(f)
        if not dec["ratio_lsh_over_full"] >= 1.0:
            raise SystemExit(f"[bench] decode gate: LSH decode slower than "
                             f"full attention at S={dec['S']}: "
                             f"{dec['ratio_lsh_over_full']:.2f}x")
        if not dec["planted_recall"] >= 0.9:
            raise SystemExit(f"[bench] decode gate: planted recall "
                             f"{dec['planted_recall']:.2f} < 0.9 — speed "
                             f"via retrieval misses is not acceptable")
        print(f"[bench] decode gates OK: "
              f"{dec['ratio_lsh_over_full']:.2f}x over full attention, "
              f"planted recall {dec['planted_recall']:.2f} "
              f"(S={dec['S']}, refresh_every={dec['refresh_every']})")
    if "pareto_smoke" in ran:
        with open("BENCH_pareto.json") as f:
            gate = json.load(f)["det_dominates_brute"]
        if not gate["ok"]:
            raise SystemExit(f"[bench] pareto gate: no DET-LSH point beats "
                             f"brute force at recall >= "
                             f"{gate['min_recall']}: {gate}")
        print(f"[bench] pareto gate OK: {gate['best_label']} reaches "
              f"recall {gate['best_recall']:.3f} at "
              f"{gate['best_work']:.0f} candidates/query vs "
              f"{gate['reference_work']:.0f} exact")
    if "tune_smoke" in ran:
        with open("BENCH_tune.json") as f:
            tg = json.load(f)["gates"]
        if not tg["tuner_hit_target"]:
            raise SystemExit(
                f"[bench] tune gate: tuner missed target recall "
                f"{tg['target_recall']}: tuned recall "
                f"{tg['tuned_recall']:.3f} "
                f"(L={tg['tuned_L']}, probe_depth={tg['tuned_probe_depth']})")
        if not tg["shrinks_L_at_fixed_recall"]:
            raise SystemExit(
                f"[bench] tune gate: tuned config does not shrink L at "
                f"fixed recall: L {tg['tuned_L']} vs {tg['baseline_L']}, "
                f"work {tg['tuned_work']:.0f} vs {tg['baseline_work']:.0f}, "
                f"recall {tg['tuned_recall']:.3f} vs target "
                f"{tg['target_recall']}")
        print(f"[bench] tune gates OK: L={tg['tuned_L']} "
              f"p={tg['tuned_probe_depth']} reaches recall "
              f"{tg['tuned_recall']:.3f} at {tg['tuned_work']:.0f} "
              f"candidates/query vs static L={tg['baseline_L']} at "
              f"{tg['baseline_work']:.0f}")
    if "recovery_smoke" in ran:
        with open("BENCH_recovery.json") as f:
            rec = json.load(f)
        if not rec["identical"]:
            raise SystemExit("[bench] recovery gate: recovered index not "
                             "bit-identical to the pre-crash one")
        if not rec["ingest_ratio"] >= 0.5:
            raise SystemExit(f"[bench] recovery gate: WAL-on ingest "
                             f"{rec['ingest_ratio']:.2f}x of WAL-off "
                             f"(< 0.5x parity floor)")
        print(f"[bench] recovery gates OK: bit-identical after replaying "
              f"{rec['replayed']} records in {rec['recovery_s'] * 1e3:.0f}ms,"
              f" WAL ingest parity {rec['ingest_ratio']:.2f}x")
    if "build_throughput_smoke" not in ran:
        print("[bench] build speedup gate skipped (build figure not run)")
        return
    with open("BENCH_build.json") as f:
        speedup = json.load(f)["build_speedup"]
    bad = {k: v for k, v in speedup.items() if not v >= 1.0}
    if bad:
        raise SystemExit(f"[bench] build-pipeline speedup gate (>= 1.0x "
                         f"over the seed builder) failed: {bad}")
    print(f"[bench] build speedup gate OK: "
          + ", ".join(f"{k}={v:.2f}x" for k, v in speedup.items()))


if __name__ == "__main__":
    main()
