"""Durability smoke: WAL ingest overhead + crash-recovery time (CI gate).

Ingests the same upsert/delete stream into a plain ``StreamingDETLSH``
(WAL off) and a ``DurableIndex`` (WAL on, ``fsync='interval'``), kills
the durable one without a final checkpoint, recovers it, and measures:

  * ingest parity — WAL-on points/s must stay >= 0.5x WAL-off (the log
    is a few framed appends per op; it must never dominate ingest);
  * recovery time and the number of WAL records replayed;
  * bitwise identity — the recovered index answers exactly like the
    pre-crash one on both engines (and ``state_digest`` matches).

Writes BENCH_recovery.json at the repo root; run.py --smoke enforces the
parity and identity gates.

  PYTHONPATH=src python -m benchmarks.run --smoke
"""

from __future__ import annotations

import json
import os
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, make_dataset, make_queries

SMOKE = dict(n=2048, n_stream=1024, chunk=128, k=10, batch=32)


def _build(data):
    from repro.core import derive_params
    from repro.streaming import StreamingDETLSH
    p = derive_params(K=4, c=1.5, L=4, beta_override=0.1)
    return StreamingDETLSH.build(jnp.asarray(data), jax.random.key(0), p,
                                 Nr=64, leaf_size=32, delta_capacity=256,
                                 max_segments=4)


def _ingest(index, stream, chunk):
    """Drive the same mutation schedule into either wrapper; returns
    points/s over the upserted rows."""
    t0 = time.perf_counter()
    for i in range(0, len(stream), chunk):
        gids = index.upsert(stream[i: i + chunk])
        if i // chunk % 3 == 2:
            index.delete(np.asarray(gids)[::7])
    sec = time.perf_counter() - t0
    return len(stream) / sec, sec


def run_recovery_smoke(cfg=None, json_path: str = "BENCH_recovery.json",
                       out_dir: str = "benchmarks/out") -> Table:
    from repro.api import SearchRequest
    from repro.durability import DurableIndex, recover

    cfg = dict(SMOKE, **(cfg or {}))
    data = make_dataset("deep-like", cfg["n"], seed=0)
    base, stream = data[: cfg["n"] - cfg["n_stream"]], \
        data[cfg["n"] - cfg["n_stream"]:]
    queries = jnp.asarray(make_queries(data, cfg["batch"], seed=1))
    root = os.path.join(out_dir, "smoke_recovery")
    shutil.rmtree(root, ignore_errors=True)

    # Warmup: pay every seal/merge JIT compile once, untimed, so the
    # parity ratio below compares steady-state ingest, not compile time.
    _ingest(_build(base), stream, cfg["chunk"])

    # WAL off: the plain index is the ingest baseline
    plain = _build(base)
    pps_off, _ = _ingest(plain, stream, cfg["chunk"])

    # WAL on: same schedule through the durable wrapper
    durable = DurableIndex.create(_build(base), root, fsync="interval")
    pps_on, _ = _ingest(durable, stream, cfg["chunk"])
    digest_pre = durable.state_digest()
    answers_pre = {}
    for engine in ("fused", "vmap"):
        req = SearchRequest(k=cfg["k"], engine=engine)
        res = durable.search(queries, req)
        answers_pre[engine] = (np.asarray(res.ids), np.asarray(res.dists))
    wal_stats = durable.durability_stats()
    durable.wal._f.close()                 # kill: no checkpoint of the tail

    t0 = time.perf_counter()
    recovered = recover(root)
    recovery_s = time.perf_counter() - t0
    replayed = recovered.last_recovery.n_replayed

    identical = recovered.state_digest() == digest_pre
    for engine in ("fused", "vmap"):
        req = SearchRequest(k=cfg["k"], engine=engine)
        res = recovered.search(queries, req)
        identical &= bool(np.array_equal(answers_pre[engine][0],
                                         np.asarray(res.ids)))
        identical &= bool(np.array_equal(answers_pre[engine][1],
                                         np.asarray(res.dists)))
    recovered.close()

    ratio = pps_on / pps_off
    table = Table("recovery_smoke",
                  ["metric", "wal_off", "wal_on", "derived"])
    table.add("ingest_pps", f"{pps_off:.0f}", f"{pps_on:.0f}",
              f"ratio={ratio:.2f}")
    table.add("recovery", "-", f"{recovery_s * 1e3:.1f}ms",
              f"replayed={replayed}")
    table.add("identical", "-", str(identical),
              f"wal_bytes={wal_stats['wal_bytes']}")

    payload = dict(bench="recovery_smoke", workload=cfg,
                   backend=jax.default_backend(),
                   ingest_pps_wal_off=pps_off, ingest_pps_wal_on=pps_on,
                   ingest_ratio=ratio, recovery_s=recovery_s,
                   replayed=replayed, identical=identical,
                   wal=wal_stats)
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    if not identical:
        raise AssertionError(
            f"recovery not bit-identical to the pre-crash index: {payload}")
    table.emit(out_dir)
    return table


def recovery_smoke() -> Table:
    """run.py --smoke entry point."""
    return run_recovery_smoke()
