"""Roofline derivation (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, three per-chip time terms:

  compute term    = MODEL_FLOPS / chips / peak_bf16
  memory term     = streaming_bytes / chips / HBM_bw
  collective term = collective_bytes / ICI_bw        (per chip)

MODEL_FLOPS (analytic, stated below) = 6*N(_active)*tokens for train,
2*N*tokens for prefill/decode, plus the attention score/value term.

streaming_bytes (analytic) — the dominant HBM traffic per step:
  train   : 3 weight passes (fwd + remat recompute + bwd) + grad write/read
            + 2x optimizer state r/w + 2x saved layer activations
  prefill : 1 weight pass + 2x KV-cache write + 2x activations
  decode  : 1 weight pass + 1x cache read + cache write (1 slot)

collective_bytes — parsed from the compiled HLO (per-device shapes), with
while-body collectives multiplied by their trip count (layers x accum;
XLA's cost analysis visits loop bodies once).

Why analytic compute/memory instead of cost_analysis(): XLA reports
per-device FLOPs/bytes with ALL loop bodies (layer scan, KV-block scan, SSD
chunk scan, accum scan) counted once, and 'bytes accessed' counts operand
bytes pre-fusion — on the CPU backend that overestimates HBM traffic by
orders of magnitude.  The HLO numbers are still recorded in each cell
(hlo_*_once) as structural cross-checks; the analytic terms use only
config-derived quantities and the measured per-device memory footprint.

roofline_frac = compute_term / max(terms): the fraction of the achievable
step time spent doing useful math (1.0 = perfectly compute-bound at peak).
"""

from __future__ import annotations

import json
import math
import os

from repro.configs import active_param_count, get_config, param_count
from repro.configs.base import ALL_SHAPES
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

OUT_HEADER = [
    "arch", "shape", "mesh", "kind", "bottleneck", "compute_s", "memory_s",
    "collective_s", "roofline_frac", "live_GiB", "fits",
]


def _trips(cfg, kind: str) -> int:
    layers = cfg.n_layers
    if cfg.family == "vlm":
        layers = cfg.n_layers // cfg.cross_attn_every
    accum = cfg.parallel.accum_steps if kind == "train" else 1
    return max(layers, 1) * max(accum, 1)


def model_flops(cfg, shape, kind: str) -> float:
    """Useful FLOPs for the whole step (global)."""
    n_params = active_param_count(cfg)
    tokens = shape.global_batch * (shape.seq_len if kind != "decode" else 1)
    mult = 6 if kind == "train" else 2
    flops = mult * n_params * tokens
    if cfg.family != "ssm" and cfg.n_heads > 1:
        h, dh = cfg.n_heads, cfg.head_dim
        bwd = 3 if kind == "train" else 1
        if kind == "decode":
            flops += 4 * shape.global_batch * h * dh * shape.seq_len \
                * cfg.n_layers
        else:
            s = shape.seq_len
            flops += 4 * 0.5 * shape.global_batch * s * s * h * dh \
                * cfg.n_layers * bwd
    return flops


def _bytes_per_param(cfg):
    p = 2  # bf16 params
    opt = 2 if cfg.parallel.opt_state_dtype == "int8" else 8  # m+v
    return p, opt


def cache_bytes(cfg, shape) -> float:
    hk, dh = cfg.n_kv_heads, cfg.head_dim
    dt = {"bfloat16": 2, "float32": 4, "float8_e4m3fn": 1}[
        cfg.parallel.kv_cache_dtype]
    kv = 2 * cfg.n_layers * shape.global_batch * shape.seq_len * hk * dh * dt
    if cfg.family == "ssm":
        kv = 0
    if cfg.ssm_state:
        d_in = cfg.ssm_expand * cfg.d_model
        nh = d_in // cfg.ssm_head_dim
        kv += cfg.n_layers * shape.global_batch * nh * cfg.ssm_head_dim \
            * cfg.ssm_state * 4
    return kv


def streaming_bytes(cfg, shape, kind: str) -> float:
    """Dominant per-step HBM traffic (global; divided by chips later)."""
    n = param_count(cfg)
    pb, ob = _bytes_per_param(cfg)
    params_b = n * pb
    tokens = shape.global_batch * shape.seq_len
    act_b = tokens * cfg.d_model * 2 * cfg.n_layers  # saved layer inputs
    if kind == "train":
        return 3 * params_b + 2 * n * 4 + 2 * n * ob + 2 * act_b
    if kind == "prefill":
        return params_b + 2 * cache_bytes(cfg, shape) + 2 * act_b / \
            max(cfg.n_layers, 1)
    # decode: read whole cache + weights once; write one slot (negligible)
    return params_b + cache_bytes(cfg, shape)


def derive(records: list[dict]) -> list[dict]:
    rows = []
    for r in records:
        if "error" in r:
            continue
        cfg = get_config(r["arch"])
        shape = ALL_SHAPES[r["shape"]]
        kind = r["kind"]
        trips = _trips(cfg, kind)
        devices = r["devices"]

        mf = model_flops(cfg, shape, kind) / devices
        sb = streaming_bytes(cfg, shape, kind) / devices
        coll = r["collectives"]
        coll_total = sum(v["entry"] for v in coll.values()) + \
            sum(v["body"] for v in coll.values()) * trips

        compute_s = mf / PEAK_FLOPS_BF16
        memory_s = sb / HBM_BW
        coll_s = coll_total / ICI_BW
        terms = {"compute": compute_s, "memory": memory_s,
                 "collective": coll_s}
        bottleneck = max(terms, key=terms.get)
        step = max(terms.values())

        rows.append(dict(
            arch=r["arch"], shape=r["shape"], mesh=r["mesh"], kind=kind,
            compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
            bottleneck=bottleneck,
            roofline_frac=compute_s / step if step else 0.0,
            model_flops_per_chip=mf, streaming_bytes_per_chip=sb,
            collective_bytes_per_chip=float(coll_total),
            hlo_flops_once=r["cost"]["hlo_flops_once"],
            hlo_bytes_once=r["cost"]["hlo_bytes_once"],
            trips=trips,
            live_GiB=r["memory"]["live_bytes"] / 2 ** 30,
            fits=r["memory"]["fits_16GiB"],
        ))
    return rows


def render(rows: list[dict]) -> str:
    lines = ["| " + " | ".join(OUT_HEADER) + " |",
             "|" + "|".join(["---"] * len(OUT_HEADER)) + "|"]
    for r in sorted(rows, key=lambda x: (x["mesh"], x["arch"], x["shape"])):
        vals = []
        for k in OUT_HEADER:
            v = r[k]
            if k in ("compute_s", "memory_s", "collective_s"):
                vals.append(f"{v:.3e}")
            elif k in ("roofline_frac", "live_GiB"):
                vals.append(f"{v:.3f}")
            else:
                vals.append(str(v))
        lines.append("| " + " | ".join(vals) + " |")
    return "\n".join(lines)


def main(path="experiments/dryrun.json", out="experiments/roofline.json"):
    records = json.load(open(path))
    rows = derive(records)
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(render(rows))
    return rows


if __name__ == "__main__":
    import sys
    main(*sys.argv[1:])
