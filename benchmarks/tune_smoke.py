"""Auto-tuner smoke: shrink L at fixed recall via multi-probe (CI).

Runs ``repro.tune.suggest_params`` at smoke scale against a static-L
reference config and writes BENCH_tune.json; run.py --smoke gates on

  * tuner_hit_target   — the tuner's chosen config reaches the target
    recall (0.9) on the held-out workload queries; and
  * shrinks_L_at_fixed_recall — that config is genuinely multi-probe
    (probe_depth > 0) and uses strictly fewer trees than the static-L
    baseline AND strictly fewer candidates per query (mean
    SearchStats.n_candidates), at recall still >= the target.

This is the paper-level claim multi-probe exists to cash: L is the
dominant cost knob (build time, memory, per-round query work all scale
linearly in it), and probing near-miss leaves buys back the recall a
smaller forest loses — so the tuned operating point must dominate the
static one on the work axis, not just match it.  Both configs are
measured through the same ``AnnIndex.search`` protocol and the same
``repro.eval.pareto.measure`` path as every other benchmark.

  PYTHONPATH=src python -m benchmarks.run --smoke
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, make_dataset, make_queries

SMOKE = dict(dataset="msong-like", n=4096, nq=32, k=10, repeat=2,
             target_recall=0.9)

# The static reference: the forest size a user without a tuner would run
# (the pareto_smoke upper spec).  The tuner's grid is capped strictly
# below this L, so hitting the target at all *requires* either a lucky
# small forest or multi-probe admission.
BASELINE = dict(K=4, L=8, beta=0.1)
GRID = dict(Ks=(4,), Ls=(2, 3, 4), betas=(0.05, 0.1),
            probe_depths=(0, 2, 4, 8))


def tune_smoke() -> Table:
    import dataclasses

    from repro.api import IndexSpec, SearchRequest, build
    from repro.baselines import BruteForce
    from repro.eval.pareto import measure
    from repro.tune import suggest_params

    cfg = SMOKE
    data = jnp.asarray(make_dataset(cfg["dataset"], cfg["n"]))
    queries = jnp.asarray(make_queries(np.asarray(data), cfg["nq"]))
    key = jax.random.PRNGKey(0)
    k = cfg["k"]

    bf = BruteForce.build(data)
    gt = bf.search(queries, SearchRequest(k=k))

    base_spec = IndexSpec(kind="static", K=BASELINE["K"], L=BASELINE["L"],
                          c=1.5, beta_override=BASELINE["beta"], Nr=64,
                          leaf_size=32)
    t0 = time.perf_counter()
    base_index = build(data, key, base_spec)
    base_index.search(queries[:1], SearchRequest(k=k))     # build + warmup
    t_base = time.perf_counter() - t0
    base_pt = measure("det-lsh", f"static-K{base_spec.K}-L{base_spec.L}",
                      base_index, queries, gt.ids, SearchRequest(k=k),
                      build_seconds=t_base, repeat=cfg["repeat"],
                      params=dict(K=base_spec.K, L=base_spec.L,
                                  beta=BASELINE["beta"], probe_depth=0))

    result = suggest_params(data, cfg["target_recall"], key=key, k=k,
                            queries=queries, Nr=64, leaf_size=32,
                            repeat=cfg["repeat"], **GRID)
    # Re-measure the winner through the spec's baked-in probe default (no
    # explicit probe_depth on the request) — the gate scores what a user
    # gets from ``api.build(data, key, result.spec)`` + a plain request.
    tuned_index = build(data, key, result.spec)
    tuned_index.search(queries[:1], SearchRequest(k=k))
    tuned_pt = measure("det-lsh", f"tuned-L{result.spec.L}-p"
                       f"{result.spec.probe_depth}", tuned_index, queries,
                       gt.ids, SearchRequest(k=k),
                       build_seconds=result.build_seconds,
                       repeat=cfg["repeat"],
                       params=dict(K=result.spec.K, L=result.spec.L,
                                   beta=result.spec.beta_override,
                                   probe_depth=result.spec.probe_depth))
    # measure() records the *request's* probe_depth; here the probing comes
    # from the index default, so stamp the effective depth on the point.
    tuned_pt = dataclasses.replace(tuned_pt,
                                   probe_depth=result.spec.probe_depth)

    gates = {
        "tuner_hit_target": bool(result.achieved
                                 and tuned_pt.recall >= cfg["target_recall"]),
        "shrinks_L_at_fixed_recall": bool(
            result.spec.L < base_spec.L
            and result.spec.probe_depth > 0
            and tuned_pt.recall >= cfg["target_recall"]
            and tuned_pt.work_per_query < base_pt.work_per_query),
        "target_recall": cfg["target_recall"],
        "baseline_L": base_spec.L,
        "tuned_L": result.spec.L,
        "tuned_probe_depth": result.spec.probe_depth,
        "baseline_recall": base_pt.recall,
        "tuned_recall": tuned_pt.recall,
        "baseline_work": base_pt.work_per_query,
        "tuned_work": tuned_pt.work_per_query,
    }
    out = {
        "dataset": cfg["dataset"], "n": cfg["n"], "k": k,
        "n_queries": cfg["nq"],
        "baseline": base_pt.to_dict(),
        "tuned": tuned_pt.to_dict(),
        "result": result.to_dict(),
        "gates": gates,
    }
    with open("BENCH_tune.json", "w") as f:
        json.dump(out, f, indent=2)

    tab = Table("tune_smoke",
                ["config", "L", "probe_depth", "recall", "work_per_q"])
    for p in result.trials:
        tab.add([p.label, p.params["L"], p.probe_depth,
                 f"{p.recall:.3f}", f"{p.work_per_query:.0f}"])
    tab.add([base_pt.label, base_spec.L, 0, f"{base_pt.recall:.3f}",
             f"{base_pt.work_per_query:.0f}"])
    tab.add([tuned_pt.label, result.spec.L, result.spec.probe_depth,
             f"{tuned_pt.recall:.3f}", f"{tuned_pt.work_per_query:.0f}"])
    tab.add(["gate_hit_target", "", "", str(gates["tuner_hit_target"]), ""])
    tab.add(["gate_shrinks_L", "", "", str(gates["shrinks_L_at_fixed_recall"]),
             ""])
    return tab
