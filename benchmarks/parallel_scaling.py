"""Figs 9/10/12: PDET-LSH indexing/query scaling with worker count.

Workers (paper: CPU threads) map to devices here.  This container has ONE
physical core, so wall-clock cannot show real speedup; what these tables
validate is the *scaling structure*: per-worker work (points indexed,
candidates scanned per shard) divides as 1/N_w while the returned results
stay identical (Theorem 3).  The speedup column is therefore reported two
ways: measured wall time (flat on 1 core, by construction) and the
work-based model T1/(T1/N_w + sync) from per-shard op counts.

Two tables live here:

  * ``fig09_10_12_scaling`` — the structure-partitioned ``build_pdet``
    runtime (per-shard forests); results across worker counts are
    measured as top-k *overlap* (different shard partitions may admit
    different, equally valid candidates).
  * ``parallel_scaling_smoke`` (``run.py --smoke`` / CI) — the
    ``repro.api`` PDETIndex (layout-sharded, DESIGN.md §7), where the
    identical-results check across worker counts is *exact*: ids and
    distance bit patterns must match at every worker count, and the
    per-shard candidate counters from ``SearchStats`` feed the work-based
    speedup model.  Written to BENCH_parallel.json and gated in CI.

Each worker-count runs in a subprocess because XLA fixes the device count
at first initialization.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import Table

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={nw}"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import json, sys, time
    import jax, jax.numpy as jnp, numpy as np
    sys.path.insert(0, {src!r}); sys.path.insert(0, {root!r})
    from repro.launch.mesh import make_mesh
    from repro.core import derive_params
    from repro.core.distributed import build_pdet
    from repro.core.query import QueryConfig
    from benchmarks.common import make_dataset, make_queries

    n, nq, k = {n}, 16, 10
    data = jnp.asarray(make_dataset("deep-like", n))
    queries = jnp.asarray(make_queries(np.asarray(data), nq))
    p = derive_params(K=4, c=1.5, L=8, beta_override=0.05)
    mesh = make_mesh(({nw},), ("data",))
    t0 = time.perf_counter()
    idx = build_pdet(data, jax.random.key(0), p, mesh, axes=("data",),
                     leaf_size=64)
    jax.block_until_ready(idx.forest.point_ids)
    t_build = time.perf_counter() - t0
    res = idx.query(queries, k=k, M=8, r_min=0.5)   # warm compile
    jax.block_until_ready(res[0])
    t0 = time.perf_counter()
    res = idx.query(queries, k=k, M=8, r_min=0.5)
    jax.block_until_ready(res[0])
    t_query = time.perf_counter() - t0
    points_per_worker = n // {nw}
    print(json.dumps(dict(nw={nw}, t_build=t_build, t_query=t_query,
                          points_per_worker=points_per_worker,
                          ids=np.asarray(res[0]).tolist())))
""")


def _run(nw: int, n: int = 20000):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = _SCRIPT.format(nw=nw, n=n, src=os.path.join(root, "src"),
                            root=root)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def fig09_10_12_scaling() -> Table:
    """Indexing (Fig 9) + query (Fig 10) scaling and speedup model (Fig 12)."""
    t = Table("fig09_10_12_scaling",
              ["workers", "build_s", "query_s", "points_per_worker",
               "work_model_speedup", "topk_overlap_vs_1w"])
    base = None
    ids1 = None
    for nw in (1, 2, 4, 8):
        r = _run(nw)
        if base is None:
            base, ids1 = r, r["ids"]
        # work model: perfectly partitioned scan + log-depth merge
        model = base["points_per_worker"] / (r["points_per_worker"]
                                             + 64 * nw.bit_length())
        # different shard partitions may admit different (equally valid)
        # candidates; overlap measures result stability across worker counts
        overlap = sum(len(set(a) & set(b)) / max(len(a), 1)
                      for a, b in zip(r["ids"], ids1)) / len(ids1)
        t.add(nw, r["t_build"], r["t_query"], r["points_per_worker"],
              model, overlap)
    return t


# ---------------------------------------------------------------------------
# CI smoke: the repro.api PDETIndex, exact identity across worker counts
# ---------------------------------------------------------------------------

_SMOKE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={nw}"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import json, sys, time
    sys.path.insert(0, {src!r}); sys.path.insert(0, {root!r})
    import jax, jax.numpy as jnp, numpy as np
    import repro
    from repro.api import IndexSpec, PlacementSpec, SearchRequest
    from benchmarks.common import make_dataset, make_queries

    n, nq, k = {n}, 16, 10
    data = jnp.asarray(make_dataset("deep-like", n))
    queries = jnp.asarray(make_queries(np.asarray(data), nq))
    spec = IndexSpec(kind="static", K=4, L=8, c=1.5, beta_override=0.05,
                     leaf_size=64,
                     placement=PlacementSpec(mesh_shape=({nw},),
                                             mesh_axes=("data",)))
    t0 = time.perf_counter()
    idx = repro.api.build(data, jax.random.key(0), spec)
    jax.block_until_ready(idx.forest.point_ids)
    t_build = time.perf_counter() - t0
    req = SearchRequest(k=k, r_min=0.5)
    res = idx.search(queries, req)               # warm compile
    jax.block_until_ready(res.dists)
    t0 = time.perf_counter()
    res = idx.search(queries, req)
    jax.block_until_ready(res.dists)
    t_query = time.perf_counter() - t0
    print(json.dumps(dict(
        nw={nw}, t_build=t_build, t_query=t_query,
        engine=res.stats.engine,
        shard_candidates=np.asarray(res.stats.shard_candidates).tolist(),
        psum_rounds=int(res.stats.psum_rounds),
        ids=np.asarray(res.ids).tolist(),
        dist_bits=np.asarray(res.dists).view(np.uint32).tolist())))
""")


def run_parallel_smoke(n: int = 8192, workers=(1, 2, 4),
                       json_path: str = "BENCH_parallel.json",
                       out_dir: str = "benchmarks/out") -> Table:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    t = Table("parallel_scaling_smoke",
              ["workers", "build_s", "query_s", "cand_per_worker_max",
               "work_model_speedup", "identical_vs_1w"])
    rows, base = [], None
    for nw in workers:
        script = _SMOKE_SCRIPT.format(nw=nw, n=n,
                                      src=os.path.join(root, "src"),
                                      root=root)
        out = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, timeout=1200)
        if out.returncode != 0:
            raise RuntimeError(out.stderr[-2000:])
        r = json.loads(out.stdout.strip().splitlines()[-1])
        base = base or r
        # Work model from the *measured* per-shard counters: the slowest
        # shard bounds the round, plus a log-depth merge term per round.
        peak = max(r["shard_candidates"])
        model = max(base["shard_candidates"]) / (
            peak + 64 * r["psum_rounds"] * nw.bit_length())
        identical = (r["ids"] == base["ids"]
                     and r["dist_bits"] == base["dist_bits"])
        r["identical"] = identical
        rows.append(r)
        t.add(nw, r["t_build"], r["t_query"], peak, model, identical)

    identical_all = all(r["identical"] for r in rows)
    payload = dict(bench="parallel_scaling_smoke",
                   workload=dict(n=n, nq=16, k=10, workers=list(workers)),
                   engine=rows[0]["engine"],
                   identical_across_workers=identical_all,
                   rows=[{k_: r[k_] for k_ in
                          ("nw", "t_build", "t_query", "shard_candidates",
                           "psum_rounds", "identical")} for r in rows])
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    if not identical_all:
        raise AssertionError(
            f"PDET results changed with worker count: {payload}")
    t.emit(out_dir)
    return t


def parallel_scaling_smoke() -> Table:
    """run.py --smoke entry point."""
    return run_parallel_smoke()
