"""Snapshot persistence smoke: save -> load -> query equality (CI artifact).

Builds a small static and a small streaming index (the streaming one with
un-sealed delta rows and pre-compaction tombstones), snapshots both under
``benchmarks/out/smoke_snapshot/``, reloads them, and verifies the reloaded
indexes answer bit-identically on both engines.  Writes BENCH_snapshot.json
(sizes, per-kind ok flags) at the repo root; CI uploads the JSON and the
snapshot directories as the restart-without-rebuild artifact.

  PYTHONPATH=src python -m benchmarks.run --smoke
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, make_dataset, make_queries

SMOKE = dict(n=2048, k=10, batch=32)


def _dir_bytes(path: str) -> int:
    return sum(os.path.getsize(os.path.join(dp, f))
               for dp, _, fs in os.walk(path) for f in fs)


def _roundtrip(index, queries, k: int, path: str) -> dict:
    """Save + load + assert per-engine bit-identical answers."""
    import repro
    from repro.api import SearchRequest

    t0 = time.perf_counter()
    index.save(path)
    t_save = time.perf_counter() - t0
    t0 = time.perf_counter()
    loaded = repro.api.load(path)
    t_load = time.perf_counter() - t0

    identical = True
    for engine in ("fused", "vmap"):
        req = SearchRequest(k=k, engine=engine)
        a = index.search(queries, req)
        b = loaded.search(queries, req)
        identical &= bool(np.array_equal(np.asarray(a.ids),
                                         np.asarray(b.ids)))
        identical &= bool(np.array_equal(np.asarray(a.dists),
                                         np.asarray(b.dists)))
    return dict(path=path, bytes=_dir_bytes(path), save_s=t_save,
                load_s=t_load, identical=identical)


def run_snapshot_smoke(cfg=None, json_path: str = "BENCH_snapshot.json",
                       out_dir: str = "benchmarks/out") -> Table:
    import repro
    from repro.api import IndexSpec

    cfg = dict(SMOKE, **(cfg or {}))
    data = make_dataset("deep-like", cfg["n"], seed=0)
    queries = jnp.asarray(make_queries(data, cfg["batch"], seed=1))
    root = os.path.join(out_dir, "smoke_snapshot")

    static = repro.api.build(
        jnp.asarray(data), jax.random.key(0),
        IndexSpec(kind="static", K=4, L=4, c=1.5, beta_override=0.1,
                  Nr=64, leaf_size=32))
    static.fused_plan()              # snapshot the fused-plan constants too
    rec_static = _roundtrip(static, queries, cfg["k"],
                            os.path.join(root, "static"))

    stream = repro.api.build(
        jnp.asarray(data[: cfg["n"] // 2]), jax.random.key(0),
        IndexSpec(kind="streaming", K=4, L=4, c=1.5, beta_override=0.1,
                  Nr=64, leaf_size=32, delta_capacity=256, max_segments=4))
    gids = stream.upsert(data[cfg["n"] // 2: cfg["n"] // 2 + 600])
    stream.delete(gids[::5])         # pre-compaction tombstones + live delta
    stream.delete(np.arange(0, 64))
    rec_stream = _roundtrip(stream, queries, cfg["k"],
                            os.path.join(root, "streaming"))

    table = Table("snapshot_smoke",
                  ["kind", "bytes", "save_s", "load_s", "identical"])
    for kind, rec in (("static", rec_static), ("streaming", rec_stream)):
        table.add(kind, rec["bytes"], rec["save_s"], rec["load_s"],
                  rec["identical"])

    payload = dict(bench="snapshot_smoke", workload=cfg,
                   backend=jax.default_backend(),
                   static=rec_static, streaming=rec_stream)
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
    if not (rec_static["identical"] and rec_stream["identical"]):
        raise AssertionError(
            f"snapshot round-trip not bit-identical: {payload}")
    table.emit(out_dir)
    return table


def snapshot_smoke() -> Table:
    """run.py --smoke entry point."""
    return run_snapshot_smoke()
