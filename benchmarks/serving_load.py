"""Serving-runtime load smoke: closed-loop mixed traffic with gates (CI).

Drives a ``ServingRuntime`` over a streaming index with the full mixed
workload — query bursts, upserts, deletes (including no-op ids), and a
forced compaction concurrent with a pinned epoch — and checks the §9
serialized-oracle contract live: every full-bucket burst must answer
bit-identically to running the same request directly against the index in
submission order (mutations are barriers, so the index state *is* the
serialized state).  Odd-sized bursts exercise the pad path and are checked
against the same oracle set-wise (padding preserves the answer set; the
bit-level guarantee is gated on unpadded buckets).

Writes BENCH_serving.json at the repo root and enforces the smoke gates
in-process (run.py --smoke re-checks them from the JSON):

  * zero shed at smoke load,
  * answers identical to the serialized oracle,
  * p99 latency bounded (generous absolute bound — warmup compiles every
    bucket first, so the percentile measures steady-state service time).

  PYTHONPATH=src python -m benchmarks.run --smoke
"""

from __future__ import annotations

import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, make_dataset, make_queries

SMOKE = dict(n=4096, k=10, rounds=3, burst=32, odd_burst=20,
             upserts_per_round=192, deletes_per_round=48,
             deadline_s=30.0, p99_budget_ms=6000.0)


def _oracle_check(rt, idx, base_req, burst, outcomes, *, bitwise):
    """Serialized oracle: the same queries, run to completion against the
    index directly (no scheduler), must match the runtime's answers."""
    from repro.serving import Answer

    req = dataclasses.replace(base_req, n_active=len(burst))
    res = idx.search(jnp.asarray(np.stack(burst)), req)
    ids, dists = np.asarray(res.ids), np.asarray(res.dists)
    ok = True
    for i, out in enumerate(outcomes):
        if not isinstance(out, Answer):
            return False
        if bitwise:
            ok &= bool(np.array_equal(np.asarray(out.ids), ids[i]))
            ok &= bool(np.array_equal(np.asarray(out.dists), dists[i]))
        else:
            ok &= set(np.asarray(out.ids).tolist()) == set(ids[i].tolist())
            ok &= bool(np.allclose(np.sort(np.asarray(out.dists)),
                                   np.sort(dists[i]), rtol=1e-5, atol=1e-5))
    return ok


def run_serving_load(cfg=None, json_path: str = "BENCH_serving.json",
                     out_dir: str = "benchmarks/out") -> Table:
    import repro
    from repro.api import IndexSpec, SearchRequest

    cfg = dict(SMOKE, **(cfg or {}))
    n, k, burst = cfg["n"], cfg["k"], cfg["burst"]
    data = make_dataset("deep-like", n, seed=0)
    d = data.shape[1]
    rng = np.random.default_rng(7)

    idx = repro.api.build(
        jnp.asarray(data), jax.random.key(0),
        IndexSpec(kind="streaming", K=4, L=4, c=1.5, beta_override=0.1,
                  Nr=64, leaf_size=32, delta_capacity=256, max_segments=4))
    # Explicit r_min: the r_min=None estimate is batch-dependent, and the
    # oracle comparison needs both sides to start at the same radius.
    base_req = SearchRequest(k=k, r_min=float(idx.r_min_for(k)))

    from repro.serving import ServingRuntime
    # max_wait 1s >> submit spacing: bursts always coalesce into one
    # deterministic bucket (closed loop — flush() drains the remainder).
    rt = ServingRuntime(idx, k=k, max_batch=burst, pad_to=burst,
                        max_wait_ms=1000.0, request=base_req)
    rt.warmup(d)

    table = Table("serving_load",
                  ["phase", "queries", "qps", "p50_ms", "p99_ms",
                   "shed", "identical"])
    identical, serve_s, served = True, 0.0, 0
    for round_ in range(cfg["rounds"]):
        queries = make_queries(data, burst, seed=100 + round_)
        t0 = time.perf_counter()
        out = rt.serve([(time.perf_counter(), q,
                         time.perf_counter() + cfg["deadline_s"])
                        for q in queries])
        serve_s += time.perf_counter() - t0
        served += len(out)
        identical &= _oracle_check(rt, idx, base_req, list(queries), out,
                                   bitwise=True)

        # mixed mutations: fresh rows, churned ids, and never-inserted ids
        # (counted no-ops); both are barriers, so the next burst's oracle
        # state is simply the index after them.
        fresh = make_dataset("deep-like", cfg["upserts_per_round"],
                             seed=200 + round_)
        gids = rt.upsert(fresh)
        rt.delete(np.concatenate([
            gids[:: max(len(gids) // cfg["deletes_per_round"], 1)],
            rng.integers(0, n, 8),
            np.arange(10**8, 10**8 + 4)]))        # no-op ids

    # padded burst: odd size < bucket exercises the pad lanes; the answer
    # set must survive padding even if lane-level floats reassociate.
    queries = make_queries(data, cfg["odd_burst"], seed=999)
    t0 = time.perf_counter()
    out = rt.serve([(time.perf_counter(), q) for q in queries])
    serve_s += time.perf_counter() - t0
    served += len(out)
    padded_ok = _oracle_check(rt, idx, base_req, list(queries), out,
                              bitwise=False)

    # forced compaction concurrent with a pinned reader: the pinned epoch
    # must answer bit-identically across the swap (RCU), and post-compaction
    # live traffic still matches the oracle.
    probe = jnp.asarray(make_queries(data, 8, seed=555))
    epoch = rt.pin()
    before = epoch.search(probe, dataclasses.replace(base_req, n_active=8))
    compacted = rt.compact(force=True)
    after = epoch.search(probe, dataclasses.replace(base_req, n_active=8))
    pinned_ok = bool(
        np.array_equal(np.asarray(before.ids), np.asarray(after.ids))
        and np.array_equal(np.asarray(before.dists),
                           np.asarray(after.dists)))
    rt.release(epoch)

    queries = make_queries(data, burst, seed=777)
    t0 = time.perf_counter()
    out = rt.serve([(time.perf_counter(), q) for q in queries])
    serve_s += time.perf_counter() - t0
    served += len(out)
    identical &= _oracle_check(rt, idx, base_req, list(queries), out,
                               bitwise=True)

    s = rt.stats.summary()
    qps = served / max(serve_s, 1e-9)
    table.add("mixed", served, qps, s["p50_ms"], s["p99_ms"],
              s["shed_total"], identical and padded_ok and pinned_ok)

    payload = dict(
        bench="serving_load", workload=cfg,
        backend=jax.default_backend(),
        closed_loop_qps=qps, served=served,
        identical_to_oracle=bool(identical),
        padded_burst_ok=bool(padded_ok),
        pinned_epoch_survives_compaction=bool(pinned_ok),
        compacted=bool(compacted),
        stats=s)
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)

    if s["shed_total"] != 0:
        raise AssertionError(f"smoke load shed requests: {s['shed']}")
    if not (identical and padded_ok and pinned_ok):
        raise AssertionError(
            f"serving answers diverged from the serialized oracle: "
            f"{payload}")
    if not s["p99_ms"] <= cfg["p99_budget_ms"]:
        raise AssertionError(
            f"p99 {s['p99_ms']:.1f}ms over budget {cfg['p99_budget_ms']}ms")
    table.emit(out_dir)
    return table


def serving_load() -> Table:
    """run.py --smoke entry point."""
    return run_serving_load()
