"""Recall/QPS Pareto smoke: DET-LSH vs baselines through one protocol (CI).

Runs ``repro.eval.pareto`` at smoke scale: a (K, L, leaf_size) x
(M, engine, probe_depth) sweep for DET-LSH plus hnsw / ivf-pq / pm-lsh /
brute-force variants, every method measured through ``AnnIndex.search``.
Writes the full curve set to BENCH_pareto.json; run.py --smoke gates on

  * det_dominates_brute.ok — some DET-LSH point must reach recall >= 0.9
    doing strictly less work per query (mean SearchStats.n_candidates)
    than the exact scan.  Work, not wall clock: at smoke scale a dense
    scan is one BLAS matmul and CPU QPS would "refute" every sublinear
    method ever published (the paper's candidate-count figures, 17-18,
    exist for the same reason).

  PYTHONPATH=src python -m benchmarks.run --smoke
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, make_dataset, make_queries

SMOKE = dict(dataset="msong-like", n=8192, nq=16, k=10, repeat=2,
             min_recall=0.9)


def _baseline_variants(data, key):
    """(label, index, build_seconds, params) per method — knob sweeps via
    rebuild or ``dataclasses.replace`` (cheap field-only variants)."""
    import dataclasses
    from repro.baselines import HNSW, IVFPQ, PMLSH

    out = {"hnsw": [], "ivf-pq": [], "pm-lsh": []}
    t0 = time.perf_counter()
    hnsw = HNSW.build(np.asarray(data), None, M=12, ef_construction=48)
    t_hnsw = time.perf_counter() - t0
    for ef in (16, 64):
        out["hnsw"].append((f"ef{ef}",
                            dataclasses.replace(hnsw, ef_search=ef),
                            t_hnsw, dict(ef_search=ef)))
    t0 = time.perf_counter()
    pq = IVFPQ.build(data, key, nlist=64, M=4, nprobe=4, rerank=128)
    t_pq = time.perf_counter() - t0
    for nprobe in (4, 8):
        out["ivf-pq"].append((f"np{nprobe}",
                              dataclasses.replace(pq, nprobe=nprobe),
                              t_pq, dict(nprobe=nprobe)))
    for beta in (0.02, 0.1):
        t0 = time.perf_counter()
        pm = PMLSH.build(data, key, beta=beta)
        out["pm-lsh"].append((f"b{beta}", pm, time.perf_counter() - t0,
                              dict(beta=beta)))
    return out


def pareto_smoke() -> Table:
    from repro.api import IndexSpec
    from repro.eval import run_pareto

    cfg = SMOKE
    data = jnp.asarray(make_dataset(cfg["dataset"], cfg["n"]))
    queries = jnp.asarray(make_queries(np.asarray(data), cfg["nq"]))
    key = jax.random.PRNGKey(0)

    specs = [IndexSpec(K=4, L=4, c=1.5, beta_override=0.05, Nr=64,
                       leaf_size=32),
             IndexSpec(K=8, L=4, c=1.5, beta_override=0.1, Nr=128,
                       leaf_size=64),
             IndexSpec(K=8, L=8, c=1.5, beta_override=0.1, Nr=128,
                       leaf_size=64)]
    # probe_depth joins (M, engine) as a first-class sweep axis: p4 points
    # are the multi-probe curves (near-miss leaf admission), p0 the classic
    # radius rounds.  max_rounds stays fixed so the point count holds at 24.
    out = run_pareto(data, queries, key, k=cfg["k"], specs=specs,
                     Ms=(4, 16), max_rounds=(48,),
                     engines=("fused", "vmap"),
                     probe_depths=(0, 4),
                     baselines=_baseline_variants(data, key),
                     repeat=cfg["repeat"], min_recall=cfg["min_recall"])
    out["dataset"] = cfg["dataset"]
    with open("BENCH_pareto.json", "w") as f:
        json.dump(out, f, indent=2)

    tab = Table("pareto_smoke",
                ["method", "label", "recall", "qps", "work_per_q"])
    for p in out["points"]:
        tab.add([p["method"], p["label"], f"{p['recall']:.3f}",
                 f"{p['qps']:.1f}", f"{p['work_per_query']:.0f}"])
    gate = out["det_dominates_brute"]
    tab.add(["gate", "det_dominates_brute", str(gate["ok"]),
             f"{gate.get('best_recall', float('nan')):.3f}",
             f"{gate.get('best_work', float('nan')):.0f}"])
    return tab
