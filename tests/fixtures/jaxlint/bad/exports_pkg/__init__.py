"""Known-bad corpus for the export-drift rule (JX501)."""

import importlib

_LAZY = {
    "thing": "fixtures.mod_a",
    "hidden": "fixtures.mod_b",  # EXPECT: export-drift
}


def __getattr__(name):
    if name in _LAZY:
        return importlib.import_module(_LAZY[name])
    raise AttributeError(name)


__all__ = [
    "thing",
    "ghost",  # EXPECT: export-drift
]
