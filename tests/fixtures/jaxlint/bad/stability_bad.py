"""Known-bad corpus for the unstable-sort rule (JX201)."""

import jax
import jax.numpy as jnp
import numpy as np


def order_jnp(v):
    return jnp.argsort(v)  # EXPECT: unstable-sort


def sort_jnp(v):
    return jnp.sort(v, axis=0)  # EXPECT: unstable-sort


def order_np(v):
    return np.argsort(v)  # EXPECT: unstable-sort


def sort_np_wrong_kind(v):
    return np.sort(v, kind="quicksort")  # EXPECT: unstable-sort


def sort_lax(d, i):
    return jax.lax.sort((d, i), num_keys=2)  # EXPECT: unstable-sort
