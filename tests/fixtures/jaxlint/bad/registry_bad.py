"""Known-bad corpus for the registry-discipline rules (JX401/JX402)."""


def pick_engine(index, engine):
    if engine == "fused":  # EXPECT: engine-bypass
        return index.fused_path()
    if engine in ("vmap", "pdet"):  # EXPECT: engine-bypass
        return index.other_path()
    return index.default_path()


def legacy_call(index, q):
    return index.query(q, r_min=1.0, M=8)  # EXPECT: deprecated-shim
