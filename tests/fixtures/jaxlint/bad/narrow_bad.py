"""Known-bad corpus for the narrow-storage widening rule (JX301)."""


def leaf_span(leaf_lo, leaf_hi):
    return leaf_hi - leaf_lo  # EXPECT: narrow-arith


def next_leaf(index):
    return index.leaf_hi + 1  # EXPECT: narrow-arith


def code_shift(codes_sorted):
    return codes_sorted * 2  # EXPECT: narrow-arith


def subscripted(leaf_lo, i):
    return leaf_lo[i] - 1  # EXPECT: narrow-arith


def augmented(leaf_hi):
    leaf_hi += 1  # EXPECT: narrow-arith
    return leaf_hi


def negated(leaf_lo):
    return -leaf_lo  # EXPECT: narrow-arith
