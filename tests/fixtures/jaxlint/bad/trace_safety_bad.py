"""Known-bad corpus for the trace-safety rules (JX101-JX104).

Every flagged line carries an ``# EXPECT: <rule>`` marker; the corpus test
asserts the analyzer reports exactly these (line, rule) pairs.
"""

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def bad_np_call(x):
    y = jnp.abs(x)
    return np.square(y)  # EXPECT: trace-np-call


@jax.jit
def bad_coerce(x):
    s = jnp.sum(x)
    return float(s)  # EXPECT: trace-scalar-coerce


@jax.jit
def bad_item(x):
    return jnp.max(x).item()  # EXPECT: trace-item-call


@jax.jit
def bad_branch(x):
    if jnp.any(x > 0):  # EXPECT: trace-py-branch
        return x
    return -x


def _helper(q):
    s = jnp.sum(q)
    return int(s)  # EXPECT: trace-scalar-coerce


@jax.jit
def entry_calls_helper(q):
    return _helper(q)


def _mapped(row):
    return np.log(jnp.asarray(row))  # EXPECT: trace-np-call


def fan_out(batch):
    return jax.vmap(_mapped)(batch)
