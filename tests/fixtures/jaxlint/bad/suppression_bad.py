"""Known-bad corpus for the suppression mechanics (rule ``suppression``).

An unjustified suppression is inert (the underlying finding still fires)
and is itself reported; naming an unknown rule is also reported.
"""

import numpy as np


def unjustified(v):
    return np.argsort(v)  # jaxlint: disable=unstable-sort  # EXPECT: suppression, unstable-sort


def unknown_rule(v):
    return np.argsort(v, kind="stable")  # jaxlint: disable=no-such-rule -- misspelled  # EXPECT: suppression
