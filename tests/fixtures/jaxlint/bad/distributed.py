"""Known-bad corpus for the pdet probe-plumbing guard (JX601).

The file is named ``distributed.py`` on purpose: the rule scopes to the
sharded-engine module by basename.
"""


def pdet_query(index, q, probe_depth=0):  # EXPECT: pdet-probe-plumbing
    return index.search(q, probes=probe_depth)


def forward_probes(index, q, request):
    return index.search(q, probe_depth=request.probe_depth)  # EXPECT: pdet-probe-plumbing


def stash_probes(request):
    probe_depth = request.probes  # EXPECT: pdet-probe-plumbing
    return probe_depth
