"""Known-bad corpus for the hygiene rules (JX701/JX702)."""

import os  # EXPECT: unused-import
import numpy as np  # EXPECT: unused-import


def banner():
    return f"no placeholders here"  # EXPECT: pointless-fstring
