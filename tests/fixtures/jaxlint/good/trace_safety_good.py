"""Known-good corpus for the trace-safety rules: every idiom here is
trace-safe and must produce zero findings."""

import functools

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def shape_is_static(x):
    # .shape/.ndim/len() launder taint: static under trace.
    n = x.shape[0]
    if n > 4:
        x = x[:4]
    return float(n) + jnp.sum(x)


@functools.partial(jax.jit, static_argnames=("k",))
def static_argname_branch(x, k):
    if k > 8:  # k is static under this jit
        k = 8
    return jax.lax.top_k(x, k)


@jax.jit
def np_on_static_tables(x):
    # Trace-time weight table from shapes only: np on static values is fine
    # (the detree.interleave_keys idiom).
    w = np.arange(x.shape[-1], dtype=np.int32)
    return x * jnp.asarray(w)


def host_fast_path(sample):
    # The repo's tracer-guard idiom: branching on trace-ness is explicit
    # author intent and exempts the guarded subtree.
    if (not isinstance(sample, jax.core.Tracer)
            and jax.default_backend() == "cpu"):
        return jnp.asarray(np.square(np.asarray(sample)))
    return sample * sample


@jax.jit
def device_branchless(x):
    y = jnp.sum(x)
    return jnp.where(y > 0, x, -x)


@jax.jit
def calls_host_path(x):
    return host_fast_path(x)
