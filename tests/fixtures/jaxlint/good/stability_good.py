"""Known-good corpus for the unstable-sort rule."""

import jax
import jax.numpy as jnp
import numpy as np


def order_jnp(v):
    return jnp.argsort(v, stable=True)


def sort_jnp(v):
    return jnp.sort(v, axis=0, stable=True)


def order_np(v):
    return np.argsort(v, kind="stable")


def sort_np_mergesort(v):
    return np.sort(v, kind="mergesort")


def lex(keys):
    return np.lexsort(keys)  # lexsort is always stable


def sort_lax(d, i):
    return jax.lax.sort((d, i), num_keys=2, is_stable=True)


def values_only(v):
    # jaxlint: disable=unstable-sort -- values-only order statistics; the
    #   permutation is never observed, stability cannot matter.
    return np.sort(v)
