"""Known-good corpus for the hygiene rules."""

import os
import numpy as np

try:
    import hypothesis  # availability probe: exempt inside try/ImportError
except ImportError:
    hypothesis = None


def where():
    return os.getcwd()


def zeros(n):
    return np.zeros(n)


def banner(name):
    return f"hello {name}"
