"""Known-good corpus for the export-drift rule: __all__, the lazy table,
and eager defs agree."""

import importlib

_LAZY = {
    "thing": "fixtures.mod_a",
    "other": "fixtures.mod_b",
}


def __getattr__(name):
    if name in _LAZY:
        return importlib.import_module(_LAZY[name])
    raise AttributeError(name)


def eager_helper():
    return None


__all__ = ["thing", "other", "eager_helper"]
