"""Known-good corpus for the pdet probe-plumbing guard: *reading* the
request's probe_depth to refuse it is the sanctioned pattern."""


def pdet_query(index, q, request):
    if request.probe_depth:
        raise NotImplementedError(
            "multi-probe on the sharded pdet engine needs a device-count-"
            "invariant global slack ranking; use the fused engine")
    return index.search(q)
