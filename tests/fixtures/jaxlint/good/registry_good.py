"""Known-good corpus for the registry-discipline rules."""


def resolve_engine(request):
    return "fused" if request.probes else "vmap"


def dispatch(index, request):
    # Comparing the *resolved* engine inside a function that consulted the
    # registry is the sanctioned thin-wrapper pattern.
    engine = resolve_engine(request)
    if engine == "fused":
        return index.fused_path()
    return index.vmap_path()


def check_outcome(result):
    assert result.stats.engine == "pdet"  # verification, not dispatch


def modern_call(index, request):
    return index.search(request)
