"""Known-good corpus for the narrow-storage widening rule."""

import jax.numpy as jnp


def leaf_span(leaf_lo, leaf_hi):
    return leaf_hi.astype(jnp.int32) - leaf_lo.astype(jnp.int32)


def next_leaf(index):
    return index.leaf_hi.astype(jnp.int32) + 1


def shape_math(leaf_lo):
    # Metadata reads are not narrow-storage reads.
    return leaf_lo.shape[0] + 1


def plain_read(codes_sorted, order):
    # Indexing without arithmetic keeps the narrow dtype on purpose.
    return codes_sorted[order]
