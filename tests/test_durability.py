"""Durability subsystem unit + edge-case tests (docs/DESIGN.md §13).

WAL mechanics (framing, torn-tail repair, rotation, checkpoint
truncation, fsync policies), atomic checkpoints, and the recovery edge
cases the crash matrix doesn't reach: empty WAL, WAL without a
checkpoint, checkpoint with an empty tail, duplicate replay after a
crash during checkpoint install, and a corrupt newest checkpoint falling
back to the previous one.  The randomized crash-point matrix itself
lives in test_durability_crash.py.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.api import SearchRequest
from repro.core import derive_params
from repro.durability import (DurableIndex, FSYNC_ALWAYS, FSYNC_INTERVAL,
                              FSYNC_OFF, RecoveryError, WalError, WalRecord,
                              WriteAheadLog, recover, scan_wal)
from repro.durability.wal import encode_record
from repro.serving import CHECKPOINT_INSTALL, FaultPlan, InjectedFault
from repro.streaming import StreamingDETLSH

D = 8
SAT = dict(r_min=1e6, M=10**6)
PARAMS = derive_params(K=2, c=1.5, L=2, beta_override=0.1)
KW = dict(Nr=8, leaf_size=8, delta_capacity=16, max_segments=2)


def make_index(rng, n=48):
    data = rng.standard_normal((n, D)).astype(np.float32)
    return StreamingDETLSH.build(jnp.asarray(data), jax.random.key(0),
                                 PARAMS, **KW)


# ---------------------------------------------------------------------------
# WAL mechanics
# ---------------------------------------------------------------------------

def test_wal_append_scan_roundtrip(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal"))
    a = np.arange(12, dtype=np.int64).reshape(3, 4)
    b = np.linspace(0, 1, 5, dtype=np.float32)
    lsn0 = wal.append("upsert", {"note": "x"}, {"gids": a, "vecs": b})
    lsn1 = wal.append("seal")
    assert (lsn0, lsn1) == (0, 1)
    wal.close()

    scan = scan_wal(str(tmp_path / "wal"))
    assert not scan.torn and scan.last_lsn == 1
    r0, r1 = scan.records
    assert r0.op == "upsert" and r0.fields == {"note": "x"}
    np.testing.assert_array_equal(r0.arrays["gids"], a)
    np.testing.assert_array_equal(r0.arrays["vecs"], b)
    assert r0.arrays["gids"].dtype == np.int64
    assert r0.arrays["vecs"].dtype == np.float32
    assert r1.op == "seal" and r1.fields == {} and r1.arrays == {}


def test_wal_reopen_continues_lsn(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal"))
    wal.append("seal")
    wal.append("seal")
    wal.close()
    wal2 = WriteAheadLog(str(tmp_path / "wal"))
    assert wal2.append("seal") == 2        # continues after what's on disk
    wal2.close()
    assert [r.lsn for r in scan_wal(str(tmp_path / "wal")).records] == \
        [0, 1, 2]


@pytest.mark.parametrize("cut", [1, 4, 9, 17])
def test_wal_torn_tail_truncated_to_record_boundary(tmp_path, cut):
    """Chopping ``cut`` bytes off the tail loses at most the last record;
    repair truncates to the boundary and a re-scan is clean."""
    wal = WriteAheadLog(str(tmp_path / "wal"))
    for i in range(4):
        wal.append("delete", arrays={"gids": np.array([i], np.int64)})
    wal.close()
    [fname] = os.listdir(tmp_path / "wal")
    fpath = tmp_path / "wal" / fname
    size = os.path.getsize(fpath)
    with open(fpath, "r+b") as f:
        f.truncate(size - cut)

    scan = scan_wal(str(tmp_path / "wal"), repair=True)
    assert scan.torn and scan.truncated_bytes > 0
    assert 3 <= len(scan.records) <= 4 and scan.records[0].lsn == 0
    lsns = [r.lsn for r in scan.records]
    assert lsns == list(range(len(lsns)))  # a prefix, never a gap
    assert not scan_wal(str(tmp_path / "wal")).torn   # repair healed it


def test_wal_corrupt_record_drops_it_and_later_segments(tmp_path):
    """A bit flip inside segment k invalidates its tail AND every later
    segment (their lsns would leave a gap) — repair removes them."""
    wal = WriteAheadLog(str(tmp_path / "wal"), segment_bytes=200)
    for i in range(6):                     # small cap => multiple segments
        wal.append("delete", arrays={"gids": np.arange(8, dtype=np.int64)})
    wal.close()
    segs = sorted(os.listdir(tmp_path / "wal"))
    assert len(segs) >= 3
    target = tmp_path / "wal" / segs[1]
    blob = bytearray(open(target, "rb").read())
    blob[len(blob) // 2] ^= 0x40
    with open(target, "wb") as f:
        f.write(bytes(blob))

    scan = scan_wal(str(tmp_path / "wal"), repair=True)
    assert scan.torn and scan.dropped_segments == len(segs) - 2
    lsns = [r.lsn for r in scan.records]
    assert lsns == list(range(len(lsns))) and len(lsns) < 6
    after = scan_wal(str(tmp_path / "wal"))
    assert not after.torn
    assert [r.lsn for r in after.records] == lsns


def test_wal_rotation_and_truncate_through(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal"), segment_bytes=200)
    for i in range(6):
        wal.append("delete", arrays={"gids": np.arange(8, dtype=np.int64)})
    n_files = len(os.listdir(tmp_path / "wal"))
    assert n_files >= 3                    # the cap forced rotations
    removed = wal.truncate_through(2)      # covers lsns 0..2
    assert removed >= 1
    wal.close()
    scan = scan_wal(str(tmp_path / "wal"))
    assert not scan.torn
    assert all(r.lsn > 2 for r in scan.records)     # covered ones are gone
    assert {r.lsn for r in scan.records} == {3, 4, 5}


def test_wal_fsync_policies(tmp_path):
    with pytest.raises(WalError, match="unknown fsync policy"):
        WriteAheadLog(str(tmp_path / "w0"), fsync="sometimes")

    always = WriteAheadLog(str(tmp_path / "w1"), fsync=FSYNC_ALWAYS)
    for _ in range(3):
        always.append("seal")
    assert always.fsyncs == 3              # one per append
    always.close()

    off = WriteAheadLog(str(tmp_path / "w2"), fsync=FSYNC_OFF)
    for _ in range(3):
        off.append("seal")
    assert off.fsyncs == 0
    off.sync()                             # explicit barrier always syncs
    assert off.fsyncs == 1
    off.close()
    assert off.fsyncs == 1                 # close honors 'off'

    interval = WriteAheadLog(str(tmp_path / "w3"), fsync=FSYNC_INTERVAL,
                             fsync_interval_bytes=150)
    interval.append("seal")                # ~60B: below the interval
    assert interval.fsyncs == 0
    for _ in range(2):
        interval.append("seal")            # crosses 150B
    assert interval.fsyncs == 1
    interval.close()


def test_wal_record_roundtrip_rejects_trailing_garbage():
    blob = encode_record(WalRecord(lsn=0, op="seal"))
    from repro.durability.wal import decode_payload
    payload = blob[8:]                     # strip the crc+len frame
    assert decode_payload(payload).op == "seal"
    with pytest.raises(ValueError, match="trailing"):
        decode_payload(payload + b"x")


# ---------------------------------------------------------------------------
# Recovery edge cases
# ---------------------------------------------------------------------------

def test_recover_empty_root_raises(tmp_path):
    with pytest.raises(RecoveryError, match="no checkpoints"):
        recover(str(tmp_path / "nothing"))


def test_recover_wal_only_raises(tmp_path):
    """A WAL with no checkpoint base cannot rebuild an index — recovery
    must say so, not return something empty."""
    root = tmp_path / "root"
    wal = WriteAheadLog(str(root / "wal"))
    wal.append("delete", arrays={"gids": np.array([1], np.int64)})
    wal.close()
    with pytest.raises(RecoveryError, match="WAL alone cannot rebuild"):
        recover(str(root))


def test_recover_checkpoint_only_empty_tail(tmp_path, rng):
    """Clean shutdown right after a checkpoint: recovery stands on the
    checkpoint, replays nothing, and is bit-identical."""
    dix = DurableIndex.create(make_index(rng), str(tmp_path / "root"))
    dix.upsert(rng.standard_normal((8, D)).astype(np.float32))
    dix.checkpoint()
    d0 = dix.state_digest()
    dix.close()

    rec = recover(str(tmp_path / "root"))
    assert rec.last_recovery.n_replayed == 0
    assert rec.last_recovery.checkpoint == "ckpt_00000001"
    assert rec.state_digest() == d0
    rec.close()


def test_recover_replays_tail_bit_identically(tmp_path, rng):
    dix = DurableIndex.create(make_index(rng), str(tmp_path / "root"))
    X = rng.standard_normal((40, D)).astype(np.float32)
    dix.upsert(X[:20])
    dix.seal()
    dix.upsert(X[20:])
    dix.delete(np.arange(5))
    d0 = dix.state_digest()
    n0 = dix.n_points
    dix.close()                            # crash: tail never checkpointed

    rec = recover(str(tmp_path / "root"))
    assert [op for _, op in rec.last_recovery.replayed] == \
        ["upsert", "seal", "upsert", "delete"]
    assert rec.state_digest() == d0 and rec.n_points == n0
    # and the recovered index keeps working: search + further mutation
    q = rng.standard_normal((2, D)).astype(np.float32)
    req = SearchRequest(k=3, **SAT)
    r1 = dix.search(q, request=req)
    r2 = rec.search(q, request=req)
    np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    rec.upsert(rng.standard_normal((4, D)).astype(np.float32))
    rec.close()


def test_grow_id_capacity_logged_and_replayed(tmp_path, rng):
    dix = DurableIndex.create(make_index(rng), str(tmp_path / "root"))
    cap = dix.index.id_capacity
    dix.grow_id_capacity(cap * 2)
    with pytest.raises(ValueError, match="cannot shrink"):
        dix.grow_id_capacity(cap)          # rejected => must NOT be logged
    d0 = dix.state_digest()
    dix.close()

    rec = recover(str(tmp_path / "root"))
    assert [op for _, op in rec.last_recovery.replayed] == ["grow"]
    assert rec.index.id_capacity == cap * 2
    assert rec.state_digest() == d0
    rec.close()


def test_create_refuses_existing_durability_root(tmp_path, rng):
    root = str(tmp_path / "root")
    DurableIndex.create(make_index(rng), root).close()
    with pytest.raises(ValueError, match="already holds checkpoints"):
        DurableIndex.create(make_index(rng), root)


def test_corrupt_newest_checkpoint_falls_back(tmp_path, rng):
    """Digest-failing newest checkpoint => recovery silently stands on the
    previous one and replays a LONGER tail — same final state."""
    dix = DurableIndex.create(make_index(rng), str(tmp_path / "root"),
                              keep_checkpoints=2)
    dix.upsert(rng.standard_normal((8, D)).astype(np.float32))
    dix.checkpoint()                       # ckpt_1 (ckpt_0 retained)
    dix.delete(np.arange(3))
    d0 = dix.state_digest()
    dix.close()

    newest = os.path.join(str(tmp_path / "root"), "checkpoints",
                          "ckpt_00000001", "common.npz")
    blob = bytearray(open(newest, "rb").read())
    blob[len(blob) // 2] ^= 0x01
    with open(newest, "wb") as f:
        f.write(bytes(blob))

    rec = recover(str(tmp_path / "root"))
    assert rec.last_recovery.checkpoint == "ckpt_00000000"
    assert [n for n, _ in rec.last_recovery.skipped_checkpoints] == \
        ["ckpt_00000001"]
    assert "sha256" in rec.last_recovery.skipped_checkpoints[0][1]
    # The retention window keeps the WAL records the fallback base needs
    # (truncation only goes through the OLDEST retained checkpoint's
    # covered lsn), so the longer replay lands on the identical state.
    assert [op for _, op in rec.last_recovery.replayed] == \
        ["upsert", "delete"]
    assert rec.state_digest() == d0
    rec.close()


def test_duplicate_replay_after_checkpoint_publish_crash(tmp_path, rng):
    """Crash BEFORE the new checkpoint publishes: the old checkpoint must
    still anchor a full-tail replay (nothing applied twice)."""
    plan = FaultPlan()
    dix = DurableIndex.create(make_index(rng), str(tmp_path / "root"),
                              fault_plan=plan)
    dix.upsert(rng.standard_normal((8, D)).astype(np.float32))
    d0 = dix.state_digest()
    plan.arm(CHECKPOINT_INSTALL)           # first crossing = publish
    with pytest.raises(InjectedFault):
        dix.checkpoint()
    dix.close()

    rec = recover(str(tmp_path / "root"))
    assert rec.last_recovery.checkpoint == "ckpt_00000000"
    assert [op for _, op in rec.last_recovery.replayed] == ["upsert"]
    assert rec.state_digest() == d0 and rec.n_points == dix.n_points
    rec.close()


def test_duplicate_replay_after_checkpoint_commit_crash(tmp_path, rng):
    """Crash AFTER publish but BEFORE the WAL commit record: the new
    checkpoint is valid and newest, and the stale WAL records (lsn <=
    covered) must be skipped, not applied twice."""
    plan = FaultPlan()
    dix = DurableIndex.create(make_index(rng), str(tmp_path / "root"),
                              fault_plan=plan)
    dix.upsert(rng.standard_normal((8, D)).astype(np.float32))
    d0 = dix.state_digest()
    n0 = dix.n_points
    plan.arm(CHECKPOINT_INSTALL, skip=1)   # second crossing = commit
    with pytest.raises(InjectedFault):
        dix.checkpoint()
    dix.close()

    rec = recover(str(tmp_path / "root"))
    assert rec.last_recovery.checkpoint == "ckpt_00000001"
    assert rec.last_recovery.n_replayed == 0       # lsn <= covered: skipped
    assert rec.state_digest() == d0 and rec.n_points == n0
    rec.close()


def test_recovered_root_keeps_checkpointing(tmp_path, rng):
    """next_checkpoint_id resumes past every on-disk directory — recovery
    then checkpointing must never overwrite an existing checkpoint."""
    dix = DurableIndex.create(make_index(rng), str(tmp_path / "root"))
    dix.upsert(rng.standard_normal((4, D)).astype(np.float32))
    dix.checkpoint()
    dix.close()
    rec = recover(str(tmp_path / "root"))
    rec.upsert(rng.standard_normal((4, D)).astype(np.float32))
    path = rec.checkpoint()
    assert os.path.basename(path) == "ckpt_00000002"
    rec.close()


# ---------------------------------------------------------------------------
# DurableIndex policy + stats
# ---------------------------------------------------------------------------

def test_maybe_checkpoint_policy(tmp_path, rng):
    dix = DurableIndex.create(make_index(rng), str(tmp_path / "root"),
                              checkpoint_bytes=1)   # any record is enough
    assert not dix.maybe_checkpoint()      # no new records since ckpt 0
    dix.upsert(rng.standard_normal((4, D)).astype(np.float32))
    assert dix.maybe_checkpoint()          # bytes due + new record
    assert not dix.maybe_checkpoint()      # nothing new again
    assert dix.checkpoints_written == 2    # create() + the policy one
    dix.close()


def test_durability_stats_and_delegation(tmp_path, rng):
    dix = DurableIndex.create(make_index(rng), str(tmp_path / "root"))
    dix.upsert(rng.standard_normal((4, D)).astype(np.float32))
    s = dix.durability_stats()
    assert s["wal_records"] >= 2           # checkpoint marker + upsert
    assert s["wal_bytes"] > 0 and s["checkpoints_written"] == 1
    assert s["recovery_replayed"] == 0
    # MutableAnnIndex surface + delegation to the wrapped index
    assert dix.n_points == dix.index.n_points
    assert dix.index_size_bytes() > 0
    assert dix.r_min_for(3) > 0
    assert dix.manifest is dix.index.manifest      # __getattr__ delegation
    with pytest.raises(AttributeError):
        dix._not_a_real_attribute
    dix.close()


def test_top_level_exports():
    assert repro.DurableIndex is DurableIndex
    assert repro.recover is recover
    assert repro.durability.WriteAheadLog is WriteAheadLog


# ---------------------------------------------------------------------------
# ServingRuntime integration (docs/DESIGN.md §13)
# ---------------------------------------------------------------------------

@pytest.mark.timeout(300)
def test_runtime_durability_counters_and_auto_checkpoint(tmp_path, rng):
    """Serving a DurableIndex: mutations hit the WAL, RuntimeStats mirrors
    the durability counters, and the background checkpoint policy fires
    once enough WAL bytes accumulate."""
    import time
    from repro.serving import Answer, ServingRuntime
    dix = DurableIndex.create(make_index(rng), str(tmp_path / "root"),
                              checkpoint_bytes=256)   # tiny: upserts trip it
    rt = ServingRuntime(dix, k=3, max_batch=4, pad_to=4,
                        request=SearchRequest(k=3, **SAT))
    rt.upsert(rng.standard_normal((8, D)).astype(np.float32))
    rt.delete(np.arange(2))
    s = rt.stats.summary()
    assert s["wal_bytes"] > 0 and s["fsyncs"] >= 0
    assert s["checkpoints"] >= 1           # the 256-byte policy tripped
    assert s["checkpoint_failures"] == 0
    assert s["recovery_replayed"] == 0     # fresh root, not a recovery
    assert dix.checkpoints_written >= 2    # create() + the background one
    # the runtime still answers correctly through the wrapper
    q = rng.standard_normal((2, D)).astype(np.float32)
    out = rt.serve([(time.perf_counter(), qq) for qq in q])
    assert len(out) == 2 and all(isinstance(o, Answer) for o in out)
    dix.close()


@pytest.mark.timeout(300)
def test_runtime_recovery_on_start(tmp_path, rng):
    """Kill a served DurableIndex, recover the root, serve the recovered
    index: stats report the replayed tail and answers are bit-identical."""
    import time
    from repro.serving import ServingRuntime
    dix = DurableIndex.create(make_index(rng), str(tmp_path / "root"))
    rt = ServingRuntime(dix, k=3, max_batch=4, pad_to=4,
                        request=SearchRequest(k=3, **SAT))
    rt.upsert(rng.standard_normal((8, D)).astype(np.float32))
    rt.delete(np.arange(2))
    q = rng.standard_normal((2, D)).astype(np.float32)
    before = rt.serve([(time.perf_counter(), qq) for qq in q])
    dix.wal._f.close()                     # kill without checkpointing

    rec = recover(str(tmp_path / "root"))
    rt2 = ServingRuntime(rec, k=3, max_batch=4, pad_to=4,
                         request=SearchRequest(k=3, **SAT))
    assert rt2.stats.summary()["recovery_replayed"] == \
        rec.last_recovery.n_replayed >= 2
    after = rt2.serve([(time.perf_counter(), qq) for qq in q])
    for a, b in zip(before, after):
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_allclose(a.dists, b.dists, rtol=0, atol=0)
    rec.close()


def test_runtime_checkpoint_failure_is_recorded_not_fatal(tmp_path, rng):
    """An injected SNAPSHOT_WRITE fault inside the background checkpoint
    is counted and served around — mutations keep landing in the WAL."""
    from repro.serving import SNAPSHOT_WRITE, ServingRuntime
    plan = FaultPlan()
    dix = DurableIndex.create(make_index(rng), str(tmp_path / "root"),
                              checkpoint_bytes=256, fault_plan=plan)
    rt = ServingRuntime(dix, k=3, max_batch=4, pad_to=4,
                        request=SearchRequest(k=3, **SAT))
    plan.arm(SNAPSHOT_WRITE)
    rt.upsert(rng.standard_normal((8, D)).astype(np.float32))
    s = rt.stats.summary()
    assert s["checkpoint_failures"] == 1
    assert isinstance(rt.last_checkpoint_error, InjectedFault)
    # durability is degraded (longer replay), never lost: recovery works
    dix.wal._f.close()
    rec = recover(str(tmp_path / "root"))
    assert any(op == "upsert" for _, op in rec.last_recovery.replayed)
    rec.close()
