"""The sharded PDET runtime behind ``repro.api`` (DESIGN.md §7).

The acceptance contract: on a forced multi-device host mesh,
``repro.api.build`` of a spec with a placement returns a ``PDETIndex``
satisfying ``AnnIndex``; searching via engine ``pdet`` returns
*bit-identical* ids/distances to a ``DETLSH`` built from the same spec
minus placement; and save/load round-trips bit-identically, including
loading onto a different device count (reshard on load).

Bit-identity is by construction (exact ``pmin`` merge of the fused round
over a layout-sharded global forest), so it is asserted exactly, never
with tolerances.  Multi-device cases run in subprocesses (XLA fixes the
device count at first init); the same-process variants are marked
``multidevice`` for the dedicated CI job that forces 4 host devices.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.api import (AnnIndex, IndexSpec, PlacementSpec, SearchRequest,
                       resolve_engine)
from tests.conftest import make_clustered, make_queries_near

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

D = 16
SPEC_KW = dict(kind="static", K=4, L=8, c=1.5, beta_override=0.1,
               Nr=32, leaf_size=32)


def _data_and_queries(n=4096, nq=16, seed=0):
    rng = np.random.default_rng(seed)
    data = make_clustered(rng, n, D)
    return data, make_queries_near(data, rng, nq)


def _det_reference(k=10, engine="fused"):
    data, queries = _data_and_queries()
    det = repro.api.build(jnp.asarray(data), jax.random.key(0),
                          IndexSpec(**SPEC_KW))
    res = det.search(jnp.asarray(queries),
                     SearchRequest(k=k, r_min=0.5, engine=engine))
    return np.asarray(res.ids), np.asarray(res.dists)


# ---------------------------------------------------------------------------
# Subprocess harness (forced host-device meshes)
# ---------------------------------------------------------------------------

_PDET_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={nd}"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import json, sys
    sys.path.insert(0, {src!r}); sys.path.insert(0, {repo!r})
    import jax, jax.numpy as jnp, numpy as np
    import repro
    from repro.api import (AnnIndex, IndexSpec, MutableAnnIndex,
                           PlacementSpec, PDETIndex, SearchRequest)
    from tests.test_pdet_api import SPEC_KW, _data_and_queries

    data, queries = _data_and_queries()
    queries = jnp.asarray(queries)
    out = {{}}
    snap = {snap!r}
    if {build}:
        spec = IndexSpec(placement=PlacementSpec(mesh_shape=({shards},),
                                                 mesh_axes=("data",)),
                         **SPEC_KW)
        idx = repro.api.build(jnp.asarray(data), jax.random.key(0), spec)
        out["is_pdet"] = isinstance(idx, PDETIndex)
        out["is_ann"] = isinstance(idx, AnnIndex)
        out["is_mutable"] = isinstance(idx, MutableAnnIndex)
        out["n_points"] = idx.n_points
        if snap:
            idx.save(snap)
    else:
        idx = repro.api.load(snap)
        out["is_pdet"] = isinstance(idx, PDETIndex)
        out["n_shards"] = idx.n_shards
    res = idx.search(queries, SearchRequest(k=10, r_min=0.5))
    out["engine"] = res.stats.engine
    out["ids"] = np.asarray(res.ids).tolist()
    out["dists_bits"] = np.asarray(res.dists).view(np.uint32).tolist()
    out["shard_candidates"] = np.asarray(res.stats.shard_candidates).tolist()
    out["psum_rounds"] = int(res.stats.psum_rounds)
    out["merge_size"] = int(res.stats.merge_size)
    print(json.dumps(out))
""")


def _run_pdet(n_devices, shards, *, snap="", build=True):
    script = _PDET_SCRIPT.format(nd=n_devices, shards=shards, snap=snap,
                                 build=build, repo=REPO,
                                 src=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_pdet_bit_identical_to_detlsh_and_snapshot_reshard(tmp_path):
    """The acceptance criterion, end to end: 4-device build == DETLSH
    bitwise; snapshot loaded onto TWO devices still == DETLSH bitwise."""
    snap = str(tmp_path / "pdet_snap")
    got = _run_pdet(4, 4, snap=snap, build=True)
    assert got["is_pdet"] and got["is_ann"] and not got["is_mutable"]
    assert got["engine"] == "pdet"
    assert got["n_points"] == 4096
    assert len(got["shard_candidates"]) == 4
    assert got["psum_rounds"] >= 1
    ref_ids, ref_dists = _det_reference(k=10, engine="fused")
    assert np.array_equal(np.asarray(got["ids"]), ref_ids)
    assert np.array_equal(
        np.asarray(got["dists_bits"], np.uint32),
        ref_dists.view(np.uint32))

    # Reload on a *different* device count: resharded, answers unchanged.
    reloaded = _run_pdet(2, 2, snap=snap, build=False)
    assert reloaded["is_pdet"] and reloaded["n_shards"] == 2
    assert reloaded["engine"] == "pdet"
    assert len(reloaded["shard_candidates"]) == 2
    assert reloaded["ids"] == got["ids"]
    assert reloaded["dists_bits"] == got["dists_bits"]
    # the snapshot really is per-shard files + a shard map
    manifest = json.load(open(os.path.join(snap, "MANIFEST.json")))
    assert manifest["kind"] == "pdet"
    assert manifest["format_version"] == repro.api.FORMAT_VERSION
    assert [e["file"] for e in manifest["shards"]] == \
        [f"shard_{s:05d}.npz" for s in range(4)]
    assert all(os.path.isfile(os.path.join(snap, e["file"]))
               for e in manifest["shards"])


_SERVE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import json, sys, time
    sys.path.insert(0, {src!r}); sys.path.insert(0, {repo!r})
    import jax, jax.numpy as jnp, numpy as np
    import repro
    from repro.api import IndexSpec, PlacementSpec, SearchRequest
    from repro.serving.lsh_service import LSHService
    from tests.test_pdet_api import SPEC_KW, _data_and_queries

    data, queries = _data_and_queries(nq=11)
    spec = IndexSpec(placement=PlacementSpec(mesh_shape=(4,),
                                             mesh_axes=("data",)),
                     **SPEC_KW)
    idx = repro.api.build(jnp.asarray(data), jax.random.key(0), spec)
    svc = LSHService(idx, k=5, max_batch=8, pad_to=8)
    svc.warmup(data.shape[1])
    results = svc.serve([(time.perf_counter(), q) for q in queries])
    strict = idx.search(jnp.asarray(queries),
                        SearchRequest(k=5, r_min=0.5, mode="strict"))
    fb = idx.search(jnp.asarray(queries),
                    SearchRequest(k=5, r_min=0.5, engine="vmap"))
    print(json.dumps(dict(
        served=len(results), s=svc.stats.summary(),
        adapter=type(svc._index).__name__,
        strict_engine=strict.stats.engine, fb_engine=fb.stats.engine,
        ids=[np.asarray(r[0]).tolist() for r in results])))
""")


@pytest.mark.slow
def test_service_serves_pdet_through_protocols():
    """LSHService drives a PDETIndex purely via AnnIndex (no adapter),
    pad lanes included; strict mode and explicit vmap fall back through
    the registry rules."""
    script = _SERVE_SCRIPT.format(repo=REPO, src=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    got = json.loads(out.stdout.strip().splitlines()[-1])
    assert got["adapter"] == "PDETIndex"     # protocol, not LegacyAdapter
    assert got["served"] == 11
    assert got["s"]["queries"] == 11
    assert got["s"]["pad_queries"] == 5      # 8 + 3(+5 pad)
    assert got["strict_engine"] == "vmap"    # mode fallback (rule 2)
    assert got["fb_engine"] == "vmap"        # explicit engine honored


# ---------------------------------------------------------------------------
# Single-device / no-mesh behavior (always runs in tier-1)
# ---------------------------------------------------------------------------

def test_forced_single_device_mesh_is_pdet_and_bit_identical(tmp_path):
    """An explicit placement is the opt-in: even a 1-device ("forced
    host") mesh routes to the pdet engine, and the answers equal the
    unplaced DETLSH bitwise — the contract's degenerate case."""
    data, queries = _data_and_queries()
    spec = IndexSpec(placement=PlacementSpec(), **SPEC_KW)
    idx = repro.api.build(jnp.asarray(data), jax.random.key(0), spec)
    assert isinstance(idx, AnnIndex)
    assert isinstance(idx, repro.api.PDETIndex)
    res = idx.search(jnp.asarray(queries), SearchRequest(k=10, r_min=0.5))
    assert res.stats.engine == "pdet"
    assert np.asarray(res.stats.shard_candidates).shape == (1,)
    assert res.stats.merge_size == 16 * 4096
    ref_ids, ref_dists = _det_reference(k=10, engine="fused")
    np.testing.assert_array_equal(np.asarray(res.ids), ref_ids)
    assert np.array_equal(np.asarray(res.dists).view(np.uint32),
                          ref_dists.view(np.uint32))

    idx.save(tmp_path / "snap")
    loaded = repro.api.load(tmp_path / "snap")
    assert isinstance(loaded, repro.api.PDETIndex)
    lres = loaded.search(jnp.asarray(queries),
                         SearchRequest(k=10, r_min=0.5))
    np.testing.assert_array_equal(np.asarray(lres.ids),
                                  np.asarray(res.ids))
    np.testing.assert_array_equal(np.asarray(lres.dists),
                                  np.asarray(res.dists))


def test_placement_spec_validation():
    with pytest.raises(ValueError, match="same length"):
        PlacementSpec(mesh_shape=(2, 2), mesh_axes=("data",))
    with pytest.raises(ValueError, match=">= 1"):
        PlacementSpec(mesh_shape=(0,), mesh_axes=("data",))
    with pytest.raises(ValueError, match="duplicate"):
        PlacementSpec(mesh_shape=(2, 2), mesh_axes=("data", "data"))
    with pytest.raises(ValueError, match="not mesh axes"):
        PlacementSpec(mesh_shape=(2,), mesh_axes=("data",),
                      data_axes=("model",))
    p = PlacementSpec(mesh_shape=(2, 4), mesh_axes=("pod", "data"))
    assert p.n_devices == 8 and p.n_shards == 8
    assert p.data_axes == ("pod", "data")
    q = PlacementSpec(mesh_shape=(2, 4), mesh_axes=("pod", "data"),
                      data_axes=("data",))
    assert q.n_shards == 4
    assert set(q.rules().values()) == {("data",)}
    assert PlacementSpec.from_dict(p.to_dict()) == p


def test_spec_placement_rules():
    with pytest.raises(ValueError, match="static"):
        IndexSpec(kind="streaming", placement=PlacementSpec())
    with pytest.raises(ValueError, match="PlacementSpec"):
        IndexSpec(placement="data")
    # dict form (the snapshot manifest path) normalizes to PlacementSpec
    spec = IndexSpec(placement=PlacementSpec(mesh_shape=(1,)).to_dict())
    assert isinstance(spec.placement, PlacementSpec)
    assert IndexSpec.from_dict(spec.to_dict()) == spec


def test_registry_mesh_rules():
    """Rule 4: pdet is mesh-gated.  'auto' prefers it exactly when a mesh
    is declared; an explicit request without a mesh raises."""
    assert resolve_engine("auto", mode="leaf", batch=64) == "fused"
    assert resolve_engine("auto", mode="leaf", batch=64,
                          mesh_devices=4) == "pdet"
    assert resolve_engine("auto", mode="leaf", batch=64,
                          mesh_devices=1) == "pdet"   # forced 1-device mesh
    assert resolve_engine("auto", mode="strict", batch=64,
                          mesh_devices=4) == "vmap"
    assert resolve_engine("pdet", mode="strict", batch=64,
                          mesh_devices=4) == "vmap"   # mode fallback
    with pytest.raises(ValueError, match="mesh"):
        resolve_engine("pdet", mode="leaf", batch=64)
    # SearchRequest / IndexSpec validation accepts the name eagerly
    SearchRequest(engine="pdet")
    IndexSpec(engine="pdet")


def test_mesh_from_placement_errors_actionably():
    from repro.launch.mesh import mesh_from_placement
    need = len(jax.devices()) + 1
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        mesh_from_placement(PlacementSpec(mesh_shape=(need,),
                                          mesh_axes=("data",)))


def test_layout_pads_to_any_shard_count():
    """A leaf count that does not divide the shard count pads with
    invalid leaves — admitted never, positions preserved — so any
    placement works and no answer can change."""
    from repro.core import DETLSH, derive_params
    from repro.core.distributed import _pad_layout_to_shards
    data, _ = _data_and_queries(n=96)
    p = derive_params(K=4, c=1.5, L=2, beta_override=0.1)
    det = DETLSH.build(jnp.asarray(data), jax.random.key(0), p,
                       leaf_size=32, Nr=32)          # 3 leaves per tree
    forest, plan = det.forest, det.fused_plan()
    padded, pplan = _pad_layout_to_shards(forest, plan, 4)
    assert padded.n_leaves == 4 and padded.point_ids.shape[1] == 4 * 32
    assert pplan.points_sorted.shape[1] == 4 * 32
    # padding is inert: invalid leaves, sentinel ids, untouched prefix
    assert not np.any(np.asarray(padded.leaf_valid)[:, 3:])
    assert np.all(np.asarray(padded.point_ids)[:, 96:] == forest.n)
    assert not np.any(np.asarray(padded.valid)[:, 96:])
    np.testing.assert_array_equal(np.asarray(padded.point_ids)[:, :96],
                                  np.asarray(forest.point_ids))
    np.testing.assert_array_equal(np.asarray(pplan.inv_perm),
                                  np.asarray(plan.inv_perm))
    same_f, same_p = _pad_layout_to_shards(forest, plan, 3)  # divides: noop
    assert same_f is forest and same_p is plan


def test_static_snapshot_rejects_placement_arg(tmp_path):
    data, _ = _data_and_queries(n=256)
    det = repro.api.build(jnp.asarray(data), jax.random.key(0),
                          IndexSpec(kind="static", K=4, L=4, c=1.5,
                                    beta_override=0.1, Nr=32, leaf_size=16))
    det.save(tmp_path / "s")
    with pytest.raises(ValueError, match="pdet"):
        repro.api.load(tmp_path / "s", placement=PlacementSpec())


# ---------------------------------------------------------------------------
# Same-process multi-device variants (the dedicated CI job forces 4 host
# devices; auto-skipped when this session has fewer)
# ---------------------------------------------------------------------------

needs_devices = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4")


@pytest.mark.multidevice
@needs_devices
def test_multidevice_build_search_roundtrip(tmp_path):
    data, queries = _data_and_queries()
    spec = IndexSpec(placement=PlacementSpec(mesh_shape=(4,),
                                             mesh_axes=("data",)),
                     **SPEC_KW)
    idx = repro.api.build(jnp.asarray(data), jax.random.key(0), spec)
    assert isinstance(idx, AnnIndex)
    res = idx.search(jnp.asarray(queries), SearchRequest(k=10, r_min=0.5))
    assert res.stats.engine == "pdet"
    assert np.asarray(res.stats.shard_candidates).shape == (4,)
    ref_ids, ref_dists = _det_reference(k=10, engine="fused")
    np.testing.assert_array_equal(np.asarray(res.ids), ref_ids)
    assert np.array_equal(np.asarray(res.dists).view(np.uint32),
                          ref_dists.view(np.uint32))

    idx.save(tmp_path / "snap")
    for placement in (None,
                      PlacementSpec(mesh_shape=(2,), mesh_axes=("data",)),
                      PlacementSpec(mesh_shape=(2, 2),
                                    mesh_axes=("pod", "data"))):
        loaded = repro.api.load(tmp_path / "snap", placement=placement)
        # the attached spec describes the index as it now lives: a
        # resharded load must not keep the stale saved placement
        assert loaded.spec.placement == loaded.placement
        lres = loaded.search(jnp.asarray(queries),
                             SearchRequest(k=10, r_min=0.5))
        np.testing.assert_array_equal(np.asarray(lres.ids),
                                      np.asarray(res.ids))
        np.testing.assert_array_equal(np.asarray(lres.dists),
                                      np.asarray(res.dists))


@pytest.mark.multidevice
@needs_devices
def test_multidevice_padded_layout_bit_identical(tmp_path):
    """4000 points at leaf_size 32 -> 125 leaves per tree: not a multiple
    of 4 shards, so the padded-layout path runs — and must still answer
    bitwise like the unplaced DETLSH, through a snapshot too."""
    rng = np.random.default_rng(3)
    data = make_clustered(rng, 4000, D)
    queries = jnp.asarray(make_queries_near(data, rng, 12))
    kw = dict(kind="static", K=4, L=4, c=1.5, beta_override=0.1,
              Nr=32, leaf_size=32)
    pdet = repro.api.build(
        jnp.asarray(data), jax.random.key(1),
        IndexSpec(placement=PlacementSpec(mesh_shape=(4,),
                                          mesh_axes=("data",)), **kw))
    det = repro.api.build(jnp.asarray(data), jax.random.key(1),
                          IndexSpec(**kw))
    a = pdet.search(queries, SearchRequest(k=8, r_min=0.5))
    b = det.search(queries, SearchRequest(k=8, r_min=0.5, engine="fused"))
    assert a.stats.engine == "pdet"
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    assert np.array_equal(np.asarray(a.dists).view(np.uint32),
                          np.asarray(b.dists).view(np.uint32))
    pdet.save(tmp_path / "snap")
    loaded = repro.api.load(
        tmp_path / "snap",
        placement=PlacementSpec(mesh_shape=(3,), mesh_axes=("data",)))
    lres = loaded.search(queries, SearchRequest(k=8, r_min=0.5))
    np.testing.assert_array_equal(np.asarray(lres.ids), np.asarray(a.ids))
    np.testing.assert_array_equal(np.asarray(lres.dists),
                                  np.asarray(a.dists))


@pytest.mark.multidevice
@needs_devices
def test_multidevice_r_min_cache_matches_detlsh():
    """With r_min=None both indexes estimate from the same rows, so the
    bit-identity contract holds for default searches too."""
    data, queries = _data_and_queries()
    spec = IndexSpec(placement=PlacementSpec(mesh_shape=(4,),
                                             mesh_axes=("data",)),
                     **SPEC_KW)
    idx = repro.api.build(jnp.asarray(data), jax.random.key(0), spec)
    det = repro.api.build(jnp.asarray(data), jax.random.key(0),
                          IndexSpec(**SPEC_KW))
    a = idx.search(jnp.asarray(queries), SearchRequest(k=7))
    b = det.search(jnp.asarray(queries), SearchRequest(k=7, engine="fused"))
    assert a.stats.r_min == b.stats.r_min
    assert not a.stats.r_min_cached
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    a2 = idx.search(jnp.asarray(queries), SearchRequest(k=7))
    assert a2.stats.r_min_cached
