"""Sharding rules unit tests (no multi-device needed: AbstractMesh-free,
1-device mesh behaves as size-1 axes; divisibility logic is pure)."""

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.specs import apply_mesh_padding
from repro.sharding import rules as R


class FakeMesh:
    """Duck-typed mesh exposing .shape for rule resolution tests."""

    def __init__(self, shape):
        self.shape = dict(shape)


def _rules(shape):
    r = R.ShardingRules.__new__(R.ShardingRules)
    r.mesh = FakeMesh(shape)
    r.rules = dict(R.DEFAULT_RULES)
    for k, v in list(r.rules.items()):
        r.rules[k] = r._filter_axes(v)
    return r


def test_spec_divisibility_fallback():
    r = _rules({"data": 16, "model": 16})
    # vocab 51865 not divisible by 16 -> replicated; 51968 is -> sharded
    assert r.spec(("vocab",), (51865,)) == P(None)
    assert r.spec(("vocab",), (51968,)) == P("model")


def test_spec_no_axis_reuse():
    r = _rules({"data": 4, "model": 4})
    # heads and d_ff both map to 'model': second one must fall back
    spec = r.spec(("heads", "d_ff"), (8, 16))
    assert spec == P("model", None)


def test_missing_axes_are_dropped():
    r = _rules({"data": 8})        # no 'model', no 'pod'
    assert r.rules["d_ff"] is None
    assert r.rules["batch"] == "data"
    assert r.spec(("batch", "d_ff"), (16, 64)) == P("data", None)


def test_param_logical_axes_matches_nested_opt_state():
    w = jnp.zeros((4, 128, 256))   # stacked-by-layer w_gate
    path = (jax.tree_util.DictKey("m"), jax.tree_util.DictKey("layers"),
            jax.tree_util.DictKey("mlp"), jax.tree_util.DictKey("w_gate"))
    axes = R.param_logical_axes(path, w)
    assert axes == (None, "fsdp", "d_ff")
    # int8 code leaf keeps the param rank
    path_q = path + (jax.tree_util.DictKey("q"),)
    assert R.param_logical_axes(path_q, w) == (None, "fsdp", "d_ff")


def test_head_padding_policy():
    r = _rules({"data": 16, "model": 16})
    # qwen1.5-32b: 40 q heads -> 48, kv 40 -> 48 (divides 48)
    cfg = apply_mesh_padding(get_config("qwen1.5-32b"), r)
    assert cfg.n_heads == 48 and cfg.n_kv_heads == 48
    # hymba: 25 -> 32, kv 5 -> 8
    cfg = apply_mesh_padding(get_config("hymba-1.5b"), r)
    assert cfg.n_heads == 32 and cfg.n_kv_heads == 8
    assert cfg.n_heads % cfg.n_kv_heads == 0
    # whisper: 8 heads < 16 -> unpadded (attention replicated)
    cfg = apply_mesh_padding(get_config("whisper-base"), r)
    assert cfg.n_heads == 8
    # vocab padded to a 128 multiple, original kept in vocab_real
    assert cfg.vocab_size % 128 == 0
    assert cfg.vocab_real == 51865


def test_all_archs_padding_invariants():
    r = _rules({"pod": 2, "data": 16, "model": 16})
    from repro.configs import list_archs
    for arch in list_archs():
        cfg = apply_mesh_padding(get_config(arch), r)
        assert cfg.n_heads % cfg.n_kv_heads == 0, arch
        assert cfg.vocab_size % 128 == 0 or cfg.vocab_size == \
            get_config(arch).vocab_size, arch
        if cfg.n_heads >= 16:
            assert cfg.n_heads % 16 == 0, arch


def test_constrain_noop_without_rules():
    x = jnp.ones((4, 4))
    y = R.constrain(x, ("batch", None))
    assert y is x
