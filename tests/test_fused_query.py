"""Fused batched query engine: equivalence/regression vs the vmap baseline.

The fused engine admits a *superset* of the vmap engine's per-round
candidates (leaf-granular admission without the top-M cut; docs/DESIGN.md
§3), so per-query results need not be bitwise equal — the contracts are:

  * returned distances are exact and ascending, ids valid;
  * per-query candidate count >= the vmap engine's (superset admission);
  * recall on a small synthetic dataset is no worse than the vmap baseline
    (the regression gate for engine changes);
  * the engine is shape-stable across batch sizes and jit-compatible.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DETLSH, derive_params, estimate_r_min
from repro.core.query import (QueryConfig, fused_query_batch, knn_query_batch,
                              make_fused_plan)
from tests.conftest import brute_force_knn, make_clustered


@pytest.fixture(scope="module")
def built():
    rng = np.random.default_rng(7)
    data = make_clustered(rng, 4096, 24)
    queries = make_clustered(rng, 12, 24)
    p = derive_params(K=4, c=1.5, L=8, beta_override=0.1)
    idx = DETLSH.build(jnp.asarray(data), jax.random.key(3), p, leaf_size=64)
    r0 = estimate_r_min(idx.data, jnp.asarray(queries), 10, p.c)
    return idx, data, queries, r0


def _run(idx, queries, r0, engine, k=10):
    cfg = QueryConfig(k=k, M=8, r_min=r0, engine=engine)
    return knn_query_batch(idx.data, idx.forest, idx.A, idx.params,
                           jnp.asarray(queries), cfg)


def test_fused_returns_valid_sorted_exact(built):
    idx, data, queries, r0 = built
    res = _run(idx, queries, r0, "fused")
    ids = np.asarray(res.ids)
    dd = np.asarray(res.dists)
    n = data.shape[0]
    assert ids.shape == (len(queries), 10)
    assert np.all((ids >= 0) & (ids < n))
    assert np.all(np.diff(dd, axis=1) >= -1e-5)
    true = np.sqrt(((data[ids] - queries[:, None, :]) ** 2).sum(-1))
    np.testing.assert_allclose(dd, true, rtol=1e-4, atol=1e-4)


def test_fused_candidates_superset_of_vmap(built):
    """Same radius schedule, admission without top-M: at every lane the
    fused |S| can only be >= the vmap |S| at an equal-or-earlier round."""
    idx, data, queries, r0 = built
    res_f = _run(idx, queries, r0, "fused")
    res_v = _run(idx, queries, r0, "vmap")
    # Lanes that stopped at the same round saw a superset of candidates.
    same = np.asarray(res_f.rounds) == np.asarray(res_v.rounds)
    assert np.all(np.asarray(res_f.n_candidates)[same]
                  >= np.asarray(res_v.n_candidates)[same])
    # Superset admission can only stop the radius schedule earlier.
    assert np.all(np.asarray(res_f.final_r) <= np.asarray(res_v.final_r) + 1e-5)


def test_fused_recall_no_worse_than_vmap(built):
    """The regression gate: batched-engine recall matches (>=) the vmap
    baseline on the synthetic workload."""
    idx, data, queries, r0 = built
    k = 10
    gt_i, gt_d = brute_force_knn(data, queries, k)
    rec = {}
    for engine in ("fused", "vmap"):
        ids = np.asarray(_run(idx, queries, r0, engine).ids)
        rec[engine] = np.mean([len(set(ids[i]) & set(gt_i[i])) / k
                               for i in range(len(queries))])
    assert rec["fused"] >= rec["vmap"] - 1e-9, rec
    assert rec["fused"] >= 0.5, rec
    # c^2 quality bound holds for the fused engine too (Theorem 2 scope).
    dd = np.asarray(_run(idx, queries, r0, "fused").dists)
    ok = np.all(dd <= idx.params.c ** 2 * gt_d + 1e-4, axis=1)
    assert ok.mean() >= idx.params.success_probability


def test_fused_batch_sizes_and_jit(built):
    idx, data, queries, r0 = built
    plan = make_fused_plan(idx.data, idx.forest)
    cfg = QueryConfig(k=5, r_min=r0, engine="fused")
    fn = jax.jit(lambda q: fused_query_batch(
        idx.data, idx.forest, idx.A, idx.params, q, cfg, plan=plan))
    for b in (1, 3, 8):
        res = fn(jnp.asarray(queries[:b]))
        assert res.ids.shape == (b, 5)
        assert np.all(np.isfinite(np.asarray(res.dists)))


def test_strict_mode_falls_back_to_vmap(built):
    """mode='strict' (unoptimized Alg. 3) is not expressible by the fused
    kernel's leaf-granular admission; the registry must route it to the
    vmap engine regardless of the requested engine.  (Engine selection has
    exactly one home — ``repro.api.registry.resolve_engine``; the old
    ``core.query._pick_engine`` shim is gone.)"""
    from repro.api.registry import resolve_engine
    assert not hasattr(__import__("repro.core.query", fromlist=[""]),
                       "_pick_engine")
    assert resolve_engine("fused", mode="strict") == "vmap"
    assert resolve_engine("auto", mode="leaf") == "fused"
    assert resolve_engine("vmap", mode="leaf") == "vmap"
    # auto is batch-size aware: tiny batches take the per-query path, but an
    # explicit engine='fused' is honored at any batch size.
    assert resolve_engine("auto", batch=1) == "vmap"
    assert resolve_engine("auto", batch=32) == "fused"
    assert resolve_engine("fused", batch=1) == "fused"
    with pytest.raises(ValueError):
        resolve_engine("warp")
