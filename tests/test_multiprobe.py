"""Multi-probe DE-Tree queries (probe_depth; docs/DESIGN.md §11).

Contracts under test:

  * probe_depth=0 is bit-identical to the unprobed engines — on both the
    fused and the vmap path, an explicit probe_depth=0 request produces
    byte-for-byte the results of a request without the field (property-
    tested across data seeds and engine configs);
  * at a fixed radius the probe admission is *nested*: candidates, recall,
    and the returned k-th distance are monotone in probe_depth;
  * SearchStats reports the probe counters (zero without probing, positive
    with it, probe_candidates <= n_candidates);
  * IndexSpec.probe_depth is the index's search-time default, overridden
    per-request by SearchRequest.probe_depth;
  * mode='strict' rejects probing eagerly (QueryConfig and SearchRequest);
  * engine='pdet' cannot probe (per-shard slack ranking would break the
    device-count-invariance contract) — explicit pdet raises, auto falls
    back to the fused engine;
  * the streaming index probes across segments and merges the counters.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro
from repro.api import IndexSpec, PlacementSpec, SearchRequest
from repro.core import DETLSH, derive_params, estimate_r_min
from repro.core.query import QueryConfig, knn_query_batch
from tests.conftest import brute_force_knn, make_clustered


@pytest.fixture(scope="module")
def built():
    rng = np.random.default_rng(11)
    data = make_clustered(rng, 4096, 24)
    queries = make_clustered(rng, 12, 24)
    p = derive_params(K=4, c=1.5, L=4, beta_override=0.1)
    idx = DETLSH.build(jnp.asarray(data), jax.random.key(5), p, leaf_size=32)
    r0 = estimate_r_min(idx.data, jnp.asarray(queries), 10, p.c)
    return idx, data, queries, r0


def _run(idx, queries, r0, engine, probe_depth, **kw):
    cfg = QueryConfig(k=10, M=8, r_min=r0, engine=engine,
                      probe_depth=probe_depth, **kw)
    return knn_query_batch(idx.data, idx.forest, idx.A, idx.params,
                           jnp.asarray(queries), cfg)


def _identical(a, b):
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))
    np.testing.assert_array_equal(np.asarray(a.rounds), np.asarray(b.rounds))
    np.testing.assert_array_equal(np.asarray(a.n_candidates),
                                  np.asarray(b.n_candidates))
    np.testing.assert_array_equal(np.asarray(a.final_r),
                                  np.asarray(b.final_r))


@pytest.mark.parametrize("engine", ["fused", "vmap"])
def test_probe_depth_zero_bit_identical(built, engine):
    idx, data, queries, r0 = built
    base = knn_query_batch(idx.data, idx.forest, idx.A, idx.params,
                           jnp.asarray(queries),
                           QueryConfig(k=10, M=8, r_min=r0, engine=engine))
    probed = _run(idx, queries, r0, engine, 0)
    _identical(base, probed)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10 ** 6), st.sampled_from(["fused", "vmap"]),
       st.sampled_from([2, 4]))
def test_property_probe_zero_identity_and_superset(seed, engine, L):
    """Across data seeds, engines, and forest sizes: probe_depth=0 ==
    no-probe bitwise, and probe_depth>0 only adds candidates."""
    rng = np.random.default_rng(seed)
    data = make_clustered(rng, 1024, 12)
    queries = make_clustered(rng, 8, 12)
    p = derive_params(K=4, c=1.5, L=L, beta_override=0.1)
    idx = DETLSH.build(jnp.asarray(data), jax.random.key(seed % 997), p,
                       leaf_size=16)
    r0 = estimate_r_min(idx.data, jnp.asarray(queries), 5, p.c)
    base = knn_query_batch(idx.data, idx.forest, idx.A, idx.params,
                           jnp.asarray(queries),
                           QueryConfig(k=5, M=8, r_min=r0, engine=engine))
    zero = knn_query_batch(idx.data, idx.forest, idx.A, idx.params,
                           jnp.asarray(queries),
                           QueryConfig(k=5, M=8, r_min=r0, engine=engine,
                                       probe_depth=0))
    _identical(base, zero)
    # fixed radius: probing admits a superset per round
    more = knn_query_batch(idx.data, idx.forest, idx.A, idx.params,
                           jnp.asarray(queries),
                           QueryConfig(k=5, M=8, r_min=r0, engine=engine,
                                       probe_depth=3, max_rounds=1))
    one = knn_query_batch(idx.data, idx.forest, idx.A, idx.params,
                          jnp.asarray(queries),
                          QueryConfig(k=5, M=8, r_min=r0, engine=engine,
                                      max_rounds=1))
    assert np.all(np.asarray(more.n_candidates)
                  >= np.asarray(one.n_candidates))


@pytest.mark.parametrize("engine", ["fused", "vmap"])
def test_recall_monotone_in_probe_depth_at_fixed_radius(built, engine):
    """At fixed (K, L, r) — explicit r_min, one round — the candidate sets
    are nested in probe_depth, so candidates/recall/k-th distance are all
    monotone.  (Across early-terminating multi-round runs the radius
    schedules differ, so only the fixed-radius form is a theorem.)"""
    idx, data, queries, r0 = built
    k = 10
    gt_i, _ = brute_force_knn(data, queries, k)
    prev_cand = None
    prev_recall = -1.0
    prev_kth = None
    for pd in (0, 1, 2, 4, 8):
        res = _run(idx, queries, r0, engine, pd, max_rounds=1)
        cand = np.asarray(res.n_candidates)
        ids = np.asarray(res.ids)
        recall = np.mean([len(set(ids[i]) & set(gt_i[i])) / k
                          for i in range(len(queries))])
        kth = np.asarray(res.dists)[:, -1]
        if prev_cand is not None:
            assert np.all(cand >= prev_cand), (engine, pd)
            assert recall >= prev_recall - 1e-12, (engine, pd)
            assert np.all(kth <= prev_kth + 1e-5), (engine, pd)
        prev_cand, prev_recall, prev_kth = cand, recall, kth
    assert prev_cand is not None and np.all(
        prev_cand >= np.asarray(_run(idx, queries, r0, engine, 0,
                                     max_rounds=1).n_candidates))


@pytest.mark.parametrize("engine", ["fused", "vmap"])
def test_probe_counters(built, engine):
    idx, data, queries, r0 = built
    res0 = _run(idx, queries, r0, engine, 0, max_rounds=1)
    resp = _run(idx, queries, r0, engine, 4, max_rounds=1)
    assert np.all(np.asarray(res0.probed_leaves) == 0)
    assert np.all(np.asarray(res0.probe_candidates) == 0)
    assert np.asarray(resp.probed_leaves).sum() > 0
    # probe_candidates counts per-tree probe admissions (work done), while
    # n_candidates dedups across trees — so the unique extra candidates vs
    # the unprobed run are a lower bound on the probe work counter.
    extra = (np.asarray(resp.n_candidates) - np.asarray(res0.n_candidates))
    assert np.all(extra >= 0)
    assert np.all(np.asarray(resp.probe_candidates) >= extra)


def test_spec_default_and_request_override(tmp_path):
    rng = np.random.default_rng(3)
    data = jnp.asarray(make_clustered(rng, 2048, 16))
    queries = jnp.asarray(make_clustered(rng, 10, 16))
    spec = IndexSpec(kind="static", K=4, L=3, c=1.5, beta_override=0.1,
                     leaf_size=32, probe_depth=3)
    index = repro.api.build(data, jax.random.key(0), spec)
    # plain request inherits the spec's probe default
    res = index.search(queries, SearchRequest(k=5))
    assert np.asarray(res.stats.probed_leaves).sum() > 0
    # request override wins — probe_depth=0 disables probing
    res0 = index.search(queries, SearchRequest(k=5, probe_depth=0))
    assert np.all(np.asarray(res0.stats.probed_leaves) == 0)
    # and the spec (with its default) round-trips through snapshots
    index.save(tmp_path / "probed")
    loaded = repro.api.load(tmp_path / "probed")
    res2 = loaded.search(queries, SearchRequest(k=5))
    assert np.asarray(res2.stats.probed_leaves).sum() > 0
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(res2.ids))


def test_strict_mode_rejects_probing():
    with pytest.raises(ValueError, match="strict"):
        QueryConfig(k=5, probe_depth=2, mode="strict")
    with pytest.raises(ValueError, match="strict"):
        SearchRequest(k=5, probe_depth=2, mode="strict")
    with pytest.raises(ValueError):
        SearchRequest(k=5, probe_depth=-1)
    with pytest.raises(ValueError):
        IndexSpec(probe_depth=-1)
    # a strict request on an index whose spec defaults to probing must not
    # inherit the default (strict lowers it to 0), not raise
    req = SearchRequest(k=5, mode="strict")
    cfg = req.to_query_config(r_min=1.0, default_probe_depth=3)
    assert cfg.probe_depth == 0 and cfg.mode == "strict"


def test_pdet_rejects_probe_and_auto_falls_back():
    rng = np.random.default_rng(9)
    data = jnp.asarray(make_clustered(rng, 2048, 16))
    queries = jnp.asarray(make_clustered(rng, 10, 16))
    spec = IndexSpec(kind="static", K=4, L=3, c=1.5, beta_override=0.1,
                     leaf_size=32, placement=PlacementSpec())
    index = repro.api.build(data, jax.random.key(0), spec)
    with pytest.raises(NotImplementedError, match="probe"):
        index.search(queries, SearchRequest(k=5, engine="pdet",
                                            probe_depth=2))
    # unspecified engine (auto-resolves to pdet on a mesh) + probing:
    # falls back to the fused engine instead of failing
    res = index.search(queries, SearchRequest(k=5, probe_depth=2))
    assert res.stats.engine == "fused"
    assert np.asarray(res.stats.probed_leaves).sum() > 0
    # and stays pdet (bit-identity contract intact) without probing
    res0 = index.search(queries, SearchRequest(k=5))
    assert res0.stats.engine == "pdet"


def test_streaming_probe_merges_counters():
    rng = np.random.default_rng(21)
    data = jnp.asarray(make_clustered(rng, 3072, 16))
    queries = jnp.asarray(make_clustered(rng, 10, 16))
    spec = IndexSpec(kind="streaming", K=4, L=3, c=1.5, beta_override=0.1,
                     leaf_size=32, probe_depth=2)
    index = repro.api.build(data[:2048], jax.random.key(0), spec)
    index.upsert(data[2048:])                            # second segment
    res = index.search(queries, SearchRequest(k=5))
    assert np.asarray(res.stats.probed_leaves).sum() > 0
    res0 = index.search(queries, SearchRequest(k=5, probe_depth=0))
    assert np.all(np.asarray(res0.stats.probed_leaves) == 0)
    assert np.all(np.asarray(res.stats.n_candidates)
                  >= np.asarray(res0.stats.n_candidates))
