"""Per-kernel interpret-mode validation against the ref.py oracles.

Every kernel is swept over shapes (including非 block-aligned ones exercising
the ops.py padding path) and dtypes, asserting allclose vs the pure-jnp
oracle — which itself is validated against a naive formulation where one
exists (attention).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(rng, shape, dtype=np.float32, scale=1.0):
    return jnp.asarray((rng.standard_normal(shape) * scale).astype(dtype))


# ---------------------------------------------------------------------------
# lsh_project
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,m", [(256, 128, 128), (300, 100, 64),
                                   (512, 960, 64), (1, 17, 3)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_lsh_project_matches_ref(rng, n, d, m, dtype):
    x = _rand(rng, (n, d)).astype(dtype)
    a = _rand(rng, (d, m)).astype(dtype)
    got = ops.lsh_project(x, a, interpret=True)
    want = ref.lsh_project(x, a)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 8)


# ---------------------------------------------------------------------------
# encode_bins
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,D,Nr", [(512, 64, 256), (700, 16, 64),
                                    (64, 4, 16), (1024, 128, 256)])
def test_encode_bins_matches_ref(rng, n, D, Nr):
    coords = _rand(rng, (n, D), scale=3.0)
    bp = jnp.sort(_rand(rng, (D, Nr + 1), scale=3.0), axis=1, stable=True)
    got = ops.encode_bins(coords, bp, interpret=True)
    want = ref.encode_bins(coords, bp)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_encode_bins_matches_core_encoding(rng):
    from repro.core import encoding as enc
    coords = _rand(rng, (512, 8), scale=2.0)
    bp = enc.select_breakpoints(coords, 32, method="full_sort")
    got = ops.encode_bins(coords, bp, interpret=True)
    want = enc.encode(coords, bp)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# build_fused: encode_pack / project_encode_pack (ref-oracle matrix; the
# multidevice CI job re-runs these under a forced 4-device host platform)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,K,L,Nr", [(512, 4, 8, 256), (300, 8, 2, 64),
                                      (64, 16, 1, 16), (1024, 2, 4, 128)])
def test_encode_pack_matches_ref(rng, n, K, L, Nr):
    coords = _rand(rng, (n, L * K), scale=3.0)
    bp = jnp.sort(_rand(rng, (L * K, Nr + 1), scale=3.0), axis=1, stable=True)
    got = ops.encode_pack(coords, bp, K=K, L=L, interpret=True, block_n=128)
    want = ref.encode_pack(coords, bp, K=K, L=L)
    for g, w, name in zip(got, want, ("proj_t", "codes_t", "key_hi",
                                      "key_lo")):
        assert g.dtype == w.dtype, (name, g.dtype, w.dtype)
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)


def test_encode_pack_codes_match_encode_bins(rng):
    """The fused kernel's codes are exactly the encode_bins codes, re-laid
    per tree, and its key words are exactly detree.interleave_keys."""
    from repro.core.detree import interleave_keys
    K, L, Nr, n = 4, 3, 32, 200
    coords = _rand(rng, (n, L * K), scale=2.0)
    bp = jnp.sort(_rand(rng, (L * K, Nr + 1), scale=2.0), axis=1, stable=True)
    proj_t, codes_t, key_hi, key_lo = ops.encode_pack(
        coords, bp, K=K, L=L, interpret=True, block_n=64)
    codes_flat = ops.encode_bins(coords, bp, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(codes_t),
        np.asarray(codes_flat).reshape(n, L, K).transpose(1, 0, 2))
    hi, lo = interleave_keys(codes_t, K)
    np.testing.assert_array_equal(np.asarray(key_hi), np.asarray(hi))
    np.testing.assert_array_equal(np.asarray(key_lo), np.asarray(lo))


@pytest.mark.parametrize("n,d,K,L,Nr", [(256, 32, 4, 4, 64),
                                        (100, 17, 2, 3, 16),
                                        (512, 128, 8, 2, 256)])
def test_project_encode_pack_matches_ref(rng, n, d, K, L, Nr):
    x = _rand(rng, (n, d))
    a = _rand(rng, (d, L * K))
    bp = jnp.sort(_rand(rng, (L * K, Nr + 1), scale=3.0), axis=1, stable=True)
    got = ops.project_encode_pack(x, a, bp, K=K, L=L, interpret=True,
                                  block_n=64)
    want = ref.project_encode_pack(x, a, bp, K=K, L=L)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=1e-5, atol=1e-5)       # proj: fp matmul
    for g, w, name in zip(got[1:], want[1:], ("codes_t", "key_hi",
                                              "key_lo")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)


# ---------------------------------------------------------------------------
# leaf_bounds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nl,K,Nr", [(256, 4, 256), (300, 16, 64),
                                     (17, 2, 16), (512, 8, 128)])
def test_leaf_bounds_matches_ref(rng, nl, K, Nr):
    bp = jnp.sort(_rand(rng, (K, Nr + 1), scale=3.0), axis=1, stable=True)
    lo = jnp.asarray(rng.integers(0, Nr, (nl, K)), jnp.int32)
    hi = jnp.clip(lo + jnp.asarray(rng.integers(0, 8, (nl, K)), jnp.int32),
                  0, Nr - 1)
    valid = jnp.asarray(rng.random(nl) > 0.1)
    q = _rand(rng, (K,), scale=2.0)
    lb_g, ub_g = ops.leaf_bounds(q, lo, hi, valid, bp, interpret=True)
    lb_w, ub_w = ref.leaf_bounds(q, lo, hi, valid, bp)
    np.testing.assert_allclose(np.asarray(lb_g), np.asarray(lb_w), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(ub_g), np.asarray(ub_w), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# l2_rerank
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,m,d", [(128, 256, 128), (1, 1000, 64),
                                   (20, 300, 420), (128, 256, 96)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_l2_rerank_matches_ref(rng, b, m, d, dtype):
    q = _rand(rng, (b, d)).astype(dtype)
    c = _rand(rng, (m, d)).astype(dtype)
    got = ops.l2_rerank(q, c, interpret=True)
    want = ref.l2_rerank(q, c)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol,
                               atol=tol)


def test_l2_rerank_is_euclidean(rng):
    q = _rand(rng, (4, 32))
    c = _rand(rng, (64, 32))
    got = np.asarray(ops.l2_rerank(q, c, interpret=True))
    want = np.sqrt(((np.asarray(q)[:, None] - np.asarray(c)[None]) ** 2
                    ).sum(-1))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# range_rerank (fused batched range query + exact rerank)
# ---------------------------------------------------------------------------

def _range_rerank_inputs(rng, L, B, K, nl, ls, d, E):
    q = _rand(rng, (B, d))
    qp = _rand(rng, (L, B, K))
    r = jnp.asarray(np.abs(rng.standard_normal(B)).astype(np.float32) * 2.0)
    r = r.at[0].set(-1.0)                      # an inactive (done) lane
    bp = jnp.sort(_rand(rng, (L, K, E), scale=3.0), axis=2, stable=True)
    lo = jnp.asarray(rng.integers(0, E - 1, (L, nl, K)), jnp.int32)
    hi = jnp.clip(lo + jnp.asarray(rng.integers(0, 4, (L, nl, K)), jnp.int32),
                  0, E - 2)
    lv = jnp.asarray(rng.random((L, nl)) > 0.15)
    pts = _rand(rng, (L, nl * ls, d))
    pv = jnp.asarray(rng.random((L, nl * ls)) > 0.1)
    return q, qp, r, lo, hi, lv, bp, pts, pv


@pytest.mark.parametrize("L,B,K,nl,ls,d,E",
                         [(2, 8, 4, 16, 8, 32, 17),
                          (3, 5, 4, 10, 8, 24, 9),      # non-aligned B/nl
                          (1, 16, 8, 8, 16, 64, 33),
                          (4, 3, 2, 24, 4, 16, 5)])
def test_range_rerank_matches_ref(rng, L, B, K, nl, ls, d, E):
    args = _range_rerank_inputs(rng, L, B, K, nl, ls, d, E)
    got = ops.range_rerank(*args, leaf_size=ls, interpret=True)
    want = ref.range_rerank(*args, leaf_size=ls)
    assert got.shape == (L, B, nl * ls)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


def test_range_rerank_admission_semantics(rng):
    """Finite entries are exactly the points of valid leaves with LB <= r,
    and carry the exact original-space distance."""
    L, B, K, nl, ls, d, E = 2, 4, 4, 12, 8, 16, 9
    q, qp, r, lo, hi, lv, bp, pts, pv = _range_rerank_inputs(
        rng, L, B, K, nl, ls, d, E)
    out = np.asarray(ops.range_rerank(q, qp, r, lo, hi, lv, bp, pts, pv,
                                      leaf_size=ls, interpret=True))
    for l in range(L):
        lb_all = np.stack([
            np.asarray(ref.leaf_bounds(qp[l, b], lo[l], hi[l], lv[l],
                                       bp[l])[0]) for b in range(B)])
        admit = (lb_all <= np.asarray(r)[:, None]) & np.asarray(lv[l])[None]
        admit_pts = np.repeat(admit, ls, axis=1) & np.asarray(pv[l])[None]
        np.testing.assert_array_equal(np.isfinite(out[l]), admit_pts)
        exact = np.sqrt((((np.asarray(q)[:, None, :]
                           - np.asarray(pts[l])[None, :, :]) ** 2).sum(-1)))
        np.testing.assert_allclose(out[l][admit_pts], exact[admit_pts],
                                   rtol=1e-4, atol=1e-4)
    assert not np.isfinite(out[:, 0]).any()    # the r=-1 lane admits nothing


# ---------------------------------------------------------------------------
# range_rerank: multi-probe (per-(tree, lane) admission radii + probe ranking)
# ---------------------------------------------------------------------------

def test_range_rerank_per_tree_radii_match_ref(rng):
    """2-D r_eff (L, B) — the form the fused engine passes after probe
    widening — takes the same padding/kernel path as the broadcast 1-D
    radii and matches the oracle."""
    L, B, K, nl, ls, d, E = 3, 5, 4, 10, 8, 24, 9
    q, qp, r, lo, hi, lv, bp, pts, pv = _range_rerank_inputs(
        rng, L, B, K, nl, ls, d, E)
    r2 = jnp.broadcast_to(r, (L, B)) + jnp.asarray(
        np.abs(rng.standard_normal((L, B))).astype(np.float32))
    r2 = jnp.where(r[None, :] < 0, -1.0, r2)       # keep done lanes done
    got = ops.range_rerank(q, qp, r2, lo, hi, lv, bp, pts, pv,
                           leaf_size=ls, interpret=True)
    want = ref.range_rerank(q, qp, r2, lo, hi, lv, bp, pts, pv,
                            leaf_size=ls)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)
    # per-(tree, lane) radii really differentiate trees: same lane, larger
    # radius on one tree must admit a superset of the smaller-radius tree
    base = ops.range_rerank(q, qp, jnp.broadcast_to(r, (L, B)), lo, hi, lv,
                            bp, pts, pv, leaf_size=ls, interpret=True)
    assert (np.isfinite(np.asarray(base)) <= np.isfinite(np.asarray(got))
            ).all()


@pytest.mark.parametrize("probe_depth", [1, 3, 16])   # 16 > nl: clamps
def test_range_rerank_probe_matches_ref(rng, probe_depth):
    L, B, K, nl, ls, d, E = 2, 5, 4, 12, 8, 16, 9
    args = _range_rerank_inputs(rng, L, B, K, nl, ls, d, E)
    got = ops.range_rerank(*args, leaf_size=ls, probe_depth=probe_depth,
                           interpret=True)
    want = ref.range_rerank(*args, leaf_size=ls, probe_depth=probe_depth)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


def test_range_rerank_probe_admission_semantics(rng):
    """probe_depth admits exactly the leaves within the widened radii
    r_adm = max(r, depth-th smallest outside LB): a superset of the
    probe_depth=0 admission, >= min(depth, n_outside) extra leaves per
    active (tree, lane) (ties admit more), and nothing on done lanes."""
    L, B, K, nl, ls, d, E = 2, 4, 4, 12, 8, 16, 9
    depth = 3
    q, qp, r, lo, hi, lv, bp, pts, pv = _range_rerank_inputs(
        rng, L, B, K, nl, ls, d, E)
    out0 = np.asarray(ops.range_rerank(q, qp, r, lo, hi, lv, bp, pts, pv,
                                       leaf_size=ls, interpret=True))
    outp = np.asarray(ops.range_rerank(q, qp, r, lo, hi, lv, bp, pts, pv,
                                       leaf_size=ls, probe_depth=depth,
                                       interpret=True))
    assert (np.isfinite(out0) <= np.isfinite(outp)).all()   # superset
    np.testing.assert_allclose(outp[np.isfinite(out0)],
                               out0[np.isfinite(out0)], rtol=1e-5, atol=1e-5)

    lb = np.asarray(ref.forest_leaf_lb(qp, lo, hi, lv, bp))
    r_adm, probe_mask = ref.probe_radii_from_lb(lb, r, depth)
    r_adm, probe_mask = np.asarray(r_adm), np.asarray(probe_mask)
    rr = np.asarray(r)
    for l in range(L):
        # finite entries == points of valid leaves with LB <= widened radius
        admit = (lb[l] <= r_adm[l][:, None]) & np.asarray(lv[l])[None]
        admit &= (rr >= 0)[:, None]
        admit_pts = np.repeat(admit, ls, axis=1) & np.asarray(pv[l])[None]
        np.testing.assert_array_equal(np.isfinite(outp[l]), admit_pts)
        for b in range(B):
            outside = (lb[l, b] > rr[b]) & np.isfinite(lb[l, b])
            if rr[b] < 0:
                assert probe_mask[l, b].sum() == 0
            else:
                assert probe_mask[l, b].sum() >= min(depth, outside.sum())
    assert not np.isfinite(outp[:, 0]).any()   # the r=-1 lane stays silent


def test_probe_depth_zero_is_identical(rng):
    """probe_depth=0 must be bit-identical to the unprobed kernel — it is
    the same call (the widening pre-pass is skipped entirely)."""
    L, B, K, nl, ls, d, E = 2, 8, 4, 16, 8, 32, 17
    args = _range_rerank_inputs(rng, L, B, K, nl, ls, d, E)
    a = np.asarray(ops.range_rerank(*args, leaf_size=ls, interpret=True))
    b = np.asarray(ops.range_rerank(*args, leaf_size=ls, probe_depth=0,
                                    interpret=True))
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("b,h,sq,sk,dh", [(1, 2, 128, 128, 64),
                                          (2, 1, 100, 260, 32),
                                          (1, 1, 128, 384, 128)])
def test_flash_attention_matches_naive(rng, b, h, sq, sk, dh, causal):
    if causal and sq != sk:
        pytest.skip("causal assumes aligned positions")
    q = _rand(rng, (b, h, sq, dh), scale=0.5)
    k = _rand(rng, (b, h, sk, dh), scale=0.5)
    v = _rand(rng, (b, h, sk, dh))
    got = ops.flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.attention_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


@pytest.mark.parametrize("causal", [False, True])
def test_blockwise_ref_matches_naive(rng, causal):
    """The XLA blockwise oracle (used in dry-run lowering) is itself exact."""
    q = _rand(rng, (2, 2, 64, 32), scale=0.5)
    k = _rand(rng, (2, 2, 64, 32), scale=0.5)
    v = _rand(rng, (2, 2, 64, 32))
    got = ref.flash_attention(q, k, v, causal=causal, block_k=16)
    want = ref.attention_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_flash_attention_bf16(rng):
    q = _rand(rng, (1, 2, 128, 64), scale=0.5).astype(jnp.bfloat16)
    k = _rand(rng, (1, 2, 128, 64), scale=0.5).astype(jnp.bfloat16)
    v = _rand(rng, (1, 2, 128, 64)).astype(jnp.bfloat16)
    got = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=5e-2,
                               atol=5e-2)
