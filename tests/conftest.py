import os
import sys

# Tests run on the single real CPU device.  Dry-run tests that need many
# placeholder devices spawn subprocesses with their own XLA_FLAGS (the flag
# must be set before jax initializes, and must NOT leak into other tests).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("JAX_ENABLE_X64", "0")

# Property tests use hypothesis when installed; otherwise fall back to the
# deterministic shim in tests/_shims (same API subset, no pip dependency).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_shims"))

import signal

import numpy as np
import pytest


@pytest.hookimpl(wrapper=True)
def pytest_runtest_call(item):
    """``@pytest.mark.timeout(N)`` fallback when pytest-timeout is not
    installed: SIGALRM aborts a hung test (e.g. a deadlocked epoch
    refcount) instead of hanging the whole job.  The real plugin — listed
    in the [test] extra and present in CI — takes precedence; this shim
    only fires when the container lacks it (no pip dependency)."""
    marker = item.get_closest_marker("timeout")
    limit = marker.args[0] if (marker and marker.args) else None
    if (limit is None or item.config.pluginmanager.hasplugin("timeout")
            or not hasattr(signal, "SIGALRM")):
        return (yield)

    def _on_alarm(signum, frame):
        raise TimeoutError(
            f"{item.nodeid} exceeded its {limit}s timeout "
            f"(conftest SIGALRM fallback)")

    old = signal.signal(signal.SIGALRM, _on_alarm)
    signal.setitimer(signal.ITIMER_REAL, float(limit))
    try:
        return (yield)
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0.0)
        signal.signal(signal.SIGALRM, old)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_clustered(rng, n, d, n_clusters=32, spread=0.15):
    """Clustered vectors — the structured regime ANN benchmarks use."""
    centers = rng.standard_normal((n_clusters, d)).astype(np.float32)
    assign = rng.integers(0, n_clusters, n)
    return (centers[assign]
            + spread * rng.standard_normal((n, d)).astype(np.float32))


@pytest.fixture(scope="session")
def small_dataset(rng):
    data = make_clustered(rng, 8192, 24)
    queries = make_clustered(rng, 16, 24)
    return data, queries


def make_queries_near(data, rng, nq, noise=0.1):
    """Queries near the data manifold (the paper draws queries from the
    dataset itself, §VI-A) — perturbed copies of random data points."""
    sel = rng.choice(len(data), nq, replace=False)
    return (data[sel]
            + noise * rng.standard_normal((nq, data.shape[1]))
            .astype(np.float32))


def brute_force_knn(data, queries, k):
    d2 = ((queries[:, None, :] - data[None, :, :]) ** 2).sum(-1)
    idx = np.argsort(d2, axis=1, kind="stable")[:, :k]
    return idx, np.sqrt(np.take_along_axis(d2, idx, axis=1))
