"""Snapshot persistence: save -> load -> search equality (repro.api).

The contract (docs/DESIGN.md §6): a reloaded index answers every search
with bit-identical ids and distances, on both engines, for both index
kinds — including a streaming index carrying pre-compaction tombstones
and un-sealed delta rows.  Plus the format-version gate: a snapshot from
an incompatible format version is rejected, never misread.
"""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.api import (AnnIndex, IndexSpec, MutableAnnIndex, SearchRequest,
                       SnapshotFormatError)
from tests.conftest import make_clustered, make_queries_near

D = 16


def _assert_identical_answers(a, b, queries, k):
    for engine in ("fused", "vmap"):
        req = SearchRequest(k=k, engine=engine)
        ra = a.search(queries, req)
        rb = b.search(queries, req)
        np.testing.assert_array_equal(np.asarray(ra.ids),
                                      np.asarray(rb.ids), err_msg=engine)
        np.testing.assert_array_equal(np.asarray(ra.dists),
                                      np.asarray(rb.dists), err_msg=engine)


@pytest.fixture(scope="module")
def static_index():
    rng = np.random.default_rng(0)
    data = make_clustered(rng, 2048, D)
    spec = IndexSpec(kind="static", K=4, L=8, c=1.5, beta_override=0.1,
                     Nr=32, leaf_size=32)
    idx = repro.api.build(jnp.asarray(data), jax.random.key(0), spec)
    queries = jnp.asarray(make_queries_near(data, rng, 12))
    return idx, queries


@pytest.fixture(scope="module")
def streaming_index():
    rng = np.random.default_rng(1)
    data = make_clustered(rng, 800, D)
    spec = IndexSpec(kind="streaming", K=4, L=4, c=1.5, beta_override=0.1,
                     Nr=32, leaf_size=16, delta_capacity=64, max_segments=4)
    idx = repro.api.build(jnp.asarray(data), jax.random.key(0), spec)
    gids = idx.upsert(make_clustered(rng, 150, D))  # 2 seals + live delta
    idx.delete(np.arange(0, 40))                    # base tombstones
    idx.delete(gids[:10])                           # sealed-delta + delta
    assert idx.memtable.n_live > 0                  # un-sealed rows persist
    assert any(s.has_tombstones for s in idx.manifest.segments)
    queries = jnp.asarray(make_queries_near(data, rng, 12))
    return idx, queries


def test_static_roundtrip_bit_identical(static_index, tmp_path):
    idx, queries = static_index
    idx.fused_plan()                   # snapshot the plan constants too
    idx.save(tmp_path / "static")
    loaded = repro.api.load(tmp_path / "static")
    assert isinstance(loaded, AnnIndex)
    assert not isinstance(loaded, MutableAnnIndex)
    assert loaded.n_points == idx.n_points
    assert loaded.params == idx.params
    assert loaded.spec == idx.spec
    assert loaded._plan is not None    # fused-plan constants round-trip
    _assert_identical_answers(idx, loaded, queries, k=10)


def test_static_rmin_cache_roundtrip(static_index, tmp_path):
    """The cached per-k radius estimates persist, so a restarted service
    answers r_min=None requests identically without re-estimating."""
    idx, queries = static_index
    idx.search(queries, SearchRequest(k=7))        # populate cache for k=7
    idx.save(tmp_path / "s2")
    loaded = repro.api.load(tmp_path / "s2")
    assert loaded._r_min_cache[7] == idx._r_min_cache[7]
    ra = idx.search(queries, SearchRequest(k=7))
    rb = loaded.search(queries, SearchRequest(k=7))
    assert rb.stats.r_min == ra.stats.r_min and rb.stats.r_min_cached
    np.testing.assert_array_equal(np.asarray(ra.ids), np.asarray(rb.ids))


def test_streaming_roundtrip_bit_identical(streaming_index, tmp_path):
    """Pre-compaction tombstones, sealed segments, and un-sealed delta
    rows all survive the round trip; answers are bit-identical."""
    idx, queries = streaming_index
    idx.save(tmp_path / "stream")
    loaded = repro.api.load(tmp_path / "stream")
    assert isinstance(loaded, MutableAnnIndex)
    assert loaded.n_live == idx.n_live
    assert loaded.n_total == idx.n_total
    assert loaded.next_gid == idx.next_gid
    assert loaded.locator == idx.locator
    assert loaded.memtable.count == idx.memtable.count
    _assert_identical_answers(idx, loaded, queries, k=10)


def test_streaming_loaded_index_still_mutable(streaming_index, tmp_path):
    """A restored index is not a read-only replica: upsert/delete/seal/
    compact continue exactly where the snapshot left off."""
    idx, queries = streaming_index
    idx.save(tmp_path / "stream2")
    loaded = repro.api.load(tmp_path / "stream2")
    rng = np.random.default_rng(7)
    probe = (make_clustered(rng, 1, D)[0] + 60.0).astype(np.float32)
    [gid] = loaded.upsert(probe)
    assert int(gid) == idx.next_gid    # gid allocation resumes, no clashes
    res = loaded.search(jnp.asarray(probe[None, :]),
                        SearchRequest(k=1, r_min=1.0))
    assert int(np.asarray(res.ids)[0, 0]) == int(gid)
    loaded.delete([gid])
    loaded.flush()
    assert loaded.compact()
    assert loaded.n_live == idx.n_live


def test_stale_streaming_rmin_cache_not_persisted(tmp_path):
    """A radius cache invalidated by mutation must not be resurrected as
    fresh by save -> load (loaded must re-estimate, like the original)."""
    rng = np.random.default_rng(5)
    data = make_clustered(rng, 256, D)
    idx = repro.api.build(
        jnp.asarray(data), jax.random.key(0),
        IndexSpec(kind="streaming", K=4, L=4, c=1.5, beta_override=0.1,
                  Nr=32, leaf_size=16, delta_capacity=32))
    q = jnp.asarray(make_queries_near(data, rng, 4))
    idx.search(q, SearchRequest(k=5))              # populate cache
    idx.upsert(make_clustered(rng, 3, D))          # invalidate it
    idx.save(tmp_path / "stale")
    loaded = repro.api.load(tmp_path / "stale")
    assert loaded._rmin_cache[1] == {}             # stale entries dropped
    ra = idx.search(q, SearchRequest(k=5))
    rb = loaded.search(q, SearchRequest(k=5))
    assert not ra.stats.r_min_cached and not rb.stats.r_min_cached
    assert ra.stats.r_min == rb.stats.r_min
    np.testing.assert_array_equal(np.asarray(ra.ids), np.asarray(rb.ids))


def test_resave_after_compaction_drops_stale_segment_files(tmp_path):
    """Re-saving into the same directory must not leave .npz files the
    new manifest no longer references (pre-compaction segments)."""
    rng = np.random.default_rng(6)
    data = make_clustered(rng, 256, D)
    idx = repro.api.build(
        jnp.asarray(data), jax.random.key(0),
        IndexSpec(kind="streaming", K=4, L=4, c=1.5, beta_override=0.1,
                  Nr=32, leaf_size=16, delta_capacity=32, max_segments=8))
    idx.upsert(make_clustered(rng, 64, D))         # +2 sealed segments
    path = tmp_path / "resave"
    idx.save(path)
    assert len([f for f in os.listdir(path)
                if f.startswith("segment_")]) == 3
    idx.compact()                                  # 3 segments -> 1
    idx.save(path)
    seg_files = [f for f in os.listdir(path) if f.startswith("segment_")]
    assert len(seg_files) == 1                     # stale files removed
    loaded = repro.api.load(path)
    assert loaded.n_live == idx.n_live
    q = jnp.asarray(make_queries_near(data, rng, 4))
    ra, rb = idx.search(q, SearchRequest(k=5)), \
        loaded.search(q, SearchRequest(k=5))
    np.testing.assert_array_equal(np.asarray(ra.ids), np.asarray(rb.ids))


def test_format_version_mismatch_rejected(static_index, tmp_path):
    idx, _ = static_index
    path = tmp_path / "vers"
    idx.save(path)
    mpath = os.path.join(path, "MANIFEST.json")
    manifest = json.load(open(mpath))
    manifest["format_version"] = 999
    json.dump(manifest, open(mpath, "w"))
    with pytest.raises(SnapshotFormatError, match="format_version"):
        repro.api.load(path)


def test_format_version_1_still_readable(static_index, tmp_path):
    """Version 2 only *added* the pdet kind; a version-1 static/streaming
    snapshot (previous release) must keep loading — upgrading repro must
    never force the rebuild persistence exists to avoid."""
    idx, queries = static_index
    path = tmp_path / "v1"
    idx.save(path)
    mpath = os.path.join(path, "MANIFEST.json")
    manifest = json.load(open(mpath))
    manifest["format_version"] = 1
    json.dump(manifest, open(mpath, "w"))
    loaded = repro.api.load(path)
    _assert_identical_answers(idx, loaded, queries, k=10)


def test_non_snapshot_directory_rejected(tmp_path):
    with pytest.raises(SnapshotFormatError, match="MANIFEST"):
        repro.api.load(tmp_path)


def test_spec_unknown_field_rejected():
    spec = IndexSpec(kind="static", K=4, L=4, c=1.5)
    d = dict(spec.to_dict(), not_a_field=1)
    with pytest.raises(ValueError, match="not_a_field"):
        IndexSpec.from_dict(d)
    assert IndexSpec.from_dict(spec.to_dict()) == spec
    assert dataclasses.asdict(spec) == spec.to_dict()
