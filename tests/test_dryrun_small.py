"""Dry-run machinery tests on a small placeholder-device mesh (subprocess,
since XLA fixes device count at first jax init).

The production 512-device sweep runs via ``python -m repro.launch.dryrun``;
here we validate the harness end-to-end (lower+compile+memory/cost/
collective records) at 8 devices for one representative arch per family.
"""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, REPRO_DRYRUN_DEVICES="8",
           PYTHONPATH=os.path.join(REPO, "src"))


def _run_dryrun(args, timeout=900):
    cmd = [sys.executable, "-m", "repro.launch.dryrun"] + args
    return subprocess.run(cmd, env=ENV, capture_output=True, text=True,
                          timeout=timeout, cwd=REPO)


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("whisper-base", "train_4k"),          # encdec
    ("granite-moe-1b-a400m", "decode_32k"),  # moe decode
    ("mamba2-780m", "long_500k"),          # ssm long-context decode
])
def test_dryrun_cell_compiles(tmp_path, arch, shape):
    out = tmp_path / "dry.json"
    r = _run_dryrun(["--arch", arch, "--shape", shape, "--mesh", "custom",
                     "--mesh-shape", "4,2:data,model", "--out", str(out)])
    assert r.returncode == 0, r.stderr[-3000:]
    recs = json.load(open(out))
    assert len(recs) == 1
    rec = recs[0]
    assert "error" not in rec, rec.get("error")
    assert rec["cost"]["hlo_flops_once"] > 0
    assert rec["memory"]["live_bytes"] > 0
    assert any(v["entry"] + v["body"] > 0
               for v in rec["collectives"].values()), \
        "expected at least one collective on a 2-way model mesh"


@pytest.mark.slow
def test_dryrun_lsh_compiles(tmp_path):
    out = tmp_path / "lsh.json"
    cmd = [sys.executable, "-m", "repro.launch.dryrun_lsh", "--mesh",
           "custom", "--mesh-shape", "4,2:data,model", "--n", "200000",
           "--out", str(out)]
    r = subprocess.run(cmd, env=ENV, capture_output=True, text=True,
                       timeout=900, cwd=REPO)
    assert r.returncode == 0, r.stderr[-3000:]
    recs = json.load(open(out))
    assert {x["workload"] for x in recs} == {"pdet_build", "pdet_query"}
    for rec in recs:
        assert rec["memory"]["live_bytes"] > 0


def test_collective_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
ENTRY %main (p0: f32[8,16]) -> f32[8,16] {
  %ar = f32[8,16]{1,0} all-reduce(f32[8,16]{1,0} %p0), replica_groups={}
}
%body_1 (p: f32[4]) -> f32[4] {
  %ag = f32[16]{0} all-gather(f32[4]{0} %p), dimensions={0}
}
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"]["entry"] == 8 * 16 * 4
    assert out["all-gather"]["body"] == 4 * 4


def test_roofline_derivation_runs():
    from benchmarks.roofline import derive
    path = os.path.join(REPO, "experiments", "dryrun.json")
    if not os.path.exists(path):
        pytest.skip("production dry-run artifact not present")
    rows = derive(json.load(open(path)))
    assert len(rows) >= 40
    for r in rows:
        assert r["bottleneck"] in ("compute", "memory", "collective")
        assert r["compute_s"] > 0
