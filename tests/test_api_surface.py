"""API-surface snapshot: the exported names and signatures of ``repro.api``.

An accidental rename, removal, or signature change in the public surface
must fail tier-1 — this is the compatibility gate for everything
downstream of the protocol (serving, benchmarks, examples, user code).
Extending the surface (new exports, new defaulted fields *appended* after
the existing ones) is allowed; changing what exists is a breaking change
and needs a deliberate update here plus a deprecation note in CHANGES.md.
"""

import dataclasses
import inspect

import pytest

import repro
import repro.api


EXPECTED_API_EXPORTS = {
    "AnnIndex", "MutableAnnIndex", "LegacyIndexAdapter", "as_ann_index",
    "IndexSpec", "PlacementSpec", "PDETIndex",
    "SearchRequest", "SearchResult", "SearchStats",
    "Rejected",
    "EngineSpec", "register_engine", "resolve_engine", "available_engines",
    "get_engine", "build", "tune", "suggest_params", "TuneResult",
    "load", "save",
    "SnapshotFormatError", "SnapshotIntegrityError", "FORMAT_VERSION",
}

# Field ORDER is part of the surface (positional construction).
EXPECTED_SEARCH_REQUEST_FIELDS = (
    "k", "r_min", "M", "mode", "engine", "n_active", "max_rounds",
    "dist_impl", "bounds_impl", "deadline", "probe_depth",
)

EXPECTED_INDEX_SPEC_FIELDS = (
    "kind", "K", "L", "c", "beta_override", "Nr", "leaf_size",
    "breakpoint_method", "project_impl", "encode_impl", "engine",
    "block_q", "block_l", "delta_capacity", "max_segments", "id_capacity",
    "placement", "build_impl", "build_chunk", "probe_depth",
)

EXPECTED_PLACEMENT_SPEC_FIELDS = ("mesh_shape", "mesh_axes", "data_axes")

# Appending defaulted fields is allowed; reordering/removing is breaking.
EXPECTED_SEARCH_STATS_FIELDS = (
    "engine", "r_min", "r_min_cached", "rounds", "n_candidates", "final_r",
    "shard_candidates", "psum_rounds", "merge_size", "degraded",
    "probed_leaves", "probe_candidates",
)

EXPECTED_PROTOCOL_MEMBERS = {
    "AnnIndex": {"n_points", "search", "r_min_for", "save",
                 "index_size_bytes"},
    "MutableAnnIndex": {"n_points", "search", "r_min_for", "save",
                        "index_size_bytes", "upsert", "delete",
                        "maybe_compact"},
}


def test_api_exports_snapshot():
    assert set(repro.api.__all__) == EXPECTED_API_EXPORTS
    for name in EXPECTED_API_EXPORTS:      # every name actually resolves
        assert getattr(repro.api, name) is not None
    assert EXPECTED_API_EXPORTS <= set(dir(repro.api))


def test_top_level_exports_snapshot():
    assert set(repro.__all__) == {"__version__", "api", "DETLSH",
                                  "StreamingDETLSH", "derive_params",
                                  "decode", "durability", "DurableIndex",
                                  "recover", "KVCacheIndex", "tune",
                                  "suggest_params", "TuneResult"}
    assert repro.DETLSH is not None
    assert repro.StreamingDETLSH is not None
    assert callable(repro.derive_params)
    assert repro.api.load is not None
    assert repro.KVCacheIndex is not None          # decode pillar (§10)
    assert repro.decode.LSHDecoder is not None
    assert callable(repro.suggest_params)          # tune pillar (§11)
    assert repro.TuneResult is repro.tune.TuneResult
    assert repro.api.tune is repro.tune.tune
    assert repro.DurableIndex is repro.durability.DurableIndex   # §13
    assert repro.recover is repro.durability.recover


def test_search_request_fields_snapshot():
    fields = tuple(f.name for f in dataclasses.fields(repro.api.SearchRequest))
    assert fields == EXPECTED_SEARCH_REQUEST_FIELDS
    # all defaulted: SearchRequest() must stay constructible bare
    repro.api.SearchRequest()


def test_index_spec_fields_snapshot():
    fields = tuple(f.name for f in dataclasses.fields(repro.api.IndexSpec))
    assert fields == EXPECTED_INDEX_SPEC_FIELDS
    repro.api.IndexSpec()


def test_callable_signatures_snapshot():
    assert list(inspect.signature(repro.api.load).parameters) == \
        ["path", "placement"]
    assert [p for p in inspect.signature(repro.api.build).parameters] == \
        ["data", "key", "spec"]
    assert [p for p in
            inspect.signature(repro.api.resolve_engine).parameters] == \
        ["requested", "mode", "batch", "mesh_devices"]
    sr = inspect.signature(repro.api.SearchResult)
    assert list(sr.parameters) == ["ids", "dists", "stats", "raw"]


def test_placement_spec_fields_snapshot():
    fields = tuple(f.name for f in
                   dataclasses.fields(repro.api.PlacementSpec))
    assert fields == EXPECTED_PLACEMENT_SPEC_FIELDS
    repro.api.PlacementSpec()          # constructible bare (1-device mesh)


def test_search_stats_fields_snapshot():
    assert repro.api.SearchStats._fields == EXPECTED_SEARCH_STATS_FIELDS
    # the per-shard counters are defaulted: non-pdet engines omit them
    s = repro.api.SearchStats(engine="vmap", r_min=1.0, r_min_cached=False,
                              rounds=None, n_candidates=None, final_r=None)
    assert s.shard_candidates is None and s.psum_rounds is None


@pytest.mark.parametrize("proto_name", sorted(EXPECTED_PROTOCOL_MEMBERS))
def test_protocol_members_snapshot(proto_name):
    import typing
    proto = getattr(repro.api, proto_name)
    if hasattr(typing, "get_protocol_members"):          # 3.12+
        members = set(typing.get_protocol_members(proto))
    elif hasattr(proto, "__protocol_attrs__"):           # 3.12 internal
        members = set(proto.__protocol_attrs__)
    else:                                                # 3.10/3.11
        members = set(typing._get_protocol_attrs(proto))
    assert members == EXPECTED_PROTOCOL_MEMBERS[proto_name]


def test_builtin_engines_registered():
    names = repro.api.available_engines()
    assert set(names) >= {"pdet", "fused", "vmap"}
    # priority order is the surface: pdet (mesh-gated) > fused > vmap
    assert names.index("pdet") < names.index("fused") < names.index("vmap")
    assert names[0] == "pdet"
